"""Generate the committed golden kernel vectors for backend parity.

Runs the pure-jnp oracle in ``kernels/ref.py`` (the same functions the
Pallas kernels are verified against) over a deterministic case set and
writes ``rust/tests/golden/kernel_vectors.json``.  The Rust side
(``rust/tests/backend_parity.rs``) replays every case through each
decision backend and asserts **exact** equality on the decide cases.

Exactness contract: every input is integral-valued f32 (real workloads
are — milli-cores and Mi are integers), so the masked overlap sums are
exact in any summation order, and the handful of non-integral ops
(``total/denom`` division, ``req*ratio``, ``remax*alpha``) are single
IEEE correctly-rounded f32 operations performed in the same order by
jax/XLA and the Rust evaluator.  JSON doubles represent every f32
exactly, so the vectors survive the round trip bit-for-bit.

Usage::

    cd python/compile && python3 gen_vectors.py

Regenerate only when the decision mathematics changes; the diff is the
review artifact.
"""

from __future__ import annotations

import json
import os

import numpy as np

from kernels.ref import aras_decide_ref, usage_integral_ref

SEED = 20230849  # arbitrary but fixed: vectors must never drift
OUT = os.path.join(
    os.path.dirname(os.path.abspath(__file__)),
    "..", "..", "rust", "tests", "golden", "kernel_vectors.json",
)


def f32(x):
    return np.asarray(x, dtype=np.float32)


def decide_case(rng, name, n_records, n_nodes, n_lanes, alpha, **over):
    """One fused-graph case: integral random state + ref outputs."""
    t_start = f32(rng.integers(0, 1000, n_records))
    cpu = f32(rng.integers(100, 4001, n_records))
    mem = f32(rng.integers(100, 8001, n_records))
    win_start = f32(rng.integers(0, 800, n_lanes))
    win_end = win_start + f32(rng.integers(1, 301, n_lanes))
    req_cpu = f32(rng.integers(100, 4001, n_lanes))
    req_mem = f32(rng.integers(100, 8001, n_lanes))
    node_cpu = f32(rng.integers(0, 8001, n_nodes))
    node_mem = f32(rng.integers(0, 16385, n_nodes))
    local = dict(locals())
    for key, value in over.items():
        assert key in local, f"unknown override {key}"
        local[key] = f32(value)
    (t_start, cpu, mem, win_start, win_end, req_cpu, req_mem, node_cpu, node_mem) = (
        local[k]
        for k in (
            "t_start", "cpu", "mem", "win_start", "win_end",
            "req_cpu", "req_mem", "node_cpu", "node_mem",
        )
    )
    # ref needs >=1 node row for argmax; model "no nodes" as one
    # zero-valued masked-out row (scalar parity: remax = total = 0).
    node_valid = np.ones(max(len(node_cpu), 1), dtype=np.float32)
    if len(node_cpu) == 0:
        node_cpu, node_mem, node_valid = f32([0]), f32([0]), f32([0])
    alloc_cpu, alloc_mem, request_cpu, request_mem = aras_decide_ref(
        t_start, cpu, mem, np.ones(n_records, dtype=np.float32),
        f32(win_start), f32(win_end), f32(req_cpu), f32(req_mem),
        node_cpu, node_mem, node_valid, np.float32(alpha),
    )
    return {
        "name": name,
        "records": [
            [float(t), float(c), float(m)] for t, c, m in zip(t_start, cpu, mem)
        ],
        "lanes": [
            {
                "win_start": float(ws), "win_end": float(we),
                "req_cpu": float(rc), "req_mem": float(rm),
            }
            for ws, we, rc, rm in zip(win_start, win_end, req_cpu, req_mem)
        ],
        "nodes": [
            [float(c), float(m)]
            for c, m, v in zip(node_cpu, node_mem, node_valid)
            if v > 0
        ],
        "alpha": float(np.float32(alpha)),
        "expect": [
            {
                "alloc_cpu": float(ac), "alloc_mem": float(am),
                "request_cpu": float(qc), "request_mem": float(qm),
            }
            for ac, am, qc, qm in zip(alloc_cpu, alloc_mem, request_cpu, request_mem)
        ],
    }


def usage_case(name, t, y, valid):
    expect = usage_integral_ref(f32(t), f32(y), f32(valid))
    return {
        "name": name,
        "t": [float(v) for v in f32(t)],
        "y": [float(v) for v in f32(y)],
        "valid": [float(v) for v in f32(valid)],
        "expect": float(expect),
    }


def main():
    rng = np.random.default_rng(SEED)
    decide = []
    # Bulk coverage: varied shapes, every batch width up to cap_batch.
    for i, (n_records, n_nodes, n_lanes) in enumerate(
        [(0, 1, 1), (1, 1, 1), (7, 3, 2), (24, 6, 4), (60, 12, 8),
         (128, 32, 8), (300, 6, 5), (40, 2, 3)]
    ):
        decide.append(decide_case(
            rng, f"random-{i}-r{n_records}-n{n_nodes}-b{n_lanes}",
            n_records, n_nodes, n_lanes, 0.8,
        ))
    # Alpha variants.
    decide.append(decide_case(rng, "alpha-0.5", 30, 6, 4, 0.5))
    decide.append(decide_case(rng, "alpha-1.0", 30, 6, 4, 1.0))
    # No live nodes: remax == total == 0, every regime-4 cut is 0.
    decide.append(decide_case(rng, "empty-nodes", 10, 0, 2, 0.8))
    # Window boundary: records exactly at win_start (in) and win_end (out).
    decide.append(decide_case(
        rng, "window-boundary", 4, 3, 2, 0.8,
        t_start=[100, 200, 100, 200],
        cpu=[1000, 2000, 4000, 800], mem=[1000, 2000, 4000, 800],
        win_start=[100, 150], win_end=[200, 250],
    ))
    # Contention: demand far beyond residuals forces regimes 2/3/4.
    decide.append(decide_case(
        rng, "contended-regimes", 50, 2, 4, 0.8,
        cpu=[4000] * 50, mem=[8000] * 50,
        win_start=[0, 0, 0, 0], win_end=[1000, 1000, 500, 2],
        node_cpu=[2000, 1500], node_mem=[4000, 3000],
    ))
    # Tied argmax-CPU nodes with different mem: first index must win.
    decide.append(decide_case(
        rng, "remax-tie-first-node", 8, 3, 2, 0.8,
        node_cpu=[5000, 5000, 4000], node_mem=[100, 16000, 8000],
    ))

    usage = [
        usage_case("flat-rate", [0, 10, 20], [2, 2, 2], [1, 1, 1]),
        usage_case("ramp", [0, 10, 20, 30], [0, 2, 4, 6], [1, 1, 1, 1]),
        usage_case("mid-invalid-gap", [0, 5, 10, 15], [2, 9, 2, 2], [1, 0, 1, 1]),
        usage_case("padded-tail", [0, 10, 10, 10], [1, 3, 0, 0], [1, 1, 0, 0]),
        usage_case("single-sample", [5], [7], [1]),
        usage_case("all-invalid", [0, 10], [1, 1], [0, 0]),
        usage_case("uneven-spacing", [0, 1, 4, 32], [8, 4, 2, 6], [1, 1, 1, 1]),
    ]

    doc = {
        "generator": "python/compile/gen_vectors.py",
        "source": "python/compile/kernels/ref.py",
        "seed": SEED,
        "decide": decide,
        "usage": usage,
    }
    with open(OUT, "w") as f:
        json.dump(doc, f, indent=1)
        f.write("\n")
    print(f"wrote {len(decide)} decide + {len(usage)} usage cases -> {OUT}")


if __name__ == "__main__":
    main()
