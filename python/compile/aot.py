"""AOT lowering: Layer-2 JAX graph -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  Lowering goes through stablehlo ->
XlaComputation with ``return_tuple=True`` (the Rust side unwraps with
``to_tuple``).

Artifacts written (all shapes static, see manifest.json):

* ``aras_decide.hlo.txt`` — the fused decision graph Rust runs on the
  allocation hot path.
* ``overlap.hlo.txt``     — the Layer-1 overlap kernel alone (runtime unit
  tests + bench_allocator).
* ``alloc_eval.hlo.txt``  — the Layer-1 evaluation kernel alone.
* ``manifest.json``       — capacities + artifact -> entry metadata parsed
  by rust/src/runtime/artifact.rs.

Run via ``make artifacts`` (no-op when inputs are unchanged).  Python never
runs after this point; the Rust binary is self-contained.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.alloc_eval import alloc_eval_pallas
from compile.kernels.overlap import overlap_pallas
from compile.kernels.usage_integral import usage_integral_pallas

# Static sample capacity for the usage-integral artifact (Figs 5-8 runs
# sample every 5 s over <= ~1.5 h => well under 4096).
CAP_SAMPLES = 4096


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_aras_decide():
    return jax.jit(model.aras_decide).lower(*model.example_args())


def lower_overlap():
    f32 = jnp.float32
    t = jax.ShapeDtypeStruct((model.CAP_TASKS,), f32)
    b = jax.ShapeDtypeStruct((model.CAP_BATCH,), f32)
    return jax.jit(overlap_pallas).lower(t, t, t, t, b, b, b, b)


def lower_alloc_eval():
    f32 = jnp.float32
    b = jax.ShapeDtypeStruct((model.CAP_BATCH,), f32)
    s = jax.ShapeDtypeStruct((), f32)
    return jax.jit(alloc_eval_pallas).lower(b, b, b, b, s, s, s, s, s)


def lower_usage_integral():
    f32 = jnp.float32
    n = jax.ShapeDtypeStruct((CAP_SAMPLES,), f32)
    return jax.jit(usage_integral_pallas).lower(n, n, n)


ARTIFACTS = {
    "aras_decide": (
        lower_aras_decide,
        {
            "inputs": [
                "t_start[T]", "cpu[T]", "mem[T]", "valid[T]",
                "win_start[B]", "win_end[B]", "req_cpu[B]", "req_mem[B]",
                "node_res_cpu[N]", "node_res_mem[N]", "node_valid[N]", "alpha[]",
            ],
            "outputs": ["alloc_cpu[B]", "alloc_mem[B]", "request_cpu[B]", "request_mem[B]"],
        },
    ),
    "overlap": (
        lower_overlap,
        {
            "inputs": [
                "t_start[T]", "cpu[T]", "mem[T]", "valid[T]",
                "win_start[B]", "win_end[B]", "req_cpu[B]", "req_mem[B]",
            ],
            "outputs": ["request_cpu[B]", "request_mem[B]"],
        },
    ),
    "alloc_eval": (
        lower_alloc_eval,
        {
            "inputs": [
                "req_cpu[B]", "req_mem[B]", "request_cpu[B]", "request_mem[B]",
                "total_res_cpu[]", "total_res_mem[]", "remax_cpu[]", "remax_mem[]", "alpha[]",
            ],
            "outputs": ["alloc_cpu[B]", "alloc_mem[B]"],
        },
    ),
    "usage_integral": (
        lower_usage_integral,
        {
            "inputs": ["t[S]", "y[S]", "valid[S]"],
            "outputs": ["mean[]"],
        },
    ),
}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "format": "hlo-text",
        "capacities": {
            "tasks": model.CAP_TASKS,
            "nodes": model.CAP_NODES,
            "batch": model.CAP_BATCH,
            "samples": CAP_SAMPLES,
        },
        "artifacts": {},
    }
    for name, (lower_fn, io_meta) in ARTIFACTS.items():
        text = to_hlo_text(lower_fn())
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {"file": f"{name}.hlo.txt", **io_meta}
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
