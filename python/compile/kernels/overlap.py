"""Layer-1 Pallas kernel: lifecycle-overlap demand aggregation.

Implements Algorithm 1, lines 8-13 of the paper as a masked interval
reduction: for each of ``B`` pending task requests, sum the CPU/memory
requests of every known task record whose start time falls inside the
request's lifecycle window ``[win_start, win_end)``.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the ``B x T`` weight
matrix never materialises in HBM — each grid step loads a ``(BT, TT)``
tile of the record arrays into VMEM, forms the window mask on the VPU and
accumulates into an f32 ``[BT]`` accumulator, i.e. the BlockSpec expresses
the HBM→VMEM schedule the paper's CPU implementation gets for free from
its Go loop.  ``interpret=True`` keeps the kernel executable on CPU-PJRT;
the lowered HLO is what the Rust runtime loads.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tile sizes. T is tiled; B is small enough (<= 64) to keep whole.
DEFAULT_T_TILE = 128


def _overlap_kernel(
    t_start_ref,
    cpu_ref,
    mem_ref,
    valid_ref,
    win_start_ref,
    win_end_ref,
    req_cpu_ref,
    req_mem_ref,
    out_cpu_ref,
    out_mem_ref,
):
    """One grid step: accumulate one T-tile of records into the B outputs."""
    t = pl.program_id(0)

    ts = t_start_ref[...]  # [TT]
    ws = win_start_ref[...]  # [B]
    we = win_end_ref[...]  # [B]

    inside = (ts[None, :] >= ws[:, None]) & (ts[None, :] < we[:, None])
    w = jnp.where(inside, 1.0, 0.0) * valid_ref[...][None, :]  # [B, TT]

    part_cpu = w @ cpu_ref[...]  # [B]
    part_mem = w @ mem_ref[...]

    # First tile seeds the accumulator with the request's own demand.
    @pl.when(t == 0)
    def _():
        out_cpu_ref[...] = req_cpu_ref[...]
        out_mem_ref[...] = req_mem_ref[...]

    out_cpu_ref[...] += part_cpu
    out_mem_ref[...] += part_mem


@functools.partial(jax.jit, static_argnames=("t_tile",))
def overlap_pallas(
    t_start,
    cpu,
    mem,
    valid,
    win_start,
    win_end,
    req_cpu,
    req_mem,
    t_tile: int = DEFAULT_T_TILE,
):
    """Pallas entry point; shapes f32[T] x4, f32[B] x4 -> (f32[B], f32[B])."""
    (t_len,) = t_start.shape
    (b,) = win_start.shape
    t_tile = min(t_tile, t_len)
    assert t_len % t_tile == 0, f"T={t_len} must be divisible by tile {t_tile}"
    grid = (t_len // t_tile,)

    rec_spec = pl.BlockSpec((t_tile,), lambda t: (t,))
    b_spec = pl.BlockSpec((b,), lambda t: (0,))

    out_cpu, out_mem = pl.pallas_call(
        _overlap_kernel,
        grid=grid,
        in_specs=[rec_spec, rec_spec, rec_spec, rec_spec, b_spec, b_spec, b_spec, b_spec],
        out_specs=[b_spec, b_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,  # CPU-PJRT executable; real TPU would drop this.
    )(t_start, cpu, mem, valid, win_start, win_end, req_cpu, req_mem)
    return out_cpu, out_mem
