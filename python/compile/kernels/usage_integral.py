"""Layer-1 Pallas kernel: time-weighted mean of a usage-rate curve.

Computes the trapezoidal integral of (t_i, y_i) samples divided by the
time span — the reduction behind the paper's "Resource Usage" metric
(time-averaged utilization over the total duration, §6.1.5). The Figs 5–8
post-processing runs this over the full sample stream.

Because consecutive trapezoids share a sample, a one-sample block overlap
would be needed to tile the stream — Pallas block indexing works in units
of whole blocks, so instead the kernel takes the full (padded, ≤16K)
sample arrays in one VMEM block: at f32[16384] × 3 inputs ≈ 192 KiB this
still fits VMEM comfortably on a real TPU.

Padding convention: invalid tail samples must repeat the last valid
(t, y) so their dt contribution is zero; `valid` gates both the
trapezoids and the span computation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _usage_kernel(t_ref, y_ref, valid_ref, out_ref):
    t = t_ref[...]
    y = y_ref[...]
    v = valid_ref[...]

    dt = t[1:] - t[:-1]
    area = jnp.sum(0.5 * (y[1:] + y[:-1]) * dt * v[1:] * v[:-1])
    tmin = jnp.min(jnp.where(v > 0, t, jnp.inf))
    tmax = jnp.max(jnp.where(v > 0, t, -jnp.inf))

    out_ref[0] = area
    out_ref[1] = tmin
    out_ref[2] = tmax


@jax.jit
def usage_integral_pallas(t, y, valid):
    """f32[N] ×3 → f32[] time-weighted mean (0.0 for empty/degenerate)."""
    (n,) = t.shape
    out = pl.pallas_call(
        _usage_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n,), lambda k: (0,)),
            pl.BlockSpec((n,), lambda k: (0,)),
            pl.BlockSpec((n,), lambda k: (0,)),
        ],
        out_specs=pl.BlockSpec((3,), lambda k: (0,)),
        out_shape=jax.ShapeDtypeStruct((3,), jnp.float32),
        interpret=True,
    )(t, y, valid)
    area, tmin, tmax = out[0], out[1], out[2]
    span = tmax - tmin
    ok = jnp.isfinite(tmin) & (span > 0)
    return jnp.where(ok, area / jnp.maximum(span, 1e-9), 0.0)
