"""Pure-jnp oracle for the ARAS decision mathematics.

This module is the *correctness reference* for the Pallas kernels in
``overlap.py`` and ``alloc_eval.py``.  Everything here mirrors the paper:

* ``overlap_ref``   — Algorithm 1, lines 8-13: accumulate the resource
  requests of every task record whose start time falls inside the
  requesting task's lifecycle window ``[win_start, win_end)``.
* ``alloc_eval_ref`` — Algorithm 3 (+ Eq. 9): the four-regime resource
  evaluation that turns the aggregated demand and the cluster residuals
  into the allocated (cpu, mem) pair.
* ``aras_decide_ref`` — the fused Layer-2 graph: node aggregation
  (Algorithm 2's output reduction) + overlap + evaluation.

The Pallas kernels must match these functions exactly (same f32 ops in the
same order), which pytest + hypothesis enforce.
"""

from __future__ import annotations

import jax.numpy as jnp


def overlap_ref(t_start, cpu, mem, valid, win_start, win_end, req_cpu, req_mem):
    """Aggregate concurrent demand inside each request's lifecycle window.

    Args:
      t_start: f32[T]  start times of known task records (Redis, Eq. 8).
      cpu:     f32[T]  requested CPU (milli-cores) of each record.
      mem:     f32[T]  requested memory (Mi) of each record.
      valid:   f32[T]  1.0 for live records, 0.0 for padding.
      win_start, win_end: f32[B] lifecycle window of each request.
      req_cpu, req_mem:   f32[B] the requesting task's own demand.

    Returns:
      (request_cpu, request_mem): f32[B] — the paper's ``request.cpu`` /
      ``request.mem`` accumulators (own demand + all window-overlapping
      records).
    """
    t_start = t_start[None, :]  # [1, T]
    inside = (t_start >= win_start[:, None]) & (t_start < win_end[:, None])
    w = jnp.where(inside, 1.0, 0.0) * valid[None, :]  # [B, T]
    request_cpu = req_cpu + w @ cpu
    request_mem = req_mem + w @ mem
    return request_cpu, request_mem


def alloc_eval_ref(
    req_cpu,
    req_mem,
    request_cpu,
    request_mem,
    total_res_cpu,
    total_res_mem,
    remax_cpu,
    remax_mem,
    alpha,
):
    """Algorithm 3: four-regime resource evaluation (branchless).

    All per-request args are f32[B]; ``total_res_*`` / ``remax_*`` /
    ``alpha`` are scalars (f32[]) describing the cluster at this instant.

    Regimes (paper's conditions):
      A1 = request.cpu < totalResidual.cpu   (cluster CPU sufficient)
      A2 = request.mem < totalResidual.mem   (cluster mem sufficient)
      B1 = req.cpu < Re_max.cpu              (fits on the biggest node)
      B2 = req.mem < Re_max.mem
      C1 = cpu_cut < Re_max.cpu              (scaled demand fits)
      C2 = mem_cut < Re_max.mem

    Returns (alloc_cpu, alloc_mem): f32[B].
    """
    # Eq. (9) resource scaling; guard the division for padded lanes.
    denom_cpu = jnp.maximum(request_cpu, 1.0)
    denom_mem = jnp.maximum(request_mem, 1.0)
    cpu_cut = req_cpu * (total_res_cpu / denom_cpu)
    mem_cut = req_mem * (total_res_mem / denom_mem)

    a1 = request_cpu < total_res_cpu
    a2 = request_mem < total_res_mem
    b1 = req_cpu < remax_cpu
    b2 = req_mem < remax_mem
    c1 = cpu_cut < remax_cpu
    c2 = mem_cut < remax_mem

    remax_cpu_a = remax_cpu * alpha
    remax_mem_a = remax_mem * alpha

    # CPU side: regime (1) A1      -> B1 ? req : remax*a   (also regime 3)
    #           regime (2) !A1&A2  -> C1 ? cpu_cut : remax*a
    #           regime (4) !A1&!A2 -> cpu_cut (unconditional)
    cpu_suff = jnp.where(b1, req_cpu, remax_cpu_a)
    cpu_insuff = jnp.where(c1, cpu_cut, remax_cpu_a)
    alloc_cpu = jnp.where(a1, cpu_suff, jnp.where(a2, cpu_insuff, cpu_cut))

    # Memory side mirrors the CPU side with regimes 2/3 swapped.
    mem_suff = jnp.where(b2, req_mem, remax_mem_a)
    mem_insuff = jnp.where(c2, mem_cut, remax_mem_a)
    alloc_mem = jnp.where(a2, mem_suff, jnp.where(a1, mem_insuff, mem_cut))

    return alloc_cpu, alloc_mem


def node_aggregate_ref(node_res_cpu, node_res_mem, node_valid):
    """Cluster-level reductions over Algorithm 2's ResidualMap.

    Returns (total_res_cpu, total_res_mem, remax_cpu, remax_mem).

    Per the paper's stated assumption, the node holding the maximum
    residual CPU is taken to hold the maximum residual memory as well:
    ``remax_mem`` is the residual memory *of the argmax-CPU node* (first
    index on ties), not an independent max.
    """
    masked_cpu = jnp.where(node_valid > 0, node_res_cpu, -jnp.inf)
    total_res_cpu = jnp.sum(node_res_cpu * node_valid)
    total_res_mem = jnp.sum(node_res_mem * node_valid)
    idx = jnp.argmax(masked_cpu)
    remax_cpu = node_res_cpu[idx]
    remax_mem = node_res_mem[idx]
    return total_res_cpu, total_res_mem, remax_cpu, remax_mem


def usage_integral_ref(t, y, valid):
    """Time-weighted mean of a sampled rate curve (trapezoidal).

    Mirrors `metrics::Collector::time_weighted_rate` on the Rust side and
    the paper's Resource Usage metric. Invalid samples contribute no area
    and do not extend the span.
    """
    dt = t[1:] - t[:-1]
    area = jnp.sum(0.5 * (y[1:] + y[:-1]) * dt * valid[1:] * valid[:-1])
    tmin = jnp.min(jnp.where(valid > 0, t, jnp.inf))
    tmax = jnp.max(jnp.where(valid > 0, t, -jnp.inf))
    span = tmax - tmin
    ok = jnp.isfinite(tmin) & (span > 0)
    return jnp.where(ok, area / jnp.maximum(span, 1e-9), 0.0)


def aras_decide_ref(
    t_start,
    cpu,
    mem,
    valid,
    win_start,
    win_end,
    req_cpu,
    req_mem,
    node_res_cpu,
    node_res_mem,
    node_valid,
    alpha,
):
    """Fused reference for the full Layer-2 decision graph.

    Returns (alloc_cpu, alloc_mem, request_cpu, request_mem): each f32[B].
    """
    request_cpu, request_mem = overlap_ref(
        t_start, cpu, mem, valid, win_start, win_end, req_cpu, req_mem
    )
    total_res_cpu, total_res_mem, remax_cpu, remax_mem = node_aggregate_ref(
        node_res_cpu, node_res_mem, node_valid
    )
    alloc_cpu, alloc_mem = alloc_eval_ref(
        req_cpu,
        req_mem,
        request_cpu,
        request_mem,
        total_res_cpu,
        total_res_mem,
        remax_cpu,
        remax_mem,
        alpha,
    )
    return alloc_cpu, alloc_mem, request_cpu, request_mem
