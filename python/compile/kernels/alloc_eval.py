"""Layer-1 Pallas kernel: Algorithm 3 four-regime resource evaluation.

A branchless, B-wide select tree over the paper's six conditions
(A1, A2, B1, B2, C1, C2) plus the Eq. (9) resource scaling.  Scalars
describing the cluster (total residuals, max-node residuals, alpha) enter
as ``(1,)`` arrays so every operand lives in VMEM; the whole kernel is a
single VPU pass — no MXU, no HBM round-trips beyond the operand loads.

Must stay numerically identical to ``ref.alloc_eval_ref`` (pytest +
hypothesis enforce exact f32 equality).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _alloc_eval_kernel(
    req_cpu_ref,
    req_mem_ref,
    request_cpu_ref,
    request_mem_ref,
    scal_ref,  # [5]: total_res_cpu, total_res_mem, remax_cpu, remax_mem, alpha
    out_cpu_ref,
    out_mem_ref,
):
    req_cpu = req_cpu_ref[...]
    req_mem = req_mem_ref[...]
    request_cpu = request_cpu_ref[...]
    request_mem = request_mem_ref[...]
    total_res_cpu = scal_ref[0]
    total_res_mem = scal_ref[1]
    remax_cpu = scal_ref[2]
    remax_mem = scal_ref[3]
    alpha = scal_ref[4]

    # Eq. (9) with guarded division (padding lanes carry request == 0).
    cpu_cut = req_cpu * (total_res_cpu / jnp.maximum(request_cpu, 1.0))
    mem_cut = req_mem * (total_res_mem / jnp.maximum(request_mem, 1.0))

    a1 = request_cpu < total_res_cpu
    a2 = request_mem < total_res_mem
    b1 = req_cpu < remax_cpu
    b2 = req_mem < remax_mem
    c1 = cpu_cut < remax_cpu
    c2 = mem_cut < remax_mem

    remax_cpu_a = remax_cpu * alpha
    remax_mem_a = remax_mem * alpha

    cpu_suff = jnp.where(b1, req_cpu, remax_cpu_a)
    cpu_insuff = jnp.where(c1, cpu_cut, remax_cpu_a)
    out_cpu_ref[...] = jnp.where(a1, cpu_suff, jnp.where(a2, cpu_insuff, cpu_cut))

    mem_suff = jnp.where(b2, req_mem, remax_mem_a)
    mem_insuff = jnp.where(c2, mem_cut, remax_mem_a)
    out_mem_ref[...] = jnp.where(a2, mem_suff, jnp.where(a1, mem_insuff, mem_cut))


@jax.jit
def alloc_eval_pallas(
    req_cpu,
    req_mem,
    request_cpu,
    request_mem,
    total_res_cpu,
    total_res_mem,
    remax_cpu,
    remax_mem,
    alpha,
):
    """Pallas entry point.

    Per-request args are f32[B]; cluster args are f32 scalars.
    Returns (alloc_cpu, alloc_mem): f32[B].
    """
    (b,) = req_cpu.shape
    scal = jnp.stack(
        [
            jnp.asarray(total_res_cpu, jnp.float32),
            jnp.asarray(total_res_mem, jnp.float32),
            jnp.asarray(remax_cpu, jnp.float32),
            jnp.asarray(remax_mem, jnp.float32),
            jnp.asarray(alpha, jnp.float32),
        ]
    )
    b_spec = pl.BlockSpec((b,), lambda: (0,))
    s_spec = pl.BlockSpec((5,), lambda: (0,))
    out_cpu, out_mem = pl.pallas_call(
        _alloc_eval_kernel,
        in_specs=[b_spec, b_spec, b_spec, b_spec, s_spec],
        out_specs=[b_spec, b_spec],
        out_shape=[
            jax.ShapeDtypeStruct((b,), jnp.float32),
            jax.ShapeDtypeStruct((b,), jnp.float32),
        ],
        interpret=True,
    )(req_cpu, req_mem, request_cpu, request_mem, scal)
    return out_cpu, out_mem
