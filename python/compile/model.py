"""Layer-2 JAX model: the fused ARAS decision graph.

``aras_decide`` is the computation the Rust coordinator executes on its
allocation hot path (after AOT lowering by ``aot.py``): given

* the Redis-style task records (Eq. 8)  — ``t_start/cpu/mem/valid``,
* the pending request batch            — ``win_start/win_end/req_cpu/req_mem``,
* Algorithm 2's ResidualMap as arrays  — ``node_res_cpu/node_res_mem/node_valid``,
* the scaling factor                   — ``alpha``,

it returns ``(alloc_cpu, alloc_mem, request_cpu, request_mem)`` per
request.  The heavy pieces run in the Layer-1 Pallas kernels; the tiny
node aggregation stays in plain jnp (XLA fuses it into the same module).

Static capacities (see also ``aot.py``/manifest): the Rust side pads its
inputs to these shapes once per MAPE cycle.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels.alloc_eval import alloc_eval_pallas
from compile.kernels.overlap import overlap_pallas

# AOT capacities — must match rust/src/runtime/batch.rs and manifest.json.
CAP_TASKS = 512  # max live task records considered per decision
CAP_NODES = 32   # max cluster nodes
CAP_BATCH = 8    # max requests decided per call


def node_aggregate(node_res_cpu, node_res_mem, node_valid):
    """Reduce Algorithm 2's ResidualMap: totals + argmax-CPU node residuals."""
    masked_cpu = jnp.where(node_valid > 0, node_res_cpu, -jnp.inf)
    total_res_cpu = jnp.sum(node_res_cpu * node_valid)
    total_res_mem = jnp.sum(node_res_mem * node_valid)
    idx = jnp.argmax(masked_cpu)
    return total_res_cpu, total_res_mem, node_res_cpu[idx], node_res_mem[idx]


def aras_decide(
    t_start,
    cpu,
    mem,
    valid,
    win_start,
    win_end,
    req_cpu,
    req_mem,
    node_res_cpu,
    node_res_mem,
    node_valid,
    alpha,
):
    """Fused ARAS decision: overlap scan -> node reduce -> Algorithm 3.

    Returns a 4-tuple of f32[B]: allocated cpu/mem and the aggregated
    request.cpu / request.mem diagnostics (the Rust engine logs the latter
    and uses them for the Alg. 1 retry condition).
    """
    request_cpu, request_mem = overlap_pallas(
        t_start, cpu, mem, valid, win_start, win_end, req_cpu, req_mem
    )
    total_res_cpu, total_res_mem, remax_cpu, remax_mem = node_aggregate(
        node_res_cpu, node_res_mem, node_valid
    )
    alloc_cpu, alloc_mem = alloc_eval_pallas(
        req_cpu,
        req_mem,
        request_cpu,
        request_mem,
        total_res_cpu,
        total_res_mem,
        remax_cpu,
        remax_mem,
        alpha,
    )
    return alloc_cpu, alloc_mem, request_cpu, request_mem


def example_args(cap_tasks: int = CAP_TASKS, cap_nodes: int = CAP_NODES, cap_batch: int = CAP_BATCH):
    """ShapeDtypeStructs for AOT lowering (order == aras_decide signature)."""
    import jax

    f32 = jnp.float32
    t = jax.ShapeDtypeStruct((cap_tasks,), f32)
    b = jax.ShapeDtypeStruct((cap_batch,), f32)
    n = jax.ShapeDtypeStruct((cap_nodes,), f32)
    s = jax.ShapeDtypeStruct((), f32)
    return (t, t, t, t, b, b, b, b, n, n, n, s)
