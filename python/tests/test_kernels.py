"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

hypothesis sweeps shapes and value distributions; assert_allclose (and
exact equality where the op sequences are identical) against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref
from compile.kernels.alloc_eval import alloc_eval_pallas
from compile.kernels.overlap import overlap_pallas

jax.config.update("jax_platform_name", "cpu")

f32 = np.float32


def rand_records(rng, t):
    return (
        rng.uniform(0, 1000, t).astype(f32),      # t_start
        rng.uniform(100, 4000, t).astype(f32),    # cpu
        rng.uniform(100, 8000, t).astype(f32),    # mem
        (rng.uniform(0, 1, t) < 0.8).astype(f32), # valid
    )


def rand_requests(rng, b):
    ws = rng.uniform(0, 800, b).astype(f32)
    we = ws + rng.uniform(1, 300, b).astype(f32)
    return ws, we, rng.uniform(100, 4000, b).astype(f32), rng.uniform(100, 8000, b).astype(f32)


# ---------------------------------------------------------------- overlap

@settings(max_examples=30, deadline=None)
@given(
    t=st.sampled_from([128, 256, 512]),
    b=st.integers(1, 16),
    seed=st.integers(0, 2**31 - 1),
)
def test_overlap_matches_ref(t, b, seed):
    rng = np.random.default_rng(seed)
    ts, cpu, mem, valid = rand_records(rng, t)
    ws, we, rc, rm = rand_requests(rng, b)
    got_c, got_m = overlap_pallas(ts, cpu, mem, valid, ws, we, rc, rm)
    want_c, want_m = ref.overlap_ref(ts, cpu, mem, valid, ws, we, rc, rm)
    np.testing.assert_allclose(got_c, want_c, rtol=1e-6)
    np.testing.assert_allclose(got_m, want_m, rtol=1e-6)


def test_overlap_empty_window():
    """A zero-length window accumulates only the request's own demand."""
    t = 128
    ts = np.linspace(0, 100, t).astype(f32)
    ones = np.ones(t, f32)
    ws = np.array([50.0], f32)
    got_c, got_m = overlap_pallas(ts, ones, ones, ones, ws, ws, np.array([7.0], f32), np.array([9.0], f32))
    assert got_c[0] == 7.0 and got_m[0] == 9.0


def test_overlap_all_invalid_records():
    t = 128
    ts = np.zeros(t, f32)
    ones = np.ones(t, f32)
    zeros = np.zeros(t, f32)
    got_c, _ = overlap_pallas(
        ts, ones, ones, zeros,
        np.array([-1.0], f32), np.array([1.0], f32),
        np.array([5.0], f32), np.array([5.0], f32),
    )
    assert got_c[0] == 5.0


def test_overlap_boundary_semantics():
    """Window is half-open: start inclusive, end exclusive (Alg. 1 line 9)."""
    t = 128
    ts = np.full(t, 10.0, f32)
    ts[1:] = 999.0  # only record 0 at t=10
    cpu = np.full(t, 3.0, f32)
    valid = np.ones(t, f32)
    # [10, 20) includes t_start=10
    c_in, _ = overlap_pallas(ts, cpu, cpu, valid, np.array([10.0], f32), np.array([20.0], f32), np.zeros(1, f32), np.zeros(1, f32))
    assert c_in[0] == 3.0
    # [0, 10) excludes t_start=10
    c_out, _ = overlap_pallas(ts, cpu, cpu, valid, np.array([0.0], f32), np.array([10.0], f32), np.zeros(1, f32), np.zeros(1, f32))
    assert c_out[0] == 0.0


@pytest.mark.parametrize("t_tile", [64, 128, 256])
def test_overlap_tile_invariance(t_tile):
    """Result must not depend on the T-tiling choice."""
    rng = np.random.default_rng(0)
    ts, cpu, mem, valid = rand_records(rng, 256)
    ws, we, rc, rm = rand_requests(rng, 4)
    a = overlap_pallas(ts, cpu, mem, valid, ws, we, rc, rm, t_tile=t_tile)
    b = ref.overlap_ref(ts, cpu, mem, valid, ws, we, rc, rm)
    np.testing.assert_allclose(a[0], b[0], rtol=1e-6)
    np.testing.assert_allclose(a[1], b[1], rtol=1e-6)


# ------------------------------------------------------------- alloc_eval

def rand_eval_inputs(rng, b):
    return dict(
        req_cpu=rng.uniform(100, 4000, b).astype(f32),
        req_mem=rng.uniform(100, 8000, b).astype(f32),
        request_cpu=rng.uniform(100, 60000, b).astype(f32),
        request_mem=rng.uniform(100, 120000, b).astype(f32),
        total_res_cpu=f32(rng.uniform(1000, 48000)),
        total_res_mem=f32(rng.uniform(1000, 98000)),
        remax_cpu=f32(rng.uniform(500, 8000)),
        remax_mem=f32(rng.uniform(500, 16000)),
        alpha=f32(0.8),
    )


@settings(max_examples=50, deadline=None)
@given(b=st.integers(1, 32), seed=st.integers(0, 2**31 - 1))
def test_alloc_eval_matches_ref(b, seed):
    rng = np.random.default_rng(seed)
    kw = rand_eval_inputs(rng, b)
    got = alloc_eval_pallas(**kw)
    want = ref.alloc_eval_ref(**kw)
    # identical op sequence -> bitwise equality expected on f32
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want[0]))
    np.testing.assert_array_equal(np.asarray(got[1]), np.asarray(want[1]))


def _eval_one(**kw):
    b1 = {k: (np.array([v], f32) if k in ("req_cpu", "req_mem", "request_cpu", "request_mem") else f32(v)) for k, v in kw.items()}
    c, m = ref.alloc_eval_ref(**b1)
    return float(c[0]), float(m[0])


def test_regime1_sufficient_grants_request():
    """A1&A2&B1&B2 -> allocate exactly the request (Alg. 3 lines 6-8)."""
    c, m = _eval_one(req_cpu=1000, req_mem=2000, request_cpu=5000, request_mem=5000,
                     total_res_cpu=40000, total_res_mem=90000, remax_cpu=7000, remax_mem=15000, alpha=0.8)
    assert (c, m) == (1000.0, 2000.0)


def test_regime1_big_task_clamped_to_alpha_max_node():
    """A1&A2, !B1 -> Re_max.cpu * alpha (lines 10-12)."""
    c, m = _eval_one(req_cpu=9000, req_mem=2000, request_cpu=9000, request_mem=2000,
                     total_res_cpu=40000, total_res_mem=90000, remax_cpu=7000, remax_mem=15000, alpha=0.8)
    assert c == pytest.approx(7000 * 0.8)
    assert m == 2000.0


def test_regime2_cpu_pressure_scales_cpu():
    """!A1&A2, C1&B2 -> cpu_cut, req.mem (lines 26-28)."""
    kw = dict(req_cpu=2000, req_mem=2000, request_cpu=50000, request_mem=2000,
              total_res_cpu=40000, total_res_mem=90000, remax_cpu=7000, remax_mem=15000, alpha=0.8)
    c, m = _eval_one(**kw)
    assert c == pytest.approx(2000 * 40000 / 50000)
    assert m == 2000.0


def test_regime4_both_scaled():
    """!A1&!A2 -> (cpu_cut, mem_cut) unconditionally (lines 65-67)."""
    kw = dict(req_cpu=2000, req_mem=4000, request_cpu=50000, request_mem=100000,
              total_res_cpu=40000, total_res_mem=90000, remax_cpu=7000, remax_mem=15000, alpha=0.8)
    c, m = _eval_one(**kw)
    assert c == pytest.approx(2000 * 40000 / 50000)
    assert m == pytest.approx(4000 * 90000 / 100000)


@settings(max_examples=50, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_alloc_never_exceeds_available(seed):
    """Invariant: allocation <= max(request, alpha * biggest node residual, cut)."""
    rng = np.random.default_rng(seed)
    kw = rand_eval_inputs(rng, 8)
    c, m = ref.alloc_eval_ref(**kw)
    cut_c = kw["req_cpu"] * kw["total_res_cpu"] / np.maximum(kw["request_cpu"], 1.0)
    bound_c = np.maximum.reduce([kw["req_cpu"], np.full(8, kw["remax_cpu"] * kw["alpha"], f32), cut_c.astype(f32)])
    assert np.all(np.asarray(c) <= bound_c + 1e-3)


# ------------------------------------------------------------------ fused

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_fused_model_matches_ref(seed):
    rng = np.random.default_rng(seed)
    t, b, n = model.CAP_TASKS, model.CAP_BATCH, model.CAP_NODES
    ts, cpu, mem, valid = rand_records(rng, t)
    ws, we, rc, rm = rand_requests(rng, b)
    nrc = rng.uniform(0, 8000, n).astype(f32)
    nrm = rng.uniform(0, 16000, n).astype(f32)
    nv = (rng.uniform(0, 1, n) < 0.7).astype(f32)
    if nv.sum() == 0:
        nv[0] = 1.0
    alpha = f32(0.8)
    got = model.aras_decide(ts, cpu, mem, valid, ws, we, rc, rm, nrc, nrm, nv, alpha)
    want = ref.aras_decide_ref(ts, cpu, mem, valid, ws, we, rc, rm, nrc, nrm, nv, alpha)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)


def test_node_aggregate_argmax_tie_first_index():
    nrc = np.array([5.0, 5.0, 1.0], f32)
    nrm = np.array([10.0, 20.0, 30.0], f32)
    nv = np.ones(3, f32)
    _, _, rc, rm = ref.node_aggregate_ref(nrc, nrm, nv)
    assert float(rc) == 5.0 and float(rm) == 10.0  # first max-CPU node's mem


def test_node_aggregate_ignores_invalid():
    nrc = np.array([9000.0, 5.0], f32)
    nrm = np.array([999.0, 10.0], f32)
    nv = np.array([0.0, 1.0], f32)
    tc, tm, rc, rm = ref.node_aggregate_ref(nrc, nrm, nv)
    assert float(tc) == 5.0 and float(rc) == 5.0 and float(rm) == 10.0
