"""AOT lowering sanity: artifacts lower, parse as HLO text, shapes match.

These tests exercise the exact code path ``make artifacts`` runs, plus a
python-side execution of the lowered module to pin the interchange
semantics (tuple outputs, parameter ordering) the Rust runtime assumes.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model


def test_lower_all_artifacts(tmp_path):
    for name, (lower_fn, _meta) in aot.ARTIFACTS.items():
        text = aot.to_hlo_text(lower_fn())
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_manifest_roundtrip(tmp_path):
    import subprocess, sys, os
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    man = json.loads((out / "manifest.json").read_text())
    assert man["capacities"]["tasks"] == model.CAP_TASKS
    assert man["capacities"]["nodes"] == model.CAP_NODES
    assert man["capacities"]["batch"] == model.CAP_BATCH
    assert man["capacities"]["samples"] == aot.CAP_SAMPLES
    assert set(man["artifacts"]) == {"aras_decide", "overlap", "alloc_eval", "usage_integral"}
    for name, entry in man["artifacts"].items():
        assert (out / entry["file"]).exists()
        assert entry["inputs"] and entry["outputs"]


def test_aras_decide_param_order_is_stable():
    """The lowered ENTRY must take 12 parameters in signature order."""
    text = aot.to_hlo_text(aot.lower_aras_decide())
    # count 'parameter(k)' occurrences 0..11
    for k in range(12):
        assert f"parameter({k})" in text, f"missing parameter({k})"
    assert "parameter(12)" not in text


def test_lowered_module_executes_like_python():
    """Compile the stablehlo module via jax and compare with direct eval."""
    rng = np.random.default_rng(42)
    t, b, n = model.CAP_TASKS, model.CAP_BATCH, model.CAP_NODES
    f32 = np.float32
    args = (
        rng.uniform(0, 100, t).astype(f32),
        rng.uniform(0, 4000, t).astype(f32),
        rng.uniform(0, 8000, t).astype(f32),
        np.ones(t, f32),
        rng.uniform(0, 50, b).astype(f32),
        rng.uniform(50, 100, b).astype(f32),
        rng.uniform(100, 4000, b).astype(f32),
        rng.uniform(100, 8000, b).astype(f32),
        rng.uniform(0, 8000, n).astype(f32),
        rng.uniform(0, 16000, n).astype(f32),
        np.ones(n, f32),
        f32(0.8),
    )
    compiled = jax.jit(model.aras_decide).lower(*args).compile()
    got = compiled(*args)
    want = model.aras_decide(*args)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w), rtol=1e-6)
