"""usage_integral Pallas kernel vs pure-jnp oracle (and vs numpy trapz)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.usage_integral import usage_integral_pallas

f32 = np.float32


def make_curve(rng, n_valid, n_total):
    t = np.sort(rng.uniform(0, 1000, n_valid)).astype(f32)
    # de-duplicate times to keep the span well-defined
    t = np.unique(t)
    n_valid = len(t)
    y = rng.uniform(0, 1, n_valid).astype(f32)
    tt = np.full(n_total, t[-1] if n_valid else 0.0, f32)
    yy = np.zeros(n_total, f32)
    vv = np.zeros(n_total, f32)
    tt[:n_valid] = t
    yy[:n_valid] = y
    vv[:n_valid] = 1.0
    return tt, yy, vv, t, y


@settings(max_examples=30, deadline=None)
@given(
    n_valid=st.integers(2, 200),
    n_total=st.sampled_from([256, 1024, 4096]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref_and_numpy(n_valid, n_total, seed):
    rng = np.random.default_rng(seed)
    tt, yy, vv, t, y = make_curve(rng, n_valid, n_total)
    got = float(usage_integral_pallas(tt, yy, vv))
    want_ref = float(ref.usage_integral_ref(tt, yy, vv))
    np.testing.assert_allclose(got, want_ref, rtol=1e-5)
    if len(t) >= 2:
        want_np = np.trapezoid(y.astype(np.float64), t.astype(np.float64)) / (t[-1] - t[0])
        np.testing.assert_allclose(got, want_np, rtol=1e-3)


def test_constant_curve_mean_is_constant():
    t = np.arange(256, dtype=f32)
    y = np.full(256, 0.42, f32)
    v = np.ones(256, f32)
    np.testing.assert_allclose(float(usage_integral_pallas(t, y, v)), 0.42, rtol=1e-6)


def test_degenerate_inputs_are_zero():
    n = 256
    t = np.zeros(n, f32)
    y = np.ones(n, f32)
    # single valid sample -> zero span -> 0.0
    v = np.zeros(n, f32)
    v[0] = 1.0
    assert float(usage_integral_pallas(t, y, v)) == 0.0
    # all invalid -> 0.0
    assert float(usage_integral_pallas(t, y, np.zeros(n, f32))) == 0.0


def test_padding_does_not_change_result():
    rng = np.random.default_rng(7)
    t_small, y_small, v_small, _, _ = make_curve(rng, 50, 256)
    t_big = np.full(4096, t_small[49], f32)
    y_big = np.zeros(4096, f32)
    v_big = np.zeros(4096, f32)
    t_big[:256] = t_small
    y_big[:256] = y_small
    v_big[:256] = v_small
    a = float(usage_integral_pallas(t_small, y_small, v_small))
    b = float(usage_integral_pallas(t_big, y_big, v_big))
    np.testing.assert_allclose(a, b, rtol=1e-6)
