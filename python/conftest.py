"""Make ``pytest python/tests/`` work from the repo root: the build-time
package is rooted at python/ (imported as ``compile.*``)."""

import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
