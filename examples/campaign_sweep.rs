//! Campaign sweep: a 12-configuration grid (2 workflows × 3 arrival
//! patterns × 2 policies) executed in parallel across the worker pool,
//! then re-run on a single thread to demonstrate the determinism
//! contract — byte-identical summary CSVs regardless of thread count.
//!
//! ```sh
//! cargo run --release --example campaign_sweep
//! ```

use kubeadaptor::campaign::{self, CampaignSpec};
use kubeadaptor::config::{ArrivalPattern, PolicySpec};
use kubeadaptor::report;
use kubeadaptor::workflow::WorkflowType;

fn main() -> anyhow::Result<()> {
    let mut spec = CampaignSpec::default();
    spec.name = "sweep-example".to_string();
    spec.workflows = vec![WorkflowType::Montage, WorkflowType::Ligo];
    spec.patterns = vec![
        ArrivalPattern::paper_constant(),
        ArrivalPattern::paper_linear(),
        ArrivalPattern::paper_pyramid(),
    ];
    spec.policies = vec![PolicySpec::adaptive(), PolicySpec::fcfs()];
    spec.base_seed = 42;
    spec.base.sample_interval_s = 5.0;

    println!("expanding {} configurations ...", spec.total_runs());
    assert!(spec.total_runs() >= 12);

    // 1. Parallel run (one worker per core).
    let t0 = std::time::Instant::now();
    let parallel = campaign::run(&spec)?;
    let parallel_csv = report::campaign::summary_csv(&parallel).to_string();
    println!(
        "parallel: {} runs on {} threads in {:.2}s",
        parallel.runs.len(),
        parallel.threads_used,
        t0.elapsed().as_secs_f64(),
    );

    // 2. Serial re-run: same spec, one thread.
    let mut serial_spec = spec.clone();
    serial_spec.threads = 1;
    let t0 = std::time::Instant::now();
    let serial = campaign::run(&serial_spec)?;
    let serial_csv = report::campaign::summary_csv(&serial).to_string();
    println!(
        "serial  : {} runs on {} thread  in {:.2}s",
        serial.runs.len(),
        serial.threads_used,
        t0.elapsed().as_secs_f64(),
    );

    assert_eq!(parallel_csv, serial_csv, "thread count must not change results");
    println!("determinism: summary CSVs byte-identical at 1 vs N threads ✓\n");

    // 3. The ARAS-vs-FCFS comparison report.
    let rows = parallel.comparison();
    println!("{}", report::campaign::render_markdown(&parallel, &rows));
    println!("{}", report::campaign::usage_chart(&rows));
    Ok(())
}
