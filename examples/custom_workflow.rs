//! Define your own workflow as JSON (the paper's CLI "customize workflows
//! on demand") and execute it under ARAS — a realistic ETL pipeline with
//! heterogeneous resource requests.
//!
//! ```sh
//! cargo run --release --example custom_workflow
//! cargo run --release --example custom_workflow -- --file my_workflow.json
//! ```

use kubeadaptor::config::{ArrivalPattern, ExperimentConfig};
use kubeadaptor::engine::Engine;
use kubeadaptor::resources::AdaptivePolicy;
use kubeadaptor::util::cli::Args;
use kubeadaptor::workflow::{parser, WorkflowType};

const ETL_PIPELINE: &str = r#"{
  "name": "nightly-etl",
  "deadline_s": 900,
  "tasks": [
    {"name": "ingest",      "deps": [],        "cpu_milli": 1000, "mem_mi": 2000},
    {"name": "validate",    "deps": [0],       "cpu_milli": 500,  "mem_mi": 1000},
    {"name": "shard-0",     "deps": [1],       "cpu_milli": 2000, "mem_mi": 4000},
    {"name": "shard-1",     "deps": [1],       "cpu_milli": 2000, "mem_mi": 4000},
    {"name": "shard-2",     "deps": [1],       "cpu_milli": 2000, "mem_mi": 4000},
    {"name": "shard-3",     "deps": [1],       "cpu_milli": 2000, "mem_mi": 4000},
    {"name": "join",        "deps": [2,3,4,5], "cpu_milli": 3000, "mem_mi": 6000},
    {"name": "aggregate",   "deps": [6],       "cpu_milli": 2000, "mem_mi": 4000},
    {"name": "report",      "deps": [7],       "cpu_milli": 500,  "mem_mi": 1000},
    {"name": "publish",     "deps": [8],       "cpu_milli": 250,  "mem_mi": 500}
  ]
}"#;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let p = Args::new("Run a custom JSON-defined workflow under ARAS")
        .opt_null("file", "path to a workflow JSON definition")
        .opt("count", "4", "number of instances to inject at once")
        .parse(&argv)?;

    let spec = match p.get("file") {
        Some(path) => parser::from_file(path)?,
        None => parser::from_json_str(ETL_PIPELINE)?,
    };
    println!(
        "workflow '{}': {} tasks, depth {}, max parallel width {}\n",
        spec.name,
        spec.tasks.len(),
        spec.depth(),
        spec.max_width()
    );
    println!("{}", spec.to_dot());

    let count = p.get_usize("count")?;
    let mut cfg = ExperimentConfig::default();
    cfg.workload.workflow = WorkflowType::Custom;
    cfg.workload.pattern = ArrivalPattern::Constant { per_burst: count, bursts: 1 };
    cfg.sample_interval_s = 2.0;

    let policy = AdaptivePolicy::new(cfg.alloc.alpha, true);
    let out = Engine::with_custom_workflow(cfg, Box::new(policy), &spec)?.run();

    println!("instances completed : {}", out.summary.workflows_completed);
    println!("tasks completed     : {}", out.summary.tasks_completed);
    println!("avg instance dur    : {:.2} min", out.summary.avg_workflow_duration_min);
    println!("cpu usage rate      : {:.3}", out.summary.cpu_usage);
    Ok(())
}
