//! Compare ARAS against the FCFS baseline across the paper's three
//! arrival patterns (§6.1.4) for a chosen workflow — a one-screen view of
//! the Table 2 dynamics.
//!
//! ```sh
//! cargo run --release --example arrival_patterns -- --workflow cybershake
//! ```

use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
use kubeadaptor::engine::run_experiment;
use kubeadaptor::util::cli::Args;
use kubeadaptor::workflow::WorkflowType;

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let p = Args::new("ARAS vs baseline across arrival patterns")
        .opt("workflow", "montage", "montage|epigenomics|cybershake|ligo")
        .opt("seed", "42", "workload seed")
        .parse(&argv)?;
    let wf = WorkflowType::parse(p.get_str("workflow"))?;
    let seed = p.get_u64("seed")?;

    println!("workflow: {}  (seed {seed})\n", wf.name());
    println!(
        "{:<10} {:<9} {:>12} {:>12} {:>9} {:>9}",
        "pattern", "policy", "total(min)", "avg-wf(min)", "cpu", "mem"
    );
    for pat in [
        ArrivalPattern::paper_constant(),
        ArrivalPattern::paper_linear(),
        ArrivalPattern::paper_pyramid(),
    ] {
        let mut per_pattern = Vec::new();
        for pol in [PolicySpec::adaptive(), PolicySpec::fcfs()] {
            let mut cfg = ExperimentConfig::paper(wf, pat, pol.clone());
            cfg.workload.seed = seed;
            cfg.sample_interval_s = 5.0;
            let out = run_experiment(&cfg)?;
            println!(
                "{:<10} {:<9} {:>12.2} {:>12.2} {:>9.3} {:>9.3}",
                pat.name(),
                pol.label(),
                out.summary.total_duration_min,
                out.summary.avg_workflow_duration_min,
                out.summary.cpu_usage,
                out.summary.mem_usage
            );
            per_pattern.push(out.summary);
        }
        let (a, b) = (&per_pattern[0], &per_pattern[1]);
        println!(
            "{:<10} {:<9} {:>11.1}% {:>11.1}%   (ARAS time savings)\n",
            "", "saving",
            (1.0 - a.total_duration_min / b.total_duration_min) * 100.0,
            (1.0 - a.avg_workflow_duration_min / b.avg_workflow_duration_min) * 100.0,
        );
    }
    Ok(())
}
