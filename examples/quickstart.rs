//! Quickstart: run one Montage workload under ARAS and print the paper's
//! Table 2 metrics, then do the same decision math through the
//! AOT-compiled PJRT module to prove all three layers compose.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
use kubeadaptor::engine::{run_experiment, Engine};
use kubeadaptor::resources::AdaptivePolicy;
use kubeadaptor::runtime::PjrtBackend;
use kubeadaptor::workflow::WorkflowType;

fn main() -> anyhow::Result<()> {
    // 1. Paper-default experiment: 30 Montage workflows, constant bursts.
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::paper_constant(),
        PolicySpec::adaptive(),
    );
    cfg.sample_interval_s = 5.0;

    println!("== scalar backend =========================================");
    let out = run_experiment(&cfg)?;
    print_summary(&out.summary);

    // 2. Same run with the ARAS decision math on the AOT-compiled XLA
    //    module (JAX + Pallas kernels, lowered by `make artifacts`).
    println!("\n== PJRT backend (artifacts/aras_decide.hlo.txt) ===========");
    match PjrtBackend::load_default() {
        Ok(backend) => {
            let policy = AdaptivePolicy::new(cfg.alloc.alpha, true).with_backend(Box::new(backend));
            let pjrt_out = Engine::with_policy(cfg, Box::new(policy))?.run();
            print_summary(&pjrt_out.summary);
            assert_eq!(
                out.summary.total_duration_min, pjrt_out.summary.total_duration_min,
                "scalar and PJRT backends must agree"
            );
            println!("\nscalar == pjrt: decisions identical across the whole run ✓");
        }
        Err(e) => println!("(skipped: {e})"),
    }
    Ok(())
}

fn print_summary(s: &kubeadaptor::metrics::RunSummary) {
    println!("workflows completed : {}", s.workflows_completed);
    println!("total duration      : {:.2} min", s.total_duration_min);
    println!("avg workflow dur    : {:.2} min", s.avg_workflow_duration_min);
    println!("cpu / mem usage     : {:.3} / {:.3}", s.cpu_usage, s.mem_usage);
}
