//! Cluster-size scaling study (ablation A3): how ARAS's advantage over
//! the FCFS baseline varies with worker count — the adaptive scheme
//! matters most when the cluster is tight.
//!
//! ```sh
//! cargo run --release --example cluster_scaling
//! ```

use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
use kubeadaptor::engine::run_experiment;
use kubeadaptor::workflow::WorkflowType;

fn main() -> anyhow::Result<()> {
    println!(
        "{:<7} {:>14} {:>14} {:>10} | {:>14} {:>14}",
        "nodes", "aras-total", "aras-avg-wf", "aras-waits", "fcfs-total", "fcfs-avg-wf"
    );
    for nodes in [2usize, 3, 4, 6, 8, 12] {
        let mut row = Vec::new();
        for pol in [PolicySpec::adaptive(), PolicySpec::fcfs()] {
            let mut cfg = ExperimentConfig::paper(
                WorkflowType::CyberShake,
                ArrivalPattern::paper_constant(),
                pol,
            );
            cfg.cluster.nodes = nodes;
            cfg.sample_interval_s = 10.0;
            row.push(run_experiment(&cfg)?);
        }
        let (a, b) = (&row[0], &row[1]);
        println!(
            "{:<7} {:>13.2}m {:>13.2}m {:>10} | {:>13.2}m {:>13.2}m",
            nodes,
            a.summary.total_duration_min,
            a.summary.avg_workflow_duration_min,
            a.summary.alloc_waits,
            b.summary.total_duration_min,
            b.summary.avg_workflow_duration_min,
        );
    }
    println!("\nARAS's edge grows as the cluster shrinks (resource scaling under pressure).");
    Ok(())
}
