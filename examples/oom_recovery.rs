//! Fig. 9 — resource-allocation failure and self-healing (§6.2.2).
//!
//! Injects 10 Montage workflows at once with under-declared minimum
//! memory so the resource-scaling method allocates below `min_mem + β`:
//! task pods OOM, KubeAdaptor captures the events, deletes the pods,
//! reallocates with fresh residuals and regenerates them.
//!
//! ```sh
//! cargo run --release --example oom_recovery
//! ```

use kubeadaptor::engine::run_experiment;
use kubeadaptor::experiments::oom;
use kubeadaptor::metrics::EventKind;

fn main() -> anyhow::Result<()> {
    let cfg = oom::config(42);
    println!(
        "injecting 10 Montage workflows at once; min_mem={}Mi, beta={}Mi, strict_min=off\n",
        cfg.task.min_mem_mi, cfg.alloc.beta_mi
    );
    let out = run_experiment(&cfg)?;

    println!("OOMKilled events    : {}", out.summary.oom_events);
    println!("workflows completed : {}/10", out.summary.workflows_completed);
    println!("tasks completed     : {}", out.summary.tasks_completed);

    // Trace the first OOMed task's full lifecycle (the Fig. 9 annotations).
    if let Some(first_oom) = out
        .metrics
        .events
        .iter()
        .find(|e| matches!(e.kind, EventKind::PodOomKilled))
    {
        let tid = first_oom.task_id.clone();
        println!("\nlifecycle of {tid} (first OOM victim):");
        for e in out.metrics.events.iter().filter(|e| e.task_id == tid) {
            let what = match &e.kind {
                EventKind::TaskRequested => "resource request".to_string(),
                EventKind::AllocDecided { cpu_milli, mem_mi } => {
                    format!("allocated {cpu_milli}m / {mem_mi}Mi")
                }
                EventKind::PodCreated => "pod created".into(),
                EventKind::PodRunning => "pod running".into(),
                EventKind::PodOomKilled => "OOMKilled (allocation < min_mem+beta)".into(),
                EventKind::PodDeleted => "pod deleted by Task Container Cleaner".into(),
                EventKind::TaskReallocated => "reallocation triggered (self-healing)".into(),
                EventKind::PodSucceeded => "pod completed".into(),
                other => format!("{other:?}"),
            };
            println!("  t={:>6.1}s  {what}", e.t);
        }
    }
    Ok(())
}
