//! Campaign-runner integration tests: the determinism contract (same
//! spec + seed ⇒ byte-identical reports at any thread count), grid
//! expansion, and the experiment modules' campaign definitions.

use kubeadaptor::campaign::{self, CampaignSpec};
use kubeadaptor::config::{ArrivalPattern, PolicySpec};
use kubeadaptor::experiments::table2;
use kubeadaptor::report;
use kubeadaptor::workflow::WorkflowType;

/// A fast 12-run grid: 2 workflows × 1 pattern × 2 policies × 3 reps.
fn small_grid() -> CampaignSpec {
    let mut spec = CampaignSpec::default();
    spec.name = "test-grid".to_string();
    spec.workflows = vec![WorkflowType::Montage, WorkflowType::CyberShake];
    spec.patterns = vec![ArrivalPattern::Constant { per_burst: 2, bursts: 2 }];
    spec.policies = vec![PolicySpec::adaptive(), PolicySpec::fcfs()];
    spec.reps = 3;
    spec.base_seed = 1234;
    spec.base.sample_interval_s = 5.0;
    spec
}

#[test]
fn summary_is_byte_identical_at_one_and_many_threads() {
    let mut serial = small_grid();
    serial.threads = 1;
    let mut parallel = small_grid();
    parallel.threads = 4;

    let a = campaign::run(&serial).unwrap();
    let b = campaign::run(&parallel).unwrap();
    assert_eq!(a.threads_used, 1);
    assert_eq!(b.threads_used, 4);

    let csv_a = report::campaign::summary_csv(&a).to_string();
    let csv_b = report::campaign::summary_csv(&b).to_string();
    assert_eq!(csv_a, csv_b, "thread count changed campaign results");

    let cmp_a = report::campaign::comparison_csv(&a.comparison()).to_string();
    let cmp_b = report::campaign::comparison_csv(&b.comparison()).to_string();
    assert_eq!(cmp_a, cmp_b);
}

#[test]
fn rerunning_the_same_spec_reproduces_the_report() {
    let spec = small_grid();
    let first = report::campaign::summary_csv(&campaign::run(&spec).unwrap()).to_string();
    let second = report::campaign::summary_csv(&campaign::run(&spec).unwrap()).to_string();
    assert_eq!(first, second);
}

#[test]
fn grid_expansion_is_ordered_and_seed_paired() {
    let spec = small_grid();
    let runs = spec.expand().unwrap();
    assert_eq!(runs.len(), 12);
    // Expansion order is stable and indexed.
    for (i, run) in runs.iter().enumerate() {
        assert_eq!(run.coord.index, i);
    }
    // Policy twins (same workflow/pattern/rep) share a workload seed …
    for run in &runs {
        let twin = runs
            .iter()
            .find(|r| {
                r.coord.policy != run.coord.policy
                    && r.coord.workflow == run.coord.workflow
                    && r.coord.rep == run.coord.rep
            })
            .expect("both policies expanded");
        assert_eq!(run.coord.seed, twin.coord.seed);
    }
    // … while different workflows and reps get distinct streams.
    let mut seeds: Vec<u64> = runs
        .iter()
        .filter(|r| r.coord.policy == PolicySpec::adaptive())
        .map(|r| r.coord.seed)
        .collect();
    seeds.sort_unstable();
    seeds.dedup();
    assert_eq!(seeds.len(), 6, "2 workflows x 3 reps = 6 distinct seeds");
}

#[test]
fn comparison_cells_pair_aras_with_baseline() {
    let mut spec = small_grid();
    spec.reps = 1;
    let result = campaign::run(&spec).unwrap();
    let rows = result.comparison();
    assert_eq!(rows.len(), 2, "one cell per workflow");
    for row in &rows {
        let a = row.adaptive.as_ref().expect("aras aggregate");
        let b = row.baseline.as_ref().expect("baseline aggregate");
        assert_eq!(a.runs, 1);
        assert_eq!(b.runs, 1);
        assert!(a.total_duration_min.mean > 0.0);
        assert!(b.total_duration_min.mean > 0.0);
        assert!(row.total_saving_pct().is_some());
    }
}

#[test]
fn table2_spec_is_the_paper_grid() {
    let spec = table2::spec(2, 7);
    assert_eq!(spec.total_runs(), 4 * 3 * 2 * 2);
    let runs = spec.expand().unwrap();
    // Every combination appears exactly `reps` times.
    for (wf, pat, pol) in table2::combinations() {
        let n = runs
            .iter()
            .filter(|r| {
                r.coord.workflow == wf
                    && r.coord.pattern.name() == pat.name()
                    && r.coord.policy == pol
            })
            .count();
        assert_eq!(n, 2, "{} {} {}", wf.name(), pat.name(), pol.label());
    }
}

#[test]
fn campaign_aggregates_match_a_direct_run() {
    // A 1-cell campaign must reproduce engine::run_experiment exactly.
    let mut spec = CampaignSpec::default();
    spec.workflows = vec![WorkflowType::Montage];
    spec.patterns = vec![ArrivalPattern::Constant { per_burst: 2, bursts: 1 }];
    spec.policies = vec![PolicySpec::adaptive()];
    spec.base.sample_interval_s = 5.0;
    spec.threads = 2;

    let result = campaign::run(&spec).unwrap();
    let run = &result.runs[0];

    let planned = spec.expand().unwrap();
    let direct = kubeadaptor::engine::run_experiment(&planned[0].cfg).unwrap();
    assert_eq!(
        direct.summary.total_duration_min,
        run.outcome.summary.total_duration_min
    );
    assert_eq!(direct.summary.cpu_usage, run.outcome.summary.cpu_usage);
    assert_eq!(direct.pods_created, run.outcome.pods_created);
}
