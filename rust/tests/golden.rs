//! Golden-trace regression tests: per-policy summary snapshots of the
//! seed experiment configs (`fig1`, `table2`, `oom`), asserted
//! bit-exactly — floats are compared on their IEEE-754 *bit patterns*,
//! so any drift in completed counts, durations or OOM totals fails
//! loudly with a per-field diff.
//!
//! ## Lifecycle
//!
//! The engine-driving tests are `#[ignore]`d and run in CI's dedicated
//! golden job: `cargo test -q --test golden -- --include-ignored`.
//! A golden file that is missing or still carries `"bootstrap": true`
//! is (re)generated in place; the test then only asserts in-process
//! determinism (each scenario is executed twice and must encode
//! identically). Committing the refreshed file locks the trace: from
//! then on any mismatch is a hard failure. To intentionally re-baseline
//! after a semantics change, set `"bootstrap": true` in the affected
//! file (or delete it) and re-run the golden job.

use std::path::PathBuf;

use kubeadaptor::campaign::{self, CampaignSpec};
use kubeadaptor::chaos::{ChaosKind, ChaosScenario};
use kubeadaptor::config::{
    ArrivalPattern, ClusterSpec, ExperimentConfig, FederationConfig, ForecasterSpec, PolicySpec,
    RouterSpec,
};
use kubeadaptor::engine::RunOutcome;
use kubeadaptor::experiments::{fig1, oom, table2};
use kubeadaptor::federation::{self, FederationSpec};
use kubeadaptor::util::json::Json;
use kubeadaptor::workflow::WorkflowType;

fn golden_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests").join("golden")
}

/// An f64 snapshot: human-readable value + exact bit pattern. Equality
/// is decided on the bits.
fn f64_field(v: f64) -> Json {
    Json::obj(vec![
        ("value", Json::num(v)),
        ("bits", Json::str(format!("{:016x}", v.to_bits()))),
    ])
}

fn count(v: u64) -> Json {
    Json::num(v as f64)
}

/// The locked-down surface of one run.
fn encode_outcome(out: &RunOutcome) -> Json {
    let s = &out.summary;
    Json::obj(vec![
        ("workflows_completed", count(s.workflows_completed as u64)),
        ("tasks_completed", count(s.tasks_completed as u64)),
        ("oom_events", count(s.oom_events as u64)),
        ("alloc_waits", count(s.alloc_waits as u64)),
        ("sla_violations", count(s.sla_violations as u64)),
        ("evictions", count(s.evictions as u64)),
        ("pods_created", count(out.pods_created)),
        ("serve_cycles", count(out.serve_cycles)),
        ("store_list_calls", count(out.store_list_calls)),
        ("statestore_writes", count(out.statestore_writes)),
        ("total_duration_min", f64_field(s.total_duration_min)),
        ("avg_workflow_duration_min", f64_field(s.avg_workflow_duration_min)),
        ("cpu_usage", f64_field(s.cpu_usage)),
        ("mem_usage", f64_field(s.mem_usage)),
    ])
}

fn encode_campaign(name: &str, result: &campaign::CampaignResult) -> Json {
    let runs: Vec<Json> = result
        .runs
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("label", Json::str(r.coord.label())),
                ("outcome", encode_outcome(&r.outcome)),
            ])
        })
        .collect();
    Json::obj(vec![("name", Json::str(name)), ("runs", Json::Arr(runs))])
}

/// Recursive structural diff; paths of differing leaves.
fn diff_json(path: &str, a: &Json, b: &Json, out: &mut Vec<String>) {
    match (a, b) {
        (Json::Obj(ma), Json::Obj(mb)) => {
            for k in ma.keys().chain(mb.keys().filter(|k| !ma.contains_key(*k))) {
                let p = format!("{path}.{k}");
                match (ma.get(k), mb.get(k)) {
                    (Some(x), Some(y)) => diff_json(&p, x, y, out),
                    (Some(_), None) => out.push(format!("{p}: missing in current")),
                    (None, Some(_)) => out.push(format!("{p}: new in current")),
                    (None, None) => unreachable!(),
                }
            }
        }
        (Json::Arr(xa), Json::Arr(xb)) => {
            if xa.len() != xb.len() {
                out.push(format!("{path}: length {} -> {}", xa.len(), xb.len()));
                return;
            }
            for (i, (x, y)) in xa.iter().zip(xb).enumerate() {
                // Label-bearing entries diff under their label for
                // readable output.
                let p = x
                    .get("label")
                    .and_then(|l| l.as_str())
                    .map(|l| format!("{path}[{l}]"))
                    .unwrap_or_else(|| format!("{path}[{i}]"));
                diff_json(&p, x, y, out);
            }
        }
        _ => {
            if a != b {
                out.push(format!(
                    "{path}: golden {} != current {}",
                    a.to_string_compact(),
                    b.to_string_compact()
                ));
            }
        }
    }
}

/// Run one golden scenario: execute the campaign twice (in-process
/// determinism gate), then compare against — or bootstrap — the
/// committed snapshot.
fn golden_check(name: &str, spec: &CampaignSpec) {
    let first = campaign::run(spec).expect("campaign run");
    let second = campaign::run(spec).expect("campaign rerun");
    let current = encode_campaign(name, &first);
    let again = encode_campaign(name, &second);
    assert_eq!(
        current.to_string_pretty(),
        again.to_string_pretty(),
        "golden '{name}': two in-process executions disagree — nondeterminism"
    );

    let path = golden_dir().join(format!("{name}.json"));
    let committed = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| Json::parse(&t).ok());
    let bootstrap = match &committed {
        None => true,
        Some(j) => j.get("bootstrap").and_then(|b| b.as_bool()).unwrap_or(false),
    };
    if bootstrap {
        std::fs::create_dir_all(golden_dir()).expect("mkdir golden");
        std::fs::write(&path, current.to_string_pretty() + "\n").expect("write golden");
        eprintln!(
            "golden '{name}': snapshot (re)generated — commit {} to lock this trace",
            path.display()
        );
        return;
    }
    let committed = committed.unwrap();
    let mut diffs = Vec::new();
    diff_json(name, &committed, &current, &mut diffs);
    assert!(
        diffs.is_empty(),
        "golden '{name}' drifted ({} differences):\n  {}\n\
         If this change is intentional, set \"bootstrap\": true in {} and re-run.",
        diffs.len(),
        diffs.join("\n  "),
        path.display()
    );
}

/// Encode one federation run: router accounting plus each member
/// cluster's full locked outcome surface (label-bearing, so the differ
/// reports drifts under the cluster name).
fn encode_federation(name: &str, result: &federation::FederationResult) -> Json {
    let s = &result.summary;
    let clusters: Vec<Json> = s
        .clusters
        .iter()
        .zip(&result.outcomes)
        .map(|(c, o)| {
            Json::obj(vec![
                ("label", Json::str(&c.name)),
                ("first_choice", count(c.first_choice as u64)),
                ("placements", count(c.placements as u64)),
                ("spill_in", count(c.spill_in as u64)),
                ("outcome", encode_outcome(o)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("name", Json::str(name)),
        ("router", Json::str(&s.router)),
        ("routed", count(s.routed as u64)),
        ("spillovers", count(s.spillovers as u64)),
        ("workflows_completed", count(s.workflows_completed as u64)),
        ("tasks_completed", count(s.tasks_completed as u64)),
        ("total_duration_min", f64_field(s.total_duration_min)),
        ("avg_workflow_duration_min", f64_field(s.avg_workflow_duration_min)),
        ("cpu_usage", f64_field(s.cpu_usage)),
        ("mem_usage", f64_field(s.mem_usage)),
        ("runs", Json::Arr(clusters)),
    ])
}

/// The federation counterpart of [`golden_check`]: run the spec twice
/// (in-process determinism gate), then compare against — or bootstrap —
/// the committed snapshot.
fn golden_federation_check(name: &str, spec: &FederationSpec) {
    let first = federation::run_spec(spec).expect("federation run");
    let second = federation::run_spec(spec).expect("federation rerun");
    let current = encode_federation(name, &first);
    let again = encode_federation(name, &second);
    assert_eq!(
        current.to_string_pretty(),
        again.to_string_pretty(),
        "golden '{name}': two in-process executions disagree — nondeterminism"
    );

    let path = golden_dir().join(format!("{name}.json"));
    let committed = std::fs::read_to_string(&path)
        .ok()
        .and_then(|t| Json::parse(&t).ok());
    let bootstrap = match &committed {
        None => true,
        Some(j) => j.get("bootstrap").and_then(|b| b.as_bool()).unwrap_or(false),
    };
    if bootstrap {
        std::fs::create_dir_all(golden_dir()).expect("mkdir golden");
        std::fs::write(&path, current.to_string_pretty() + "\n").expect("write golden");
        eprintln!(
            "golden '{name}': snapshot (re)generated — commit {} to lock this trace",
            path.display()
        );
        return;
    }
    let committed = committed.unwrap();
    let mut diffs = Vec::new();
    diff_json(name, &committed, &current, &mut diffs);
    assert!(
        diffs.is_empty(),
        "golden '{name}' drifted ({} differences):\n  {}\n\
         If this change is intentional, set \"bootstrap\": true in {} and re-run.",
        diffs.len(),
        diffs.join("\n  "),
        path.display()
    );
}

/// Give a single-policy experiment spec an explicit policy axis.
fn with_policy(mut spec: CampaignSpec, policy: PolicySpec) -> CampaignSpec {
    spec.policies = vec![policy];
    spec
}

#[test]
#[ignore = "golden-trace job: cargo test -q --test golden -- --include-ignored"]
fn golden_fig1() {
    golden_check("fig1-adaptive", &fig1::spec(42));
    golden_check("fig1-baseline", &with_policy(fig1::spec(42), PolicySpec::fcfs()));
}

#[test]
#[ignore = "golden-trace job: cargo test -q --test golden -- --include-ignored"]
fn golden_oom() {
    golden_check("oom-adaptive", &oom::spec(42));
    golden_check("oom-baseline", &with_policy(oom::spec(42), PolicySpec::fcfs()));
}

#[test]
#[ignore = "golden-trace job: cargo test -q --test golden -- --include-ignored"]
fn golden_table2() {
    // The full paper grid already carries both policies on its axis.
    golden_check("table2", &table2::spec(1, 42));
}

#[test]
#[ignore = "golden-trace job: cargo test -q --test golden -- --include-ignored"]
fn golden_forecast_predictive() {
    // The forecast-augmented path locked end to end: predictive policy
    // plus a seasonal forecaster under a multi-burst workload, where the
    // forecast demand term is non-zero. (The forecaster-free scenarios
    // above double as the strictly-opt-in guarantee — they never see a
    // forecast and must stay bit-identical.)
    let mut base = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 2, bursts: 3 },
        PolicySpec::named("predictive"),
    );
    base.forecast.forecaster = Some(ForecasterSpec::named("seasonal"));
    base.sample_interval_s = 5.0;
    let mut spec = CampaignSpec::from_base(base);
    spec.name = "forecast-predictive".to_string();
    golden_check("forecast-predictive", &spec);
}

/// The shared chaos golden workload: multi-burst Montage under ARAS on
/// the paper cluster, small enough for the golden job, busy enough that
/// a fault window at t=60 s lands mid-flight.
fn chaos_base() -> ExperimentConfig {
    let mut base = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 2, bursts: 3 },
        PolicySpec::adaptive(),
    );
    base.sample_interval_s = 5.0;
    base
}

#[test]
#[ignore = "golden-trace job: cargo test -q --test golden -- --include-ignored"]
fn golden_chaos_hog() {
    // Noisy-neighbor path locked end to end: a CPU hog squats on the
    // busiest node for 5 minutes, shrinking allocatable outside the
    // engine's control (hog-stolen integrals + alloc-wait pressure).
    let mut base = chaos_base();
    base.chaos.scenarios = vec![ChaosScenario {
        at: 60.0,
        duration: 300.0,
        kind: ChaosKind::CpuHog,
        node: None,
        magnitude: 4000.0,
    }];
    let mut spec = CampaignSpec::from_base(base);
    spec.name = "chaos-hog".to_string();
    golden_check("chaos-hog", &spec);
}

#[test]
#[ignore = "golden-trace job: cargo test -q --test golden -- --include-ignored"]
fn golden_chaos_partition() {
    // Informer↔store partition locked end to end: snapshots freeze for
    // 5 minutes (stale-snapshot cycles, double-allocation attempts and
    // the post-heal recovery are all part of the locked surface).
    let mut base = chaos_base();
    base.chaos.scenarios = vec![ChaosScenario {
        at: 60.0,
        duration: 300.0,
        kind: ChaosKind::Partition,
        node: None,
        magnitude: 0.0,
    }];
    let mut spec = CampaignSpec::from_base(base);
    spec.name = "chaos-partition".to_string();
    golden_check("chaos-partition", &spec);
}

#[test]
#[ignore = "golden-trace job: cargo test -q --test golden -- --include-ignored"]
fn golden_federation() {
    // The federated path locked end to end: a heterogeneous 3-cluster
    // federation under the forecast-headroom router, multi-burst so
    // later decisions see live queue/forecast state. Covers the full
    // chain — per-cluster seed derivation, router ranking, spill
    // checks, and the cross-cluster summary fold.
    let mut base = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 2, bursts: 3 },
        PolicySpec::adaptive(),
    );
    base.forecast.forecaster = Some(ForecasterSpec::named("seasonal"));
    base.sample_interval_s = 5.0;
    base.workload.seed = 42;
    let spec = FederationSpec {
        name: "federation".to_string(),
        base,
        federation: FederationConfig {
            clusters: vec![
                ClusterSpec::named("big").with_nodes(6).with_weight(3.0),
                ClusterSpec::named("mid").with_nodes(4).with_weight(2.0),
                ClusterSpec::named("small").with_nodes(2).with_weight(1.0),
            ],
            router: RouterSpec::named("forecast-headroom"),
            ..FederationConfig::default()
        },
    };
    golden_federation_check("federation", &spec);
}

// ------------------------------------------------------------------
// Harness mechanics (not ignored — cheap, no engine runs): the bit
// encoding and the differ must themselves be trustworthy.
// ------------------------------------------------------------------

#[test]
fn f64_bits_distinguish_values_display_rounds_together() {
    let a = f64_field(0.1 + 0.2);
    let b = f64_field(0.3);
    // 0.1 + 0.2 != 0.3 in f64; the bit encoding must see that.
    assert_ne!(a, b);
    let mut diffs = Vec::new();
    diff_json("x", &a, &b, &mut diffs);
    assert_eq!(diffs.len(), 2, "value and bits both differ: {diffs:?}");
    // Identical values encode identically and round-trip through JSON.
    let c = Json::parse(&f64_field(0.3).to_string_pretty()).unwrap();
    let mut diffs = Vec::new();
    diff_json("y", &c, &b, &mut diffs);
    assert!(diffs.is_empty(), "{diffs:?}");
}

#[test]
fn differ_reports_paths_and_lengths() {
    let old = Json::parse(r#"{"runs":[{"label":"a","outcome":{"pods":21}}]}"#).unwrap();
    let new_same_shape =
        Json::parse(r#"{"runs":[{"label":"a","outcome":{"pods":22}}]}"#).unwrap();
    let mut diffs = Vec::new();
    diff_json("t", &old, &new_same_shape, &mut diffs);
    assert_eq!(diffs.len(), 1);
    assert!(diffs[0].contains("t.runs[a].outcome.pods"), "{}", diffs[0]);

    let new_longer = Json::parse(r#"{"runs":[1,2]}"#).unwrap();
    let mut diffs = Vec::new();
    diff_json("t", &old, &new_longer, &mut diffs);
    assert!(diffs.iter().any(|d| d.contains("length 1 -> 2")), "{diffs:?}");
}

#[test]
fn bootstrap_markers_are_committed_for_every_scenario() {
    // The nine scenario files must exist in the repo (bootstrap markers
    // until the golden job locks them); a typo'd name here would make a
    // golden test silently bootstrap forever.
    for name in [
        "fig1-adaptive",
        "fig1-baseline",
        "oom-adaptive",
        "oom-baseline",
        "table2",
        "forecast-predictive",
        "chaos-hog",
        "chaos-partition",
        "federation",
    ] {
        let path = golden_dir().join(format!("{name}.json"));
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("missing golden file {}: {e}", path.display()));
        let j = Json::parse(&text).expect("golden file parses");
        let locked = j.get("runs").is_some();
        let bootstrap = j.get("bootstrap").and_then(|b| b.as_bool()).unwrap_or(false);
        assert!(
            locked || bootstrap,
            "{name}.json is neither a locked snapshot nor a bootstrap marker"
        );
    }
}

#[test]
fn bench_baseline_is_committed() {
    // The perf baseline follows the same lifecycle as the goldens:
    // committed as a bootstrap marker, regenerated by the CI bench job,
    // committed again to lock real numbers. Either state must parse and
    // document its regeneration command.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_baseline.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing {}: {e}", path.display()));
    let j = Json::parse(&text).expect("BENCH_baseline.json parses");
    assert!(
        j.get("command").and_then(|c| c.as_str()).map_or(false, |c| c.contains("bench")),
        "baseline must document its regeneration command"
    );
    let bootstrap = j.get("bootstrap").and_then(|b| b.as_bool()).unwrap_or(false);
    let locked = j.get("allocator").and_then(|a| a.get("ns_per_decision")).is_some()
        && j.get("engine").and_then(|e| e.get("tasks_per_sec")).is_some();
    assert!(
        locked || bootstrap,
        "BENCH_baseline.json is neither locked numbers nor a bootstrap marker"
    );
    // The serve-cycle snapshot benchmark (full rebuild vs incremental
    // delta) and the batched-decision comparison (scalar per-item vs
    // native full-lane) are part of the schema: locked baselines must
    // carry their entries, the bootstrap marker must document them.
    if locked && !bootstrap {
        let sizes = match j.get("snapshot") {
            Some(Json::Arr(sizes)) => sizes,
            other => panic!("locked baseline missing snapshot section: {other:?}"),
        };
        assert!(!sizes.is_empty(), "snapshot section must not be empty");
        for entry in sizes {
            for key in ["nodes", "full_ms_mean", "incremental_ms_mean", "speedup"] {
                assert!(entry.get(key).is_some(), "snapshot entry missing '{key}'");
            }
        }
        let batched = j.get("batched").expect("locked baseline missing batched section");
        let batched_keys =
            ["lanes", "records", "scalar_ns_per_decision", "native_ns_per_decision", "speedup"];
        for key in batched_keys {
            assert!(batched.get(key).is_some(), "batched section missing '{key}'");
        }
        // Span-derived cycle-phase timings (PR 9): a locked baseline
        // must attribute engine wall time to plan/schedule/snapshot.
        let phases = j
            .get("engine")
            .and_then(|e| e.get("phases"))
            .expect("locked baseline missing engine.phases section");
        for key in [
            "serve_cycles",
            "plan_calls",
            "schedule_calls",
            "snapshot_applies",
            "serve_ms",
            "plan_ms",
            "schedule_ms",
            "snapshot_ms",
        ] {
            assert!(phases.get(key).is_some(), "engine.phases missing '{key}'");
        }
        // Federation routing hot path (PR 10): ns/routing-decision at a
        // small and a wide member count.
        let routers = match j.get("router") {
            Some(Json::Arr(routers)) => routers,
            other => panic!("locked baseline missing router section: {other:?}"),
        };
        assert!(!routers.is_empty(), "router section must not be empty");
        for entry in routers {
            for key in ["clusters", "ns_per_decision", "samples"] {
                assert!(entry.get(key).is_some(), "router entry missing '{key}'");
            }
        }
    } else {
        let note = j.get("note").and_then(|n| n.as_str()).unwrap_or_default();
        assert!(
            note.contains("snapshot"),
            "bootstrap marker must document the snapshot benchmark schema"
        );
        assert!(
            note.contains("batched"),
            "bootstrap marker must document the batched-decision benchmark schema"
        );
        assert!(
            note.contains("phases"),
            "bootstrap marker must document the engine.phases timing schema"
        );
        assert!(
            note.contains("router"),
            "bootstrap marker must document the federation router benchmark schema"
        );
    }
}

#[test]
fn bench_trajectory_is_committed() {
    // The perf trajectory records one compact JSONL point per PR
    // (appended by `bench --trajectory BENCH_trajectory.jsonl --label
    // prN`). Every line must parse; real points carry the span-derived
    // phase timings, the initial bootstrap line documents itself.
    let path =
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_trajectory.jsonl");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing {}: {e}", path.display()));
    let mut lines = 0usize;
    for (i, line) in text.lines().filter(|l| !l.trim().is_empty()).enumerate() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("trajectory line {}: {e}", i + 1));
        let bootstrap = j.get("bootstrap").and_then(|b| b.as_bool()).unwrap_or(false);
        if !bootstrap {
            for key in ["label", "ns_per_decision", "tasks_per_sec", "plan_ms"] {
                assert!(
                    j.get(key).is_some(),
                    "trajectory line {} missing '{key}'",
                    i + 1
                );
            }
        }
        lines += 1;
    }
    assert!(lines > 0, "trajectory must have at least one line");
}
