//! Federation integration tests: the determinism contract (bit-identical
//! `FederatedSummary` at any thread count) and the spillover guarantee
//! (a regional outage reroutes every workflow off the dead cluster
//! without losing completions).

use kubeadaptor::cluster::{ClusterEvent, ClusterEventKind};
use kubeadaptor::config::{
    ArrivalPattern, ClusterSpec, ExperimentConfig, FederationConfig, RouterSpec,
};
use kubeadaptor::federation::{self, FederatedSummary, FederationSpec};

/// A 3-cluster heterogeneous federation over a small shared workload.
fn hetero_spec(router: &str) -> FederationSpec {
    let mut base = ExperimentConfig::default();
    base.workload.pattern = ArrivalPattern::Constant { per_burst: 3, bursts: 2 };
    base.workload.seed = 97;
    base.sample_interval_s = 5.0;
    FederationSpec {
        name: format!("hetero-{router}"),
        base,
        federation: FederationConfig {
            clusters: vec![
                ClusterSpec::named("big").with_nodes(6).with_weight(3.0),
                ClusterSpec::named("mid").with_nodes(4).with_weight(2.0),
                ClusterSpec::named("small").with_nodes(2).with_weight(1.0),
            ],
            router: RouterSpec::named(router),
            ..FederationConfig::default()
        },
    }
}

/// Everything observable about a summary, with floats as raw bits so a
/// 1-ulp drift across thread counts fails loudly.
#[allow(clippy::type_complexity)]
fn fingerprint(
    s: &FederatedSummary,
) -> (String, usize, usize, usize, usize, [u64; 4], Vec<(String, usize, usize, usize, [u64; 4])>)
{
    (
        s.router.clone(),
        s.routed,
        s.spillovers,
        s.workflows_completed,
        s.tasks_completed,
        [
            s.total_duration_min.to_bits(),
            s.avg_workflow_duration_min.to_bits(),
            s.cpu_usage.to_bits(),
            s.mem_usage.to_bits(),
        ],
        s.clusters
            .iter()
            .map(|c| {
                (
                    c.name.clone(),
                    c.placements,
                    c.spill_in,
                    c.workflows_completed,
                    [
                        c.total_duration_min.to_bits(),
                        c.avg_workflow_duration_min.to_bits(),
                        c.cpu_usage.to_bits(),
                        c.mem_usage.to_bits(),
                    ],
                )
            })
            .collect(),
    )
}

#[test]
fn federated_summary_is_bit_identical_at_one_and_many_threads() {
    let specs: Vec<FederationSpec> =
        ["round-robin", "least-queue", "forecast-headroom", "weighted"]
            .iter()
            .map(|r| hetero_spec(r))
            .collect();

    let serial = federation::run_many(&specs, 1).unwrap();
    let parallel = federation::run_many(&specs, 4).unwrap();
    assert_eq!(serial.len(), 4);
    assert_eq!(parallel.len(), 4);

    for (a, b) in serial.iter().zip(&parallel) {
        assert_eq!(
            fingerprint(&a.summary),
            fingerprint(&b.summary),
            "thread count changed a federated summary (router '{}')",
            a.summary.router
        );
    }
    // Each run placed and finished the whole shared workload.
    for r in &serial {
        assert_eq!(r.summary.routed, 6);
        assert_eq!(r.summary.clusters.iter().map(|c| c.placements).sum::<usize>(), 6);
        assert_eq!(r.summary.workflows_completed, 6);
    }
}

/// The outage spec: three equal clusters, with every node of the first
/// one crashing at t=0 — before the first routing decision runs.
fn outage_spec(dead: bool) -> FederationSpec {
    let mut base = ExperimentConfig::default();
    base.workload.pattern = ArrivalPattern::Constant { per_burst: 3, bursts: 2 };
    base.workload.seed = 11;
    base.sample_interval_s = 5.0;
    let mut east = ClusterSpec::named("east").with_nodes(2);
    if dead {
        // Both nodes are crashed *by name* at t=0: named crashes bypass
        // the victim picker (which spares the last node standing), so
        // the cluster is truly empty before any capacity is handed out.
        east.events = vec![
            ClusterEvent { at: 0.0, kind: ClusterEventKind::Crash { node: Some("node-0".into()) } },
            ClusterEvent { at: 0.0, kind: ClusterEventKind::Crash { node: Some("node-1".into()) } },
        ];
    }
    FederationSpec {
        name: format!("outage-{}", if dead { "storm" } else { "quiet" }),
        base,
        federation: FederationConfig {
            clusters: vec![
                east,
                ClusterSpec::named("west").with_nodes(2),
                ClusterSpec::named("north").with_nodes(2),
            ],
            router: RouterSpec::named("round-robin"),
            ..FederationConfig::default()
        },
    }
}

#[test]
fn outage_reroutes_every_workflow_off_the_dead_cluster() {
    let stormy = federation::run_spec(&outage_spec(true)).unwrap().summary;
    let quiet = federation::run_spec(&outage_spec(false)).unwrap().summary;

    // Nothing lands on the crashed cluster; everything it would have
    // taken spills to the live ones.
    let east = &stormy.clusters[0];
    assert_eq!(east.placements, 0, "dead cluster received placements");
    assert!(east.first_choice > 0, "round-robin never ranked east first");
    assert_eq!(stormy.spillovers, east.first_choice);
    assert_eq!(
        stormy.clusters.iter().map(|c| c.spill_in).sum::<usize>(),
        stormy.spillovers
    );
    assert_eq!(stormy.clusters.iter().map(|c| c.placements).sum::<usize>(), stormy.routed);

    // The rerouted federation still finishes the entire workload — the
    // same completions as its quiet twin, which shares the arrival
    // sequence (template sampled from the base seed).
    assert_eq!(stormy.routed, quiet.routed);
    assert_eq!(
        stormy.workflows_completed, quiet.workflows_completed,
        "outage lost workflows: {} vs quiet {}",
        stormy.workflows_completed, quiet.workflows_completed
    );
    assert_eq!(quiet.spillovers, 0, "quiet twin should not spill");
}
