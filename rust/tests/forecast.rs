//! Forecaster test suite: hand-computed series for the smoothing
//! predictors, plus property tests — forecasts are finite, non-negative,
//! bit-deterministic across identical observation streams, and
//! window-mean is invariant to value order inside one window.

use kubeadaptor::config::ForecasterSpec;
use kubeadaptor::forecast::{
    registry, DemandSample, Forecaster, HoltForecaster, SeasonalForecaster, WindowMeanForecaster,
};
use kubeadaptor::simcore::Rng;

fn sample(t: f64, cpu: f64) -> DemandSample {
    DemandSample { t, arrivals: 0.0, queue_len: 0.0, cpu_demand: cpu, mem_demand: 2.0 * cpu }
}

// ------------------------------------------------- hand-computed series

#[test]
fn holt_linear_hand_computed() {
    // alpha = beta = 0.5, unit-spaced observations 10, 20, 30:
    //   obs 1: level = 10, trend = 0
    //   obs 2: level = 0.5*20 + 0.5*(10 + 0)     = 15
    //          trend = 0.5*(15-10)/1 + 0.5*0     = 2.5
    //   obs 3: level = 0.5*30 + 0.5*(15 + 2.5)   = 23.75
    //          trend = 0.5*(23.75-15)/1 + 0.5*2.5 = 5.625
    // Every intermediate is dyadic, so the comparisons are exact.
    let mut f = HoltForecaster::new(0.5, 0.5).unwrap();
    f.observe(&sample(0.0, 10.0));
    f.observe(&sample(1.0, 20.0));
    f.observe(&sample(2.0, 30.0));
    assert_eq!(f.predict(0.0).unwrap().cpu_demand, 23.75);
    assert_eq!(f.predict(2.0).unwrap().cpu_demand, 23.75 + 2.0 * 5.625);
    // The mem series ran the same recurrence on doubled inputs.
    assert_eq!(f.predict(0.0).unwrap().mem_demand, 47.5);
}

#[test]
fn holt_winters_hand_computed() {
    // period = 40 s, 4 buckets, alpha = 0.5, beta = 0 (no trend),
    // gamma = 0.5. Observations: 100 @ t=0 (bucket 0), 0 @ t=10
    // (bucket 1), 0 @ t=20 (bucket 2):
    //   t=0 : level = 100,                  seasonal[0] = 0
    //   t=10: level = 0.5*0 + 0.5*100 = 50, seasonal[1] = 0.5*(0-50)  = -25
    //   t=20: level = 0.5*0 + 0.5*50  = 25, seasonal[2] = 0.5*(0-25)  = -12.5
    let mut f = SeasonalForecaster::new(40.0, 4, 0.5, 0.0, 0.5).unwrap();
    f.observe(&sample(0.0, 100.0));
    f.observe(&sample(10.0, 0.0));
    f.observe(&sample(20.0, 0.0));
    // Horizon 20 lands at t=40 → bucket 0 (seasonal 0): level alone.
    assert_eq!(f.predict(20.0).unwrap().cpu_demand, 25.0);
    // Horizon 30 lands at t=50 → bucket 1: 25 + (-25) = 0.
    assert_eq!(f.predict(30.0).unwrap().cpu_demand, 0.0);
    // Horizon 40 wraps a full period → bucket 2: 25 + (-12.5).
    assert_eq!(f.predict(40.0).unwrap().cpu_demand, 12.5);
}

// ------------------------------------------------------ property tests

/// A deterministic pseudo-random observation stream: bursty arrivals,
/// sawtooth demand, occasional queue pressure.
fn stream(seed: u64, ticks: usize) -> Vec<DemandSample> {
    let mut rng = Rng::new(seed);
    (0..ticks)
        .map(|i| {
            let t = i as f64 * 5.0;
            DemandSample {
                t,
                arrivals: rng.range_inclusive(0, 5) as f64,
                queue_len: rng.range_inclusive(0, 20) as f64,
                cpu_demand: rng.uniform(0.0, 48_000.0),
                mem_demand: rng.uniform(0.0, 60_000.0),
            }
        })
        .collect()
}

fn all_builtin_specs() -> Vec<ForecasterSpec> {
    let names = registry::global().read().unwrap().names();
    names.into_iter().map(ForecasterSpec::named).collect()
}

#[test]
fn forecasts_are_finite_and_non_negative_for_every_builtin() {
    for spec in all_builtin_specs() {
        let mut f = registry::build_forecaster(&spec).unwrap();
        assert!(f.predict(30.0).is_none(), "{}: unprimed predict must be None", spec.name);
        for s in stream(7, 200) {
            f.observe(&s);
        }
        for horizon in [0.0, 1.0, 30.0, 300.0, 3600.0] {
            let fc = f.predict(horizon).unwrap();
            for (label, v) in [
                ("cpu", fc.cpu_demand),
                ("mem", fc.mem_demand),
                ("queue", fc.queue_len),
                ("rate", fc.arrival_rate),
            ] {
                assert!(
                    v.is_finite() && v >= 0.0,
                    "{} @h={horizon}: {label} = {v} must be finite and >= 0",
                    spec.name
                );
            }
            assert_eq!(fc.horizon_s, horizon);
        }
    }
}

#[test]
fn identical_observation_streams_forecast_bit_identically() {
    for spec in all_builtin_specs() {
        let mut a = registry::build_forecaster(&spec).unwrap();
        let mut b = registry::build_forecaster(&spec).unwrap();
        for s in stream(11, 150) {
            a.observe(&s);
            b.observe(&s);
        }
        for horizon in [1.0, 60.0, 600.0] {
            let fa = a.predict(horizon).unwrap();
            let fb = b.predict(horizon).unwrap();
            assert_eq!(
                fa.cpu_demand.to_bits(),
                fb.cpu_demand.to_bits(),
                "{}: cpu forecast must be bit-deterministic",
                spec.name
            );
            assert_eq!(fa.mem_demand.to_bits(), fb.mem_demand.to_bits());
            assert_eq!(fa.queue_len.to_bits(), fb.queue_len.to_bits());
            assert_eq!(fa.arrival_rate.to_bits(), fb.arrival_rate.to_bits());
        }
    }
}

#[test]
fn window_mean_is_invariant_to_value_order_within_the_window() {
    // Same timestamps, same multiset of values, different order — the
    // windowed mean must not care. (A shared warm-up sample pins the
    // first-observation rate handling to the same state in both runs.)
    let orderings: [[f64; 3]; 3] =
        [[100.0, 900.0, 500.0], [500.0, 100.0, 900.0], [900.0, 500.0, 100.0]];
    let mut forecasts = Vec::new();
    for values in orderings {
        let mut f = WindowMeanForecaster::new(3).unwrap();
        f.observe(&sample(0.0, 777.0)); // warm-up, evicted from the window
        for (i, v) in values.into_iter().enumerate() {
            f.observe(&sample((i as f64 + 1.0) * 10.0, v));
        }
        forecasts.push(f.predict(60.0).unwrap());
    }
    assert_eq!(forecasts[0].cpu_demand, 500.0);
    for fc in &forecasts[1..] {
        assert_eq!(fc.cpu_demand.to_bits(), forecasts[0].cpu_demand.to_bits());
        assert_eq!(fc.mem_demand.to_bits(), forecasts[0].mem_demand.to_bits());
        assert_eq!(fc.queue_len.to_bits(), forecasts[0].queue_len.to_bits());
    }
}

#[test]
fn seasonal_outpredicts_naive_on_a_periodic_burst_train() {
    // A burst train with period 300: the seasonal forecaster, asked to
    // look one burst ahead from a calm tick, must predict more demand
    // than naive-last (which can only repeat the calm tick).
    let mk_train = |f: &mut dyn Forecaster| {
        for period in 0..8 {
            for tick in 0..10 {
                let t = period as f64 * 300.0 + tick as f64 * 30.0;
                let demand = if tick == 0 { 40_000.0 } else { 2_000.0 };
                f.observe(&sample(t, demand));
            }
        }
    };
    let mut seasonal =
        registry::build_forecaster(&ForecasterSpec::named("seasonal")).unwrap();
    let mut naive = registry::build_forecaster(&ForecasterSpec::named("naive-last")).unwrap();
    mk_train(seasonal.as_mut());
    mk_train(naive.as_mut());
    // Last observation at t = 2370 (tick 9, calm). Horizon 30 lands at
    // t = 2400 — the next burst.
    let s = seasonal.predict(30.0).unwrap().cpu_demand;
    let n = naive.predict(30.0).unwrap().cpu_demand;
    assert!(s > n + 10_000.0, "seasonal {s} must anticipate the burst naive {n} misses");
}

// -------------------------------------------------- registry round-trip

#[test]
fn global_registry_resolves_aliases_and_rejects_unknowns() {
    let reg = registry::global().read().unwrap();
    assert_eq!(reg.canonical_name("ewma"), Some("holt"));
    assert_eq!(reg.canonical_name("holt-winters"), Some("seasonal"));
    drop(reg);
    let err = registry::build_forecaster(&ForecasterSpec::named("oracle-9000"))
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown forecaster"), "{err}");
    assert!(err.contains("naive-last"), "roster must be listed: {err}");
}

#[test]
fn listing_is_sorted() {
    let listing = registry::forecaster_listing();
    let names: Vec<&str> = listing.iter().map(|(n, _, _)| n.as_str()).collect();
    let mut sorted = names.clone();
    sorted.sort();
    assert_eq!(names, sorted, "--list-forecasters must print in sorted order");
    assert!(names.contains(&"seasonal"));
}
