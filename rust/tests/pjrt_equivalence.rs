//! PJRT ↔ scalar backend equivalence — the L3↔L2/L1 contract.
//!
//! The AOT-compiled `aras_decide.hlo.txt` (JAX + Pallas, lowered by
//! `make artifacts`) must produce the same decisions as the scalar Rust
//! evaluator. Inputs are integral-valued f32s (real workloads are: milli-
//! cores and Mi are integers), for which both the XLA dot-product
//! reduction and the scalar loop are exact — so equality is exact.
//!
//! These tests require `artifacts/` (run `make artifacts` first); they
//! fail loudly if missing, because silently skipping would disable the
//! only check on the compiled hot path.

use kubeadaptor::resources::adaptive::{DecisionBackend, DecisionInputs, ScalarBackend};
use kubeadaptor::runtime::PjrtBackend;
use kubeadaptor::simcore::Rng;

fn load_backend() -> PjrtBackend {
    PjrtBackend::load_default().expect("artifacts missing — run `make artifacts`")
}

fn random_inputs(rng: &mut Rng, n_records: usize, n_nodes: usize) -> DecisionInputs {
    let records: Vec<(f32, f32, f32)> = (0..n_records)
        .map(|_| {
            (
                rng.range_inclusive(0, 1000) as f32,
                rng.range_inclusive(100, 4000) as f32,
                rng.range_inclusive(100, 8000) as f32,
            )
        })
        .collect();
    let win_start = rng.range_inclusive(0, 800) as f32;
    DecisionInputs {
        records,
        win_start,
        win_end: win_start + rng.range_inclusive(1, 300) as f32,
        req_cpu: rng.range_inclusive(100, 4000) as f32,
        req_mem: rng.range_inclusive(100, 8000) as f32,
        node_res: (0..n_nodes)
            .map(|_| (rng.range_inclusive(0, 8000) as f32, rng.range_inclusive(0, 16384) as f32))
            .collect(),
        alpha: 0.8,
    }
}

#[test]
fn pjrt_matches_scalar_on_random_states() {
    let mut pjrt = load_backend();
    let mut scalar = ScalarBackend;
    let mut rng = Rng::new(2024);
    for case in 0..200 {
        let inputs = random_inputs(&mut rng, (case % 40) * 8, 1 + case % 12);
        let a = scalar.decide(&inputs);
        let b = pjrt.decide(&inputs);
        assert_eq!(a.request_cpu, b.request_cpu, "case {case}: request_cpu");
        assert_eq!(a.request_mem, b.request_mem, "case {case}: request_mem");
        assert_eq!(a.alloc_cpu, b.alloc_cpu, "case {case}: alloc_cpu {a:?} vs {b:?}");
        assert_eq!(a.alloc_mem, b.alloc_mem, "case {case}: alloc_mem");
    }
}

#[test]
fn pjrt_handles_empty_records_and_single_node() {
    let mut pjrt = load_backend();
    let mut scalar = ScalarBackend;
    let inputs = DecisionInputs {
        records: vec![],
        win_start: 0.0,
        win_end: 15.0,
        req_cpu: 2000.0,
        req_mem: 4000.0,
        node_res: vec![(8000.0, 16384.0)],
        alpha: 0.8,
    };
    let a = scalar.decide(&inputs);
    let b = pjrt.decide(&inputs);
    assert_eq!(a.alloc_cpu, 2000.0);
    assert_eq!(b.alloc_cpu, 2000.0);
    assert_eq!(a.alloc_mem, b.alloc_mem);
}

#[test]
fn pjrt_record_overflow_folds_losslessly() {
    // More records than the artifact capacity (512): the PJRT padder
    // folds the overflow into one in-window record; totals must match
    // the scalar path exactly.
    let mut pjrt = load_backend();
    let mut scalar = ScalarBackend;
    let records: Vec<(f32, f32, f32)> =
        (0..700).map(|i| ((i % 100) as f32, 100.0, 200.0)).collect();
    let inputs = DecisionInputs {
        records,
        win_start: 0.0,
        win_end: 100.0, // every record in-window
        req_cpu: 2000.0,
        req_mem: 4000.0,
        node_res: vec![(8000.0, 16384.0); 6],
        alpha: 0.8,
    };
    let a = scalar.decide(&inputs);
    let b = pjrt.decide(&inputs);
    assert_eq!(a.request_cpu, b.request_cpu); // 2000 + 700*100 = 72000, exact in f32
    assert_eq!(a.alloc_cpu, b.alloc_cpu);
    assert_eq!(a.alloc_mem, b.alloc_mem);
}

#[test]
fn usage_integral_artifact_matches_rust_reduction() {
    use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicyKind};
    use kubeadaptor::engine::run_experiment;
    use kubeadaptor::runtime::UsageIntegral;
    use kubeadaptor::workflow::WorkflowType;

    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 3, bursts: 1 },
        PolicyKind::Adaptive,
    );
    cfg.sample_interval_s = 2.0;
    let out = run_experiment(&cfg).unwrap();
    assert!(out.metrics.samples.len() > 20);

    let integral = UsageIntegral::load_default().expect("artifacts missing");
    let pjrt_cpu = integral.mean_rate(&out.metrics.samples, |s| s.cpu_rate).unwrap();
    let pjrt_mem = integral.mean_rate(&out.metrics.samples, |s| s.mem_rate).unwrap();
    let rust = out.metrics.summarize();
    assert!(
        (pjrt_cpu as f64 - rust.cpu_usage).abs() < 1e-4,
        "cpu: pjrt {pjrt_cpu} vs rust {}",
        rust.cpu_usage
    );
    assert!((pjrt_mem as f64 - rust.mem_usage).abs() < 1e-4);
}

#[test]
fn usage_integral_degenerate_inputs() {
    use kubeadaptor::metrics::UsageSample;
    use kubeadaptor::runtime::UsageIntegral;

    let integral = UsageIntegral::load_default().expect("artifacts missing");
    assert_eq!(integral.mean_rate(&[], |s| s.cpu_rate).unwrap(), 0.0);
    let one = vec![UsageSample {
        t: 5.0,
        cpu_used: 0.0,
        mem_used: 0.0,
        cpu_rate: 0.7,
        mem_rate: 0.7,
        running_pods: 1,
    }];
    assert_eq!(integral.mean_rate(&one, |s| s.cpu_rate).unwrap(), 0.0);
}

#[test]
fn engine_run_with_pjrt_backend_matches_scalar_run() {
    use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicyKind};
    use kubeadaptor::engine::Engine;
    use kubeadaptor::resources::AdaptivePolicy;
    use kubeadaptor::workflow::WorkflowType;

    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 2, bursts: 1 },
        PolicyKind::Adaptive,
    );
    cfg.sample_interval_s = 5.0;

    let scalar_out = Engine::with_policy(
        cfg.clone(),
        Box::new(AdaptivePolicy::new(cfg.alloc.alpha, true)),
    )
    .unwrap()
    .run();

    let pjrt_policy = AdaptivePolicy::new(cfg.alloc.alpha, true)
        .with_backend(Box::new(load_backend()));
    let pjrt_out = Engine::with_policy(cfg, Box::new(pjrt_policy)).unwrap().run();

    // Same decisions => byte-identical simulation trajectories.
    assert_eq!(scalar_out.summary.total_duration_min, pjrt_out.summary.total_duration_min);
    assert_eq!(
        scalar_out.summary.avg_workflow_duration_min,
        pjrt_out.summary.avg_workflow_duration_min
    );
    assert_eq!(scalar_out.pods_created, pjrt_out.pods_created);
    assert_eq!(scalar_out.metrics.events.len(), pjrt_out.metrics.events.len());
}
