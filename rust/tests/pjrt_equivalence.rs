//! PJRT ↔ scalar backend equivalence — the L3↔L2/L1 contract.
//!
//! The AOT-compiled `aras_decide.hlo.txt` (JAX + Pallas, lowered by
//! `make artifacts`) must produce the same decisions as the scalar Rust
//! evaluator. Inputs are integral-valued f32s (real workloads are: milli-
//! cores and Mi are integers), for which both the XLA dot-product
//! reduction and the scalar loop are exact — so equality is exact.
//!
//! These tests require `artifacts/` (run `make artifacts` first) and a
//! real PJRT binding. When either is unavailable — no artifacts dir, or
//! the offline `vendor/xla` stub is linked — every test SKIPs loudly on
//! stderr rather than failing, so `cargo test` stays green on machines
//! that cannot run the compiled path. Set `KA_REQUIRE_PJRT=1` to turn
//! skips back into hard failures (CI machines with the runtime).

use kubeadaptor::resources::adaptive::{DecisionBackend, DecisionInputs, ScalarBackend};
use kubeadaptor::runtime::PjrtBackend;
use kubeadaptor::simcore::Rng;

/// Unwrap a runtime loader's result, or skip (None) when the runtime is
/// unavailable. `KA_REQUIRE_PJRT=1` (or any value but ""/"0"/"false")
/// turns skips into hard failures.
fn load_or_skip<T>(result: anyhow::Result<T>) -> Option<T> {
    match result {
        Ok(v) => Some(v),
        Err(e) => {
            let required = std::env::var("KA_REQUIRE_PJRT")
                .is_ok_and(|v| !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false"));
            if required {
                panic!("KA_REQUIRE_PJRT set but PJRT unavailable: {e}");
            }
            eprintln!("SKIP pjrt_equivalence: {e}");
            None
        }
    }
}

fn load_backend() -> Option<PjrtBackend> {
    load_or_skip(PjrtBackend::load_default())
}

fn load_usage_integral() -> Option<kubeadaptor::runtime::UsageIntegral> {
    load_or_skip(kubeadaptor::runtime::UsageIntegral::load_default())
}

fn random_inputs(rng: &mut Rng, n_records: usize, n_nodes: usize) -> DecisionInputs {
    let records: Vec<(f32, f32, f32)> = (0..n_records)
        .map(|_| {
            (
                rng.range_inclusive(0, 1000) as f32,
                rng.range_inclusive(100, 4000) as f32,
                rng.range_inclusive(100, 8000) as f32,
            )
        })
        .collect();
    let win_start = rng.range_inclusive(0, 800) as f32;
    DecisionInputs {
        records,
        win_start,
        win_end: win_start + rng.range_inclusive(1, 300) as f32,
        req_cpu: rng.range_inclusive(100, 4000) as f32,
        req_mem: rng.range_inclusive(100, 8000) as f32,
        node_res: (0..n_nodes)
            .map(|_| (rng.range_inclusive(0, 8000) as f32, rng.range_inclusive(0, 16384) as f32))
            .collect(),
        alpha: 0.8,
    }
}

#[test]
fn pjrt_matches_scalar_on_random_states() {
    let Some(mut pjrt) = load_backend() else { return };
    let mut scalar = ScalarBackend;
    let mut rng = Rng::new(2024);
    for case in 0..200 {
        let inputs = random_inputs(&mut rng, (case % 40) * 8, 1 + case % 12);
        let a = scalar.decide(&inputs);
        let b = pjrt.decide(&inputs);
        assert_eq!(a.request_cpu, b.request_cpu, "case {case}: request_cpu");
        assert_eq!(a.request_mem, b.request_mem, "case {case}: request_mem");
        assert_eq!(a.alloc_cpu, b.alloc_cpu, "case {case}: alloc_cpu {a:?} vs {b:?}");
        assert_eq!(a.alloc_mem, b.alloc_mem, "case {case}: alloc_mem");
    }
}

#[test]
fn pjrt_handles_empty_records_and_single_node() {
    let Some(mut pjrt) = load_backend() else { return };
    let mut scalar = ScalarBackend;
    let inputs = DecisionInputs {
        records: vec![],
        win_start: 0.0,
        win_end: 15.0,
        req_cpu: 2000.0,
        req_mem: 4000.0,
        node_res: vec![(8000.0, 16384.0)],
        alpha: 0.8,
    };
    let a = scalar.decide(&inputs);
    let b = pjrt.decide(&inputs);
    assert_eq!(a.alloc_cpu, 2000.0);
    assert_eq!(b.alloc_cpu, 2000.0);
    assert_eq!(a.alloc_mem, b.alloc_mem);
}

#[test]
fn pjrt_record_overflow_folds_losslessly() {
    // More records than the artifact capacity (512): the PJRT padder
    // folds the overflow into one in-window record; totals must match
    // the scalar path exactly.
    let Some(mut pjrt) = load_backend() else { return };
    let mut scalar = ScalarBackend;
    let records: Vec<(f32, f32, f32)> =
        (0..700).map(|i| ((i % 100) as f32, 100.0, 200.0)).collect();
    let inputs = DecisionInputs {
        records,
        win_start: 0.0,
        win_end: 100.0, // every record in-window
        req_cpu: 2000.0,
        req_mem: 4000.0,
        node_res: vec![(8000.0, 16384.0); 6],
        alpha: 0.8,
    };
    let a = scalar.decide(&inputs);
    let b = pjrt.decide(&inputs);
    assert_eq!(a.request_cpu, b.request_cpu); // 2000 + 700*100 = 72000, exact in f32
    assert_eq!(a.alloc_cpu, b.alloc_cpu);
    assert_eq!(a.alloc_mem, b.alloc_mem);
}

#[test]
fn usage_integral_artifact_matches_rust_reduction() {
    use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
    use kubeadaptor::engine::run_experiment;
    use kubeadaptor::workflow::WorkflowType;

    let Some(integral) = load_usage_integral() else { return };
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 3, bursts: 1 },
        PolicySpec::adaptive(),
    );
    cfg.sample_interval_s = 2.0;
    let out = run_experiment(&cfg).unwrap();
    assert!(out.metrics.samples.len() > 20);

    let pjrt_cpu = integral.mean_rate(&out.metrics.samples, |s| s.cpu_rate).unwrap();
    let pjrt_mem = integral.mean_rate(&out.metrics.samples, |s| s.mem_rate).unwrap();
    let rust = out.metrics.summarize();
    assert!(
        (pjrt_cpu as f64 - rust.cpu_usage).abs() < 1e-4,
        "cpu: pjrt {pjrt_cpu} vs rust {}",
        rust.cpu_usage
    );
    assert!((pjrt_mem as f64 - rust.mem_usage).abs() < 1e-4);
}

#[test]
fn usage_integral_degenerate_inputs() {
    use kubeadaptor::metrics::UsageSample;

    let Some(integral) = load_usage_integral() else { return };
    assert_eq!(integral.mean_rate(&[], |s| s.cpu_rate).unwrap(), 0.0);
    let one = vec![UsageSample {
        t: 5.0,
        cpu_used: 0.0,
        mem_used: 0.0,
        cpu_rate: 0.7,
        mem_rate: 0.7,
        running_pods: 1,
        nodes: 6,
    }];
    assert_eq!(integral.mean_rate(&one, |s| s.cpu_rate).unwrap(), 0.0);
}

#[test]
fn engine_run_with_pjrt_backend_matches_scalar_run() {
    use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
    use kubeadaptor::engine::Engine;
    use kubeadaptor::resources::AdaptivePolicy;
    use kubeadaptor::workflow::WorkflowType;

    let Some(backend) = load_backend() else { return };
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 2, bursts: 1 },
        PolicySpec::adaptive(),
    );
    cfg.sample_interval_s = 5.0;

    let scalar_out = Engine::with_policy(
        cfg.clone(),
        Box::new(AdaptivePolicy::new(cfg.alloc.alpha, true)),
    )
    .unwrap()
    .run();

    let pjrt_policy = AdaptivePolicy::new(cfg.alloc.alpha, true).with_backend(Box::new(backend));
    let pjrt_out = Engine::with_policy(cfg, Box::new(pjrt_policy)).unwrap().run();

    // Same decisions => byte-identical simulation trajectories.
    assert_eq!(scalar_out.summary.total_duration_min, pjrt_out.summary.total_duration_min);
    assert_eq!(
        scalar_out.summary.avg_workflow_duration_min,
        pjrt_out.summary.avg_workflow_duration_min
    );
    assert_eq!(scalar_out.pods_created, pjrt_out.pods_created);
    assert_eq!(scalar_out.metrics.events.len(), pjrt_out.metrics.events.len());
}
