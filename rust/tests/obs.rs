//! Observability integration tests: span tracing on real engine runs
//! (determinism, counter alignment, journal round-trip), Prometheus
//! exposition validity, and exact-vs-streaming quantile parity through
//! the full metrics pipeline.

use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
use kubeadaptor::engine::Engine;
use kubeadaptor::obs::trace::{Journal, TraceEvent, TraceMeta};
use kubeadaptor::obs::{expo, Phase};
use kubeadaptor::resources::registry;
use kubeadaptor::workflow::WorkflowType;

fn small_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 2, bursts: 1 },
        PolicySpec::adaptive(),
    );
    cfg.workload.seed = seed;
    cfg.sample_interval_s = 5.0;
    cfg
}

fn engine(cfg: &ExperimentConfig) -> Engine {
    let policy = registry::build_policy(&cfg.alloc.policy, &cfg.alloc).unwrap();
    Engine::with_policy(cfg.clone(), policy).unwrap()
}

/// Assemble the `--trace-out` journal exactly the way the CLI does.
fn journal_of(cfg: &ExperimentConfig, out: &kubeadaptor::engine::RunOutcome) -> Journal {
    let events: Vec<TraceEvent> = out
        .metrics
        .events
        .iter()
        .map(|e| {
            let (kind, detail) = e.kind.name_and_detail();
            TraceEvent {
                t: e.t,
                workflow_uid: e.workflow_uid,
                task_id: e.task_id.to_string(),
                kind: kind.to_string(),
                detail,
            }
        })
        .collect();
    Journal {
        meta: TraceMeta {
            workflow: cfg.workload.workflow.name().to_string(),
            pattern: cfg.workload.pattern.name().to_string(),
            policy: cfg.alloc.policy.label(),
            seed: cfg.workload.seed,
        },
        spans: out.spans.clone(),
        events,
    }
}

#[test]
fn trace_journal_round_trips_on_a_real_run() {
    let cfg = small_cfg(42);
    let mut eng = engine(&cfg);
    eng.enable_span_trace();
    let out = eng.run();

    assert!(!out.spans.is_empty(), "an instrumented run must record spans");
    assert!(
        out.spans.windows(2).all(|w| w[0].seq < w[1].seq),
        "span sequence numbers must be strictly increasing"
    );
    assert!(
        out.spans.iter().all(|s| s.wall_ns == 0),
        "no wall-clock reads unless opted in"
    );

    let journal = journal_of(&cfg, &out);
    let text = journal.to_jsonl();
    let back = Journal::parse(&text).expect("journal parses back");
    assert_eq!(back, journal, "journal must round-trip exactly");
    assert_eq!(text, back.to_jsonl(), "re-serialization must be byte-identical");
}

#[test]
fn span_counts_align_with_engine_counters() {
    let cfg = small_cfg(7);
    let mut eng = engine(&cfg);
    eng.enable_span_trace();
    let out = eng.run();

    let count = |p: Phase| out.spans.iter().filter(|s| s.phase == p).count() as u64;
    // The ServeCycle span wraps exactly the cycles the engine counts.
    assert_eq!(count(Phase::ServeCycle), out.serve_cycles);
    // The summary breakdown is the same recorder, copied at finish().
    assert_eq!(out.summary.phases.serve_cycles, out.serve_cycles);
    assert_eq!(out.summary.phases.plan_calls, count(Phase::Plan));
    assert_eq!(out.summary.phases.schedule_calls, count(Phase::Schedule));
    assert_eq!(out.summary.phases.snapshot_applies, count(Phase::SnapshotApply));
    assert!(out.summary.phases.plan_calls > 0, "a run must plan at least once");
    assert!(out.summary.phases.snapshot_applies > 0, "serve cycles capture snapshots");
    // No forecaster configured, no chaos: those phases stay silent.
    assert_eq!(count(Phase::ForecastObserve), 0);
    assert_eq!(count(Phase::Chaos), 0);
}

#[test]
fn span_tracing_does_not_perturb_results() {
    let cfg = small_cfg(42);
    let base = engine(&cfg).run();
    let mut traced_eng = engine(&cfg);
    traced_eng.enable_span_trace();
    let traced = traced_eng.run();

    assert!(base.spans.is_empty(), "default runs retain no spans");
    assert!(!traced.spans.is_empty());
    // Bit-exact twin results: observability must be a pure observer.
    assert_eq!(
        base.summary.total_duration_min.to_bits(),
        traced.summary.total_duration_min.to_bits()
    );
    assert_eq!(base.summary.cpu_usage.to_bits(), traced.summary.cpu_usage.to_bits());
    assert_eq!(base.summary.mem_usage.to_bits(), traced.summary.mem_usage.to_bits());
    assert_eq!(base.summary.tasks_completed, traced.summary.tasks_completed);
    assert_eq!(base.pods_created, traced.pods_created);
    assert_eq!(base.summary.phases, traced.summary.phases);
}

#[test]
fn prometheus_exposition_is_valid_and_complete() {
    let cfg = small_cfg(42);
    let mut eng = engine(&cfg);
    eng.start();
    while eng.step() {}

    let text = eng.prometheus_metrics();
    expo::validate(&text).unwrap_or_else(|e| panic!("invalid exposition: {e}\n{text}"));

    // At least one counter, one gauge and one histogram, as the
    // protocol contract promises.
    assert!(text.contains("# TYPE ka_serve_cycles_total counter"));
    assert!(text.contains("# TYPE ka_pods_created_total counter"));
    assert!(text.contains("# TYPE ka_virtual_time_seconds gauge"));
    assert!(text.contains("# TYPE ka_workflow_duration_seconds histogram"));
    assert!(text.contains("ka_workflow_duration_seconds_bucket{le=\"+Inf\"}"));
    assert!(text.contains("ka_workflow_duration_seconds_sum"));
    assert!(text.contains("ka_workflow_duration_seconds_count"));
    // Per-phase call counters carry the phase label.
    assert!(text.contains("ka_phase_calls_total{phase=\"plan\"}"));
    assert!(text.contains("ka_phase_calls_total{phase=\"serve_cycle\"}"));
}

#[test]
fn streaming_quantiles_match_exact_percentiles_on_small_runs() {
    // Within the histogram's exact buffer the streaming quantiles must
    // agree bit-for-bit with the stored-sample percentile math they
    // replaced — through the full engine pipeline, not just the unit.
    for seed in [3, 42, 99] {
        let out = engine(&small_cfg(seed)).run();
        let n = out.metrics.wf_durations.len();
        assert!(n > 0, "run completed no workflows");
        assert!(n <= 64, "this test needs to stay within the exact buffer");
        let exact_p50 = kubeadaptor::util::stats::percentile(&out.metrics.wf_durations, 50.0);
        let exact_p95 = kubeadaptor::util::stats::percentile(&out.metrics.wf_durations, 95.0);
        assert_eq!(out.summary.wf_duration_p50_s.to_bits(), exact_p50.to_bits());
        assert_eq!(out.summary.wf_duration_p95_s.to_bits(), exact_p95.to_bits());
    }
}

#[test]
fn wall_clock_opt_in_attributes_time_without_changing_counts() {
    let cfg = small_cfg(42);
    let base = engine(&cfg).run();
    let mut timed_eng = engine(&cfg);
    timed_eng.enable_wall_clock_obs();
    let timed = timed_eng.run();

    // Counts are clock-independent; virtual results stay bit-exact.
    assert_eq!(base.summary.phases.serve_cycles, timed.summary.phases.serve_cycles);
    assert_eq!(base.summary.phases.plan_calls, timed.summary.phases.plan_calls);
    assert_eq!(
        base.summary.total_duration_min.to_bits(),
        timed.summary.total_duration_min.to_bits()
    );
    // The default run must not have read the clock at all.
    assert_eq!(base.summary.phases.serve_wall_ns, 0);
    assert_eq!(base.summary.phases.plan_wall_ns, 0);
    // The timed run attributed real time to the busiest phase.
    assert!(
        timed.summary.phases.serve_wall_ns > 0,
        "wall-clock opt-in must attribute serve-cycle time"
    );
}
