//! Failure-injection tests: OOM storms, pathological configs, starvation
//! and recovery — the §6.2.2 self-healing claims under stress — plus the
//! stale-snapshot semantics of chaos informer partitions.

use kubeadaptor::chaos::{ChaosKind, ChaosScenario};
use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
use kubeadaptor::engine::run_experiment;
use kubeadaptor::experiments::oom;
use kubeadaptor::metrics::EventKind;
use kubeadaptor::workflow::WorkflowType;

#[test]
fn fig9_scenario_every_oom_is_reallocated_and_completes() {
    let cfg = oom::config(42);
    let out = run_experiment(&cfg).unwrap();
    assert!(out.summary.oom_events > 0);
    let reallocs = out.metrics.count(|k| matches!(k, EventKind::TaskReallocated));
    assert_eq!(out.summary.oom_events, reallocs);
    assert_eq!(out.summary.workflows_completed, 10);
    // Every task eventually succeeded despite the kills.
    assert_eq!(out.summary.tasks_completed, 10 * 21);
}

#[test]
fn oom_lifecycle_ordering_holds_for_every_killed_task() {
    let out = run_experiment(&oom::config(7)).unwrap();
    let events = &out.metrics.events;
    for e in events {
        if matches!(e.kind, EventKind::PodOomKilled) {
            // After each OOM, the same task must see deletion, then a new
            // running pod, then success.
            let after: Vec<_> = events
                .iter()
                .filter(|x| x.task_id == e.task_id && x.t >= e.t)
                .collect();
            let deleted = after.iter().any(|x| matches!(x.kind, EventKind::PodDeleted));
            let rerun = after.iter().any(|x| matches!(x.kind, EventKind::PodRunning) && x.t > e.t);
            let done = after.iter().any(|x| matches!(x.kind, EventKind::PodSucceeded));
            assert!(deleted && rerun && done, "task {} not healed", e.task_id);
        }
    }
}

#[test]
fn repeated_oom_does_not_livelock() {
    // min_mem equal to the full request: even a full allocation only
    // just suffices; scaled allocations always OOM. The engine must
    // still converge because reallocation happens with fresh residuals.
    let mut cfg = oom::config(3);
    cfg.task.min_mem_mi = 3900;
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.workflows_completed, 10, "oom={} ", out.summary.oom_events);
}

#[test]
fn strict_min_starvation_resolves_when_resources_free() {
    // strict_min + tiny cluster: requests queue but must all eventually
    // run as earlier pods release resources.
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::CyberShake,
        ArrivalPattern::Constant { per_burst: 4, bursts: 1 },
        PolicySpec::adaptive(),
    );
    cfg.cluster.nodes = 2;
    cfg.sample_interval_s = 5.0;
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.workflows_completed, 4);
    assert!(out.summary.alloc_waits > 0, "scenario should exercise waiting");
}

#[test]
fn baseline_survives_overload_too() {
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Ligo,
        ArrivalPattern::Constant { per_burst: 8, bursts: 1 },
        PolicySpec::fcfs(),
    );
    cfg.cluster.nodes = 2;
    cfg.sample_interval_s = 5.0;
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.workflows_completed, 8);
}

#[test]
fn single_node_cluster_serializes_but_completes() {
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Epigenomics,
        ArrivalPattern::Constant { per_burst: 2, bursts: 1 },
        PolicySpec::adaptive(),
    );
    cfg.cluster.nodes = 1;
    cfg.sample_interval_s = 5.0;
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.workflows_completed, 2);
}

#[test]
fn oversized_task_rejected_by_validation() {
    let mut cfg = ExperimentConfig::default();
    cfg.task.req_cpu_milli = cfg.cluster.node_cpu_milli + 1;
    assert!(run_experiment(&cfg).is_err());
}

/// A cluster-wide informer↔store partition over `[at, at + duration)`.
fn partition(at: f64, duration: f64) -> ChaosScenario {
    ChaosScenario { at, duration, kind: ChaosKind::Partition, node: None, magnitude: 0.0 }
}

/// An overloaded 2-node cluster partitioned just after the first serve
/// cycle: the frozen snapshot predates every placement, so the policy
/// keeps planning onto nodes it believes are empty.
fn partitioned_overload(policy: PolicySpec) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 8, bursts: 1 },
        policy,
    );
    cfg.cluster.nodes = 2;
    cfg.sample_interval_s = 5.0;
    cfg.chaos.scenarios = vec![partition(1.0, 300.0)];
    cfg
}

#[test]
fn partition_heals_and_every_workflow_completes() {
    let out = run_experiment(&partitioned_overload(PolicySpec::adaptive())).unwrap();
    assert!(out.stale_snapshot_cycles > 0, "partition never froze a snapshot");
    // Frozen cycles skip the informer sync; every other cycle pays
    // exactly one, plus the engine's construction-time list.
    assert_eq!(
        out.store_list_calls,
        out.serve_cycles - out.stale_snapshot_cycles as u64 + 1,
        "sync accounting drifted under the partition"
    );
    assert_eq!(out.summary.workflows_completed, 8, "run must self-heal after the partition");
    assert_eq!(out.summary.tasks_completed, 8 * 21);
}

#[test]
fn stale_snapshots_count_double_alloc_attempts_but_never_overcommit() {
    let cfg = partitioned_overload(PolicySpec::fcfs());
    let out = run_experiment(&cfg).unwrap();
    assert!(
        out.double_alloc_attempts > 0,
        "a loaded partition window must provoke stale double-allocation plans"
    );
    // Every detected attempt took the rollback path and surfaced as an
    // unschedulable alloc-wait — none of them landed on a node.
    let unsched = out.metrics.count(|k| {
        matches!(k, EventKind::AllocWait { reason } if reason.starts_with("unschedulable"))
    });
    assert!(
        unsched >= out.double_alloc_attempts,
        "{unsched} unschedulable waits < {} double-alloc attempts",
        out.double_alloc_attempts
    );
    // Capacity ledger: FCFS pods hold exactly the full request, so peak
    // pod concurrency is bounded by physical capacity even while the
    // policy plans against a frozen (empty-looking) snapshot.
    let per_node = (cfg.cluster.node_cpu_milli / cfg.task.req_cpu_milli)
        .min(cfg.cluster.node_mem_mi / cfg.task.req_mem_mi);
    let cap = cfg.cluster.nodes as i64 * per_node;
    let mut running = 0i64;
    let mut peak = 0i64;
    for e in &out.metrics.events {
        match &e.kind {
            EventKind::PodRunning => {
                running += 1;
                peak = peak.max(running);
            }
            EventKind::PodSucceeded | EventKind::PodOomKilled => running -= 1,
            _ => {}
        }
    }
    assert!(peak > 0, "scenario never ran a pod");
    assert!(peak <= cap, "double-booked past capacity: peak {peak} > {cap}");
    assert_eq!(out.summary.workflows_completed, 8);
}

#[test]
fn zero_beta_tightens_oom_threshold() {
    // With beta = 0 a pod whose allocation equals min_mem exactly runs;
    // the paper's beta >= 20 margin exists for the Stress overhead.
    let mut cfg = oom::config(5);
    cfg.alloc.beta_mi = 0.0;
    let a = run_experiment(&cfg).unwrap();
    cfg.alloc.beta_mi = 500.0;
    let b = run_experiment(&cfg).unwrap();
    assert!(
        b.summary.oom_events >= a.summary.oom_events,
        "larger beta should OOM at least as often: {} vs {}",
        a.summary.oom_events,
        b.summary.oom_events
    );
}
