//! Failure-injection tests: OOM storms, pathological configs, starvation
//! and recovery — the §6.2.2 self-healing claims under stress.

use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
use kubeadaptor::engine::run_experiment;
use kubeadaptor::experiments::oom;
use kubeadaptor::metrics::EventKind;
use kubeadaptor::workflow::WorkflowType;

#[test]
fn fig9_scenario_every_oom_is_reallocated_and_completes() {
    let cfg = oom::config(42);
    let out = run_experiment(&cfg).unwrap();
    assert!(out.summary.oom_events > 0);
    let reallocs = out.metrics.count(|k| matches!(k, EventKind::TaskReallocated));
    assert_eq!(out.summary.oom_events, reallocs);
    assert_eq!(out.summary.workflows_completed, 10);
    // Every task eventually succeeded despite the kills.
    assert_eq!(out.summary.tasks_completed, 10 * 21);
}

#[test]
fn oom_lifecycle_ordering_holds_for_every_killed_task() {
    let out = run_experiment(&oom::config(7)).unwrap();
    let events = &out.metrics.events;
    for e in events {
        if matches!(e.kind, EventKind::PodOomKilled) {
            // After each OOM, the same task must see deletion, then a new
            // running pod, then success.
            let after: Vec<_> = events
                .iter()
                .filter(|x| x.task_id == e.task_id && x.t >= e.t)
                .collect();
            let deleted = after.iter().any(|x| matches!(x.kind, EventKind::PodDeleted));
            let rerun = after.iter().any(|x| matches!(x.kind, EventKind::PodRunning) && x.t > e.t);
            let done = after.iter().any(|x| matches!(x.kind, EventKind::PodSucceeded));
            assert!(deleted && rerun && done, "task {} not healed", e.task_id);
        }
    }
}

#[test]
fn repeated_oom_does_not_livelock() {
    // min_mem equal to the full request: even a full allocation only
    // just suffices; scaled allocations always OOM. The engine must
    // still converge because reallocation happens with fresh residuals.
    let mut cfg = oom::config(3);
    cfg.task.min_mem_mi = 3900;
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.workflows_completed, 10, "oom={} ", out.summary.oom_events);
}

#[test]
fn strict_min_starvation_resolves_when_resources_free() {
    // strict_min + tiny cluster: requests queue but must all eventually
    // run as earlier pods release resources.
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::CyberShake,
        ArrivalPattern::Constant { per_burst: 4, bursts: 1 },
        PolicySpec::adaptive(),
    );
    cfg.cluster.nodes = 2;
    cfg.sample_interval_s = 5.0;
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.workflows_completed, 4);
    assert!(out.summary.alloc_waits > 0, "scenario should exercise waiting");
}

#[test]
fn baseline_survives_overload_too() {
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Ligo,
        ArrivalPattern::Constant { per_burst: 8, bursts: 1 },
        PolicySpec::fcfs(),
    );
    cfg.cluster.nodes = 2;
    cfg.sample_interval_s = 5.0;
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.workflows_completed, 8);
}

#[test]
fn single_node_cluster_serializes_but_completes() {
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Epigenomics,
        ArrivalPattern::Constant { per_burst: 2, bursts: 1 },
        PolicySpec::adaptive(),
    );
    cfg.cluster.nodes = 1;
    cfg.sample_interval_s = 5.0;
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.workflows_completed, 2);
}

#[test]
fn oversized_task_rejected_by_validation() {
    let mut cfg = ExperimentConfig::default();
    cfg.task.req_cpu_milli = cfg.cluster.node_cpu_milli + 1;
    assert!(run_experiment(&cfg).is_err());
}

#[test]
fn zero_beta_tightens_oom_threshold() {
    // With beta = 0 a pod whose allocation equals min_mem exactly runs;
    // the paper's beta >= 20 margin exists for the Stress overhead.
    let mut cfg = oom::config(5);
    cfg.alloc.beta_mi = 0.0;
    let a = run_experiment(&cfg).unwrap();
    cfg.alloc.beta_mi = 500.0;
    let b = run_experiment(&cfg).unwrap();
    assert!(
        b.summary.oom_events >= a.summary.oom_events,
        "larger beta should OOM at least as often: {} vs {}",
        a.summary.oom_events,
        b.summary.oom_events
    );
}
