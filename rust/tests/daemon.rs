//! Daemon-mode integration tests: a real daemon thread, a real socket,
//! the real line protocol. Covers the determinism bridge (held ingest
//! replays a batch workload bit-exactly), graceful drain/shutdown,
//! schedule-DSL sources end-to-end, and protocol resilience.

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::thread::JoinHandle;
use std::time::Duration;

use kubeadaptor::config::{
    ArrivalPattern, DaemonConfig, ExperimentConfig, ScheduleSource, SnapshotMode,
};
use kubeadaptor::daemon::client::Client;
use kubeadaptor::daemon::serve;
use kubeadaptor::engine::{run_experiment, RunOutcome};
use kubeadaptor::util::json::Json;
use kubeadaptor::workflow::WorkflowType;

static SOCK_N: AtomicUsize = AtomicUsize::new(0);

/// A per-test unix socket address that cannot collide across the
/// parallel test threads of one run or across concurrent runs.
fn sock_addr() -> String {
    let n = SOCK_N.fetch_add(1, Ordering::SeqCst);
    format!("unix:/tmp/kubeadaptor-test-{}-{n}.sock", std::process::id())
}

/// The workload both sides of the determinism bridge run: two bursts of
/// two Montage workflows, 60 s apart.
fn base_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 2, bursts: 2 };
    cfg.workload.burst_interval_s = 60.0;
    cfg.sample_interval_s = 5.0;
    cfg
}

fn daemon_cfg(addr: &str, hold: bool) -> ExperimentConfig {
    let mut cfg = base_cfg();
    cfg.daemon = Some(DaemonConfig {
        listen: addr.to_string(),
        pace: None,
        hold,
        sources: Vec::new(),
    });
    cfg
}

fn start_daemon(cfg: ExperimentConfig) -> JoinHandle<anyhow::Result<Option<RunOutcome>>> {
    std::thread::spawn(move || serve(cfg))
}

fn connect(addr: &str) -> Client {
    Client::connect_with_retry(addr, Duration::from_secs(10)).expect("daemon comes up")
}

#[test]
fn held_ingest_over_the_socket_reproduces_the_batch_summary_bit_exactly() {
    let batch = run_experiment(&base_cfg()).unwrap();

    let addr = sock_addr();
    let handle = start_daemon(daemon_cfg(&addr, true));
    let mut client = connect(&addr);

    let status = client.status().unwrap();
    assert_eq!(status.get("state").and_then(Json::as_str), Some("holding"));

    // Replay base_cfg's plan through live ingest: bursts of 2 at t=0, t=60.
    let first = client.submit(WorkflowType::Montage, 2, Some(0.0)).unwrap();
    let second = client.submit(WorkflowType::Montage, 2, Some(60.0)).unwrap();
    assert_ne!(first, second, "submission ids must be distinct");

    client.drain().unwrap();
    let done = client.wait_for_state("completed", Duration::from_secs(30)).unwrap();
    client.shutdown().unwrap();
    let outcome = handle.join().unwrap().unwrap().expect("drained daemon returns an outcome");

    // The determinism bridge: identical to the batch twin, bit for bit.
    assert_eq!(batch.summary.workflows_completed, outcome.summary.workflows_completed);
    assert_eq!(batch.summary.tasks_completed, outcome.summary.tasks_completed);
    assert_eq!(
        batch.summary.total_duration_min.to_bits(),
        outcome.summary.total_duration_min.to_bits()
    );
    assert_eq!(
        batch.summary.avg_workflow_duration_min.to_bits(),
        outcome.summary.avg_workflow_duration_min.to_bits()
    );
    assert_eq!(batch.summary.cpu_usage.to_bits(), outcome.summary.cpu_usage.to_bits());
    assert_eq!(batch.summary.mem_usage.to_bits(), outcome.summary.mem_usage.to_bits());
    assert_eq!(batch.pods_created, outcome.pods_created);
    assert_eq!(batch.serve_cycles, outcome.serve_cycles);
    assert_eq!(batch.store_list_calls, outcome.store_list_calls);

    // The wire-format summary round-trips the same numbers.
    let summary = done.get("summary").expect("completed status carries a summary");
    assert_eq!(
        summary.get("total_duration_min").and_then(Json::as_f64).unwrap().to_bits(),
        batch.summary.total_duration_min.to_bits()
    );
    assert_eq!(
        summary.get("workflows_completed").and_then(Json::as_i64),
        Some(batch.summary.workflows_completed as i64)
    );
    let subs = match summary.get("submissions") {
        Some(Json::Arr(subs)) => subs,
        other => panic!("summary.submissions missing: {other:?}"),
    };
    assert_eq!(subs.len(), 2);
    for sub in subs {
        assert!(sub.get("latency_s").and_then(Json::as_f64).unwrap() > 0.0);
    }
}

#[test]
fn drain_stops_ingest_and_lets_in_flight_work_complete() {
    let addr = sock_addr();
    let handle = start_daemon(daemon_cfg(&addr, false));
    let mut client = connect(&addr);

    client.submit(WorkflowType::Montage, 1, None).unwrap();
    client.drain().unwrap();

    // Post-drain ingest is refused, whether the drain is still running
    // or already finished.
    let err = client.submit(WorkflowType::Montage, 1, None).unwrap_err().to_string();
    assert!(err.contains("not accepting"), "unexpected refusal message: {err}");

    let done = client.wait_for_state("completed", Duration::from_secs(30)).unwrap();
    let summary = done.get("summary").expect("completed status carries a summary");
    assert_eq!(summary.get("workflows_completed").and_then(Json::as_i64), Some(1));
    assert_eq!(summary.get("tasks_unfinished").and_then(Json::as_i64), Some(0));

    client.shutdown().unwrap();
    let outcome = handle.join().unwrap().unwrap().expect("drained daemon returns an outcome");
    assert_eq!(outcome.summary.workflows_completed, 1);
    assert_eq!(outcome.metrics.submissions.len(), 1);
}

#[test]
fn schedule_dsl_sources_feed_submissions_end_to_end() {
    // Client-registered source.
    let addr = sock_addr();
    let handle = start_daemon(daemon_cfg(&addr, true));
    let mut client = connect(&addr);
    let reply = client.schedule("at 0 repeat 2", WorkflowType::Montage, 1).unwrap();
    assert_eq!(reply.get("submissions").and_then(Json::as_i64), Some(2));
    let bad = client.schedule("every -5m", WorkflowType::Montage, 1).unwrap_err();
    assert!(bad.to_string().contains("must be > 0"), "{bad}");
    client.drain().unwrap();
    let done = client.wait_for_state("completed", Duration::from_secs(30)).unwrap();
    let summary = done.get("summary").expect("summary");
    assert_eq!(summary.get("workflows_completed").and_then(Json::as_i64), Some(2));
    client.shutdown().unwrap();
    handle.join().unwrap().unwrap();

    // Config-declared source (no client traffic needed to generate load).
    let addr = sock_addr();
    let mut cfg = daemon_cfg(&addr, true);
    cfg.daemon.as_mut().unwrap().sources.push(ScheduleSource {
        schedule: "at 30 repeat 3".to_string(),
        workflow: WorkflowType::Ligo,
        count: 1,
    });
    let handle = start_daemon(cfg);
    let mut client = connect(&addr);
    client.drain().unwrap();
    let done = client.wait_for_state("completed", Duration::from_secs(30)).unwrap();
    let summary = done.get("summary").expect("summary");
    assert_eq!(summary.get("workflows_completed").and_then(Json::as_i64), Some(3));
    client.shutdown().unwrap();
    let outcome = handle.join().unwrap().unwrap().unwrap();
    assert_eq!(outcome.metrics.submissions.len(), 3);
}

#[test]
fn hot_swap_over_the_socket_updates_policy_and_forecaster() {
    let addr = sock_addr();
    let handle = start_daemon(daemon_cfg(&addr, true));
    let mut client = connect(&addr);

    let policies = client
        .request(&kubeadaptor::daemon::protocol::Request::ListPolicies)
        .unwrap();
    let names = format!("{:?}", policies.get("policies"));
    assert!(names.contains("adaptive"), "roster missing adaptive: {names}");

    let reply = client
        .request(&kubeadaptor::daemon::protocol::Request::SwapPolicy {
            policy: "fcfs".to_string(),
        })
        .unwrap();
    assert_eq!(reply.get("policy").and_then(Json::as_str), Some("baseline"));
    let status = client.status().unwrap();
    assert_eq!(status.get("policy").and_then(Json::as_str), Some("baseline"));

    let reply = client
        .request(&kubeadaptor::daemon::protocol::Request::SwapForecaster {
            forecaster: Some("holt".to_string()),
        })
        .unwrap();
    assert!(
        reply.get("forecaster").and_then(Json::as_str).unwrap_or("").contains("holt"),
        "{reply:?}"
    );
    let reply = client
        .request(&kubeadaptor::daemon::protocol::Request::SwapForecaster { forecaster: None })
        .unwrap();
    assert_eq!(reply.get("forecaster"), Some(&Json::Null));

    let bad = client
        .request(&kubeadaptor::daemon::protocol::Request::SwapPolicy {
            policy: "no-such-policy".to_string(),
        })
        .unwrap_err();
    assert!(bad.to_string().contains("daemon error"), "{bad}");

    // Shutdown without drain: no outcome, clean exit.
    client.shutdown().unwrap();
    let outcome = handle.join().unwrap().unwrap();
    assert!(outcome.is_none(), "un-drained daemon must not fabricate an outcome");
}

#[test]
fn malformed_lines_get_error_replies_without_killing_the_connection() {
    let addr = sock_addr();
    let handle = start_daemon(daemon_cfg(&addr, true));
    // Wait for the socket, then talk raw bytes on a second connection.
    let mut client = connect(&addr);
    let path = addr.strip_prefix("unix:").unwrap();
    let raw = UnixStream::connect(path).unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());
    let mut writer = raw;
    let mut roundtrip = |line: &str| -> Json {
        writeln!(writer, "{line}").unwrap();
        writer.flush().unwrap();
        let mut reply = String::new();
        reader.read_line(&mut reply).unwrap();
        Json::parse(reply.trim()).expect("daemon always replies with json")
    };

    let doc = roundtrip("this is not json");
    assert_eq!(doc.get("ok").and_then(Json::as_bool), Some(false));
    assert!(doc.get("error").and_then(Json::as_str).unwrap().contains("bad request json"));

    let doc = roundtrip(r#"{"cmd":"frobnicate"}"#);
    assert_eq!(doc.get("ok").and_then(Json::as_bool), Some(false));
    assert!(doc.get("error").and_then(Json::as_str).unwrap().contains("unknown cmd"));

    let doc = roundtrip(r#"{"cmd":"submit","workflow":"montage","count":0}"#);
    assert_eq!(doc.get("ok").and_then(Json::as_bool), Some(false));

    // The same connection still serves valid requests afterwards.
    let doc = roundtrip(r#"{"cmd":"status"}"#);
    assert_eq!(doc.get("ok").and_then(Json::as_bool), Some(true));
    assert_eq!(doc.get("state").and_then(Json::as_str), Some("holding"));

    client.shutdown().unwrap();
    handle.join().unwrap().unwrap();
}

#[test]
fn tcp_transport_serves_the_same_protocol() {
    // Derive a port from the pid to keep parallel CI shards apart.
    let port = 21000 + (std::process::id() % 10_000) as u16;
    let addr = format!("tcp:127.0.0.1:{port}");
    let handle = start_daemon(daemon_cfg(&addr, true));
    let mut client = connect(&addr);
    let status = client.status().unwrap();
    assert_eq!(status.get("state").and_then(Json::as_str), Some("holding"));
    client.submit(WorkflowType::Montage, 1, Some(0.0)).unwrap();
    client.drain().unwrap();
    client.wait_for_state("completed", Duration::from_secs(30)).unwrap();
    client.shutdown().unwrap();
    let outcome = handle.join().unwrap().unwrap().unwrap();
    assert_eq!(outcome.summary.workflows_completed, 1);
}

#[test]
fn daemon_runs_on_incremental_snapshots_with_verify_mode() {
    // The serving path on Verify-mode snapshots: every fresh snapshot is
    // cross-checked against a full rebuild while live ingest runs.
    let addr = sock_addr();
    let mut cfg = daemon_cfg(&addr, false);
    cfg.snapshot_mode = SnapshotMode::Verify;
    let handle = start_daemon(cfg);
    let mut client = connect(&addr);
    client.submit(WorkflowType::CyberShake, 2, Some(0.0)).unwrap();
    client.drain().unwrap();
    client.wait_for_state("completed", Duration::from_secs(30)).unwrap();
    client.shutdown().unwrap();
    let outcome = handle.join().unwrap().unwrap().unwrap();
    assert_eq!(outcome.summary.workflows_completed, 2);
    assert_eq!(outcome.tasks_unfinished, 0);
}

#[test]
fn metrics_request_serves_valid_prometheus_text_and_status_carries_counters() {
    let addr = sock_addr();
    let handle = start_daemon(daemon_cfg(&addr, false));
    let mut client = connect(&addr);

    client.submit(WorkflowType::Montage, 1, Some(0.0)).unwrap();
    // Free-running: wait for the submission to complete (state stays
    // "running" until a drain, so poll the progress counter instead).
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    loop {
        let st = client.status().unwrap();
        if st.get("completed").and_then(Json::as_f64).unwrap_or(0.0) >= 1.0 {
            break;
        }
        assert!(std::time::Instant::now() < deadline, "submission never completed");
        std::thread::sleep(Duration::from_millis(20));
    }

    // The live exposition must be valid Prometheus text with counters,
    // gauges and the workflow-duration histogram.
    let text = client.metrics().unwrap();
    kubeadaptor::obs::expo::validate(&text)
        .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{text}"));
    assert!(text.contains("# TYPE ka_serve_cycles_total counter"));
    assert!(text.contains("# TYPE ka_alloc_queue_depth gauge"));
    assert!(text.contains("# TYPE ka_workflow_duration_seconds histogram"));
    assert!(text.contains("ka_workflow_duration_seconds_bucket{le=\"+Inf\"} 1"));

    // The status reply carries the live engine counters.
    let st = client.status().unwrap();
    for key in
        ["serve_cycles", "stale_snapshot_cycles", "alloc_queue_depth", "double_alloc_attempts"]
    {
        assert!(st.get(key).and_then(Json::as_f64).is_some(), "status missing '{key}'");
    }
    assert!(st.get("serve_cycles").and_then(Json::as_f64).unwrap() >= 1.0);
    assert_eq!(st.get("alloc_queue_depth").and_then(Json::as_f64), Some(0.0));

    // After a drain the engine is gone; metrics must refuse, status
    // must drop the live counters and serve the summary instead.
    client.drain().unwrap();
    let done = client.wait_for_state("completed", Duration::from_secs(30)).unwrap();
    assert!(done.get("serve_cycles").is_none());
    let err = client.metrics().expect_err("no live engine after drain");
    assert!(format!("{err:#}").contains("completed"), "unexpected error: {err:#}");

    client.shutdown().unwrap();
    let outcome = handle.join().unwrap().unwrap().expect("drained daemon returns an outcome");
    assert_eq!(outcome.summary.workflows_completed, 1);
}
