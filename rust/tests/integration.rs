//! End-to-end integration tests: full engine runs across policies,
//! patterns and topologies, plus cross-module invariants.

use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
use kubeadaptor::engine::run_experiment;
use kubeadaptor::metrics::EventKind;
use kubeadaptor::workflow::WorkflowType;

fn small(workflow: WorkflowType, pattern: ArrivalPattern, policy: PolicySpec) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper(workflow, pattern, policy);
    cfg.sample_interval_s = 5.0;
    cfg.workload.seed = 11;
    cfg
}

#[test]
fn paper_patterns_complete_for_all_workflows_adaptive() {
    for wf in WorkflowType::paper_set() {
        let cfg = small(wf, ArrivalPattern::Constant { per_burst: 3, bursts: 2 }, PolicySpec::adaptive());
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 6, "{wf:?}");
        let expected_tasks = 6 * match wf {
            WorkflowType::Montage => 21,
            WorkflowType::Epigenomics => 20,
            WorkflowType::CyberShake => 22,
            WorkflowType::Ligo => 23,
            WorkflowType::Custom => unreachable!(),
        };
        assert_eq!(out.summary.tasks_completed, expected_tasks, "{wf:?}");
    }
}

#[test]
fn adaptive_beats_baseline_on_duration_under_contention() {
    // The paper's headline: under bursty arrivals ARAS completes
    // individual workflows faster than FCFS.
    for wf in WorkflowType::paper_set() {
        let a = run_experiment(&small(wf, ArrivalPattern::paper_constant(), PolicySpec::adaptive()))
            .unwrap();
        let b = run_experiment(&small(wf, ArrivalPattern::paper_constant(), PolicySpec::fcfs()))
            .unwrap();
        assert!(
            a.summary.avg_workflow_duration_min < b.summary.avg_workflow_duration_min,
            "{wf:?}: adaptive {} !< baseline {}",
            a.summary.avg_workflow_duration_min,
            b.summary.avg_workflow_duration_min
        );
        assert!(
            a.summary.total_duration_min <= b.summary.total_duration_min + 0.01,
            "{wf:?}: total duration regressed"
        );
    }
}

#[test]
fn determinism_same_seed_same_metrics() {
    let cfg = small(WorkflowType::CyberShake, ArrivalPattern::paper_linear(), PolicySpec::adaptive());
    let a = run_experiment(&cfg).unwrap();
    let b = run_experiment(&cfg).unwrap();
    assert_eq!(a.summary.total_duration_min, b.summary.total_duration_min);
    assert_eq!(a.summary.avg_workflow_duration_min, b.summary.avg_workflow_duration_min);
    assert_eq!(a.summary.cpu_usage, b.summary.cpu_usage);
    assert_eq!(a.metrics.events.len(), b.metrics.events.len());
    assert_eq!(a.pods_created, b.pods_created);
}

#[test]
fn different_seeds_change_durations() {
    let mut c1 = small(WorkflowType::Montage, ArrivalPattern::paper_constant(), PolicySpec::adaptive());
    let mut c2 = c1.clone();
    c1.workload.seed = 1;
    c2.workload.seed = 2;
    let a = run_experiment(&c1).unwrap();
    let b = run_experiment(&c2).unwrap();
    // Durations are sampled from the seed; metrics should differ.
    assert_ne!(a.summary.avg_workflow_duration_min, b.summary.avg_workflow_duration_min);
}

#[test]
fn no_oom_in_table2_configuration() {
    // Table 2 runs use strict_min: allocations below min+beta wait instead
    // of launching doomed pods, so no OOM events should ever occur.
    for pat in [
        ArrivalPattern::paper_constant(),
        ArrivalPattern::paper_linear(),
        ArrivalPattern::paper_pyramid(),
    ] {
        let out = run_experiment(&small(WorkflowType::CyberShake, pat, PolicySpec::adaptive())).unwrap();
        assert_eq!(out.summary.oom_events, 0, "{pat:?}");
    }
}

#[test]
fn event_log_is_causally_ordered_per_task() {
    let out = run_experiment(&small(
        WorkflowType::Epigenomics,
        ArrivalPattern::Constant { per_burst: 2, bursts: 1 },
        PolicySpec::adaptive(),
    ))
    .unwrap();
    // For each task: Requested <= Created <= Running <= Succeeded <= Deleted.
    use std::collections::BTreeMap;
    let mut per_task: BTreeMap<&str, Vec<(&EventKind, f64)>> = BTreeMap::new();
    for e in &out.metrics.events {
        if !e.task_id.is_empty() {
            per_task.entry(e.task_id.as_str()).or_default().push((&e.kind, e.t));
        }
    }
    for (task, evs) in per_task {
        let t_of = |pred: &dyn Fn(&EventKind) -> bool| {
            evs.iter().find(|(k, _)| pred(k)).map(|(_, t)| *t)
        };
        let created = t_of(&|k| matches!(k, EventKind::PodCreated)).unwrap_or(0.0);
        let running = t_of(&|k| matches!(k, EventKind::PodRunning)).expect(task);
        let done = t_of(&|k| matches!(k, EventKind::PodSucceeded)).expect(task);
        let deleted = t_of(&|k| matches!(k, EventKind::PodDeleted)).expect(task);
        assert!(created <= running && running < done && done < deleted, "{task}");
    }
}

#[test]
fn arrival_curve_matches_pattern() {
    let out = run_experiment(&small(
        WorkflowType::Montage,
        ArrivalPattern::paper_pyramid(),
        PolicySpec::adaptive(),
    ))
    .unwrap();
    let curve = &out.metrics.arrivals;
    assert_eq!(curve.last().unwrap().1, 34);
    // Cumulative counts are non-decreasing and burst times are 300s apart.
    for w in curve.windows(2) {
        assert!(w[1].1 >= w[0].1);
        assert!((w[1].0 - w[0].0 - 300.0).abs() < 1e-9);
    }
}

#[test]
fn usage_rates_bounded_and_proportional() {
    let out = run_experiment(&small(
        WorkflowType::Ligo,
        ArrivalPattern::paper_constant(),
        PolicySpec::adaptive(),
    ))
    .unwrap();
    for s in &out.metrics.samples {
        assert!((0.0..=1.0).contains(&s.cpu_rate), "cpu {}", s.cpu_rate);
        assert!((0.0..=1.0).contains(&s.mem_rate), "mem {}", s.mem_rate);
    }
    // CPU and memory rates track each other (paper: identical curves;
    // ours diverge slightly because allocatable mem is calibrated below
    // nominal — see EXPERIMENTS.md §Calibration).
    let avg_gap: f64 = out
        .metrics
        .samples
        .iter()
        .map(|s| (s.cpu_rate - s.mem_rate).abs())
        .sum::<f64>()
        / out.metrics.samples.len().max(1) as f64;
    assert!(avg_gap < 0.15, "cpu/mem curves diverge: {avg_gap}");
}

#[test]
fn custom_workflow_runs_end_to_end() {
    use kubeadaptor::engine::Engine;
    use kubeadaptor::resources::FcfsPolicy;
    use kubeadaptor::workflow::parser;

    let spec = parser::from_json_str(
        r#"{"name":"etl","tasks":[
            {"name":"extract","deps":[]},
            {"name":"t1","deps":[0]},
            {"name":"t2","deps":[0]},
            {"name":"load","deps":[1,2]}
        ]}"#,
    )
    .unwrap();
    let mut cfg = ExperimentConfig::default();
    cfg.workload.workflow = WorkflowType::Custom;
    cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 2, bursts: 1 };
    cfg.sample_interval_s = 5.0;
    let engine = Engine::with_custom_workflow(cfg, Box::new(FcfsPolicy::new()), &spec).unwrap();
    let out = engine.run();
    assert_eq!(out.summary.workflows_completed, 2);
    assert_eq!(out.summary.tasks_completed, 8);
}

#[test]
fn cleaner_removes_all_pods_and_namespaces() {
    for pol in [PolicySpec::adaptive(), PolicySpec::fcfs()] {
        let out = run_experiment(&small(
            WorkflowType::CyberShake,
            ArrivalPattern::Constant { per_burst: 3, bursts: 2 },
            pol.clone(),
        ))
        .unwrap();
        assert_eq!(out.pods_remaining, 0, "{pol:?}: pods left behind");
        assert_eq!(out.namespaces_remaining, 0, "{pol:?}: namespaces left behind");
    }
}

#[test]
fn sla_with_generous_slack_has_no_violations() {
    let mut cfg = small(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 2, bursts: 1 },
        PolicySpec::adaptive(),
    );
    cfg.workload.deadline_slack = Some(3.0);
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.sla_violations, 0);
}

#[test]
fn sla_with_impossible_slack_flags_everything() {
    let mut cfg = small(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 2, bursts: 1 },
        PolicySpec::adaptive(),
    );
    cfg.workload.deadline_slack = Some(0.1); // deadline at 10% of estimate
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.sla_violations, 2);
}

#[test]
fn sla_disabled_reports_zero() {
    let out = run_experiment(&small(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 1, bursts: 1 },
        PolicySpec::adaptive(),
    ))
    .unwrap();
    assert_eq!(out.summary.sla_violations, 0);
}

#[test]
fn baseline_violates_more_slas_than_adaptive_under_contention() {
    let mk = |pol| {
        let mut cfg = small(WorkflowType::Ligo, ArrivalPattern::paper_constant(), pol);
        cfg.workload.deadline_slack = Some(1.6);
        run_experiment(&cfg).unwrap().summary.sla_violations
    };
    let adaptive = mk(PolicySpec::adaptive());
    let baseline = mk(PolicySpec::fcfs());
    assert!(
        adaptive <= baseline,
        "adaptive {adaptive} violations vs baseline {baseline}"
    );
    assert!(baseline > 0, "scenario should stress the baseline");
}

#[test]
fn trace_replay_equals_equivalent_pattern() {
    use kubeadaptor::engine::Engine;
    use kubeadaptor::resources::AdaptivePolicy;
    use kubeadaptor::workload::{self, trace};

    let cfg = small(WorkflowType::Montage, ArrivalPattern::paper_constant(), PolicySpec::adaptive());
    let pattern_out = run_experiment(&cfg).unwrap();

    // Export the same schedule as a trace and replay it.
    let bursts = workload::schedule(&cfg.workload.pattern, cfg.workload.burst_interval_s).unwrap();
    let text = trace::to_json(&bursts);
    let replay = trace::parse(&text).unwrap();
    let trace_out = Engine::with_trace(
        cfg.clone(),
        Box::new(AdaptivePolicy::new(cfg.alloc.alpha, true)),
        replay,
        None,
    )
    .unwrap()
    .run();

    assert_eq!(
        pattern_out.summary.total_duration_min,
        trace_out.summary.total_duration_min
    );
    assert_eq!(pattern_out.pods_created, trace_out.pods_created);
}

#[test]
fn statestore_traffic_scales_with_tasks_not_quadratically() {
    let small_run = run_experiment(&small(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 1, bursts: 1 },
        PolicySpec::adaptive(),
    ))
    .unwrap();
    let big_run = run_experiment(&small(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 4, bursts: 1 },
        PolicySpec::adaptive(),
    ))
    .unwrap();
    let ratio = big_run.statestore_writes as f64 / small_run.statestore_writes as f64;
    assert!(ratio < 16.0, "store writes grew superlinearly: {ratio}");
}
