//! Resource Manager API v2 contract tests.
//!
//! * **Sequential equivalence** (property-checked): `plan()` over a
//!   whole batch — in any order — is bit-identical to serving the same
//!   requests one at a time against a store that is refreshed between
//!   decisions, i.e. exactly what the pre-batching engine did. This is
//!   the guarantee that the batched migration changed no numbers.
//! * **One snapshot per cycle**: the engine takes exactly one discovery
//!   snapshot (one apiserver watch drain) per queue-serve cycle,
//!   asserted through `store_list_calls`.
//! * **Registry round-trip**: every registered policy drives a smoke
//!   campaign end to end.
//! * **Lifecycle hooks**: `on_release` / `on_oom` / `on_tick` fire at
//!   the documented engine points.

use std::cell::Cell;
use std::rc::Rc;

use kubeadaptor::campaign::{self, CampaignSpec};
use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
use kubeadaptor::engine::{run_experiment, Engine};
use kubeadaptor::resources::discovery::NodeResidual;
use kubeadaptor::resources::registry;
use kubeadaptor::resources::{
    AdaptivePolicy, ClusterSnapshot, Decision, FcfsPolicy, Policy, ResidualMap, TaskRequest,
};
use kubeadaptor::simcore::Rng;
use kubeadaptor::statestore::{StateStore, TaskRecord};
use kubeadaptor::testutil::forall;

// ------------------------------------------------------ scenario generator

/// One randomized allocation scenario: a store of pending records, a
/// batch of requests (each with its own record in the store, as the
/// engine guarantees), and a cluster residual state.
#[derive(Debug, Clone)]
struct Scenario {
    /// (task_id, record) pairs; batch members' ids are `b0..bN`.
    records: Vec<(String, TaskRecord)>,
    batch: Vec<TaskRequest>,
    nodes: Vec<(f64, f64)>,
}

impl Scenario {
    fn store(&self) -> StateStore {
        let mut s = StateStore::new();
        for (id, rec) in &self.records {
            s.put_task(id.clone(), rec.clone());
        }
        s
    }

    fn snapshot(&self) -> ClusterSnapshot {
        ClusterSnapshot::from_residuals(ResidualMap {
            entries: self
                .nodes
                .iter()
                .enumerate()
                .map(|(i, &(c, m))| NodeResidual {
                    ip: format!("10.0.0.{i}"),
                    name: format!("node-{i}"),
                    pool: "node".into(),
                    residual_cpu: c,
                    residual_mem: m,
                })
                .collect(),
        })
    }
}

fn record(rng: &mut Rng, t_start: f64) -> TaskRecord {
    let duration = rng.range_inclusive(5, 60) as f64;
    TaskRecord {
        workflow_uid: 1,
        t_start,
        duration,
        t_end: t_start + duration,
        cpu: rng.range_inclusive(100, 4000) as f64,
        mem: rng.range_inclusive(100, 8000) as f64,
        flag: false,
        estimated: true,
    }
}

fn gen_scenario(rng: &mut Rng) -> Scenario {
    let now = rng.range_inclusive(0, 800) as f64;
    let mut records = Vec::new();
    // Background records scattered around the timeline (some in-window,
    // some not; a few completed and therefore invisible).
    for i in 0..rng.range_inclusive(0, 20) as usize {
        let mut rec = record(rng, rng.range_inclusive(0, 1000) as f64);
        rec.flag = rng.range_inclusive(0, 9) == 0;
        records.push((format!("bg{i}"), rec));
    }
    // Batch members: each Ready task has a (stale-estimate) record.
    let batch: Vec<TaskRequest> = (0..rng.range_inclusive(1, 8) as usize)
        .map(|i| {
            let stale_start = rng.range_inclusive(0, 1000) as f64;
            let rec = record(rng, stale_start);
            let req = TaskRequest {
                task_id: format!("b{i}"),
                req_cpu: rec.cpu,
                req_mem: rec.mem,
                min_cpu: 100.0,
                min_mem: 100.0,
                win_start: now,
                win_end: now + rec.duration,
            };
            records.push((format!("b{i}"), rec));
            req
        })
        .collect();
    let nodes: Vec<(f64, f64)> = (0..rng.range_inclusive(1, 8) as usize)
        .map(|_| {
            (rng.range_inclusive(0, 8000) as f64, rng.range_inclusive(0, 16384) as f64)
        })
        .collect();
    Scenario { records, batch, nodes }
}

/// Fisher–Yates over the batch, driven by the scenario RNG.
fn shuffled(batch: &[TaskRequest], rng: &mut Rng) -> Vec<TaskRequest> {
    let mut out: Vec<TaskRequest> = batch.to_vec();
    for i in (1..out.len()).rev() {
        let j = rng.range_inclusive(0, i as i64) as usize;
        out.swap(i, j);
    }
    out
}

// ------------------------------------------------- sequential v1 reference

/// Serve `batch` one request at a time, refreshing each task's record in
/// the store before its decision — the exact store choreography of the
/// pre-batching engine (`try_alloc`). A single-request `plan()` call is
/// the v1 `allocate()`.
fn sequential_plan(
    policy: &mut dyn Policy,
    batch: &[TaskRequest],
    snapshot: &ClusterSnapshot,
    store: &mut StateStore,
) -> Vec<Decision> {
    batch
        .iter()
        .map(|req| {
            store.update_task(&req.task_id, |r| {
                r.t_start = req.win_start;
                r.t_end = req.win_end;
            });
            let mut ds = policy.plan(std::slice::from_ref(req), snapshot, store);
            assert_eq!(ds.len(), 1);
            ds.remove(0)
        })
        .collect()
}

fn check_parity(make: &dyn Fn() -> Box<dyn Policy>, scenario: &Scenario) -> Result<(), String> {
    for shuffle_pass in 0..2 {
        let batch = if shuffle_pass == 0 {
            scenario.batch.clone()
        } else {
            // Order-robustness: the contract holds for any serve order.
            let mut rng = Rng::new(shuffle_pass as u64 + 99);
            shuffled(&scenario.batch, &mut rng)
        };
        let snapshot = scenario.snapshot();

        let mut batched_policy = make();
        let batched = batched_policy.plan(&batch, &snapshot, &scenario.store());

        let mut seq_policy = make();
        let mut seq_store = scenario.store();
        let sequential = sequential_plan(seq_policy.as_mut(), &batch, &snapshot, &mut seq_store);

        if batched != sequential {
            return Err(format!(
                "batched != sequential (shuffle={shuffle_pass})\nbatched:    {batched:?}\nsequential: {sequential:?}"
            ));
        }
    }
    Ok(())
}

#[test]
fn aras_batched_plan_is_bit_identical_to_sequential_v1() {
    let make = || -> Box<dyn Policy> { Box::new(AdaptivePolicy::new(0.8, true)) };
    forall(2024, 150, gen_scenario, |scenario| check_parity(&make, scenario)).unwrap();
}

#[test]
fn aras_without_lookahead_keeps_the_parity_too() {
    let make = || -> Box<dyn Policy> { Box::new(AdaptivePolicy::new(0.8, false)) };
    forall(7, 80, gen_scenario, |scenario| check_parity(&make, scenario)).unwrap();
}

#[test]
fn fcfs_batched_plan_is_bit_identical_to_sequential_v1() {
    let make = || -> Box<dyn Policy> { Box::new(FcfsPolicy::new()) };
    forall(11, 80, gen_scenario, |scenario| check_parity(&make, scenario)).unwrap();
}

#[test]
fn generator_produces_contended_scenarios() {
    // Guard against a vacuous property: a healthy share of scenarios
    // must actually scale allocations (demand exceeding residuals).
    let mut contended = 0;
    let mut rng = Rng::new(2024);
    for _ in 0..150 {
        let scenario = gen_scenario(&mut rng);
        let mut p = AdaptivePolicy::new(0.8, true);
        let ds = p.plan(&scenario.batch, &scenario.snapshot(), &scenario.store());
        if ds
            .iter()
            .zip(&scenario.batch)
            .any(|(d, r)| (d.cpu_milli as f64) < r.req_cpu || (d.mem_mi as f64) < r.req_mem)
        {
            contended += 1;
        }
    }
    assert!(contended >= 10, "only {contended}/150 scenarios exercised scaling");
}

// --------------------------------------------------- engine-level contract

#[test]
fn exactly_one_discovery_snapshot_per_serve_cycle() {
    for policy in [PolicySpec::adaptive(), PolicySpec::fcfs()] {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 3, bursts: 2 };
        cfg.alloc.policy = policy.clone();
        cfg.sample_interval_s = 5.0;
        let out = run_experiment(&cfg).unwrap();
        assert!(out.serve_cycles > 0, "{policy:?}");
        // One watch drain per cycle + the informer's construction sync.
        assert_eq!(
            out.store_list_calls,
            out.serve_cycles + 1,
            "{policy:?}: snapshots per cycle drifted from 1"
        );
    }
}

#[test]
fn campaign_reports_are_stable_across_reruns_with_batched_planning() {
    // The determinism side of the migration contract: same spec + seed
    // produce byte-identical reports under the batched engine.
    let mut spec = CampaignSpec::default();
    spec.name = "v2-stability".into();
    spec.patterns = vec![ArrivalPattern::Constant { per_burst: 2, bursts: 2 }];
    spec.base.workload.pattern = spec.patterns[0];
    spec.base.sample_interval_s = 5.0;
    spec.reps = 2;
    let a = kubeadaptor::report::campaign::summary_csv(&campaign::run(&spec).unwrap()).to_string();
    let b = kubeadaptor::report::campaign::summary_csv(&campaign::run(&spec).unwrap()).to_string();
    assert_eq!(a, b);
    assert!(a.contains(",adaptive,"), "canonical policy labels in the CSV:\n{a}");
    assert!(a.contains(",baseline,"));
}

#[test]
fn smoke_campaign_runs_every_registered_policy() {
    let names = registry::policy_names();
    assert!(names.len() >= 4, "expected the four built-ins, got {names:?}");
    let mut spec = CampaignSpec::default();
    spec.name = "registry-smoke".into();
    spec.policies = names.iter().map(PolicySpec::named).collect();
    spec.patterns = vec![ArrivalPattern::Constant { per_burst: 2, bursts: 1 }];
    spec.base.workload.pattern = spec.patterns[0];
    spec.base.sample_interval_s = 5.0;
    let result = campaign::run(&spec).unwrap();
    assert_eq!(result.runs.len(), names.len());
    for run in &result.runs {
        assert_eq!(
            run.outcome.summary.workflows_completed,
            2,
            "policy {} did not complete the smoke workload",
            run.coord.label()
        );
    }
    // The canonical pair keeps its slots; the rest appear as extras.
    let rows = result.comparison();
    assert_eq!(rows.len(), 1);
    assert!(rows[0].adaptive.is_some() && rows[0].baseline.is_some());
    assert_eq!(rows[0].extras.len(), names.len() - 2);
}

// ------------------------------------------------------------------ hooks

#[derive(Clone, Default)]
struct HookCounts {
    releases: Rc<Cell<u64>>,
    ooms: Rc<Cell<u64>>,
    ticks: Rc<Cell<u64>>,
}

/// ARAS with hook counters bolted on — also demonstrates wrapping a
/// policy without engine involvement.
struct HookProbe {
    inner: AdaptivePolicy,
    counts: HookCounts,
}

impl Policy for HookProbe {
    fn name(&self) -> &str {
        "hook-probe"
    }

    fn plan(
        &mut self,
        batch: &[TaskRequest],
        snapshot: &ClusterSnapshot,
        store: &StateStore,
    ) -> Vec<Decision> {
        self.inner.plan(batch, snapshot, store)
    }

    fn on_release(&mut self, _now: f64) {
        self.counts.releases.set(self.counts.releases.get() + 1);
    }

    fn on_oom(&mut self, _task_id: &str, _now: f64) {
        self.counts.ooms.set(self.counts.ooms.get() + 1);
    }

    fn on_tick(&mut self, _now: f64) {
        self.counts.ticks.set(self.counts.ticks.get() + 1);
    }
}

#[test]
fn lifecycle_hooks_fire_at_the_documented_points() {
    // The Fig. 9 failure scenario produces releases, OOMs and ticks.
    let cfg = kubeadaptor::experiments::oom::config(42);
    let counts = HookCounts::default();
    let probe = HookProbe {
        inner: AdaptivePolicy::new(cfg.alloc.alpha, cfg.alloc.lookahead),
        counts: counts.clone(),
    };
    let out = Engine::with_policy(cfg, Box::new(probe)).unwrap().run();
    assert!(out.summary.oom_events > 0, "scenario must OOM");
    assert_eq!(
        counts.ooms.get(),
        out.summary.oom_events as u64,
        "one on_oom per OOMKilled pod"
    );
    // Every successful pod releases twice (finish + cleanup deletion).
    assert!(counts.releases.get() >= out.summary.tasks_completed as u64);
    assert!(counts.ticks.get() > 0, "sampling ticks reach the policy");
}
