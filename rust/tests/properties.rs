//! Property-based tests on coordinator invariants (testutil::prop —
//! the offline proptest replacement).

use kubeadaptor::cluster::objects::{Node, Pod, PodPhase};
use kubeadaptor::cluster::{Informer, ObjectStore, Scheduler};
use kubeadaptor::config::ArrivalPattern;
use kubeadaptor::resources::adaptive::{DecisionBackend, DecisionInputs, ScalarBackend};
use kubeadaptor::resources::discover;
use kubeadaptor::simcore::Rng;
use kubeadaptor::testutil::{forall, PropResult};

fn pod(uid: u64, cpu: i64, mem: i64) -> Pod {
    Pod {
        uid,
        name: format!("p{uid}"),
        namespace: "ns".into(),
        task_id: format!("t{uid}"),
        phase: PodPhase::Pending,
        node: None,
        request_cpu: cpu,
        request_mem: mem,
        min_mem: 100,
        duration: 10.0,
        created_at: 0.0,
        started_at: None,
        finished_at: None,
    }
}

/// Scheduler never overcommits a node, for any random pod stream.
#[test]
fn prop_scheduler_never_overcommits() {
    forall(
        0xC0FFEE,
        60,
        |rng: &mut Rng| {
            let n_nodes = rng.range_inclusive(1, 8) as usize;
            let pods: Vec<(i64, i64)> = (0..rng.range_inclusive(1, 60))
                .map(|_| (rng.range_inclusive(100, 4000), rng.range_inclusive(100, 8000)))
                .collect();
            (n_nodes, pods)
        },
        |(n_nodes, pods)| {
            let mut store = ObjectStore::new();
            for i in 0..*n_nodes {
                store.add_node(Node::new(i, 8000, 16384));
            }
            let mut sched = Scheduler::new();
            for (i, &(cpu, mem)) in pods.iter().enumerate() {
                store.create_pod(pod(i as u64 + 1, cpu, mem));
                let _ = sched.schedule(&mut store, i as u64 + 1);
            }
            for i in 0..*n_nodes {
                let (rc, rm) = store.residual_of(&format!("node-{i}")).unwrap();
                if rc < 0 || rm < 0 {
                    return Err(format!("node-{i} overcommitted: cpu={rc} mem={rm}"));
                }
            }
            Ok(())
        },
    )
    .unwrap();
}

/// Informer cache equals ground truth after any mutation sequence.
#[test]
fn prop_informer_cache_converges() {
    forall(
        0xBEEF,
        60,
        |rng: &mut Rng| {
            // op stream: 0=create, 1=advance phase, 2=delete, 3=sync
            (0..rng.range_inclusive(5, 80)).map(|_| rng.below(4) as u8).collect::<Vec<u8>>()
        },
        |ops| {
            let mut store = ObjectStore::new();
            store.add_node(Node::new(0, 8000, 16384));
            let mut inf = Informer::new();
            let mut next_uid = 0u64;
            let mut live: Vec<u64> = Vec::new();
            for (step, &op) in ops.iter().enumerate() {
                match op {
                    0 => {
                        next_uid += 1;
                        store.create_pod(pod(next_uid, 500, 500));
                        live.push(next_uid);
                    }
                    1 => {
                        if let Some(&uid) = live.first() {
                            let phase = store.pod(uid).unwrap().phase;
                            let next = match phase {
                                PodPhase::Pending => PodPhase::Running,
                                PodPhase::Running => PodPhase::Succeeded,
                                _ => PodPhase::Succeeded,
                            };
                            let _ = store.set_pod_phase(uid, next, step as f64);
                        }
                    }
                    2 => {
                        if let Some(uid) = live.pop() {
                            store.delete_pod(uid);
                        }
                    }
                    _ => {
                        inf.sync(&store);
                    }
                }
            }
            inf.sync(&store);
            if inf.pod_list().len() != store.pod_count() {
                return Err(format!(
                    "cache has {} pods, store has {}",
                    inf.pod_list().len(),
                    store.pod_count()
                ));
            }
            for p in inf.pod_list() {
                let truth = store.pod(p.uid).ok_or("ghost pod in cache")?;
                if truth.phase != p.phase {
                    return Err(format!("pod {} phase stale", p.uid));
                }
            }
            Ok(())
        },
    )
    .unwrap();
}

/// ARAS allocation is always bounded: never exceeds the request, and
/// under a fallback regime never exceeds alpha * biggest node (both
/// dimensions), for arbitrary cluster states.
#[test]
fn prop_aras_allocation_bounded() {
    forall(
        0xA11C,
        300,
        |rng: &mut Rng| {
            let records: Vec<(f32, f32, f32)> = (0..rng.range_inclusive(0, 100))
                .map(|_| {
                    (
                        rng.range_inclusive(0, 500) as f32,
                        rng.range_inclusive(100, 4000) as f32,
                        rng.range_inclusive(100, 8000) as f32,
                    )
                })
                .collect();
            let ws = rng.range_inclusive(0, 400) as f32;
            DecisionInputs {
                records,
                win_start: ws,
                win_end: ws + rng.range_inclusive(1, 120) as f32,
                req_cpu: rng.range_inclusive(100, 4000) as f32,
                req_mem: rng.range_inclusive(100, 8000) as f32,
                node_res: (0..rng.range_inclusive(1, 10))
                    .map(|_| {
                        (rng.range_inclusive(0, 8000) as f32, rng.range_inclusive(0, 16384) as f32)
                    })
                    .collect(),
                alpha: 0.8,
            }
        },
        |inputs| {
            let out = ScalarBackend.decide(inputs);
            let remax_cpu =
                inputs.node_res.iter().map(|r| r.0).fold(f32::NEG_INFINITY, f32::max);
            let total_cpu: f32 = inputs.node_res.iter().map(|r| r.0).sum();
            let cut = inputs.req_cpu * (total_cpu / out.request_cpu.max(1.0));
            let bound = inputs.req_cpu.max(remax_cpu * inputs.alpha).max(cut) + 1e-2;
            if out.alloc_cpu > bound {
                return Err(format!("alloc_cpu {} > bound {bound}", out.alloc_cpu));
            }
            if out.request_cpu < inputs.req_cpu {
                return Err("window demand below own request".into());
            }
            if !out.alloc_cpu.is_finite() || !out.alloc_mem.is_finite() {
                return Err("non-finite allocation".into());
            }
            Ok(())
        },
    )
    .unwrap();
}

/// Arrival patterns always hit their configured totals, whatever the
/// parameters.
#[test]
fn prop_arrival_patterns_sum_to_total() {
    forall(
        0xF00D,
        200,
        |rng: &mut Rng| {
            let which = rng.below(3);
            let total = rng.range_inclusive(1, 80) as usize;
            match which {
                0 => ArrivalPattern::Constant {
                    per_burst: rng.range_inclusive(1, 9) as usize,
                    bursts: rng.range_inclusive(1, 9) as usize,
                },
                1 => ArrivalPattern::Linear {
                    d: rng.range_inclusive(1, 4) as usize,
                    k: rng.range_inclusive(1, 4) as usize,
                    total,
                },
                _ => ArrivalPattern::Pyramid {
                    start: 2,
                    step: 2,
                    peak: rng.range_inclusive(4, 10) as usize,
                    total,
                },
            }
        },
        |pat| {
            let bursts = pat.bursts();
            if bursts.iter().any(|&b| b == 0) {
                return Err(format!("zero burst in {bursts:?}"));
            }
            let sum: usize = bursts.iter().sum();
            let want = match pat {
                ArrivalPattern::Constant { per_burst, bursts } => per_burst * bursts,
                ArrivalPattern::Linear { total, .. } => *total,
                ArrivalPattern::Pyramid { total, .. } => *total,
            };
            if sum != want {
                return Err(format!("{pat:?}: sum {sum} != {want}"));
            }
            Ok(())
        },
    )
    .unwrap();
}

/// Discovery over a random informer state always reports residuals that
/// sum to (allocatable - live requests), per node and in aggregate.
#[test]
fn prop_discovery_conserves_resources() {
    forall(
        0xD15C,
        80,
        |rng: &mut Rng| {
            let n_nodes = rng.range_inclusive(1, 6) as usize;
            let placements: Vec<(usize, i64, i64, u8)> = (0..rng.range_inclusive(0, 40))
                .map(|_| {
                    (
                        rng.below(n_nodes as u64) as usize,
                        rng.range_inclusive(100, 2000),
                        rng.range_inclusive(100, 4000),
                        rng.below(3) as u8, // 0=pending 1=running 2=succeeded
                    )
                })
                .collect();
            (n_nodes, placements)
        },
        |(n_nodes, placements)| {
            let mut store = ObjectStore::new();
            for i in 0..*n_nodes {
                store.add_node(Node::new(i, 8000, 16384));
            }
            let mut live_cpu = 0i64;
            for (i, &(node, cpu, mem, phase)) in placements.iter().enumerate() {
                let mut p = pod(i as u64 + 1, cpu, mem);
                p.node = Some(format!("node-{node}"));
                store.create_pod(p);
                let uid = i as u64 + 1;
                match phase {
                    1 => {
                        store.set_pod_phase(uid, PodPhase::Running, 1.0);
                        live_cpu += cpu;
                    }
                    2 => {
                        store.set_pod_phase(uid, PodPhase::Running, 1.0);
                        store.set_pod_phase(uid, PodPhase::Succeeded, 2.0);
                    }
                    _ => live_cpu += cpu,
                }
            }
            let mut inf = Informer::new();
            inf.sync(&store);
            let map = discover(&inf);
            let want_total = (*n_nodes as i64 * 8000 - live_cpu) as f64;
            if (map.total_cpu() - want_total).abs() > 1e-6 {
                return Err(format!("total cpu {} != {want_total}", map.total_cpu()));
            }
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn prop_results_are_deterministic_per_seed() {
    // Meta-property: two engines with equal seeds produce equal pod counts.
    let r = forall(
        7,
        5,
        |rng: &mut Rng| rng.range_inclusive(1, 10_000) as u64,
        |&seed| {
            use kubeadaptor::config::{ExperimentConfig, PolicySpec};
            use kubeadaptor::engine::run_experiment;
            use kubeadaptor::workflow::WorkflowType;
            let mut cfg = ExperimentConfig::paper(
                WorkflowType::Montage,
                ArrivalPattern::Constant { per_burst: 2, bursts: 1 },
                PolicySpec::adaptive(),
            );
            cfg.workload.seed = seed;
            cfg.sample_interval_s = 10.0;
            let a = run_experiment(&cfg).map_err(|e| e.to_string())?;
            let b = run_experiment(&cfg).map_err(|e| e.to_string())?;
            if a.pods_created != b.pods_created {
                return Err(format!("seed {seed}: {} vs {}", a.pods_created, b.pods_created));
            }
            Ok(())
        },
    );
    assert!(matches!(r, PropResult::Ok { .. }));
}

// ---------------------------------------------------------------------
// Scheduler::select_node properties (cluster-dynamics lockdown): the
// selection is always feasible, invariant under node-insertion order,
// and the LeastAllocated tie-break is a total, deterministic order.
// ---------------------------------------------------------------------

/// A random heterogeneous cluster: node shapes, pre-placed load, one
/// probe request. Returned as plain data so the property can rebuild
/// the store under different insertion orders.
#[allow(clippy::type_complexity)]
fn gen_cluster(
    rng: &mut Rng,
) -> (Vec<(String, i64, i64, bool)>, Vec<(usize, i64, i64)>, (i64, i64)) {
    let n_nodes = rng.range_inclusive(1, 9) as usize;
    let nodes: Vec<(String, i64, i64, bool)> = (0..n_nodes)
        .map(|i| {
            // A few duplicate shapes to force ties; a few cordoned nodes.
            let shape = rng.below(3);
            let (cpu, mem) = match shape {
                0 => (4000, 8192),
                1 => (8000, 16384),
                _ => (16000, 32768),
            };
            (format!("node-{i}"), cpu, mem, rng.below(5) == 0)
        })
        .collect();
    let load: Vec<(usize, i64, i64)> = (0..rng.range_inclusive(0, 25))
        .map(|_| {
            (
                rng.below(n_nodes as u64) as usize,
                rng.range_inclusive(100, 4000),
                rng.range_inclusive(100, 8000),
            )
        })
        .collect();
    let request = (rng.range_inclusive(100, 9000), rng.range_inclusive(100, 17000));
    (nodes, load, request)
}

/// Build a store with the given node insertion order.
fn build_store(
    order: &[usize],
    nodes: &[(String, i64, i64, bool)],
    load: &[(usize, i64, i64)],
) -> ObjectStore {
    let mut store = ObjectStore::new();
    for &i in order {
        let (name, cpu, mem, cordoned) = &nodes[i];
        let mut node = Node::new(i, *cpu, *mem);
        node.name = name.clone();
        store.add_node(node);
        if *cordoned {
            store.set_schedulable(name, false);
        }
    }
    for (j, &(node_idx, cpu, mem)) in load.iter().enumerate() {
        let mut p = pod(j as u64 + 1, cpu, mem);
        p.node = Some(nodes[node_idx].0.clone());
        store.create_pod(p);
    }
    store
}

#[test]
fn prop_select_node_feasible_and_insertion_order_invariant() {
    forall(
        0x5E1EC7,
        150,
        |rng: &mut Rng| {
            let (nodes, load, request) = gen_cluster(rng);
            let mut shuffled: Vec<usize> = (0..nodes.len()).collect();
            rng.shuffle(&mut shuffled);
            (nodes, load, request, shuffled)
        },
        |(nodes, load, request, shuffled)| {
            let forward: Vec<usize> = (0..nodes.len()).collect();
            let store_a = build_store(&forward, nodes, load);
            let store_b = build_store(shuffled, nodes, load);
            let probe = pod(9999, request.0, request.1);
            let sel_a = Scheduler::new().select_node(&store_a, &probe);
            let sel_b = Scheduler::new().select_node(&store_b, &probe);
            if sel_a != sel_b {
                return Err(format!("insertion order changed selection: {sel_a:?} vs {sel_b:?}"));
            }
            match sel_a {
                None => {
                    // None is only legal when no schedulable node fits.
                    for (name, _, _, _) in nodes {
                        let node = store_a.node(name).unwrap();
                        let (rc, rm) = store_a.residual_of(name).unwrap();
                        if node.schedulable && rc >= request.0 && rm >= request.1 {
                            return Err(format!("{name} fits but nothing selected"));
                        }
                    }
                }
                Some(name) => {
                    let node = store_a.node(&name).ok_or("selected unknown node")?;
                    if !node.schedulable {
                        return Err(format!("{name} is cordoned"));
                    }
                    let (rc, rm) = store_a.residual_of(&name).unwrap();
                    if rc < request.0 || rm < request.1 {
                        return Err(format!(
                            "{name} infeasible: residual ({rc}, {rm}) < request {request:?}"
                        ));
                    }
                }
            }
            Ok(())
        },
    )
    .unwrap();
}

#[test]
fn prop_select_node_tie_break_is_total_and_deterministic() {
    // All nodes identical ⇒ the LeastAllocated order degenerates to the
    // name tie-break, which must pick the lexicographically smallest
    // name no matter how many equal candidates exist or how the store
    // was built — and repeated calls must agree with themselves.
    forall(
        0x71EB4EA4,
        100,
        |rng: &mut Rng| {
            let n = rng.range_inclusive(2, 12) as usize;
            let mut order: Vec<usize> = (0..n).collect();
            rng.shuffle(&mut order);
            (n, order)
        },
        |(n, order)| {
            let nodes: Vec<(String, i64, i64, bool)> =
                (0..*n).map(|i| (format!("node-{i}"), 8000, 16384, false)).collect();
            let store = build_store(order, &nodes, &[]);
            let probe = pod(1, 1000, 1000);
            let mut sched = Scheduler::new();
            let first = sched.select_node(&store, &probe).ok_or("no selection")?;
            // Smallest name: "node-0" < "node-1" < "node-10" < "node-2" …
            let smallest = store
                .node_names()
                .first()
                .cloned()
                .ok_or("empty store")?;
            if first != smallest {
                return Err(format!("tie-break picked {first}, expected {smallest}"));
            }
            let again = sched.select_node(&store, &probe).ok_or("no selection")?;
            if again != first {
                return Err(format!("repeated call flipped: {first} vs {again}"));
            }
            Ok(())
        },
    )
    .unwrap();
}
