//! Cluster-dynamics integration tests: the declarative JSON surface
//! (config pools/events/autoscaler, the cluster-events trace file)
//! driven end-to-end through `run_experiment`, with the eviction
//! accounting invariant checked on every run.

use kubeadaptor::cluster::{dynamics, ChurnProfile};
use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
use kubeadaptor::engine::{run_experiment, RunOutcome};

fn assert_accounted(out: &RunOutcome) {
    assert_eq!(
        out.pods_evicted,
        out.evicted_rescheduled + out.evicted_unresolved as u64,
        "every evicted pod must be rescheduled or accounted unresolved"
    );
    assert_eq!(out.summary.evictions as u64, out.pods_evicted);
}

#[test]
fn json_config_with_pools_events_and_autoscaler_runs_end_to_end() {
    let cfg = ExperimentConfig::from_json_str(
        r#"{
            "pools": [
                {"label": "core", "count": 3, "cpu_milli": 8000, "mem_mi": 10240},
                {"label": "burst", "count": 1, "cpu_milli": 16000, "mem_mi": 20480}
            ],
            "cluster_events": [
                {"at": 30, "kind": "join", "pool": "burst", "count": 1},
                {"at": 90, "kind": "drain", "node": "core-0"},
                {"at": 150, "kind": "crash", "node": "core-1"}
            ],
            "autoscaler": {"min_nodes": 2, "max_nodes": 8, "provision_s": 10},
            "pattern": "constant",
            "seed": 9
        }"#,
    )
    .unwrap();
    let mut cfg = cfg;
    // Trim the paper pattern (5x6) down for test runtime.
    cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 3, bursts: 2 };
    cfg.workload.burst_interval_s = 120.0;
    cfg.sample_interval_s = 5.0;
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.workflows_completed, 6);
    assert_eq!(out.tasks_unfinished, 0);
    assert!(out.summary.nodes_joined >= 1, "scheduled join must land");
    // The scheduled drain + crash; the autoscaler may add (and later
    // drain) more on top, so this is a floor, not an exact count.
    assert!(out.summary.nodes_removed >= 2, "drain + crash");
    assert_accounted(&out);
    // Node names are pool-scoped.
    assert!(out
        .metrics
        .events
        .iter()
        .any(|e| matches!(&e.kind,
            kubeadaptor::metrics::EventKind::NodeJoined { node } if node == "burst-1")));
}

#[test]
fn cluster_events_trace_file_replays() {
    let dir = std::env::temp_dir().join("ka_dyn_trace_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("events.json");

    // Export → file → parse round-trip, exactly like workload traces.
    let profile = ChurnProfile::drain_storm(15.0, 60.0, 2);
    std::fs::write(&path, dynamics::to_json(&profile.events)).unwrap();
    let replayed = dynamics::from_file(path.to_str().unwrap()).unwrap();
    assert_eq!(replayed, profile.events);

    let mut cfg = ExperimentConfig::default();
    cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 3, bursts: 1 };
    cfg.sample_interval_s = 5.0;
    cfg.cluster.events = replayed;
    let out = run_experiment(&cfg).unwrap();
    assert_eq!(out.summary.workflows_completed, 3);
    assert!(out.pods_evicted > 0, "t=15 drain hits the running source pods");
    assert_accounted(&out);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn crash_storm_profile_self_heals_for_both_policies() {
    for policy in [PolicySpec::adaptive(), PolicySpec::fcfs()] {
        let mut cfg = ExperimentConfig::default();
        cfg.alloc.policy = policy.clone();
        cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 4, bursts: 1 };
        cfg.sample_interval_s = 5.0;
        let profile = ChurnProfile::crash_storm(15.0, 45.0, 2);
        cfg.cluster.events = profile.events;
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(
            out.summary.workflows_completed,
            4,
            "{}: crash storm must self-heal",
            policy.label()
        );
        assert!(out.pods_evicted > 0, "{}", policy.label());
        assert_eq!(out.tasks_unfinished, 0);
        assert_accounted(&out);
        assert_eq!(out.summary.nodes_removed, 2);
        assert_eq!(out.pods_remaining, 0, "cleaner must sweep evicted pods");
        assert_eq!(out.namespaces_remaining, 0);
    }
}
