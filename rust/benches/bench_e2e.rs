//! End-to-end benchmark: one full Table 2 cell per (workflow × pattern ×
//! policy) — the cost of regenerating the paper's evaluation, and the
//! DES throughput (simulated seconds per wall second).
//!
//! This is the bench behind experiment T2 (DESIGN.md §4): it runs each
//! combination once and reports both the wall time of the run and the
//! headline metrics, so regressions in either performance or *results*
//! show up in `cargo bench` output.

use kubeadaptor::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
use kubeadaptor::engine::run_experiment;
use kubeadaptor::util::bench::{bench, header, report};
use kubeadaptor::workflow::WorkflowType;

fn main() {
    header("T2 end-to-end: full paper runs (30-34 workflows each)");
    let mut total_sim_minutes = 0.0;
    let mut total_wall_ms = 0.0;
    for wf in WorkflowType::paper_set() {
        for (pat, pat_name) in [
            (ArrivalPattern::paper_constant(), "constant"),
            (ArrivalPattern::paper_linear(), "linear"),
            (ArrivalPattern::paper_pyramid(), "pyramid"),
        ] {
            for pol in [PolicySpec::adaptive(), PolicySpec::fcfs()] {
                let mut cfg = ExperimentConfig::paper(wf, pat, pol.clone());
                cfg.sample_interval_s = 5.0;
                let mut last_total = 0.0;
                let r = bench(
                    &format!("{}/{}/{}", wf.name(), pat_name, pol.label()),
                    1,
                    5,
                    || {
                        let out = run_experiment(&cfg).expect("run");
                        last_total = out.summary.total_duration_min;
                    },
                );
                total_sim_minutes += last_total;
                total_wall_ms += r.summary.mean;
                report(&r);
            }
        }
    }
    println!(
        "\nDES speed: {:.0}x real time ({:.0} simulated minutes in {:.0} ms wall)",
        total_sim_minutes * 60.0 * 1000.0 / total_wall_ms,
        total_sim_minutes,
        total_wall_ms
    );
}
