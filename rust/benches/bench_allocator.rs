//! Allocation hot-path microbenchmarks: the ARAS decision (Algorithms
//! 1–3) on the scalar backend vs the AOT-compiled PJRT module, across
//! record-count scales, plus resource discovery (Algorithm 2).
//!
//! The scalar/PJRT comparison quantifies the FFI+copy overhead of running
//! the decision math on the compiled XLA module — see EXPERIMENTS.md
//! §Perf for the discussion.

use kubeadaptor::cluster::objects::{Node, Pod, PodPhase};
use kubeadaptor::cluster::{Informer, ObjectStore};
use kubeadaptor::resources::adaptive::{DecisionBackend, DecisionInputs, ScalarBackend};
use kubeadaptor::resources::discover;
use kubeadaptor::runtime::PjrtBackend;
use kubeadaptor::simcore::Rng;
use kubeadaptor::util::bench::{bench, header, report};

fn inputs(rng: &mut Rng, n_records: usize, n_nodes: usize) -> DecisionInputs {
    DecisionInputs {
        records: (0..n_records)
            .map(|_| {
                (
                    rng.range_inclusive(0, 1000) as f32,
                    rng.range_inclusive(100, 4000) as f32,
                    rng.range_inclusive(100, 8000) as f32,
                )
            })
            .collect(),
        win_start: 100.0,
        win_end: 400.0,
        req_cpu: 2000.0,
        req_mem: 4000.0,
        node_res: (0..n_nodes)
            .map(|_| (rng.range_inclusive(0, 8000) as f32, rng.range_inclusive(0, 16384) as f32))
            .collect(),
        alpha: 0.8,
    }
}

fn main() {
    let mut rng = Rng::new(99);

    header("ARAS decision: scalar backend");
    for n in [0usize, 32, 128, 512] {
        let input = inputs(&mut rng, n, 6);
        let mut backend = ScalarBackend;
        let r = bench(&format!("scalar/records={n}"), 100, 2000, || {
            std::hint::black_box(backend.decide(&input));
        });
        report(&r);
    }

    header("ARAS decision: PJRT backend (AOT XLA module)");
    match PjrtBackend::load_default() {
        Ok(mut backend) => {
            for n in [0usize, 32, 128, 512] {
                let input = inputs(&mut rng, n, 6);
                let r = bench(&format!("pjrt/records={n}"), 10, 200, || {
                    std::hint::black_box(backend.decide(&input));
                });
                report(&r);
            }
        }
        Err(e) => println!("(pjrt skipped: {e})"),
    }

    header("usage-curve integration: Rust reduction vs PJRT kernel");
    {
        use kubeadaptor::metrics::{Collector, UsageSample};
        let mut c = Collector::new();
        for i in 0..2000 {
            c.sample(UsageSample {
                t: i as f64 * 5.0,
                cpu_used: 0.0,
                mem_used: 0.0,
                cpu_rate: ((i % 13) as f64) / 13.0,
                mem_rate: 0.3,
                running_pods: i % 20,
                nodes: 6,
            });
        }
        let r = bench("usage/rust_reduction_2000_samples", 100, 2000, || {
            std::hint::black_box(c.summarize());
        });
        report(&r);
        if let Ok(integral) = kubeadaptor::runtime::UsageIntegral::load_default() {
            let r = bench("usage/pjrt_kernel_2000_samples", 10, 200, || {
                std::hint::black_box(integral.mean_rate(&c.samples, |s| s.cpu_rate).unwrap());
            });
            report(&r);
        }
    }

    header("Resource discovery (Algorithm 2) over informer cache");
    for pods in [10usize, 100, 500] {
        let mut store = ObjectStore::new();
        for i in 0..6 {
            store.add_node(Node::new(i, 8000, 16384));
        }
        for uid in 0..pods as u64 {
            let mut p = Pod {
                uid: uid + 1,
                name: format!("p{uid}"),
                namespace: "ns".into(),
                task_id: format!("t{uid}"),
                phase: PodPhase::Running,
                node: Some(format!("node-{}", uid % 6)),
                request_cpu: 500,
                request_mem: 1000,
                min_mem: 500,
                duration: 10.0,
                created_at: 0.0,
                started_at: None,
                finished_at: None,
            };
            p.phase = PodPhase::Pending;
            store.create_pod(p);
        }
        let mut informer = Informer::new();
        informer.sync(&store);
        let r = bench(&format!("discover/pods={pods}"), 100, 2000, || {
            std::hint::black_box(discover(&informer));
        });
        report(&r);
    }
}
