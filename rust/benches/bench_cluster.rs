//! Cluster-substrate microbenchmarks: object-store mutations, informer
//! sync, scheduler placement and the DES event queue — the building
//! blocks whose costs bound engine throughput.

use kubeadaptor::cluster::objects::{Node, Pod, PodPhase};
use kubeadaptor::cluster::{Informer, ObjectStore, Scheduler};
use kubeadaptor::simcore::EventQueue;
use kubeadaptor::util::bench::{bench, header, report};

fn pod(uid: u64) -> Pod {
    Pod {
        uid,
        name: format!("p{uid}"),
        namespace: "ns".into(),
        task_id: format!("t{uid}"),
        phase: PodPhase::Pending,
        node: None,
        request_cpu: 1000,
        request_mem: 2000,
        min_mem: 1000,
        duration: 10.0,
        created_at: 0.0,
        started_at: None,
        finished_at: None,
    }
}

fn main() {
    header("object store: pod lifecycle (create+bind+run+succeed+delete)");
    let r = bench("store/full_lifecycle_x100", 10, 500, || {
        let mut store = ObjectStore::new();
        for i in 0..6 {
            store.add_node(Node::new(i, 8000, 16384));
        }
        for uid in 1..=100u64 {
            store.create_pod(pod(uid));
            store.bind_pod(uid, &format!("node-{}", uid % 6));
            store.set_pod_phase(uid, PodPhase::Running, 1.0);
            store.set_pod_phase(uid, PodPhase::Succeeded, 2.0);
            store.delete_pod(uid);
        }
        std::hint::black_box(store.resource_version());
    });
    report(&r);

    header("informer: incremental sync");
    for churn in [10usize, 100, 1000] {
        let r = bench(&format!("informer/sync_churn={churn}"), 10, 300, || {
            let mut store = ObjectStore::new();
            store.add_node(Node::new(0, 8000, 16384));
            let mut inf = Informer::new();
            inf.sync(&store);
            for uid in 1..=churn as u64 {
                store.create_pod(pod(uid));
            }
            inf.sync(&store);
            std::hint::black_box(inf.pod_list().len());
        });
        report(&r);
    }

    header("scheduler: placement under load");
    for nodes in [6usize, 32] {
        let r = bench(&format!("scheduler/place_100_pods_{nodes}_nodes"), 10, 300, || {
            let mut store = ObjectStore::new();
            for i in 0..nodes {
                store.add_node(Node::new(i, 8000, 16384));
            }
            let mut sched = Scheduler::new();
            for uid in 1..=100u64 {
                store.create_pod(pod(uid));
                let _ = sched.schedule(&mut store, uid);
            }
            std::hint::black_box(sched.attempts());
        });
        report(&r);
    }

    header("scheduler: select_node tie-break (by-ref compare, no String clones)");
    {
        // Worst case for the tie-break: every node identical, so every
        // candidate survives to the final comparison. Micro-assert the
        // deterministic outcome before timing it.
        let mut store = ObjectStore::new();
        for i in 0..64 {
            store.add_node(Node::new(i, 8000, 16384));
        }
        let probe = pod(1);
        let mut sched = Scheduler::new();
        let first = sched.select_node(&store, &probe).expect("fits");
        assert_eq!(first, "node-0", "tie-break must pick the smallest name");
        assert_eq!(
            sched.select_node(&store, &probe).as_deref(),
            Some("node-0"),
            "tie-break must be deterministic across calls"
        );
        let r = bench("scheduler/select_node_64way_tie", 10, 2000, || {
            std::hint::black_box(sched.select_node(&store, &probe));
        });
        report(&r);
    }

    header("DES event queue");
    let r = bench("event_queue/push_pop_100k", 3, 100, || {
        let mut q: EventQueue<u64> = EventQueue::new();
        for i in 0..100_000u64 {
            q.schedule_at((i % 977) as f64, i);
        }
        while q.pop().is_some() {}
        std::hint::black_box(q.processed());
    });
    report(&r);
}
