//! Human-readable schedule DSL for recurring workflow submissions.
//!
//! The daemon's answer to batch-mode [`crate::config::ArrivalPattern`]s:
//! instead of a pre-materialized burst list, a submission source carries
//! a small declarative schedule compiled from a one-line expression (the
//! cirrus `schedule-dsl` idiom):
//!
//! ```text
//! at 60                      one submission at virtual t=60s
//! at 60 repeat 10            ten submissions at t=60s (one burst of 10)
//! every 5m                   unbounded: t=300, 600, 900, ...
//! every 30s from 2m repeat 5 t=120, 150, 180, 210, 240
//! ```
//!
//! Durations are seconds by default; the `s`/`m`/`h` suffixes scale by
//! 1/60/3600. Parsing is hardened: unknown units, non-positive
//! intervals, non-finite times (`1e999` parses to `inf`) and `repeat 0`
//! are all rejected with actionable messages, and [`Schedule`] prints a
//! canonical form that re-parses to a bit-identical value (the
//! parse→print→parse round-trip property below).

use std::fmt;

use crate::simcore::SimTime;

/// A compiled submission schedule: the virtual-time instants at which a
/// daemon submission source fires.
#[derive(Debug, Clone, PartialEq)]
pub enum Schedule {
    /// `repeat` submissions, all at instant `at` (one burst).
    At { at: SimTime, repeat: u64 },
    /// Submissions at `from + k * interval` for `k = 0, 1, ...`;
    /// `repeat = None` never stops. `from` defaults to one `interval`
    /// (the cirrus reading of "every 5m": first run five minutes in).
    Every { interval: SimTime, from: SimTime, repeat: Option<u64> },
}

impl Schedule {
    /// Parse a schedule expression. See the module docs for the grammar.
    pub fn parse(input: &str) -> anyhow::Result<Schedule> {
        let toks: Vec<&str> = input.split_whitespace().collect();
        let mut t = toks.iter().copied().peekable();
        let head = t.next().ok_or_else(|| {
            anyhow::anyhow!("empty schedule: expected 'at <time>' or 'every <interval>'")
        })?;
        let sched = match head {
            "at" => {
                let at = parse_duration(take(&mut t, "at", "a time")?)?;
                anyhow::ensure!(at >= 0.0, "'at {at}': time must be >= 0");
                let repeat = match t.peek() {
                    Some(&"repeat") => {
                        t.next();
                        parse_repeat(take(&mut t, "repeat", "a count")?)?
                    }
                    _ => 1,
                };
                Schedule::At { at, repeat }
            }
            "every" => {
                let interval = parse_duration(take(&mut t, "every", "an interval")?)?;
                anyhow::ensure!(
                    interval > 0.0,
                    "'every' interval must be > 0, got {interval}"
                );
                let mut from = interval;
                let mut repeat = None;
                if t.peek() == Some(&&"from") {
                    t.next();
                    from = parse_duration(take(&mut t, "from", "a start time")?)?;
                    anyhow::ensure!(from >= 0.0, "'from {from}': time must be >= 0");
                }
                if t.peek() == Some(&&"repeat") {
                    t.next();
                    repeat = Some(parse_repeat(take(&mut t, "repeat", "a count")?)?);
                }
                Schedule::Every { interval, from, repeat }
            }
            other => anyhow::bail!(
                "unknown schedule keyword '{other}': expected 'at <time> [repeat <n>]' \
                 or 'every <interval> [from <time>] [repeat <n>]'"
            ),
        };
        if let Some(trailing) = t.next() {
            anyhow::bail!("unexpected trailing token '{trailing}' in schedule '{input}'");
        }
        Ok(sched)
    }

    /// Virtual time of the `k`-th submission (0-based); `None` once the
    /// schedule is exhausted.
    pub fn occurrence(&self, k: u64) -> Option<SimTime> {
        match *self {
            Schedule::At { at, repeat } => (k < repeat).then_some(at),
            Schedule::Every { interval, from, repeat } => {
                if repeat.is_some_and(|r| k >= r) {
                    None
                } else {
                    Some(from + k as f64 * interval)
                }
            }
        }
    }

    /// Total submission count, `None` when unbounded.
    pub fn occurrences(&self) -> Option<u64> {
        match *self {
            Schedule::At { repeat, .. } => Some(repeat),
            Schedule::Every { repeat, .. } => repeat,
        }
    }
}

impl fmt::Display for Schedule {
    /// Canonical form: durations in raw seconds (`{}` formatting of f64
    /// is shortest-round-trip, so `parse(to_string())` is bit-exact),
    /// defaults omitted.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Schedule::At { at, repeat } => {
                write!(f, "at {at}s")?;
                if repeat != 1 {
                    write!(f, " repeat {repeat}")?;
                }
                Ok(())
            }
            Schedule::Every { interval, from, repeat } => {
                write!(f, "every {interval}s")?;
                if from.to_bits() != interval.to_bits() {
                    write!(f, " from {from}s")?;
                }
                if let Some(r) = repeat {
                    write!(f, " repeat {r}")?;
                }
                Ok(())
            }
        }
    }
}

fn take<'a>(
    t: &mut impl Iterator<Item = &'a str>,
    after: &str,
    what: &str,
) -> anyhow::Result<&'a str> {
    t.next()
        .ok_or_else(|| anyhow::anyhow!("'{after}' needs {what} after it, e.g. '{after} 5m'"))
}

/// Parse `<number>[s|m|h]` into seconds. The unit is the *trailing*
/// alphabetic run so scientific notation (`1e999`) stays part of the
/// number and gets the finiteness check, not a unit error.
fn parse_duration(tok: &str) -> anyhow::Result<f64> {
    let split = tok
        .char_indices()
        .rev()
        .take_while(|(_, c)| c.is_alphabetic())
        .last()
        .map(|(i, _)| i)
        .unwrap_or(tok.len());
    let (num, unit) = tok.split_at(split);
    anyhow::ensure!(
        !num.is_empty(),
        "bad duration '{tok}': expected a number like 90, 5m, 1.5h"
    );
    let scale = match unit {
        "" | "s" => 1.0,
        "m" => 60.0,
        "h" => 3600.0,
        other => anyhow::bail!(
            "unknown duration unit '{other}' in '{tok}': use s (seconds), m (minutes) or h (hours)"
        ),
    };
    let value: f64 = num
        .parse()
        .map_err(|_| anyhow::anyhow!("bad duration '{tok}': expected a number like 90, 5m, 1.5h"))?;
    let seconds = value * scale;
    anyhow::ensure!(
        seconds.is_finite(),
        "duration '{tok}' is not finite — pick a representable time"
    );
    Ok(seconds)
}

fn parse_repeat(tok: &str) -> anyhow::Result<u64> {
    let n: u64 = tok
        .parse()
        .map_err(|_| anyhow::anyhow!("bad repeat count '{tok}': expected a positive integer"))?;
    anyhow::ensure!(n >= 1, "repeat count must be >= 1 (got {n}); drop the source instead");
    Ok(n)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::Rng;

    #[test]
    fn parses_the_doc_examples() {
        assert_eq!(Schedule::parse("at 60").unwrap(), Schedule::At { at: 60.0, repeat: 1 });
        assert_eq!(
            Schedule::parse("at 60 repeat 10").unwrap(),
            Schedule::At { at: 60.0, repeat: 10 }
        );
        assert_eq!(
            Schedule::parse("every 5m").unwrap(),
            Schedule::Every { interval: 300.0, from: 300.0, repeat: None }
        );
        assert_eq!(
            Schedule::parse("every 30s from 2m repeat 5").unwrap(),
            Schedule::Every { interval: 30.0, from: 120.0, repeat: Some(5) }
        );
        assert_eq!(Schedule::parse("at 1.5h").unwrap(), Schedule::At { at: 5400.0, repeat: 1 });
    }

    #[test]
    fn occurrences_enumerate_the_schedule() {
        let s = Schedule::parse("at 60 repeat 3").unwrap();
        assert_eq!(s.occurrence(0), Some(60.0));
        assert_eq!(s.occurrence(2), Some(60.0));
        assert_eq!(s.occurrence(3), None);
        assert_eq!(s.occurrences(), Some(3));

        let e = Schedule::parse("every 30s from 2m repeat 5").unwrap();
        assert_eq!(e.occurrence(0), Some(120.0));
        assert_eq!(e.occurrence(4), Some(240.0));
        assert_eq!(e.occurrence(5), None);

        let unbounded = Schedule::parse("every 5m").unwrap();
        assert_eq!(unbounded.occurrence(0), Some(300.0));
        assert_eq!(unbounded.occurrence(1000), Some(300.0 * 1001.0));
        assert_eq!(unbounded.occurrences(), None);
    }

    #[test]
    fn rejects_malformed_inputs_with_actionable_errors() {
        // (input, substring the error must contain)
        let cases = [
            ("", "empty schedule"),
            ("whenever", "unknown schedule keyword"),
            ("at", "'at' needs a time"),
            ("every", "'every' needs an interval"),
            ("every 0s", "must be > 0"),
            ("every -5m", "must be > 0"),
            ("at -1", "must be >= 0"),
            ("every 5m from -1s", "must be >= 0"),
            ("every 5q", "unknown duration unit 'q'"),
            ("every 5min", "unknown duration unit 'min'"),
            ("at 1e999", "not finite"),
            ("at abc", "bad duration"),
            ("at 60 repeat", "'repeat' needs a count"),
            ("at 60 repeat 0", "repeat count must be >= 1"),
            ("at 60 repeat 2.5", "bad repeat count"),
            ("at 60 repeat -3", "bad repeat count"),
            ("at 60 bogus", "unexpected trailing token 'bogus'"),
            ("every 5m from 1m from 2m", "unexpected trailing token"),
        ];
        for (input, want) in cases {
            let err = Schedule::parse(input).expect_err(input).to_string();
            assert!(err.contains(want), "'{input}': error '{err}' should mention '{want}'");
        }
    }

    /// Schedule equality where times compare by f64 bit pattern — the
    /// round-trip property below is *bit*-exactness, not approximate.
    fn bits_eq(a: &Schedule, b: &Schedule) -> bool {
        match (a, b) {
            (Schedule::At { at: a1, repeat: r1 }, Schedule::At { at: a2, repeat: r2 }) => {
                a1.to_bits() == a2.to_bits() && r1 == r2
            }
            (
                Schedule::Every { interval: i1, from: f1, repeat: r1 },
                Schedule::Every { interval: i2, from: f2, repeat: r2 },
            ) => i1.to_bits() == i2.to_bits() && f1.to_bits() == f2.to_bits() && r1 == r2,
            _ => false,
        }
    }

    #[test]
    fn parse_print_parse_round_trip_property() {
        // Deterministic property sweep: random schedules (messy floats
        // included) must survive print → parse bit-exactly.
        let mut rng = Rng::new(0xDA3_1107);
        for case in 0..500u32 {
            let sched = match rng.below(4) {
                0 => Schedule::At {
                    at: rng.uniform(0.0, 1e6),
                    repeat: 1 + rng.below(1000),
                },
                1 => Schedule::Every {
                    interval: rng.uniform(1e-3, 1e5),
                    from: rng.uniform(0.0, 1e6),
                    repeat: None,
                },
                2 => {
                    let interval = rng.uniform(1e-3, 1e5);
                    Schedule::Every { interval, from: interval, repeat: Some(1 + rng.below(50)) }
                }
                _ => Schedule::Every {
                    interval: rng.uniform(1e-3, 1e5),
                    from: rng.uniform(0.0, 1e6),
                    repeat: Some(1 + rng.below(50)),
                },
            };
            let printed = sched.to_string();
            let reparsed = Schedule::parse(&printed)
                .unwrap_or_else(|e| panic!("case {case}: '{printed}' failed to re-parse: {e}"));
            assert!(
                bits_eq(&sched, &reparsed),
                "case {case}: {sched:?} -> '{printed}' -> {reparsed:?}"
            );
        }
    }

    #[test]
    fn canonical_print_examples() {
        assert_eq!(Schedule::parse("at 60 repeat 10").unwrap().to_string(), "at 60s repeat 10");
        assert_eq!(Schedule::parse("every 5m").unwrap().to_string(), "every 300s");
        assert_eq!(
            Schedule::parse("every 30s from 2m repeat 5").unwrap().to_string(),
            "every 30s from 120s repeat 5"
        );
        // `from` equal to the interval is the default — omitted.
        assert_eq!(Schedule::parse("every 2m from 120s").unwrap().to_string(), "every 120s");
    }
}
