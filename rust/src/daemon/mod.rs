//! Daemon mode — the engine as a long-lived serving subsystem.
//!
//! `serve` runs one [`Engine`] in *serving* form (empty injection plan,
//! live ingest via [`Engine::submit_at`]) while listening on a Unix or
//! TCP socket for line-delimited JSON commands ([`protocol`]): submit
//! workflows, register recurring [`schedule`]-DSL sources, inspect
//! status, hot-swap the policy or forecaster through the registries,
//! drain, shut down.
//!
//! Virtual time advances in one of two ways:
//!
//! * **free-running** (default): pending events drain as fast as the
//!   host allows, in bounded slices so the protocol stays responsive;
//! * **paced** (`pace = k`): virtual time tracks wall-clock time scaled
//!   by `k` — `pace = 60` plays one virtual minute per real second.
//!
//! With `hold = true` the engine stays un-started while submissions
//! queue up; `drain` then starts it and runs to completion. Because
//! held submissions enter the event queue exactly like batch plan
//! bursts, a held replay of a batch workload reproduces the batch
//! `RunSummary` bit-exactly (the determinism bridge — see
//! `rust/tests/daemon.rs`).
//!
//! Threading: the caller's thread owns the engine and is the only one
//! that touches it. A listener thread accepts connections; one thread
//! per connection reads lines and forwards `(line, reply_channel)`
//! pairs over an mpsc channel to the engine loop, which interleaves
//! command handling with simulation slices.

pub mod client;
pub mod protocol;
pub mod schedule;

use std::fs;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::config::{DaemonConfig, ExperimentConfig, ForecasterSpec, PolicySpec};
use crate::engine::{Engine, RunOutcome};
use crate::util::json::Json;
use crate::workflow::{WorkflowSpec, WorkflowType};
use protocol::{err_line, ok_line, Request};
use schedule::Schedule;

/// A parsed listen address.
#[derive(Debug, Clone, PartialEq)]
pub enum Listen {
    /// `unix:<path>` — a filesystem socket (tests, CI, local clients).
    Unix(String),
    /// `tcp:<host>:<port>`.
    Tcp(String),
}

impl Listen {
    /// Parse `unix:<path>` or `tcp:<host>:<port>` (the same grammar
    /// [`DaemonConfig::validate`] enforces).
    pub fn parse(addr: &str) -> anyhow::Result<Listen> {
        match addr.split_once(':') {
            Some(("unix", path)) if !path.is_empty() => Ok(Listen::Unix(path.to_string())),
            Some(("tcp", hostport)) => {
                let (host, port) = hostport.rsplit_once(':').ok_or_else(|| {
                    anyhow::anyhow!("tcp listen address '{hostport}' needs host:port")
                })?;
                anyhow::ensure!(!host.is_empty(), "tcp listen address '{hostport}' has no host");
                port.parse::<u16>().map_err(|_| {
                    anyhow::anyhow!("bad tcp port '{port}' in listen address '{hostport}'")
                })?;
                Ok(Listen::Tcp(hostport.to_string()))
            }
            _ => anyhow::bail!(
                "listen address '{addr}' must be unix:<path> or tcp:<host>:<port>"
            ),
        }
    }
}

/// One message from a connection handler to the engine loop.
type CmdMsg = (String, Sender<String>);

/// Events processed per slice between protocol polls in free-running
/// mode — large enough to make progress, small enough to stay
/// responsive.
const SLICE: usize = 4096;

/// Run the daemon until a `shutdown` command. Returns the drained
/// [`RunOutcome`] when a `drain` completed before shutdown, `None` when
/// the daemon was stopped without draining.
pub fn serve(cfg: ExperimentConfig) -> anyhow::Result<Option<RunOutcome>> {
    let dcfg: DaemonConfig = cfg.daemon.clone().unwrap_or_default();
    dcfg.validate()?;
    let listen = Listen::parse(&dcfg.listen)?;

    let mut engine = Engine::serving(cfg)?;
    let mut sources = Vec::new();
    for src in &dcfg.sources {
        register_source(&mut engine, &src.schedule, src.workflow, src.count, &mut sources)?;
    }
    if !dcfg.hold {
        engine.start();
    }

    let (cmd_tx, cmd_rx) = mpsc::channel::<CmdMsg>();
    let stop = Arc::new(AtomicBool::new(false));
    let listener = spawn_listener(listen, cmd_tx, Arc::clone(&stop))?;

    let mut daemon = Daemon {
        engine: Some(engine),
        outcome: None,
        summary: None,
        sources,
        pace: dcfg.pace,
        holding: dcfg.hold,
        draining: false,
        stop_requested: false,
        clock: if dcfg.hold { None } else { Some(Instant::now()) },
    };
    daemon.run(&cmd_rx);

    stop.store(true, Ordering::SeqCst);
    let _ = listener.join();
    Ok(daemon.outcome)
}

/// A live submission source compiled from the schedule DSL. Only
/// unbounded (`every` without `repeat`) schedules live here — bounded
/// ones are fully materialized at registration.
struct Source {
    schedule: Schedule,
    template: WorkflowSpec,
    count: usize,
    /// Next occurrence index to schedule.
    next_k: u64,
    /// Virtual time of the most recently scheduled occurrence; keeping
    /// exactly one future occurrence pending means the event queue
    /// never runs dry while a source is active.
    last_at: f64,
}

/// Register a schedule source: bounded schedules become their full list
/// of submissions immediately (so held replays see every occurrence);
/// unbounded ones get a cursor that [`Daemon::feed_sources`] advances.
fn register_source(
    engine: &mut Engine,
    schedule: &str,
    workflow: WorkflowType,
    count: usize,
    sources: &mut Vec<Source>,
) -> anyhow::Result<Option<u64>> {
    let sched = Schedule::parse(schedule)?;
    let template = engine.workflow_template(workflow)?;
    match sched.occurrences() {
        Some(n) => {
            for k in 0..n {
                let at = sched.occurrence(k).expect("k < occurrence count");
                engine.submit_at(at, template.clone(), count)?;
            }
            Ok(Some(n))
        }
        None => {
            sources.push(Source {
                schedule: sched,
                template,
                count,
                next_k: 0,
                last_at: f64::NEG_INFINITY,
            });
            Ok(None)
        }
    }
}

/// The engine loop's state machine: Holding → Running → Draining →
/// Completed, advanced between protocol commands.
struct Daemon {
    /// Consumed by `finalize` (RunOutcome construction takes the engine).
    engine: Option<Engine>,
    outcome: Option<RunOutcome>,
    /// Cached summary document served by `status` after completion.
    summary: Option<Json>,
    sources: Vec<Source>,
    pace: Option<f64>,
    holding: bool,
    draining: bool,
    stop_requested: bool,
    /// Wall-clock origin for paced mode; set when the engine starts.
    clock: Option<Instant>,
}

impl Daemon {
    fn run(&mut self, cmd_rx: &Receiver<CmdMsg>) {
        loop {
            // Serve every queued command first: the protocol stays
            // responsive no matter how busy the sim is.
            while let Ok(msg) = cmd_rx.try_recv() {
                self.dispatch(msg);
            }
            if self.stop_requested {
                break;
            }
            if self.can_advance() {
                self.advance();
            } else {
                // Idle (holding, done, or queue empty): block for the
                // next command instead of spinning.
                match cmd_rx.recv_timeout(Duration::from_millis(50)) {
                    Ok(msg) => self.dispatch(msg),
                    Err(RecvTimeoutError::Timeout) => {}
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        }
    }

    fn can_advance(&self) -> bool {
        let Some(engine) = &self.engine else { return false };
        if self.holding {
            return false;
        }
        if self.draining {
            return true; // finalize even with an empty queue
        }
        if engine.event_cap_hit() {
            return false; // stuck; only drain/shutdown make progress
        }
        !engine.queue_is_empty() || !self.sources.is_empty()
    }

    /// One stride of simulation: feed schedule sources, advance virtual
    /// time (free-running slice, paced catch-up, or drain-to-empty),
    /// finalize when a drain completes.
    fn advance(&mut self) {
        if !self.draining {
            self.feed_sources();
        }
        let engine = self.engine.as_mut().expect("checked by can_advance");
        if self.draining {
            if engine.queue_is_empty() || engine.event_cap_hit() {
                self.finalize();
            } else {
                // Drains ignore pacing: in-flight work completes at
                // full speed.
                engine.run_slice(SLICE * 16);
            }
            return;
        }
        match self.pace {
            None => {
                engine.run_slice(SLICE);
            }
            Some(pace) => {
                let clock = self.clock.get_or_insert_with(Instant::now);
                let target = clock.elapsed().as_secs_f64() * pace;
                engine.run_until(target);
                // Wall clock has to catch up before more work is due.
                thread::sleep(Duration::from_millis(5));
            }
        }
    }

    /// Keep one future occurrence of every unbounded source scheduled.
    fn feed_sources(&mut self) {
        let Some(engine) = self.engine.as_mut() else { return };
        for src in &mut self.sources {
            while src.last_at <= engine.now() {
                let at = src
                    .schedule
                    .occurrence(src.next_k)
                    .expect("unbounded schedules never exhaust");
                if let Err(e) = engine.submit_at(at, src.template.clone(), src.count) {
                    crate::log_warn!("schedule source submission failed: {e:#}");
                    src.last_at = f64::INFINITY; // disable the source
                    break;
                }
                src.last_at = at;
                src.next_k += 1;
            }
        }
    }

    /// A completed drain: summarize and cache the outcome.
    fn finalize(&mut self) {
        let Some(engine) = self.engine.take() else { return };
        let outcome = engine.finish();
        self.summary = Some(summary_doc(&outcome));
        self.outcome = Some(outcome);
        self.draining = false;
    }

    fn dispatch(&mut self, (line, reply): CmdMsg) {
        let resp = match Request::parse_line(&line).and_then(|req| self.handle(req)) {
            Ok(resp) => resp,
            Err(e) => err_line(&format!("{e:#}")),
        };
        let _ = reply.send(resp);
    }

    fn state_name(&self) -> &'static str {
        if self.engine.is_none() {
            "completed"
        } else if self.holding {
            "holding"
        } else if self.draining {
            "draining"
        } else {
            "running"
        }
    }

    fn handle(&mut self, req: Request) -> anyhow::Result<String> {
        match req {
            Request::Submit { workflow, count, at } => {
                let engine = self.ingest_engine()?;
                let template = engine.workflow_template(workflow)?;
                let at = at.unwrap_or_else(|| engine.now());
                let id = engine.submit_at(at, template, count)?;
                Ok(ok_line(vec![("submission", Json::num(id as f64))]))
            }
            Request::Schedule { schedule, workflow, count } => {
                anyhow::ensure!(
                    !self.draining && self.engine.is_some(),
                    "daemon is {}; not accepting submissions",
                    self.state_name()
                );
                let canonical = Schedule::parse(&schedule)?.to_string();
                let bounded = register_source(
                    self.engine.as_mut().expect("checked above"),
                    &schedule,
                    workflow,
                    count,
                    &mut self.sources,
                )?;
                Ok(ok_line(vec![
                    ("schedule", Json::str(canonical)),
                    (
                        "submissions",
                        bounded.map(|n| Json::num(n as f64)).unwrap_or(Json::Null),
                    ),
                ]))
            }
            Request::Status => Ok(self.status_line()),
            Request::Metrics => {
                let engine = self
                    .engine
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("daemon is completed; no live engine"))?;
                Ok(ok_line(vec![("metrics", Json::str(engine.prometheus_metrics()))]))
            }
            Request::ListPolicies => {
                let names: Vec<Json> = crate::resources::registry::policy_names()
                    .into_iter()
                    .map(Json::str)
                    .collect();
                Ok(ok_line(vec![("policies", Json::Arr(names))]))
            }
            Request::ListForecasters => {
                let names: Vec<Json> = crate::forecast::registry::forecaster_names()
                    .into_iter()
                    .map(Json::str)
                    .collect();
                Ok(ok_line(vec![("forecasters", Json::Arr(names))]))
            }
            Request::SwapPolicy { policy } => {
                let spec = PolicySpec::parse(&policy)?;
                let engine = self
                    .engine
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("daemon is completed; nothing to swap"))?;
                engine.swap_policy(&spec)?;
                Ok(ok_line(vec![("policy", Json::str(engine.policy_name()))]))
            }
            Request::SwapForecaster { forecaster } => {
                let spec = match &forecaster {
                    Some(s) => Some(ForecasterSpec::parse(s)?),
                    None => None,
                };
                let engine = self
                    .engine
                    .as_mut()
                    .ok_or_else(|| anyhow::anyhow!("daemon is completed; nothing to swap"))?;
                engine.swap_forecaster(spec.as_ref())?;
                let label =
                    engine.forecaster_label().map(Json::str).unwrap_or(Json::Null);
                Ok(ok_line(vec![("forecaster", label)]))
            }
            Request::Drain => {
                if self.engine.is_none() {
                    return Ok(ok_line(vec![("state", Json::str("completed"))]));
                }
                // Ingest stops now: sources are dropped, submits refused.
                self.sources.clear();
                self.draining = true;
                if self.holding {
                    self.holding = false;
                    self.engine.as_mut().expect("checked above").start();
                    self.clock.get_or_insert_with(Instant::now);
                }
                Ok(ok_line(vec![("state", Json::str("draining"))]))
            }
            Request::Shutdown => {
                self.stop_requested = true;
                Ok(ok_line(vec![("state", Json::str("stopping"))]))
            }
        }
    }

    /// The engine, if it may still accept submissions.
    fn ingest_engine(&mut self) -> anyhow::Result<&mut Engine> {
        anyhow::ensure!(
            !self.draining && self.engine.is_some(),
            "daemon is {}; not accepting submissions",
            self.state_name()
        );
        Ok(self.engine.as_mut().expect("checked above"))
    }

    fn status_line(&self) -> String {
        let mut fields: Vec<(&str, Json)> =
            vec![("state", Json::str(self.state_name()))];
        match &self.engine {
            Some(engine) => {
                let (injected, completed) = engine.progress();
                fields.push(("now", Json::num(engine.now())));
                fields.push(("injected", Json::num(injected as f64)));
                fields.push(("completed", Json::num(completed as f64)));
                fields.push((
                    "pending_submissions",
                    Json::num(engine.pending_submissions() as f64),
                ));
                fields.push(("policy", Json::str(engine.policy_name())));
                fields.push((
                    "serve_cycles",
                    Json::num(engine.serve_cycle_count() as f64),
                ));
                fields.push((
                    "stale_snapshot_cycles",
                    Json::num(engine.stale_snapshot_cycle_count() as f64),
                ));
                fields.push((
                    "alloc_queue_depth",
                    Json::num(engine.alloc_queue_depth() as f64),
                ));
                fields.push((
                    "double_alloc_attempts",
                    Json::num(engine.double_alloc_attempt_count() as f64),
                ));
                fields.push((
                    "forecaster",
                    engine.forecaster_label().map(Json::str).unwrap_or(Json::Null),
                ));
                let subs: Vec<Json> = engine
                    .submission_statuses()
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("id", Json::num(s.id as f64)),
                            ("workflow", Json::str(s.workflow.clone())),
                            ("count", Json::num(s.count as f64)),
                            ("submitted_for", Json::num(s.submitted_for)),
                            (
                                "injected_at",
                                s.injected_at.map(Json::num).unwrap_or(Json::Null),
                            ),
                            ("completed", Json::num(s.completed as f64)),
                            (
                                "completed_at",
                                s.completed_at.map(Json::num).unwrap_or(Json::Null),
                            ),
                        ])
                    })
                    .collect();
                fields.push(("submissions", Json::Arr(subs)));
            }
            None => {
                if let Some(summary) = &self.summary {
                    fields.push(("summary", summary.clone()));
                }
            }
        }
        ok_line(fields)
    }
}

/// The machine-readable run summary served after a drain (a compact
/// subset of [`RunOutcome`], with per-submission latency).
fn summary_doc(out: &RunOutcome) -> Json {
    let subs: Vec<Json> = out
        .metrics
        .submissions
        .iter()
        .map(|s| {
            Json::obj(vec![
                ("id", Json::num(s.id as f64)),
                ("injected_at", Json::num(s.injected_at)),
                ("completed_at", Json::num(s.completed_at)),
                ("latency_s", Json::num(s.latency_s())),
                ("workflows", Json::num(s.workflows as f64)),
            ])
        })
        .collect();
    Json::obj(vec![
        ("workflows_completed", Json::num(out.summary.workflows_completed as f64)),
        ("tasks_completed", Json::num(out.summary.tasks_completed as f64)),
        ("total_duration_min", Json::num(out.summary.total_duration_min)),
        (
            "avg_workflow_duration_min",
            Json::num(out.summary.avg_workflow_duration_min),
        ),
        ("cpu_usage", Json::num(out.summary.cpu_usage)),
        ("mem_usage", Json::num(out.summary.mem_usage)),
        ("pods_created", Json::num(out.pods_created as f64)),
        ("serve_cycles", Json::num(out.serve_cycles as f64)),
        ("store_list_calls", Json::num(out.store_list_calls as f64)),
        ("tasks_unfinished", Json::num(out.tasks_unfinished as f64)),
        ("submissions", Json::Arr(subs)),
    ])
}

// ----------------------------------------------------------- transport

enum ConnStream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl ConnStream {
    fn try_clone(&self) -> std::io::Result<ConnStream> {
        match self {
            ConnStream::Unix(s) => s.try_clone().map(ConnStream::Unix),
            ConnStream::Tcp(s) => s.try_clone().map(ConnStream::Tcp),
        }
    }
}

impl Read for ConnStream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            ConnStream::Unix(s) => s.read(buf),
            ConnStream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for ConnStream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            ConnStream::Unix(s) => s.write(buf),
            ConnStream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            ConnStream::Unix(s) => s.flush(),
            ConnStream::Tcp(s) => s.flush(),
        }
    }
}

enum Acceptor {
    Unix(UnixListener, String),
    Tcp(TcpListener),
}

fn spawn_listener(
    listen: Listen,
    cmd_tx: Sender<CmdMsg>,
    stop: Arc<AtomicBool>,
) -> anyhow::Result<thread::JoinHandle<()>> {
    let acceptor = match listen {
        Listen::Unix(path) => {
            // A previous daemon's stale socket file would block the bind.
            let _ = fs::remove_file(&path);
            let l = UnixListener::bind(&path)
                .map_err(|e| anyhow::anyhow!("cannot listen on unix:{path}: {e}"))?;
            l.set_nonblocking(true)?;
            Acceptor::Unix(l, path)
        }
        Listen::Tcp(hostport) => {
            let l = TcpListener::bind(&hostport)
                .map_err(|e| anyhow::anyhow!("cannot listen on tcp:{hostport}: {e}"))?;
            l.set_nonblocking(true)?;
            Acceptor::Tcp(l)
        }
    };
    Ok(thread::spawn(move || listener_loop(acceptor, cmd_tx, stop)))
}

fn listener_loop(acceptor: Acceptor, cmd_tx: Sender<CmdMsg>, stop: Arc<AtomicBool>) {
    while !stop.load(Ordering::SeqCst) {
        let accepted = match &acceptor {
            Acceptor::Unix(l, _) => l.accept().map(|(s, _)| ConnStream::Unix(s)),
            Acceptor::Tcp(l) => l.accept().map(|(s, _)| ConnStream::Tcp(s)),
        };
        match accepted {
            Ok(stream) => {
                // Accepted sockets must block: the handler reads lines.
                let ok = match &stream {
                    ConnStream::Unix(s) => s.set_nonblocking(false).is_ok(),
                    ConnStream::Tcp(s) => s.set_nonblocking(false).is_ok(),
                };
                if ok {
                    let tx = cmd_tx.clone();
                    thread::spawn(move || conn_loop(stream, tx));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                thread::sleep(Duration::from_millis(15));
            }
            Err(_) => break,
        }
    }
    if let Acceptor::Unix(_, path) = &acceptor {
        let _ = fs::remove_file(path);
    }
}

/// One connection: read request lines, relay to the engine loop, write
/// reply lines. Exits on client disconnect or daemon stop.
fn conn_loop(stream: ConnStream, cmd_tx: Sender<CmdMsg>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let mut writer = stream;
    let reader = BufReader::new(read_half);
    let (reply_tx, reply_rx) = mpsc::channel::<String>();
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        if cmd_tx.send((line, reply_tx.clone())).is_err() {
            break; // engine loop gone: daemon is stopping
        }
        let Ok(resp) = reply_rx.recv_timeout(Duration::from_secs(60)) else { break };
        if writeln!(writer, "{resp}").is_err() {
            break;
        }
        let _ = writer.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn listen_parse_accepts_unix_and_tcp() {
        assert_eq!(
            Listen::parse("unix:/tmp/d.sock").unwrap(),
            Listen::Unix("/tmp/d.sock".into())
        );
        assert_eq!(
            Listen::parse("tcp:127.0.0.1:4100").unwrap(),
            Listen::Tcp("127.0.0.1:4100".into())
        );
    }

    #[test]
    fn listen_parse_rejects_malformed_addresses() {
        for bad in ["", "unix:", "tcp:localhost", "tcp::4100", "tcp:h:99999", "http:x"] {
            assert!(Listen::parse(bad).is_err(), "{bad}");
        }
    }
}
