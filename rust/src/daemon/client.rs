//! Blocking client for the daemon's line protocol — used by the
//! `client` subcommand, the integration tests and the CI smoke step.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::os::unix::net::UnixStream;
use std::time::{Duration, Instant};

use super::protocol::Request;
use super::Listen;
use crate::util::json::Json;
use crate::workflow::WorkflowType;

/// One protocol connection (stream + buffered reader halves).
pub struct Client {
    writer: Stream,
    reader: BufReader<Stream>,
}

enum Stream {
    Unix(UnixStream),
    Tcp(TcpStream),
}

impl std::io::Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.read(buf),
            Stream::Tcp(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Unix(s) => s.write(buf),
            Stream::Tcp(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Unix(s) => s.flush(),
            Stream::Tcp(s) => s.flush(),
        }
    }
}

impl Client {
    /// Connect to `unix:<path>` or `tcp:<host>:<port>`.
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream = match Listen::parse(addr)? {
            Listen::Unix(path) => Stream::Unix(UnixStream::connect(&path).map_err(|e| {
                anyhow::anyhow!("cannot connect to daemon at unix:{path}: {e}")
            })?),
            Listen::Tcp(hostport) => Stream::Tcp(TcpStream::connect(&hostport).map_err(|e| {
                anyhow::anyhow!("cannot connect to daemon at tcp:{hostport}: {e}")
            })?),
        };
        let reader = match &stream {
            Stream::Unix(s) => BufReader::new(Stream::Unix(s.try_clone()?)),
            Stream::Tcp(s) => BufReader::new(Stream::Tcp(s.try_clone()?)),
        };
        Ok(Client { writer: stream, reader })
    }

    /// [`Client::connect`], retrying until `timeout` — rides out the
    /// daemon's startup window (the CI smoke step's entry point).
    pub fn connect_with_retry(addr: &str, timeout: Duration) -> anyhow::Result<Client> {
        let deadline = Instant::now() + timeout;
        loop {
            match Client::connect(addr) {
                Ok(c) => return Ok(c),
                Err(e) if Instant::now() >= deadline => {
                    return Err(e.context(format!("daemon did not come up within {timeout:?}")))
                }
                Err(_) => std::thread::sleep(Duration::from_millis(25)),
            }
        }
    }

    /// Send one request line, read one response line. `Err` on
    /// transport failure *or* an `"ok": false` reply (the server's
    /// error message becomes the anyhow message).
    pub fn request(&mut self, req: &Request) -> anyhow::Result<Json> {
        let line = req.to_json().to_string_compact();
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()?;
        let mut reply = String::new();
        let n = self.reader.read_line(&mut reply)?;
        anyhow::ensure!(n > 0, "daemon closed the connection");
        let doc = Json::parse(reply.trim())
            .map_err(|e| anyhow::anyhow!("bad response json: {e} in {reply:?}"))?;
        if doc.get("ok").and_then(Json::as_bool) != Some(true) {
            let msg = doc
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("daemon replied ok=false with no error message");
            anyhow::bail!("daemon error: {msg}");
        }
        Ok(doc)
    }

    /// Submit `count` workflows at virtual time `at` (None = now);
    /// returns the submission id.
    pub fn submit(
        &mut self,
        workflow: WorkflowType,
        count: usize,
        at: Option<f64>,
    ) -> anyhow::Result<u64> {
        let doc = self.request(&Request::Submit { workflow, count, at })?;
        doc.get("submission")
            .and_then(Json::as_i64)
            .map(|id| id as u64)
            .ok_or_else(|| anyhow::anyhow!("submit reply missing 'submission' id"))
    }

    /// Register a recurring submission source from a DSL expression.
    pub fn schedule(
        &mut self,
        schedule: &str,
        workflow: WorkflowType,
        count: usize,
    ) -> anyhow::Result<Json> {
        self.request(&Request::Schedule { schedule: schedule.to_string(), workflow, count })
    }

    /// Full status document.
    pub fn status(&mut self) -> anyhow::Result<Json> {
        self.request(&Request::Status)
    }

    /// Prometheus text exposition of the live engine.
    pub fn metrics(&mut self) -> anyhow::Result<String> {
        let doc = self.request(&Request::Metrics)?;
        doc.get("metrics")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| anyhow::anyhow!("metrics reply missing 'metrics' text"))
    }

    /// Stop ingest and let in-flight work complete.
    pub fn drain(&mut self) -> anyhow::Result<Json> {
        self.request(&Request::Drain)
    }

    /// Stop the daemon.
    pub fn shutdown(&mut self) -> anyhow::Result<Json> {
        self.request(&Request::Shutdown)
    }

    /// Poll `status` until its `"state"` equals `want` (e.g.
    /// `"completed"`); returns the final status document.
    pub fn wait_for_state(&mut self, want: &str, timeout: Duration) -> anyhow::Result<Json> {
        let deadline = Instant::now() + timeout;
        loop {
            let doc = self.status()?;
            let state = doc.get("state").and_then(Json::as_str).unwrap_or("");
            if state == want {
                return Ok(doc);
            }
            anyhow::ensure!(
                Instant::now() < deadline,
                "daemon did not reach state '{want}' within {timeout:?} (last: '{state}')"
            );
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}
