//! Line-delimited JSON protocol between the daemon and its clients.
//!
//! One request per line, one response per line, both compact JSON. A
//! request is an object with a `"cmd"` key; responses always carry
//! `"ok": true|false`, with `"error"` set on failure:
//!
//! ```text
//! -> {"cmd":"submit","workflow":"montage","count":2,"at":60}
//! <- {"ok":true,"submission":0}
//! -> {"cmd":"status"}
//! <- {"ok":true,"state":"running","now":61.5,...}
//! ```
//!
//! Commands: `submit` (optionally with a `"schedule"` DSL expression
//! instead of `"at"`), `status`, `metrics` (Prometheus text exposition
//! of the live engine, as a `"metrics"` string field), `list-policies`,
//! `list-forecasters`, `swap-policy`, `swap-forecaster`, `drain`,
//! `shutdown`. Malformed lines never kill the connection — they produce
//! an `"ok": false` reply and the session continues.

use crate::util::json::Json;
use crate::workflow::WorkflowType;

/// A parsed client request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Submit `count` workflow instances at virtual time `at`
    /// (default: now).
    Submit { workflow: WorkflowType, count: usize, at: Option<f64> },
    /// Register a recurring submission source from a schedule-DSL
    /// expression (`"every 5m"`, `"at 60 repeat 10"`).
    Schedule { schedule: String, workflow: WorkflowType, count: usize },
    /// Progress report: state, virtual time, per-submission status.
    Status,
    /// Prometheus text exposition of the live engine's counters, gauges
    /// and histograms (returned as a `"metrics"` string field).
    Metrics,
    /// Registered allocation-policy names (hot-swap targets).
    ListPolicies,
    /// Registered forecaster names (hot-swap targets).
    ListForecasters,
    /// Hot-swap the allocation policy (CLI spec syntax, e.g.
    /// `"baseline"` or `"adaptive:theta_ts=0.5"`).
    SwapPolicy { policy: String },
    /// Hot-swap the forecaster; `None` disables forecasting.
    SwapForecaster { forecaster: Option<String> },
    /// Stop ingest, let in-flight work complete, then summarize.
    Drain,
    /// Stop the daemon (after replying).
    Shutdown,
}

impl Request {
    /// Parse one protocol line.
    pub fn parse_line(line: &str) -> anyhow::Result<Request> {
        let doc = Json::parse(line.trim())
            .map_err(|e| anyhow::anyhow!("bad request json: {e}"))?;
        let cmd = doc
            .get("cmd")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("request needs a string 'cmd' key"))?;
        let workflow = |doc: &Json| -> anyhow::Result<WorkflowType> {
            let name = doc
                .get("workflow")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow::anyhow!("'{cmd}' needs a string 'workflow' key"))?;
            WorkflowType::parse(name)
        };
        let count = |doc: &Json| -> anyhow::Result<usize> {
            match doc.get("count") {
                None => Ok(1),
                Some(v) => {
                    let n = v
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("'count' must be a number"))?;
                    anyhow::ensure!(
                        n.fract() == 0.0 && n >= 1.0 && n <= 1e9,
                        "'count' must be a positive integer, got {n}"
                    );
                    Ok(n as usize)
                }
            }
        };
        match cmd {
            "submit" => {
                if let Some(sched) = doc.get("schedule") {
                    let schedule = sched
                        .as_str()
                        .ok_or_else(|| anyhow::anyhow!("'schedule' must be a string"))?
                        .to_string();
                    // Reject bad DSL at the protocol edge, not mid-serve.
                    super::schedule::Schedule::parse(&schedule)?;
                    Ok(Request::Schedule { schedule, workflow: workflow(&doc)?, count: count(&doc)? })
                } else {
                    let at = match doc.get("at") {
                        None => None,
                        Some(v) => Some(
                            v.as_f64()
                                .ok_or_else(|| anyhow::anyhow!("'at' must be a number"))?,
                        ),
                    };
                    Ok(Request::Submit { workflow: workflow(&doc)?, count: count(&doc)?, at })
                }
            }
            "status" => Ok(Request::Status),
            "metrics" => Ok(Request::Metrics),
            "list-policies" => Ok(Request::ListPolicies),
            "list-forecasters" => Ok(Request::ListForecasters),
            "swap-policy" => {
                let policy = doc
                    .get("policy")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow::anyhow!("'swap-policy' needs a string 'policy' key"))?
                    .to_string();
                Ok(Request::SwapPolicy { policy })
            }
            "swap-forecaster" => {
                let forecaster = match doc.get("forecaster") {
                    None | Some(Json::Null) => None,
                    Some(v) => Some(
                        v.as_str()
                            .ok_or_else(|| {
                                anyhow::anyhow!("'forecaster' must be a string or null")
                            })?
                            .to_string(),
                    ),
                };
                Ok(Request::SwapForecaster { forecaster })
            }
            "drain" => Ok(Request::Drain),
            "shutdown" => Ok(Request::Shutdown),
            other => anyhow::bail!(
                "unknown cmd '{other}': expected submit|status|metrics|list-policies|\
                 list-forecasters|swap-policy|swap-forecaster|drain|shutdown"
            ),
        }
    }

    /// Serialize for the wire (the client's encoder).
    pub fn to_json(&self) -> Json {
        match self {
            Request::Submit { workflow, count, at } => {
                let mut fields = vec![
                    ("cmd", Json::str("submit")),
                    ("workflow", Json::str(workflow.name())),
                    ("count", Json::num(*count as f64)),
                ];
                if let Some(at) = at {
                    fields.push(("at", Json::num(*at)));
                }
                Json::obj(fields)
            }
            Request::Schedule { schedule, workflow, count } => Json::obj(vec![
                ("cmd", Json::str("submit")),
                ("schedule", Json::str(schedule.clone())),
                ("workflow", Json::str(workflow.name())),
                ("count", Json::num(*count as f64)),
            ]),
            Request::Status => Json::obj(vec![("cmd", Json::str("status"))]),
            Request::Metrics => Json::obj(vec![("cmd", Json::str("metrics"))]),
            Request::ListPolicies => Json::obj(vec![("cmd", Json::str("list-policies"))]),
            Request::ListForecasters => Json::obj(vec![("cmd", Json::str("list-forecasters"))]),
            Request::SwapPolicy { policy } => Json::obj(vec![
                ("cmd", Json::str("swap-policy")),
                ("policy", Json::str(policy.clone())),
            ]),
            Request::SwapForecaster { forecaster } => Json::obj(vec![
                ("cmd", Json::str("swap-forecaster")),
                (
                    "forecaster",
                    forecaster.as_ref().map(|f| Json::str(f.clone())).unwrap_or(Json::Null),
                ),
            ]),
            Request::Drain => Json::obj(vec![("cmd", Json::str("drain"))]),
            Request::Shutdown => Json::obj(vec![("cmd", Json::str("shutdown"))]),
        }
    }
}

/// An `{"ok":true, ...}` response line.
pub fn ok_line(mut fields: Vec<(&str, Json)>) -> String {
    fields.insert(0, ("ok", Json::Bool(true)));
    Json::obj(fields).to_string_compact()
}

/// An `{"ok":false,"error":...}` response line.
pub fn err_line(msg: &str) -> String {
    Json::obj(vec![("ok", Json::Bool(false)), ("error", Json::str(msg))]).to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_command() {
        let cases: Vec<(&str, Request)> = vec![
            (
                r#"{"cmd":"submit","workflow":"montage","count":2,"at":60}"#,
                Request::Submit { workflow: WorkflowType::Montage, count: 2, at: Some(60.0) },
            ),
            (
                r#"{"cmd":"submit","workflow":"ligo"}"#,
                Request::Submit { workflow: WorkflowType::Ligo, count: 1, at: None },
            ),
            (
                r#"{"cmd":"submit","schedule":"every 5m","workflow":"montage"}"#,
                Request::Schedule {
                    schedule: "every 5m".into(),
                    workflow: WorkflowType::Montage,
                    count: 1,
                },
            ),
            (r#"{"cmd":"status"}"#, Request::Status),
            (r#"{"cmd":"metrics"}"#, Request::Metrics),
            (r#"{"cmd":"list-policies"}"#, Request::ListPolicies),
            (r#"{"cmd":"list-forecasters"}"#, Request::ListForecasters),
            (
                r#"{"cmd":"swap-policy","policy":"baseline"}"#,
                Request::SwapPolicy { policy: "baseline".into() },
            ),
            (
                r#"{"cmd":"swap-forecaster","forecaster":"holt"}"#,
                Request::SwapForecaster { forecaster: Some("holt".into()) },
            ),
            (
                r#"{"cmd":"swap-forecaster","forecaster":null}"#,
                Request::SwapForecaster { forecaster: None },
            ),
            (r#"{"cmd":"drain"}"#, Request::Drain),
            (r#"{"cmd":"shutdown"}"#, Request::Shutdown),
        ];
        for (line, want) in cases {
            assert_eq!(Request::parse_line(line).unwrap(), want, "{line}");
        }
    }

    #[test]
    fn requests_round_trip_through_the_wire_encoding() {
        let reqs = vec![
            Request::Submit { workflow: WorkflowType::CyberShake, count: 3, at: Some(12.5) },
            Request::Submit { workflow: WorkflowType::Montage, count: 1, at: None },
            Request::Schedule {
                schedule: "at 60 repeat 2".into(),
                workflow: WorkflowType::Epigenomics,
                count: 2,
            },
            Request::Status,
            Request::Metrics,
            Request::SwapPolicy { policy: "adaptive".into() },
            Request::SwapForecaster { forecaster: None },
            Request::Drain,
            Request::Shutdown,
        ];
        for req in reqs {
            let line = req.to_json().to_string_compact();
            assert_eq!(Request::parse_line(&line).unwrap(), req, "{line}");
        }
    }

    #[test]
    fn rejects_malformed_requests() {
        let cases = [
            ("not json", "bad request json"),
            (r#"{"workflow":"montage"}"#, "'cmd'"),
            (r#"{"cmd":"frobnicate"}"#, "unknown cmd"),
            (r#"{"cmd":"submit"}"#, "'workflow'"),
            (r#"{"cmd":"submit","workflow":"nope"}"#, "unknown workflow"),
            (r#"{"cmd":"submit","workflow":"montage","count":0}"#, "positive integer"),
            (r#"{"cmd":"submit","workflow":"montage","count":1.5}"#, "positive integer"),
            (r#"{"cmd":"submit","workflow":"montage","at":"soon"}"#, "'at' must be a number"),
            (
                r#"{"cmd":"submit","schedule":"every 0m","workflow":"montage"}"#,
                "must be > 0",
            ),
            (r#"{"cmd":"swap-policy"}"#, "'policy'"),
        ];
        for (line, want) in cases {
            let err = Request::parse_line(line).expect_err(line).to_string();
            assert!(err.contains(want), "'{line}': '{err}' should mention '{want}'");
        }
    }

    #[test]
    fn response_lines_are_single_line_json() {
        let ok = ok_line(vec![("submission", Json::num(3.0))]);
        assert_eq!(ok, r#"{"ok":true,"submission":3}"#);
        let doc = Json::parse(&ok).unwrap();
        assert_eq!(doc.get("ok").and_then(Json::as_bool), Some(true));

        let err = err_line("bad thing\nhappened");
        assert!(!err.contains('\n'), "errors must stay one line: {err:?}");
        let doc = Json::parse(&err).unwrap();
        assert_eq!(doc.get("ok").and_then(Json::as_bool), Some(false));
    }
}
