//! KubeAdaptor — the workflow containerization engine (Fig. 2) driven by
//! the MAPE-K loop (Fig. 3) over the discrete-event simulator.
//!
//! Module roles map onto the paper's components:
//!
//! * **Workflow Injection Module** — [`crate::workload`] builds the
//!   injection plan; `Ev::Inject` bursts feed the Interface Unit.
//! * **Interface Unit** — workflow decomposition, state-store writes,
//!   readiness tracking ([`Engine::inject_workflow`], task state machine).
//! * **Containerized Executor** — pod creation with the Resource
//!   Manager's allocation (`Engine::apply_decision`).
//! * **Resource Manager** — [`crate::resources`] (Monitor=one
//!   `ClusterSnapshot` per queue-serve cycle, Analyse/Plan=one batched
//!   `Policy::plan` call per cycle, Execute=executor; Knowledge=state
//!   store). Policies are resolved by name through
//!   [`crate::resources::registry`].
//! * **Task Container Cleaner** — `Ev::Cleanup` deletes Succeeded /
//!   OOMKilled pods and triggers waiting requests (resource release).
//! * **State Tracker / Informer** — [`crate::cluster::Informer`] synced
//!   before every discovery pass.
//!
//! Self-healing (§6.2.2): under-provisioned pods OOM, are captured,
//! deleted, re-allocated and re-launched without operator intervention.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::chaos::ChaosKind;
use crate::cluster::{
    AutoscalerMode, ClusterEventKind, Informer, ObjectStore, Pod, PodPhase, Scheduler,
};
use crate::config::{ExperimentConfig, ForecasterSpec, PolicySpec, SnapshotMode};
use crate::forecast::{DemandForecast, DemandSample, Forecaster};
use crate::metrics::{Collector, EventKind, ForecastPoint, RunSummary, SubmissionRecord, UsageSample};
use crate::obs::{self, Phase};
use crate::resources::discovery::IncrementalDiscovery;
use crate::resources::{registry, ClusterSnapshot, Decision, Policy, TaskRequest};
use crate::simcore::{EventQueue, Rng, SimTime};
use crate::statestore::{StateStore, TaskRecord, WorkflowRecord, WorkflowStatus};
use crate::workflow::{WorkflowSpec, WorkflowType};
use crate::workload::{self, InjectionPlan};
use crate::cluster::objects::Node;

/// Per-task runtime state machine.
#[derive(Debug, Clone, PartialEq)]
enum TaskState {
    /// Waiting on `deps_left` predecessors.
    Blocked { deps_left: usize },
    /// Dependencies met; may be waiting for resources.
    Ready,
    /// Pod launched (uid).
    Launched { pod: u64 },
    Done,
}

/// One injected workflow instance.
struct WfRuntime {
    uid: u64,
    spec: WorkflowSpec,
    injected_at: SimTime,
    first_task_start: Option<SimTime>,
    states: Vec<TaskState>,
    succs: Vec<Vec<usize>>,
    /// Topological order, computed once at injection (perf: reused by
    /// every refresh_estimates call — see EXPERIMENTS.md §Perf).
    topo: Vec<usize>,
    remaining: usize,
}

/// Engine events.
#[derive(Debug)]
enum Ev {
    /// Inject burst `idx` of the plan.
    Inject { burst: usize },
    /// Enqueue (workflow index, task index) for allocation (FCFS).
    TryAlloc { wf: usize, task: usize },
    /// Serve the allocation queue head(s) after a resource release.
    ServeQueue,
    /// Pod finished its startup and begins Running.
    PodStart { pod: u64 },
    /// Pod completed successfully.
    PodFinish { pod: u64 },
    /// Under-provisioned pod hits OOM.
    PodOom { pod: u64 },
    /// Task Container Cleaner deletes a terminal pod.
    Cleanup { pod: u64 },
    /// Metrics sampling tick.
    Sample,
    /// `count` nodes of pool `pool` join the cluster (scheduled
    /// ClusterEvent, or an autoscaler scale-up once provisioned).
    NodeJoin { pool: String, count: usize, autoscaled: bool },
    /// Cordon a node, evict its pods gracefully, then remove it.
    /// `None` picks a victim deterministically.
    NodeDrain { node: Option<String> },
    /// A node vanishes immediately; its pods are killed.
    NodeCrash { node: Option<String> },
    /// Final step of a drain: the node object leaves the cluster.
    NodeRemove { node: String },
    /// Chaos scenario `idx` of the config's scenario list activates.
    ChaosStart { idx: usize },
    /// Chaos scenario `idx` deactivates (hogs release, storms clear,
    /// partitions heal).
    ChaosEnd { idx: usize },
    /// Live ingest: inject submission `sub` (a daemon `submit` command
    /// or one schedule-source occurrence).
    Submit { sub: usize },
}

/// One live submission: `count` instances of a workflow spec, requested
/// for virtual time `requested_at` through [`Engine::submit_at`].
struct Submission {
    spec: WorkflowSpec,
    count: usize,
    requested_at: SimTime,
    injected_at: Option<SimTime>,
    completed: usize,
    completed_at: Option<SimTime>,
}

/// Public view of a submission's progress (the daemon's `status` reply).
#[derive(Debug, Clone, PartialEq)]
pub struct SubmissionStatus {
    pub id: u64,
    pub workflow: String,
    pub count: usize,
    pub submitted_for: SimTime,
    pub injected_at: Option<SimTime>,
    pub completed: usize,
    pub completed_at: Option<SimTime>,
}

/// Result of a full engine run.
pub struct RunOutcome {
    pub summary: RunSummary,
    pub metrics: Collector,
    /// Scheduler/pod bookkeeping for diagnostics.
    pub pods_created: u64,
    pub store_list_calls: u64,
    /// Queue-serve cycles that took a discovery snapshot. The v2
    /// contract is one snapshot (one apiserver watch drain) per cycle:
    /// `store_list_calls == serve_cycles + 1` (the +1 is the informer's
    /// initial sync at engine construction). Chaos partitions and
    /// latency storms suppress the sync on stale cycles, so under fault
    /// injection the invariant generalizes to `store_list_calls ==
    /// serve_cycles - stale_snapshot_cycles + 1`.
    pub serve_cycles: u64,
    pub statestore_writes: u64,
    /// Namespaces left in the cluster at run end (0 when the Task
    /// Container Cleaner fully cleaned up).
    pub namespaces_remaining: usize,
    /// Pods left in the cluster at run end (0 expected).
    pub pods_remaining: usize,
    /// Pods evicted by node drains/crashes.
    pub pods_evicted: u64,
    /// Evicted pods whose task re-entered the allocation queue (the
    /// drain/crash self-healing path).
    pub evicted_rescheduled: u64,
    /// Evicted pods whose cleanup/requeue never ran by run end (only
    /// possible when the event cap aborts a run). The accounting
    /// invariant `pods_evicted == evicted_rescheduled +
    /// evicted_unresolved` holds structurally on every run — no
    /// eviction disappears silently.
    pub evicted_unresolved: usize,
    /// Tasks that never completed (0 on healthy runs; > 0 means the run
    /// hit the event cap or the cluster could no longer host them).
    pub tasks_unfinished: usize,
    /// Integral of CPU declared stolen by cpu-hog chaos scenarios
    /// (milli-core·seconds = Σ magnitude × duration over applied hogs).
    pub hog_stolen_cpu_s: f64,
    /// Integral of memory declared stolen by mem-hog chaos scenarios
    /// (Mi·seconds).
    pub hog_stolen_mem_s: f64,
    /// Serve cycles whose snapshot skipped the informer sync because a
    /// partition (or an unelapsed latency-storm delay) was active.
    pub stale_snapshot_cycles: usize,
    /// Allocations planned on a stale snapshot that the real store then
    /// refused to bind (rolled back) — detected double-allocation
    /// attempts.
    pub double_alloc_attempts: usize,
    /// Retained span records (empty unless [`Engine::enable_span_trace`]
    /// was called before the run).
    pub spans: Vec<obs::SpanRecord>,
}

/// Hard cap on processed events per run (see [`Engine::step`]).
const MAX_EVENTS: u64 = 10_000_000;

/// The KubeAdaptor engine.
pub struct Engine {
    cfg: ExperimentConfig,
    queue: EventQueue<Ev>,
    store: ObjectStore,
    informer: Informer,
    scheduler: Scheduler,
    statestore: StateStore,
    policy: Box<dyn Policy>,
    plan: InjectionPlan,
    workflows: Vec<WfRuntime>,
    next_wf: usize,
    pod_seq: u64,
    /// The allocation queue, strict FCFS order. The paper's Resource
    /// Manager "responds to the workflow task's resource request
    /// iteratively": requests are served one at a time in arrival order,
    /// and an unsatisfiable head **blocks the queue** until resources are
    /// released — this head-of-line wait is exactly the baseline's
    /// "endless waiting" failure mode (§6.2.1), while ARAS's scaled
    /// allocations keep the head admissible and the queue flowing.
    alloc_queue: VecDeque<(usize, usize)>,
    /// Whether a retry for a stalled head is already scheduled.
    head_retry_pending: bool,
    /// Whether the previous serve cycle ended on a blocked head — the
    /// next cycle then probes the head alone before a whole-queue plan.
    head_blocked: bool,
    /// Queue-serve cycles that captured a discovery snapshot.
    serve_cycles: u64,
    metrics: Collector,
    injected_requests: usize,
    sampling: bool,
    /// Release-triggered queue wakeups (the paper's Informer monitoring;
    /// false for the baseline, which relies on the resync timer).
    reactive: bool,
    // ---- cluster dynamics ----
    /// Pods evicted by drain/crash, awaiting cleanup + rescheduling.
    evicted: BTreeSet<u64>,
    pods_evicted: u64,
    evicted_rescheduled: u64,
    /// Next node index per pool label (node names are never reused).
    pool_seq: BTreeMap<String, usize>,
    /// Cluster-wide node ordinal (unique IPs across pools).
    node_ord: usize,
    /// Autoscaler: scale-ups in flight (provisioning).
    pending_joins: usize,
    /// Autoscaler: consecutive pressure-free ticks.
    idle_ticks: u32,
    /// Autoscaler-added nodes still in the cluster (scale-down pool,
    /// LIFO — the autoscaler never drains statically configured nodes).
    scaled_up: Vec<String>,
    // ---- demand forecasting ----
    /// The configured forecaster (None = subsystem off; strictly no
    /// behavior change on any engine path).
    forecaster: Option<Box<dyn Forecaster>>,
    /// Cumulative arrivals already handed to the forecaster.
    observed_arrivals: usize,
    /// Last tick's one-step-ahead prediction awaiting its actual:
    /// (target time, predicted cpu demand, predicted mem demand).
    pending_eval: Option<(SimTime, f64, f64)>,
    // ---- chaos (fault injection) ----
    /// Active cpu/mem hogs: scenario idx → (node, cpu delta, mem delta)
    /// actually applied, for exact restore at scenario end.
    hog_applied: BTreeMap<usize, (String, i64, i64)>,
    /// Active io hogs: scenario idx → (node, slowdown factor > 1).
    io_applied: BTreeMap<usize, (String, f64)>,
    /// Active informer↔store partitions (scenario count).
    partitions_active: usize,
    /// Active latency storms: (scenario idx, propagation delay seconds).
    storm_delays: Vec<(usize, f64)>,
    /// Virtual time of the last informer sync (latency-storm gating).
    last_sync_at: SimTime,
    /// Whether the last captured snapshot skipped the sync (stale).
    last_snapshot_stale: bool,
    hog_stolen_cpu_s: f64,
    hog_stolen_mem_s: f64,
    stale_snapshot_cycles: usize,
    double_alloc_attempts: usize,
    // ---- live ingest (daemon mode) ----
    /// Submissions accepted through [`Engine::submit_at`] (empty for
    /// batch runs).
    submissions: Vec<Submission>,
    /// Workflow index → submission index, for per-submission latency
    /// accounting on completion.
    wf_submission: BTreeMap<usize, usize>,
    /// Submissions scheduled but not yet injected — gates the sampler's
    /// all-done check so a run never winds down with ingest in flight.
    pending_submits: usize,
    /// Whether [`Engine::start`] has scheduled the plan.
    started: bool,
    /// Whether the event cap aborted processing.
    capped: bool,
    // ---- incremental snapshots ----
    /// Delta-maintained Algorithm 2 state (None in [`SnapshotMode::Full`]).
    inc: Option<IncrementalDiscovery>,
    /// Cross-check every fresh incremental snapshot against a full
    /// rebuild ([`SnapshotMode::Verify`]).
    verify_snapshots: bool,
    /// Span recorder: deterministic phase counts always; wall clocks and
    /// span retention strictly opt-in (see [`crate::obs`]).
    obs: obs::Recorder,
}

impl Engine {
    /// Build an engine with the policy the config's [`crate::config::PolicySpec`]
    /// describes, resolved through the global policy registry. Unknown
    /// policy or forecaster names, bad params, and an unavailable PJRT
    /// runtime (when `alloc.backend` asks for it) all fail here.
    pub fn new(cfg: ExperimentConfig) -> anyhow::Result<Self> {
        let policy = registry::build_policy(&cfg.alloc.policy, &cfg.alloc)?;
        Self::with_policy(cfg, policy)
    }

    /// Build with an explicit policy (PJRT backends, custom policies).
    pub fn with_policy(cfg: ExperimentConfig, policy: Box<dyn Policy>) -> anyhow::Result<Self> {
        cfg.validate()?;
        let plan = workload::plan(&cfg.workload, &cfg.task, None)?;
        Self::build(cfg, policy, plan)
    }

    /// Build with an explicit arrival trace (workload::trace replay).
    pub fn with_trace(
        cfg: ExperimentConfig,
        policy: Box<dyn Policy>,
        bursts: Vec<crate::workload::Burst>,
        custom: Option<&WorkflowSpec>,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        let plan = workload::plan_from_bursts(bursts, &cfg.workload, &cfg.task, custom)?;
        Self::build(cfg, policy, plan)
    }

    /// Build with a custom workflow spec instead of a named topology.
    pub fn with_custom_workflow(
        cfg: ExperimentConfig,
        policy: Box<dyn Policy>,
        custom: &WorkflowSpec,
    ) -> anyhow::Result<Self> {
        cfg.validate()?;
        custom.validate()?;
        let plan = workload::plan(&cfg.workload, &cfg.task, Some(custom))?;
        Self::build(cfg, policy, plan)
    }

    fn build(
        cfg: ExperimentConfig,
        policy: Box<dyn Policy>,
        plan: InjectionPlan,
    ) -> anyhow::Result<Self> {
        // Resolve the forecaster up front: unknown names and bad params
        // fail at construction with the registry roster, like policies.
        let forecaster = match &cfg.forecast.forecaster {
            Some(spec) => Some(crate::forecast::build_forecaster(spec)?),
            None => None,
        };
        let mut store = ObjectStore::new();
        let mut pool_seq: BTreeMap<String, usize> = BTreeMap::new();
        let mut node_ord = 0usize;
        for pool in cfg.cluster.effective_pools() {
            for idx in 0..pool.count {
                store.add_node(Node::labeled(
                    &pool.label,
                    idx,
                    node_ord,
                    pool.cpu_milli,
                    pool.mem_mi,
                ));
                node_ord += 1;
            }
            pool_seq.insert(pool.label.clone(), pool.count);
        }
        let mut informer = Informer::new();
        informer.sync(&store);
        // Incremental discovery state is primed from the same cache the
        // full rebuild would read, so both paths start identical.
        let inc = match cfg.snapshot_mode {
            SnapshotMode::Full => None,
            SnapshotMode::Incremental | SnapshotMode::Verify => {
                Some(IncrementalDiscovery::prime(&informer))
            }
        };
        let verify_snapshots = cfg.snapshot_mode == SnapshotMode::Verify;
        let reactive = policy.reactive_monitoring();
        Ok(Engine {
            cfg,
            queue: EventQueue::new(),
            store,
            informer,
            scheduler: Scheduler::new(),
            statestore: StateStore::new(),
            policy,
            plan,
            workflows: Vec::new(),
            next_wf: 0,
            pod_seq: 0,
            alloc_queue: VecDeque::new(),
            head_retry_pending: false,
            head_blocked: false,
            serve_cycles: 0,
            metrics: Collector::new(),
            injected_requests: 0,
            sampling: true,
            reactive,
            evicted: BTreeSet::new(),
            pods_evicted: 0,
            evicted_rescheduled: 0,
            pool_seq,
            node_ord,
            pending_joins: 0,
            idle_ticks: 0,
            scaled_up: Vec::new(),
            forecaster,
            observed_arrivals: 0,
            pending_eval: None,
            hog_applied: BTreeMap::new(),
            io_applied: BTreeMap::new(),
            partitions_active: 0,
            storm_delays: Vec::new(),
            last_sync_at: 0.0,
            last_snapshot_stale: false,
            hog_stolen_cpu_s: 0.0,
            hog_stolen_mem_s: 0.0,
            stale_snapshot_cycles: 0,
            double_alloc_attempts: 0,
            submissions: Vec::new(),
            wf_submission: BTreeMap::new(),
            pending_submits: 0,
            started: false,
            capped: false,
            inc,
            verify_snapshots,
            obs: obs::Recorder::new(),
        })
    }

    /// Wake the allocation queue after a resource release. Reactive
    /// policies get an informer-latency wakeup; the baseline waits for
    /// its periodic resync timer (scheduled when the head stalled).
    fn wake_queue(&mut self) {
        if self.reactive {
            self.head_retry_pending = false;
            self.queue
                .schedule_in(self.cfg.timing.informer_latency_s, Ev::ServeQueue);
        } else if !self.alloc_queue.is_empty() && !self.head_retry_pending {
            self.head_retry_pending = true;
            self.queue.schedule_in(self.cfg.timing.retry_interval_s, Ev::ServeQueue);
        }
    }

    /// Schedule the injection plan, cluster dynamics, chaos scenarios
    /// and the sampler. Idempotent; the first step of [`Engine::run`],
    /// called explicitly by the daemon's serve loop.
    pub fn start(&mut self) {
        if self.started {
            return;
        }
        self.started = true;
        for (i, _) in self.plan.bursts.iter().enumerate() {
            let at = self.plan.bursts[i].at;
            self.queue.schedule_at(at, Ev::Inject { burst: i });
        }
        // Declarative cluster dynamics ride the same event queue.
        for ev in self.cfg.cluster.events.clone() {
            let payload = match ev.kind {
                ClusterEventKind::Join { pool, count } => {
                    Ev::NodeJoin { pool, count, autoscaled: false }
                }
                ClusterEventKind::Drain { node } => Ev::NodeDrain { node },
                ClusterEventKind::Crash { node } => Ev::NodeCrash { node },
            };
            self.queue.schedule_at(ev.at, payload);
        }
        // Chaos scenarios ride the same queue: one start and one end
        // event per scenario. Strictly opt-in — the default (empty)
        // scenario list schedules nothing and the run is bit-identical
        // to a build without the subsystem.
        for (idx, s) in self.cfg.chaos.scenarios.clone().into_iter().enumerate() {
            self.queue.schedule_at(s.at, Ev::ChaosStart { idx });
            self.queue.schedule_at(s.at + s.duration, Ev::ChaosEnd { idx });
        }
        self.queue.schedule_at(0.0, Ev::Sample);
    }

    /// Process one event. Returns false when the queue is drained or the
    /// event cap tripped. The batch loop and the daemon's serve loop are
    /// both built from exactly this step, so they cannot diverge.
    pub fn step(&mut self) -> bool {
        if self.capped {
            return false;
        }
        let Some((now, ev)) = self.queue.pop() else { return false };
        self.handle(now, ev);
        // Hard cap guards against pathological configs (e.g. starved
        // strict-min runs that can never finish).
        if self.queue.processed() > MAX_EVENTS {
            crate::log_warn!("event cap hit; aborting run");
            self.capped = true;
            return false;
        }
        true
    }

    /// Step until the queue drains (or the cap trips).
    fn drain_events(&mut self) {
        while self.step() {}
    }

    /// Step at most `n` events; returns false when the queue drained or
    /// the cap tripped before `n` — the daemon's virtual-time slice.
    pub fn run_slice(&mut self, n: usize) -> bool {
        for _ in 0..n {
            if !self.step() {
                return false;
            }
        }
        true
    }

    /// Step while the next event is due at or before virtual time `t` —
    /// the daemon's paced (wall-clock-coupled) serve loop.
    pub fn run_until(&mut self, t: SimTime) {
        while self.queue.peek_time().is_some_and(|at| at <= t) {
            if !self.step() {
                return;
            }
        }
    }

    /// Run to completion and summarize.
    pub fn run(mut self) -> RunOutcome {
        self.start();
        self.drain_events();
        self.finish()
    }

    /// Summarize a drained run. The second half of [`Engine::run`],
    /// called explicitly by the daemon once ingest is drained.
    pub fn finish(mut self) -> RunOutcome {
        let makespan = self
            .workflows
            .iter()
            .filter_map(|w| self.statestore.get_workflow(w.uid).and_then(|r| r.completed_at))
            .fold(0.0f64, f64::max);
        self.metrics.makespan_s = makespan;
        self.metrics.sla_violations = self
            .statestore
            .workflows()
            .filter(|w| w.sla_violated(makespan))
            .count();
        self.metrics.hog_stolen_cpu_s = self.hog_stolen_cpu_s;
        self.metrics.hog_stolen_mem_s = self.hog_stolen_mem_s;
        self.metrics.stale_snapshot_cycles = self.stale_snapshot_cycles;
        self.metrics.double_alloc_attempts = self.double_alloc_attempts;
        self.metrics.phase_breakdown = self.obs.breakdown();
        let summary = self.metrics.summarize();
        let tasks_unfinished = self.workflows.iter().map(|w| w.remaining).sum();
        RunOutcome {
            summary,
            pods_created: self.pod_seq,
            store_list_calls: self.store.list_call_count(),
            serve_cycles: self.serve_cycles,
            statestore_writes: self.statestore.write_count(),
            namespaces_remaining: self.store.namespace_count(),
            pods_remaining: self.store.pod_count(),
            pods_evicted: self.pods_evicted,
            evicted_rescheduled: self.evicted_rescheduled,
            evicted_unresolved: self.evicted.len(),
            tasks_unfinished,
            hog_stolen_cpu_s: self.hog_stolen_cpu_s,
            hog_stolen_mem_s: self.hog_stolen_mem_s,
            stale_snapshot_cycles: self.stale_snapshot_cycles,
            double_alloc_attempts: self.double_alloc_attempts,
            spans: self.obs.take_spans(),
            metrics: self.metrics,
        }
    }

    // ------------------------------------------------- live ingest API

    /// Build an engine with an *empty* injection plan for daemon mode:
    /// every workflow arrives through [`Engine::submit_at`]. The
    /// workload seed still parameterizes workflow templates, so a daemon
    /// replay of a batch plan reproduces the batch run bit-exactly.
    pub fn serving(cfg: ExperimentConfig) -> anyhow::Result<Self> {
        cfg.validate()?;
        let policy = registry::build_policy(&cfg.alloc.policy, &cfg.alloc)?;
        let plan = workload::plan_from_bursts(Vec::new(), &cfg.workload, &cfg.task, None)?;
        Self::build(cfg, policy, plan)
    }

    /// The deterministic workflow template a batch run of this config
    /// would inject for `kind` — the same `instantiate` call with a
    /// fresh seed-derived RNG, so daemon submissions of the configured
    /// workflow type are spec-identical to the batch plan's instances.
    pub fn workflow_template(&self, kind: WorkflowType) -> anyhow::Result<WorkflowSpec> {
        anyhow::ensure!(
            kind != WorkflowType::Custom,
            "custom workflows cannot be submitted by name; pick a named topology"
        );
        let mut rng = Rng::new(self.cfg.workload.seed);
        Ok(workload::instantiate(kind, None, &self.cfg.task, &mut rng))
    }

    /// Accept `count` instances of `spec` for injection at virtual time
    /// `at` (clamped to now if already past). Returns the submission id.
    /// Usable before or after [`Engine::start`]; submissions queued
    /// before `start` ride the same event queue as plan bursts.
    pub fn submit_at(
        &mut self,
        at: SimTime,
        spec: WorkflowSpec,
        count: usize,
    ) -> anyhow::Result<u64> {
        anyhow::ensure!(at.is_finite() && at >= 0.0, "submission time must be finite and >= 0");
        anyhow::ensure!(count > 0, "submission count must be > 0");
        spec.validate()?;
        let at = at.max(self.queue.now());
        let sub = self.submissions.len();
        self.submissions.push(Submission {
            spec,
            count,
            requested_at: at,
            injected_at: None,
            completed: 0,
            completed_at: None,
        });
        self.pending_submits += 1;
        self.queue.schedule_at(at, Ev::Submit { sub });
        // A drained sampler stops rescheduling itself; live ingest after
        // that point must restart the cadence or usage sampling (and the
        // autoscaler riding it) would silently stop.
        if self.started && !self.sampling {
            self.sampling = true;
            self.queue.schedule_at(at, Ev::Sample);
        }
        Ok(sub as u64)
    }

    /// Mirror of [`Engine::on_inject`] for live submissions: same
    /// injection path, same arrival accounting, plus the submission
    /// bookkeeping the daemon's status/latency reporting reads.
    fn on_submit(&mut self, now: SimTime, sub: usize) {
        let count = self.submissions[sub].count;
        for _ in 0..count {
            let spec = self.submissions[sub].spec.clone();
            let wf_idx = self.workflows.len();
            self.inject_workflow(now, spec);
            self.wf_submission.insert(wf_idx, sub);
        }
        self.injected_requests += count;
        self.metrics.arrival(now, self.injected_requests);
        self.pending_submits -= 1;
        self.submissions[sub].injected_at = Some(now);
    }

    /// Per-submission completion accounting (the daemon's latency view).
    fn complete_submission(&mut self, now: SimTime, sub: usize) {
        let s = &mut self.submissions[sub];
        s.completed += 1;
        if s.completed == s.count {
            s.completed_at = Some(now);
            self.metrics.submissions.push(SubmissionRecord {
                id: sub as u64,
                submitted_for: s.requested_at,
                injected_at: s.injected_at.unwrap_or(now),
                completed_at: now,
                workflows: s.count,
            });
        }
    }

    /// Hot-swap the allocation policy through the registry. Queued
    /// requests are re-planned by the new policy on the next serve
    /// cycle — per-cycle planning means there is no warm state to
    /// migrate beyond the policy's own (fresh) instance.
    pub fn swap_policy(&mut self, spec: &PolicySpec) -> anyhow::Result<()> {
        let policy = registry::build_policy(spec, &self.cfg.alloc)?;
        self.reactive = policy.reactive_monitoring();
        self.policy = policy;
        self.cfg.alloc.policy = spec.clone();
        Ok(())
    }

    /// Hot-swap (or disable) the demand forecaster. The accuracy ledger
    /// keeps prior points; the pending one-step-ahead evaluation is
    /// dropped because it scored the *old* forecaster.
    pub fn swap_forecaster(&mut self, spec: Option<&ForecasterSpec>) -> anyhow::Result<()> {
        self.forecaster = match spec {
            Some(s) => Some(crate::forecast::build_forecaster(s)?),
            None => None,
        };
        self.cfg.forecast.forecaster = spec.cloned();
        self.pending_eval = None;
        Ok(())
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.queue.now()
    }

    /// Whether the event queue is fully drained.
    pub fn queue_is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Whether the event cap aborted processing.
    pub fn event_cap_hit(&self) -> bool {
        self.capped
    }

    /// (workflows injected, workflows completed) so far.
    pub fn progress(&self) -> (usize, usize) {
        let injected = self.workflows.len();
        let completed = self.workflows.iter().filter(|w| w.remaining == 0).count();
        (injected, completed)
    }

    /// Name of the active allocation policy.
    pub fn policy_name(&self) -> &str {
        self.policy.name()
    }

    /// Label of the active forecaster, if any.
    pub fn forecaster_label(&self) -> Option<String> {
        self.cfg.forecast.forecaster.as_ref().map(|s| s.label())
    }

    /// Submissions not yet injected.
    pub fn pending_submissions(&self) -> usize {
        self.pending_submits
    }

    /// Status of every submission, in id order.
    pub fn submission_statuses(&self) -> Vec<SubmissionStatus> {
        self.submissions
            .iter()
            .enumerate()
            .map(|(i, s)| SubmissionStatus {
                id: i as u64,
                workflow: s.spec.name.clone(),
                count: s.count,
                submitted_for: s.requested_at,
                injected_at: s.injected_at,
                completed: s.completed,
                completed_at: s.completed_at,
            })
            .collect()
    }

    // ------------------------------------------------------ observability

    /// Queue-serve cycles that captured a discovery snapshot.
    pub fn serve_cycle_count(&self) -> u64 {
        self.serve_cycles
    }

    /// Serve cycles planned against a stale snapshot (chaos partitions /
    /// latency storms).
    pub fn stale_snapshot_cycle_count(&self) -> usize {
        self.stale_snapshot_cycles
    }

    /// Detected double-allocation attempts (stale plan, store refused).
    pub fn double_alloc_attempt_count(&self) -> usize {
        self.double_alloc_attempts
    }

    /// Current allocation-queue depth (FCFS backlog).
    pub fn alloc_queue_depth(&self) -> usize {
        self.alloc_queue.len()
    }

    /// Per-phase span counts and (if enabled) wall time so far.
    pub fn obs_breakdown(&self) -> obs::PhaseBreakdown {
        self.obs.breakdown()
    }

    /// Demand forecast `horizon_s` virtual seconds ahead, from this
    /// cluster's own forecaster. `None` when forecasting is off or the
    /// forecaster hasn't warmed up — federation routers treat that as
    /// "assume current demand persists".
    pub fn current_forecast(&self, horizon_s: f64) -> Option<DemandForecast> {
        self.predict(horizon_s)
    }

    /// Total allocatable capacity over live nodes:
    /// `(cpu_milli, mem_mi)`. Shrinks and grows with churn/autoscaling.
    pub fn cluster_capacity(&self) -> (f64, f64) {
        let (mut cpu, mut mem) = (0.0, 0.0);
        for node in self.store.nodes_iter() {
            cpu += node.allocatable_cpu as f64;
            mem += node.allocatable_mem as f64;
        }
        (cpu, mem)
    }

    /// Residual capacity: allocatable minus requests held by live pods,
    /// `(cpu_milli, mem_mi)` — the headroom a federation router scores
    /// placements against.
    pub fn cluster_residual(&self) -> (f64, f64) {
        let (mut cpu, mut mem) = self.cluster_capacity();
        for pod in self.store.pods_iter() {
            if pod.phase.holds_resources() {
                cpu -= pod.request_cpu as f64;
                mem -= pod.request_mem as f64;
            }
        }
        (cpu, mem)
    }

    /// Opt into wall-clock span timing (bench only; wall durations are
    /// machine-dependent and never reach golden output).
    pub fn enable_wall_clock_obs(&mut self) {
        self.obs.enable_wall_clock();
    }

    /// Opt into retaining per-span records for `run --trace-out`.
    pub fn enable_span_trace(&mut self) {
        self.obs.enable_trace();
    }

    /// Render the engine's live state as a Prometheus text exposition:
    /// counters (cycles, placements, phase calls), gauges (virtual time,
    /// queue depths, cluster size) and the workflow-duration histogram.
    pub fn prometheus_metrics(&self) -> String {
        let mut e = obs::expo::TextExposition::new();
        e.counter(
            "ka_serve_cycles_total",
            "Queue-serve cycles that captured a discovery snapshot.",
            self.serve_cycles as f64,
        );
        e.counter(
            "ka_stale_snapshot_cycles_total",
            "Serve cycles planned against a stale snapshot.",
            self.stale_snapshot_cycles as f64,
        );
        e.counter(
            "ka_double_alloc_attempts_total",
            "Stale-snapshot allocations the store refused to bind.",
            self.double_alloc_attempts as f64,
        );
        e.counter(
            "ka_pods_created_total",
            "Pods created over the engine lifetime.",
            self.pod_seq as f64,
        );
        e.counter(
            "ka_store_list_calls_total",
            "Full object-store list scans (informer syncs).",
            self.store.list_call_count() as f64,
        );
        e.counter(
            "ka_statestore_writes_total",
            "State-store write operations.",
            self.statestore.write_count() as f64,
        );
        e.counter(
            "ka_scheduler_attempts_total",
            "Pod placement attempts.",
            self.scheduler.attempts() as f64,
        );
        e.counter(
            "ka_scheduler_failures_total",
            "Pod placement attempts that found no feasible node.",
            self.scheduler.failures() as f64,
        );
        e.counter(
            "ka_scheduler_nodes_considered_total",
            "Candidate nodes examined across all placement attempts.",
            self.scheduler.nodes_considered() as f64,
        );
        let b = self.obs.breakdown();
        e.counter_vec(
            "ka_phase_calls_total",
            "Span count per engine phase.",
            "phase",
            &[
                (Phase::ServeCycle.name(), b.serve_cycles as f64),
                (Phase::Plan.name(), b.plan_calls as f64),
                (Phase::Schedule.name(), b.schedule_calls as f64),
                (Phase::SnapshotApply.name(), b.snapshot_applies as f64),
                (Phase::ForecastObserve.name(), b.forecast_observes as f64),
                (Phase::ForecastPredict.name(), b.forecast_predicts as f64),
                (Phase::Chaos.name(), b.chaos_events as f64),
            ],
        );
        e.gauge(
            "ka_virtual_time_seconds",
            "Current virtual time of the simulation.",
            self.queue.now(),
        );
        e.gauge(
            "ka_alloc_queue_depth",
            "Task requests waiting in the FCFS allocation queue.",
            self.alloc_queue.len() as f64,
        );
        e.gauge(
            "ka_pending_submissions",
            "Accepted submissions not yet injected.",
            self.pending_submits as f64,
        );
        e.gauge("ka_nodes", "Nodes currently in the cluster.", self.store.node_count() as f64);
        e.gauge("ka_pods", "Pods currently in the cluster.", self.store.pod_count() as f64);
        e.gauge(
            "ka_incremental_tracked_pods",
            "Pods tracked by incremental discovery (0 in full mode).",
            self.inc.as_ref().map_or(0, |i| i.tracked_pods()) as f64,
        );
        e.counter(
            "ka_incremental_deltas_total",
            "Watch-event deltas applied by incremental discovery.",
            self.inc.as_ref().map_or(0, |i| i.deltas_applied()) as f64,
        );
        e.histogram(
            "ka_workflow_duration_seconds",
            "Completed workflow durations (virtual seconds).",
            &self.metrics.wf_duration_hist,
        );
        e.render()
    }

    // ------------------------------------------------------------ events

    fn handle(&mut self, now: SimTime, ev: Ev) {
        match ev {
            Ev::Inject { burst } => self.on_inject(now, burst),
            Ev::TryAlloc { wf, task } => {
                if self.workflows[wf].states[task] == TaskState::Ready
                    && !self.alloc_queue.contains(&(wf, task))
                {
                    self.alloc_queue.push_back((wf, task));
                }
                // A stalled non-reactive (baseline) head blocks until its
                // resync timer fires; new arrivals only queue behind it.
                if self.reactive || !self.head_retry_pending {
                    self.serve_queue(now);
                }
            }
            Ev::ServeQueue => self.serve_queue(now),
            Ev::PodStart { pod } => self.on_pod_start(now, pod),
            Ev::PodFinish { pod } => self.on_pod_finish(now, pod),
            Ev::PodOom { pod } => self.on_pod_oom(now, pod),
            Ev::Cleanup { pod } => self.on_cleanup(now, pod),
            Ev::Sample => self.on_sample(now),
            Ev::NodeJoin { pool, count, autoscaled } => {
                self.on_node_join(now, &pool, count, autoscaled)
            }
            Ev::NodeDrain { node } => self.on_node_drain(now, node),
            Ev::NodeCrash { node } => self.on_node_crash(now, node),
            Ev::NodeRemove { node } => self.on_node_remove(now, &node),
            Ev::ChaosStart { idx } => {
                let tok = self.obs.begin();
                self.on_chaos_start(now, idx);
                self.obs.end(Phase::Chaos, now, tok);
            }
            Ev::ChaosEnd { idx } => {
                let tok = self.obs.begin();
                self.on_chaos_end(now, idx);
                self.obs.end(Phase::Chaos, now, tok);
            }
            Ev::Submit { sub } => self.on_submit(now, sub),
        }
    }

    fn on_inject(&mut self, now: SimTime, burst: usize) {
        let count = self.plan.bursts[burst].count;
        for _ in 0..count {
            let spec = self.plan.workflows[self.next_wf].clone();
            self.next_wf += 1;
            self.inject_workflow(now, spec);
        }
        self.injected_requests += count;
        self.metrics.arrival(now, self.injected_requests);
    }

    /// Interface Unit: decompose the workflow, write estimated task
    /// records to the state store, release source tasks.
    fn inject_workflow(&mut self, now: SimTime, spec: WorkflowSpec) {
        let uid = self.workflows.len() as u64 + 1;
        let est = spec.estimate_schedule(
            now,
            self.cfg.timing.pod_startup_s,
            self.cfg.timing.pod_delete_s + self.cfg.timing.informer_latency_s,
        );
        for (j, task) in spec.tasks.iter().enumerate() {
            self.statestore.put_task(
                task_key(uid, j),
                TaskRecord {
                    workflow_uid: uid,
                    t_start: est[j].0,
                    duration: task.duration_s,
                    t_end: est[j].1,
                    cpu: task.cpu_milli as f64,
                    mem: task.mem_mi as f64,
                    flag: false,
                    estimated: true,
                },
            );
        }
        // Eq. 3/4: the workflow deadline; explicit in the spec, or
        // derived from the estimated schedule with the configured slack.
        let est_end = est.iter().map(|e| e.1).fold(now, f64::max);
        let deadline_at = spec
            .deadline_s
            .map(|d| now + d)
            .or_else(|| self.cfg.workload.deadline_slack.map(|s| now + (est_end - now) * s));
        self.statestore.put_workflow(WorkflowRecord {
            uid,
            name: format!("{}-{uid}", spec.name),
            injected_at: now,
            started_at: None,
            completed_at: None,
            status: WorkflowStatus::Running,
            total_tasks: spec.tasks.len(),
            done_tasks: 0,
            deadline_at,
        });
        self.metrics.log(now, uid, "", EventKind::WorkflowInjected);
        // One namespace per workflow instance (Containerized Executor).
        self.store.create_namespace(&format!("wf-{uid}"));

        let states: Vec<TaskState> = spec
            .tasks
            .iter()
            .map(|t| {
                if t.deps.is_empty() {
                    TaskState::Ready
                } else {
                    TaskState::Blocked { deps_left: t.deps.len() }
                }
            })
            .collect();
        let succs = spec.successors();
        let topo = spec.topo_order().expect("validated dag");
        let remaining = spec.tasks.len();
        let wf_idx = self.workflows.len();
        let sources = spec.sources();
        self.workflows.push(WfRuntime {
            uid,
            spec,
            injected_at: now,
            first_task_start: None,
            states,
            succs,
            topo,
            remaining,
        });
        for s in sources {
            self.queue.schedule_in(0.0, Ev::TryAlloc { wf: wf_idx, task: s });
        }
    }

    /// Serve the allocation queue strictly in order. One reconcile cycle:
    /// take a single [`ClusterSnapshot`] (Monitor, Algorithm 2), hand the
    /// policy **every** admissible head in one batched [`Policy::plan`]
    /// call (Analyse + Plan, Algorithms 1 & 3), then launch decisions in
    /// queue order until the first head that must wait (Execute). All
    /// requests of a cycle see the same snapshot — pods created inside
    /// the cycle are not yet visible in the cache (informer semantics),
    /// which lets Eq. (9) partition one residual across a whole wave.
    ///
    /// Decisions past the first waiting head are discarded and re-planned
    /// next cycle — a deliberate trade: whole-batch planning is what lets
    /// batched backends (PJRT lanes) amortize, at worst O(queue) policy
    /// work per cycle on the scalar path. The stalled-head probe below
    /// removes the dominant waste case (a still-blocked head).
    fn serve_queue(&mut self, now: SimTime) {
        // If the previous cycle ended on a blocked head (whether this
        // wake is the retry timer or a release event), the head is
        // probably still inadmissible — probe it alone before paying for
        // a whole-queue plan. Exact for request-scoped policies: a
        // single-request plan equals lane 0 of the batched plan (the
        // sequential-equivalence contract).
        let probe_head = self.head_retry_pending || self.head_blocked;
        self.head_retry_pending = false;
        if self.alloc_queue.is_empty() {
            return; // nothing pending — skip the discovery pass entirely
        }
        self.serve_cycles += 1;
        let cycle_tok = self.obs.begin();
        self.serve_cycle_body(now, probe_head);
        self.obs.end(Phase::ServeCycle, now, cycle_tok);
    }

    /// The instrumented body of one serve cycle (a span per phase; early
    /// returns all land back in [`Engine::serve_queue`], which closes the
    /// cycle span).
    fn serve_cycle_body(&mut self, now: SimTime, probe_head: bool) {
        let snap_tok = self.obs.begin();
        let mut snapshot = self.capture_snapshot(now);
        self.obs.end(Phase::SnapshotApply, now, snap_tok);
        // Attach the current demand forecast (None when forecasting is
        // off or unprimed) — forecast-aware policies read it, everyone
        // else ignores it.
        if self.forecaster.is_some() {
            let tok = self.obs.begin();
            snapshot.forecast = self.predict(self.cfg.forecast.horizon_s);
            self.obs.end(Phase::ForecastPredict, now, tok);
        }

        // Gather the admissible (Ready) entries in queue order. Entries
        // that went stale stay queued; they are dropped when reached,
        // exactly as one-at-a-time serving did.
        let batch: Vec<(usize, usize)> = self
            .alloc_queue
            .iter()
            .copied()
            .filter(|&(wf, task)| self.workflows[wf].states[task] == TaskState::Ready)
            .collect();

        let mut start = 0usize;
        if probe_head && batch.len() > 1 {
            // Only the head's request is materialized: while it stays
            // blocked, each retry cycle is O(1), not O(queue).
            let head_req = self.make_request(now, batch[0].0, batch[0].1);
            let plan_tok = self.obs.begin();
            let head =
                self.policy.plan(std::slice::from_ref(&head_req), &snapshot, &self.statestore);
            self.obs.end(Phase::Plan, now, plan_tok);
            if head.len() != 1 {
                self.plan_contract_violation(head.len(), 1);
                return;
            }
            if !self.serve_one(now, batch[0], &head_req, head[0]) {
                return; // still blocked — the probe saved a whole-queue plan
            }
            start = 1;
        }

        let requests: Vec<TaskRequest> = batch[start..]
            .iter()
            .map(|&(wf, task)| self.make_request(now, wf, task))
            .collect();
        let decisions: Vec<Decision> = if requests.is_empty() {
            Vec::new()
        } else {
            let plan_tok = self.obs.begin();
            let d = self.policy.plan(&requests, &snapshot, &self.statestore);
            self.obs.end(Phase::Plan, now, plan_tok);
            d
        };
        if decisions.len() != requests.len() {
            self.plan_contract_violation(decisions.len(), requests.len());
            return;
        }
        for ((&coord, req), &decision) in batch[start..].iter().zip(&requests).zip(&decisions) {
            if !self.serve_one(now, coord, req, decision) {
                return;
            }
        }
        // Every batch member launched; clear any trailing stale entries.
        while let Some(&(wf, task)) = self.alloc_queue.front() {
            if self.workflows[wf].states[task] == TaskState::Ready {
                break;
            }
            self.alloc_queue.pop_front();
        }
    }

    /// Serve one batch member: drop stale entries queued ahead of it,
    /// act on its decision, pop it on launch. On a head-of-line wait,
    /// schedules the fallback retry (in case no release event arrives)
    /// and returns false — the cycle must stop.
    fn serve_one(
        &mut self,
        now: SimTime,
        coord: (usize, usize),
        req: &TaskRequest,
        decision: Decision,
    ) -> bool {
        while self.alloc_queue.front().is_some_and(|&head| head != coord) {
            self.alloc_queue.pop_front();
        }
        let (wf, task) = coord;
        if self.apply_decision(now, wf, task, req, decision) {
            self.alloc_queue.pop_front();
            self.head_blocked = false;
            true
        } else {
            self.head_blocked = true;
            if !self.head_retry_pending {
                self.head_retry_pending = true;
                self.queue.schedule_in(self.cfg.timing.retry_interval_s, Ev::ServeQueue);
            }
            false
        }
    }

    /// A custom policy returned the wrong number of decisions: don't
    /// guess at pairings — wait for the retry timer and re-plan.
    fn plan_contract_violation(&mut self, got: usize, want: usize) {
        crate::log_warn!(
            "policy '{}' returned {got} decisions for {want} requests; retrying",
            self.policy.name(),
        );
        self.head_retry_pending = true;
        self.queue.schedule_in(self.cfg.timing.retry_interval_s, Ev::ServeQueue);
    }

    /// Build the Resource Manager request for a Ready task at `now`.
    fn make_request(&self, now: SimTime, wf: usize, task: usize) -> TaskRequest {
        let uid = self.workflows[wf].uid;
        let t = &self.workflows[wf].spec.tasks[task];
        TaskRequest {
            task_id: task_key(uid, task),
            req_cpu: t.cpu_milli as f64,
            req_mem: t.mem_mi as f64,
            min_cpu: t.min_cpu_milli as f64,
            min_mem: t.min_mem_mi as f64,
            win_start: now,
            win_end: now + t.duration_s,
        }
    }

    /// Containerized Executor: act on one planned decision. Returns true
    /// when the task pod launched; false when the request must wait for
    /// resource release.
    fn apply_decision(
        &mut self,
        now: SimTime,
        wf: usize,
        task: usize,
        req: &TaskRequest,
        decision: Decision,
    ) -> bool {
        let uid = self.workflows[wf].uid;
        let tid = &req.task_id;
        let duration = self.workflows[wf].spec.tasks[task].duration_s;
        self.metrics.log(now, uid, tid, EventKind::TaskRequested);

        // Refresh this task's window estimate in the Knowledge base so
        // subsequent cycles see it at its actual position in time (the
        // policy's batch overlay applies the same refresh virtually for
        // later members of *this* cycle).
        self.statestore.update_task(tid, |r| {
            r.t_start = now;
            r.t_end = now + duration;
        });

        // Algorithm 1 line 27: minimum-resource condition. Under
        // strict_min the request waits for resource release; otherwise we
        // launch anyway and the pod will OOM (§6.2.2 failure evaluation).
        if self.cfg.alloc.strict_min
            && !decision.meets_minimum(req.min_cpu, req.min_mem, self.cfg.alloc.beta_mi)
        {
            self.metrics.log(now, uid, tid, EventKind::AllocWait {
                reason: format!("below-min cpu={} mem={}", decision.cpu_milli, decision.mem_mi),
            });
            return false;
        }

        // Execute: create the pod and let the scheduler bind it.
        self.pod_seq += 1;
        let pod_uid = self.pod_seq;
        let pod = Pod {
            uid: pod_uid,
            name: format!("pod-{pod_uid}"),
            namespace: format!("wf-{uid}"),
            task_id: tid.clone(),
            phase: PodPhase::Pending,
            node: None,
            request_cpu: decision.cpu_milli.max(1),
            request_mem: decision.mem_mi.max(1),
            min_mem: self.workflows[wf].spec.tasks[task].min_mem_mi,
            duration,
            created_at: now,
            started_at: None,
            finished_at: None,
        };
        self.store.create_pod(pod);
        let sched_tok = self.obs.begin();
        let placement = self.scheduler.schedule(&mut self.store, pod_uid);
        self.obs.end(Phase::Schedule, now, sched_tok);
        match placement {
            Some(_node) => {
                self.metrics.log(now, uid, tid, EventKind::AllocDecided {
                    cpu_milli: decision.cpu_milli,
                    mem_mi: decision.mem_mi,
                });
                self.metrics.log(now, uid, tid, EventKind::PodCreated);
                self.workflows[wf].states[task] = TaskState::Launched { pod: pod_uid };
                self.queue
                    .schedule_in(self.cfg.timing.pod_startup_s, Ev::PodStart { pod: pod_uid });
                true
            }
            None => {
                // No node fits the allocation right now: roll back and wait
                // (the pod never held resources — it was never bound).
                // Under a stale snapshot this rollback is the detected
                // double-allocation attempt: the frozen residuals said the
                // pod would fit, the real store refused.
                if self.last_snapshot_stale {
                    self.double_alloc_attempts += 1;
                }
                self.store.delete_pod(pod_uid);
                self.metrics.log(now, uid, tid, EventKind::AllocWait {
                    reason: format!(
                        "unschedulable cpu={} mem={}",
                        decision.cpu_milli, decision.mem_mi
                    ),
                });
                false
            }
        }
    }

    fn on_pod_start(&mut self, now: SimTime, pod_uid: u64) {
        if !self.store.set_pod_phase(pod_uid, PodPhase::Running, now) {
            return;
        }
        let pod = self.store.pod(pod_uid).unwrap().clone();
        let (wf, task) = parse_task_key(&pod.task_id);
        let uid = self.workflows[wf].uid;
        if self.workflows[wf].first_task_start.is_none() {
            self.workflows[wf].first_task_start = Some(now);
            self.statestore.update_workflow(uid, |w| w.started_at = Some(now));
        }
        // Executor updates the Knowledge base with actual times.
        self.statestore.update_task(&pod.task_id, |r| {
            r.t_start = now;
            r.t_end = now + pod.duration;
            r.estimated = false;
        });
        self.metrics.log(now, uid, &pod.task_id, EventKind::PodRunning);
        let _ = task;
        // The Containerized Executor "continuously updates" the Knowledge
        // base: with this task's actual start known, re-estimate the
        // workflow's unstarted tasks so ARAS's lookahead stays accurate
        // as the real schedule drifts from the injection-time estimate.
        self.refresh_estimates(wf, now);

        // An io-hog on the pod's node stretches its wall-clock (the
        // noisy neighbor steals bandwidth the engine cannot allocate
        // around). Factor is exactly 1.0 when no hog is active, keeping
        // the arithmetic bit-identical to the chaos-free path.
        let io = self.io_factor(pod.node.as_deref());
        if pod.mem_sufficient(self.cfg.alloc.beta_mi) {
            self.queue.schedule_in(pod.duration * io, Ev::PodFinish { pod: pod_uid });
        } else {
            // §6.2.2: the Stress allocation exceeds the quota — OOM.
            let delay = (pod.duration * self.cfg.timing.oom_after_frac).max(0.1) * io;
            self.queue.schedule_in(delay, Ev::PodOom { pod: pod_uid });
        }
    }

    fn on_pod_finish(&mut self, now: SimTime, pod_uid: u64) {
        if !self.store.set_pod_phase(pod_uid, PodPhase::Succeeded, now) {
            return;
        }
        let pod = self.store.pod(pod_uid).unwrap().clone();
        let (wf, task) = parse_task_key(&pod.task_id);
        let uid = self.workflows[wf].uid;
        self.statestore.update_task(&pod.task_id, |r| {
            r.flag = true;
            r.t_end = now;
        });
        self.metrics.log(now, uid, &pod.task_id, EventKind::PodSucceeded);
        self.metrics.tasks_completed += 1;
        self.workflows[wf].states[task] = TaskState::Done;
        self.workflows[wf].remaining -= 1;
        self.statestore.update_workflow(uid, |w| w.done_tasks += 1);

        if self.workflows[wf].remaining == 0 {
            let start = self.workflows[wf].first_task_start.unwrap_or(now);
            self.metrics.workflow_completed(now - start);
            self.statestore.update_workflow(uid, |w| {
                w.status = WorkflowStatus::Completed;
                w.completed_at = Some(now);
            });
            self.metrics.log(now, uid, "", EventKind::WorkflowCompleted);
            if let Some(&sub) = self.wf_submission.get(&wf) {
                self.complete_submission(now, sub);
            }
        }

        // Task Container Cleaner path.
        self.queue.schedule_in(self.cfg.timing.pod_delete_s, Ev::Cleanup { pod: pod_uid });
        // A Succeeded pod no longer holds resources (Alg. 2 counts only
        // Pending/Running) — notify the policy and wake the queue.
        self.policy.on_release(now);
        self.wake_queue();
    }

    fn on_pod_oom(&mut self, now: SimTime, pod_uid: u64) {
        if !self.store.set_pod_phase(pod_uid, PodPhase::OomKilled, now) {
            return;
        }
        let pod = self.store.pod(pod_uid).unwrap().clone();
        let (wf, task) = parse_task_key(&pod.task_id);
        let uid = self.workflows[wf].uid;
        self.metrics.log(now, uid, &pod.task_id, EventKind::PodOomKilled);
        self.policy.on_oom(&pod.task_id, now);
        // Task goes back to Ready; reallocation happens after cleanup
        // (self-healing: capture, delete, reallocate, regenerate).
        self.workflows[wf].states[task] = TaskState::Ready;
        self.queue.schedule_in(self.cfg.timing.pod_delete_s, Ev::Cleanup { pod: pod_uid });
    }

    fn on_cleanup(&mut self, now: SimTime, pod_uid: u64) {
        let Some(pod) = self.store.pod(pod_uid) else { return };
        if !pod.phase.cleanable() {
            return;
        }
        let pod = self.store.delete_pod(pod_uid).unwrap();
        let (wf, task) = parse_task_key(&pod.task_id);
        let uid = self.workflows[wf].uid;
        self.metrics.log(now, uid, &pod.task_id, EventKind::PodDeleted);

        if pod.phase == PodPhase::OomKilled {
            // Regenerate the task pod with a fresh allocation.
            self.metrics.log(now, uid, &pod.task_id, EventKind::TaskReallocated);
            self.queue
                .schedule_in(self.cfg.timing.retry_interval_s, Ev::TryAlloc { wf, task });
        } else if self.evicted.remove(&pod_uid) {
            // Drain/crash victim: its dead pod is gone, re-enter the
            // allocation queue immediately (the node event already cost
            // the grace/notice delay; resources on surviving nodes may
            // be free right now).
            self.evicted_rescheduled += 1;
            self.metrics.log(now, uid, &pod.task_id, EventKind::TaskReallocated);
            self.queue.schedule_in(0.0, Ev::TryAlloc { wf, task });
        } else if pod.phase == PodPhase::Succeeded {
            // Paper's control flow (Fig. 2): the Task Container Cleaner's
            // successful-deletion feedback is what triggers the Interface
            // Unit to launch subsequent tasks — successors release *after
            // deletion*, not after completion.
            let succs = self.workflows[wf].succs[task].clone();
            for s in succs {
                if let TaskState::Blocked { deps_left } = &mut self.workflows[wf].states[s] {
                    *deps_left -= 1;
                    if *deps_left == 0 {
                        self.workflows[wf].states[s] = TaskState::Ready;
                        self.queue.schedule_in(0.0, Ev::TryAlloc { wf, task: s });
                    }
                }
            }
        }
        // Cleaner also deletes "workflow namespaces without uncompleted
        // task pods": once the workflow finished and its pods are gone.
        if self.workflows[wf].remaining == 0 {
            self.store.delete_namespace(&pod.namespace);
        }
        // Resources were released — notify the policy, wake the queue.
        self.policy.on_release(now);
        self.wake_queue();
    }

    /// Recompute estimated (t_start, t_end) for every not-yet-launched
    /// task of workflow `wf`, propagating actual times of launched/done
    /// tasks through the DAG.
    fn refresh_estimates(&mut self, wf: usize, now: SimTime) {
        let startup = self.cfg.timing.pod_startup_s;
        let gap = self.cfg.timing.pod_delete_s + self.cfg.timing.informer_latency_s;
        let order = std::mem::take(&mut self.workflows[wf].topo);
        let uid = self.workflows[wf].uid;
        let n = self.workflows[wf].spec.tasks.len();
        let mut ends = vec![0.0f64; n];
        for &i in &order {
            let key = task_key(uid, i);
            let launched = matches!(
                self.workflows[wf].states[i],
                TaskState::Launched { .. } | TaskState::Done
            );
            if launched {
                // Actual (or actual-start-based) times already in the store.
                if let Some(rec) = self.statestore.get_task(&key) {
                    ends[i] = rec.t_end;
                }
                continue;
            }
            let ready = self.workflows[wf].spec.tasks[i]
                .deps
                .iter()
                .map(|&d| ends[d] + gap)
                .fold(self.workflows[wf].injected_at, f64::max)
                .max(now);
            let start = ready + startup;
            let duration = self.workflows[wf].spec.tasks[i].duration_s;
            ends[i] = start + duration;
            self.statestore.update_task(&key, |r| {
                r.t_start = start;
                r.t_end = start + duration;
            });
        }
        self.workflows[wf].topo = order;
    }

    // ------------------------------------------------- cluster dynamics

    /// `count` nodes of pool `pool` join. Pool shape comes from the
    /// config's pool table (validated); names continue the pool's
    /// sequence and are never reused.
    fn on_node_join(&mut self, now: SimTime, pool: &str, count: usize, autoscaled: bool) {
        let Some(shape) = self
            .cfg
            .cluster
            .effective_pools()
            .into_iter()
            .find(|p| p.label == pool)
        else {
            crate::log_warn!("node join for unknown pool '{pool}' ignored");
            if autoscaled {
                self.pending_joins = self.pending_joins.saturating_sub(count);
            }
            return;
        };
        for _ in 0..count {
            let idx = self.pool_seq.entry(pool.to_string()).or_insert(0);
            let node = Node::labeled(pool, *idx, self.node_ord, shape.cpu_milli, shape.mem_mi);
            *idx += 1;
            self.node_ord += 1;
            let name = node.name.clone();
            self.store.add_node(node);
            if autoscaled {
                self.pending_joins = self.pending_joins.saturating_sub(1);
                self.scaled_up.push(name.clone());
            }
            self.metrics.log(now, 0, "", EventKind::NodeJoined { node: name });
        }
        // New capacity can unblock a stalled head: wake the queue.
        self.wake_queue();
    }

    /// Drain: cordon, evict pods gracefully (grace = `pod_delete_s`),
    /// remove the node once the grace period elapsed.
    fn on_node_drain(&mut self, now: SimTime, node: Option<String>) {
        let Some(name) = node.or_else(|| self.pick_victim()) else {
            crate::log_warn!("drain skipped: no eligible node");
            return;
        };
        if self.store.node(&name).is_none() {
            crate::log_warn!("drain of unknown node '{name}' ignored");
            return;
        }
        if !self.store.set_schedulable(&name, false) {
            return; // already draining
        }
        self.scaled_up.retain(|n| n != &name);
        self.metrics.log(now, 0, "", EventKind::NodeDraining { node: name.clone() });
        self.evict_node_pods(now, &name, true);
        self.queue
            .schedule_in(self.cfg.timing.pod_delete_s, Ev::NodeRemove { node: name });
    }

    /// Crash: the node vanishes now; its pods are killed and cleaned up
    /// once the control plane notices (informer latency).
    fn on_node_crash(&mut self, now: SimTime, node: Option<String>) {
        let Some(name) = node.or_else(|| self.pick_victim()) else {
            crate::log_warn!("crash skipped: no eligible node");
            return;
        };
        if self.store.remove_node(&name).is_none() {
            crate::log_warn!("crash of unknown node '{name}' ignored");
            return;
        }
        self.scaled_up.retain(|n| n != &name);
        self.metrics.log(now, 0, "", EventKind::NodeCrashed { node: name.clone() });
        self.metrics.log(now, 0, "", EventKind::NodeRemoved { node: name.clone() });
        self.evict_node_pods(now, &name, false);
    }

    fn on_node_remove(&mut self, now: SimTime, node: &str) {
        if self.store.remove_node(node).is_some() {
            self.metrics.log(now, 0, "", EventKind::NodeRemoved { node: node.to_string() });
        }
    }

    /// Kill every resource-holding pod on `node` and queue its cleanup;
    /// the cleanup path reschedules the task (the OOM-realloc route).
    /// Drains give pods the deletion grace period; crashes surface after
    /// the informer notices the node is gone.
    fn evict_node_pods(&mut self, now: SimTime, node: &str, drain: bool) {
        let victims: Vec<u64> = self
            .store
            .pods_iter()
            .filter(|p| p.phase.holds_resources() && p.node.as_deref() == Some(node))
            .map(|p| p.uid)
            .collect();
        let delay = if drain {
            self.cfg.timing.pod_delete_s
        } else {
            self.cfg.timing.informer_latency_s
        };
        for uid in victims {
            if !self.store.set_pod_phase(uid, PodPhase::Failed, now) {
                continue;
            }
            let pod = self.store.pod(uid).unwrap().clone();
            let (wf, task) = parse_task_key(&pod.task_id);
            let wf_uid = self.workflows[wf].uid;
            self.metrics.log(now, wf_uid, &pod.task_id, EventKind::PodEvicted {
                node: node.to_string(),
                drain,
            });
            self.evicted.insert(uid);
            self.pods_evicted += 1;
            // The task goes back to Ready; it re-enters the allocation
            // queue after its dead pod is cleaned up (self-healing:
            // capture, delete, reallocate, regenerate — §6.2.2's path,
            // driven by a node event instead of an OOM).
            self.workflows[wf].states[task] = TaskState::Ready;
            self.queue.schedule_in(delay, Ev::Cleanup { pod: uid });
        }
    }

    /// Deterministic victim for an unnamed drain/crash: the schedulable
    /// node hosting the most resource-holding pods (ties: highest name)
    /// — the impactful choice, so storm profiles actually displace work
    /// — but never the last schedulable node standing, so a churn
    /// scenario degrades a run without bricking it.
    fn pick_victim(&self) -> Option<String> {
        let schedulable: Vec<&Node> =
            self.store.nodes_iter().filter(|n| n.schedulable).collect();
        if schedulable.len() <= 1 {
            return None;
        }
        let load = |name: &str| {
            self.store
                .pods_iter()
                .filter(|p| p.phase.holds_resources() && p.node.as_deref() == Some(name))
                .count()
        };
        schedulable
            .into_iter()
            .map(|n| (load(&n.name), n.name.clone()))
            .max()
            .map(|(_, name)| name)
    }

    // ------------------------------------------------- chaos injection

    /// One Monitor pass, honoring active chaos faults: a partition (or a
    /// latency storm whose propagation delay has not elapsed since the
    /// last successful sync) suppresses the informer sync, yielding a
    /// *stale* snapshot — Resource Discovery over whatever the cache
    /// last saw. With no fault active this is exactly
    /// [`ClusterSnapshot::capture`].
    fn capture_snapshot(&mut self, now: SimTime) -> ClusterSnapshot {
        let storm_delay = self.storm_delays.iter().map(|&(_, d)| d).fold(0.0f64, f64::max);
        let stale = self.partitions_active > 0
            || (storm_delay > 0.0 && now - self.last_sync_at < storm_delay);
        if stale {
            self.stale_snapshot_cycles += 1;
            self.last_snapshot_stale = true;
            match &self.inc {
                // Stale + incremental: no sync, no deltas — residuals
                // from the accumulators exactly as the cache last saw
                // them, mirroring `capture_stale`'s frozen rebuild.
                Some(inc) => ClusterSnapshot {
                    residuals: inc.residuals(&self.informer),
                    taken_at: now,
                    resource_version: self.informer.synced_version(),
                    watch_events_applied: 0,
                    pods_cached: self.informer.pod_count(),
                    nodes_cached: self.informer.node_count(),
                    forecast: None,
                },
                None => ClusterSnapshot::capture_stale(&self.informer, now),
            }
        } else {
            self.last_snapshot_stale = false;
            self.last_sync_at = now;
            if self.inc.is_some() {
                // Incremental Monitor pass: one watch drain (same store
                // accounting as `capture`), deltas applied to the
                // maintained accumulators instead of a full PodList fold.
                let events = self.informer.sync_events(&self.store);
                let inc = self.inc.as_mut().expect("checked above");
                for (_, ev) in &events {
                    inc.apply(ev, &self.informer);
                }
                let residuals = inc.residuals(&self.informer);
                if self.verify_snapshots {
                    verify_residuals(&residuals, &self.informer);
                }
                ClusterSnapshot {
                    residuals,
                    taken_at: now,
                    resource_version: self.informer.synced_version(),
                    watch_events_applied: events.len(),
                    pods_cached: self.informer.pod_count(),
                    nodes_cached: self.informer.node_count(),
                    forecast: None,
                }
            } else {
                ClusterSnapshot::capture(&mut self.informer, &self.store, now)
            }
        }
    }

    /// Slowdown factor for pods bound to `node`: the strongest active
    /// io-hog on it, 1.0 otherwise.
    fn io_factor(&self, node: Option<&str>) -> f64 {
        let Some(node) = node else { return 1.0 };
        self.io_applied
            .values()
            .filter(|(n, _)| n == node)
            .map(|&(_, f)| f)
            .fold(1.0f64, f64::max)
    }

    /// Target node for a node-scoped chaos scenario: the named node if
    /// it still exists, or (unnamed) the schedulable node hosting the
    /// most resource-holding pods — the impactful choice, same tie-break
    /// as [`Self::pick_victim`] but a hog may target the last node (it
    /// degrades the node, it does not remove it).
    fn resolve_chaos_node(&self, named: &Option<String>) -> Option<String> {
        if let Some(n) = named {
            return self.store.node(n).map(|_| n.clone());
        }
        self.store
            .nodes_iter()
            .filter(|n| n.schedulable)
            .map(|n| {
                let load = self
                    .store
                    .pods_iter()
                    .filter(|p| {
                        p.phase.holds_resources() && p.node.as_deref() == Some(n.name.as_str())
                    })
                    .count();
                (load, n.name.clone())
            })
            .max()
            .map(|(_, name)| name)
    }

    /// A chaos scenario activates. Hogs shrink the target node's
    /// allocatable outside the engine's control (residuals fall with no
    /// allocation backing them); storms and partitions only flip flags
    /// that [`Self::capture_snapshot`] reads.
    fn on_chaos_start(&mut self, _now: SimTime, idx: usize) {
        let s = self.cfg.chaos.scenarios[idx].clone();
        match s.kind {
            ChaosKind::CpuHog | ChaosKind::MemHog => {
                let Some(node) = self.resolve_chaos_node(&s.node) else {
                    crate::log_warn!("chaos {}: no target node; skipped", s.kind.name());
                    return;
                };
                let (d_cpu, d_mem) = if s.kind == ChaosKind::CpuHog {
                    (s.magnitude as i64, 0)
                } else {
                    (0, s.magnitude as i64)
                };
                self.store.adjust_allocatable(&node, -d_cpu, -d_mem);
                self.hog_applied.insert(idx, (node, d_cpu, d_mem));
                self.hog_stolen_cpu_s += d_cpu as f64 * s.duration;
                self.hog_stolen_mem_s += d_mem as f64 * s.duration;
            }
            ChaosKind::IoHog => {
                let Some(node) = self.resolve_chaos_node(&s.node) else {
                    crate::log_warn!("chaos io-hog: no target node; skipped");
                    return;
                };
                self.io_applied.insert(idx, (node, s.magnitude));
            }
            ChaosKind::LatencyStorm => self.storm_delays.push((idx, s.magnitude)),
            ChaosKind::Partition => self.partitions_active += 1,
        }
    }

    /// A chaos scenario deactivates: restore exactly what its start
    /// applied. A hogged node that was drained/crashed away in the
    /// meantime is skipped (`adjust_allocatable` refuses unknown nodes).
    fn on_chaos_end(&mut self, now: SimTime, idx: usize) {
        if let Some((node, d_cpu, d_mem)) = self.hog_applied.remove(&idx) {
            self.store.adjust_allocatable(&node, d_cpu, d_mem);
            // Restored capacity can unblock a stalled head.
            self.policy.on_release(now);
            self.wake_queue();
            return;
        }
        if self.io_applied.remove(&idx).is_some() {
            return;
        }
        let before = self.storm_delays.len();
        self.storm_delays.retain(|&(i, _)| i != idx);
        if self.storm_delays.len() != before {
            return;
        }
        if self.cfg.chaos.scenarios[idx].kind == ChaosKind::Partition {
            self.partitions_active = self.partitions_active.saturating_sub(1);
            if self.partitions_active == 0 {
                // The partition healed: the next serve cycle syncs and
                // plans on fresh state — wake it so recovery is not left
                // to the retry timer.
                self.wake_queue();
            }
        }
    }

    /// Autoscaler (policy-orthogonal): evaluated on every metrics tick.
    /// Queue pressure — actual, or forecast at the provisioning horizon
    /// in predictive mode — scales up (bounded by `max_nodes`, after a
    /// provisioning delay); sustained calm drains one empty node the
    /// autoscaler itself added (bounded by `min_nodes`).
    fn autoscale(&mut self, now: SimTime) {
        let Some(asc) = self.cfg.cluster.autoscaler.clone() else { return };
        let actual = self.store.schedulable_node_count();
        // Scale-up reasons about *projected* capacity (don't over-order
        // while nodes are provisioning); scale-down about *actual*
        // capacity only — counting in-flight joins there could drain a
        // live node below `min_nodes` for the provisioning window.
        let projected = actual + self.pending_joins;
        // Predictive mode: the queue the forecaster expects one
        // provisioning delay ahead counts as pressure, so the node is
        // ready when the burst lands instead of trailing it. 0.0 (never
        // pressure) in reactive mode or while the forecaster is unprimed.
        let predicted_queue = if asc.mode == AutoscalerMode::Predictive && self.forecaster.is_some()
        {
            let tok = self.obs.begin();
            let q = self.predict(asc.provision_s).map(|f| f.queue_len).unwrap_or(0.0);
            self.obs.end(Phase::ForecastPredict, now, tok);
            q
        } else {
            0.0
        };
        let pressure = self.alloc_queue.len() >= asc.scale_up_queue
            || predicted_queue >= asc.scale_up_queue as f64;
        if pressure {
            self.idle_ticks = 0;
            if projected < asc.max_nodes {
                let pool = asc
                    .pool
                    .clone()
                    .unwrap_or_else(|| self.cfg.cluster.effective_pools()[0].label.clone());
                self.pending_joins += 1;
                self.queue.schedule_in(asc.provision_s, Ev::NodeJoin {
                    pool,
                    count: 1,
                    autoscaled: true,
                });
            }
        } else if self.alloc_queue.is_empty() && self.pending_joins == 0 && actual > asc.min_nodes
        {
            // Predictive mode also holds capacity a forecast burst is
            // about to use instead of draining into it.
            if predicted_queue >= 1.0 {
                self.idle_ticks = 0;
            } else {
                self.idle_ticks += 1;
                if self.idle_ticks >= asc.scale_down_ticks {
                    if let Some(name) = self.pick_scale_down_target() {
                        self.idle_ticks = 0;
                        self.on_node_drain(now, Some(name));
                    }
                }
            }
        } else {
            self.idle_ticks = 0;
        }
    }

    /// Current forecast `horizon_s` ahead; None when forecasting is off
    /// or the forecaster has no observations yet.
    fn predict(&self, horizon_s: f64) -> Option<DemandForecast> {
        self.forecaster.as_ref().and_then(|f| f.predict(horizon_s))
    }

    /// Feed the forecaster this tick's demand observation and score the
    /// previous tick's one-step-ahead prediction against what actually
    /// materialized (the MAPE/RMSE ledger). `held_*` are the sampled
    /// resource holdings; queued demand is added here so the forecaster
    /// sees pressure the cluster has not admitted yet.
    fn observe_demand(&mut self, now: SimTime, held_cpu: f64, held_mem: f64) {
        if self.forecaster.is_none() {
            return;
        }
        let mut queued_cpu = 0.0f64;
        let mut queued_mem = 0.0f64;
        for &(wf, task) in &self.alloc_queue {
            if self.workflows[wf].states[task] == TaskState::Ready {
                let t = &self.workflows[wf].spec.tasks[task];
                queued_cpu += t.cpu_milli as f64;
                queued_mem += t.mem_mi as f64;
            }
        }
        let cpu_demand = held_cpu + queued_cpu;
        let mem_demand = held_mem + queued_mem;
        if let Some((target, pred_cpu, pred_mem)) = self.pending_eval.take() {
            if now >= target {
                self.metrics.forecast_points.push(ForecastPoint {
                    pred_cpu,
                    actual_cpu: cpu_demand,
                    pred_mem,
                    actual_mem: mem_demand,
                });
            } else {
                // Target tick not reached yet (irregular tick spacing);
                // keep waiting.
                self.pending_eval = Some((target, pred_cpu, pred_mem));
            }
        }
        let arrivals = (self.injected_requests - self.observed_arrivals) as f64;
        self.observed_arrivals = self.injected_requests;
        let sample = DemandSample {
            t: now,
            arrivals,
            queue_len: self.alloc_queue.len() as f64,
            cpu_demand,
            mem_demand,
        };
        let forecaster = self.forecaster.as_mut().expect("checked above");
        let obs_tok = self.obs.begin();
        forecaster.observe(&sample);
        self.obs.end(Phase::ForecastObserve, now, obs_tok);
        // Predict one tick ahead for the accuracy ledger.
        let step = self.cfg.sample_interval_s.max(1.0);
        if self.pending_eval.is_none() {
            let tok = self.obs.begin();
            let fc = forecaster.predict(step);
            self.obs.end(Phase::ForecastPredict, now, tok);
            if let Some(fc) = fc {
                self.pending_eval = Some((now + step, fc.cpu_demand, fc.mem_demand));
            }
        }
    }

    /// Most recently added idle autoscaled node (LIFO), if any.
    fn pick_scale_down_target(&self) -> Option<String> {
        self.scaled_up
            .iter()
            .rev()
            .find(|name| {
                self.store.node(name).is_some_and(|n| n.schedulable)
                    && !self.store.pods_iter().any(|p| {
                        p.phase.holds_resources() && p.node.as_deref() == Some(name.as_str())
                    })
            })
            .cloned()
    }

    fn on_sample(&mut self, now: SimTime) {
        self.policy.on_tick(now);
        self.autoscale(now);
        // Denominators track the *live* node set: static runs see the
        // configured totals, churning/autoscaled runs see capacity move.
        let (mut total_cpu, mut total_mem) = (0.0f64, 0.0f64);
        for node in self.store.nodes_iter() {
            total_cpu += node.allocatable_cpu as f64;
            total_mem += node.allocatable_mem as f64;
        }
        let mut cpu_used = 0.0;
        let mut mem_used = 0.0;
        let mut running = 0usize;
        for pod in self.store.pods_iter() {
            if pod.phase.holds_resources() {
                cpu_used += pod.request_cpu as f64;
                mem_used += pod.request_mem as f64;
                if pod.phase == PodPhase::Running {
                    running += 1;
                }
            }
        }
        // Usage rate = nominal workload occupancy: each running task
        // contributes its *declared* demand (Eq. 1 cpu/mem) regardless of
        // the possibly-scaled allocation — a scaled pod performs the same
        // work. This matches the paper's observation that CPU and memory
        // usage rates coincide (requests are proportional to node
        // capacity) and that usage gains track makespan ratios.
        let nom_cpu = (running as i64 * self.cfg.task.req_cpu_milli) as f64;
        let nom_mem = (running as i64 * self.cfg.task.req_mem_mi) as f64;
        let rate = |nom: f64, total: f64| if total > 0.0 { (nom / total).min(1.0) } else { 0.0 };
        self.metrics.sample(UsageSample {
            t: now,
            cpu_used,
            mem_used,
            cpu_rate: rate(nom_cpu, total_cpu),
            mem_rate: rate(nom_mem, total_mem),
            running_pods: running,
            nodes: self.store.node_count(),
        });
        // Demand forecasting rides the sampling cadence: strictly
        // observation (no events, no store writes), so a run without a
        // forecaster is bit-identical to one that never had the hook.
        self.observe_demand(now, cpu_used, mem_used);

        let all_done = self.next_wf >= self.plan.workflows.len()
            && self.pending_submits == 0
            && self.workflows.iter().all(|w| w.remaining == 0);
        if self.sampling && !all_done {
            self.queue.schedule_in(self.cfg.sample_interval_s.max(1.0), Ev::Sample);
        } else {
            self.sampling = false;
        }
    }
}

/// [`SnapshotMode::Verify`] invariant: the incrementally maintained
/// residuals must be *bit-identical* to a full Algorithm 2 rebuild over
/// the same informer cache. Any drift is a delta-maintenance bug —
/// panic with the first diverging entry rather than serve wrong state.
fn verify_residuals(incremental: &crate::resources::ResidualMap, informer: &Informer) {
    let full = crate::resources::discover(informer);
    assert_eq!(
        incremental.entries.len(),
        full.entries.len(),
        "incremental snapshot diverged: {} entries vs {} in full rebuild",
        incremental.entries.len(),
        full.entries.len(),
    );
    for (a, b) in incremental.entries.iter().zip(&full.entries) {
        assert!(
            a.name == b.name
                && a.ip == b.ip
                && a.pool == b.pool
                && a.residual_cpu.to_bits() == b.residual_cpu.to_bits()
                && a.residual_mem.to_bits() == b.residual_mem.to_bits(),
            "incremental snapshot diverged at node {}: \
             inc=({}, {:.1}, {:.1}) full=({}, {:.1}, {:.1})",
            a.name,
            a.ip,
            a.residual_cpu,
            a.residual_mem,
            b.ip,
            b.residual_cpu,
            b.residual_mem,
        );
    }
}

fn task_key(wf_uid: u64, task_idx: usize) -> String {
    format!("wf{wf_uid}-t{task_idx}")
}

/// Inverse of [`task_key`] → (workflow index = uid-1, task index).
fn parse_task_key(key: &str) -> (usize, usize) {
    let rest = key.strip_prefix("wf").expect("task key");
    let (wf, task) = rest.split_once("-t").expect("task key");
    (wf.parse::<usize>().unwrap() - 1, task.parse().unwrap())
}

/// Run one experiment from a config — the single-run primitive beneath
/// everything: each [`crate::campaign`] worker thread executes exactly
/// this function per grid cell, so one `run_experiment` call and one
/// campaign cell are interchangeable.
pub fn run_experiment(cfg: &ExperimentConfig) -> anyhow::Result<RunOutcome> {
    let mut cfg = cfg.clone();
    if cfg.sample_interval_s <= 0.0 {
        cfg.sample_interval_s = 5.0;
    }
    Ok(Engine::new(cfg)?.run())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ArrivalPattern, PolicySpec};
    use crate::workflow::WorkflowType;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 2, bursts: 2 };
        cfg.workload.burst_interval_s = 60.0;
        cfg.sample_interval_s = 5.0;
        cfg
    }

    #[test]
    fn montage_run_completes_all_workflows() {
        let out = run_experiment(&tiny_cfg()).unwrap();
        assert_eq!(out.summary.workflows_completed, 4);
        assert_eq!(out.summary.tasks_completed, 4 * 21);
        assert!(out.summary.total_duration_min > 0.0);
        assert_eq!(out.summary.oom_events, 0);
    }

    #[test]
    fn baseline_run_completes_too() {
        let mut cfg = tiny_cfg();
        cfg.alloc.policy = PolicySpec::fcfs();
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 4);
    }

    #[test]
    fn every_registered_policy_completes_a_run() {
        // Registry round-trip: each built-in (including the two
        // registry-proving policies) drives a full engine run.
        for name in crate::resources::registry::policy_names() {
            let mut cfg = tiny_cfg();
            cfg.alloc.policy = PolicySpec::named(&name);
            let out = run_experiment(&cfg).unwrap();
            assert_eq!(out.summary.workflows_completed, 4, "policy {name}");
        }
    }

    #[test]
    fn unknown_policy_fails_at_engine_construction() {
        let mut cfg = tiny_cfg();
        cfg.alloc.policy = PolicySpec::named("not-registered");
        let err = run_experiment(&cfg).unwrap_err().to_string();
        assert!(err.contains("unknown policy"), "{err}");
    }

    #[test]
    fn task_key_roundtrip() {
        assert_eq!(parse_task_key(&task_key(3, 17)), (2, 17));
    }

    #[test]
    fn all_four_topologies_run() {
        for kind in WorkflowType::paper_set() {
            let mut cfg = tiny_cfg();
            cfg.workload.workflow = kind;
            cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 1, bursts: 1 };
            let out = run_experiment(&cfg).unwrap();
            assert_eq!(out.summary.workflows_completed, 1, "{kind:?}");
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let a = run_experiment(&tiny_cfg()).unwrap();
        let b = run_experiment(&tiny_cfg()).unwrap();
        assert_eq!(a.summary.total_duration_min, b.summary.total_duration_min);
        assert_eq!(a.summary.avg_workflow_duration_min, b.summary.avg_workflow_duration_min);
        assert_eq!(a.summary.cpu_usage, b.summary.cpu_usage);
    }

    #[test]
    fn drain_evicts_and_reschedules_everything() {
        use crate::cluster::{ClusterEvent, ClusterEventKind};
        let mut cfg = tiny_cfg();
        // Two drains while the first burst is in flight: node-0 hosts a
        // running source-task pod at t=20 (LeastAllocated spread the two
        // t=0 pods onto node-0/node-1 at t=12).
        cfg.cluster.events = vec![
            ClusterEvent {
                at: 20.0,
                kind: ClusterEventKind::Drain { node: Some("node-0".into()) },
            },
            ClusterEvent {
                at: 40.0,
                kind: ClusterEventKind::Drain { node: Some("node-2".into()) },
            },
        ];
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 4, "drain must self-heal");
        assert!(out.pods_evicted > 0, "drain at t=20 must hit running pods");
        assert_eq!(out.pods_evicted, out.evicted_rescheduled, "every eviction rescheduled");
        assert_eq!(out.tasks_unfinished, 0);
        assert_eq!(out.summary.evictions as u64, out.pods_evicted);
        assert_eq!(out.summary.nodes_removed, 2);
        assert_eq!(out.pods_remaining, 0);
        // The node-count timeseries steps down.
        let last = out.metrics.samples.last().unwrap();
        assert_eq!(last.nodes, 4);
    }

    #[test]
    fn crash_kills_pods_and_still_completes() {
        use crate::cluster::{ClusterEvent, ClusterEventKind};
        let mut cfg = tiny_cfg();
        cfg.cluster.events = vec![ClusterEvent {
            at: 25.0,
            kind: ClusterEventKind::Crash { node: Some("node-0".into()) },
        }];
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 4);
        assert_eq!(out.pods_evicted, out.evicted_rescheduled);
        assert_eq!(out.summary.nodes_removed, 1);
        assert_eq!(out.tasks_unfinished, 0);
    }

    #[test]
    fn join_event_grows_the_cluster() {
        use crate::cluster::{ClusterEvent, ClusterEventKind};
        let mut cfg = tiny_cfg();
        cfg.cluster.events = vec![ClusterEvent {
            at: 10.0,
            kind: ClusterEventKind::Join { pool: "node".into(), count: 2 },
        }];
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 4);
        assert_eq!(out.summary.nodes_joined, 2);
        assert_eq!(out.metrics.samples.last().unwrap().nodes, 8);
    }

    #[test]
    fn heterogeneous_pools_complete_a_run() {
        use crate::config::NodePool;
        let mut cfg = tiny_cfg();
        cfg.cluster.pools = vec![
            NodePool::new("big", 2, 16000, 20480),
            NodePool::new("small", 3, 4000, 5120),
        ];
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 4);
        assert_eq!(out.metrics.samples.last().unwrap().nodes, 5);
    }

    #[test]
    fn last_node_is_never_drained() {
        use crate::cluster::{ClusterEvent, ClusterEventKind};
        let mut cfg = tiny_cfg();
        cfg.cluster.nodes = 1;
        cfg.cluster.events =
            vec![ClusterEvent { at: 5.0, kind: ClusterEventKind::Drain { node: None } }];
        let out = run_experiment(&cfg).unwrap();
        // The unnamed drain finds no eligible victim and is skipped.
        assert_eq!(out.summary.nodes_removed, 0);
        assert_eq!(out.summary.workflows_completed, 4);
    }

    #[test]
    fn autoscaler_scales_up_under_pressure_and_back_down() {
        use crate::cluster::AutoscalerConfig;
        let mut cfg = tiny_cfg();
        // A small cluster + one big burst of *full-size* requests (FCFS
        // never scales them down) = guaranteed sustained queue pressure;
        // ARAS might admit the whole wave by scaling and never pressure
        // the autoscaler.
        cfg.alloc.policy = PolicySpec::fcfs();
        cfg.cluster.nodes = 2;
        cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 8, bursts: 1 };
        cfg.cluster.autoscaler = Some(AutoscalerConfig {
            min_nodes: 2,
            max_nodes: 6,
            scale_up_queue: 2,
            scale_down_ticks: 2,
            provision_s: 10.0,
            pool: None,
            mode: crate::cluster::AutoscalerMode::Reactive,
        });
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 8);
        assert!(out.summary.nodes_joined > 0, "pressure must trigger scale-ups");
        assert!(
            out.metrics.samples.iter().any(|s| s.nodes > 2),
            "node-count timeseries must show the scale-up"
        );
        // Scale-down drains only autoscaled nodes: never below the start.
        assert!(out.metrics.samples.iter().all(|s| s.nodes >= 2));
        assert_eq!(out.pods_evicted, out.evicted_rescheduled);
    }

    #[test]
    fn churn_runs_are_deterministic() {
        use crate::cluster::ChurnProfile;
        let mut cfg = tiny_cfg();
        let storm = ChurnProfile::drain_storm(20.0, 60.0, 2);
        cfg.cluster.events = storm.events;
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(a.summary.total_duration_min, b.summary.total_duration_min);
        assert_eq!(a.summary.evictions, b.summary.evictions);
        assert_eq!(a.pods_evicted, b.pods_evicted);
        assert_eq!(a.pods_created, b.pods_created);
    }

    #[test]
    fn forecasting_is_observation_only_for_non_predictive_policies() {
        // A configured forecaster only *watches* unless a consumer
        // (predictive policy / predictive autoscaler) reads it: the run
        // must be bit-identical to the forecaster-free twin, except for
        // the populated accuracy ledger.
        let plain = run_experiment(&tiny_cfg()).unwrap();
        let mut cfg = tiny_cfg();
        cfg.forecast.forecaster = Some(crate::config::ForecasterSpec::named("holt"));
        let watched = run_experiment(&cfg).unwrap();
        assert_eq!(
            plain.summary.total_duration_min.to_bits(),
            watched.summary.total_duration_min.to_bits()
        );
        assert_eq!(plain.summary.cpu_usage.to_bits(), watched.summary.cpu_usage.to_bits());
        assert_eq!(plain.pods_created, watched.pods_created);
        assert_eq!(plain.serve_cycles, watched.serve_cycles);
        assert_eq!(plain.summary.forecast_points, 0);
        assert!(watched.summary.forecast_points > 0, "accuracy ledger must fill");
        assert!(watched.summary.forecast_rmse_cpu >= 0.0);
    }

    #[test]
    fn predictive_policy_with_forecaster_completes_deterministically() {
        let mut cfg = tiny_cfg();
        cfg.alloc.policy = PolicySpec::named("predictive");
        cfg.forecast.forecaster = Some(crate::config::ForecasterSpec::named("seasonal"));
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(a.summary.workflows_completed, 4);
        assert_eq!(a.summary.total_duration_min.to_bits(), b.summary.total_duration_min.to_bits());
        assert!(a.summary.forecast_points > 0);
    }

    #[test]
    fn unknown_forecaster_fails_at_engine_construction() {
        let mut cfg = tiny_cfg();
        cfg.forecast.forecaster = Some(crate::config::ForecasterSpec::named("crystal-ball"));
        let err = run_experiment(&cfg).unwrap_err().to_string();
        assert!(err.contains("unknown forecaster"), "{err}");
    }

    #[test]
    fn predictive_autoscaler_scales_and_completes() {
        use crate::cluster::{AutoscalerConfig, AutoscalerMode};
        let mut cfg = tiny_cfg();
        cfg.alloc.policy = PolicySpec::fcfs();
        cfg.cluster.nodes = 2;
        cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 8, bursts: 1 };
        cfg.forecast.forecaster = Some(crate::config::ForecasterSpec::named("seasonal"));
        cfg.cluster.autoscaler = Some(AutoscalerConfig {
            min_nodes: 2,
            max_nodes: 6,
            scale_up_queue: 2,
            scale_down_ticks: 2,
            provision_s: 10.0,
            pool: None,
            mode: AutoscalerMode::Predictive,
        });
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 8);
        // Actual queue pressure still counts as pressure in predictive
        // mode, so the storm must trigger scale-ups here too.
        assert!(out.summary.nodes_joined > 0);
        assert!(out.metrics.samples.iter().all(|s| s.nodes >= 2));
        assert_eq!(out.pods_evicted, out.evicted_rescheduled);
    }

    #[test]
    fn predictive_autoscaler_without_forecaster_acts_reactively() {
        use crate::cluster::{AutoscalerConfig, AutoscalerMode};
        let make = |mode: AutoscalerMode| {
            let mut cfg = tiny_cfg();
            cfg.alloc.policy = PolicySpec::fcfs();
            cfg.cluster.nodes = 2;
            cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 8, bursts: 1 };
            cfg.cluster.autoscaler = Some(AutoscalerConfig {
                min_nodes: 2,
                max_nodes: 6,
                scale_up_queue: 2,
                scale_down_ticks: 2,
                provision_s: 10.0,
                pool: None,
                mode,
            });
            cfg
        };
        let reactive = run_experiment(&make(AutoscalerMode::Reactive)).unwrap();
        let predictive = run_experiment(&make(AutoscalerMode::Predictive)).unwrap();
        // No forecaster configured: the two modes are bit-identical.
        assert_eq!(
            reactive.summary.total_duration_min.to_bits(),
            predictive.summary.total_duration_min.to_bits()
        );
        assert_eq!(reactive.summary.nodes_joined, predictive.summary.nodes_joined);
    }

    #[test]
    fn oom_and_selfhealing_when_quota_below_min() {
        // Force scaling below the Stress requirement (§6.2.2 setup):
        // min_mem close to the full request + heavy concurrency.
        let mut cfg = tiny_cfg();
        cfg.alloc.strict_min = false;
        cfg.task.min_mem_mi = 3500;
        cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 10, bursts: 1 };
        let out = run_experiment(&cfg).unwrap();
        assert!(out.summary.oom_events > 0, "expected OOM events");
        // Self-healing: everything still completes.
        assert_eq!(out.summary.workflows_completed, 10);
    }

    // ------------------------------------------------------------ chaos

    #[test]
    fn chaos_is_strictly_opt_in() {
        // The default config carries an empty scenario list: nothing is
        // scheduled, every chaos counter stays zero, and the run is
        // bit-identical to one whose chaos field was never touched.
        let plain = run_experiment(&tiny_cfg()).unwrap();
        let mut cfg = tiny_cfg();
        cfg.chaos = crate::chaos::ChaosConfig::default();
        let twin = run_experiment(&cfg).unwrap();
        assert_eq!(
            plain.summary.total_duration_min.to_bits(),
            twin.summary.total_duration_min.to_bits()
        );
        assert_eq!(plain.summary.cpu_usage.to_bits(), twin.summary.cpu_usage.to_bits());
        assert_eq!(plain.pods_created, twin.pods_created);
        assert_eq!(plain.serve_cycles, twin.serve_cycles);
        assert_eq!(plain.stale_snapshot_cycles, 0);
        assert_eq!(plain.double_alloc_attempts, 0);
        assert_eq!(plain.hog_stolen_cpu_s, 0.0);
        assert_eq!(plain.summary.stale_snapshot_cycles, 0);
        // The one-sync-per-cycle invariant holds without faults.
        assert_eq!(plain.store_list_calls, plain.serve_cycles + 1);
    }

    #[test]
    fn cpu_hog_steals_capacity_and_is_restored() {
        use crate::chaos::ChaosProfile;
        let mut cfg = tiny_cfg();
        // Steal most of node-0's CPU while the first burst is in flight.
        cfg.chaos = ChaosProfile::cpu_hog(5.0, 200.0, 7000).to_config();
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 4, "hog must degrade, not brick");
        assert_eq!(out.hog_stolen_cpu_s, 7000.0 * 200.0);
        assert_eq!(out.summary.hog_stolen_cpu_s, 7000.0 * 200.0);
        assert_eq!(out.hog_stolen_mem_s, 0.0);
        assert_eq!(out.pods_remaining, 0, "restore + cleanup must leave nothing behind");
    }

    #[test]
    fn mem_hog_on_unnamed_node_targets_deterministically() {
        use crate::chaos::ChaosProfile;
        let mut cfg = tiny_cfg();
        cfg.chaos = ChaosProfile::mem_hog(10.0, 120.0, 12000).to_config();
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(a.summary.workflows_completed, 4);
        assert_eq!(a.hog_stolen_mem_s, 12000.0 * 120.0);
        assert_eq!(a.summary.total_duration_min.to_bits(), b.summary.total_duration_min.to_bits());
        assert_eq!(a.double_alloc_attempts, b.double_alloc_attempts);
    }

    #[test]
    fn io_hog_stretches_pod_wall_clock() {
        use crate::chaos::ChaosProfile;
        let plain = run_experiment(&tiny_cfg()).unwrap();
        let mut cfg = tiny_cfg();
        // Pressure node-0 for the whole run at 4x slowdown.
        cfg.chaos = {
            let mut c = ChaosProfile::io_hog(0.0, 100_000.0, 4.0).to_config();
            c.scenarios[0].node = Some("node-0".into());
            c
        };
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 4);
        assert!(
            out.summary.total_duration_min > plain.summary.total_duration_min,
            "io pressure must lengthen the run: {} vs {}",
            out.summary.total_duration_min,
            plain.summary.total_duration_min
        );
    }

    #[test]
    fn partition_freezes_snapshots_and_counts_stale_cycles() {
        use crate::chaos::ChaosProfile;
        let mut cfg = tiny_cfg();
        cfg.chaos = ChaosProfile::partition(1.0, 120.0).to_config();
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 4, "run must heal after the partition");
        assert!(out.stale_snapshot_cycles > 0, "cycles inside the window must be stale");
        assert_eq!(out.summary.stale_snapshot_cycles, out.stale_snapshot_cycles);
        assert_eq!(out.tasks_unfinished, 0);
        assert_eq!(out.pods_remaining, 0);
        // Stale cycles skip the informer sync (the generalized invariant).
        assert_eq!(
            out.store_list_calls,
            out.serve_cycles - out.stale_snapshot_cycles as u64 + 1
        );
    }

    #[test]
    fn latency_storm_delays_snapshot_propagation() {
        use crate::chaos::ChaosProfile;
        let mut cfg = tiny_cfg();
        // A delay far above the event cadence behaves like a partition
        // for the storm window: every sync inside it is suppressed.
        cfg.chaos = ChaosProfile::latency_storm(1.0, 90.0, 1e6).to_config();
        let out = run_experiment(&cfg).unwrap();
        assert_eq!(out.summary.workflows_completed, 4);
        assert!(out.stale_snapshot_cycles > 0, "storm must stale some cycles");
    }

    #[test]
    fn chaos_runs_are_deterministic() {
        use crate::chaos::ChaosProfile;
        let mut cfg = tiny_cfg();
        cfg.cluster.nodes = 2;
        cfg.alloc.policy = PolicySpec::fcfs();
        cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 8, bursts: 1 };
        cfg.chaos = ChaosProfile::partition(1.0, 300.0).to_config();
        let a = run_experiment(&cfg).unwrap();
        let b = run_experiment(&cfg).unwrap();
        assert_eq!(a.summary.total_duration_min.to_bits(), b.summary.total_duration_min.to_bits());
        assert_eq!(a.stale_snapshot_cycles, b.stale_snapshot_cycles);
        assert_eq!(a.double_alloc_attempts, b.double_alloc_attempts);
        assert!(a.double_alloc_attempts > 0, "a loaded stale window must trip the counter");
    }

    // ------------------------------------------------------ live ingest

    /// The determinism bridge: replaying a batch plan through the live
    /// ingest path (`serving` + `submit_at`) must reproduce the batch
    /// `RunSummary` bit-exactly — same specs, same times, same event
    /// ordering, byte-for-byte the same side effects.
    #[test]
    fn ingest_replay_reproduces_batch_run_bit_exactly() {
        let batch = run_experiment(&tiny_cfg()).unwrap();

        let mut eng = Engine::serving(tiny_cfg()).unwrap();
        let template = eng.workflow_template(WorkflowType::Montage).unwrap();
        // tiny_cfg's plan: bursts of 2 at t=0 and t=60.
        eng.submit_at(0.0, template.clone(), 2).unwrap();
        eng.submit_at(60.0, template, 2).unwrap();
        let live = eng.run();

        assert_eq!(batch.summary.workflows_completed, live.summary.workflows_completed);
        assert_eq!(batch.summary.tasks_completed, live.summary.tasks_completed);
        assert_eq!(
            batch.summary.total_duration_min.to_bits(),
            live.summary.total_duration_min.to_bits()
        );
        assert_eq!(
            batch.summary.avg_workflow_duration_min.to_bits(),
            live.summary.avg_workflow_duration_min.to_bits()
        );
        assert_eq!(batch.summary.cpu_usage.to_bits(), live.summary.cpu_usage.to_bits());
        assert_eq!(batch.summary.mem_usage.to_bits(), live.summary.mem_usage.to_bits());
        assert_eq!(batch.pods_created, live.pods_created);
        assert_eq!(batch.serve_cycles, live.serve_cycles);
        assert_eq!(batch.store_list_calls, live.store_list_calls);
        assert_eq!(batch.statestore_writes, live.statestore_writes);
        // Submission accounting is daemon-side only: two records with
        // full-batch latency, absent from the batch twin.
        assert_eq!(batch.metrics.submissions.len(), 0);
        assert_eq!(live.metrics.submissions.len(), 2);
        for rec in &live.metrics.submissions {
            assert!(rec.latency_s() > 0.0);
            assert_eq!(rec.workflows, 2);
        }
    }

    #[test]
    fn submissions_after_queue_drained_restart_sampling() {
        let mut eng = Engine::serving(tiny_cfg()).unwrap();
        let template = eng.workflow_template(WorkflowType::Montage).unwrap();
        eng.submit_at(0.0, template.clone(), 1).unwrap();
        eng.start();
        eng.drain_events();
        assert!(eng.queue_is_empty(), "first submission must fully drain");
        let (injected, completed) = eng.progress();
        assert_eq!((injected, completed), (1, 1));

        // The sampler wound down with the queue; a late submission must
        // restart it and run to completion, not hang or get dropped.
        let later = eng.now() + 100.0;
        eng.submit_at(later, template, 1).unwrap();
        eng.drain_events();
        let (injected, completed) = eng.progress();
        assert_eq!((injected, completed), (2, 2));
        let out = eng.finish();
        assert_eq!(out.summary.workflows_completed, 2);
        assert_eq!(out.tasks_unfinished, 0);
        assert_eq!(out.metrics.submissions.len(), 2);
    }

    #[test]
    fn submit_at_rejects_bad_inputs() {
        let mut eng = Engine::serving(tiny_cfg()).unwrap();
        let template = eng.workflow_template(WorkflowType::Montage).unwrap();
        assert!(eng.submit_at(f64::NAN, template.clone(), 1).is_err());
        assert!(eng.submit_at(-1.0, template.clone(), 1).is_err());
        assert!(eng.submit_at(0.0, template, 0).is_err());
        assert!(eng.workflow_template(WorkflowType::Custom).is_err());
    }

    #[test]
    fn hot_swap_policy_and_forecaster_mid_run() {
        let mut eng = Engine::serving(tiny_cfg()).unwrap();
        let template = eng.workflow_template(WorkflowType::Montage).unwrap();
        eng.submit_at(0.0, template.clone(), 1).unwrap();
        eng.start();
        eng.run_until(30.0);
        let before = eng.policy_name().to_string();
        eng.swap_policy(&PolicySpec::fcfs()).unwrap();
        assert_ne!(eng.policy_name(), before, "swap must take effect");
        assert!(eng.swap_policy(&PolicySpec::named("no-such-policy")).is_err());

        assert_eq!(eng.forecaster_label(), None);
        eng.swap_forecaster(Some(&crate::config::ForecasterSpec::named("holt"))).unwrap();
        assert!(eng.forecaster_label().is_some());
        eng.swap_forecaster(None).unwrap();
        assert_eq!(eng.forecaster_label(), None);

        // Later work is served by the swapped-in policy; the run still
        // completes cleanly.
        eng.submit_at(eng.now() + 10.0, template, 1).unwrap();
        eng.drain_events();
        let out = eng.finish();
        assert_eq!(out.summary.workflows_completed, 2);
        assert_eq!(out.tasks_unfinished, 0);
    }

    // -------------------------------------------- incremental snapshots

    /// Incremental and verify modes must be bit-identical to a full
    /// rebuild on a clean run — including the apiserver-accounting
    /// invariant (`sync_events` costs exactly what `sync` did).
    #[test]
    fn incremental_snapshots_match_full_bit_exactly() {
        let full = run_experiment(&tiny_cfg()).unwrap();
        for mode in [SnapshotMode::Incremental, SnapshotMode::Verify] {
            let mut cfg = tiny_cfg();
            cfg.snapshot_mode = mode;
            let out = run_experiment(&cfg).unwrap();
            assert_eq!(
                full.summary.total_duration_min.to_bits(),
                out.summary.total_duration_min.to_bits(),
                "{mode:?}"
            );
            assert_eq!(full.summary.cpu_usage.to_bits(), out.summary.cpu_usage.to_bits());
            assert_eq!(full.summary.mem_usage.to_bits(), out.summary.mem_usage.to_bits());
            assert_eq!(full.pods_created, out.pods_created);
            assert_eq!(full.serve_cycles, out.serve_cycles);
            assert_eq!(full.store_list_calls, out.store_list_calls, "{mode:?}");
            assert_eq!(full.statestore_writes, out.statestore_writes);
        }
    }

    /// The hard case: node churn (drain + join), a partition freezing
    /// the cache, and a cpu-hog shrinking allocatable — verify mode
    /// cross-checks every fresh snapshot against a full rebuild, and the
    /// run must still match the full-mode twin bit-exactly.
    #[test]
    fn incremental_snapshots_match_full_under_churn_and_chaos() {
        use crate::chaos::ChaosProfile;
        use crate::cluster::{ClusterEvent, ClusterEventKind};
        let make = |mode: SnapshotMode| {
            let mut cfg = tiny_cfg();
            cfg.cluster.events = vec![
                ClusterEvent {
                    at: 20.0,
                    kind: ClusterEventKind::Drain { node: Some("node-0".into()) },
                },
                ClusterEvent {
                    at: 30.0,
                    kind: ClusterEventKind::Join { pool: "node".into(), count: 1 },
                },
            ];
            cfg.chaos = ChaosProfile::partition(1.0, 120.0).to_config();
            cfg.chaos
                .scenarios
                .extend(ChaosProfile::cpu_hog(140.0, 60.0, 3000).to_config().scenarios);
            cfg.snapshot_mode = mode;
            cfg
        };
        let full = run_experiment(&make(SnapshotMode::Full)).unwrap();
        let verify = run_experiment(&make(SnapshotMode::Verify)).unwrap();
        assert_eq!(
            full.summary.total_duration_min.to_bits(),
            verify.summary.total_duration_min.to_bits()
        );
        assert_eq!(full.summary.workflows_completed, verify.summary.workflows_completed);
        assert_eq!(full.stale_snapshot_cycles, verify.stale_snapshot_cycles);
        assert_eq!(full.double_alloc_attempts, verify.double_alloc_attempts);
        assert_eq!(full.store_list_calls, verify.store_list_calls);
        assert_eq!(full.pods_evicted, verify.pods_evicted);
        assert!(verify.stale_snapshot_cycles > 0, "partition must stale some cycles");
    }

    /// OOM self-healing exercises every pod phase transition the
    /// incremental accumulators must track (OomKilled drops requests).
    #[test]
    fn verify_mode_holds_under_oom_self_healing() {
        let mut cfg = tiny_cfg();
        cfg.alloc.strict_min = false;
        cfg.task.min_mem_mi = 3500;
        cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 10, bursts: 1 };
        cfg.snapshot_mode = SnapshotMode::Verify;
        let out = run_experiment(&cfg).unwrap();
        assert!(out.summary.oom_events > 0, "expected OOM events");
        assert_eq!(out.summary.workflows_completed, 10);
        assert_eq!(out.tasks_unfinished, 0);
    }
}
