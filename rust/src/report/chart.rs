//! Terminal chart renderer: braille-free ASCII line/step charts used to
//! show the Figs 5–8 usage curves directly in the console (the CSVs
//! remain the machine-readable output).

/// Render one or two series as an ASCII chart.
///
/// `series`: (label, points) — points are (x, y) with y in [0, y_max].
pub struct Chart {
    width: usize,
    height: usize,
    y_max: f64,
}

impl Default for Chart {
    fn default() -> Self {
        Self { width: 72, height: 14, y_max: 1.0 }
    }
}

impl Chart {
    pub fn new(width: usize, height: usize, y_max: f64) -> Self {
        assert!(width >= 8 && height >= 2 && y_max > 0.0);
        Self { width, height, y_max }
    }

    /// Render series with distinct glyphs ('*', '+', 'o', ...).
    pub fn render(&self, series: &[(&str, &[(f64, f64)])]) -> String {
        let glyphs = ['*', '+', 'o', 'x', '#'];
        let x_max = series
            .iter()
            .flat_map(|(_, pts)| pts.iter().map(|p| p.0))
            .fold(1.0f64, f64::max);

        let mut grid = vec![vec![' '; self.width]; self.height];
        for (si, (_, pts)) in series.iter().enumerate() {
            let g = glyphs[si % glyphs.len()];
            for &(x, y) in *pts {
                let cx = ((x / x_max) * (self.width - 1) as f64).round() as usize;
                let cy = ((y.min(self.y_max) / self.y_max) * (self.height - 1) as f64).round()
                    as usize;
                let row = self.height - 1 - cy;
                grid[row][cx.min(self.width - 1)] = g;
            }
        }

        let mut out = String::new();
        for (i, row) in grid.iter().enumerate() {
            let y_label = self.y_max * (self.height - 1 - i) as f64 / (self.height - 1) as f64;
            out.push_str(&format!("{y_label:>6.2} |"));
            out.extend(row.iter());
            out.push('\n');
        }
        out.push_str(&format!("{:>6} +{}\n", "", "-".repeat(self.width)));
        out.push_str(&format!("{:>8}0{:>width$.0}s\n", "", x_max, width = self.width - 2));
        for (si, (label, _)) in series.iter().enumerate() {
            out.push_str(&format!("{:>8}{} = {}\n", "", glyphs[si % glyphs.len()], label));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_expected_dimensions() {
        let pts: Vec<(f64, f64)> = (0..100).map(|i| (i as f64, (i % 10) as f64 / 10.0)).collect();
        let chart = Chart::default();
        let s = chart.render(&[("cpu", &pts)]);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 14 + 3); // grid + axis + x labels + legend
        assert!(s.contains("* = cpu"));
    }

    #[test]
    fn high_values_clamped_to_ymax() {
        let pts = [(0.0, 5.0), (1.0, 0.0)];
        let chart = Chart::new(10, 4, 1.0);
        let s = chart.render(&[("y", &pts)]);
        // the 5.0 point lands on the top row, not out of bounds
        assert!(s.lines().next().unwrap().contains('*'));
    }

    #[test]
    fn two_series_use_distinct_glyphs() {
        let a = [(0.0, 0.2), (10.0, 0.2)];
        let b = [(0.0, 0.8), (10.0, 0.8)];
        let s = Chart::default().render(&[("aras", &a), ("fcfs", &b)]);
        assert!(s.contains('*') && s.contains('+'));
        assert!(s.contains("* = aras") && s.contains("+ = fcfs"));
    }

    #[test]
    #[should_panic]
    fn rejects_degenerate_geometry() {
        Chart::new(2, 1, 1.0);
    }
}
