//! Campaign report rendering: per-run summary CSV, ARAS-vs-baseline
//! comparison CSV, a markdown report, and a terminal chart.
//!
//! All numeric columns use fixed-precision formatting, so re-running the
//! same campaign (same spec + seed) writes byte-identical files — the
//! reproducibility contract `rust/tests/campaign.rs` asserts.

use std::fmt::Write as _;

use crate::campaign::{CampaignResult, ComparisonRow};
use crate::report::chart::Chart;
use crate::util::csv::CsvWriter;

/// One row per run, in grid-expansion order.
pub fn summary_csv(result: &CampaignResult) -> CsvWriter {
    let mut w = CsvWriter::new(&[
        "index",
        "workflow",
        "pattern",
        "pattern_detail",
        "policy",
        "nodes",
        "alpha",
        "lookahead",
        "churn",
        "rep",
        "seed",
        "workflows_completed",
        "tasks_completed",
        "total_duration_min",
        "avg_workflow_duration_min",
        "cpu_usage",
        "mem_usage",
        "oom_events",
        "alloc_waits",
        "pods_created",
        "evictions",
        "forecaster",
        "forecast_points",
        "forecast_mape_cpu",
        "forecast_mape_mem",
        "forecast_rmse_cpu",
        "forecast_rmse_mem",
        "chaos",
        "hog_stolen_cpu_s",
        "hog_stolen_mem_s",
        "stale_snapshot_cycles",
        "double_alloc_attempts",
        "wf_duration_p50_s",
        "wf_duration_p95_s",
        "serve_cycles",
        "plan_calls",
        "schedule_calls",
        "snapshot_applies",
        "clusters",
        "router",
    ]);
    for run in &result.runs {
        let c = &run.coord;
        let s = &run.outcome.summary;
        w.row(&[
            c.index.to_string(),
            c.workflow.name().to_string(),
            c.pattern.name().to_string(),
            c.pattern.detail(),
            c.policy.label(),
            c.nodes.to_string(),
            format!("{:.3}", c.alpha),
            (if c.lookahead { "on" } else { "off" }).to_string(),
            c.churn.clone(),
            c.rep.to_string(),
            c.seed.to_string(),
            s.workflows_completed.to_string(),
            s.tasks_completed.to_string(),
            format!("{:.4}", s.total_duration_min),
            format!("{:.4}", s.avg_workflow_duration_min),
            format!("{:.6}", s.cpu_usage),
            format!("{:.6}", s.mem_usage),
            s.oom_events.to_string(),
            s.alloc_waits.to_string(),
            run.outcome.pods_created.to_string(),
            s.evictions.to_string(),
            c.forecaster.clone(),
            s.forecast_points.to_string(),
            format!("{:.3}", s.forecast_mape_cpu),
            format!("{:.3}", s.forecast_mape_mem),
            format!("{:.3}", s.forecast_rmse_cpu),
            format!("{:.3}", s.forecast_rmse_mem),
            c.chaos.clone(),
            format!("{:.1}", s.hog_stolen_cpu_s),
            format!("{:.1}", s.hog_stolen_mem_s),
            s.stale_snapshot_cycles.to_string(),
            s.double_alloc_attempts.to_string(),
            format!("{:.3}", s.wf_duration_p50_s),
            format!("{:.3}", s.wf_duration_p95_s),
            s.phases.serve_cycles.to_string(),
            s.phases.plan_calls.to_string(),
            s.phases.schedule_calls.to_string(),
            s.phases.snapshot_applies.to_string(),
            c.clusters.to_string(),
            c.router.clone(),
        ]);
    }
    w
}

/// One row per comparison cell: both policies' aggregates plus the
/// paper's headline deltas (time savings, usage gains).
pub fn comparison_csv(rows: &[ComparisonRow]) -> CsvWriter {
    let mut w = CsvWriter::new(&[
        "workflow",
        "pattern",
        "pattern_detail",
        "nodes",
        "alpha",
        "lookahead",
        "churn",
        "forecaster",
        "adaptive_total_min",
        "baseline_total_min",
        "adaptive_avg_min",
        "baseline_avg_min",
        "adaptive_cpu_usage",
        "baseline_cpu_usage",
        "adaptive_mem_usage",
        "baseline_mem_usage",
        "total_saving_pct",
        "avg_saving_pct",
        "cpu_gain_pts",
        "mem_gain_pts",
        "chaos",
        "adaptive_wf_p50_s",
        "baseline_wf_p50_s",
        "adaptive_plan_calls",
        "baseline_plan_calls",
        "clusters",
        "router",
    ]);
    let cell = |v: Option<f64>, digits: usize| match v {
        Some(x) => format!("{:.*}", digits, x),
        None => String::new(),
    };
    for r in rows {
        let a = r.adaptive.as_ref();
        let b = r.baseline.as_ref();
        w.row(&[
            r.workflow.name().to_string(),
            r.pattern.name().to_string(),
            r.pattern.detail(),
            r.nodes.to_string(),
            format!("{:.3}", r.alpha),
            (if r.lookahead { "on" } else { "off" }).to_string(),
            r.churn.clone(),
            r.forecaster.clone(),
            cell(a.map(|x| x.total_duration_min.mean), 4),
            cell(b.map(|x| x.total_duration_min.mean), 4),
            cell(a.map(|x| x.avg_workflow_duration_min.mean), 4),
            cell(b.map(|x| x.avg_workflow_duration_min.mean), 4),
            cell(a.map(|x| x.cpu_usage.mean), 6),
            cell(b.map(|x| x.cpu_usage.mean), 6),
            cell(a.map(|x| x.mem_usage.mean), 6),
            cell(b.map(|x| x.mem_usage.mean), 6),
            cell(r.total_saving_pct(), 2),
            cell(r.avg_saving_pct(), 2),
            cell(r.cpu_gain_pts(), 2),
            cell(r.mem_gain_pts(), 2),
            r.chaos.clone(),
            cell(a.map(|x| x.wf_duration_p50_s), 3),
            cell(b.map(|x| x.wf_duration_p50_s), 3),
            cell(a.map(|x| x.plan_calls), 1),
            cell(b.map(|x| x.plan_calls), 1),
            r.clusters.to_string(),
            r.router.clone(),
        ]);
    }
    w
}

/// Human-readable campaign report (markdown). `rows` is the result's
/// [`CampaignResult::comparison`] output — passed in so callers compute
/// it once and share it with [`comparison_csv`]/[`usage_chart`].
pub fn render_markdown(result: &CampaignResult, rows: &[ComparisonRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "# Campaign report: {}\n", result.name);
    let _ = writeln!(
        out,
        "{} runs across {} comparison cells ({} worker threads).\n",
        result.runs.len(),
        rows.len(),
        result.threads_used,
    );
    let _ = writeln!(
        out,
        "| Workflow | Pattern | Nodes | α | Lookahead | Churn | Forecaster | Chaos | ARAS total (min) | FCFS total (min) | Total saving | Avg saving | CPU gain | Mem gain |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|");
    let fmt_cell = |agg: Option<&crate::campaign::PolicyAgg>| match agg {
        Some(a) => a.total_duration_min.fmt(2),
        None => "—".to_string(),
    };
    let fmt_pct = |v: Option<f64>, suffix: &str| match v {
        Some(x) => format!("{x:+.1}{suffix}"),
        None => "—".to_string(),
    };
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} | {} |",
            r.workflow.name(),
            r.pattern.name(),
            r.nodes,
            r.alpha,
            if r.lookahead { "on" } else { "off" },
            r.churn,
            r.forecaster,
            r.chaos,
            fmt_cell(r.adaptive.as_ref()),
            fmt_cell(r.baseline.as_ref()),
            fmt_pct(r.total_saving_pct(), "%"),
            fmt_pct(r.avg_saving_pct(), "%"),
            fmt_pct(r.cpu_gain_pts(), " pts"),
            fmt_pct(r.mem_gain_pts(), " pts"),
        );
    }
    // Policies beyond the canonical ARAS/FCFS pair (registry policies
    // riding the grid) get their own table; absent for the standard
    // two-policy grids, so their reports stay byte-identical.
    if rows.iter().any(|r| !r.extras.is_empty()) {
        let _ = writeln!(
            out,
            "\n### Additional policies\n\n| Workflow | Pattern | Policy | Total (min) | Avg workflow (min) | CPU usage | Mem usage |"
        );
        let _ = writeln!(out, "|---|---|---|---|---|---|---|");
        for r in rows {
            for agg in &r.extras {
                let _ = writeln!(
                    out,
                    "| {} | {} | {} | {} | {} | {:.3} | {:.3} |",
                    r.workflow.name(),
                    r.pattern.name(),
                    agg.policy,
                    agg.total_duration_min.fmt(2),
                    agg.avg_workflow_duration_min.fmt(2),
                    agg.cpu_usage.mean,
                    agg.mem_usage.mean,
                );
            }
        }
    }
    if let Some(headline) = headline(rows) {
        let _ = writeln!(out, "\n{headline}");
    }
    out
}

/// The paper-abstract-style headline: min..max savings across cells.
pub fn headline(rows: &[ComparisonRow]) -> Option<String> {
    let totals: Vec<f64> = rows.iter().filter_map(|r| r.total_saving_pct()).collect();
    let avgs: Vec<f64> = rows.iter().filter_map(|r| r.avg_saving_pct()).collect();
    if totals.is_empty() || avgs.is_empty() {
        return None;
    }
    let span = |xs: &[f64]| {
        (
            xs.iter().copied().fold(f64::INFINITY, f64::min),
            xs.iter().copied().fold(f64::NEG_INFINITY, f64::max),
        )
    };
    let (t_lo, t_hi) = span(&totals);
    let (a_lo, a_hi) = span(&avgs);
    Some(format!(
        "ARAS vs FCFS across {} cells: total-duration saving {t_lo:.1}%..{t_hi:.1}%, \
         per-workflow saving {a_lo:.1}%..{a_hi:.1}% \
         (paper reports 9.8%..40.92% and 26.4%..79.86%).",
        rows.len(),
    ))
}

/// Terminal chart: mean CPU usage rate per comparison cell, ARAS vs
/// baseline (x = cell index in grid order, y = usage rate in [0, 1]).
pub fn usage_chart(rows: &[ComparisonRow]) -> String {
    let adaptive: Vec<(f64, f64)> = rows
        .iter()
        .enumerate()
        .filter_map(|(i, r)| r.adaptive.as_ref().map(|a| (i as f64, a.cpu_usage.mean)))
        .collect();
    let baseline: Vec<(f64, f64)> = rows
        .iter()
        .enumerate()
        .filter_map(|(i, r)| r.baseline.as_ref().map(|b| (i as f64, b.cpu_usage.mean)))
        .collect();
    let mut series: Vec<(&str, &[(f64, f64)])> = Vec::new();
    if !adaptive.is_empty() {
        series.push(("aras cpu usage (per cell)", &adaptive));
    }
    if !baseline.is_empty() {
        series.push(("fcfs cpu usage (per cell)", &baseline));
    }
    if series.is_empty() {
        return String::new();
    }
    Chart::default().render(&series)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::campaign::{run, CampaignSpec};
    use crate::config::ArrivalPattern;

    fn tiny_result() -> CampaignResult {
        let mut spec = CampaignSpec::default();
        spec.name = "tiny".into();
        spec.base.workload.pattern = ArrivalPattern::Constant { per_burst: 2, bursts: 1 };
        spec.patterns = vec![spec.base.workload.pattern];
        spec.base.sample_interval_s = 5.0;
        spec.threads = 2;
        run(&spec).unwrap()
    }

    #[test]
    fn summary_csv_has_one_row_per_run() {
        let result = tiny_result();
        let csv = summary_csv(&result);
        assert_eq!(csv.len(), result.runs.len());
        assert!(csv
            .to_string()
            .starts_with("index,workflow,pattern,pattern_detail,policy"));
    }

    #[test]
    fn comparison_csv_and_markdown_render() {
        let result = tiny_result();
        let rows = result.comparison();
        let csv = comparison_csv(&rows).to_string();
        assert!(csv.contains("montage,constant"));
        let md = render_markdown(&result, &rows);
        assert!(md.contains("# Campaign report: tiny"));
        assert!(md.contains("| montage | constant |"));
    }

    #[test]
    fn usage_chart_renders_two_series() {
        let result = tiny_result();
        let chart = usage_chart(&result.comparison());
        assert!(chart.contains("aras cpu usage"));
        assert!(chart.contains("fcfs cpu usage"));
    }
}
