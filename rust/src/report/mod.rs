//! Report rendering: Table 2 (markdown), figure CSVs, terminal charts,
//! run summaries, and campaign reports ([`campaign`]).

pub mod campaign;
pub mod chart;

use std::fmt::Write as _;

use crate::metrics::Collector;
use crate::util::csv::CsvWriter;
use crate::util::stats;

/// One Table 2 cell: mean ± δ over repetitions.
#[derive(Debug, Clone, Copy, Default)]
pub struct Cell {
    pub mean: f64,
    pub stddev: f64,
}

impl Cell {
    pub fn of(samples: &[f64]) -> Cell {
        Cell { mean: stats::mean(samples), stddev: stats::stddev(samples) }
    }

    pub fn fmt(&self, digits: usize) -> String {
        format!("{:.*} (δ={:.*})", digits, self.mean, digits.min(2), self.stddev)
    }
}

/// One (workflow × pattern × policy) row group of Table 2.
#[derive(Debug, Clone)]
pub struct Table2Entry {
    pub workflow: String,
    pub pattern: String,
    pub policy: String,
    pub total_duration_min: Cell,
    pub avg_workflow_duration_min: Cell,
    pub cpu_usage: Cell,
    pub mem_usage: Cell,
}

/// Render the full Table 2 in the paper's layout (metrics × patterns,
/// Adaptive vs Baseline side by side), as markdown.
pub fn render_table2(entries: &[Table2Entry]) -> String {
    let workflows = ["montage", "epigenomics", "cybershake", "ligo"];
    let patterns = ["constant", "linear", "pyramid"];
    let metrics: [(&str, fn(&Table2Entry) -> Cell, usize); 4] = [
        ("Total Duration of All Workflows (min)", |e| e.total_duration_min, 2),
        ("Average Workflow Duration (min)", |e| e.avg_workflow_duration_min, 2),
        ("CPU resource Usage", |e| e.cpu_usage, 2),
        ("Memory resource Usage", |e| e.mem_usage, 2),
    ];

    let find = |wf: &str, pat: &str, pol: &str| {
        entries
            .iter()
            .find(|e| e.workflow == wf && e.pattern == pat && e.policy == pol)
    };

    let mut out = String::new();
    let _ = writeln!(out, "# Table 2 — Evaluation results (mean, δ over repetitions)\n");
    let _ = writeln!(
        out,
        "| Workflow | Metric | Constant Adaptive | Constant Baseline | Linear Adaptive | Linear Baseline | Pyramid Adaptive | Pyramid Baseline |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|");
    for wf in workflows {
        for (mname, pick, digits) in &metrics {
            let mut row = format!("| {wf} | {mname} |");
            for pat in patterns {
                for pol in ["adaptive", "baseline"] {
                    match find(wf, pat, pol) {
                        Some(e) => {
                            let _ = write!(row, " {} |", pick(e).fmt(*digits));
                        }
                        None => {
                            let _ = write!(row, " — |");
                        }
                    }
                }
            }
            let _ = writeln!(out, "{row}");
        }
    }
    out
}

/// Paper-style comparison: time savings of Adaptive vs Baseline per
/// workflow/pattern (the percentages quoted throughout §6.2.1).
pub fn render_savings(entries: &[Table2Entry]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "\n## ARAS vs Baseline (positive = ARAS better)\n");
    let _ = writeln!(
        out,
        "| Workflow | Pattern | Total-duration saving | Avg-workflow-duration saving | CPU usage gain | Mem usage gain |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|");
    for wf in ["montage", "epigenomics", "cybershake", "ligo"] {
        for pat in ["constant", "linear", "pyramid"] {
            let a = entries.iter().find(|e| e.workflow == wf && e.pattern == pat && e.policy == "adaptive");
            let b = entries.iter().find(|e| e.workflow == wf && e.pattern == pat && e.policy == "baseline");
            if let (Some(a), Some(b)) = (a, b) {
                let save = |x: f64, y: f64| if y > 0.0 { (1.0 - x / y) * 100.0 } else { 0.0 };
                let _ = writeln!(
                    out,
                    "| {wf} | {pat} | {:.1}% | {:.1}% | {:+.1} pts | {:+.1} pts |",
                    save(a.total_duration_min.mean, b.total_duration_min.mean),
                    save(a.avg_workflow_duration_min.mean, b.avg_workflow_duration_min.mean),
                    (a.cpu_usage.mean - b.cpu_usage.mean) * 100.0,
                    (a.mem_usage.mean - b.mem_usage.mean) * 100.0,
                );
            }
        }
    }
    out
}

/// Usage-curve CSV for Figs 5–8: time, requests step curve, cpu/mem rate.
pub fn usage_curve_csv(collector: &Collector) -> CsvWriter {
    let mut w = CsvWriter::new(&[
        "t_s",
        "cumulative_requests",
        "cpu_rate",
        "mem_rate",
        "running_pods",
        "nodes",
    ]);
    let mut arrivals = collector.arrivals.iter().peekable();
    let mut cum = 0usize;
    for s in &collector.samples {
        while let Some(&&(at, c)) = arrivals.peek() {
            if at <= s.t {
                cum = c;
                arrivals.next();
            } else {
                break;
            }
        }
        w.row(&[
            format!("{:.1}", s.t),
            cum.to_string(),
            format!("{:.4}", s.cpu_rate),
            format!("{:.4}", s.mem_rate),
            s.running_pods.to_string(),
            s.nodes.to_string(),
        ]);
    }
    w
}

/// Task-lifecycle timeline CSV for Fig. 1 / Fig. 9: one row per event.
pub fn event_timeline_csv(collector: &Collector) -> CsvWriter {
    let mut w = CsvWriter::new(&["t_s", "workflow", "task", "event", "detail"]);
    for e in &collector.events {
        let (name, detail) = e.kind.name_and_detail();
        w.row(&[
            format!("{:.1}", e.t),
            e.workflow_uid.to_string(),
            e.task_id.to_string(),
            name.to_string(),
            detail,
        ]);
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(wf: &str, pat: &str, pol: &str, total: f64) -> Table2Entry {
        Table2Entry {
            workflow: wf.into(),
            pattern: pat.into(),
            policy: pol.into(),
            total_duration_min: Cell { mean: total, stddev: 0.1 },
            avg_workflow_duration_min: Cell { mean: total / 5.0, stddev: 0.05 },
            cpu_usage: Cell { mean: 0.3, stddev: 0.0 },
            mem_usage: Cell { mean: 0.3, stddev: 0.0 },
        }
    }

    #[test]
    fn table_contains_all_rows() {
        let entries = vec![
            entry("montage", "constant", "adaptive", 33.0),
            entry("montage", "constant", "baseline", 36.8),
        ];
        let md = render_table2(&entries);
        assert!(md.contains("| montage | Total Duration of All Workflows (min) | 33.00"));
        assert!(md.contains("36.80"));
        assert!(md.contains("— |")); // missing cells rendered as dashes
    }

    #[test]
    fn savings_sign_correct() {
        let entries = vec![
            entry("montage", "constant", "adaptive", 30.0),
            entry("montage", "constant", "baseline", 40.0),
        ];
        let s = render_savings(&entries);
        assert!(s.contains("25.0%"), "{s}");
    }

    #[test]
    fn cell_formats_mean_and_delta() {
        let c = Cell::of(&[1.0, 2.0, 3.0]);
        assert!(c.fmt(2).starts_with("2.00 (δ="));
    }
}
