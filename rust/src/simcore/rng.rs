//! Deterministic PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! Replaces the `rand` crate (unavailable offline). Statistical quality is
//! far beyond what the workload generator needs; determinism is the point:
//! every experiment takes an explicit seed, repetition `r` of a run uses
//! `seed + r`, and identical seeds must reproduce identical metrics.

/// SplitMix64 — used to expand a 64-bit seed into xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Derive a stream seed from a base seed and a coordinate vector.
///
/// Used by the campaign runner to give every grid cell an independent,
/// reproducible workload seed: the result depends only on `(base,
/// coords)` — never on thread count, scheduling order, or which other
/// cells the campaign contains — so a run is bit-identical whether it
/// executes alone or inside a 1000-cell sweep. The fold is sequential
/// (each coordinate perturbs the SplitMix64 state before the next), so
/// coordinate *order* matters: `[1, 2] != [2, 1]`.
pub fn derive_seed(base: u64, coords: &[u64]) -> u64 {
    let mut state = base;
    let mut out = splitmix64(&mut state);
    for &c in coords {
        state ^= c.wrapping_mul(0xD6E8_FEB8_6659_FD93);
        out = splitmix64(&mut state) ^ out.rotate_left(17);
    }
    out
}

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = splitmix64(&mut sm);
        }
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n) using Lemire's method (n > 0).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // rejection-free for practical purposes given 64-bit width
        let x = self.next_u64();
        ((x as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Derive an independent child generator (stream split).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_bounds() {
        let mut r = Rng::new(42);
        for _ in 0..1000 {
            let x = r.uniform(10.0, 20.0);
            assert!((10.0..20.0).contains(&x));
        }
    }

    #[test]
    fn below_in_bounds_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 5];
        for _ in 0..200 {
            let v = r.below(5) as usize;
            assert!(v < 5);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn range_inclusive_hits_endpoints() {
        let mut r = Rng::new(9);
        let (mut lo_seen, mut hi_seen) = (false, false);
        for _ in 0..500 {
            match r.range_inclusive(10, 20) {
                10 => lo_seen = true,
                20 => hi_seen = true,
                v => assert!((10..=20).contains(&v)),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(11);
        let n = 10_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn derive_seed_is_deterministic_and_order_sensitive() {
        assert_eq!(derive_seed(42, &[1, 2, 3]), derive_seed(42, &[1, 2, 3]));
        assert_ne!(derive_seed(42, &[1, 2, 3]), derive_seed(42, &[3, 2, 1]));
        assert_ne!(derive_seed(42, &[1, 2, 3]), derive_seed(43, &[1, 2, 3]));
        assert_ne!(derive_seed(42, &[]), derive_seed(42, &[0]));
    }

    #[test]
    fn derive_seed_separates_adjacent_cells() {
        // Adjacent grid coordinates must produce well-separated streams.
        let mut seen = std::collections::HashSet::new();
        for a in 0..8u64 {
            for b in 0..8u64 {
                for rep in 0..4u64 {
                    assert!(seen.insert(derive_seed(7, &[a, b, rep])));
                }
            }
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut a = Rng::new(5);
        let mut c = a.fork();
        let x: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let y: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_ne!(x, y);
    }
}
