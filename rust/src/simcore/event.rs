//! Generic discrete-event queue.
//!
//! The engine defines its own event payload type `E`; the queue orders by
//! (time, insertion sequence) so simultaneous events run in deterministic
//! FIFO order — a requirement for reproducible experiments (same seed ⇒
//! identical metrics, asserted in `rust/tests/integration.rs`).

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use super::SimTime;

/// An event scheduled at a virtual time.
#[derive(Debug)]
pub struct ScheduledEvent<E> {
    pub time: SimTime,
    pub seq: u64,
    pub payload: E,
}

impl<E> PartialEq for ScheduledEvent<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for ScheduledEvent<E> {}

impl<E> Ord for ScheduledEvent<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for ScheduledEvent<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Earliest-first event queue with a monotone virtual clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<ScheduledEvent<E>>,
    now: SimTime,
    seq: u64,
    processed: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self { heap: BinaryHeap::new(), now: 0.0, seq: 0, processed: 0 }
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time (the time of the last popped event).
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Number of events executed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Schedule `payload` at absolute time `at` (clamped to now).
    pub fn schedule_at(&mut self, at: SimTime, payload: E) {
        let time = if at < self.now { self.now } else { at };
        self.seq += 1;
        self.heap.push(ScheduledEvent { time, seq: self.seq, payload });
    }

    /// Schedule `payload` after a relative delay (>= 0).
    pub fn schedule_in(&mut self, delay: SimTime, payload: E) {
        debug_assert!(delay >= 0.0, "negative delay");
        self.schedule_at(self.now + delay.max(0.0), payload);
    }

    /// Pop the earliest event, advancing the clock to its time.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now, "time went backwards");
        self.now = ev.time;
        self.processed += 1;
        Some((ev.time, ev.payload))
    }

    /// Peek at the time of the next event without advancing.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|e| e.time)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.schedule_at(5.0, "b");
        q.schedule_at(1.0, "a");
        q.schedule_at(5.0, "c");
        assert_eq!(q.pop().unwrap(), (1.0, "a"));
        assert_eq!(q.pop().unwrap(), (5.0, "b"));
        assert_eq!(q.pop().unwrap(), (5.0, "c"));
        assert!(q.pop().is_none());
    }

    #[test]
    fn clock_advances_monotonically() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, 1u32);
        q.pop();
        assert_eq!(q.now(), 3.0);
        // scheduling in the past clamps to now
        q.schedule_at(1.0, 2u32);
        let (t, _) = q.pop().unwrap();
        assert_eq!(t, 3.0);
    }

    #[test]
    fn schedule_in_is_relative() {
        let mut q = EventQueue::new();
        q.schedule_at(10.0, 0u8);
        q.pop();
        q.schedule_in(2.5, 1u8);
        assert_eq!(q.pop().unwrap().0, 12.5);
    }

    #[test]
    fn processed_counts() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.schedule_at(i as f64, i);
        }
        while q.pop().is_some() {}
        assert_eq!(q.processed(), 10);
    }
}
