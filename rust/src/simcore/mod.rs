//! Discrete-event simulation core: virtual clock, event queue, PRNG.
//!
//! This substrate replaces the paper's wall-clock testbed with virtual
//! time (see DESIGN.md §Substitutions): a run of 34 workflows (~700 pods)
//! executes in milliseconds while preserving every time *ratio* the
//! paper's metrics are built from.

pub mod event;
pub mod rng;

pub use event::{EventQueue, ScheduledEvent};
pub use rng::{derive_seed, Rng};

/// Virtual time in seconds since the start of a run.
pub type SimTime = f64;
