//! `kubeadaptor` — CLI for the KubeAdaptor + ARAS reproduction.
//!
//! Subcommands:
//! * `run`      — one experiment (workflow × pattern × policy), prints the summary
//! * `campaign` — declarative sweep grid executed across a thread pool
//! * `table2`   — regenerate Table 2 (all 24 combinations × reps)
//! * `figures`  — regenerate Figs 1 and 5–8 (CSV series + ASCII gantt)
//! * `oom`      — the Fig. 9 failure/self-healing evaluation
//! * `chaos`    — fault-injection evaluation (hogs, latency storms, partitions)
//! * `federate` — multi-cluster federation: router comparison over sharded clusters
//! * `bench`    — perf baseline (allocator ns/decision, engine tasks/sec)
//! * `ablate`   — α / lookahead / cluster-size ablations
//! * `dag`      — dump a workflow topology as DOT (Fig. 4)
//! * `daemon`   — long-running serving mode with live workflow ingest
//! * `client`   — one-shot client for a running daemon

use std::path::Path;

use kubeadaptor::campaign::CampaignSpec;
use kubeadaptor::chaos::ChaosProfile;
use kubeadaptor::cluster::{dynamics, AutoscalerConfig, ChurnProfile};
use kubeadaptor::config::{
    ArrivalPattern, Backend, ExperimentConfig, ForecasterSpec, PolicySpec, RouterSpec,
};
use kubeadaptor::engine::Engine;
use kubeadaptor::experiments::{
    ablation, chaos, churn, federate, fig1, forecast, oom, table2, usage_curves,
};
use kubeadaptor::federation::registry as router_registry;
use kubeadaptor::forecast::registry as forecast_registry;
use kubeadaptor::report;
use kubeadaptor::resources::registry;
use kubeadaptor::util::cli::Args;
use kubeadaptor::util::log::{set_level, Level};
use kubeadaptor::workflow::{topologies, WorkflowType};

fn main() {
    // Behave like a unix CLI when piped into `head` etc.: die quietly on
    // SIGPIPE instead of panicking on a failed stdout write.
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest: Vec<String> = argv.iter().skip(1).cloned().collect();
    let code = match cmd {
        "run" => cmd_run(&rest),
        "campaign" => cmd_campaign(&rest),
        "table2" => cmd_table2(&rest),
        "figures" => cmd_figures(&rest),
        "oom" => cmd_oom(&rest),
        "churn" => cmd_churn(&rest),
        "forecast" => cmd_forecast(&rest),
        "chaos" => cmd_chaos(&rest),
        "federate" => cmd_federate(&rest),
        "bench" => cmd_bench(&rest),
        "ablate" => cmd_ablate(&rest),
        "dag" => cmd_dag(&rest),
        "export-trace" => cmd_export_trace(&rest),
        "daemon" => cmd_daemon(&rest),
        "client" => cmd_client(&rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_help();
            std::process::exit(2);
        }
    };
    if let Err(e) = code {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "kubeadaptor — ARAS workflow-containerization engine (paper reproduction)

USAGE: kubeadaptor <command> [options]

COMMANDS:
  run      run one experiment           (--workflow --pattern --policy --backend --seed ...,
                                         --list-policies / --list-backends show the rosters)
  campaign run a sweep grid in parallel (--workflows --patterns --policies --backend --nodes
                                         --alphas --reps --seed --threads --out)
  table2   regenerate Table 2           (--reps --seed --out)
  figures  regenerate Figs 1, 5-8      (--fig N | --all, --seed, --out)
  oom      Fig. 9 failure evaluation    (--seed --out)
  churn    cluster-dynamics evaluation  (--seed --out; static vs drain-storm vs autoscaled)
  forecast reactive-vs-predictive eval  (--seed --out --quick; --list-forecasters shows the roster)
  chaos    fault-injection evaluation   (--seed --out --quick; hogs, latency storms, partitions)
  federate multi-cluster router eval    (--seed --out --quick --threads; skewed, capacity-asym,
                                         outage scenarios x all routers; --list-routers)
  bench    perf baseline                (--out --smoke; allocator ns/decision, engine tasks/sec)
  ablate   ablation studies             (--param alpha|lookahead|nodes --seed)
  dag      dump topology as DOT         (--workflow)
  export-trace  dump a synthetic pattern as a replayable trace (--pattern)
  daemon   serve live workflow ingest    (--listen --pace --hold --schedule; line-JSON protocol)
  client   send one command to a daemon  (--addr --cmd submit|status|metrics|drain|shutdown ...)

Run 'kubeadaptor <command> --help' for options."
    );
}

/// Parse a `--policy` value and resolve it through the registry so
/// unknown names fail here, with the roster, instead of deep in a run.
fn parse_policy(s: &str) -> anyhow::Result<PolicySpec> {
    let mut spec = PolicySpec::parse(s)?;
    // Single guard scope: deriving both the canonical name and the
    // error roster from one read lock (a second read() while this one
    // is held could deadlock behind a queued writer).
    let canonical = {
        let reg = registry::global().read().unwrap();
        match reg.canonical_name(&spec.name) {
            Some(name) => name.to_string(),
            None => anyhow::bail!(
                "unknown policy '{}' (registered: {}; see --list-policies)",
                spec.name,
                reg.names().join(", ")
            ),
        }
    };
    spec.name = canonical;
    Ok(spec)
}

/// Render the registry roster (the `--list-policies` output).
fn render_policy_listing() -> String {
    let mut out = String::from("registered policies:\n");
    for (name, aliases, summary) in registry::policy_listing() {
        let alias_note = if aliases.is_empty() {
            String::new()
        } else {
            format!(" (aliases: {})", aliases.join(", "))
        };
        out.push_str(&format!("  {name:<18} {summary}{alias_note}\n"));
    }
    out.push_str("\nselect with --policy <name> or --policy <name>:key=value,key=value\n");
    out
}

/// Parse a `--forecaster` value and resolve it through the forecast
/// registry, mirroring [`parse_policy`].
fn parse_forecaster(s: &str) -> anyhow::Result<ForecasterSpec> {
    let mut spec = ForecasterSpec::parse(s)?;
    let canonical = {
        let reg = forecast_registry::global().read().unwrap();
        match reg.canonical_name(&spec.name) {
            Some(name) => name.to_string(),
            None => anyhow::bail!(
                "unknown forecaster '{}' (registered: {}; see --list-forecasters)",
                spec.name,
                reg.names().join(", ")
            ),
        }
    };
    spec.name = canonical;
    Ok(spec)
}

/// Render the decision-backend roster (the `--list-backends` output),
/// with live availability probing (pjrt reports *why* it is missing).
fn render_backend_listing() -> String {
    let mut out = String::from("registered decision backends:\n");
    for (name, summary, availability) in kubeadaptor::resources::backends::listing() {
        out.push_str(&format!("  {name:<10} {summary}\n             [{availability}]\n"));
    }
    out.push_str("\nselect with --backend <name> (or the \"backend\" config key)\n");
    out
}

/// Parse a `--router` value and resolve it through the federation
/// registry, mirroring [`parse_policy`].
fn parse_router(s: &str) -> anyhow::Result<RouterSpec> {
    let mut spec = RouterSpec::parse(s)?;
    let canonical = {
        let reg = router_registry::global().read().unwrap();
        match reg.canonical_name(&spec.name) {
            Some(name) => name.to_string(),
            None => anyhow::bail!(
                "unknown router '{}' (registered: {}; see --list-routers)",
                spec.name,
                reg.names().join(", ")
            ),
        }
    };
    spec.name = canonical;
    Ok(spec)
}

/// Render the router roster (the `--list-routers` output).
fn render_router_listing() -> String {
    let mut out = String::from("registered routers:\n");
    for (name, aliases, summary) in router_registry::router_listing() {
        let alias_note = if aliases.is_empty() {
            String::new()
        } else {
            format!(" (aliases: {})", aliases.join(", "))
        };
        out.push_str(&format!("  {name:<18} {summary}{alias_note}\n"));
    }
    out.push_str("\nselect with --router <name> or --router <name>:key=value,key=value\n");
    out
}

/// Render the forecaster roster (the `--list-forecasters` output).
fn render_forecaster_listing() -> String {
    let mut out = String::from("registered forecasters:\n");
    for (name, aliases, summary) in forecast_registry::forecaster_listing() {
        let alias_note = if aliases.is_empty() {
            String::new()
        } else {
            format!(" (aliases: {})", aliases.join(", "))
        };
        out.push_str(&format!("  {name:<18} {summary}{alias_note}\n"));
    }
    out.push_str("\nselect with --forecaster <name> or --forecaster <name>:key=value,key=value\n");
    out
}

fn parse_common(cfg: &mut ExperimentConfig, p: &kubeadaptor::util::cli::Parsed) -> anyhow::Result<()> {
    cfg.workload.workflow = WorkflowType::parse(p.get_str("workflow"))?;
    cfg.workload.pattern = ArrivalPattern::parse(p.get_str("pattern"))?;
    cfg.alloc.policy = parse_policy(p.get_str("policy"))?;
    cfg.alloc.backend = Backend::parse(p.get_str("backend"))?;
    cfg.alloc.alpha = p.get_f64("alpha")?;
    cfg.workload.seed = p.get_u64("seed")?;
    cfg.cluster.nodes = p.get_usize("nodes")?;
    if p.flag("verbose") {
        set_level(Level::Info);
    }
    if let Some(path) = p.get("config") {
        let text = std::fs::read_to_string(path)?;
        *cfg = ExperimentConfig::from_json_str(&text)?;
    }
    Ok(())
}

fn cmd_run(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new("Run one experiment and print the summary")
        .opt("workflow", "montage", "montage|epigenomics|cybershake|ligo")
        .opt("pattern", "constant", "constant|linear|pyramid")
        .opt("policy", "adaptive", "registered policy name[:key=value,...] — see --list-policies")
        .opt("backend", "scalar", "scalar|native|pjrt (ARAS decision math) — see --list-backends")
        .opt("alpha", "0.8", "Eq. (9) scale factor")
        .opt("seed", "42", "workload seed")
        .opt("nodes", "6", "worker node count")
        .opt_null("config", "JSON config file (overrides all other options)")
        .opt_null("trace", "arrival-trace JSON file (replaces --pattern)")
        .opt_null("cluster-events", "cluster-events trace JSON file (node join/drain/crash)")
        .opt_null("chaos-file", "chaos scenario JSON file (fault injection; see EXPERIMENTS.md)")
        .opt_null("autoscale", "autoscaler 'min,max[,mode]' (e.g. 4,12 or 4,12,predictive)")
        .opt_null("forecaster", "demand forecaster name[:key=value,...] — see --list-forecasters")
        .opt_null("slack", "SLA deadline slack factor (enables violation tracking)")
        .opt_null(
            "trace-out",
            "write a schema-validated line-JSON span/event journal to this file",
        )
        .flag("list-policies", "list registered policies and exit")
        .flag("list-forecasters", "list registered forecasters and exit")
        .flag("list-backends", "list decision backends (with availability) and exit")
        .flag("list-routers", "list registered federation routers and exit")
        .flag("chart", "render the usage curve as a terminal chart")
        .flag("verbose", "log engine progress")
        .parse(argv)?;
    if p.flag("list-policies") {
        print!("{}", render_policy_listing());
        return Ok(());
    }
    if p.flag("list-forecasters") {
        print!("{}", render_forecaster_listing());
        return Ok(());
    }
    if p.flag("list-backends") {
        print!("{}", render_backend_listing());
        return Ok(());
    }
    if p.flag("list-routers") {
        print!("{}", render_router_listing());
        return Ok(());
    }
    let mut cfg = ExperimentConfig::default();
    parse_common(&mut cfg, &p)?;
    cfg.sample_interval_s = 5.0;
    if let Some(s) = p.get("slack") {
        cfg.workload.deadline_slack = Some(s.parse()?);
    }
    if let Some(f) = p.get("forecaster") {
        cfg.forecast.forecaster = Some(parse_forecaster(f)?);
    }
    if let Some(path) = p.get("cluster-events") {
        cfg.cluster.events = dynamics::from_file(path)?;
    }
    if let Some(path) = p.get("chaos-file") {
        cfg.chaos = kubeadaptor::chaos::ChaosConfig {
            scenarios: kubeadaptor::chaos::from_file(path)?,
        };
        cfg.chaos.validate()?;
    }
    if let Some(bounds) = p.get("autoscale") {
        let (min, rest) = bounds
            .split_once(',')
            .ok_or_else(|| anyhow::anyhow!("--autoscale wants 'min,max[,mode]'"))?;
        let (max, mode) = match rest.split_once(',') {
            Some((max, mode)) => {
                (max, kubeadaptor::cluster::AutoscalerMode::parse(mode.trim())?)
            }
            None => (rest, kubeadaptor::cluster::AutoscalerMode::Reactive),
        };
        let mut asc = AutoscalerConfig::bounded(min.trim().parse()?, max.trim().parse()?);
        asc.mode = mode;
        cfg.cluster.autoscaler = Some(asc);
    }

    // One wiring point: the registry factory assembles the policy,
    // including the PJRT backend when `--backend pjrt` (the adaptive
    // factory reads `alloc.backend`).
    let policy = registry::build_policy(&cfg.alloc.policy, &cfg.alloc)?;
    let mut engine = match p.get("trace") {
        Some(path) => {
            let bursts = kubeadaptor::workload::trace::from_file(path)?;
            Engine::with_trace(cfg.clone(), policy, bursts, None)?
        }
        None => Engine::with_policy(cfg.clone(), policy)?,
    };
    if p.get("trace-out").is_some() {
        engine.enable_span_trace();
    }
    let outcome = engine.run();

    if let Some(path) = p.get("trace-out") {
        use kubeadaptor::obs::trace::{Journal, TraceEvent, TraceMeta};
        let events: Vec<TraceEvent> = outcome
            .metrics
            .events
            .iter()
            .map(|e| {
                let (kind, detail) = e.kind.name_and_detail();
                TraceEvent {
                    t: e.t,
                    workflow_uid: e.workflow_uid,
                    task_id: e.task_id.to_string(),
                    kind: kind.to_string(),
                    detail,
                }
            })
            .collect();
        let journal = Journal {
            meta: TraceMeta {
                workflow: cfg.workload.workflow.name().to_string(),
                pattern: cfg.workload.pattern.name().to_string(),
                policy: cfg.alloc.policy.label(),
                seed: cfg.workload.seed,
            },
            spans: outcome.spans.clone(),
            events,
        };
        let text = journal.to_jsonl();
        // The journal must survive its own schema check before it is
        // worth writing — a file that does not parse is worse than none.
        let back = Journal::parse(&text)?;
        anyhow::ensure!(back == journal, "trace journal failed round-trip");
        std::fs::write(path, &text)?;
        eprintln!(
            "wrote trace journal {path} ({} spans, {} events)",
            journal.spans.len(),
            journal.events.len()
        );
    }

    let s = &outcome.summary;
    println!("workflow            : {}", cfg.workload.workflow.name());
    println!("pattern             : {}", cfg.workload.pattern.name());
    println!("policy              : {}", cfg.alloc.policy.label());
    println!("workflows completed : {}", s.workflows_completed);
    println!("tasks completed     : {}", s.tasks_completed);
    println!("total duration      : {:.2} min", s.total_duration_min);
    println!("avg workflow dur    : {:.2} min", s.avg_workflow_duration_min);
    println!("cpu usage rate      : {:.3}", s.cpu_usage);
    println!("mem usage rate      : {:.3}", s.mem_usage);
    println!("alloc waits         : {}", s.alloc_waits);
    let below_min = outcome.metrics.count(|k| {
        matches!(k, kubeadaptor::metrics::EventKind::AllocWait { reason } if reason.starts_with("below-min"))
    });
    let unsched = outcome.metrics.count(|k| {
        matches!(k, kubeadaptor::metrics::EventKind::AllocWait { reason } if reason.starts_with("unschedulable"))
    });
    println!("  below-min         : {below_min}");
    println!("  unschedulable     : {unsched}");
    println!("oom events          : {}", s.oom_events);
    if s.evictions > 0 || s.nodes_joined > 0 || s.nodes_removed > 0 {
        println!("evictions           : {}", s.evictions);
        println!("  rescheduled       : {}", outcome.evicted_rescheduled);
        println!("nodes joined/left   : +{}/-{}", s.nodes_joined, s.nodes_removed);
    }
    if cfg.workload.deadline_slack.is_some() {
        println!("sla violations      : {}", s.sla_violations);
    }
    println!("pods created        : {}", outcome.pods_created);
    if !cfg.chaos.is_quiet() {
        println!("chaos scenarios     : {}", cfg.chaos.scenarios.len());
        println!("  hog stolen        : {:.0} cpu·s / {:.0} Mi·s", s.hog_stolen_cpu_s, s.hog_stolen_mem_s);
        println!("  stale snapshots   : {}", s.stale_snapshot_cycles);
        println!("  double-allocs     : {}", s.double_alloc_attempts);
    }

    if p.flag("chart") {
        let cpu: Vec<(f64, f64)> =
            outcome.metrics.samples.iter().map(|s| (s.t, s.cpu_rate)).collect();
        let total = outcome.metrics.arrivals.last().map(|a| a.1).unwrap_or(1) as f64;
        let req: Vec<(f64, f64)> = outcome
            .metrics
            .arrivals
            .iter()
            .map(|&(t, c)| (t, c as f64 / total))
            .collect();
        println!(
            "\n{}",
            kubeadaptor::report::chart::Chart::default()
                .render(&[("cpu usage rate", &cpu), ("requests (cumulative, normalized)", &req)])
        );
    }
    Ok(())
}

fn cmd_campaign(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new(
        "Run a declarative experiment campaign: the sweep grid expands to \
         workflows x patterns x policies x cluster sizes x alphas x reps and \
         executes across an OS-thread worker pool with per-cell derived seeds \
         (byte-identical results at any thread count).",
    )
    .opt("workflows", "all", "comma list or 'all' (montage,epigenomics,cybershake,ligo)")
    .opt("patterns", "all", "comma list or 'all' (constant,linear,pyramid)")
    .opt("policies", "both", "comma list of registry names, 'both' (adaptive,fcfs) or 'all'")
    .opt(
        "backend",
        "scalar",
        "scalar|native|pjrt decision backend for every cell — see run --list-backends",
    )
    .opt("nodes", "6", "comma list of worker-node counts")
    .opt("alphas", "0.8", "comma list of Eq. (9) scale factors")
    .opt(
        "churns",
        "static",
        "';'-separated churn profiles: static | autoscale:min=M,max=N | \
         autoscale-pred:min=M,max=N | drain-storm:start=S,period=P,drains=N | \
         crash-storm:start=S,period=P,crashes=N",
    )
    .opt(
        "forecasters",
        "none",
        "';'-separated forecaster specs or 'none' (e.g. none;seasonal:period=300) \
         — see --list-forecasters",
    )
    .opt(
        "chaos",
        "none",
        "';'-separated chaos profiles: none | cpu-hog:at=A,duration=D,magnitude=M | \
         mem-hog:at=A,duration=D,magnitude=M | io-hog:at=A,duration=D,magnitude=F | \
         latency-storm:at=A,duration=D,magnitude=S | partition:at=A,duration=D",
    )
    .opt(
        "clusters",
        "1",
        "comma list of federation cluster counts (1 = plain single-cluster cell; \
         k > 1 shards the cell across k clusters behind --router)",
    )
    .opt("router", "round-robin", "global router for federated cells — see --list-routers")
    .opt("reps", "1", "repetitions (seed streams) per grid cell")
    .opt("seed", "42", "campaign base seed")
    .opt("threads", "0", "worker threads (0 = one per core)")
    .opt("name", "campaign", "campaign name (report titles, file names)")
    .opt("out", "results/campaign", "output directory")
    .flag("list-policies", "list registered policies and exit")
    .flag("list-forecasters", "list registered forecasters and exit")
    .flag("list-routers", "list registered federation routers and exit")
    .flag("chart", "render the per-cell usage chart to the terminal")
    .flag("verbose", "log engine progress")
    .parse(argv)?;
    if p.flag("list-policies") {
        print!("{}", render_policy_listing());
        return Ok(());
    }
    if p.flag("list-forecasters") {
        print!("{}", render_forecaster_listing());
        return Ok(());
    }
    if p.flag("list-routers") {
        print!("{}", render_router_listing());
        return Ok(());
    }
    if p.flag("verbose") {
        set_level(Level::Info);
    }

    let mut spec = CampaignSpec::default();
    spec.name = p.get_str("name").to_string();
    spec.workflows = match p.get_str("workflows") {
        "all" => WorkflowType::paper_set().to_vec(),
        list => list
            .split(',')
            .map(|s| WorkflowType::parse(s.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?,
    };
    spec.patterns = match p.get_str("patterns") {
        "all" => ArrivalPattern::paper_set().to_vec(),
        list => list
            .split(',')
            .map(|s| ArrivalPattern::parse(s.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?,
    };
    spec.policies = match p.get_str("policies") {
        "both" => vec![PolicySpec::adaptive(), PolicySpec::fcfs()],
        "all" => registry::policy_names().into_iter().map(PolicySpec::named).collect(),
        list => list
            .split(',')
            .map(|s| parse_policy(s.trim()))
            .collect::<anyhow::Result<Vec<_>>>()?,
    };
    spec.cluster_sizes = p
        .get_str("nodes")
        .split(',')
        .map(|s| s.trim().parse::<usize>().map_err(|e| anyhow::anyhow!("--nodes '{s}': {e}")))
        .collect::<anyhow::Result<Vec<_>>>()?;
    spec.alphas = p
        .get_str("alphas")
        .split(',')
        .map(|s| s.trim().parse::<f64>().map_err(|e| anyhow::anyhow!("--alphas '{s}': {e}")))
        .collect::<anyhow::Result<Vec<_>>>()?;
    // Parameterized profiles contain commas (`autoscale:min=4,max=10`),
    // so ';' separates profiles; a ';'-free, ':'-free value is treated
    // as a plain comma list (`static,autoscale`).
    spec.churns = p
        .get_str("churns")
        .split(';')
        .flat_map(|group| {
            if group.contains(':') {
                vec![group]
            } else {
                group.split(',').collect()
            }
        })
        .filter(|s| !s.trim().is_empty())
        .map(ChurnProfile::parse)
        .collect::<anyhow::Result<Vec<_>>>()?;
    // Same ';' framing as --churns (forecaster specs carry commas in
    // their params); 'none' is the forecaster-off axis value.
    spec.forecasters = p
        .get_str("forecasters")
        .split(';')
        .flat_map(|group| {
            if group.contains(':') {
                vec![group]
            } else {
                group.split(',').collect()
            }
        })
        .filter(|s| !s.trim().is_empty())
        .map(|s| {
            if s.trim().eq_ignore_ascii_case("none") {
                Ok(None)
            } else {
                parse_forecaster(s.trim()).map(Some)
            }
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    // Same ';' framing again (profile params carry commas); the chaos
    // axis is excluded from seed derivation, so every profile replays
    // the identical workload.
    spec.chaos = p
        .get_str("chaos")
        .split(';')
        .flat_map(|group| {
            if group.contains(':') {
                vec![group]
            } else {
                group.split(',').collect()
            }
        })
        .filter(|s| !s.trim().is_empty())
        .map(ChaosProfile::parse)
        .collect::<anyhow::Result<Vec<_>>>()?;
    spec.clusters = p
        .get_str("clusters")
        .split(',')
        .map(|s| {
            s.trim().parse::<usize>().map_err(|e| anyhow::anyhow!("--clusters '{s}': {e}"))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    spec.router = parse_router(p.get_str("router"))?;
    spec.reps = p.get_usize("reps")?;
    spec.base_seed = p.get_u64("seed")?;
    spec.threads = p.get_usize("threads")?;
    spec.base.sample_interval_s = 5.0;
    spec.base.alloc.backend = Backend::parse(p.get_str("backend"))?;

    eprintln!(
        "campaign '{}': {} runs ({} workflows x {} patterns x {} policies x {} cluster sizes x {} alphas x {} churns x {} forecasters x {} chaos x {} cluster counts x {} reps)",
        spec.name,
        spec.total_runs(),
        spec.workflows.len(),
        spec.patterns.len(),
        spec.policies.len(),
        spec.cluster_sizes.len(),
        spec.alphas.len(),
        spec.churns.len(),
        spec.forecasters.len(),
        spec.chaos.len(),
        spec.clusters.len(),
        spec.reps,
    );
    let t0 = std::time::Instant::now();
    let result = kubeadaptor::campaign::run(&spec)?;
    let elapsed = t0.elapsed().as_secs_f64();

    let out_dir = Path::new(p.get_str("out"));
    std::fs::create_dir_all(out_dir)?;
    let summary_path = out_dir.join(format!("{}_summary.csv", spec.name));
    report::campaign::summary_csv(&result).write_file(&summary_path)?;
    let rows = result.comparison();
    let comparison_path = out_dir.join(format!("{}_comparison.csv", spec.name));
    report::campaign::comparison_csv(&rows).write_file(&comparison_path)?;
    let md = report::campaign::render_markdown(&result, &rows);
    let report_path = out_dir.join(format!("{}_report.md", spec.name));
    std::fs::write(&report_path, &md)?;

    println!("{md}");
    if p.flag("chart") {
        println!("{}", report::campaign::usage_chart(&rows));
    }
    eprintln!(
        "ran {} runs on {} threads in {elapsed:.1}s",
        result.runs.len(),
        result.threads_used
    );
    for path in [&summary_path, &comparison_path, &report_path] {
        eprintln!("wrote {}", path.display());
    }
    Ok(())
}

fn cmd_table2(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new("Regenerate Table 2 (4 workflows x 3 patterns x 2 policies)")
        .opt("reps", "3", "repetitions per combination")
        .opt("seed", "42", "campaign base seed (each rep derives its own stream)")
        .opt("out", "results/table2.md", "output markdown path")
        .parse(argv)?;
    let reps = p.get_usize("reps")?;
    let seed = p.get_u64("seed")?;
    eprintln!("running {} combinations x {reps} reps ...", table2::combinations().len());
    let t0 = std::time::Instant::now();
    let entries = table2::run(reps, seed)?;
    let md = format!("{}{}", report::render_table2(&entries), report::render_savings(&entries));
    let out_path = p.get_str("out").to_string();
    if let Some(parent) = Path::new(&out_path).parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&out_path, &md)?;
    println!("{md}");
    eprintln!("wrote {out_path} in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_figures(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new("Regenerate figure data (Fig 1 gantt, Figs 5-8 usage curves, Fig 4 DOT)")
        .opt_null("fig", "figure number (1, 4, 5, 6, 7, 8)")
        .opt("seed", "42", "workload seed")
        .opt("out", "results", "output directory")
        .flag("all", "generate every figure")
        .parse(argv)?;
    let out_dir = Path::new(p.get_str("out")).to_path_buf();
    std::fs::create_dir_all(&out_dir)?;
    let seed = p.get_u64("seed")?;
    let figs: Vec<u32> = if p.flag("all") {
        vec![1, 4, 5, 6, 7, 8]
    } else {
        vec![p.get_u64("fig").map_err(|_| anyhow::anyhow!("--fig N or --all required"))? as u32]
    };
    for fig in figs {
        match fig {
            1 => {
                let out = fig1::run(seed, &out_dir)?;
                println!("Fig 1 — Montage(21) execution timeline under ARAS\n{}", out.gantt);
                println!("wrote {}", out.csv_path);
            }
            4 => {
                for kind in WorkflowType::paper_set() {
                    let dot = topologies::build(kind).to_dot();
                    let path = out_dir.join(format!("fig4_{}.dot", kind.name()));
                    std::fs::write(&path, dot)?;
                    println!("wrote {}", path.display());
                }
            }
            5..=8 => {
                let kind = match fig {
                    5 => WorkflowType::Montage,
                    6 => WorkflowType::Epigenomics,
                    7 => WorkflowType::CyberShake,
                    _ => WorkflowType::Ligo,
                };
                for path in usage_curves::run(kind, seed, &out_dir)? {
                    println!("wrote {path}");
                }
            }
            other => anyhow::bail!("no figure {other} (1, 4, 5-8)"),
        }
    }
    Ok(())
}

fn cmd_oom(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new("Fig. 9 — resource-allocation failure + self-healing evaluation")
        .opt("seed", "42", "workload seed")
        .opt("out", "results", "output directory")
        .parse(argv)?;
    let out_dir = Path::new(p.get_str("out")).to_path_buf();
    std::fs::create_dir_all(&out_dir)?;
    let out = oom::run(p.get_u64("seed")?, &out_dir)?;
    println!("OOMKilled events    : {}", out.oom_events);
    println!("reallocations       : {}", out.reallocations);
    println!("workflows completed : {}/10", out.workflows_completed);
    if let Some((alloc_t, oom_t, realloc_t, complete_t)) = out.first_lifecycle {
        println!("first OOM lifecycle : alloc@{alloc_t:.0}s -> OOMKilled@{oom_t:.0}s -> Reallocation@{realloc_t:.0}s -> complete@{complete_t:.0}s");
    }
    println!("wrote {}", out.csv_path);
    Ok(())
}

fn cmd_churn(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new(
        "Cluster-dynamics evaluation: ARAS vs FCFS on identical workloads \
         across static, drain-storm and autoscaled clusters",
    )
    .opt("seed", "42", "campaign base seed")
    .opt("out", "results", "output directory")
    .parse(argv)?;
    let out_dir = Path::new(p.get_str("out")).to_path_buf();
    let out = churn::run(p.get_u64("seed")?, &out_dir)?;
    println!("{}", out.report);
    for r in &out.rows {
        anyhow::ensure!(
            r.pods_evicted == r.evicted_rescheduled + r.evicted_unresolved as u64,
            "eviction accounting broken in cell {}/{}",
            r.churn,
            r.policy
        );
    }
    println!("wrote {}", out.csv_path);
    Ok(())
}

fn cmd_forecast(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new(
        "Forecast evaluation: reactive vs predictive — plain ARAS vs the \
         forecast-augmented policy, and a queue-trailing vs look-ahead \
         autoscaler — on workload-paired cells under the paper's arrival \
         patterns, with per-resource forecast accuracy (MAPE/RMSE)",
    )
    .opt("seed", "42", "campaign base seed")
    .opt("out", "results", "output directory")
    .flag("quick", "tiny grid (CI smoke): one truncated constant pattern")
    .flag("list-forecasters", "list registered forecasters and exit")
    .parse(argv)?;
    if p.flag("list-forecasters") {
        print!("{}", render_forecaster_listing());
        return Ok(());
    }
    let out_dir = Path::new(p.get_str("out")).to_path_buf();
    let seed = p.get_u64("seed")?;
    let spec = if p.flag("quick") {
        forecast::spec_with(seed, vec![ArrivalPattern::Constant { per_burst: 3, bursts: 2 }])
    } else {
        forecast::spec(seed)
    };
    let out = forecast::run_spec(&spec, &out_dir)?;
    println!("{}", out.report);
    for r in &out.rows {
        anyhow::ensure!(
            r.forecast_points > 0,
            "forecast accuracy ledger empty in cell {}/{}",
            r.churn,
            r.policy
        );
    }
    println!("wrote {}", out.csv_path);
    Ok(())
}

fn cmd_chaos(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new(
        "Chaos evaluation: the forecast grid (adaptive vs predictive \
         allocation x reactive vs predictive autoscaling) crossed with a \
         fault axis — noisy-neighbor hog, informer latency storm, \
         informer partition — every fault cell workload-paired with its \
         quiet twin so the deltas are pure fault impact",
    )
    .opt("seed", "42", "campaign base seed")
    .opt("out", "results", "output directory")
    .flag("quick", "tiny grid (CI smoke): one truncated constant pattern")
    .parse(argv)?;
    let out_dir = Path::new(p.get_str("out")).to_path_buf();
    let seed = p.get_u64("seed")?;
    let spec = if p.flag("quick") {
        chaos::spec_with(seed, vec![ArrivalPattern::Constant { per_burst: 3, bursts: 2 }])
    } else {
        chaos::spec(seed)
    };
    // run_spec enforces the experiment invariants (quiet cells clean,
    // hog cells stole, partition cells went stale) before reporting.
    let out = chaos::run_spec(&spec, &out_dir)?;
    println!("{}", out.report);
    println!("wrote {}", out.csv_path);
    Ok(())
}

fn cmd_federate(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new(
        "Multi-cluster federation evaluation: every registered router \
         places an identical workload across heterogeneous sharded \
         clusters under skewed traffic, capacity asymmetry, and a \
         regional outage (one cluster dark from t = 0). Per-cell \
         placements, spillovers and durations land in \
         federate_summary.csv; the ka_fed_* Prometheus exposition of the \
         skewed forecast-headroom run lands next to it.",
    )
    .opt("seed", "42", "base workload seed (per-cluster seeds derive from it)")
    .opt("out", "results/federate", "output directory")
    .opt("threads", "0", "worker threads across federations (0 = one per core)")
    .flag("quick", "tiny arrival streams (CI smoke)")
    .flag("list-routers", "list registered federation routers and exit")
    .parse(argv)?;
    if p.flag("list-routers") {
        print!("{}", render_router_listing());
        return Ok(());
    }
    let out_dir = Path::new(p.get_str("out")).to_path_buf();
    let t0 = std::time::Instant::now();
    let out = federate::run(p.get_u64("seed")?, p.flag("quick"), p.get_usize("threads")?, &out_dir)?;
    println!("{}", out.report);
    for r in &out.rows {
        anyhow::ensure!(
            r.placements.iter().map(|&(_, n)| n).sum::<usize>() == r.routed,
            "placement accounting broken in cell {}/{}",
            r.scenario,
            r.router
        );
    }
    eprintln!("ran {} federations in {:.1}s", out.rows.len(), t0.elapsed().as_secs_f64());
    println!("wrote {}", out.csv_path);
    println!("wrote {}", out.metrics_path);
    Ok(())
}

fn cmd_bench(argv: &[String]) -> anyhow::Result<()> {
    use kubeadaptor::resources::adaptive::{DecisionBackend, DecisionInputs, ScalarBackend};
    use kubeadaptor::runtime::NativeBackend;
    use kubeadaptor::simcore::Rng;
    use kubeadaptor::util::bench::bench;
    use kubeadaptor::util::json::Json;

    let p = Args::new(
        "Perf baseline: ARAS allocator ns/decision (scalar per-item vs \
         native full-lane batched, 128 usage records) and end-to-end \
         engine throughput (tasks/sec, 1000-node cluster). The committed \
         BENCH_baseline.json is regenerated with: cargo run --release -- bench",
    )
    .opt("out", "BENCH_baseline.json", "output JSON path")
    .opt_null("trajectory", "append a compact JSONL perf point to this file (per-PR history)")
    .opt("label", "dev", "trajectory point label (e.g. 'pr9')")
    .flag("smoke", "tiny sample counts (CI harness check, not a perf run)")
    .parse(argv)?;
    let smoke = p.flag("smoke");

    // Allocator hot path: the ARAS decision (Algorithms 1-3) at the
    // mid-scale record count from the microbench sweep.
    let mut rng = Rng::new(99);
    let input = DecisionInputs {
        records: (0..128)
            .map(|_| {
                (
                    rng.range_inclusive(0, 1000) as f32,
                    rng.range_inclusive(100, 4000) as f32,
                    rng.range_inclusive(100, 8000) as f32,
                )
            })
            .collect(),
        win_start: 100.0,
        win_end: 400.0,
        req_cpu: 2000.0,
        req_mem: 4000.0,
        node_res: (0..6)
            .map(|_| (rng.range_inclusive(0, 8000) as f32, rng.range_inclusive(0, 16384) as f32))
            .collect(),
        alpha: 0.8,
    };
    let mut backend = ScalarBackend;
    let (warmup, samples) = if smoke { (10, 50) } else { (200, 5000) };
    let alloc = bench("allocator/scalar_decide_128_records", warmup, samples, || {
        std::hint::black_box(backend.decide(&input));
    });
    let ns_per_decision = alloc.summary.mean * 1e6;

    // Batched decisions: one queue-serve cycle's worth of requests
    // sharing a single store/node view (the lane-filling fast path).
    // Scalar serves the batch per item; native fills all cap_batch
    // lanes of one fused execution — the raw-speed bet this baseline
    // makes checkable. Lanes get divergent windows on purpose: since
    // the cross-lane fold fix that is the general (and once-corrupted)
    // case, and with 128 records it stays on the chunked path.
    let mut native = NativeBackend::load_default()?;
    let lanes = native.capacities().2;
    let batch: Vec<DecisionInputs> = (0..lanes)
        .map(|lane| DecisionInputs {
            win_start: (lane * 60) as f32,
            win_end: (lane * 60 + 300) as f32,
            req_cpu: 500.0 + (lane as f32) * 250.0,
            req_mem: 1000.0 + (lane as f32) * 500.0,
            ..input.clone()
        })
        .collect();
    let scalar_batch = bench(
        &format!("allocator/scalar_batch_{lanes}_lanes_128_records"),
        warmup,
        samples,
        || {
            std::hint::black_box(backend.decide_batch(&batch));
        },
    );
    let native_batch = bench(
        &format!("allocator/native_batch_{lanes}_lanes_128_records"),
        warmup,
        samples,
        || {
            std::hint::black_box(native.decide_batch(&batch));
        },
    );
    let scalar_batch_ns = scalar_batch.summary.mean * 1e6 / lanes as f64;
    let native_batch_ns = native_batch.summary.mean * 1e6 / lanes as f64;
    let batch_speedup = scalar_batch_ns / native_batch_ns.max(1e-9);

    // Engine throughput: the full MAPE-K loop on a 1000-node cluster.
    // Each sample builds and runs a fresh engine on the identical
    // deterministic workload, so the figure is end-to-end (setup
    // included) tasks per wall-clock second.
    let mut cfg = ExperimentConfig::default();
    cfg.cluster.nodes = 1000;
    cfg.workload.pattern = if smoke {
        ArrivalPattern::Constant { per_burst: 2, bursts: 1 }
    } else {
        ArrivalPattern::Constant { per_burst: 10, bursts: 3 }
    };
    cfg.sample_interval_s = 5.0;
    let run_once = |cfg: &ExperimentConfig| -> anyhow::Result<usize> {
        let policy = registry::build_policy(&cfg.alloc.policy, &cfg.alloc)?;
        Ok(Engine::with_policy(cfg.clone(), policy)?.run().summary.tasks_completed)
    };
    let tasks = run_once(&cfg)?;
    anyhow::ensure!(tasks > 0, "engine bench completed no tasks");
    let (e_warmup, e_samples) = if smoke { (0, 1) } else { (1, 5) };
    let eng = bench("engine/montage_constant_1000_nodes", e_warmup, e_samples, || {
        std::hint::black_box(run_once(&cfg).expect("engine bench run"));
    });
    let tasks_per_sec = tasks as f64 / (eng.summary.mean / 1e3);

    // Cycle-phase attribution: one additional run with wall-clock spans
    // enabled (strictly opt-in — wall time never reaches golden output)
    // so the baseline records *where* engine wall time goes.
    let phases = {
        let policy = registry::build_policy(&cfg.alloc.policy, &cfg.alloc)?;
        let mut engine = Engine::with_policy(cfg.clone(), policy)?;
        engine.enable_wall_clock_obs();
        engine.run().summary.phases
    };
    let ns_to_ms = |ns: u64| ns as f64 / 1e6;

    // Serve-cycle snapshot path: full ResidualMap rebuild vs incremental
    // delta maintenance under steady pod churn — the daemon hot loop.
    // Each timed cycle mutates two pods, drains the watch, and produces
    // a snapshot; full mode re-folds every pod, incremental applies the
    // two deltas.
    use kubeadaptor::cluster::{Informer, Node, ObjectStore, Pod, PodPhase};
    use kubeadaptor::resources::discover;
    use kubeadaptor::resources::discovery::IncrementalDiscovery;
    const PODS_PER_NODE: usize = 4;
    fn snapshot_store(nodes: usize) -> (ObjectStore, u64) {
        let mut store = ObjectStore::new();
        for i in 0..nodes {
            store.add_node(Node::new(i, 16000, 32768));
        }
        let mut uid = 0u64;
        for _ in 0..PODS_PER_NODE {
            for node in 0..nodes {
                store.create_pod(snapshot_pod(uid, node, nodes));
                uid += 1;
            }
        }
        (store, uid)
    }
    fn snapshot_pod(uid: u64, node: usize, nodes: usize) -> Pod {
        Pod {
            uid,
            name: format!("bench-p{uid}"),
            namespace: "bench".into(),
            task_id: format!("bench-t{uid}"),
            phase: PodPhase::Running,
            node: Some(format!("node-{}", node % nodes)),
            request_cpu: 500 + (uid % 7) as i64 * 100,
            request_mem: 1000 + (uid % 5) as i64 * 200,
            min_mem: 500,
            duration: 60.0,
            created_at: 0.0,
            started_at: Some(0.0),
            finished_at: None,
        }
    }
    let sizes: &[usize] = if smoke { &[100] } else { &[1_000, 10_000] };
    let (s_warmup, s_samples) = if smoke { (2, 10) } else { (20, 200) };
    let mut snapshot_docs: Vec<Json> = Vec::new();
    for &nodes in sizes {
        let (mut store, mut next_uid) = snapshot_store(nodes);
        let mut inf = Informer::new();
        inf.sync(&store);
        let mut del = 0u64;
        let full = bench(
            &format!("snapshot/full_rebuild_{nodes}_nodes"),
            s_warmup,
            s_samples,
            || {
                store.delete_pod(del);
                store.create_pod(snapshot_pod(next_uid, next_uid as usize, nodes));
                del += 1;
                next_uid += 1;
                inf.sync(&store);
                std::hint::black_box(discover(&inf).total_cpu());
            },
        );

        let (mut store, mut next_uid) = snapshot_store(nodes);
        let mut inf = Informer::new();
        inf.sync(&store);
        let mut inc = IncrementalDiscovery::prime(&inf);
        let mut del = 0u64;
        let delta = bench(
            &format!("snapshot/incremental_delta_{nodes}_nodes"),
            s_warmup,
            s_samples,
            || {
                store.delete_pod(del);
                store.create_pod(snapshot_pod(next_uid, next_uid as usize, nodes));
                del += 1;
                next_uid += 1;
                for (_, ev) in inf.sync_events(&store) {
                    inc.apply(&ev, &inf);
                }
                std::hint::black_box(inc.residuals(&inf).total_cpu());
            },
        );

        let speedup = full.summary.mean / delta.summary.mean.max(1e-9);
        println!(
            "snapshot ({nodes} nodes) : full {:.3} ms vs incremental {:.3} ms ({speedup:.1}x)",
            full.summary.mean, delta.summary.mean
        );
        snapshot_docs.push(Json::obj(vec![
            ("nodes", Json::num(nodes as f64)),
            ("pods", Json::num((nodes * PODS_PER_NODE) as f64)),
            ("full_ms_mean", Json::num(full.summary.mean)),
            ("full_ms_p50", Json::num(full.summary.p50)),
            ("incremental_ms_mean", Json::num(delta.summary.mean)),
            ("incremental_ms_p50", Json::num(delta.summary.p50)),
            ("speedup", Json::num(speedup)),
            ("samples", Json::num(full.summary.n as f64)),
        ]));
    }

    // Federation routing hot path: one forecast-headroom ranking over a
    // synthetic federation snapshot, at a small and a wide member count
    // — the per-workflow cost the global router adds to a submission.
    use kubeadaptor::federation::{ForecastHeadroomRouter, RouteInput, Router};
    use kubeadaptor::forecast::DemandForecast;
    let mut router_docs: Vec<Json> = Vec::new();
    let mut router16_ns = 0.0;
    for &clusters in &[4usize, 16] {
        let inputs: Vec<RouteInput> = (0..clusters)
            .map(|i| RouteInput {
                cluster: i,
                name: format!("c{i}"),
                weight: 1.0 + (i % 3) as f64,
                queue_depth: i % 5,
                stale_rate: 0.01 * i as f64,
                capacity_cpu: 48_000.0,
                capacity_mem: 61_440.0,
                residual_cpu: 48_000.0 - 1_500.0 * (i % 7) as f64,
                residual_mem: 61_440.0 - 2_000.0 * (i % 7) as f64,
                forecast: Some(DemandForecast {
                    horizon_s: 60.0,
                    cpu_demand: 4_000.0 + 500.0 * i as f64,
                    mem_demand: 8_000.0 + 700.0 * i as f64,
                    queue_len: (i % 5) as f64,
                    arrival_rate: 0.05,
                }),
            })
            .collect();
        let mut router = ForecastHeadroomRouter::new(0.05)?;
        let (r_warmup, r_samples) = if smoke { (10, 50) } else { (500, 20_000) };
        let res = bench(
            &format!("router/forecast_headroom_rank_{clusters}_clusters"),
            r_warmup,
            r_samples,
            || {
                std::hint::black_box(router.rank(&inputs));
            },
        );
        let ns = res.summary.mean * 1e6;
        if clusters == 16 {
            router16_ns = ns;
        }
        println!("router ({clusters:>2} clusters): {ns:.0} ns/routing-decision");
        router_docs.push(Json::obj(vec![
            ("clusters", Json::num(clusters as f64)),
            ("ns_per_decision", Json::num(ns)),
            ("samples", Json::num(res.summary.n as f64)),
        ]));
    }

    let doc = Json::obj(vec![
        // Mirrors the golden-trace lifecycle: the committed baseline
        // starts as a bootstrap marker; a generated file is real data.
        ("bootstrap", Json::Bool(false)),
        ("command", Json::str("cargo run --release -- bench --out BENCH_baseline.json")),
        ("smoke", Json::Bool(smoke)),
        (
            "allocator",
            Json::obj(vec![
                ("name", Json::str(&alloc.name)),
                ("mean_ms", Json::num(alloc.summary.mean)),
                ("p50_ms", Json::num(alloc.summary.p50)),
                ("p99_ms", Json::num(alloc.summary.p99)),
                ("samples", Json::num(alloc.summary.n as f64)),
                ("ns_per_decision", Json::num(ns_per_decision)),
            ]),
        ),
        (
            "batched",
            Json::obj(vec![
                ("name", Json::str("allocator/batched_scalar_vs_native")),
                ("lanes", Json::num(lanes as f64)),
                ("records", Json::num(128.0)),
                ("scalar_ns_per_decision", Json::num(scalar_batch_ns)),
                ("native_ns_per_decision", Json::num(native_batch_ns)),
                ("speedup", Json::num(batch_speedup)),
                ("samples", Json::num(native_batch.summary.n as f64)),
            ]),
        ),
        (
            "engine",
            Json::obj(vec![
                ("name", Json::str(&eng.name)),
                ("nodes", Json::num(1000.0)),
                ("tasks_completed", Json::num(tasks as f64)),
                ("wall_ms_mean", Json::num(eng.summary.mean)),
                ("wall_ms_p50", Json::num(eng.summary.p50)),
                ("samples", Json::num(eng.summary.n as f64)),
                ("tasks_per_sec", Json::num(tasks_per_sec)),
                (
                    "phases",
                    Json::obj(vec![
                        ("serve_cycles", Json::num(phases.serve_cycles as f64)),
                        ("plan_calls", Json::num(phases.plan_calls as f64)),
                        ("schedule_calls", Json::num(phases.schedule_calls as f64)),
                        ("snapshot_applies", Json::num(phases.snapshot_applies as f64)),
                        ("serve_ms", Json::num(ns_to_ms(phases.serve_wall_ns))),
                        ("plan_ms", Json::num(ns_to_ms(phases.plan_wall_ns))),
                        ("schedule_ms", Json::num(ns_to_ms(phases.schedule_wall_ns))),
                        ("snapshot_ms", Json::num(ns_to_ms(phases.snapshot_wall_ns))),
                    ]),
                ),
            ]),
        ),
        ("snapshot", Json::Arr(snapshot_docs)),
        ("router", Json::Arr(router_docs)),
    ]);
    let out_path = p.get_str("out");
    if let Some(parent) = Path::new(out_path).parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(out_path, format!("{}\n", doc.to_string_pretty()))?;
    println!("allocator           : {:.0} ns/decision ({} samples)", ns_per_decision, alloc.summary.n);
    println!(
        "batched ({lanes} lanes)    : scalar {scalar_batch_ns:.0} vs native {native_batch_ns:.0} \
         ns/decision ({batch_speedup:.2}x)"
    );
    println!("engine (1k nodes)   : {tasks_per_sec:.0} tasks/sec ({tasks} tasks, {:.0} ms/run)", eng.summary.mean);
    println!(
        "cycle phases        : plan {:.2} ms, schedule {:.2} ms, snapshot {:.2} ms \
         over {} serve cycles",
        ns_to_ms(phases.plan_wall_ns),
        ns_to_ms(phases.schedule_wall_ns),
        ns_to_ms(phases.snapshot_wall_ns),
        phases.serve_cycles,
    );
    println!("wrote {out_path}");

    if let Some(traj_path) = p.get("trajectory") {
        // One compact line per invocation: the committed perf history
        // (per-PR), greppable and parseable without tooling.
        let point = Json::obj(vec![
            ("label", Json::str(p.get_str("label"))),
            ("smoke", Json::Bool(smoke)),
            ("ns_per_decision", Json::num(ns_per_decision)),
            ("native_batch_ns_per_decision", Json::num(native_batch_ns)),
            ("batch_speedup", Json::num(batch_speedup)),
            ("tasks_per_sec", Json::num(tasks_per_sec)),
            ("router16_ns_per_decision", Json::num(router16_ns)),
            ("serve_ms", Json::num(ns_to_ms(phases.serve_wall_ns))),
            ("plan_ms", Json::num(ns_to_ms(phases.plan_wall_ns))),
            ("schedule_ms", Json::num(ns_to_ms(phases.schedule_wall_ns))),
            ("snapshot_ms", Json::num(ns_to_ms(phases.snapshot_wall_ns))),
        ]);
        use std::io::Write as _;
        let mut f =
            std::fs::OpenOptions::new().create(true).append(true).open(traj_path)?;
        writeln!(f, "{}", point.to_string_compact())?;
        println!("appended trajectory point '{}' to {traj_path}", p.get_str("label"));
    }
    Ok(())
}

fn cmd_ablate(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new("Ablations: --param alpha|lookahead|nodes")
        .opt("param", "alpha", "which ablation to run")
        .opt("seed", "42", "workload seed")
        .parse(argv)?;
    let seed = p.get_u64("seed")?;
    let (rows, title) = match p.get_str("param") {
        "alpha" => (ablation::alpha_sweep(seed)?, "alpha (Eq. 9 scale factor)"),
        "lookahead" => (ablation::lookahead_ablation(seed)?, "lifecycle lookahead"),
        "nodes" => (ablation::node_sweep(seed)?, "cluster size"),
        other => anyhow::bail!("unknown ablation '{other}'"),
    };
    println!("{}", ablation::render(&rows, title));
    Ok(())
}

fn cmd_export_trace(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new("Export a synthetic arrival pattern as a replayable JSON trace")
        .opt("pattern", "constant", "constant|linear|pyramid")
        .opt("interval", "300", "seconds between bursts")
        .parse(argv)?;
    let pattern = ArrivalPattern::parse(p.get_str("pattern"))?;
    let bursts = kubeadaptor::workload::schedule(&pattern, p.get_f64("interval")?)?;
    println!("{}", kubeadaptor::workload::trace::to_json(&bursts));
    Ok(())
}

fn cmd_daemon(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new(
        "Run the engine as a long-lived daemon: live workflow ingest over a \
         line-JSON socket protocol, schedule-DSL submission sources, hot \
         policy/forecaster swap, drain-to-summary. See ARCHITECTURE.md \
         §Daemon mode.",
    )
    .opt("listen", "unix:/tmp/kubeadaptor.sock", "unix:<path> or tcp:<host>:<port>")
    .opt("policy", "adaptive", "allocation policy — see run --list-policies")
    .opt("backend", "scalar", "scalar|native|pjrt decision backend — see run --list-backends")
    .opt("snapshots", "incremental", "serve-cycle snapshots: full|incremental|verify")
    .opt("alpha", "0.8", "Eq. (9) scale factor")
    .opt("seed", "42", "workload seed (fixes the workflow templates)")
    .opt("nodes", "6", "worker node count")
    .opt_null("pace", "virtual seconds per wall-clock second (default: free-running)")
    .opt_null("forecaster", "demand forecaster — see run --list-forecasters")
    .opt_null(
        "schedule",
        "submission source '<dsl>;<workflow>[;<count>]', e.g. 'every 5m;montage;2'",
    )
    .opt_null("config", "JSON config file (overrides all other options)")
    .flag("hold", "queue submissions without starting; 'drain' starts the run")
    .flag("verbose", "log engine progress")
    .parse(argv)?;

    let mut cfg = ExperimentConfig::default();
    if p.flag("verbose") {
        set_level(Level::Info);
    }
    if let Some(path) = p.get("config") {
        cfg = ExperimentConfig::from_json_str(&std::fs::read_to_string(path)?)?;
    } else {
        cfg.alloc.policy = parse_policy(p.get_str("policy"))?;
        cfg.alloc.backend = Backend::parse(p.get_str("backend"))?;
        cfg.alloc.alpha = p.get_f64("alpha")?;
        cfg.workload.seed = p.get_u64("seed")?;
        cfg.cluster.nodes = p.get_usize("nodes")?;
        cfg.snapshot_mode = kubeadaptor::config::SnapshotMode::parse(p.get_str("snapshots"))?;
        if let Some(f) = p.get("forecaster") {
            cfg.forecast.forecaster = Some(parse_forecaster(f)?);
        }
        let mut dcfg = kubeadaptor::config::DaemonConfig {
            listen: p.get_str("listen").to_string(),
            pace: match p.get("pace") {
                Some(_) => Some(p.get_f64("pace")?),
                None => None,
            },
            hold: p.flag("hold"),
            sources: Vec::new(),
        };
        if let Some(src) = p.get("schedule") {
            let mut parts = src.splitn(3, ';');
            let dsl = parts.next().unwrap_or_default().trim().to_string();
            let workflow = WorkflowType::parse(
                parts
                    .next()
                    .ok_or_else(|| {
                        anyhow::anyhow!("--schedule wants '<dsl>;<workflow>[;<count>]', got '{src}'")
                    })?
                    .trim(),
            )?;
            let count = match parts.next() {
                Some(n) => n.trim().parse::<usize>().map_err(|_| {
                    anyhow::anyhow!("bad count in --schedule '{src}'")
                })?,
                None => 1,
            };
            dcfg.sources.push(kubeadaptor::config::ScheduleSource {
                schedule: dsl,
                workflow,
                count,
            });
        }
        cfg.daemon = Some(dcfg);
    }
    let listen = cfg.daemon.as_ref().map(|d| d.listen.clone()).unwrap_or_default();
    eprintln!("daemon listening on {listen} (send {{\"cmd\":\"shutdown\"}} to stop)");
    match kubeadaptor::daemon::serve(cfg)? {
        Some(outcome) => {
            let s = &outcome.summary;
            println!("state               : drained");
            println!("workflows completed : {}", s.workflows_completed);
            println!("tasks completed     : {}", s.tasks_completed);
            println!("total duration      : {:.2} min", s.total_duration_min);
            println!("cpu usage rate      : {:.3}", s.cpu_usage);
            println!("mem usage rate      : {:.3}", s.mem_usage);
            println!("submissions served  : {}", outcome.metrics.submissions.len());
        }
        None => println!("state               : stopped without drain"),
    }
    Ok(())
}

fn cmd_client(argv: &[String]) -> anyhow::Result<()> {
    use kubeadaptor::daemon::client::Client;
    use kubeadaptor::daemon::protocol::Request;

    let p = Args::new("Send one command to a running daemon and print the JSON reply")
        .opt("addr", "unix:/tmp/kubeadaptor.sock", "daemon address (unix:<path>|tcp:<host>:<port>)")
        .opt(
            "cmd",
            "status",
            "submit|status|metrics|list-policies|list-forecasters|swap-policy|\
             swap-forecaster|drain|shutdown",
        )
        .opt("workflow", "montage", "workflow to submit")
        .opt("count", "1", "instances per submission")
        .opt_null("at", "virtual submission time (submit; default: now)")
        .opt_null("schedule", "schedule DSL (submit), e.g. 'every 5m' or 'at 60 repeat 10'")
        .opt_null("policy", "policy for swap-policy")
        .opt_null("forecaster", "forecaster for swap-forecaster (omit to disable forecasting)")
        .opt_null("wait-state", "after the command, poll status until this state (e.g. completed)")
        .opt("timeout", "30", "seconds to wait for connect / --wait-state")
        .parse(argv)?;

    let timeout = std::time::Duration::from_secs_f64(p.get_f64("timeout")?);
    let req = match p.get_str("cmd") {
        "submit" => {
            let workflow = WorkflowType::parse(p.get_str("workflow"))?;
            let count = p.get_usize("count")?;
            match p.get("schedule") {
                Some(dsl) => {
                    Request::Schedule { schedule: dsl.to_string(), workflow, count }
                }
                None => Request::Submit {
                    workflow,
                    count,
                    at: match p.get("at") {
                        Some(_) => Some(p.get_f64("at")?),
                        None => None,
                    },
                },
            }
        }
        "status" => Request::Status,
        "metrics" => Request::Metrics,
        "list-policies" => Request::ListPolicies,
        "list-forecasters" => Request::ListForecasters,
        "swap-policy" => Request::SwapPolicy {
            policy: p
                .get("policy")
                .ok_or_else(|| anyhow::anyhow!("swap-policy wants --policy <name>"))?
                .to_string(),
        },
        "swap-forecaster" => Request::SwapForecaster {
            forecaster: p.get("forecaster").map(|s| s.to_string()),
        },
        "drain" => Request::Drain,
        "shutdown" => Request::Shutdown,
        other => anyhow::bail!("unknown client cmd '{other}' (see --help)"),
    };
    let mut client = Client::connect_with_retry(p.get_str("addr"), timeout)?;
    let reply = client.request(&req)?;
    // Prometheus exposition is text, not JSON — print it raw so the
    // output can be scraped or piped into promtool as-is.
    if let Request::Metrics = req {
        use kubeadaptor::util::json::Json;
        match reply.get("metrics").and_then(Json::as_str) {
            Some(text) => print!("{text}"),
            None => println!("{}", reply.to_string_pretty()),
        }
    } else {
        println!("{}", reply.to_string_pretty());
    }
    if let Some(want) = p.get("wait-state") {
        let doc = client.wait_for_state(want, timeout)?;
        println!("{}", doc.to_string_pretty());
    }
    Ok(())
}

fn cmd_dag(argv: &[String]) -> anyhow::Result<()> {
    let p = Args::new("Dump a workflow topology as Graphviz DOT")
        .opt("workflow", "montage", "montage|epigenomics|cybershake|ligo")
        .parse(argv)?;
    let kind = WorkflowType::parse(p.get_str("workflow"))?;
    let spec = topologies::build(kind);
    println!("{}", spec.to_dot());
    eprintln!(
        "# {} tasks, depth {}, max width {}",
        spec.tasks.len(),
        spec.depth(),
        spec.max_width()
    );
    Ok(())
}
