//! Tiny CSV writer for figure data series (Figs 1, 5–9).

use std::fmt::Write as _;
use std::fs;
use std::io;
use std::path::Path;

/// Accumulates rows and writes an RFC-4180-ish CSV file.
pub struct CsvWriter {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvWriter {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn row_f64(&mut self, cells: &[f64]) {
        self.row(&cells.iter().map(|v| format!("{v}")).collect::<Vec<_>>());
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.header.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|s| esc(s)).collect::<Vec<_>>().join(","));
        }
        out
    }

    pub fn write_file(&self, path: impl AsRef<Path>) -> io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            fs::create_dir_all(parent)?;
        }
        fs::write(path, self.to_string())
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_header_and_rows() {
        let mut w = CsvWriter::new(&["t", "cpu"]);
        w.row_f64(&[0.0, 0.25]);
        w.row_f64(&[1.0, 0.5]);
        let s = w.to_string();
        assert_eq!(s, "t,cpu\n0,0.25\n1,0.5\n");
    }

    #[test]
    fn escapes_commas_and_quotes() {
        let mut w = CsvWriter::new(&["name"]);
        w.row(&["a,b\"c".to_string()]);
        assert!(w.to_string().contains("\"a,b\"\"c\""));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut w = CsvWriter::new(&["a", "b"]);
        w.row(&["only-one".to_string()]);
    }
}
