//! Minimal JSON parser + emitter (serde replacement).
//!
//! Used for the AOT `manifest.json`, workflow definition files, experiment
//! configs and machine-readable reports. Supports the full JSON grammar
//! except unicode escapes beyond BMP surrogate pairs (which we reject
//! loudly rather than mis-decode).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) for stable output.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|f| f as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Path access: `j.at(&["capacities", "tasks"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(out)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        if (0xD800..0xE000).contains(&cp) {
                            return Err(self.err("surrogate escapes unsupported"));
                        }
                        s.push(char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a":[1,2,{"b":"c"}],"d":{}}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.at(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"name":"montage","tasks":[{"id":1,"cpu":2000},{"id":2,"cpu":null}],"ok":true}"#;
        let j = Json::parse(src).unwrap();
        let again = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, again);
        let again2 = Json::parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, again2);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_content() {
        let j = Json::parse(r#""héllo é""#).unwrap();
        assert_eq!(j.as_str(), Some("héllo é"));
    }

    #[test]
    fn integer_output_has_no_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.5).to_string_compact(), "3.5");
    }
}
