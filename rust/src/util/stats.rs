//! Descriptive statistics used by metrics, reports and the bench harness.

/// Arithmetic mean (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation — the paper reports δ over 3 repetitions
/// of the *same* configuration, which is a population, not a sample.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated percentile, p in [0, 100]. NaN samples are
/// tolerated (`total_cmp` sorts them after +∞ instead of panicking), so
/// one poisoned metrics sample cannot kill a whole campaign report.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_unstable_by(f64::total_cmp);
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Min/max helpers tolerant of NaN-free input.
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Summary of a set of samples (used by the bench harness).
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Summary {
        Summary {
            n: xs.len(),
            mean: mean(xs),
            stddev: stddev(xs),
            min: min(xs),
            p50: percentile(xs, 50.0),
            p99: percentile(xs, 99.0),
            max: max(xs),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert_eq!(mean(&xs), 5.0);
        assert!((stddev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(stddev(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn nan_samples_do_not_panic() {
        // partial_cmp().unwrap() used to panic here, taking the whole
        // campaign report down with it. total_cmp sorts NaN after +inf,
        // so finite percentiles of the clean prefix stay meaningful.
        let xs = [3.0, f64::NAN, 1.0, 2.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert!(percentile(&xs, 100.0).is_nan());
        let s = Summary::of(&xs);
        assert_eq!(s.n, 4);
        // p50 interpolates within the sorted finite prefix [1, 2, 3, NaN].
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn summary_fields_consistent() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert_eq!(s.n, 100);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(s.p50, 50.5);
    }
}
