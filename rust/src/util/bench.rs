//! Minimal benchmark harness (criterion replacement for the offline
//! build): warmup + timed samples, mean/p50/p99 reporting, and a
//! plain-text table compatible with `cargo bench` output capture.

use std::time::Instant;

use super::stats::Summary;

/// One benchmark's result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ms: Vec<f64>,
    pub summary: Summary,
}

/// Run `f` for `warmup` unmeasured and `samples` measured iterations.
pub fn bench(name: &str, warmup: usize, samples: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples_ms = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        f();
        samples_ms.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let summary = Summary::of(&samples_ms);
    BenchResult { name: name.to_string(), samples_ms, summary }
}

/// Print one result row (call `header()` first).
pub fn report(r: &BenchResult) {
    println!(
        "{:<44} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>6}",
        r.name, r.summary.mean, r.summary.p50, r.summary.p99, r.summary.max, r.summary.n
    );
}

pub fn header(title: &str) {
    println!("\n== {title}");
    println!(
        "{:<44} {:>10} {:>10} {:>10} {:>10} {:>6}",
        "benchmark", "mean(ms)", "p50(ms)", "p99(ms)", "max(ms)", "n"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_requested_samples() {
        let r = bench("noop", 2, 10, || {
            std::hint::black_box(1 + 1);
        });
        assert_eq!(r.samples_ms.len(), 10);
        assert!(r.summary.mean >= 0.0);
    }
}
