//! Declarative CLI argument parser (clap replacement).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, positional
//! subcommands and generated `--help` text. Only what the `kubeadaptor`
//! binary and examples need — by design.

use std::collections::BTreeMap;

#[derive(Debug)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    BadValue(String, String),
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => write!(f, "unknown option --{name}"),
            CliError::MissingValue(name) => write!(f, "option --{name} expects a value"),
            CliError::BadValue(name, value) => {
                write!(f, "invalid value for --{name}: {value}")
            }
        }
    }
}

impl std::error::Error for CliError {}

/// One declared option.
struct OptSpec {
    name: &'static str,
    help: &'static str,
    takes_value: bool,
    default: Option<String>,
}

/// A small declarative argument parser.
pub struct Args {
    about: &'static str,
    specs: Vec<OptSpec>,
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn new(about: &'static str) -> Self {
        Self {
            about,
            specs: Vec::new(),
            values: BTreeMap::new(),
            flags: BTreeMap::new(),
            positional: Vec::new(),
        }
    }

    /// Declare a value option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, takes_value: true, default: Some(default.into()) });
        self
    }

    /// Declare a value option with no default (optional).
    pub fn opt_null(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, takes_value: true, default: None });
        self
    }

    /// Declare a boolean flag (default false).
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.specs.push(OptSpec { name, help, takes_value: false, default: None });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{}\n\nOptions:\n", self.about);
        for spec in &self.specs {
            let mut line = format!("  --{}", spec.name);
            if spec.takes_value {
                line.push_str(" <v>");
            }
            if let Some(d) = &spec.default {
                line.push_str(&format!(" (default: {d})"));
            }
            s.push_str(&format!("{:<36} {}\n", line, spec.help));
        }
        s.push_str("  --help                             print this help\n");
        s
    }

    /// Parse an argv slice (without the program name). Prints usage and
    /// exits on `--help`.
    pub fn parse(mut self, argv: &[String]) -> Result<Parsed, CliError> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                print!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| CliError::Unknown(key.clone()))?;
                if spec.takes_value {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or_else(|| CliError::MissingValue(key.clone()))?
                        }
                    };
                    self.values.insert(key, val);
                } else {
                    self.flags.insert(key, true);
                }
            } else {
                self.positional.push(a.clone());
            }
            i += 1;
        }
        // Fill defaults.
        for spec in &self.specs {
            if spec.takes_value {
                if let Some(d) = &spec.default {
                    self.values.entry(spec.name.to_string()).or_insert_with(|| d.clone());
                }
            }
        }
        Ok(Parsed { values: self.values, flags: self.flags, positional: self.positional })
    }
}

/// Parse results with typed getters.
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    pub positional: Vec<String>,
}

impl Parsed {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_str(&self, key: &str) -> &str {
        self.get(key).unwrap_or_default()
    }

    pub fn get_f64(&self, key: &str) -> Result<f64, CliError> {
        let v = self.get(key).ok_or_else(|| CliError::MissingValue(key.into()))?;
        v.parse().map_err(|_| CliError::BadValue(key.into(), v.into()))
    }

    pub fn get_u64(&self, key: &str) -> Result<u64, CliError> {
        let v = self.get(key).ok_or_else(|| CliError::MissingValue(key.into()))?;
        v.parse().map_err(|_| CliError::BadValue(key.into(), v.into()))
    }

    pub fn get_usize(&self, key: &str) -> Result<usize, CliError> {
        Ok(self.get_u64(key)? as usize)
    }

    pub fn flag(&self, key: &str) -> bool {
        self.flags.get(key).copied().unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_values_flags_positionals() {
        let p = Args::new("t")
            .opt("reps", "3", "repetitions")
            .opt("out", "results", "output dir")
            .flag("verbose", "chatty")
            .parse(&argv(&["table2", "--reps", "5", "--verbose"]))
            .unwrap();
        assert_eq!(p.positional, vec!["table2"]);
        assert_eq!(p.get_u64("reps").unwrap(), 5);
        assert_eq!(p.get_str("out"), "results");
        assert!(p.flag("verbose"));
    }

    #[test]
    fn equals_syntax() {
        let p = Args::new("t")
            .opt("alpha", "0.8", "")
            .parse(&argv(&["--alpha=0.5"]))
            .unwrap();
        assert_eq!(p.get_f64("alpha").unwrap(), 0.5);
    }

    #[test]
    fn unknown_option_errors() {
        let e = Args::new("t").parse(&argv(&["--nope"]));
        assert!(matches!(e, Err(CliError::Unknown(_))));
    }

    #[test]
    fn missing_value_errors() {
        let e = Args::new("t").opt_null("out", "").parse(&argv(&["--out"]));
        assert!(matches!(e, Err(CliError::MissingValue(_))));
    }

    #[test]
    fn bad_value_errors() {
        let p = Args::new("t").opt("reps", "x", "").parse(&argv(&[])).unwrap();
        assert!(matches!(p.get_u64("reps"), Err(CliError::BadValue(_, _))));
    }
}
