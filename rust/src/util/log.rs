//! Leveled logger controlled by the `KA_LOG` environment variable
//! (`error|warn|info|debug|trace`; default `warn`). Experiments keep it
//! quiet; `--verbose` on the CLI bumps it to `info`.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(255); // 255 = uninitialised

fn init_from_env() -> u8 {
    let lvl = match std::env::var("KA_LOG").unwrap_or_default().to_lowercase().as_str() {
        "error" => Level::Error,
        "info" => Level::Info,
        "debug" => Level::Debug,
        "trace" => Level::Trace,
        _ => Level::Warn,
    } as u8;
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

pub fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v == 255 {
        init_from_env()
    } else {
        v
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, args: std::fmt::Arguments) {
    if enabled(l) {
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        };
        eprintln!("[{tag}] {args}");
    }
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_level_controls_enabled() {
        set_level(Level::Info);
        assert!(enabled(Level::Warn));
        assert!(enabled(Level::Info));
        assert!(!enabled(Level::Debug));
        set_level(Level::Warn);
    }
}
