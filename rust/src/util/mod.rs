//! Small utility substrates built from scratch (the offline toolchain has
//! no serde/clap/criterion): JSON, CLI parsing, statistics, CSV, logging.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod log;
pub mod stats;
