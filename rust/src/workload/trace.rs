//! Arrival-trace replay: drive the engine with recorded burst schedules
//! instead of the paper's synthetic patterns.
//!
//! Trace format (JSON):
//! ```json
//! {"bursts": [{"at": 0, "count": 3}, {"at": 120, "count": 7}, ...]}
//! ```
//! Times are seconds from run start; bursts must be time-ordered.

use crate::util::json::Json;

use super::Burst;

pub fn parse(text: &str) -> anyhow::Result<Vec<Burst>> {
    let j = Json::parse(text)?;
    let arr = j
        .get("bursts")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow::anyhow!("trace needs a 'bursts' array"))?;
    anyhow::ensure!(!arr.is_empty(), "trace has no bursts");
    let mut bursts = Vec::with_capacity(arr.len());
    let mut last = f64::NEG_INFINITY;
    for (i, b) in arr.iter().enumerate() {
        let at = b
            .get("at")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("burst {i}: missing 'at'"))?;
        let count = b
            .get("count")
            .and_then(|v| v.as_i64())
            .ok_or_else(|| anyhow::anyhow!("burst {i}: missing 'count'"))?;
        // `1e999` parses to +inf (Rust's f64 parsing saturates), and inf
        // or NaN times would corrupt the event queue's ordering — reject.
        anyhow::ensure!(at.is_finite(), "burst {i}: non-finite time");
        anyhow::ensure!(at >= 0.0, "burst {i}: negative time");
        anyhow::ensure!(at >= last, "burst {i}: out of order");
        anyhow::ensure!(count > 0, "burst {i}: count must be positive");
        last = at;
        bursts.push(Burst { at, count: count as usize });
    }
    Ok(bursts)
}

pub fn from_file(path: &str) -> anyhow::Result<Vec<Burst>> {
    parse(
        &std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading trace {path}: {e}"))?,
    )
}

/// Serialize a burst schedule back to the trace format (round-trips with
/// [`parse`]; used to export synthetic patterns as traces).
pub fn to_json(bursts: &[Burst]) -> String {
    let items: Vec<Json> = bursts
        .iter()
        .map(|b| Json::obj(vec![("at", Json::num(b.at)), ("count", Json::num(b.count as f64))]))
        .collect();
    Json::obj(vec![("bursts", Json::Arr(items))]).to_string_pretty()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ArrivalPattern;

    #[test]
    fn parses_valid_trace() {
        let b = parse(r#"{"bursts":[{"at":0,"count":3},{"at":120,"count":7}]}"#).unwrap();
        assert_eq!(b.len(), 2);
        assert_eq!(b[1], Burst { at: 120.0, count: 7 });
    }

    #[test]
    fn rejects_malformed_traces() {
        assert!(parse(r#"{}"#).is_err());
        assert!(parse(r#"{"bursts":[]}"#).is_err());
        assert!(parse(r#"{"bursts":[{"at":-1,"count":1}]}"#).is_err());
        assert!(parse(r#"{"bursts":[{"at":10,"count":1},{"at":5,"count":1}]}"#).is_err());
        assert!(parse(r#"{"bursts":[{"at":0,"count":0}]}"#).is_err());
    }

    #[test]
    fn rejects_non_finite_times() {
        // 1e999 saturates to +inf when parsed; NaN cannot be written as a
        // JSON literal, so the infinities are the reachable edge.
        assert!(parse(r#"{"bursts":[{"at":1e999,"count":1}]}"#).is_err());
        assert!(parse(r#"{"bursts":[{"at":-1e999,"count":1}]}"#).is_err());
        // An inf in the middle also breaks the ordering check for
        // whatever follows it — but it must already fail on its own.
        assert!(parse(r#"{"bursts":[{"at":0,"count":1},{"at":1e999,"count":1}]}"#).is_err());
    }

    #[test]
    fn random_schedules_roundtrip_bit_exactly() {
        // Property: parse(to_json(b)) == b for arbitrary valid schedules,
        // including fractional times (shortest-roundtrip float printing).
        crate::testutil::forall(
            0x7ACE,
            200,
            |rng: &mut crate::simcore::Rng| {
                let n = rng.range_inclusive(1, 12) as usize;
                let mut t = 0.0f64;
                (0..n)
                    .map(|_| {
                        t += rng.uniform(0.0, 500.0);
                        Burst { at: t, count: rng.range_inclusive(1, 40) as usize }
                    })
                    .collect::<Vec<_>>()
            },
            |bursts| {
                let again = parse(&to_json(bursts)).map_err(|e| e.to_string())?;
                if &again == bursts {
                    Ok(())
                } else {
                    Err(format!("round-trip drift: {bursts:?} != {again:?}"))
                }
            },
        )
        .unwrap();
    }

    #[test]
    fn synthetic_pattern_roundtrips_as_trace() {
        let bursts = crate::workload::schedule(&ArrivalPattern::paper_pyramid(), 300.0).unwrap();
        let text = to_json(&bursts);
        let again = parse(&text).unwrap();
        assert_eq!(bursts, again);
    }

    #[test]
    fn trace_drives_engine() {
        use crate::config::{ExperimentConfig, PolicySpec};
        use crate::engine::Engine;
        use crate::resources::FcfsPolicy;

        let bursts = parse(r#"{"bursts":[{"at":0,"count":2},{"at":60,"count":1}]}"#).unwrap();
        let mut cfg = ExperimentConfig::default();
        cfg.alloc.policy = PolicySpec::fcfs();
        cfg.sample_interval_s = 10.0;
        let engine =
            Engine::with_trace(cfg, Box::new(FcfsPolicy::new()), bursts, None).unwrap();
        let out = engine.run();
        assert_eq!(out.summary.workflows_completed, 3);
    }
}
