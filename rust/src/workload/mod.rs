//! Workflow Injection Module: turns an arrival pattern into a concrete
//! injection schedule and instantiates workflow specs (Parser+Packaging
//! in Fig. 2).

pub mod trace;

use crate::config::{ArrivalPattern, TaskConfig, WorkloadConfig};
use crate::simcore::{Rng, SimTime};
use crate::workflow::{topologies, WorkflowSpec, WorkflowType};

/// One scheduled injection burst.
#[derive(Debug, Clone, PartialEq)]
pub struct Burst {
    pub at: SimTime,
    pub count: usize,
}

/// Build a plan from an explicit burst schedule (trace replay). Burst
/// times must be finite and non-negative, counts positive — the same
/// hardening [`trace::parse`] applies, enforced here too so
/// programmatic bursts can't smuggle in what a trace file cannot.
pub fn plan_from_bursts(
    bursts: Vec<Burst>,
    workload: &WorkloadConfig,
    task_cfg: &TaskConfig,
    custom: Option<&WorkflowSpec>,
) -> anyhow::Result<InjectionPlan> {
    Ok(plan_iter_from_bursts(bursts, workload, task_cfg, custom)?.collect_plan())
}

/// Lazy streaming counterpart of [`plan_from_bursts`]: validates the
/// schedule and instantiates the workflow template eagerly (so errors
/// surface before the first arrival), then yields `(time, spec)` pairs
/// one arrival at a time. Consumers that never materialize the whole
/// plan — the federation router, eventually million-task streaming
/// ingest — stay O(1) in plan memory; [`PlanIter::collect_plan`]
/// rebuilds the batch plan bit-identically (regression-tested).
pub fn plan_iter_from_bursts(
    bursts: Vec<Burst>,
    workload: &WorkloadConfig,
    task_cfg: &TaskConfig,
    custom: Option<&WorkflowSpec>,
) -> anyhow::Result<PlanIter> {
    for (i, b) in bursts.iter().enumerate() {
        anyhow::ensure!(b.at.is_finite(), "burst {i}: non-finite time {}", b.at);
        anyhow::ensure!(b.at >= 0.0, "burst {i}: negative time {}", b.at);
        anyhow::ensure!(b.count > 0, "burst {i}: count must be positive");
    }
    let mut rng = Rng::new(workload.seed);
    let template = instantiate(workload.workflow, custom, task_cfg, &mut rng);
    Ok(PlanIter { bursts, template, burst: 0, emitted: 0 })
}

/// Lazy streaming counterpart of [`plan`]: pattern → schedule →
/// arrival iterator.
pub fn plan_iter(
    workload: &WorkloadConfig,
    task_cfg: &TaskConfig,
    custom: Option<&WorkflowSpec>,
) -> anyhow::Result<PlanIter> {
    let bursts = schedule(&workload.pattern, workload.burst_interval_s)?;
    plan_iter_from_bursts(bursts, workload, task_cfg, custom)
}

/// Streaming arrival iterator: yields one `(injection time, workflow
/// spec)` pair per arriving request, in burst order. Holds only the
/// burst schedule and the single sampled template (task durations are
/// part of the workflow definition — see [`plan`] — so every arrival
/// clones the same template, exactly like the batch path).
#[derive(Debug, Clone)]
pub struct PlanIter {
    bursts: Vec<Burst>,
    template: WorkflowSpec,
    burst: usize,
    emitted: usize,
}

impl PlanIter {
    /// Total arrivals this iterator will yield (ignoring consumption).
    pub fn total(&self) -> usize {
        self.bursts.iter().map(|b| b.count).sum()
    }

    /// The validated burst schedule.
    pub fn bursts(&self) -> &[Burst] {
        &self.bursts
    }

    /// Materialize the batch [`InjectionPlan`] — bit-identical to what
    /// the eager path historically produced (one template instantiation
    /// from the workload seed, cloned `total` times).
    pub fn collect_plan(self) -> InjectionPlan {
        let total = self.total();
        InjectionPlan { bursts: self.bursts, workflows: vec![self.template; total] }
    }
}

impl Iterator for PlanIter {
    type Item = (SimTime, WorkflowSpec);

    fn next(&mut self) -> Option<Self::Item> {
        while let Some(b) = self.bursts.get(self.burst) {
            if self.emitted < b.count {
                self.emitted += 1;
                return Some((b.at, self.template.clone()));
            }
            self.burst += 1;
            self.emitted = 0;
        }
        None
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let remaining: usize = self
            .bursts
            .iter()
            .skip(self.burst)
            .map(|b| b.count)
            .sum::<usize>()
            .saturating_sub(self.emitted);
        (remaining, Some(remaining))
    }
}

/// Expand a pattern into timed bursts (burst 0 at t=0). The interval
/// must be finite and strictly positive: zero or negative intervals
/// would silently collapse every burst onto t=0 (or corrupt the event
/// queue with negative times) — rejected loudly instead, matching the
/// non-finite `at` hardening of the trace parsers.
pub fn schedule(pattern: &ArrivalPattern, interval_s: f64) -> anyhow::Result<Vec<Burst>> {
    anyhow::ensure!(
        interval_s.is_finite() && interval_s > 0.0,
        "burst interval must be finite and > 0, got {interval_s}"
    );
    Ok(pattern
        .bursts()
        .into_iter()
        .enumerate()
        .map(|(i, count)| Burst { at: i as f64 * interval_s, count })
        .collect())
}

/// Instantiate one workflow: clone the topology template and sample task
/// durations/resources per the task config. Deterministic given `rng`.
pub fn instantiate(
    kind: WorkflowType,
    custom: Option<&WorkflowSpec>,
    task_cfg: &TaskConfig,
    rng: &mut Rng,
) -> WorkflowSpec {
    let mut spec = match kind {
        WorkflowType::Custom => custom.expect("custom workflow requires a spec").clone(),
        k => topologies::build(k),
    };
    for t in &mut spec.tasks {
        if t.duration_s == 0.0 {
            t.duration_s = rng.uniform(task_cfg.duration_lo_s, task_cfg.duration_hi_s);
        }
        // Template tasks inherit the experiment's resource settings
        // (§6.1.3 sets these uniformly for all task pods).
        t.cpu_milli = task_cfg.req_cpu_milli;
        t.mem_mi = task_cfg.req_mem_mi;
        t.min_cpu_milli = task_cfg.min_cpu_milli;
        t.min_mem_mi = task_cfg.min_mem_mi;
    }
    spec
}

/// The full injection plan for a run: burst times plus per-workflow specs.
pub struct InjectionPlan {
    pub bursts: Vec<Burst>,
    /// Workflow instances in injection order, one per arriving request.
    pub workflows: Vec<WorkflowSpec>,
}

pub fn plan(
    workload: &WorkloadConfig,
    task_cfg: &TaskConfig,
    custom: Option<&WorkflowSpec>,
) -> anyhow::Result<InjectionPlan> {
    // Task durations are part of the workflow *definition* (Eq. 1:
    // `duration` is a predefined task field imported from the ConfigMap,
    // §6.1.3) — sampled once per run; every injected instance of the
    // workflow is identical, exactly like re-submitting the same
    // definition to the paper's CLI.
    Ok(plan_iter(workload, task_cfg, custom)?.collect_plan())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskConfig;

    #[test]
    fn constant_schedule_times() {
        let b = schedule(&ArrivalPattern::paper_constant(), 300.0).unwrap();
        assert_eq!(b.len(), 6);
        assert_eq!(b[0], Burst { at: 0.0, count: 5 });
        assert_eq!(b[5], Burst { at: 1500.0, count: 5 });
    }

    #[test]
    fn schedule_rejects_non_positive_or_non_finite_intervals() {
        // Regression: these used to be accepted silently, collapsing
        // every burst onto t=0 (or worse, scheduling negative times).
        let p = ArrivalPattern::paper_constant();
        for bad in [0.0, -300.0, f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let err = schedule(&p, bad);
            assert!(err.is_err(), "interval {bad} must be rejected");
        }
        let msg = schedule(&p, 0.0).unwrap_err().to_string();
        assert!(msg.contains("burst interval"), "{msg}");
        assert!(schedule(&p, 0.001).is_ok());
    }

    #[test]
    fn plan_from_bursts_rejects_bad_burst_schedules() {
        let wl = WorkloadConfig::default();
        let cfg = TaskConfig::default();
        let ok = vec![Burst { at: 0.0, count: 2 }, Burst { at: 60.0, count: 1 }];
        assert!(plan_from_bursts(ok, &wl, &cfg, None).is_ok());
        let inf = vec![Burst { at: f64::INFINITY, count: 1 }];
        assert!(plan_from_bursts(inf, &wl, &cfg, None).is_err());
        let nan = vec![Burst { at: f64::NAN, count: 1 }];
        assert!(plan_from_bursts(nan, &wl, &cfg, None).is_err());
        let neg = vec![Burst { at: -1.0, count: 1 }];
        assert!(plan_from_bursts(neg, &wl, &cfg, None).is_err());
        let zero = vec![Burst { at: 0.0, count: 0 }];
        assert!(plan_from_bursts(zero, &wl, &cfg, None).is_err());
    }

    #[test]
    fn instantiate_samples_durations_in_range() {
        let cfg = TaskConfig::default();
        let mut rng = Rng::new(1);
        let wf = instantiate(WorkflowType::Montage, None, &cfg, &mut rng);
        for t in &wf.tasks {
            assert!((10.0..20.0).contains(&t.duration_s), "{}", t.duration_s);
            assert_eq!(t.cpu_milli, 2000);
        }
    }

    #[test]
    fn instantiation_is_deterministic() {
        let cfg = TaskConfig::default();
        let a = instantiate(WorkflowType::Ligo, None, &cfg, &mut Rng::new(7));
        let b = instantiate(WorkflowType::Ligo, None, &cfg, &mut Rng::new(7));
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.duration_s, y.duration_s);
        }
    }

    #[test]
    fn plan_counts_match_pattern_total() {
        let wl = WorkloadConfig {
            pattern: ArrivalPattern::paper_pyramid(),
            ..WorkloadConfig::default()
        };
        let p = plan(&wl, &TaskConfig::default(), None).unwrap();
        assert_eq!(p.workflows.len(), 34);
        assert_eq!(p.bursts.iter().map(|b| b.count).sum::<usize>(), 34);
    }

    #[test]
    fn plan_iter_streams_the_batch_plan_bit_identically() {
        // Regression lock for the plan_from_bursts → plan_iter rebase:
        // the streamed arrivals and the recollected batch plan must
        // match the eager plan bit for bit (Debug formatting of f64
        // round-trips, so string equality is bit equality).
        let wl = WorkloadConfig {
            pattern: ArrivalPattern::paper_pyramid(),
            ..WorkloadConfig::default()
        };
        let cfg = TaskConfig::default();
        let batch = plan(&wl, &cfg, None).unwrap();
        let it = plan_iter(&wl, &cfg, None).unwrap();
        assert_eq!(it.total(), batch.workflows.len());
        assert_eq!(it.bursts(), &batch.bursts[..]);
        assert_eq!(it.size_hint(), (34, Some(34)));
        // Streamed arrivals: times follow the burst schedule, specs
        // clone the one sampled template.
        let streamed: Vec<(SimTime, WorkflowSpec)> = it.clone().collect();
        assert_eq!(streamed.len(), batch.workflows.len());
        let mut k = 0;
        for b in &batch.bursts {
            for _ in 0..b.count {
                assert_eq!(streamed[k].0, b.at);
                assert_eq!(
                    format!("{:?}", streamed[k].1),
                    format!("{:?}", batch.workflows[k])
                );
                k += 1;
            }
        }
        // Recollecting the iterator rebuilds the batch plan exactly.
        let rebuilt = it.collect_plan();
        assert_eq!(rebuilt.bursts, batch.bursts);
        assert_eq!(
            format!("{:?}", rebuilt.workflows),
            format!("{:?}", batch.workflows)
        );
    }

    #[test]
    fn plan_iter_rejects_bad_bursts_eagerly() {
        let wl = WorkloadConfig::default();
        let cfg = TaskConfig::default();
        let bad = vec![Burst { at: f64::NAN, count: 1 }];
        assert!(plan_iter_from_bursts(bad, &wl, &cfg, None).is_err());
        let zero = vec![Burst { at: 0.0, count: 0 }];
        assert!(plan_iter_from_bursts(zero, &wl, &cfg, None).is_err());
    }
}
