//! Workflow Injection Module: turns an arrival pattern into a concrete
//! injection schedule and instantiates workflow specs (Parser+Packaging
//! in Fig. 2).

pub mod trace;

use crate::config::{ArrivalPattern, TaskConfig, WorkloadConfig};
use crate::simcore::{Rng, SimTime};
use crate::workflow::{topologies, WorkflowSpec, WorkflowType};

/// One scheduled injection burst.
#[derive(Debug, Clone, PartialEq)]
pub struct Burst {
    pub at: SimTime,
    pub count: usize,
}

/// Build a plan from an explicit burst schedule (trace replay).
pub fn plan_from_bursts(
    bursts: Vec<Burst>,
    workload: &WorkloadConfig,
    task_cfg: &TaskConfig,
    custom: Option<&WorkflowSpec>,
) -> InjectionPlan {
    let total: usize = bursts.iter().map(|b| b.count).sum();
    let mut rng = Rng::new(workload.seed);
    let template = instantiate(workload.workflow, custom, task_cfg, &mut rng);
    InjectionPlan { bursts, workflows: vec![template; total] }
}

/// Expand a pattern into timed bursts (burst 0 at t=0).
pub fn schedule(pattern: &ArrivalPattern, interval_s: f64) -> Vec<Burst> {
    pattern
        .bursts()
        .into_iter()
        .enumerate()
        .map(|(i, count)| Burst { at: i as f64 * interval_s, count })
        .collect()
}

/// Instantiate one workflow: clone the topology template and sample task
/// durations/resources per the task config. Deterministic given `rng`.
pub fn instantiate(
    kind: WorkflowType,
    custom: Option<&WorkflowSpec>,
    task_cfg: &TaskConfig,
    rng: &mut Rng,
) -> WorkflowSpec {
    let mut spec = match kind {
        WorkflowType::Custom => custom.expect("custom workflow requires a spec").clone(),
        k => topologies::build(k),
    };
    for t in &mut spec.tasks {
        if t.duration_s == 0.0 {
            t.duration_s = rng.uniform(task_cfg.duration_lo_s, task_cfg.duration_hi_s);
        }
        // Template tasks inherit the experiment's resource settings
        // (§6.1.3 sets these uniformly for all task pods).
        t.cpu_milli = task_cfg.req_cpu_milli;
        t.mem_mi = task_cfg.req_mem_mi;
        t.min_cpu_milli = task_cfg.min_cpu_milli;
        t.min_mem_mi = task_cfg.min_mem_mi;
    }
    spec
}

/// The full injection plan for a run: burst times plus per-workflow specs.
pub struct InjectionPlan {
    pub bursts: Vec<Burst>,
    /// Workflow instances in injection order, one per arriving request.
    pub workflows: Vec<WorkflowSpec>,
}

pub fn plan(
    workload: &WorkloadConfig,
    task_cfg: &TaskConfig,
    custom: Option<&WorkflowSpec>,
) -> InjectionPlan {
    let bursts = schedule(&workload.pattern, workload.burst_interval_s);
    let total: usize = bursts.iter().map(|b| b.count).sum();
    let mut rng = Rng::new(workload.seed);
    // Task durations are part of the workflow *definition* (Eq. 1:
    // `duration` is a predefined task field imported from the ConfigMap,
    // §6.1.3) — sampled once per run; every injected instance of the
    // workflow is identical, exactly like re-submitting the same
    // definition to the paper's CLI.
    let template = instantiate(workload.workflow, custom, task_cfg, &mut rng);
    let workflows = vec![template; total];
    InjectionPlan { bursts, workflows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TaskConfig;

    #[test]
    fn constant_schedule_times() {
        let b = schedule(&ArrivalPattern::paper_constant(), 300.0);
        assert_eq!(b.len(), 6);
        assert_eq!(b[0], Burst { at: 0.0, count: 5 });
        assert_eq!(b[5], Burst { at: 1500.0, count: 5 });
    }

    #[test]
    fn instantiate_samples_durations_in_range() {
        let cfg = TaskConfig::default();
        let mut rng = Rng::new(1);
        let wf = instantiate(WorkflowType::Montage, None, &cfg, &mut rng);
        for t in &wf.tasks {
            assert!((10.0..20.0).contains(&t.duration_s), "{}", t.duration_s);
            assert_eq!(t.cpu_milli, 2000);
        }
    }

    #[test]
    fn instantiation_is_deterministic() {
        let cfg = TaskConfig::default();
        let a = instantiate(WorkflowType::Ligo, None, &cfg, &mut Rng::new(7));
        let b = instantiate(WorkflowType::Ligo, None, &cfg, &mut Rng::new(7));
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.duration_s, y.duration_s);
        }
    }

    #[test]
    fn plan_counts_match_pattern_total() {
        let wl = WorkloadConfig {
            pattern: ArrivalPattern::paper_pyramid(),
            ..WorkloadConfig::default()
        };
        let p = plan(&wl, &TaskConfig::default(), None);
        assert_eq!(p.workflows.len(), 34);
        assert_eq!(p.bursts.iter().map(|b| b.count).sum::<usize>(), 34);
    }
}
