//! Experiment and engine configuration.
//!
//! Defaults reproduce the paper's testbed (§6.1): 6 worker nodes with
//! 8 cores / 16 GB each, task pods requesting 2000m CPU / 4000Mi memory
//! with a 1000Mi minimum, durations U[10, 20] s, α = 0.8, β = 20Mi,
//! bursts every 300 s. Configs load from JSON files (see
//! `ExperimentConfig::from_json`) and every field has a builder-style
//! setter path through plain struct mutation.

use crate::cluster::dynamics::{self, AutoscalerConfig, ClusterEvent};
use crate::util::json::Json;
use crate::workflow::WorkflowType;

pub use crate::chaos::ChaosConfig;

/// Which resource-allocation policy drives the Resource Manager: a
/// string key into the [`crate::resources::registry::PolicyRegistry`]
/// plus optional numeric parameters. Replaces the old closed
/// `PolicyKind` enum — adding a policy is one registry call, not an
/// enum edit rippling through seven modules.
///
/// The spec is *resolved* (name looked up, params validated, policy
/// instantiated) by the registry at engine construction; config only
/// carries the description, so unknown names fail at `Engine::new`
/// with the list of registered policies.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicySpec {
    /// Registry key (canonical lowercase name, e.g. `"adaptive"`).
    pub name: String,
    /// Policy parameters as key → value pairs, e.g. `[("budget", 3.0)]`.
    /// Both [`PolicySpec::parse`] and [`PolicySpec::with_param`] keep
    /// this sorted by key, so equal configurations compare equal (and
    /// share one report label) regardless of how they were written.
    pub params: Vec<(String, f64)>,
}

impl PolicySpec {
    /// A parameter-less spec for a registered policy name. Lowercases
    /// and maps the legacy `aras`/`fcfs` aliases to their canonical
    /// names, so programmatic specs group into the same report slots as
    /// CLI-parsed ones (and duplicate-axis detection catches
    /// `adaptive` + `aras` in one grid).
    pub fn named(name: impl Into<String>) -> Self {
        let name = match name.into().to_lowercase().as_str() {
            "aras" => "adaptive".to_string(),
            "fcfs" => "baseline".to_string(),
            other => other.to_string(),
        };
        Self { name, params: Vec::new() }
    }

    /// The paper's ARAS (Algorithms 1–3, Eq. 9).
    pub fn adaptive() -> Self {
        Self::named("adaptive")
    }

    /// The FCFS baseline from the authors' prior work [21].
    pub fn fcfs() -> Self {
        Self::named("baseline")
    }

    /// Builder-style parameter attachment. Keys are lowercased and the
    /// param list stays key-sorted, matching [`PolicySpec::parse`] so
    /// programmatic and parsed specs of one configuration are equal.
    pub fn with_param(mut self, key: impl Into<String>, value: f64) -> Self {
        self.params.push((key.into().to_lowercase(), value));
        self.params.sort_by(|a, b| a.0.cmp(&b.0));
        self
    }

    /// Look up a parameter by key.
    pub fn param(&self, key: &str) -> Option<f64> {
        self.params.iter().find(|(k, _)| k.as_str() == key).map(|&(_, v)| v)
    }

    /// Parse a CLI/JSON policy string: `name` or `name:key=value,key=value`.
    /// Names are lowercased; the legacy `aras`/`fcfs` aliases canonicalize
    /// to `adaptive`/`baseline` so pre-registry spellings keep working.
    /// Parameter values are numbers, or `true|on`/`false|off` for flags.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (name, params) = parse_spec_str(s, "policy")?;
        Ok(Self { name: Self::named(name).name, params })
    }

    /// Report label: the name alone, or `name:k=v,…` when parameterized.
    /// Parameter-less specs render exactly like the old `PolicyKind`
    /// names, keeping campaign reports byte-identical.
    pub fn label(&self) -> String {
        spec_label(&self.name, &self.params)
    }
}

/// Shared `name` / `name:key=value,...` parser behind [`PolicySpec::parse`]
/// and [`ForecasterSpec::parse`]: lowercases the name and keys, accepts
/// `true|on`/`false|off` flag values, rejects duplicates, returns params
/// sorted by key.
fn parse_spec_str(s: &str, what: &str) -> anyhow::Result<(String, Vec<(String, f64)>)> {
    let s = s.trim();
    let (raw_name, raw_params) = match s.split_once(':') {
        Some((n, p)) => (n, Some(p)),
        None => (s, None),
    };
    anyhow::ensure!(!raw_name.trim().is_empty(), "empty {what} name");
    let name = raw_name.trim().to_lowercase();
    let mut params = Vec::new();
    if let Some(raw) = raw_params {
        for pair in raw.split(',').filter(|p| !p.trim().is_empty()) {
            let (k, v) = pair
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("{what} param '{pair}' is not key=value"))?;
            let key = k.trim().to_lowercase();
            let value = match v.trim().to_lowercase().as_str() {
                "true" | "on" => 1.0,
                "false" | "off" => 0.0,
                num => num
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("{what} param '{key}': bad value '{v}'"))?,
            };
            anyhow::ensure!(
                !params.iter().any(|(existing, _)| *existing == key),
                "{what} param '{key}' given twice"
            );
            params.push((key, value));
        }
    }
    params.sort_by(|a, b| a.0.cmp(&b.0));
    Ok((name, params))
}

fn spec_label(name: &str, params: &[(String, f64)]) -> String {
    if params.is_empty() {
        return name.to_string();
    }
    let params: Vec<String> = params.iter().map(|(k, v)| format!("{k}={v}")).collect();
    format!("{}:{}", name, params.join(","))
}

/// Which demand forecaster (if any) feeds the engine's look-ahead
/// machinery: a string key into the
/// [`crate::forecast::registry::ForecasterRegistry`] plus optional
/// numeric parameters — the forecasting twin of [`PolicySpec`]. Resolved
/// at engine construction, so unknown names fail early with the roster.
#[derive(Debug, Clone, PartialEq)]
pub struct ForecasterSpec {
    /// Registry key (canonical lowercase name, e.g. `"seasonal"`).
    pub name: String,
    /// Parameters as key → value pairs, kept sorted by key so equal
    /// configurations compare equal regardless of spelling order.
    pub params: Vec<(String, f64)>,
}

impl ForecasterSpec {
    /// A parameter-less spec for a registered forecaster name.
    /// Lowercases and maps the built-in aliases (`last`, `ewma`,
    /// `holt-winters`) to their canonical names — kept in lockstep with
    /// the registry alias lists, exactly like [`PolicySpec::named`]
    /// does for `aras`/`fcfs` — so programmatic and config-file specs
    /// group into the same report labels as CLI-resolved ones, and the
    /// campaign forecaster-axis duplicate check catches `holt` + `ewma`
    /// in one grid. Aliases of user-registered forecasters are not
    /// rewritten here.
    pub fn named(name: impl Into<String>) -> Self {
        let name = match name.into().to_lowercase().as_str() {
            "last" => "naive-last".to_string(),
            "ewma" => "holt".to_string(),
            "holt-winters" => "seasonal".to_string(),
            other => other.to_string(),
        };
        Self { name, params: Vec::new() }
    }

    /// Builder-style parameter attachment (keys lowercased, list kept
    /// sorted, matching [`ForecasterSpec::parse`]).
    pub fn with_param(mut self, key: impl Into<String>, value: f64) -> Self {
        self.params.push((key.into().to_lowercase(), value));
        self.params.sort_by(|a, b| a.0.cmp(&b.0));
        self
    }

    /// Look up a parameter by key.
    pub fn param(&self, key: &str) -> Option<f64> {
        self.params.iter().find(|(k, _)| k.as_str() == key).map(|&(_, v)| v)
    }

    /// Parse a CLI/JSON forecaster string: `name` or `name:key=value,…`.
    /// Built-in aliases canonicalize like [`ForecasterSpec::named`].
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (name, params) = parse_spec_str(s, "forecaster")?;
        Ok(Self { name: Self::named(name).name, params })
    }

    /// Report label: the name alone, or `name:k=v,…` when parameterized.
    pub fn label(&self) -> String {
        spec_label(&self.name, &self.params)
    }
}

/// Which global routing strategy places workflows across a federation:
/// a string key into the
/// [`crate::federation::registry::RouterRegistry`] plus optional
/// numeric parameters — the routing twin of [`PolicySpec`] and
/// [`ForecasterSpec`]. Resolved when the federation runner is built, so
/// unknown names fail early with the registered roster.
#[derive(Debug, Clone, PartialEq)]
pub struct RouterSpec {
    /// Registry key (canonical lowercase name, e.g. `"forecast-headroom"`).
    pub name: String,
    /// Parameters as key → value pairs, kept sorted by key so equal
    /// configurations compare equal regardless of spelling order.
    pub params: Vec<(String, f64)>,
}

impl RouterSpec {
    /// A parameter-less spec for a registered router name. Lowercases
    /// and maps the built-in aliases (`rr`, `lq`, `headroom`, `wrr`) to
    /// their canonical names — kept in lockstep with the registry alias
    /// lists, exactly like [`PolicySpec::named`] and
    /// [`ForecasterSpec::named`].
    pub fn named(name: impl Into<String>) -> Self {
        let name = match name.into().to_lowercase().as_str() {
            "rr" => "round-robin".to_string(),
            "lq" => "least-queue".to_string(),
            "headroom" => "forecast-headroom".to_string(),
            "wrr" => "weighted".to_string(),
            other => other.to_string(),
        };
        Self { name, params: Vec::new() }
    }

    /// Builder-style parameter attachment (keys lowercased, list kept
    /// sorted, matching [`RouterSpec::parse`]).
    pub fn with_param(mut self, key: impl Into<String>, value: f64) -> Self {
        self.params.push((key.into().to_lowercase(), value));
        self.params.sort_by(|a, b| a.0.cmp(&b.0));
        self
    }

    /// Look up a parameter by key.
    pub fn param(&self, key: &str) -> Option<f64> {
        self.params.iter().find(|(k, _)| k.as_str() == key).map(|&(_, v)| v)
    }

    /// Parse a CLI/JSON router string: `name` or `name:key=value,…`.
    /// Built-in aliases canonicalize like [`RouterSpec::named`].
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (name, params) = parse_spec_str(s, "router")?;
        Ok(Self { name: Self::named(name).name, params })
    }

    /// Report label: the name alone, or `name:k=v,…` when parameterized.
    pub fn label(&self) -> String {
        spec_label(&self.name, &self.params)
    }
}

impl Default for RouterSpec {
    /// Round-robin: the strategy that needs no forecast, no weights and
    /// no cluster state, so a default-constructed federation is
    /// maximally predictable.
    fn default() -> Self {
        Self::named("round-robin")
    }
}

/// Demand-forecasting configuration. The default — no forecaster — turns
/// the subsystem off entirely: the engine takes no observations, no
/// forecast rides the [`crate::resources::ClusterSnapshot`], and runs
/// are bit-identical to pre-forecast builds (golden-trace locked).
#[derive(Debug, Clone, PartialEq)]
pub struct ForecastConfig {
    /// Which forecaster to run; `None` disables forecasting.
    pub forecaster: Option<ForecasterSpec>,
    /// Horizon (virtual seconds) of the forecast attached to each
    /// cluster snapshot handed to policies.
    pub horizon_s: f64,
}

impl Default for ForecastConfig {
    fn default() -> Self {
        Self { forecaster: None, horizon_s: 60.0 }
    }
}

/// How the engine builds the [`crate::resources::ClusterSnapshot`] each
/// serve cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SnapshotMode {
    /// Full Algorithm 2 fold over the informer's `PodList` per cycle —
    /// the original behavior and the golden-locked default.
    #[default]
    Full,
    /// Incrementally maintained residuals: per-pod request deltas are
    /// applied from the same watch events the informer syncs
    /// ([`crate::resources::discovery::IncrementalDiscovery`]), skipping
    /// the O(pods) fold. Bit-exact with `Full` (integer accumulators).
    Incremental,
    /// Incremental, but every fresh snapshot is cross-checked against a
    /// full rebuild and any bitwise divergence panics with the diff —
    /// the invariant-check mode used by tests and chaos runs.
    Verify,
}

impl SnapshotMode {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_lowercase().as_str() {
            "full" => Ok(SnapshotMode::Full),
            "incremental" | "inc" => Ok(SnapshotMode::Incremental),
            "verify" => Ok(SnapshotMode::Verify),
            other => anyhow::bail!("unknown snapshot mode '{other}' (full|incremental|verify)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SnapshotMode::Full => "full",
            SnapshotMode::Incremental => "incremental",
            SnapshotMode::Verify => "verify",
        }
    }
}

/// A recurring submission source for daemon mode: a schedule-DSL
/// expression (see [`crate::daemon::schedule::Schedule`]) paired with
/// what to submit at each occurrence.
#[derive(Debug, Clone, PartialEq)]
pub struct ScheduleSource {
    /// Schedule DSL text, e.g. `"every 5m"` or `"at 60 repeat 10"`.
    pub schedule: String,
    /// Workflow type submitted at each occurrence.
    pub workflow: WorkflowType,
    /// Workflows per occurrence (a burst of this size).
    pub count: usize,
}

/// Daemon-mode configuration (`daemon` subcommand / `"daemon"` config
/// key): where to listen, how virtual time advances, and any declarative
/// submission sources that generate traffic without a client.
#[derive(Debug, Clone, PartialEq)]
pub struct DaemonConfig {
    /// Listen address: `unix:<path>` or `tcp:<host>:<port>`.
    pub listen: String,
    /// Virtual-seconds advanced per wall-clock second. `None` (default)
    /// = free-running virtual time: the sim drains pending events as
    /// fast as it can between protocol commands.
    pub pace: Option<f64>,
    /// When true the engine stays un-started, queueing submissions,
    /// until a `drain` arrives — the determinism-bridge mode: hold →
    /// submit a batch workload → drain reproduces the batch run
    /// bit-exactly.
    pub hold: bool,
    /// Declarative recurring submission sources (schedule DSL).
    pub sources: Vec<ScheduleSource>,
}

impl Default for DaemonConfig {
    fn default() -> Self {
        Self {
            listen: "unix:/tmp/kubeadaptor.sock".to_string(),
            pace: None,
            hold: false,
            sources: Vec::new(),
        }
    }
}

impl DaemonConfig {
    pub fn validate(&self) -> anyhow::Result<()> {
        let ok_addr = match self.listen.split_once(':') {
            Some(("unix", path)) => !path.is_empty(),
            Some(("tcp", hostport)) => {
                matches!(hostport.rsplit_once(':'), Some((h, p)) if !h.is_empty() && p.parse::<u16>().is_ok())
            }
            _ => false,
        };
        anyhow::ensure!(
            ok_addr,
            "daemon listen address '{}' must be unix:<path> or tcp:<host>:<port>",
            self.listen
        );
        if let Some(pace) = self.pace {
            anyhow::ensure!(
                pace.is_finite() && pace > 0.0,
                "daemon pace must be finite and > 0, got {pace}"
            );
        }
        for (i, src) in self.sources.iter().enumerate() {
            crate::daemon::schedule::Schedule::parse(&src.schedule)
                .map_err(|e| anyhow::anyhow!("daemon source {i}: {e}"))?;
            anyhow::ensure!(src.count > 0, "daemon source {i}: zero count");
        }
        Ok(())
    }
}

/// Numerical backend for the ARAS decision math. Resolved to a
/// [`crate::resources::adaptive::DecisionBackend`] by
/// `crate::resources::backends` (the one wiring point). Selected with
/// `--backend` on `run`/`campaign`/`daemon` or the config `"backend"`
/// key (a `--config` file, where accepted, replaces the whole config —
/// the same convention as every other option); default `scalar`. All
/// three are bit-identical on integral inputs — the contract
/// `rust/tests/backend_parity.rs` enforces.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust scalar implementation (always available; per-item).
    Scalar,
    /// Native vectorized interpreter of the compiled decision graph
    /// (always available; lane-batched, `runtime/native.rs`).
    Native,
    /// AOT-compiled XLA module loaded via PJRT (`artifacts/aras_decide.hlo.txt`).
    Pjrt,
}

impl Backend {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_lowercase().as_str() {
            "scalar" => Ok(Backend::Scalar),
            "native" | "interpreter" => Ok(Backend::Native),
            "pjrt" | "xla" => Ok(Backend::Pjrt),
            other => anyhow::bail!("unknown backend '{other}' (scalar|native|pjrt)"),
        }
    }

    /// Canonical registry name.
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Native => "native",
            Backend::Pjrt => "pjrt",
        }
    }
}

/// Workflow request arrival patterns (§6.1.4, Fig. 5a–c).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// `y = per_burst` workflows every burst, `bursts` times (paper: 5×6).
    Constant { per_burst: usize, bursts: usize },
    /// `y = k*x + d` workflows on burst x = 0.. while total < cap (paper: d=2, k=2, 30 total).
    Linear { d: usize, k: usize, total: usize },
    /// 2,4,6,4,2,... until `total` reached (paper: peak 6, 34 total).
    Pyramid { start: usize, step: usize, peak: usize, total: usize },
}

impl ArrivalPattern {
    pub fn name(&self) -> &'static str {
        match self {
            ArrivalPattern::Constant { .. } => "constant",
            ArrivalPattern::Linear { .. } => "linear",
            ArrivalPattern::Pyramid { .. } => "pyramid",
        }
    }

    /// The paper's three patterns with their §6.1.4 parameters.
    pub fn paper_constant() -> Self {
        ArrivalPattern::Constant { per_burst: 5, bursts: 6 }
    }

    pub fn paper_linear() -> Self {
        ArrivalPattern::Linear { d: 2, k: 2, total: 30 }
    }

    pub fn paper_pyramid() -> Self {
        ArrivalPattern::Pyramid { start: 2, step: 2, peak: 6, total: 34 }
    }

    /// The paper's three evaluation patterns, in Table 2 column order.
    pub fn paper_set() -> [ArrivalPattern; 3] {
        [Self::paper_constant(), Self::paper_linear(), Self::paper_pyramid()]
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_lowercase().as_str() {
            "constant" => Ok(Self::paper_constant()),
            "linear" => Ok(Self::paper_linear()),
            "pyramid" => Ok(Self::paper_pyramid()),
            other => anyhow::bail!("unknown pattern '{other}' (constant|linear|pyramid)"),
        }
    }

    /// Parameter-carrying label, e.g. `constant(5x6)` — distinguishes two
    /// patterns of the same variant with different parameters (the plain
    /// [`Self::name`] cannot).
    pub fn detail(&self) -> String {
        match *self {
            ArrivalPattern::Constant { per_burst, bursts } => {
                format!("constant({per_burst}x{bursts})")
            }
            ArrivalPattern::Linear { d, k, total } => format!("linear(d{d},k{k},n{total})"),
            ArrivalPattern::Pyramid { start, step, peak, total } => {
                format!("pyramid({start}..{peak}/{step},n{total})")
            }
        }
    }

    /// Burst sizes in order, e.g. pyramid(2,2,6,34) → [2,4,6,4,2,2,4,6,4]…
    pub fn bursts(&self) -> Vec<usize> {
        match *self {
            ArrivalPattern::Constant { per_burst, bursts } => vec![per_burst; bursts],
            ArrivalPattern::Linear { d, k, total } => {
                let mut out = Vec::new();
                let mut sum = 0;
                let mut x = 0usize;
                while sum < total {
                    let y = (d + k * x).min(total - sum);
                    out.push(y);
                    sum += y;
                    x += 1;
                }
                out
            }
            ArrivalPattern::Pyramid { start, step, peak, total } => {
                let mut out = Vec::new();
                let mut sum = 0;
                let mut y = start;
                let mut rising = true;
                while sum < total {
                    let burst = y.min(total - sum);
                    out.push(burst);
                    sum += burst;
                    if rising {
                        if y >= peak {
                            rising = false;
                            y = y.saturating_sub(step).max(start);
                        } else {
                            y += step;
                        }
                    } else if y <= start {
                        rising = true;
                        y += step;
                    } else {
                        y = y.saturating_sub(step).max(start);
                    }
                }
                out
            }
        }
    }

    /// Total workflows injected by this pattern.
    pub fn total(&self) -> usize {
        self.bursts().iter().sum()
    }
}

/// A pool of identically-shaped worker nodes. Heterogeneous clusters
/// declare several pools; nodes are named `{label}-{idx}`.
#[derive(Debug, Clone, PartialEq)]
pub struct NodePool {
    /// Nodes in this pool at cluster start.
    pub count: usize,
    /// Allocatable CPU per node, milli-cores.
    pub cpu_milli: i64,
    /// Allocatable memory per node, Mi.
    pub mem_mi: i64,
    /// Pool label (node-name prefix); must be unique across pools.
    pub label: String,
}

impl NodePool {
    pub fn new(label: impl Into<String>, count: usize, cpu_milli: i64, mem_mi: i64) -> Self {
        NodePool { count, cpu_milli, mem_mi, label: label.into() }
    }
}

/// K8s cluster shape (§6.1.1), plus the dynamics the paper's fixed
/// testbed never exercises: heterogeneous node pools, scheduled
/// node-lifecycle events, and a reactive autoscaler.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Worker node count (paper: 6; the master hosts no task pods).
    /// Ignored when explicit `pools` are configured.
    pub nodes: usize,
    /// Allocatable CPU per node, milli-cores (8 cores).
    pub node_cpu_milli: i64,
    /// Allocatable memory per node, Mi (16 GB).
    pub node_mem_mi: i64,
    /// Heterogeneous node pools. Empty (the default) = one uniform pool
    /// labeled "node" derived from the three legacy fields above, which
    /// keeps every pre-pool config bit-identical.
    pub pools: Vec<NodePool>,
    /// Scheduled node-lifecycle events (join/drain/crash), replayable
    /// from a JSON trace (`cluster::dynamics`).
    pub events: Vec<ClusterEvent>,
    /// Reactive autoscaler; None = static cluster.
    pub autoscaler: Option<AutoscalerConfig>,
}

impl ClusterConfig {
    /// The pools this config resolves to: explicit pools, or the single
    /// legacy-derived default pool.
    pub fn effective_pools(&self) -> Vec<NodePool> {
        if self.pools.is_empty() {
            vec![NodePool::new("node", self.nodes, self.node_cpu_milli, self.node_mem_mi)]
        } else {
            self.pools.clone()
        }
    }

    /// Total nodes at cluster start.
    pub fn initial_nodes(&self) -> usize {
        self.effective_pools().iter().map(|p| p.count).sum()
    }
}

impl Default for ClusterConfig {
    fn default() -> Self {
        // 8-core / 16 GB workers (§6.1.1). Allocatable memory sits well
        // below raw capacity: kubelet system/eviction reservations plus
        // the pods the paper's testbed co-hosts on the workers
        // (kube-system DaemonSets, the containerized Workflow Injector
        // and Containerized Workflow Builder deployments, Redis). 10 GB
        // allocatable per worker calibrates the reproduction's
        // ARAS-vs-baseline factors to the paper's Table 2 band (see
        // EXPERIMENTS.md §Calibration); memory is the binding dimension
        // at 2 Guaranteed 4000Mi pods per node.
        Self {
            nodes: 6,
            node_cpu_milli: 8000,
            node_mem_mi: 10240,
            pools: Vec::new(),
            events: Vec::new(),
            autoscaler: None,
        }
    }
}

/// Engine/cluster timing constants (virtual seconds).
#[derive(Debug, Clone)]
pub struct TimingConfig {
    /// Pod image-pull + container start latency once scheduled.
    pub pod_startup_s: f64,
    /// Deletion round-trip for completed/OOM pods (paper's Fig. 9 shows
    /// tens of seconds of cleanup delay under load).
    pub pod_delete_s: f64,
    /// Informer cache sync latency (List-Watch propagation).
    pub informer_latency_s: f64,
    /// Interval between retry scans when requests wait for resources.
    pub retry_interval_s: f64,
    /// Delay before an under-provisioned pod hits OOM (fraction of its
    /// duration; Fig. 9 shows OOM at ~2/3 of what would have been the run).
    pub oom_after_frac: f64,
}

impl Default for TimingConfig {
    fn default() -> Self {
        // Calibrated to the paper's testbed (§6.2.1): per-workflow
        // durations of ~5.7 min for a depth-8 Montage with U[10,20]s
        // tasks imply a pod cycle (create+schedule+pull+start ... delete+
        // feedback) of ~25 s per level on their cluster.
        Self {
            pod_startup_s: 12.0,
            pod_delete_s: 12.0,
            informer_latency_s: 1.0,
            // K8s informer resync default (the baseline's only recovery
            // path from a stalled allocation; Fig. 9 reaction latency).
            retry_interval_s: 30.0,
            oom_after_frac: 0.3,
        }
    }
}

/// Resource-allocation parameters (§5).
#[derive(Debug, Clone)]
pub struct AllocConfig {
    pub policy: PolicySpec,
    pub backend: Backend,
    /// Eq. (9) scale factor for max-node fallbacks (paper: 0.8).
    pub alpha: f64,
    /// Memory headroom constant in Mi (paper: β ≥ 20).
    pub beta_mi: f64,
    /// When true (Table 2 runs), an allocation below `min + β` waits and
    /// retries instead of launching a doomed pod; when false (Fig. 9),
    /// the pod launches and OOMs — exercising self-healing.
    pub strict_min: bool,
    /// ARAS lookahead: consider future task records within the current
    /// task's lifecycle (Alg. 1 lines 8–13). Disabling is ablation A2.
    pub lookahead: bool,
}

impl Default for AllocConfig {
    fn default() -> Self {
        Self {
            policy: PolicySpec::adaptive(),
            backend: Backend::Scalar,
            alpha: 0.8,
            beta_mi: 20.0,
            strict_min: true,
            lookahead: true,
        }
    }
}

/// Per-task resource parameters (§6.1.3).
#[derive(Debug, Clone)]
pub struct TaskConfig {
    /// Requested CPU per task pod (milli-cores).
    pub req_cpu_milli: i64,
    /// Requested memory per task pod (Mi).
    pub req_mem_mi: i64,
    /// Minimum CPU to run the container.
    pub min_cpu_milli: i64,
    /// Minimum memory (the Stress tool's allocation).
    pub min_mem_mi: i64,
    /// Task duration sampled U[lo, hi] seconds.
    pub duration_lo_s: f64,
    pub duration_hi_s: f64,
}

impl Default for TaskConfig {
    fn default() -> Self {
        Self {
            req_cpu_milli: 2000,
            req_mem_mi: 4000,
            min_cpu_milli: 200,
            min_mem_mi: 1000,
            duration_lo_s: 10.0,
            duration_hi_s: 20.0,
        }
    }
}

/// Workload shape: which workflow, how many, how they arrive.
#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub workflow: WorkflowType,
    pub pattern: ArrivalPattern,
    /// Seconds between request bursts (paper: 300).
    pub burst_interval_s: f64,
    pub seed: u64,
    /// Optional SLA: each workflow gets `deadline = estimated makespan ×
    /// slack` at injection (Eqs. 2–4; the paper assumes deadlines are
    /// "valid and achievable", i.e. slack > 1). None disables SLA
    /// tracking (the Table 2 runs don't report violations).
    pub deadline_slack: Option<f64>,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        Self {
            workflow: WorkflowType::Montage,
            pattern: ArrivalPattern::paper_constant(),
            burst_interval_s: 300.0,
            seed: 42,
            deadline_slack: None,
        }
    }
}

/// Top-level experiment configuration.
#[derive(Debug, Clone, Default)]
pub struct ExperimentConfig {
    pub cluster: ClusterConfig,
    pub timing: TimingConfig,
    pub alloc: AllocConfig,
    pub task: TaskConfig,
    pub workload: WorkloadConfig,
    /// Demand forecasting (off by default).
    pub forecast: ForecastConfig,
    /// Chaos fault injection (off by default — the empty scenario list
    /// schedules nothing and keeps runs bit-identical to pre-chaos
    /// builds, golden-trace locked).
    pub chaos: ChaosConfig,
    /// Metrics sampling interval for usage curves (virtual seconds).
    pub sample_interval_s: f64,
    /// Snapshot maintenance strategy (full rebuild by default).
    pub snapshot_mode: SnapshotMode,
    /// Daemon-mode settings; `None` for batch runs.
    pub daemon: Option<DaemonConfig>,
    /// Multi-cluster federation; `None` (the default) runs the ordinary
    /// single-cluster engine, bit-identical to pre-federation builds
    /// (golden-trace locked).
    pub federation: Option<FederationConfig>,
}

impl ExperimentConfig {
    /// Paper-default config for a given workflow/pattern/policy triple.
    pub fn paper(workflow: WorkflowType, pattern: ArrivalPattern, policy: PolicySpec) -> Self {
        let mut cfg = ExperimentConfig::default();
        cfg.workload.workflow = workflow;
        cfg.workload.pattern = pattern;
        cfg.alloc.policy = policy;
        cfg
    }

    /// Load overrides from a JSON object; unknown keys are rejected.
    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let mut cfg = ExperimentConfig::default();
        let obj = j.as_obj().ok_or_else(|| anyhow::anyhow!("config must be an object"))?;
        for (k, v) in obj {
            match k.as_str() {
                "nodes" => cfg.cluster.nodes = req_i64(v, k)? as usize,
                "node_cpu_milli" => cfg.cluster.node_cpu_milli = req_i64(v, k)?,
                "node_mem_mi" => cfg.cluster.node_mem_mi = req_i64(v, k)?,
                "alpha" => cfg.alloc.alpha = req_f64(v, k)?,
                "beta_mi" => cfg.alloc.beta_mi = req_f64(v, k)?,
                "policy" => cfg.alloc.policy = PolicySpec::parse(req_str(v, k)?)?,
                "backend" => cfg.alloc.backend = Backend::parse(req_str(v, k)?)?,
                "strict_min" => cfg.alloc.strict_min = req_bool(v, k)?,
                "lookahead" => cfg.alloc.lookahead = req_bool(v, k)?,
                "workflow" => cfg.workload.workflow = WorkflowType::parse(req_str(v, k)?)?,
                "pattern" => cfg.workload.pattern = ArrivalPattern::parse(req_str(v, k)?)?,
                "burst_interval_s" => cfg.workload.burst_interval_s = req_f64(v, k)?,
                "seed" => cfg.workload.seed = req_i64(v, k)? as u64,
                "deadline_slack" => cfg.workload.deadline_slack = Some(req_f64(v, k)?),
                "req_cpu_milli" => cfg.task.req_cpu_milli = req_i64(v, k)?,
                "req_mem_mi" => cfg.task.req_mem_mi = req_i64(v, k)?,
                "min_cpu_milli" => cfg.task.min_cpu_milli = req_i64(v, k)?,
                "min_mem_mi" => cfg.task.min_mem_mi = req_i64(v, k)?,
                "duration_lo_s" => cfg.task.duration_lo_s = req_f64(v, k)?,
                "duration_hi_s" => cfg.task.duration_hi_s = req_f64(v, k)?,
                "pod_startup_s" => cfg.timing.pod_startup_s = req_f64(v, k)?,
                "pod_delete_s" => cfg.timing.pod_delete_s = req_f64(v, k)?,
                "retry_interval_s" => cfg.timing.retry_interval_s = req_f64(v, k)?,
                "forecaster" => {
                    cfg.forecast.forecaster = Some(ForecasterSpec::parse(req_str(v, k)?)?)
                }
                "forecast_horizon_s" => cfg.forecast.horizon_s = req_f64(v, k)?,
                "pools" => cfg.cluster.pools = parse_pools(v)?,
                "cluster_events" => cfg.cluster.events = dynamics::events_from_json(v)?,
                "chaos_scenarios" => {
                    cfg.chaos.scenarios = crate::chaos::scenarios_from_json(v)?
                }
                "autoscaler" => {
                    cfg.cluster.autoscaler = Some(AutoscalerConfig::from_json(v)?)
                }
                "snapshot_mode" => cfg.snapshot_mode = SnapshotMode::parse(req_str(v, k)?)?,
                "daemon" => cfg.daemon = Some(parse_daemon(v)?),
                "federation" => cfg.federation = Some(parse_federation(v)?),
                other => anyhow::bail!("unknown config key '{other}'"),
            }
        }
        Ok(cfg)
    }

    pub fn from_json_str(s: &str) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse(s)?)
    }

    /// Validate invariants before a run.
    pub fn validate(&self) -> anyhow::Result<()> {
        let pools = self.cluster.effective_pools();
        anyhow::ensure!(self.cluster.initial_nodes() > 0, "need at least one node");
        for (i, pool) in pools.iter().enumerate() {
            anyhow::ensure!(pool.count > 0, "pool '{}' has zero nodes", pool.label);
            anyhow::ensure!(!pool.label.is_empty(), "pool {i} has an empty label");
            anyhow::ensure!(
                pool.cpu_milli > 0 && pool.mem_mi > 0,
                "pool '{}' has non-positive capacity",
                pool.label
            );
            anyhow::ensure!(
                !pools[..i].iter().any(|p| p.label == pool.label),
                "duplicate pool label '{}'",
                pool.label
            );
        }
        // Exclusive lower bound: α = 0 would zero every fallback
        // allocation (Eq. 9 scales by α), which the paper's (0,1] range
        // rules out.
        anyhow::ensure!(
            self.alloc.alpha > 0.0 && self.alloc.alpha <= 1.0,
            "alpha in (0,1]"
        );
        anyhow::ensure!(self.alloc.beta_mi >= 0.0, "beta >= 0");
        anyhow::ensure!(self.task.duration_lo_s <= self.task.duration_hi_s, "duration range");
        anyhow::ensure!(
            self.forecast.horizon_s.is_finite() && self.forecast.horizon_s > 0.0,
            "forecast horizon must be finite and > 0, got {}",
            self.forecast.horizon_s
        );
        // At least one pool must be able to host a full-request task pod,
        // or every run would stall on an unschedulable head.
        let max_cpu = pools.iter().map(|p| p.cpu_milli).max().unwrap_or(0);
        let max_mem = pools.iter().map(|p| p.mem_mi).max().unwrap_or(0);
        anyhow::ensure!(
            self.task.req_cpu_milli <= max_cpu,
            "task request exceeds node capacity"
        );
        anyhow::ensure!(
            self.task.req_mem_mi <= max_mem,
            "task memory request exceeds node capacity"
        );
        // Cluster events must reference known pools and carry sane times.
        for (i, ev) in self.cluster.events.iter().enumerate() {
            anyhow::ensure!(
                ev.at.is_finite() && ev.at >= 0.0,
                "cluster event {i}: bad time {}",
                ev.at
            );
            if let crate::cluster::ClusterEventKind::Join { pool, count } = &ev.kind {
                anyhow::ensure!(*count > 0, "cluster event {i}: zero-count join");
                anyhow::ensure!(
                    pools.iter().any(|p| &p.label == pool),
                    "cluster event {i}: join references unknown pool '{pool}'"
                );
            }
        }
        if let Some(asc) = &self.cluster.autoscaler {
            asc.validate()?;
            if let Some(pool) = &asc.pool {
                anyhow::ensure!(
                    pools.iter().any(|p| &p.label == pool),
                    "autoscaler references unknown pool '{pool}'"
                );
            }
        }
        self.chaos.validate()?;
        if let Some(daemon) = &self.daemon {
            daemon.validate()?;
        }
        if let Some(federation) = &self.federation {
            federation.validate()?;
        }
        Ok(())
    }
}

/// Per-cluster overlay on a federation's base [`ExperimentConfig`].
/// Every field except `name` is optional: `None`/empty means "inherit
/// the base", so a homogeneous federation is just N named specs.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterSpec {
    /// Cluster identity — report label, metric label value and the
    /// coordinate fed into `derive_seed` alongside the cluster index.
    pub name: String,
    /// Static routing weight for the `weighted` router; must be finite
    /// and > 0. Other routers ignore it.
    pub weight: f64,
    /// Node-count override (`None` = base cluster size).
    pub nodes: Option<usize>,
    /// Allocation-policy override.
    pub policy: Option<PolicySpec>,
    /// Forecaster override.
    pub forecaster: Option<ForecasterSpec>,
    /// Autoscaler override.
    pub autoscaler: Option<AutoscalerConfig>,
    /// Extra scheduled churn for this cluster only (appended to the
    /// base event list) — how a regional outage is pinned to one
    /// cluster.
    pub events: Vec<ClusterEvent>,
    /// Extra chaos scenarios for this cluster only.
    pub chaos: Vec<crate::chaos::ChaosScenario>,
}

impl ClusterSpec {
    /// A cluster that inherits everything from the base config.
    pub fn named(name: impl Into<String>) -> Self {
        Self {
            name: name.into(),
            weight: 1.0,
            nodes: None,
            policy: None,
            forecaster: None,
            autoscaler: None,
            events: Vec::new(),
            chaos: Vec::new(),
        }
    }

    /// Builder-style weight attachment.
    pub fn with_weight(mut self, weight: f64) -> Self {
        self.weight = weight;
        self
    }

    /// Builder-style node-count override.
    pub fn with_nodes(mut self, nodes: usize) -> Self {
        self.nodes = Some(nodes);
        self
    }

    /// Materialize this cluster's standalone config: the base overlaid
    /// with every `Some`/non-empty field. The result has `federation`
    /// cleared — a member cluster is always an ordinary single-cluster
    /// engine (federations don't nest).
    pub fn apply(&self, base: &ExperimentConfig) -> ExperimentConfig {
        let mut cfg = base.clone();
        cfg.federation = None;
        if let Some(nodes) = self.nodes {
            cfg.cluster.nodes = nodes;
        }
        if let Some(policy) = &self.policy {
            cfg.alloc.policy = policy.clone();
        }
        if let Some(forecaster) = &self.forecaster {
            cfg.forecast.forecaster = Some(forecaster.clone());
        }
        if let Some(autoscaler) = &self.autoscaler {
            cfg.cluster.autoscaler = Some(autoscaler.clone());
        }
        cfg.cluster.events.extend(self.events.iter().cloned());
        cfg.chaos.scenarios.extend(self.chaos.iter().cloned());
        cfg
    }
}

/// Multi-cluster federation: N member clusters behind one global
/// router, sharing a virtual clock. Strictly opt-in — the subsystem is
/// inert unless [`ExperimentConfig::federation`] is `Some`.
#[derive(Debug, Clone, PartialEq)]
pub struct FederationConfig {
    /// Member clusters (≥ 1, unique names).
    pub clusters: Vec<ClusterSpec>,
    /// Global routing strategy.
    pub router: RouterSpec,
    /// Forecast horizon (virtual seconds) the router queries each
    /// cluster at when scoring a submission.
    pub submit_horizon_s: f64,
    /// Spill off the first-choice cluster when its allocation-queue
    /// depth exceeds this.
    pub spill_queue_depth: usize,
    /// Spill off the first-choice cluster when its stale-snapshot rate
    /// (stale serve cycles / serve cycles) exceeds this.
    pub spill_stale_rate: f64,
}

impl Default for FederationConfig {
    fn default() -> Self {
        Self {
            clusters: Vec::new(),
            router: RouterSpec::default(),
            submit_horizon_s: 60.0,
            spill_queue_depth: 8,
            spill_stale_rate: 0.5,
        }
    }
}

impl FederationConfig {
    /// A homogeneous federation of `k` clusters named `c0..c{k-1}`.
    pub fn homogeneous(k: usize, router: RouterSpec) -> Self {
        Self {
            clusters: (0..k).map(|i| ClusterSpec::named(format!("c{i}"))).collect(),
            router,
            ..Self::default()
        }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.clusters.is_empty(),
            "federation needs at least one cluster (got zero; drop the \
             'federation' block for a single-cluster run)"
        );
        for (i, c) in self.clusters.iter().enumerate() {
            anyhow::ensure!(c.name.trim() != "", "federation cluster {i} has an empty name");
            anyhow::ensure!(
                !self.clusters[..i].iter().any(|p| p.name == c.name),
                "duplicate federation cluster name '{}' (names key per-cluster \
                 seeds, reports and metric labels, so they must be unique)",
                c.name
            );
            anyhow::ensure!(
                c.weight.is_finite() && c.weight > 0.0,
                "federation cluster '{}' has router weight {} (must be finite and > 0)",
                c.name,
                c.weight
            );
            if let Some(nodes) = c.nodes {
                anyhow::ensure!(nodes > 0, "federation cluster '{}' has zero nodes", c.name);
            }
        }
        anyhow::ensure!(
            self.submit_horizon_s.is_finite() && self.submit_horizon_s > 0.0,
            "federation submit horizon must be finite and > 0, got {}",
            self.submit_horizon_s
        );
        anyhow::ensure!(
            self.spill_stale_rate.is_finite() && self.spill_stale_rate >= 0.0,
            "federation spill stale-rate threshold must be finite and >= 0, got {}",
            self.spill_stale_rate
        );
        Ok(())
    }
}

/// Parse the `"daemon"` config object:
/// `{"listen": "unix:/tmp/ka.sock", "pace": 10, "hold": false,
///   "sources": [{"schedule": "every 5m", "workflow": "montage", "count": 2}]}`.
fn parse_daemon(v: &Json) -> anyhow::Result<DaemonConfig> {
    let obj = v.as_obj().ok_or_else(|| anyhow::anyhow!("'daemon' must be an object"))?;
    let mut cfg = DaemonConfig::default();
    for (k, v) in obj {
        match k.as_str() {
            "listen" => cfg.listen = req_str(v, k)?.to_string(),
            "pace" => cfg.pace = Some(req_f64(v, k)?),
            "hold" => cfg.hold = req_bool(v, k)?,
            "sources" => {
                let arr =
                    v.as_arr().ok_or_else(|| anyhow::anyhow!("'sources' must be an array"))?;
                let mut sources = Vec::with_capacity(arr.len());
                for (i, s) in arr.iter().enumerate() {
                    let obj = s
                        .as_obj()
                        .ok_or_else(|| anyhow::anyhow!("daemon source {i} must be an object"))?;
                    let mut src = ScheduleSource {
                        schedule: String::new(),
                        workflow: WorkflowType::Montage,
                        count: 1,
                    };
                    for (k, v) in obj {
                        match k.as_str() {
                            "schedule" => src.schedule = req_str(v, k)?.to_string(),
                            "workflow" => src.workflow = WorkflowType::parse(req_str(v, k)?)?,
                            "count" => src.count = req_i64(v, k)? as usize,
                            other => anyhow::bail!("daemon source {i}: unknown key '{other}'"),
                        }
                    }
                    anyhow::ensure!(!src.schedule.is_empty(), "daemon source {i}: missing 'schedule'");
                    sources.push(src);
                }
                cfg.sources = sources;
            }
            other => anyhow::bail!("daemon config: unknown key '{other}'"),
        }
    }
    Ok(cfg)
}

/// Parse the `"federation"` config object:
/// `{"router": "forecast-headroom", "submit_horizon_s": 60,
///   "spill_queue_depth": 8, "spill_stale_rate": 0.5,
///   "clusters": [{"name": "east", "weight": 2, "nodes": 8,
///                 "policy": "adaptive", "forecaster": "seasonal"}]}`.
fn parse_federation(v: &Json) -> anyhow::Result<FederationConfig> {
    let obj = v.as_obj().ok_or_else(|| anyhow::anyhow!("'federation' must be an object"))?;
    let mut cfg = FederationConfig::default();
    for (k, v) in obj {
        match k.as_str() {
            "router" => cfg.router = RouterSpec::parse(req_str(v, k)?)?,
            "submit_horizon_s" => cfg.submit_horizon_s = req_f64(v, k)?,
            "spill_queue_depth" => cfg.spill_queue_depth = req_i64(v, k)? as usize,
            "spill_stale_rate" => cfg.spill_stale_rate = req_f64(v, k)?,
            "clusters" => {
                let arr =
                    v.as_arr().ok_or_else(|| anyhow::anyhow!("'clusters' must be an array"))?;
                let mut clusters = Vec::with_capacity(arr.len());
                for (i, c) in arr.iter().enumerate() {
                    let obj = c.as_obj().ok_or_else(|| {
                        anyhow::anyhow!("federation cluster {i} must be an object")
                    })?;
                    let mut spec = ClusterSpec::named("");
                    for (k, v) in obj {
                        match k.as_str() {
                            "name" => spec.name = req_str(v, k)?.to_string(),
                            "weight" => spec.weight = req_f64(v, k)?,
                            "nodes" => spec.nodes = Some(req_i64(v, k)? as usize),
                            "policy" => spec.policy = Some(PolicySpec::parse(req_str(v, k)?)?),
                            "forecaster" => {
                                spec.forecaster = Some(ForecasterSpec::parse(req_str(v, k)?)?)
                            }
                            "autoscaler" => {
                                spec.autoscaler = Some(AutoscalerConfig::from_json(v)?)
                            }
                            "events" => spec.events = dynamics::events_from_json(v)?,
                            "chaos" => spec.chaos = crate::chaos::scenarios_from_json(v)?,
                            other => {
                                anyhow::bail!("federation cluster {i}: unknown key '{other}'")
                            }
                        }
                    }
                    anyhow::ensure!(!spec.name.is_empty(), "federation cluster {i}: missing 'name'");
                    clusters.push(spec);
                }
                cfg.clusters = clusters;
            }
            other => anyhow::bail!("federation config: unknown key '{other}'"),
        }
    }
    Ok(cfg)
}

/// Parse the `"pools"` config array:
/// `[{"label": "big", "count": 2, "cpu_milli": 16000, "mem_mi": 32768}, ...]`.
fn parse_pools(v: &Json) -> anyhow::Result<Vec<NodePool>> {
    let arr = v.as_arr().ok_or_else(|| anyhow::anyhow!("'pools' must be an array"))?;
    let mut pools = Vec::with_capacity(arr.len());
    for (i, p) in arr.iter().enumerate() {
        let obj = p.as_obj().ok_or_else(|| anyhow::anyhow!("pool {i} must be an object"))?;
        let mut pool = NodePool::new("", 0, 0, 0);
        for (k, v) in obj {
            match k.as_str() {
                "label" => pool.label = req_str(v, k)?.to_string(),
                "count" => pool.count = req_i64(v, k)? as usize,
                "cpu_milli" => pool.cpu_milli = req_i64(v, k)?,
                "mem_mi" => pool.mem_mi = req_i64(v, k)?,
                other => anyhow::bail!("pool {i}: unknown key '{other}'"),
            }
        }
        anyhow::ensure!(!pool.label.is_empty(), "pool {i}: missing 'label'");
        pools.push(pool);
    }
    Ok(pools)
}

fn req_f64(v: &Json, k: &str) -> anyhow::Result<f64> {
    v.as_f64().ok_or_else(|| anyhow::anyhow!("key '{k}' must be a number"))
}

fn req_i64(v: &Json, k: &str) -> anyhow::Result<i64> {
    v.as_i64().ok_or_else(|| anyhow::anyhow!("key '{k}' must be a number"))
}

fn req_str<'a>(v: &'a Json, k: &str) -> anyhow::Result<&'a str> {
    v.as_str().ok_or_else(|| anyhow::anyhow!("key '{k}' must be a string"))
}

fn req_bool(v: &Json, k: &str) -> anyhow::Result<bool> {
    v.as_bool().ok_or_else(|| anyhow::anyhow!("key '{k}' must be a bool"))
}

impl Default for WorkflowType {
    fn default() -> Self {
        WorkflowType::Montage
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_patterns_total_correctly() {
        assert_eq!(ArrivalPattern::paper_constant().total(), 30);
        assert_eq!(ArrivalPattern::paper_linear().total(), 30);
        assert_eq!(ArrivalPattern::paper_pyramid().total(), 34);
    }

    #[test]
    fn linear_bursts_rise() {
        let b = ArrivalPattern::paper_linear().bursts();
        assert_eq!(b, vec![2, 4, 6, 8, 10]);
    }

    #[test]
    fn pyramid_bursts_rise_and_fall() {
        let b = ArrivalPattern::paper_pyramid().bursts();
        assert_eq!(b.iter().sum::<usize>(), 34);
        assert_eq!(&b[..3], &[2, 4, 6]);
        assert!(b[3] < b[2], "must descend after peak: {b:?}");
    }

    #[test]
    fn from_json_overrides() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{"nodes": 3, "alpha": 0.5, "policy": "fcfs", "workflow": "ligo"}"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.nodes, 3);
        assert_eq!(cfg.alloc.alpha, 0.5);
        assert_eq!(cfg.alloc.policy, PolicySpec::fcfs());
        assert_eq!(cfg.workload.workflow, WorkflowType::Ligo);
    }

    #[test]
    fn policy_spec_parses_names_aliases_and_params() {
        assert_eq!(PolicySpec::parse("adaptive").unwrap(), PolicySpec::adaptive());
        assert_eq!(PolicySpec::parse("ARAS").unwrap(), PolicySpec::adaptive());
        assert_eq!(PolicySpec::parse("fcfs").unwrap(), PolicySpec::fcfs());
        assert_eq!(PolicySpec::parse("baseline").unwrap(), PolicySpec::fcfs());
        // Programmatic construction canonicalizes the same way.
        assert_eq!(PolicySpec::named("ARAS"), PolicySpec::adaptive());
        assert_eq!(PolicySpec::named("FCFS"), PolicySpec::fcfs());

        let spec = PolicySpec::parse("rate-capped:budget=3,lookahead=off").unwrap();
        assert_eq!(spec.name, "rate-capped");
        assert_eq!(spec.param("budget"), Some(3.0));
        assert_eq!(spec.param("lookahead"), Some(0.0));
        // Params are sorted: input order does not affect equality.
        assert_eq!(spec, PolicySpec::parse("rate-capped:lookahead=false,budget=3").unwrap());

        assert!(PolicySpec::parse("").is_err());
        assert!(PolicySpec::parse("x:noequals").is_err());
        assert!(PolicySpec::parse("x:k=notanumber").is_err());
        assert!(PolicySpec::parse("x:k=1,k=2").is_err());
    }

    #[test]
    fn policy_spec_labels_match_legacy_names() {
        assert_eq!(PolicySpec::adaptive().label(), "adaptive");
        assert_eq!(PolicySpec::fcfs().label(), "baseline");
        assert_eq!(
            PolicySpec::named("static-headroom").with_param("headroom", 1.5).label(),
            "static-headroom:headroom=1.5"
        );
    }

    #[test]
    fn forecaster_spec_parses_and_labels() {
        assert_eq!(ForecasterSpec::parse("seasonal").unwrap(), ForecasterSpec::named("seasonal"));
        assert_eq!(ForecasterSpec::parse("HOLT").unwrap().name, "holt");
        // Built-in aliases canonicalize on both construction paths, so
        // a config-file "ewma" and a CLI "holt" share one report label
        // and the campaign duplicate-axis check sees them as equal.
        assert_eq!(ForecasterSpec::parse("ewma").unwrap().name, "holt");
        assert_eq!(ForecasterSpec::named("EWMA"), ForecasterSpec::named("holt"));
        assert_eq!(ForecasterSpec::parse("holt-winters").unwrap().name, "seasonal");
        assert_eq!(ForecasterSpec::named("last").name, "naive-last");
        let spec = ForecasterSpec::parse("seasonal:period=120,buckets=6").unwrap();
        assert_eq!(spec.param("period"), Some(120.0));
        assert_eq!(spec.param("buckets"), Some(6.0));
        // Params are sorted: input order does not affect equality.
        assert_eq!(spec, ForecasterSpec::parse("seasonal:buckets=6,period=120").unwrap());
        assert_eq!(spec.label(), "seasonal:buckets=6,period=120");
        assert_eq!(ForecasterSpec::named("holt").label(), "holt");
        assert!(ForecasterSpec::parse("").is_err());
        assert!(ForecasterSpec::parse("x:noequals").is_err());
        assert!(ForecasterSpec::parse("x:k=notanumber").is_err());
        assert!(ForecasterSpec::parse("x:k=1,k=2").is_err());
    }

    #[test]
    fn from_json_parses_forecast_config() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{"forecaster": "holt:alpha=0.4", "forecast_horizon_s": 45}"#,
        )
        .unwrap();
        let spec = cfg.forecast.forecaster.unwrap();
        assert_eq!(spec.name, "holt");
        assert_eq!(spec.param("alpha"), Some(0.4));
        assert_eq!(cfg.forecast.horizon_s, 45.0);
        // Default: forecasting off.
        let cfg = ExperimentConfig::default();
        assert!(cfg.forecast.forecaster.is_none());
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn validate_rejects_bad_forecast_horizon() {
        let mut cfg = ExperimentConfig::default();
        cfg.forecast.horizon_s = 0.0;
        assert!(cfg.validate().is_err());
        cfg.forecast.horizon_s = f64::INFINITY;
        assert!(cfg.validate().is_err());
        cfg.forecast.horizon_s = 30.0;
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn validate_rejects_alpha_zero() {
        // Regression: the old check used an inclusive range `0.0..=1.0`
        // while the error message (and the paper) say (0,1].
        let mut cfg = ExperimentConfig::default();
        cfg.alloc.alpha = 0.0;
        assert!(cfg.validate().is_err(), "alpha = 0 must be rejected");
        cfg.alloc.alpha = -0.1;
        assert!(cfg.validate().is_err());
        cfg.alloc.alpha = 1.0;
        assert!(cfg.validate().is_ok(), "alpha = 1 is the inclusive upper bound");
        cfg.alloc.alpha = f64::MIN_POSITIVE;
        assert!(cfg.validate().is_ok(), "any positive alpha is valid");
    }

    #[test]
    fn from_json_rejects_unknown_keys() {
        assert!(ExperimentConfig::from_json_str(r#"{"nope": 1}"#).is_err());
    }

    #[test]
    fn from_json_parses_cluster_dynamics() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{
                "pools": [
                    {"label": "big", "count": 2, "cpu_milli": 16000, "mem_mi": 32768},
                    {"label": "small", "count": 4, "cpu_milli": 4000, "mem_mi": 8192}
                ],
                "cluster_events": [{"at": 300, "kind": "drain", "node": "small-0"}],
                "autoscaler": {"min_nodes": 2, "max_nodes": 10}
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.cluster.pools.len(), 2);
        assert_eq!(cfg.cluster.initial_nodes(), 6);
        assert_eq!(cfg.cluster.events.len(), 1);
        assert_eq!(cfg.cluster.autoscaler.as_ref().unwrap().max_nodes, 10);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn effective_pools_default_is_legacy_shape() {
        let cfg = ExperimentConfig::default();
        let pools = cfg.cluster.effective_pools();
        assert_eq!(pools.len(), 1);
        assert_eq!(pools[0], NodePool::new("node", 6, 8000, 10240));
        assert_eq!(cfg.cluster.initial_nodes(), 6);
    }

    #[test]
    fn validate_rejects_bad_cluster_dynamics() {
        use crate::cluster::{ClusterEvent, ClusterEventKind};
        // Duplicate pool labels.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.pools =
            vec![NodePool::new("a", 1, 8000, 10240), NodePool::new("a", 1, 8000, 10240)];
        assert!(cfg.validate().is_err());
        // Join referencing an unknown pool.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.events = vec![ClusterEvent {
            at: 10.0,
            kind: ClusterEventKind::Join { pool: "ghost".into(), count: 1 },
        }];
        assert!(cfg.validate().is_err());
        // Non-finite event time.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.events =
            vec![ClusterEvent { at: f64::NAN, kind: ClusterEventKind::Drain { node: None } }];
        assert!(cfg.validate().is_err());
        // Inverted autoscaler bounds.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.autoscaler = Some(crate::cluster::AutoscalerConfig::bounded(9, 3));
        assert!(cfg.validate().is_err());
        // Task pod that fits no pool.
        let mut cfg = ExperimentConfig::default();
        cfg.cluster.pools = vec![NodePool::new("tiny", 4, 1000, 2000)];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn from_json_parses_snapshot_mode_and_daemon() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{
                "snapshot_mode": "incremental",
                "daemon": {
                    "listen": "tcp:127.0.0.1:7421",
                    "pace": 60,
                    "hold": false,
                    "sources": [
                        {"schedule": "every 5m", "workflow": "ligo", "count": 2},
                        {"schedule": "at 60 repeat 10", "workflow": "montage", "count": 1}
                    ]
                }
            }"#,
        )
        .unwrap();
        assert_eq!(cfg.snapshot_mode, SnapshotMode::Incremental);
        let d = cfg.daemon.as_ref().unwrap();
        assert_eq!(d.listen, "tcp:127.0.0.1:7421");
        assert_eq!(d.pace, Some(60.0));
        assert_eq!(d.sources.len(), 2);
        assert_eq!(d.sources[0].workflow, WorkflowType::Ligo);
        assert!(cfg.validate().is_ok());
        // Defaults: full snapshots, no daemon.
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.snapshot_mode, SnapshotMode::Full);
        assert!(cfg.daemon.is_none());
        // Mode aliases and rejection.
        assert_eq!(SnapshotMode::parse("inc").unwrap(), SnapshotMode::Incremental);
        assert_eq!(SnapshotMode::parse("VERIFY").unwrap(), SnapshotMode::Verify);
        assert!(SnapshotMode::parse("delta").is_err());
        assert!(ExperimentConfig::from_json_str(r#"{"snapshot_mode": "nope"}"#).is_err());
        // Unknown daemon keys are rejected.
        assert!(ExperimentConfig::from_json_str(r#"{"daemon": {"nope": 1}}"#).is_err());
    }

    #[test]
    fn daemon_config_validation() {
        let mut d = DaemonConfig::default();
        assert!(d.validate().is_ok(), "default listen address must validate");
        d.listen = "udp:nope".into();
        assert!(d.validate().is_err());
        d.listen = "unix:".into();
        assert!(d.validate().is_err());
        d.listen = "tcp:127.0.0.1:notaport".into();
        assert!(d.validate().is_err());
        d.listen = "tcp:127.0.0.1:7421".into();
        assert!(d.validate().is_ok());
        d.pace = Some(0.0);
        assert!(d.validate().is_err());
        d.pace = Some(f64::INFINITY);
        assert!(d.validate().is_err());
        d.pace = Some(10.0);
        assert!(d.validate().is_ok());
        // Sources: schedule must parse and count must be positive.
        d.sources = vec![ScheduleSource {
            schedule: "every 0m".into(),
            workflow: WorkflowType::Montage,
            count: 1,
        }];
        assert!(d.validate().is_err());
        d.sources[0].schedule = "every 5m".into();
        d.sources[0].count = 0;
        assert!(d.validate().is_err());
        d.sources[0].count = 3;
        assert!(d.validate().is_ok());
    }

    #[test]
    fn from_json_parses_chaos_scenarios() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{"chaos_scenarios": [
                {"at": 120, "kind": "cpu-hog", "duration": 300, "magnitude": 4000},
                {"at": 600, "kind": "partition", "duration": 90}
            ]}"#,
        )
        .unwrap();
        assert_eq!(cfg.chaos.scenarios.len(), 2);
        assert!(cfg.validate().is_ok());
        // Default: chaos off.
        assert!(ExperimentConfig::default().chaos.is_quiet());
        // Bad scenarios are rejected at parse time...
        assert!(ExperimentConfig::from_json_str(
            r#"{"chaos_scenarios": [{"at": -1, "kind": "partition", "duration": 5}]}"#
        )
        .is_err());
        // ...and programmatic mistakes at validate time.
        let mut cfg = ExperimentConfig::default();
        cfg.chaos.scenarios = vec![crate::chaos::ChaosScenario {
            at: 0.0,
            duration: -1.0,
            kind: crate::chaos::ChaosKind::Partition,
            node: None,
            magnitude: 0.0,
        }];
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validate_catches_oversized_tasks() {
        let mut cfg = ExperimentConfig::default();
        cfg.task.req_cpu_milli = 99999;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn defaults_match_paper() {
        let cfg = ExperimentConfig::default();
        assert_eq!(cfg.cluster.nodes, 6);
        assert_eq!(cfg.cluster.node_cpu_milli, 8000);
        assert_eq!(cfg.task.req_cpu_milli, 2000);
        assert_eq!(cfg.task.req_mem_mi, 4000);
        assert_eq!(cfg.alloc.alpha, 0.8);
        assert!(cfg.validate().is_ok());
    }

    #[test]
    fn router_spec_parses_aliases_and_params() {
        assert_eq!(RouterSpec::parse("rr").unwrap().name, "round-robin");
        assert_eq!(RouterSpec::parse("LQ").unwrap().name, "least-queue");
        assert_eq!(RouterSpec::named("headroom").name, "forecast-headroom");
        assert_eq!(RouterSpec::named("WRR"), RouterSpec::named("weighted"));
        assert_eq!(RouterSpec::default(), RouterSpec::named("round-robin"));
        let spec = RouterSpec::parse("forecast-headroom:margin=0.1").unwrap();
        assert_eq!(spec.param("margin"), Some(0.1));
        assert_eq!(spec.label(), "forecast-headroom:margin=0.1");
        assert_eq!(RouterSpec::named("weighted").label(), "weighted");
        assert!(RouterSpec::parse("").is_err());
        assert!(RouterSpec::parse("x:k=notanumber").is_err());
    }

    #[test]
    fn federation_validate_rejects_zero_clusters() {
        let fed = FederationConfig::default();
        let err = fed.validate().unwrap_err().to_string();
        assert!(err.contains("at least one cluster"), "unexpected error: {err}");
    }

    #[test]
    fn federation_validate_rejects_duplicate_cluster_names() {
        let mut fed = FederationConfig::homogeneous(2, RouterSpec::default());
        fed.clusters[1].name = "c0".to_string();
        let err = fed.validate().unwrap_err().to_string();
        assert!(err.contains("duplicate federation cluster name 'c0'"), "unexpected error: {err}");
    }

    #[test]
    fn federation_validate_rejects_non_finite_weights() {
        for bad in [f64::NAN, f64::INFINITY, 0.0, -1.0] {
            let mut fed = FederationConfig::homogeneous(2, RouterSpec::default());
            fed.clusters[0].weight = bad;
            let err = fed.validate().unwrap_err().to_string();
            assert!(err.contains("router weight"), "weight {bad}: unexpected error: {err}");
        }
        // Sanity: the untouched twin passes.
        assert!(FederationConfig::homogeneous(2, RouterSpec::default()).validate().is_ok());
    }

    #[test]
    fn federation_parses_from_json_and_rides_experiment_validate() {
        let cfg = ExperimentConfig::from_json_str(
            r#"{"federation": {
                "router": "forecast-headroom:margin=0.05",
                "submit_horizon_s": 45,
                "spill_queue_depth": 4,
                "clusters": [
                    {"name": "east", "weight": 2, "nodes": 8, "forecaster": "seasonal"},
                    {"name": "west", "policy": "baseline"}
                ]
            }}"#,
        )
        .unwrap();
        let fed = cfg.federation.as_ref().unwrap();
        assert_eq!(fed.router.name, "forecast-headroom");
        assert_eq!(fed.submit_horizon_s, 45.0);
        assert_eq!(fed.spill_queue_depth, 4);
        assert_eq!(fed.clusters.len(), 2);
        assert_eq!(fed.clusters[0].nodes, Some(8));
        assert_eq!(fed.clusters[1].policy, Some(PolicySpec::named("baseline")));
        assert!(cfg.validate().is_ok());
        // A bad federation block fails the top-level validate.
        let mut bad = cfg.clone();
        bad.federation.as_mut().unwrap().clusters.clear();
        assert!(bad.validate().is_err());
        // Unknown keys are rejected at parse time.
        assert!(ExperimentConfig::from_json_str(r#"{"federation": {"bogus": 1}}"#).is_err());
    }

    #[test]
    fn cluster_spec_overlay_inherits_and_overrides() {
        let base = ExperimentConfig::default();
        let spec = ClusterSpec::named("east")
            .with_weight(2.0)
            .with_nodes(9);
        let cfg = spec.apply(&base);
        assert_eq!(cfg.cluster.nodes, 9);
        assert_eq!(cfg.alloc.policy, base.alloc.policy);
        assert!(cfg.federation.is_none());
        // Empty overlay inherits the base cluster size.
        let cfg = ClusterSpec::named("west").apply(&base);
        assert_eq!(cfg.cluster.nodes, base.cluster.nodes);
    }
}
