//! Figs 5–8 — CPU/memory usage-rate curves under the three arrival
//! patterns, ARAS vs baseline, one figure per workflow type.
//!
//! A thin [`CampaignSpec`] over one workflow: 3 patterns × 2 policies,
//! executed in parallel by the campaign runner; each run's sampled usage
//! curve is written as its own CSV series.

use std::path::Path;

use crate::campaign::{self, CampaignSpec};
use crate::config::{ArrivalPattern, PolicySpec};
use crate::report::usage_curve_csv;
use crate::workflow::WorkflowType;

/// Which figure number the paper assigns to each workflow's usage curves.
pub fn figure_number(wf: WorkflowType) -> u32 {
    match wf {
        WorkflowType::Montage => 5,
        WorkflowType::Epigenomics => 6,
        WorkflowType::CyberShake => 7,
        WorkflowType::Ligo => 8,
        WorkflowType::Custom => 0,
    }
}

/// The one-figure campaign: 3 patterns × 2 policies for `wf`.
pub fn spec(wf: WorkflowType, seed: u64) -> CampaignSpec {
    let mut spec = CampaignSpec::default();
    spec.name = format!("fig{}-usage-curves", figure_number(wf));
    spec.workflows = vec![wf];
    spec.patterns = ArrivalPattern::paper_set().to_vec();
    spec.policies = vec![PolicySpec::adaptive(), PolicySpec::fcfs()];
    spec.base_seed = seed;
    spec.base.sample_interval_s = 5.0;
    spec
}

/// Generate the six series of one figure (3 patterns × 2 policies) into
/// `out_dir/fig<N>_<pattern>_<policy>.csv`. Returns written paths.
pub fn run(wf: WorkflowType, seed: u64, out_dir: &Path) -> anyhow::Result<Vec<String>> {
    let fig = figure_number(wf);
    let result = campaign::run(&spec(wf, seed))?;
    let mut written = Vec::new();
    for run in &result.runs {
        let csv = usage_curve_csv(&run.outcome.metrics);
        let path = out_dir.join(format!(
            "fig{fig}_{}_{}.csv",
            run.coord.pattern.name(),
            run.coord.policy.label()
        ));
        csv.write_file(&path)?;
        written.push(path.display().to_string());
    }
    Ok(written)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_numbers_match_paper() {
        assert_eq!(figure_number(WorkflowType::Montage), 5);
        assert_eq!(figure_number(WorkflowType::Ligo), 8);
    }

    #[test]
    fn writes_six_csvs() {
        let dir = std::env::temp_dir().join("ka_usage_test");
        let _ = std::fs::remove_dir_all(&dir);
        let written = run(WorkflowType::Montage, 3, &dir).unwrap();
        assert_eq!(written.len(), 6);
        for p in &written {
            let text = std::fs::read_to_string(p).unwrap();
            assert!(text.starts_with("t_s,cumulative_requests,cpu_rate"));
            assert!(text.lines().count() > 10, "curve too short in {p}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
