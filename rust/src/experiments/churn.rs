//! Churn — cluster-dynamics evaluation (beyond the paper's fixed
//! testbed): the same workload-paired ARAS-vs-FCFS comparison, swept
//! across cluster-turbulence profiles — static, a drain storm that
//! removes nodes mid-run, and a reactive autoscaler.
//!
//! Expected qualitative result (see EXPERIMENTS.md §churn): under drain
//! storms ARAS degrades more gracefully than FCFS — its scaled
//! allocations keep the shrunken cluster's allocation queue flowing,
//! while the baseline's full-size requests stall the head on every
//! capacity dip. The autoscaled profile recovers most of the static
//! performance for both policies.

use std::fmt::Write as _;
use std::path::Path;

use crate::campaign::{self, CampaignSpec};
use crate::cluster::ChurnProfile;
use crate::config::{ArrivalPattern, PolicySpec};
use crate::report;
use crate::workflow::WorkflowType;

/// One (churn, policy) result row.
#[derive(Debug, Clone)]
pub struct ChurnRow {
    pub churn: String,
    pub policy: String,
    pub total_duration_min: f64,
    pub avg_workflow_duration_min: f64,
    pub workflows_completed: usize,
    pub evictions: usize,
    pub nodes_joined: usize,
    pub nodes_removed: usize,
    /// Eviction accounting (acceptance: rescheduled + unresolved covers
    /// every evicted pod — nothing vanishes silently).
    pub pods_evicted: u64,
    pub evicted_rescheduled: u64,
    pub evicted_unresolved: usize,
    pub tasks_unfinished: usize,
}

pub struct ChurnOutput {
    pub csv_path: String,
    pub report: String,
    pub rows: Vec<ChurnRow>,
}

/// The churn campaign grid: one workload (Montage under the paper's
/// constant pattern, truncated to 20 requests), ARAS + FCFS, three
/// cluster-turbulence profiles. The churn axis is workload-paired: all
/// six cells replay bit-identical workloads.
pub fn spec(seed: u64) -> CampaignSpec {
    spec_with(seed, ArrivalPattern::Constant { per_burst: 5, bursts: 4 })
}

/// Grid with an explicit arrival pattern (tests use a smaller one).
pub fn spec_with(seed: u64, pattern: ArrivalPattern) -> CampaignSpec {
    let mut spec = CampaignSpec::default();
    spec.name = "churn".to_string();
    spec.workflows = vec![WorkflowType::Montage];
    spec.patterns = vec![pattern];
    spec.policies = vec![PolicySpec::adaptive(), PolicySpec::fcfs()];
    spec.churns = vec![
        ChurnProfile::none(),
        // Three unnamed drains starting at t=350 (mid-burst-2), every
        // 300 s: each hits the currently most-loaded node.
        ChurnProfile::drain_storm(350.0, 300.0, 3),
        // Reactive autoscaler: grow up to 10 nodes under queue pressure,
        // drain back to the initial 6 when calm.
        ChurnProfile::autoscaled(6, 10),
    ];
    spec.base_seed = seed;
    spec.base.sample_interval_s = 5.0;
    spec
}

/// Run the churn campaign and render its per-cell table.
pub fn run(seed: u64, out_dir: &Path) -> anyhow::Result<ChurnOutput> {
    run_spec(&spec(seed), out_dir)
}

pub fn run_spec(spec: &CampaignSpec, out_dir: &Path) -> anyhow::Result<ChurnOutput> {
    let result = campaign::run(spec)?;
    let rows: Vec<ChurnRow> = result
        .runs
        .iter()
        .map(|r| ChurnRow {
            churn: r.coord.churn.clone(),
            policy: r.coord.policy.label(),
            total_duration_min: r.outcome.summary.total_duration_min,
            avg_workflow_duration_min: r.outcome.summary.avg_workflow_duration_min,
            workflows_completed: r.outcome.summary.workflows_completed,
            evictions: r.outcome.summary.evictions,
            nodes_joined: r.outcome.summary.nodes_joined,
            nodes_removed: r.outcome.summary.nodes_removed,
            pods_evicted: r.outcome.pods_evicted,
            evicted_rescheduled: r.outcome.evicted_rescheduled,
            evicted_unresolved: r.outcome.evicted_unresolved,
            tasks_unfinished: r.outcome.tasks_unfinished,
        })
        .collect();

    std::fs::create_dir_all(out_dir)?;
    let csv_path = out_dir.join("churn_summary.csv");
    report::campaign::summary_csv(&result).write_file(&csv_path)?;

    Ok(ChurnOutput { csv_path: csv_path.display().to_string(), report: render(&rows), rows })
}

/// Markdown table: one row per (churn, policy) cell.
pub fn render(rows: &[ChurnRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "# Churn: cluster dynamics × policy\n");
    let _ = writeln!(
        out,
        "| Churn | Policy | Total (min) | Avg workflow (min) | Completed | Evictions | Nodes +/- |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {} | {:.2} | {:.2} | {} | {} | +{}/-{} |",
            r.churn,
            r.policy,
            r.total_duration_min,
            r.avg_workflow_duration_min,
            r.workflows_completed,
            r.evictions,
            r.nodes_joined,
            r.nodes_removed,
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> CampaignSpec {
        // 3 workflows, two churn profiles. The first drain fires at
        // t=15, when the three source-task pods are guaranteed Running
        // (start = 12 s, minimum duration = 10 s), so the storm always
        // displaces at least one pod.
        let mut spec = spec_with(7, ArrivalPattern::Constant { per_burst: 3, bursts: 1 });
        spec.churns = vec![
            ChurnProfile::none(),
            ChurnProfile::drain_storm(15.0, 30.0, 2),
        ];
        spec
    }

    #[test]
    fn churn_experiment_is_deterministic_and_accounts_evictions() {
        let dir = std::env::temp_dir().join("ka_churn_test");
        let _ = std::fs::remove_dir_all(&dir);
        let a = run_spec(&small_spec(), &dir).unwrap();
        let b = run_spec(&small_spec(), &dir).unwrap();
        // Same seed ⇒ identical summaries, bit-exact.
        assert_eq!(a.rows.len(), b.rows.len());
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(x.total_duration_min, y.total_duration_min, "{}/{}", x.churn, x.policy);
            assert_eq!(x.evictions, y.evictions);
            assert_eq!(x.pods_evicted, y.pods_evicted);
        }
        // Every cell completes all workflows; every eviction is
        // rescheduled or explicitly accounted unfinished.
        let mut storm_evictions = 0;
        for r in &a.rows {
            assert_eq!(r.workflows_completed, 3, "{}/{}", r.churn, r.policy);
            assert_eq!(r.tasks_unfinished, 0);
            assert_eq!(r.evicted_unresolved, 0, "healthy runs resolve every eviction");
            assert_eq!(r.pods_evicted, r.evicted_rescheduled + r.evicted_unresolved as u64);
            assert_eq!(r.evictions as u64, r.pods_evicted);
            if r.churn.starts_with("drain-storm") {
                storm_evictions += r.evictions;
                assert!(r.nodes_removed > 0, "storm must remove nodes");
            } else {
                assert_eq!(r.evictions, 0, "static cells must not evict");
            }
        }
        assert!(storm_evictions > 0, "the drain storm must displace at least one pod");
        assert!(a.report.contains("drain-storm"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
