//! Fig. 9 — resource-allocation failure evaluation (§6.2.2).
//!
//! 10 Montage workflows injected at once; `min_mem` tuned so the
//! resource-scaling method's quota can fall below `min_mem + β`, driving
//! task pods into OOMKilled. KubeAdaptor must capture the OOM, delete the
//! pod, reallocate and regenerate it (self-healing), and all workflows
//! must still complete.

use std::path::Path;

use crate::campaign::{self, CampaignSpec};
use crate::config::{ArrivalPattern, ExperimentConfig, PolicySpec};
use crate::metrics::EventKind;
use crate::report::event_timeline_csv;
use crate::workflow::WorkflowType;

pub struct OomOutput {
    pub csv_path: String,
    pub oom_events: usize,
    pub reallocations: usize,
    pub workflows_completed: usize,
    /// First OOM lifecycle extracted for the Fig. 9 annotations:
    /// (alloc_t, oom_t, realloc_t, complete_t).
    pub first_lifecycle: Option<(f64, f64, f64, f64)>,
}

pub fn config(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 10, bursts: 1 },
        PolicySpec::adaptive(),
    );
    // §6.2.2: Stress needs 2000Mi; users under-declared minimums, so the
    // scaling method may allocate below min+β. strict_min off = launch
    // anyway (the production mistake the paper simulates).
    cfg.task.min_mem_mi = 2000;
    cfg.alloc.strict_min = false;
    cfg.workload.seed = seed;
    cfg.sample_interval_s = 2.0;
    cfg
}

/// The Fig. 9 campaign: a single cell whose *base* config carries the
/// failure-evaluation overrides (`strict_min = false`, Stress-sized
/// minimum memory); every grid axis is seeded from that config. Like
/// all campaigns, the workload seed is derived from `seed` (it is the
/// campaign base seed), so `run(seed, ..)` is reproducible per seed but
/// is not the same workload as `run_experiment(&config(seed))`.
pub fn spec(seed: u64) -> CampaignSpec {
    let mut spec = CampaignSpec::from_base(config(seed));
    spec.name = "fig9-oom".to_string();
    spec
}

pub fn run(seed: u64, out_dir: &Path) -> anyhow::Result<OomOutput> {
    let mut result = campaign::run(&spec(seed))?;
    let out = result.runs.pop().expect("single-cell campaign").outcome;
    let csv = event_timeline_csv(&out.metrics);
    let csv_path = out_dir.join("fig9_oom_timeline.csv");
    csv.write_file(&csv_path)?;

    // Find the first task that OOMed and trace its lifecycle.
    let events = &out.metrics.events;
    let first_lifecycle = events
        .iter()
        .find(|e| matches!(e.kind, EventKind::PodOomKilled))
        .map(|oom| {
            let tid = &oom.task_id;
            let alloc_t = events
                .iter()
                .find(|e| e.task_id == *tid && matches!(e.kind, EventKind::AllocDecided { .. }))
                .map(|e| e.t)
                .unwrap_or(0.0);
            let realloc_t = events
                .iter()
                .find(|e| {
                    e.task_id == *tid && e.t > oom.t && matches!(e.kind, EventKind::TaskReallocated)
                })
                .map(|e| e.t)
                .unwrap_or(oom.t);
            let complete_t = events
                .iter()
                .find(|e| {
                    e.task_id == *tid && e.t > oom.t && matches!(e.kind, EventKind::PodSucceeded)
                })
                .map(|e| e.t)
                .unwrap_or(realloc_t);
            (alloc_t, oom.t, realloc_t, complete_t)
        });

    Ok(OomOutput {
        csv_path: csv_path.display().to_string(),
        oom_events: out.summary.oom_events,
        reallocations: out.metrics.count(|k| matches!(k, EventKind::TaskReallocated)),
        workflows_completed: out.summary.workflows_completed,
        first_lifecycle,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oom_storm_selfheals() {
        let dir = std::env::temp_dir().join("ka_oom_test");
        let _ = std::fs::remove_dir_all(&dir);
        let out = run(42, &dir).unwrap();
        assert!(out.oom_events > 0, "scenario must produce OOM kills");
        assert_eq!(out.oom_events, out.reallocations, "every OOM reallocated");
        assert_eq!(out.workflows_completed, 10, "self-healing completes all workflows");
        let (alloc_t, oom_t, realloc_t, complete_t) = out.first_lifecycle.unwrap();
        assert!(alloc_t <= oom_t && oom_t < realloc_t && realloc_t <= complete_t);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
