//! Experiment harness — one module per paper table/figure (DESIGN.md §4).

pub mod ablation;
pub mod fig1;
pub mod oom;
pub mod table2;
pub mod usage_curves;
