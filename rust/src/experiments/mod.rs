//! Experiment harness — one module per paper table/figure (DESIGN.md §4),
//! plus scenario families beyond the paper ([`churn`]: cluster dynamics,
//! [`forecast`]: reactive vs predictive allocation/autoscaling,
//! [`chaos`]: policy robustness under injected faults, [`federate`]:
//! global routing across sharded clusters).

pub mod ablation;
pub mod chaos;
pub mod churn;
pub mod federate;
pub mod fig1;
pub mod forecast;
pub mod oom;
pub mod table2;
pub mod usage_curves;
