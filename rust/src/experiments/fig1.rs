//! Fig. 1 — execution timeline of a single small-scale Montage workflow
//! under ARAS: per-task lifecycle (request → running → done) showing the
//! concurrency windows the resource-scaling method reasons over.

use std::fmt::Write as _;
use std::path::Path;

use crate::campaign::{self, CampaignSpec};
use crate::config::{ArrivalPattern, PolicySpec};
use crate::metrics::EventKind;
use crate::report::event_timeline_csv;
use crate::workflow::WorkflowType;

pub struct Fig1Output {
    pub csv_path: String,
    pub gantt: String,
    /// (task_id, start, end) spans.
    pub spans: Vec<(String, f64, f64)>,
}

/// The Fig. 1 campaign: a single-cell grid (one Montage workflow under
/// ARAS) — the timeline post-processing below is the figure-specific part.
pub fn spec(seed: u64) -> CampaignSpec {
    let mut base = crate::config::ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::Constant { per_burst: 1, bursts: 1 },
        PolicySpec::adaptive(),
    );
    base.workload.seed = seed;
    base.sample_interval_s = 1.0;
    let mut spec = CampaignSpec::from_base(base);
    spec.name = "fig1".to_string();
    spec
}

pub fn run(seed: u64, out_dir: &Path) -> anyhow::Result<Fig1Output> {
    let mut result = campaign::run(&spec(seed))?;
    let out = result.runs.pop().expect("single-cell campaign").outcome;

    // Extract per-task running spans.
    let mut spans: Vec<(String, f64, f64)> = Vec::new();
    let mut starts: Vec<(String, f64)> = Vec::new();
    for e in &out.metrics.events {
        match e.kind {
            EventKind::PodRunning => starts.push((e.task_id.to_string(), e.t)),
            EventKind::PodSucceeded => {
                if let Some(pos) = starts.iter().position(|(id, _)| id.as_str() == &*e.task_id) {
                    let (id, t0) = starts.remove(pos);
                    spans.push((id, t0, e.t));
                }
            }
            _ => {}
        }
    }
    spans.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

    let csv = event_timeline_csv(&out.metrics);
    let csv_path = out_dir.join("fig1_montage_timeline.csv");
    csv.write_file(&csv_path)?;

    Ok(Fig1Output {
        csv_path: csv_path.display().to_string(),
        gantt: ascii_gantt(&spans),
        spans,
    })
}

/// Render task spans as an ASCII gantt (the shape of Fig. 1).
pub fn ascii_gantt(spans: &[(String, f64, f64)]) -> String {
    let t_max = spans.iter().map(|s| s.2).fold(1.0f64, f64::max);
    let width = 72usize;
    let scale = width as f64 / t_max;
    let mut out = String::new();
    let _ = writeln!(out, "task              0{:>width$.0}s", t_max, width = width - 1);
    for (id, t0, t1) in spans {
        let a = (t0 * scale).round() as usize;
        let b = ((t1 * scale).round() as usize).max(a + 1).min(width);
        let mut bar = String::new();
        bar.push_str(&" ".repeat(a));
        bar.push_str(&"█".repeat(b - a));
        let _ = writeln!(out, "{:<17} {bar}", truncate(id, 17));
    }
    out
}

fn truncate(s: &str, n: usize) -> &str {
    if s.len() <= n {
        s
    } else {
        &s[..n]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn montage_timeline_has_21_spans() {
        let dir = std::env::temp_dir().join("ka_fig1_test");
        let _ = std::fs::remove_dir_all(&dir);
        let out = run(42, &dir).unwrap();
        assert_eq!(out.spans.len(), 21);
        // Tasks run in dependency order: mJPEG is last.
        let last = &out.spans.last().unwrap().0;
        assert_eq!(last, "wf1-t20");
        assert!(out.gantt.lines().count() >= 22);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spans_respect_dependencies() {
        let dir = std::env::temp_dir().join("ka_fig1_test2");
        let _ = std::fs::remove_dir_all(&dir);
        let out = run(7, &dir).unwrap();
        let find = |id: &str| out.spans.iter().find(|(s, _, _)| s == id).unwrap();
        // entry (t0) must finish before any mProjectPP (t1..t4) starts.
        let entry_end = find("wf1-t0").2;
        for i in 1..=4 {
            assert!(find(&format!("wf1-t{i}")).1 >= entry_end);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
