//! Table 2 — the paper's headline evaluation: 4 workflows × 3 arrival
//! patterns × {ARAS, baseline}, `reps` repetitions each, reporting mean
//! and δ for total duration, average workflow duration, CPU and memory
//! usage.
//!
//! This module is a thin [`CampaignSpec`] definition: the grid expansion,
//! per-run seeding and the parallel worker pool all live in
//! [`crate::campaign`]; here we only declare the paper's grid and map the
//! aggregated cells into [`Table2Entry`] rows.

use crate::campaign::{self, CampaignSpec};
use crate::config::{ArrivalPattern, PolicySpec};
use crate::report::Table2Entry;
use crate::workflow::WorkflowType;

/// Every (workflow, pattern, policy) combination of Table 2.
pub fn combinations() -> Vec<(WorkflowType, ArrivalPattern, PolicySpec)> {
    let mut out = Vec::new();
    for wf in WorkflowType::paper_set() {
        for pat in ArrivalPattern::paper_set() {
            for pol in [PolicySpec::adaptive(), PolicySpec::fcfs()] {
                out.push((wf, pat, pol));
            }
        }
    }
    out
}

/// The Table 2 campaign: the paper's full grid with `reps` seed streams
/// per cell. ARAS and baseline twins share seeds (campaign invariant),
/// so each repetition compares the two policies on identical workloads.
pub fn spec(reps: usize, base_seed: u64) -> CampaignSpec {
    let mut spec = CampaignSpec::default();
    spec.name = "table2".to_string();
    spec.workflows = WorkflowType::paper_set().to_vec();
    spec.patterns = ArrivalPattern::paper_set().to_vec();
    spec.policies = vec![PolicySpec::adaptive(), PolicySpec::fcfs()];
    spec.reps = reps;
    spec.base_seed = base_seed;
    spec.base.sample_interval_s = 5.0;
    spec
}

/// Run the full table via the campaign runner.
pub fn run(reps: usize, base_seed: u64) -> anyhow::Result<Vec<Table2Entry>> {
    entries(&campaign::run(&spec(reps, base_seed))?)
}

/// Map aggregated comparison cells into Table 2's row layout.
pub fn entries(result: &campaign::CampaignResult) -> anyhow::Result<Vec<Table2Entry>> {
    let mut out = Vec::new();
    for row in result.comparison() {
        for agg in [&row.adaptive, &row.baseline].into_iter().flatten() {
            out.push(Table2Entry {
                workflow: row.workflow.name().to_string(),
                pattern: row.pattern.name().to_string(),
                policy: agg.policy.clone(),
                total_duration_min: agg.total_duration_min,
                avg_workflow_duration_min: agg.avg_workflow_duration_min,
                cpu_usage: agg.cpu_usage,
                mem_usage: agg.mem_usage,
            });
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combinations_cover_table() {
        assert_eq!(combinations().len(), 4 * 3 * 2);
    }

    #[test]
    fn spec_matches_combinations() {
        let s = spec(3, 42);
        assert_eq!(s.total_runs(), combinations().len() * 3);
    }

    #[test]
    fn single_rep_smoke() {
        // Only a smoke subset here; the full table runs in benches/CLI.
        let entries = run(1, 7).unwrap();
        assert_eq!(entries.len(), 24);
        for e in &entries {
            assert!(e.total_duration_min.mean > 0.0, "{e:?}");
        }
    }
}
