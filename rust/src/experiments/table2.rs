//! Table 2 — the paper's headline evaluation: 4 workflows × 3 arrival
//! patterns × {ARAS, baseline}, `reps` repetitions each, reporting mean
//! and δ for total duration, average workflow duration, CPU and memory
//! usage. Runs execute in parallel across std threads (one DES per run).

use std::sync::mpsc;

use crate::config::{ArrivalPattern, ExperimentConfig, PolicyKind};
use crate::engine::run_experiment;
use crate::report::{Cell, Table2Entry};
use crate::workflow::WorkflowType;

/// Every (workflow, pattern, policy) combination of Table 2.
pub fn combinations() -> Vec<(WorkflowType, ArrivalPattern, PolicyKind)> {
    let mut out = Vec::new();
    for wf in WorkflowType::paper_set() {
        for pat in [
            ArrivalPattern::paper_constant(),
            ArrivalPattern::paper_linear(),
            ArrivalPattern::paper_pyramid(),
        ] {
            for pol in [PolicyKind::Adaptive, PolicyKind::Fcfs] {
                out.push((wf, pat, pol));
            }
        }
    }
    out
}

/// Run the full table. `base_seed + rep` seeds each repetition, so the
/// Adaptive and Baseline runs of a repetition see identical workloads.
pub fn run(reps: usize, base_seed: u64) -> anyhow::Result<Vec<Table2Entry>> {
    let combos = combinations();
    let (tx, rx) = mpsc::channel();

    std::thread::scope(|scope| {
        for (idx, &(wf, pat, pol)) in combos.iter().enumerate() {
            let tx = tx.clone();
            scope.spawn(move || {
                let mut totals = Vec::new();
                let mut avgs = Vec::new();
                let mut cpus = Vec::new();
                let mut mems = Vec::new();
                for rep in 0..reps {
                    let mut cfg = ExperimentConfig::paper(wf, pat, pol);
                    cfg.workload.seed = base_seed + rep as u64;
                    cfg.sample_interval_s = 5.0;
                    let out = run_experiment(&cfg).expect("run");
                    totals.push(out.summary.total_duration_min);
                    avgs.push(out.summary.avg_workflow_duration_min);
                    cpus.push(out.summary.cpu_usage);
                    mems.push(out.summary.mem_usage);
                }
                let entry = Table2Entry {
                    workflow: wf.name().to_string(),
                    pattern: pat.name().to_string(),
                    policy: pol.name().to_string(),
                    total_duration_min: Cell::of(&totals),
                    avg_workflow_duration_min: Cell::of(&avgs),
                    cpu_usage: Cell::of(&cpus),
                    mem_usage: Cell::of(&mems),
                };
                tx.send((idx, entry)).expect("send");
            });
        }
    });
    drop(tx);

    let mut results: Vec<(usize, Table2Entry)> = rx.into_iter().collect();
    results.sort_by_key(|(i, _)| *i);
    Ok(results.into_iter().map(|(_, e)| e).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combinations_cover_table() {
        assert_eq!(combinations().len(), 4 * 3 * 2);
    }

    #[test]
    fn single_rep_smoke() {
        // Only a smoke subset here; the full table runs in benches/CLI.
        let entries = run(1, 7).unwrap();
        assert_eq!(entries.len(), 24);
        for e in &entries {
            assert!(e.total_duration_min.mean > 0.0, "{e:?}");
        }
    }
}
