//! Ablations (DESIGN.md A1–A3): design-choice sensitivity studies the
//! paper motivates but does not tabulate.
//!
//! * **alpha** — Eq. (9)'s α scale factor (paper fixes 0.8 "through lots
//!   of experimental evaluations"); sweep 0.5..1.0.
//! * **lookahead** — ARAS with the Alg. 1 lines 8–13 window scan disabled
//!   (no future-task awareness): collapses toward the baseline.
//! * **nodes** — cluster-size scaling, 3..12 workers.
//!
//! Each ablation is a thin [`CampaignSpec`] with one extra grid axis
//! (α values, lookahead settings, or cluster sizes); the campaign
//! runner's seed derivation keeps the workload identical across every
//! row of a sweep, so rows differ only by the ablated knob.

use crate::campaign::{self, CampaignRun, CampaignSpec};
use crate::config::{ArrivalPattern, PolicySpec};
use crate::workflow::WorkflowType;

#[derive(Debug, Clone)]
pub struct AblationRow {
    pub label: String,
    pub total_duration_min: f64,
    pub avg_workflow_duration_min: f64,
    pub cpu_usage: f64,
    pub alloc_waits: usize,
}

/// Shared scaffold: Montage under the constant pattern, ARAS policy.
fn base_spec(name: &str, seed: u64) -> CampaignSpec {
    let mut base = crate::config::ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::paper_constant(),
        PolicySpec::adaptive(),
    );
    base.workload.seed = seed;
    base.sample_interval_s = 5.0;
    let mut spec = CampaignSpec::from_base(base);
    spec.name = name.to_string();
    spec
}

fn row(label: String, run: &CampaignRun) -> AblationRow {
    let s = &run.outcome.summary;
    AblationRow {
        label,
        total_duration_min: s.total_duration_min,
        avg_workflow_duration_min: s.avg_workflow_duration_min,
        cpu_usage: s.cpu_usage,
        alloc_waits: s.alloc_waits,
    }
}

/// A1: α sweep.
pub fn alpha_sweep(seed: u64) -> anyhow::Result<Vec<AblationRow>> {
    let mut spec = base_spec("ablation-alpha", seed);
    spec.alphas = vec![0.5, 0.6, 0.7, 0.8, 0.9, 1.0];
    let result = campaign::run(&spec)?;
    Ok(result
        .runs
        .iter()
        .map(|r| row(format!("alpha={}", r.coord.alpha), r))
        .collect())
}

/// A2: lookahead on/off vs baseline.
pub fn lookahead_ablation(seed: u64) -> anyhow::Result<Vec<AblationRow>> {
    let mut spec = base_spec("ablation-lookahead", seed);
    spec.lookaheads = vec![true, false];
    let result = campaign::run(&spec)?;
    let mut rows: Vec<AblationRow> = result
        .runs
        .iter()
        .map(|r| {
            row(
                format!("aras(lookahead={})", if r.coord.lookahead { "on" } else { "off" }),
                r,
            )
        })
        .collect();

    // The baseline row: same seed derivation (identical workload), FCFS.
    let mut fcfs = base_spec("ablation-lookahead-baseline", seed);
    fcfs.policies = vec![PolicySpec::fcfs()];
    let result = campaign::run(&fcfs)?;
    rows.extend(result.runs.iter().map(|r| row("baseline(fcfs)".to_string(), r)));
    Ok(rows)
}

/// A3: cluster-size scaling.
pub fn node_sweep(seed: u64) -> anyhow::Result<Vec<AblationRow>> {
    let mut spec = base_spec("ablation-nodes", seed);
    spec.cluster_sizes = vec![3, 4, 6, 8, 12];
    let result = campaign::run(&spec)?;
    Ok(result
        .runs
        .iter()
        .map(|r| row(format!("nodes={}", r.coord.nodes), r))
        .collect())
}

/// Render rows as a markdown table.
pub fn render(rows: &[AblationRow], title: &str) -> String {
    let mut out = format!("## Ablation: {title}\n\n");
    out.push_str("| Config | Total (min) | Avg workflow (min) | CPU usage | Alloc waits |\n");
    out.push_str("|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {:.2} | {:.2} | {:.3} | {} |\n",
            r.label, r.total_duration_min, r.avg_workflow_duration_min, r.cpu_usage, r.alloc_waits
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookahead_off_is_no_better_than_on() {
        let rows = lookahead_ablation(5).unwrap();
        assert_eq!(rows.len(), 3);
        let on = rows.iter().find(|r| r.label.contains("on")).unwrap();
        let off = rows.iter().find(|r| r.label.contains("off")).unwrap();
        assert!(
            off.total_duration_min >= on.total_duration_min - 0.5,
            "lookahead should not hurt: on={} off={}",
            on.total_duration_min,
            off.total_duration_min
        );
    }

    #[test]
    fn more_nodes_never_slower() {
        let rows = node_sweep(5).unwrap();
        let first = rows.first().unwrap().total_duration_min;
        let last = rows.last().unwrap().total_duration_min;
        assert!(last <= first + 0.5, "12 nodes should beat 3: {first} -> {last}");
    }

    #[test]
    fn alpha_sweep_rows_share_the_workload_seed() {
        let mut spec = base_spec("ablation-alpha", 9);
        spec.alphas = vec![0.5, 0.8];
        let runs = spec.expand().unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].coord.seed, runs[1].coord.seed);
    }
}
