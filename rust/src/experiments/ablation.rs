//! Ablations (DESIGN.md A1–A3): design-choice sensitivity studies the
//! paper motivates but does not tabulate.
//!
//! * **alpha** — Eq. (9)'s α scale factor (paper fixes 0.8 "through lots
//!   of experimental evaluations"); sweep 0.5..1.0.
//! * **lookahead** — ARAS with the Alg. 1 lines 8–13 window scan disabled
//!   (no future-task awareness): collapses toward the baseline.
//! * **nodes** — cluster-size scaling, 3..12 workers.

use crate::config::{ArrivalPattern, ExperimentConfig, PolicyKind};
use crate::engine::run_experiment;
use crate::workflow::WorkflowType;

#[derive(Debug, Clone)]
pub struct AblationRow {
    pub label: String,
    pub total_duration_min: f64,
    pub avg_workflow_duration_min: f64,
    pub cpu_usage: f64,
    pub alloc_waits: usize,
}

fn base_cfg(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::paper(
        WorkflowType::Montage,
        ArrivalPattern::paper_constant(),
        PolicyKind::Adaptive,
    );
    cfg.workload.seed = seed;
    cfg.sample_interval_s = 5.0;
    cfg
}

fn row(label: String, cfg: &ExperimentConfig) -> anyhow::Result<AblationRow> {
    let out = run_experiment(cfg)?;
    Ok(AblationRow {
        label,
        total_duration_min: out.summary.total_duration_min,
        avg_workflow_duration_min: out.summary.avg_workflow_duration_min,
        cpu_usage: out.summary.cpu_usage,
        alloc_waits: out.summary.alloc_waits,
    })
}

/// A1: α sweep.
pub fn alpha_sweep(seed: u64) -> anyhow::Result<Vec<AblationRow>> {
    [0.5, 0.6, 0.7, 0.8, 0.9, 1.0]
        .iter()
        .map(|&a| {
            let mut cfg = base_cfg(seed);
            cfg.alloc.alpha = a;
            row(format!("alpha={a}"), &cfg)
        })
        .collect()
}

/// A2: lookahead on/off vs baseline.
pub fn lookahead_ablation(seed: u64) -> anyhow::Result<Vec<AblationRow>> {
    let mut rows = Vec::new();
    let cfg = base_cfg(seed);
    rows.push(row("aras(lookahead=on)".into(), &cfg)?);
    let mut cfg2 = base_cfg(seed);
    cfg2.alloc.lookahead = false;
    rows.push(row("aras(lookahead=off)".into(), &cfg2)?);
    let mut cfg3 = base_cfg(seed);
    cfg3.alloc.policy = PolicyKind::Fcfs;
    rows.push(row("baseline(fcfs)".into(), &cfg3)?);
    Ok(rows)
}

/// A3: cluster-size scaling.
pub fn node_sweep(seed: u64) -> anyhow::Result<Vec<AblationRow>> {
    [3usize, 4, 6, 8, 12]
        .iter()
        .map(|&n| {
            let mut cfg = base_cfg(seed);
            cfg.cluster.nodes = n;
            row(format!("nodes={n}"), &cfg)
        })
        .collect()
}

/// Render rows as a markdown table.
pub fn render(rows: &[AblationRow], title: &str) -> String {
    let mut out = format!("## Ablation: {title}\n\n");
    out.push_str("| Config | Total (min) | Avg workflow (min) | CPU usage | Alloc waits |\n");
    out.push_str("|---|---|---|---|---|\n");
    for r in rows {
        out.push_str(&format!(
            "| {} | {:.2} | {:.2} | {:.3} | {} |\n",
            r.label, r.total_duration_min, r.avg_workflow_duration_min, r.cpu_usage, r.alloc_waits
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lookahead_off_is_no_better_than_on() {
        let rows = lookahead_ablation(5).unwrap();
        let on = &rows[0];
        let off = &rows[1];
        assert!(
            off.total_duration_min >= on.total_duration_min - 0.5,
            "lookahead should not hurt: on={} off={}",
            on.total_duration_min,
            off.total_duration_min
        );
    }

    #[test]
    fn more_nodes_never_slower() {
        let rows = node_sweep(5).unwrap();
        let first = rows.first().unwrap().total_duration_min;
        let last = rows.last().unwrap().total_duration_min;
        assert!(last <= first + 0.5, "12 nodes should beat 3: {first} -> {last}");
    }
}
