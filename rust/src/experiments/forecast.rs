//! Forecast — reactive vs predictive evaluation (beyond the paper):
//! the same workload-paired grid as [`super::churn`], but the axis under
//! test is *anticipation*. A small cluster sits behind a slow-provisioning
//! autoscaler; the grid crosses
//!
//! * policies `adaptive` (plain ARAS) × `predictive` (ARAS + forecast
//!   demand in every lifecycle window), and
//! * churn profiles `autoscale[…]` (reactive, trails actual queue
//!   length) × `autoscale-pred[…]` (scales ahead of the forecast queue),
//!
//! under the paper's arrival patterns, with a `seasonal` forecaster
//! (period = the 300 s burst cadence) observing every cell. The
//! forecaster axis and churn axis are both excluded from seed
//! derivation, so every cell replays a bit-identical workload.
//!
//! Expected qualitative result (see EXPERIMENTS.md §forecast): under
//! bursty arrivals the predictive autoscaler provisions *before* each
//! burst lands — capacity is ready when the reactive twin is still
//! waiting out its provisioning delay — so queued tasks are admitted
//! earlier and average workflow duration drops. The MAPE/RMSE columns
//! report how good the forecasts actually were.

use std::fmt::Write as _;
use std::path::Path;

use crate::campaign::{self, CampaignSpec};
use crate::cluster::{AutoscalerConfig, AutoscalerMode, ChurnProfile};
use crate::config::{ArrivalPattern, ForecasterSpec, PolicySpec};
use crate::report;
use crate::workflow::WorkflowType;

/// One (pattern, churn, policy) result row.
#[derive(Debug, Clone)]
pub struct ForecastRow {
    pub pattern: String,
    pub churn: String,
    pub policy: String,
    pub forecaster: String,
    pub total_duration_min: f64,
    pub avg_workflow_duration_min: f64,
    pub workflows_completed: usize,
    pub nodes_joined: usize,
    pub forecast_points: usize,
    pub mape_cpu: f64,
    pub mape_mem: f64,
    pub rmse_cpu: f64,
    pub rmse_mem: f64,
}

pub struct ForecastOutput {
    pub csv_path: String,
    pub report: String,
    pub rows: Vec<ForecastRow>,
}

/// Autoscaler bounds of the experiment: a 4-node cluster allowed to grow
/// to 8, with a 60 s provisioning delay — long enough that trailing the
/// queue visibly costs wall-clock, and exactly the look-ahead horizon
/// the predictive mode predicts at.
fn autoscaler(mode: AutoscalerMode) -> AutoscalerConfig {
    AutoscalerConfig {
        min_nodes: 4,
        max_nodes: 8,
        scale_up_queue: 2,
        scale_down_ticks: 3,
        provision_s: 60.0,
        pool: None,
        mode,
    }
}

fn reactive_profile() -> ChurnProfile {
    ChurnProfile {
        label: "autoscale[4,8]".to_string(),
        events: Vec::new(),
        autoscaler: Some(autoscaler(AutoscalerMode::Reactive)),
    }
}

fn predictive_profile() -> ChurnProfile {
    ChurnProfile {
        label: "autoscale-pred[4,8]".to_string(),
        events: Vec::new(),
        autoscaler: Some(autoscaler(AutoscalerMode::Predictive)),
    }
}

/// The full grid: the paper's three arrival patterns.
pub fn spec(seed: u64) -> CampaignSpec {
    spec_with(seed, ArrivalPattern::paper_set().to_vec())
}

/// Grid with explicit arrival patterns (tests and the CI smoke run use
/// a truncated one).
pub fn spec_with(seed: u64, patterns: Vec<ArrivalPattern>) -> CampaignSpec {
    let mut spec = CampaignSpec::default();
    spec.name = "forecast".to_string();
    spec.workflows = vec![WorkflowType::Montage];
    spec.patterns = patterns;
    spec.policies = vec![PolicySpec::adaptive(), PolicySpec::named("predictive")];
    spec.cluster_sizes = vec![4];
    spec.churns = vec![reactive_profile(), predictive_profile()];
    // Seasonal forecaster, period = the burst cadence: after one cycle
    // it has seen where in the period the bursts land.
    spec.forecasters = vec![Some(ForecasterSpec::named("seasonal"))];
    spec.base_seed = seed;
    spec.base.sample_interval_s = 5.0;
    spec
}

/// Run the forecast campaign and render its per-cell table.
pub fn run(seed: u64, out_dir: &Path) -> anyhow::Result<ForecastOutput> {
    run_spec(&spec(seed), out_dir)
}

pub fn run_spec(spec: &CampaignSpec, out_dir: &Path) -> anyhow::Result<ForecastOutput> {
    let result = campaign::run(spec)?;
    let rows: Vec<ForecastRow> = result
        .runs
        .iter()
        .map(|r| ForecastRow {
            pattern: r.coord.pattern.name().to_string(),
            churn: r.coord.churn.clone(),
            policy: r.coord.policy.label(),
            forecaster: r.coord.forecaster.clone(),
            total_duration_min: r.outcome.summary.total_duration_min,
            avg_workflow_duration_min: r.outcome.summary.avg_workflow_duration_min,
            workflows_completed: r.outcome.summary.workflows_completed,
            nodes_joined: r.outcome.summary.nodes_joined,
            forecast_points: r.outcome.summary.forecast_points,
            mape_cpu: r.outcome.summary.forecast_mape_cpu,
            mape_mem: r.outcome.summary.forecast_mape_mem,
            rmse_cpu: r.outcome.summary.forecast_rmse_cpu,
            rmse_mem: r.outcome.summary.forecast_rmse_mem,
        })
        .collect();

    std::fs::create_dir_all(out_dir)?;
    let csv_path = out_dir.join("forecast_summary.csv");
    report::campaign::summary_csv(&result).write_file(&csv_path)?;

    Ok(ForecastOutput { csv_path: csv_path.display().to_string(), report: render(&rows), rows })
}

/// Markdown: the per-cell table plus reactive-vs-predictive autoscaler
/// deltas per (pattern, policy) — negative delta = the predictive
/// autoscaler admitted tasks earlier.
pub fn render(rows: &[ForecastRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "# Forecast: reactive vs predictive × arrival pattern\n");
    let _ = writeln!(
        out,
        "| Pattern | Churn | Policy | Forecaster | Total (min) | Avg workflow (min) | Nodes + | Points | MAPE cpu % | RMSE cpu |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {:.2} | {:.2} | +{} | {} | {:.1} | {:.0} |",
            r.pattern,
            r.churn,
            r.policy,
            r.forecaster,
            r.total_duration_min,
            r.avg_workflow_duration_min,
            r.nodes_joined,
            r.forecast_points,
            r.mape_cpu,
            r.rmse_cpu,
        );
    }
    // Headline deltas: same pattern + policy, predictive vs reactive
    // autoscaler (both cells replay identical workloads).
    let mut pairs: Vec<String> = Vec::new();
    for r in rows {
        if !r.churn.starts_with("autoscale-pred") {
            continue;
        }
        let Some(reactive) = rows.iter().find(|o| {
            o.pattern == r.pattern
                && o.policy == r.policy
                && o.churn.starts_with("autoscale[")
        }) else {
            continue;
        };
        let delta = r.avg_workflow_duration_min - reactive.avg_workflow_duration_min;
        pairs.push(format!(
            "- {}/{}: predictive autoscaler avg workflow {:+.2} min vs reactive ({:.2} → {:.2})",
            r.pattern,
            r.policy,
            delta,
            reactive.avg_workflow_duration_min,
            r.avg_workflow_duration_min,
        ));
    }
    if !pairs.is_empty() {
        let _ = writeln!(out, "\n### Predictive-vs-reactive autoscaler\n");
        for p in pairs {
            let _ = writeln!(out, "{p}");
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> CampaignSpec {
        // 2 bursts of 4 Montage workflows on the 4-node cluster: real
        // queue pressure, small enough for a unit test.
        spec_with(11, vec![ArrivalPattern::Constant { per_burst: 4, bursts: 2 }])
    }

    #[test]
    fn forecast_experiment_is_deterministic_and_scores_forecasts() {
        let dir = std::env::temp_dir().join("ka_forecast_test");
        let _ = std::fs::remove_dir_all(&dir);
        let a = run_spec(&small_spec(), &dir).unwrap();
        let b = run_spec(&small_spec(), &dir).unwrap();
        // 2 churns × 2 policies.
        assert_eq!(a.rows.len(), 4);
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(
                x.total_duration_min.to_bits(),
                y.total_duration_min.to_bits(),
                "{}/{}",
                x.churn,
                x.policy
            );
            assert_eq!(x.nodes_joined, y.nodes_joined);
        }
        for r in &a.rows {
            assert_eq!(r.workflows_completed, 8, "{}/{}", r.churn, r.policy);
            assert_eq!(r.forecaster, "seasonal");
            assert!(r.forecast_points > 0, "MAPE/RMSE must be populated: {}/{}", r.churn, r.policy);
            assert!(r.mape_cpu.is_finite() && r.mape_cpu >= 0.0);
            assert!(r.rmse_cpu.is_finite() && r.rmse_cpu >= 0.0);
        }
        assert!(a.report.contains("autoscale-pred"));
        assert!(a.report.contains("Predictive-vs-reactive"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
