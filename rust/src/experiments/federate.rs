//! Federate — global-router comparison over sharded simulated clusters
//! (beyond the paper): every registered routing strategy places the
//! *same* arrival sequence across a heterogeneous federation, under
//! three scenario families:
//!
//! * `skewed` — pyramid traffic over clusters of very different sizes
//!   (8/6/2 nodes); a size-blind router keeps feeding the small cluster
//!   its full share and the federation makespan is decided there.
//! * `capacity-asym` — steady traffic over a 10/6/2 split; same failure
//!   mode at steady state.
//! * `outage` — three equal clusters, one of which loses every node at
//!   t = 0 (a regional outage); routers must notice the dead region and
//!   spill its share to the survivors.
//!
//! Every (scenario, router) cell replays a bit-identical workload: the
//! arrival stream comes from the shared base seed, and per-cluster
//! engine seeds derive from `(base, FED_SEED_STREAM, index)` — so the
//! comparison isolates the routing strategy exactly like the campaign
//! isolates the allocation policy.
//!
//! Expected qualitative result (see EXPERIMENTS.md §federate): under
//! skewed capacity `forecast-headroom` beats `round-robin` on total
//! duration — it routes on normalized residual headroom (minus each
//! cluster's own forecast demand), so the small cluster only gets work
//! the big ones can't take sooner.

use std::fmt::Write as _;
use std::path::Path;

use crate::cluster::{ClusterEvent, ClusterEventKind};
use crate::config::{
    ArrivalPattern, ClusterSpec, ExperimentConfig, FederationConfig, ForecasterSpec, RouterSpec,
};
use crate::federation::{self, FederationSpec};
use crate::util::csv::CsvWriter;
use crate::workflow::WorkflowType;

/// Scenario families, in run order.
pub const SCENARIOS: [&str; 3] = ["skewed", "capacity-asym", "outage"];

/// One (scenario, router) result row.
#[derive(Debug, Clone)]
pub struct FedRow {
    pub scenario: String,
    pub router: String,
    pub clusters: usize,
    pub routed: usize,
    pub spillovers: usize,
    pub workflows_completed: usize,
    pub total_duration_min: f64,
    pub avg_workflow_duration_min: f64,
    pub cpu_usage: f64,
    /// Per-cluster placement counts, federation order.
    pub placements: Vec<(String, usize)>,
}

pub struct FederateOutput {
    pub csv_path: String,
    pub metrics_path: String,
    pub report: String,
    pub rows: Vec<FedRow>,
}

/// The four built-in routers, compared in registration order.
fn routers() -> Vec<RouterSpec> {
    vec![
        RouterSpec::named("round-robin"),
        RouterSpec::named("least-queue"),
        RouterSpec::named("forecast-headroom"),
        RouterSpec::named("weighted"),
    ]
}

/// Shared base config: Montage on every member, with a seasonal
/// forecaster so the headroom router scores real forecasts, not just
/// residuals.
fn base_config(seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.workload.workflow = WorkflowType::Montage;
    cfg.workload.seed = seed;
    cfg.forecast.forecaster = Some(ForecasterSpec::named("seasonal"));
    cfg.sample_interval_s = 5.0;
    cfg
}

/// Arrival pattern + member clusters of one scenario. Clusters are
/// listed biggest-first: at the empty-federation instant every
/// normalized headroom ties at 1.0 and rankers fall back to index
/// order, which must prefer capacity.
fn scenario(name: &str, quick: bool) -> (ArrivalPattern, Vec<ClusterSpec>) {
    let pattern = if quick {
        ArrivalPattern::Constant { per_burst: 4, bursts: 2 }
    } else if name == "skewed" {
        ArrivalPattern::paper_pyramid()
    } else {
        ArrivalPattern::paper_constant()
    };
    let clusters = match name {
        "skewed" => vec![
            ClusterSpec::named("big").with_nodes(8).with_weight(4.0),
            ClusterSpec::named("mid").with_nodes(6).with_weight(3.0),
            ClusterSpec::named("small").with_nodes(2).with_weight(1.0),
        ],
        "capacity-asym" => vec![
            ClusterSpec::named("core").with_nodes(10).with_weight(5.0),
            ClusterSpec::named("regional").with_nodes(6).with_weight(3.0),
            ClusterSpec::named("edge").with_nodes(2).with_weight(1.0),
        ],
        "outage" => {
            let mut east = ClusterSpec::named("east").with_nodes(6);
            // The regional outage: every east node is crashed by name at
            // t = 0, before the first routing decision. Named crashes
            // bypass the victim picker (which spares the last node
            // standing), so the region really goes dark — and because it
            // dies before any placement, nothing strands there and the
            // run still terminates.
            east.events = (0..6)
                .map(|i| ClusterEvent {
                    at: 0.0,
                    kind: ClusterEventKind::Crash { node: Some(format!("node-{i}")) },
                })
                .collect();
            vec![
                east,
                ClusterSpec::named("west").with_nodes(6),
                ClusterSpec::named("north").with_nodes(6),
            ]
        }
        other => unreachable!("unknown federate scenario '{other}'"),
    };
    (pattern, clusters)
}

/// The full (scenario × router) spec grid.
pub fn specs(seed: u64, quick: bool) -> Vec<FederationSpec> {
    let mut out = Vec::new();
    for name in SCENARIOS {
        let (pattern, clusters) = scenario(name, quick);
        for router in routers() {
            let mut base = base_config(seed);
            base.workload.pattern = pattern.clone();
            out.push(FederationSpec {
                name: format!("{name}/{}", router.label()),
                base,
                federation: FederationConfig {
                    clusters: clusters.clone(),
                    router,
                    ..FederationConfig::default()
                },
            });
        }
    }
    out
}

/// Run the grid (`quick` shrinks the arrival streams for smokes/tests)
/// and write `federate_summary.csv` + a Prometheus exposition of the
/// skewed forecast-headroom run to `out_dir`.
pub fn run(seed: u64, quick: bool, threads: usize, out_dir: &Path) -> anyhow::Result<FederateOutput> {
    let specs = specs(seed, quick);
    let results = federation::run_many(&specs, threads)?;
    let rows: Vec<FedRow> = specs
        .iter()
        .zip(&results)
        .map(|(spec, r)| {
            let s = &r.summary;
            FedRow {
                scenario: spec.name.split('/').next().unwrap_or_default().to_string(),
                router: s.router.clone(),
                clusters: s.clusters.len(),
                routed: s.routed,
                spillovers: s.spillovers,
                workflows_completed: s.workflows_completed,
                total_duration_min: s.total_duration_min,
                avg_workflow_duration_min: s.avg_workflow_duration_min,
                cpu_usage: s.cpu_usage,
                placements: s.clusters.iter().map(|c| (c.name.clone(), c.placements)).collect(),
            }
        })
        .collect();

    std::fs::create_dir_all(out_dir)?;
    let csv_path = out_dir.join("federate_summary.csv");
    csv(&rows).write_file(&csv_path)?;
    let metrics_path = out_dir.join("federate_metrics.prom");
    let headroom = specs
        .iter()
        .zip(&results)
        .find(|(s, _)| s.name == "skewed/forecast-headroom")
        .map(|(_, r)| r.summary.prometheus_metrics())
        .unwrap_or_default();
    std::fs::write(&metrics_path, headroom)?;

    Ok(FederateOutput {
        csv_path: csv_path.display().to_string(),
        metrics_path: metrics_path.display().to_string(),
        report: render(&rows),
        rows,
    })
}

/// Per-row CSV (column set is part of the CI smoke contract — it greps
/// for `spillovers`).
pub fn csv(rows: &[FedRow]) -> CsvWriter {
    let mut w = CsvWriter::new(&[
        "scenario",
        "router",
        "clusters",
        "routed",
        "spillovers",
        "workflows_completed",
        "total_duration_min",
        "avg_workflow_duration_min",
        "cpu_usage",
        "placements",
    ]);
    for r in rows {
        w.row(&[
            r.scenario.clone(),
            r.router.clone(),
            r.clusters.to_string(),
            r.routed.to_string(),
            r.spillovers.to_string(),
            r.workflows_completed.to_string(),
            format!("{:.4}", r.total_duration_min),
            format!("{:.4}", r.avg_workflow_duration_min),
            format!("{:.6}", r.cpu_usage),
            r.placements
                .iter()
                .map(|(name, n)| format!("{name}:{n}"))
                .collect::<Vec<_>>()
                .join(" "),
        ]);
    }
    w
}

/// Markdown: the per-cell table plus the headroom-vs-round-robin
/// headline per scenario (positive saving = the forecast router's
/// federation finished sooner on an identical workload).
pub fn render(rows: &[FedRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "# Federate: global routers over sharded clusters\n");
    let _ = writeln!(
        out,
        "| Scenario | Router | Routed | Spilled | Completed | Total (min) | Avg workflow (min) | Placements |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|");
    for r in rows {
        let placements = r
            .placements
            .iter()
            .map(|(name, n)| format!("{name}:{n}"))
            .collect::<Vec<_>>()
            .join(" ");
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {:.2} | {:.2} | {} |",
            r.scenario,
            r.router,
            r.routed,
            r.spillovers,
            r.workflows_completed,
            r.total_duration_min,
            r.avg_workflow_duration_min,
            placements,
        );
    }
    let mut lines: Vec<String> = Vec::new();
    for r in rows.iter().filter(|r| r.router == "forecast-headroom") {
        let Some(rr) =
            rows.iter().find(|o| o.scenario == r.scenario && o.router == "round-robin")
        else {
            continue;
        };
        if rr.total_duration_min > 0.0 {
            let saving = (1.0 - r.total_duration_min / rr.total_duration_min) * 100.0;
            lines.push(format!(
                "- {}: forecast-headroom total {:.2} min vs round-robin {:.2} min ({saving:+.1}% saving)",
                r.scenario, r.total_duration_min, rr.total_duration_min,
            ));
        }
    }
    if !lines.is_empty() {
        let _ = writeln!(out, "\n### Forecast-headroom vs round-robin\n");
        for line in lines {
            let _ = writeln!(out, "{line}");
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn federate_quick_is_deterministic_and_covers_the_grid() {
        let dir = std::env::temp_dir().join("ka_federate_test");
        let _ = std::fs::remove_dir_all(&dir);
        let a = run(11, true, 2, &dir).unwrap();
        let b = run(11, true, 2, &dir).unwrap();
        assert_eq!(a.rows.len(), SCENARIOS.len() * 4);
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(
                x.total_duration_min.to_bits(),
                y.total_duration_min.to_bits(),
                "{}/{}",
                x.scenario,
                x.router
            );
            assert_eq!(x.spillovers, y.spillovers, "{}/{}", x.scenario, x.router);
            assert_eq!(x.placements, y.placements, "{}/{}", x.scenario, x.router);
        }
        for r in &a.rows {
            assert_eq!(r.routed, 8, "{}/{}", r.scenario, r.router);
            assert_eq!(
                r.placements.iter().map(|(_, n)| n).sum::<usize>(),
                8,
                "{}/{}",
                r.scenario,
                r.router
            );
            // East dies before the first routing decision, so even the
            // outage scenario strands nothing: every stream completes.
            assert_eq!(r.workflows_completed, 8, "{}/{}", r.scenario, r.router);
        }
        // The dead region forces a size-blind router to spill.
        let outage_rr = a
            .rows
            .iter()
            .find(|r| r.scenario == "outage" && r.router == "round-robin")
            .unwrap();
        assert!(outage_rr.spillovers > 0, "dead region must divert round-robin placements");
        assert!(a.report.contains("Forecast-headroom vs round-robin"));
        let csv_text = std::fs::read_to_string(&a.csv_path).unwrap();
        assert!(csv_text.contains("spillovers"));
        let prom = std::fs::read_to_string(&a.metrics_path).unwrap();
        assert!(prom.contains("ka_fed_routed_total"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn forecast_headroom_beats_round_robin_when_capacity_is_skewed() {
        let dir = std::env::temp_dir().join("ka_federate_skew_test");
        let _ = std::fs::remove_dir_all(&dir);
        let out = run(11, true, 2, &dir).unwrap();
        let cell = |router: &str| {
            out.rows
                .iter()
                .find(|r| r.scenario == "skewed" && r.router == router)
                .unwrap()
                .total_duration_min
        };
        let (headroom, rr) = (cell("forecast-headroom"), cell("round-robin"));
        assert!(
            headroom < rr,
            "forecast-headroom ({headroom:.2} min) must beat round-robin ({rr:.2} min) \
             when capacity is skewed"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}
