//! Chaos — policy robustness under injected faults (beyond the paper):
//! the workload-paired grid of [`super::forecast`] (adaptive vs
//! predictive allocation × reactive vs predictive autoscaling, seasonal
//! forecaster observing every cell), crossed with a fault axis covering
//! every chaos family:
//!
//! * `none` — the quiet twin every fault cell is compared against,
//! * `mem-hog[…]` — a noisy neighbor holds memory on the busiest node,
//! * `latency-storm[…]` — store→informer propagation degrades,
//! * `partition[…]` — the informer is cut off; snapshots freeze.
//!
//! The chaos axis is excluded from seed derivation (like churn and
//! forecasters), so each fault family hits a bit-identical workload and
//! the per-cell deltas are pure fault impact. The chaos counters
//! (hog-stolen integrals, stale-snapshot cycles, double-allocation
//! attempts) quantify the injected pressure; the duration deltas
//! quantify what each policy/autoscaler combination made of it.

use std::fmt::Write as _;
use std::path::Path;

use crate::campaign::{self, CampaignSpec};
use crate::chaos::ChaosProfile;
use crate::cluster::{AutoscalerConfig, AutoscalerMode, ChurnProfile};
use crate::config::{ArrivalPattern, ForecasterSpec, PolicySpec};
use crate::report;
use crate::workflow::WorkflowType;

/// One (pattern, churn, chaos, policy) result row.
#[derive(Debug, Clone)]
pub struct ChaosRow {
    pub pattern: String,
    pub churn: String,
    pub chaos: String,
    pub policy: String,
    pub total_duration_min: f64,
    pub avg_workflow_duration_min: f64,
    pub workflows_completed: usize,
    pub alloc_waits: usize,
    pub hog_stolen_cpu_s: f64,
    pub hog_stolen_mem_s: f64,
    pub stale_snapshot_cycles: usize,
    pub double_alloc_attempts: usize,
}

pub struct ChaosOutput {
    pub csv_path: String,
    pub report: String,
    pub rows: Vec<ChaosRow>,
}

/// Same bounds as the forecast experiment: 4 nodes growing to 8 with a
/// 60 s provisioning delay, so fault windows interact with scaling.
fn autoscaler(mode: AutoscalerMode) -> AutoscalerConfig {
    AutoscalerConfig {
        min_nodes: 4,
        max_nodes: 8,
        scale_up_queue: 2,
        scale_down_ticks: 3,
        provision_s: 60.0,
        pool: None,
        mode,
    }
}

fn reactive_profile() -> ChurnProfile {
    ChurnProfile {
        label: "autoscale[4,8]".to_string(),
        events: Vec::new(),
        autoscaler: Some(autoscaler(AutoscalerMode::Reactive)),
    }
}

fn predictive_profile() -> ChurnProfile {
    ChurnProfile {
        label: "autoscale-pred[4,8]".to_string(),
        events: Vec::new(),
        autoscaler: Some(autoscaler(AutoscalerMode::Predictive)),
    }
}

/// The fault axis: the quiet cell plus one representative of each
/// family, all active from t=60 s — inside the first workload wave.
fn fault_axis() -> Vec<ChaosProfile> {
    vec![
        ChaosProfile::none(),
        ChaosProfile::mem_hog(60.0, 600.0, 8192),
        ChaosProfile::latency_storm(60.0, 600.0, 45.0),
        ChaosProfile::partition(60.0, 300.0),
    ]
}

/// The full grid: the paper's constant arrival pattern under all four
/// fault cells × both policies × both autoscaler modes.
pub fn spec(seed: u64) -> CampaignSpec {
    spec_with(seed, vec![ArrivalPattern::paper_constant()])
}

/// Grid with explicit arrival patterns (tests and the CI smoke run use
/// a truncated one).
pub fn spec_with(seed: u64, patterns: Vec<ArrivalPattern>) -> CampaignSpec {
    let mut spec = CampaignSpec::default();
    spec.name = "chaos".to_string();
    spec.workflows = vec![WorkflowType::Montage];
    spec.patterns = patterns;
    spec.policies = vec![PolicySpec::adaptive(), PolicySpec::named("predictive")];
    spec.cluster_sizes = vec![4];
    spec.churns = vec![reactive_profile(), predictive_profile()];
    spec.forecasters = vec![Some(ForecasterSpec::named("seasonal"))];
    spec.chaos = fault_axis();
    spec.base_seed = seed;
    spec.base.sample_interval_s = 5.0;
    spec
}

/// Run the chaos campaign and render its per-cell table.
pub fn run(seed: u64, out_dir: &Path) -> anyhow::Result<ChaosOutput> {
    run_spec(&spec(seed), out_dir)
}

pub fn run_spec(spec: &CampaignSpec, out_dir: &Path) -> anyhow::Result<ChaosOutput> {
    let result = campaign::run(spec)?;
    let rows: Vec<ChaosRow> = result
        .runs
        .iter()
        .map(|r| ChaosRow {
            pattern: r.coord.pattern.name().to_string(),
            churn: r.coord.churn.clone(),
            chaos: r.coord.chaos.clone(),
            policy: r.coord.policy.label(),
            total_duration_min: r.outcome.summary.total_duration_min,
            avg_workflow_duration_min: r.outcome.summary.avg_workflow_duration_min,
            workflows_completed: r.outcome.summary.workflows_completed,
            alloc_waits: r.outcome.summary.alloc_waits,
            hog_stolen_cpu_s: r.outcome.hog_stolen_cpu_s,
            hog_stolen_mem_s: r.outcome.hog_stolen_mem_s,
            stale_snapshot_cycles: r.outcome.stale_snapshot_cycles,
            double_alloc_attempts: r.outcome.double_alloc_attempts,
        })
        .collect();

    // Hard invariants of the experiment — a silent violation would make
    // every delta below meaningless.
    for r in &rows {
        anyhow::ensure!(
            r.chaos != "none" || (r.stale_snapshot_cycles == 0 && r.hog_stolen_mem_s == 0.0),
            "quiet cell {}/{} shows chaos accounting",
            r.churn,
            r.policy
        );
        if r.chaos.starts_with("mem-hog") {
            anyhow::ensure!(
                r.hog_stolen_mem_s > 0.0,
                "hog cell {}/{} stole nothing",
                r.churn,
                r.policy
            );
        }
        if r.chaos.starts_with("partition") {
            anyhow::ensure!(
                r.stale_snapshot_cycles > 0,
                "partition cell {}/{} never went stale",
                r.churn,
                r.policy
            );
        }
    }

    std::fs::create_dir_all(out_dir)?;
    let csv_path = out_dir.join("chaos_summary.csv");
    report::campaign::summary_csv(&result).write_file(&csv_path)?;

    Ok(ChaosOutput { csv_path: csv_path.display().to_string(), report: render(&rows), rows })
}

/// Markdown: the per-cell table plus per-(churn, policy) fault-impact
/// deltas against the quiet twin (bit-identical workloads, so the delta
/// is entirely the fault's doing).
pub fn render(rows: &[ChaosRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "# Chaos: fault families × policy × autoscaler mode\n");
    let _ = writeln!(
        out,
        "| Pattern | Churn | Chaos | Policy | Total (min) | Avg workflow (min) | Waits | Stolen cpu·s | Stolen Mi·s | Stale cycles | Double-allocs |"
    );
    let _ = writeln!(out, "|---|---|---|---|---|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {:.2} | {:.2} | {} | {:.0} | {:.0} | {} | {} |",
            r.pattern,
            r.churn,
            r.chaos,
            r.policy,
            r.total_duration_min,
            r.avg_workflow_duration_min,
            r.alloc_waits,
            r.hog_stolen_cpu_s,
            r.hog_stolen_mem_s,
            r.stale_snapshot_cycles,
            r.double_alloc_attempts,
        );
    }
    // Fault impact: every fault cell vs its quiet twin in the same
    // (pattern, churn, policy) slice.
    let mut impacts: Vec<String> = Vec::new();
    for r in rows {
        if r.chaos == "none" {
            continue;
        }
        let Some(quiet) = rows.iter().find(|o| {
            o.chaos == "none"
                && o.pattern == r.pattern
                && o.churn == r.churn
                && o.policy == r.policy
        }) else {
            continue;
        };
        let delta = r.avg_workflow_duration_min - quiet.avg_workflow_duration_min;
        impacts.push(format!(
            "- {} on {}/{}: avg workflow {:+.2} min vs quiet ({:.2} → {:.2})",
            r.chaos,
            r.churn,
            r.policy,
            delta,
            quiet.avg_workflow_duration_min,
            r.avg_workflow_duration_min,
        ));
    }
    if !impacts.is_empty() {
        let _ = writeln!(out, "\n### Fault impact vs the quiet twin\n");
        for line in impacts {
            let _ = writeln!(out, "{line}");
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> CampaignSpec {
        // 2 bursts of 4 Montage workflows on the 4-node cluster: enough
        // pressure for faults to bite, small enough for a unit test.
        spec_with(11, vec![ArrivalPattern::Constant { per_burst: 4, bursts: 2 }])
    }

    #[test]
    fn chaos_experiment_is_deterministic_and_counts_faults() {
        let dir = std::env::temp_dir().join("ka_chaos_test");
        let _ = std::fs::remove_dir_all(&dir);
        let a = run_spec(&small_spec(), &dir).unwrap();
        let b = run_spec(&small_spec(), &dir).unwrap();
        // 2 churns × 4 fault cells × 2 policies.
        assert_eq!(a.rows.len(), 16);
        for (x, y) in a.rows.iter().zip(&b.rows) {
            assert_eq!(
                x.total_duration_min.to_bits(),
                y.total_duration_min.to_bits(),
                "{}/{}/{}",
                x.churn,
                x.chaos,
                x.policy
            );
            assert_eq!(x.double_alloc_attempts, y.double_alloc_attempts);
        }
        for r in &a.rows {
            assert_eq!(
                r.workflows_completed, 8,
                "every cell must self-heal: {}/{}/{}",
                r.churn, r.chaos, r.policy
            );
        }
        // Each fault family leaves its fingerprint somewhere in the grid.
        assert!(a.rows.iter().any(|r| r.hog_stolen_mem_s > 0.0));
        assert!(a
            .rows
            .iter()
            .any(|r| r.chaos.starts_with("partition") && r.stale_snapshot_cycles > 0));
        assert!(a
            .rows
            .iter()
            .any(|r| r.chaos.starts_with("latency-storm") && r.stale_snapshot_cycles > 0));
        assert!(a.report.contains("Fault impact"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}
