//! Observability: deterministic-by-default tracing and metrics.
//!
//! Three pieces, kept deliberately small:
//!
//! * [`Recorder`] — span counters over engine phases. Virtual-time
//!   span records and call counts are **always** deterministic;
//!   wall-clock timing is strictly opt-in ([`Recorder::enable_wall_clock`],
//!   used by `bench`) so golden traces stay bit-identical with
//!   observability compiled in and enabled.
//! * [`quantile`] — constant-memory streaming quantiles (P²) behind a
//!   [`quantile::Histogram`], replacing stored-sample percentile math.
//! * [`expo`] / [`trace`] — Prometheus text exposition for the daemon's
//!   `metrics` request, and the line-JSON span/event journal behind
//!   `run --trace-out`.
//!
//! Determinism rules, stated once and enforced everywhere:
//! 1. counts and virtual timestamps are recorded unconditionally —
//!    they are pure functions of the simulation and cost no entropy;
//! 2. wall-clock reads (`Instant::now`) happen only when
//!    `enable_wall_clock` was called, and wall durations never feed
//!    back into simulation state;
//! 3. span-record accumulation (`enable_trace`) is opt-in so default
//!    runs do not grow a vector they will never read.

pub mod expo;
pub mod quantile;
pub mod trace;

use std::time::Instant;

/// Engine phases instrumented with spans.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// One pass over the allocation queue (`serve_queue` with work to do).
    ServeCycle,
    /// A `policy.plan()` invocation (head probe or batch).
    Plan,
    /// A `scheduler.schedule()` placement attempt.
    Schedule,
    /// Snapshot maintenance: incremental delta application or full capture.
    SnapshotApply,
    /// `forecaster.observe()` ingestion of a usage sample.
    ForecastObserve,
    /// `forecaster.predict()` horizon query.
    ForecastPredict,
    /// Chaos event handling (start or end of an injected fault).
    Chaos,
}

impl Phase {
    pub const ALL: [Phase; 7] = [
        Phase::ServeCycle,
        Phase::Plan,
        Phase::Schedule,
        Phase::SnapshotApply,
        Phase::ForecastObserve,
        Phase::ForecastPredict,
        Phase::Chaos,
    ];

    /// Stable wire name (trace journal, Prometheus label).
    pub fn name(self) -> &'static str {
        match self {
            Phase::ServeCycle => "serve_cycle",
            Phase::Plan => "plan",
            Phase::Schedule => "schedule",
            Phase::SnapshotApply => "snapshot_apply",
            Phase::ForecastObserve => "forecast_observe",
            Phase::ForecastPredict => "forecast_predict",
            Phase::Chaos => "chaos",
        }
    }

    pub fn parse(s: &str) -> Option<Phase> {
        Phase::ALL.iter().copied().find(|p| p.name() == s)
    }

    fn idx(self) -> usize {
        match self {
            Phase::ServeCycle => 0,
            Phase::Plan => 1,
            Phase::Schedule => 2,
            Phase::SnapshotApply => 3,
            Phase::ForecastObserve => 4,
            Phase::ForecastPredict => 5,
            Phase::Chaos => 6,
        }
    }
}

const NPHASES: usize = Phase::ALL.len();

/// Handle returned by [`Recorder::begin`]; carries the wall-clock start
/// only when wall timing is enabled. Passing it back to
/// [`Recorder::end`] closes the span.
#[derive(Debug)]
pub struct SpanToken {
    wall: Option<Instant>,
}

/// One completed span, retained only when tracing is enabled.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    /// Monotonic sequence number (deterministic ordering key).
    pub seq: u64,
    pub phase: Phase,
    /// Virtual time at which the span closed.
    pub t: f64,
    /// Wall nanoseconds; 0 unless wall-clock timing was enabled.
    pub wall_ns: u64,
}

/// Deterministic phase counts plus (opt-in) wall-clock nanoseconds,
/// copied into `RunSummary` at the end of a run.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseBreakdown {
    pub serve_cycles: u64,
    pub plan_calls: u64,
    pub schedule_calls: u64,
    pub snapshot_applies: u64,
    pub forecast_observes: u64,
    pub forecast_predicts: u64,
    pub chaos_events: u64,
    pub serve_wall_ns: u64,
    pub plan_wall_ns: u64,
    pub schedule_wall_ns: u64,
    pub snapshot_wall_ns: u64,
    pub forecast_wall_ns: u64,
    pub chaos_wall_ns: u64,
}

/// Span recorder threaded through the engine. Deterministic by
/// default: counting is unconditional, clocks and span retention are
/// opt-in.
#[derive(Debug, Default)]
pub struct Recorder {
    wall_clock: bool,
    counts: [u64; NPHASES],
    wall_ns: [u64; NPHASES],
    spans: Option<Vec<SpanRecord>>,
    seq: u64,
}

impl Recorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Opt into wall-clock span timing (bench only — wall durations are
    /// machine-dependent and must never reach golden output).
    pub fn enable_wall_clock(&mut self) {
        self.wall_clock = true;
    }

    /// Opt into retaining per-span records for `--trace-out`.
    pub fn enable_trace(&mut self) {
        if self.spans.is_none() {
            self.spans = Some(Vec::new());
        }
    }

    pub fn trace_enabled(&self) -> bool {
        self.spans.is_some()
    }

    /// Open a span. Reads the clock only when wall timing is on.
    pub fn begin(&self) -> SpanToken {
        SpanToken { wall: self.wall_clock.then(Instant::now) }
    }

    /// Close a span: count it, attribute wall time, and (if tracing)
    /// append a record stamped with virtual time `t`.
    pub fn end(&mut self, phase: Phase, t: f64, tok: SpanToken) {
        let i = phase.idx();
        self.counts[i] += 1;
        let wall_ns = match tok.wall {
            Some(start) => {
                let ns = start.elapsed().as_nanos() as u64;
                self.wall_ns[i] += ns;
                ns
            }
            None => 0,
        };
        if let Some(spans) = &mut self.spans {
            spans.push(SpanRecord { seq: self.seq, phase, t, wall_ns });
            self.seq += 1;
        }
    }

    pub fn count(&self, phase: Phase) -> u64 {
        self.counts[phase.idx()]
    }

    pub fn wall_ns(&self, phase: Phase) -> u64 {
        self.wall_ns[phase.idx()]
    }

    /// Snapshot the per-phase totals.
    pub fn breakdown(&self) -> PhaseBreakdown {
        PhaseBreakdown {
            serve_cycles: self.count(Phase::ServeCycle),
            plan_calls: self.count(Phase::Plan),
            schedule_calls: self.count(Phase::Schedule),
            snapshot_applies: self.count(Phase::SnapshotApply),
            forecast_observes: self.count(Phase::ForecastObserve),
            forecast_predicts: self.count(Phase::ForecastPredict),
            chaos_events: self.count(Phase::Chaos),
            serve_wall_ns: self.wall_ns(Phase::ServeCycle),
            plan_wall_ns: self.wall_ns(Phase::Plan),
            schedule_wall_ns: self.wall_ns(Phase::Schedule),
            snapshot_wall_ns: self.wall_ns(Phase::SnapshotApply),
            forecast_wall_ns: self.wall_ns(Phase::ForecastObserve)
                + self.wall_ns(Phase::ForecastPredict),
            chaos_wall_ns: self.wall_ns(Phase::Chaos),
        }
    }

    /// Drain retained span records (empty unless tracing was enabled).
    pub fn take_spans(&mut self) -> Vec<SpanRecord> {
        self.spans.take().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_without_clock_by_default() {
        let mut r = Recorder::new();
        let tok = r.begin();
        assert!(tok.wall.is_none(), "default recorder must not read the clock");
        r.end(Phase::Plan, 1.5, tok);
        assert_eq!(r.count(Phase::Plan), 1);
        assert_eq!(r.wall_ns(Phase::Plan), 0);
        assert!(r.take_spans().is_empty(), "no span retention unless traced");
    }

    #[test]
    fn trace_records_sequence_and_virtual_time() {
        let mut r = Recorder::new();
        r.enable_trace();
        for (i, t) in [0.5, 1.0, 2.5].iter().enumerate() {
            let tok = r.begin();
            r.end(if i == 1 { Phase::Schedule } else { Phase::Plan }, *t, tok);
        }
        let spans = r.take_spans();
        assert_eq!(spans.len(), 3);
        assert_eq!(spans[0].seq, 0);
        assert_eq!(spans[1].phase, Phase::Schedule);
        assert_eq!(spans[2].t, 2.5);
        assert!(spans.iter().all(|s| s.wall_ns == 0));
    }

    #[test]
    fn wall_clock_is_opt_in() {
        let mut r = Recorder::new();
        r.enable_wall_clock();
        let tok = r.begin();
        assert!(tok.wall.is_some());
        r.end(Phase::ServeCycle, 0.0, tok);
        assert_eq!(r.count(Phase::ServeCycle), 1);
        // elapsed >= 0 trivially; the point is it was attributed.
    }

    #[test]
    fn breakdown_mirrors_counts() {
        let mut r = Recorder::new();
        for _ in 0..3 {
            let tok = r.begin();
            r.end(Phase::Plan, 0.0, tok);
        }
        let tok = r.begin();
        r.end(Phase::Chaos, 0.0, tok);
        let b = r.breakdown();
        assert_eq!(b.plan_calls, 3);
        assert_eq!(b.chaos_events, 1);
        assert_eq!(b.serve_cycles, 0);
    }

    #[test]
    fn phase_names_round_trip() {
        for p in Phase::ALL {
            assert_eq!(Phase::parse(p.name()), Some(p));
        }
        assert_eq!(Phase::parse("nope"), None);
    }
}
