//! Constant-memory streaming quantiles: the P² algorithm (Jain &
//! Chlamtac, 1985) behind a small [`Histogram`] type.
//!
//! The ROADMAP's million-task goal rules out stored-sample percentile
//! math — a 1M-workflow run cannot keep every duration around just to
//! sort it at the end. A [`Histogram`] costs O(1) memory per series:
//!
//! * **Exact** for small runs: the first [`EXACT_CAP`] observations are
//!   buffered, and quantiles over them use the same linear-interpolation
//!   formula as [`crate::util::stats::percentile`] — so small runs (all
//!   of CI, all golden scenarios) agree *bit-exactly* with the stored-
//!   sample math they replace.
//! * **P² estimated** beyond that: one five-marker P² estimator per
//!   tracked quantile, updated in O(1) per observation.
//! * **Bucketed** for exposition: fixed upper-bound buckets feed the
//!   Prometheus text format ([`crate::obs::expo`]) without retaining
//!   samples.
//!
//! Everything here is plain arithmetic on the observed values —
//! no clocks, no randomness — so feeding deterministic virtual-time
//! data yields bit-identical state on every run.

/// Observations buffered before switching to P² estimation. CI-scale
/// runs stay below this, keeping their quantiles exact.
pub const EXACT_CAP: usize = 64;

/// Quantiles a [`Histogram`] tracks with dedicated P² estimators.
pub const TRACKED_QUANTILES: [f64; 4] = [0.50, 0.90, 0.95, 0.99];

/// One P² estimator for a single quantile `q`: five markers whose
/// heights converge on (min, q/2-ish, q, (1+q)/2-ish, max). O(1) space,
/// O(1) update.
#[derive(Debug, Clone)]
pub struct P2 {
    q: f64,
    /// Observations seen (NaN excluded).
    n: u64,
    /// First five observations, sorted on the fifth (bootstrap buffer).
    init: Vec<f64>,
    /// Marker heights (valid once n >= 5).
    heights: [f64; 5],
    /// Actual marker positions, 1-based.
    pos: [f64; 5],
    /// Desired marker positions.
    desired: [f64; 5],
    /// Per-observation desired-position increments.
    incr: [f64; 5],
}

impl P2 {
    pub fn new(q: f64) -> Self {
        assert!((0.0..=1.0).contains(&q), "quantile must be in [0, 1], got {q}");
        P2 {
            q,
            n: 0,
            init: Vec::with_capacity(5),
            heights: [0.0; 5],
            pos: [1.0, 2.0, 3.0, 4.0, 5.0],
            desired: [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q, 3.0 + 2.0 * q, 5.0],
            incr: [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0],
        }
    }

    /// The quantile this estimator tracks.
    pub fn q(&self) -> f64 {
        self.q
    }

    /// Observations seen so far.
    pub fn len(&self) -> u64 {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Feed one observation. NaN is dropped (one poisoned sample must
    /// not corrupt the marker invariants).
    pub fn observe(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        self.n += 1;
        if self.n <= 5 {
            self.init.push(x);
            if self.n == 5 {
                self.init.sort_unstable_by(f64::total_cmp);
                for (h, &v) in self.heights.iter_mut().zip(&self.init) {
                    *h = v;
                }
            }
            return;
        }
        // Locate the cell k such that heights[k] <= x < heights[k+1],
        // extending the extreme markers when x falls outside them.
        let k = if x < self.heights[0] {
            self.heights[0] = x;
            0
        } else if x >= self.heights[4] {
            self.heights[4] = x;
            3
        } else {
            let mut k = 0;
            for i in 0..4 {
                if self.heights[i] <= x {
                    k = i;
                }
            }
            k
        };
        for p in &mut self.pos[k + 1..] {
            *p += 1.0;
        }
        for (d, inc) in self.desired.iter_mut().zip(&self.incr) {
            *d += inc;
        }
        // Adjust the three interior markers toward their desired
        // positions, by the piecewise-parabolic (P²) formula, falling
        // back to linear interpolation when the parabola would push a
        // height past its neighbor.
        for i in 1..4 {
            let d = self.desired[i] - self.pos[i];
            if (d >= 1.0 && self.pos[i + 1] - self.pos[i] > 1.0)
                || (d <= -1.0 && self.pos[i - 1] - self.pos[i] < -1.0)
            {
                let s = d.signum();
                let h = self.parabolic(i, s);
                self.heights[i] = if self.heights[i - 1] < h && h < self.heights[i + 1] {
                    h
                } else {
                    self.linear(i, s)
                };
                self.pos[i] += s;
            }
        }
    }

    fn parabolic(&self, i: usize, s: f64) -> f64 {
        let (hm, h, hp) = (self.heights[i - 1], self.heights[i], self.heights[i + 1]);
        let (pm, p, pp) = (self.pos[i - 1], self.pos[i], self.pos[i + 1]);
        h + s / (pp - pm)
            * ((p - pm + s) * (hp - h) / (pp - p) + (pp - p - s) * (h - hm) / (p - pm))
    }

    fn linear(&self, i: usize, s: f64) -> f64 {
        let j = if s > 0.0 { i + 1 } else { i - 1 };
        self.heights[i]
            + s * (self.heights[j] - self.heights[i]) / (self.pos[j] - self.pos[i])
    }

    /// Current estimate of quantile `q`. Exact (sorted-buffer
    /// interpolation) while n < 5; the center marker height afterwards.
    pub fn estimate(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        if self.n < 5 {
            let mut v = self.init.clone();
            v.sort_unstable_by(f64::total_cmp);
            return crate::util::stats::percentile(&v, self.q * 100.0);
        }
        self.heights[2]
    }
}

/// Default bucket upper bounds (virtual seconds): log-ish spacing that
/// covers task durations through multi-hour workflow makespans.
pub const DEFAULT_BOUNDS: [f64; 12] =
    [1.0, 5.0, 15.0, 60.0, 120.0, 300.0, 600.0, 1200.0, 1800.0, 3600.0, 7200.0, 14400.0];

/// A constant-memory distribution summary: count/sum/min/max, fixed
/// exposition buckets, exact quantiles up to [`EXACT_CAP`] observations,
/// and P² estimates beyond.
#[derive(Debug, Clone)]
pub struct Histogram {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    /// Exact buffer: the first [`EXACT_CAP`] observations.
    exact: Vec<f64>,
    /// One P² estimator per [`TRACKED_QUANTILES`] entry, fed from the
    /// first observation so the handoff at the cap is seamless.
    estimators: Vec<P2>,
    /// Bucket upper bounds (ascending); the implicit +Inf bucket is
    /// `count` itself.
    bounds: Vec<f64>,
    /// Non-cumulative per-bucket counts, `bounds.len()` entries.
    buckets: Vec<u64>,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::with_bounds(&DEFAULT_BOUNDS)
    }

    /// Custom exposition buckets (`bounds` must be ascending).
    pub fn with_bounds(bounds: &[f64]) -> Self {
        debug_assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bounds must ascend");
        Histogram {
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            exact: Vec::new(),
            estimators: TRACKED_QUANTILES.iter().map(|&q| P2::new(q)).collect(),
            bounds: bounds.to_vec(),
            buckets: vec![0; bounds.len()],
        }
    }

    /// Feed one observation (NaN dropped).
    pub fn observe(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        self.count += 1;
        self.sum += x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if self.exact.len() < EXACT_CAP {
            self.exact.push(x);
        }
        for e in &mut self.estimators {
            e.observe(x);
        }
        for (i, &b) in self.bounds.iter().enumerate() {
            if x <= b {
                self.buckets[i] += 1;
                break;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// Whether quantiles are still exact (n within the buffer).
    pub fn is_exact(&self) -> bool {
        self.count as usize <= EXACT_CAP
    }

    /// Quantile estimate. While the run is small (`is_exact`) this is
    /// the same linear-interpolated percentile as
    /// [`crate::util::stats::percentile`], for *any* q. Beyond the
    /// buffer, the nearest [`TRACKED_QUANTILES`] estimator answers, and
    /// the readout is clamped to `[min, max]` and made monotone across
    /// the tracked set (independent P² markers can cross by their error
    /// bound; a quantile readout must not).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if self.is_exact() {
            return crate::util::stats::percentile(&self.exact, q * 100.0);
        }
        let quantiles = self.quantiles();
        let mut best = quantiles[0];
        for &(tq, v) in &quantiles {
            if (tq - q).abs() < (best.0 - q).abs() {
                best = (tq, v);
            }
        }
        best.1
    }

    /// All tracked quantiles, monotone and clamped to the observed
    /// range.
    pub fn quantiles(&self) -> Vec<(f64, f64)> {
        let mut out = Vec::with_capacity(self.estimators.len());
        let mut floor = f64::NEG_INFINITY;
        for e in &self.estimators {
            let v = if self.is_exact() {
                crate::util::stats::percentile(&self.exact, e.q() * 100.0)
            } else {
                e.estimate().clamp(self.min, self.max)
            };
            let v = v.max(floor);
            floor = v;
            out.push((e.q(), v));
        }
        out
    }

    /// Cumulative `(upper_bound, count)` pairs for Prometheus
    /// exposition; the caller appends the `+Inf` bucket as `count()`.
    pub fn cumulative_buckets(&self) -> Vec<(f64, u64)> {
        let mut cum = 0u64;
        self.bounds
            .iter()
            .zip(&self.buckets)
            .map(|(&b, &c)| {
                cum += c;
                (b, cum)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simcore::Rng;
    use crate::util::stats::percentile;

    fn exact(xs: &[f64], q: f64) -> f64 {
        percentile(xs, q * 100.0)
    }

    /// Deterministic pseudo-uniform stream in [0, 1000).
    fn uniform_stream(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.below(1_000_000) as f64 / 1000.0).collect()
    }

    #[test]
    fn histogram_exact_for_small_n_any_quantile() {
        // Satellite property: exact agreement with sorted-sample
        // percentiles for every n <= EXACT_CAP, across many quantiles.
        let xs = uniform_stream(EXACT_CAP, 7);
        let mut h = Histogram::new();
        for (i, &x) in xs.iter().enumerate() {
            h.observe(x);
            let seen = &xs[..=i];
            for q in [0.0, 0.1, 0.25, 0.5, 0.77, 0.9, 0.99, 1.0] {
                assert_eq!(
                    h.quantile(q).to_bits(),
                    exact(seen, q).to_bits(),
                    "n={} q={q}",
                    i + 1
                );
            }
        }
        assert!(h.is_exact());
    }

    #[test]
    fn histogram_quantiles_monotone_on_random_streams() {
        for seed in [1u64, 42, 99, 0xBEEF] {
            let mut h = Histogram::new();
            for x in uniform_stream(5000, seed) {
                h.observe(x);
            }
            let qs = h.quantiles();
            for w in qs.windows(2) {
                assert!(
                    w[0].1 <= w[1].1,
                    "seed {seed}: q{} = {} > q{} = {}",
                    w[0].0,
                    w[0].1,
                    w[1].0,
                    w[1].1
                );
            }
        }
    }

    #[test]
    fn p2_bounded_error_on_random_stream() {
        let xs = uniform_stream(10_000, 1234);
        for q in [0.5, 0.9, 0.95, 0.99] {
            let mut p = P2::new(q);
            for &x in &xs {
                p.observe(x);
            }
            let want = exact(&xs, q);
            let err = (p.estimate() - want).abs();
            // P² on a well-behaved stream tracks within a few percent of
            // the value range (1000 here).
            assert!(err < 30.0, "q={q}: est {} vs exact {want} (err {err})", p.estimate());
        }
    }

    #[test]
    fn p2_bounded_error_on_adversarial_streams() {
        let n = 5000usize;
        // Ascending and descending sorted streams.
        let asc: Vec<f64> = (0..n).map(|i| i as f64).collect();
        let desc: Vec<f64> = (0..n).rev().map(|i| i as f64).collect();
        for (name, xs) in [("asc", &asc), ("desc", &desc)] {
            for q in [0.5, 0.9, 0.99] {
                let mut p = P2::new(q);
                for &x in xs {
                    p.observe(x);
                }
                let want = exact(xs, q);
                let err = (p.estimate() - want).abs() / n as f64;
                assert!(err < 0.05, "{name} q={q}: est {} vs {want}", p.estimate());
            }
        }
        // Constant stream: every quantile is the constant, exactly.
        let mut p = P2::new(0.9);
        for _ in 0..n {
            p.observe(42.0);
        }
        assert_eq!(p.estimate(), 42.0);
        // Extreme (NaN-free) magnitudes stay within observed range.
        let mut h = Histogram::new();
        let mut rng = Rng::new(5);
        for _ in 0..1000 {
            let mag = match rng.below(3) {
                0 => 1e-9,
                1 => 1.0,
                _ => 1e12,
            };
            h.observe(mag);
        }
        for (_, v) in h.quantiles() {
            assert!((1e-9..=1e12).contains(&v), "estimate {v} escaped observed range");
        }
    }

    #[test]
    fn histogram_counts_sum_and_buckets() {
        let mut h = Histogram::with_bounds(&[1.0, 10.0, 100.0]);
        for x in [0.5, 5.0, 50.0, 500.0] {
            h.observe(x);
        }
        assert_eq!(h.count(), 4);
        assert_eq!(h.sum(), 555.5);
        assert_eq!(h.min(), 0.5);
        assert_eq!(h.max(), 500.0);
        assert_eq!(h.cumulative_buckets(), vec![(1.0, 1), (10.0, 2), (100.0, 3)]);
    }

    #[test]
    fn nan_observations_are_dropped() {
        let mut h = Histogram::new();
        h.observe(1.0);
        h.observe(f64::NAN);
        h.observe(3.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.quantile(0.5), 2.0);
    }

    #[test]
    fn empty_histogram_is_safe() {
        let h = Histogram::new();
        assert_eq!(h.count(), 0);
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0.0);
        assert_eq!(h.max(), 0.0);
    }

    #[test]
    fn deterministic_across_runs() {
        // Same stream, two instances: bit-identical state at readout —
        // the golden-trace-compatible property everything else rests on.
        let xs = uniform_stream(3000, 77);
        let (mut a, mut b) = (Histogram::new(), Histogram::new());
        for &x in &xs {
            a.observe(x);
            b.observe(x);
        }
        for ((qa, va), (qb, vb)) in a.quantiles().into_iter().zip(b.quantiles()) {
            assert_eq!(qa, qb);
            assert_eq!(va.to_bits(), vb.to_bits());
        }
    }
}
