//! Line-JSON span/event journal behind `run --trace-out FILE`.
//!
//! One JSON object per line (keys sorted by the emitter), schema
//! version 1:
//!
//! ```text
//! {"pattern":"constant","policy":"aras","seed":42,"type":"meta","version":1,"workflow":"montage"}
//! {"phase":"serve_cycle","seq":0,"t":12.5,"type":"span","wall_ns":0}
//! {"detail":"","kind":"PodCreated","t":30,"task":"mProject_1","type":"event","workflow":0}
//! {"events":M,"spans":N,"type":"end"}
//! ```
//!
//! The journal is deterministic: spans carry virtual time and a
//! sequence number (wall_ns is 0 unless the producer opted into wall
//! clocks), events are the collector's event log in order, and the
//! trailing `end` line carries counts so a truncated file fails
//! [`Journal::parse`] loudly. Round-tripping `to_jsonl` → `parse` is
//! exact and covered by tests.

use super::{Phase, SpanRecord};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};

pub const TRACE_VERSION: i64 = 1;

/// Run identity stamped on the first journal line.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct TraceMeta {
    pub workflow: String,
    pub pattern: String,
    pub policy: String,
    pub seed: u64,
}

/// One collector event, flattened to wire strings.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub t: f64,
    pub workflow_uid: u64,
    pub task_id: String,
    pub kind: String,
    pub detail: String,
}

/// A full trace journal: meta, spans, events.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Journal {
    pub meta: TraceMeta,
    pub spans: Vec<SpanRecord>,
    pub events: Vec<TraceEvent>,
}

impl Journal {
    /// Serialize to line-delimited JSON (trailing newline included).
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        let meta = Json::obj(vec![
            ("type", Json::str("meta")),
            ("version", Json::num(TRACE_VERSION as f64)),
            ("workflow", Json::str(&self.meta.workflow)),
            ("pattern", Json::str(&self.meta.pattern)),
            ("policy", Json::str(&self.meta.policy)),
            ("seed", Json::num(self.meta.seed as f64)),
        ]);
        out.push_str(&meta.to_string_compact());
        out.push('\n');
        for s in &self.spans {
            let line = Json::obj(vec![
                ("type", Json::str("span")),
                ("seq", Json::num(s.seq as f64)),
                ("phase", Json::str(s.phase.name())),
                ("t", Json::num(s.t)),
                ("wall_ns", Json::num(s.wall_ns as f64)),
            ]);
            out.push_str(&line.to_string_compact());
            out.push('\n');
        }
        for e in &self.events {
            let line = Json::obj(vec![
                ("type", Json::str("event")),
                ("t", Json::num(e.t)),
                ("workflow", Json::num(e.workflow_uid as f64)),
                ("task", Json::str(&e.task_id)),
                ("kind", Json::str(&e.kind)),
                ("detail", Json::str(&e.detail)),
            ]);
            out.push_str(&line.to_string_compact());
            out.push('\n');
        }
        let end = Json::obj(vec![
            ("type", Json::str("end")),
            ("spans", Json::num(self.spans.len() as f64)),
            ("events", Json::num(self.events.len() as f64)),
        ]);
        out.push_str(&end.to_string_compact());
        out.push('\n');
        out
    }

    /// Parse and schema-validate a journal. Rejects unknown line types,
    /// missing fields, unknown phases, version mismatches, missing or
    /// mismatched `end` counts.
    pub fn parse(text: &str) -> Result<Journal> {
        let mut journal = Journal::default();
        let mut saw_meta = false;
        let mut saw_end = false;
        for (i, line) in text.lines().enumerate() {
            let n = i + 1;
            if line.is_empty() {
                continue;
            }
            if saw_end {
                bail!("line {n}: content after end line");
            }
            let j = Json::parse(line).with_context(|| format!("trace line {n}"))?;
            let ty = j
                .get("type")
                .and_then(Json::as_str)
                .with_context(|| format!("line {n}: missing 'type'"))?;
            match ty {
                "meta" => {
                    if saw_meta {
                        bail!("line {n}: duplicate meta line");
                    }
                    let version = req_i64(&j, "version", n)?;
                    if version != TRACE_VERSION {
                        bail!("line {n}: unsupported trace version {version}");
                    }
                    journal.meta = TraceMeta {
                        workflow: req_str(&j, "workflow", n)?,
                        pattern: req_str(&j, "pattern", n)?,
                        policy: req_str(&j, "policy", n)?,
                        seed: req_i64(&j, "seed", n)? as u64,
                    };
                    saw_meta = true;
                }
                "span" => {
                    let phase_name = req_str(&j, "phase", n)?;
                    let phase = Phase::parse(&phase_name)
                        .with_context(|| format!("line {n}: unknown phase '{phase_name}'"))?;
                    journal.spans.push(SpanRecord {
                        seq: req_i64(&j, "seq", n)? as u64,
                        phase,
                        t: req_f64(&j, "t", n)?,
                        wall_ns: req_i64(&j, "wall_ns", n)? as u64,
                    });
                }
                "event" => {
                    journal.events.push(TraceEvent {
                        t: req_f64(&j, "t", n)?,
                        workflow_uid: req_i64(&j, "workflow", n)? as u64,
                        task_id: req_str(&j, "task", n)?,
                        kind: req_str(&j, "kind", n)?,
                        detail: req_str(&j, "detail", n)?,
                    });
                }
                "end" => {
                    let (spans, events) =
                        (req_i64(&j, "spans", n)?, req_i64(&j, "events", n)?);
                    if spans as usize != journal.spans.len()
                        || events as usize != journal.events.len()
                    {
                        bail!(
                            "line {n}: end counts ({spans} spans, {events} events) disagree \
                             with body ({} spans, {} events)",
                            journal.spans.len(),
                            journal.events.len()
                        );
                    }
                    saw_end = true;
                }
                other => bail!("line {n}: unknown line type '{other}'"),
            }
        }
        if !saw_meta {
            bail!("trace has no meta line");
        }
        if !saw_end {
            bail!("trace has no end line (truncated?)");
        }
        Ok(journal)
    }
}

fn req_str(j: &Json, key: &str, line: usize) -> Result<String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .with_context(|| format!("line {line}: missing string '{key}'"))
}

fn req_f64(j: &Json, key: &str, line: usize) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("line {line}: missing number '{key}'"))
}

fn req_i64(j: &Json, key: &str, line: usize) -> Result<i64> {
    j.get(key)
        .and_then(Json::as_i64)
        .with_context(|| format!("line {line}: missing integer '{key}'"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Journal {
        Journal {
            meta: TraceMeta {
                workflow: "montage".into(),
                pattern: "constant".into(),
                policy: "aras".into(),
                seed: 42,
            },
            spans: vec![
                SpanRecord { seq: 0, phase: Phase::ServeCycle, t: 12.5, wall_ns: 0 },
                SpanRecord { seq: 1, phase: Phase::Plan, t: 12.5, wall_ns: 0 },
            ],
            events: vec![TraceEvent {
                t: 30.0,
                workflow_uid: 0,
                task_id: "mProject_1".into(),
                kind: "PodCreated".into(),
                detail: String::new(),
            }],
        }
    }

    #[test]
    fn round_trips_exactly() {
        let j = sample();
        let text = j.to_jsonl();
        let back = Journal::parse(&text).unwrap();
        assert_eq!(j, back);
        // And the re-serialization is byte-identical.
        assert_eq!(text, back.to_jsonl());
    }

    #[test]
    fn rejects_truncation_and_corruption() {
        let text = sample().to_jsonl();
        // Drop the end line: truncation must fail.
        let truncated: String =
            text.lines().take(text.lines().count() - 1).map(|l| format!("{l}\n")).collect();
        assert!(Journal::parse(&truncated).is_err());
        // Tamper with the end count.
        let tampered = text.replace("\"spans\":2", "\"spans\":7");
        assert!(Journal::parse(&tampered).is_err());
        // Unknown phase.
        let badphase = text.replace("serve_cycle", "warp_drive");
        assert!(Journal::parse(&badphase).is_err());
        // Unknown line type.
        let badtype = text.replace("\"type\":\"span\"", "\"type\":\"mystery\"");
        assert!(Journal::parse(&badtype).is_err());
        // Version bump.
        let badver = text.replace("\"version\":1", "\"version\":99");
        assert!(Journal::parse(&badver).is_err());
    }

    #[test]
    fn empty_sections_round_trip() {
        let j = Journal {
            meta: TraceMeta {
                workflow: "w".into(),
                pattern: "p".into(),
                policy: "x".into(),
                seed: 0,
            },
            spans: vec![],
            events: vec![],
        };
        assert_eq!(Journal::parse(&j.to_jsonl()).unwrap(), j);
    }
}
