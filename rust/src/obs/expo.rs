//! Prometheus text-format exposition (version 0.0.4).
//!
//! A tiny append-only renderer — `# HELP` / `# TYPE` headers followed by
//! sample lines — plus a [`validate`] checker used by tests and the CI
//! smoke step. No client library, no registry: the engine builds a
//! fresh exposition from its live counters on every daemon `metrics`
//! request, which keeps the hot path free of metric bookkeeping it
//! doesn't already do.

use super::quantile::Histogram;

/// Format a sample value the way Prometheus expects: integers without a
/// fraction, everything else via Rust's shortest-roundtrip `{}`.
fn fmt_val(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Builder for one exposition document.
#[derive(Debug, Default)]
pub struct TextExposition {
    out: String,
}

impl TextExposition {
    pub fn new() -> Self {
        Self::default()
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    }

    /// A single unlabeled counter.
    pub fn counter(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "counter");
        self.out.push_str(&format!("{name} {}\n", fmt_val(value)));
    }

    /// A counter family with one label dimension.
    pub fn counter_vec(&mut self, name: &str, help: &str, label: &str, series: &[(&str, f64)]) {
        self.header(name, help, "counter");
        for (lv, v) in series {
            self.out.push_str(&format!("{name}{{{label}=\"{lv}\"}} {}\n", fmt_val(*v)));
        }
    }

    /// A single unlabeled gauge.
    pub fn gauge(&mut self, name: &str, help: &str, value: f64) {
        self.header(name, help, "gauge");
        self.out.push_str(&format!("{name} {}\n", fmt_val(value)));
    }

    /// A gauge family with one label dimension.
    pub fn gauge_vec(&mut self, name: &str, help: &str, label: &str, series: &[(&str, f64)]) {
        self.header(name, help, "gauge");
        for (lv, v) in series {
            self.out.push_str(&format!("{name}{{{label}=\"{lv}\"}} {}\n", fmt_val(*v)));
        }
    }

    /// A full histogram: cumulative `le` buckets, `+Inf`, `_sum`,
    /// `_count`.
    pub fn histogram(&mut self, name: &str, help: &str, h: &Histogram) {
        self.header(name, help, "histogram");
        for (bound, cum) in h.cumulative_buckets() {
            self.out
                .push_str(&format!("{name}_bucket{{le=\"{}\"}} {cum}\n", fmt_val(bound)));
        }
        self.out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count()));
        self.out.push_str(&format!("{name}_sum {}\n", fmt_val(h.sum())));
        self.out.push_str(&format!("{name}_count {}\n", h.count()));
    }

    pub fn render(self) -> String {
        self.out
    }
}

/// Structural validation of an exposition document. Checks that every
/// sample line belongs to a `# TYPE`-declared metric, values parse as
/// floats, and every histogram carries its `+Inf` bucket, `_sum` and
/// `_count` series. Returns the first violation as an error string.
pub fn validate(text: &str) -> Result<(), String> {
    use std::collections::BTreeMap;
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    for (i, line) in text.lines().enumerate() {
        let n = i + 1;
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (name, kind) = (it.next().unwrap_or(""), it.next().unwrap_or(""));
            if name.is_empty() || !matches!(kind, "counter" | "gauge" | "histogram") {
                return Err(format!("line {n}: malformed TYPE line: {line}"));
            }
            types.insert(name.to_string(), kind.to_string());
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        // Sample line: name[{labels}] value
        let name_end = line.find(['{', ' ']).ok_or(format!("line {n}: no value: {line}"))?;
        let full_name = &line[..name_end];
        let value = line
            .rsplit(' ')
            .next()
            .ok_or(format!("line {n}: no value: {line}"))?;
        value
            .parse::<f64>()
            .map_err(|_| format!("line {n}: bad value '{value}'"))?;
        let base = full_name
            .strip_suffix("_bucket")
            .or_else(|| full_name.strip_suffix("_sum"))
            .or_else(|| full_name.strip_suffix("_count"))
            .filter(|b| types.get(*b).map(String::as_str) == Some("histogram"))
            .unwrap_or(full_name);
        if !types.contains_key(base) {
            return Err(format!("line {n}: sample for undeclared metric '{full_name}'"));
        }
    }
    // Every histogram must expose +Inf, _sum and _count.
    for (name, kind) in &types {
        if kind == "histogram" {
            for needle in [
                format!("{name}_bucket{{le=\"+Inf\"}} "),
                format!("{name}_sum "),
                format!("{name}_count "),
            ] {
                if !text.contains(&needle) {
                    return Err(format!("histogram '{name}' missing series '{}'", needle.trim()));
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_counters_gauges_and_histograms() {
        let mut h = Histogram::with_bounds(&[1.0, 10.0]);
        for x in [0.5, 5.0, 50.0] {
            h.observe(x);
        }
        let mut e = TextExposition::new();
        e.counter("ka_cycles_total", "Serve cycles.", 12.0);
        e.counter_vec(
            "ka_phase_calls_total",
            "Calls per phase.",
            "phase",
            &[("plan", 3.0), ("schedule", 4.0)],
        );
        e.gauge("ka_queue_depth", "Queue depth.", 2.0);
        e.gauge_vec(
            "ka_cluster_nodes",
            "Nodes per cluster.",
            "cluster",
            &[("east", 4.0), ("west", 8.0)],
        );
        e.histogram("ka_wf_duration_seconds", "Workflow durations.", &h);
        let text = e.render();
        assert!(text.contains("# TYPE ka_cycles_total counter"));
        assert!(text.contains("ka_phase_calls_total{phase=\"plan\"} 3"));
        assert!(text.contains("# TYPE ka_cluster_nodes gauge"));
        assert!(text.contains("ka_cluster_nodes{cluster=\"west\"} 8"));
        assert!(text.contains("ka_wf_duration_seconds_bucket{le=\"10\"} 2"));
        assert!(text.contains("ka_wf_duration_seconds_bucket{le=\"+Inf\"} 3"));
        assert!(text.contains("ka_wf_duration_seconds_sum 55.5"));
        assert!(text.contains("ka_wf_duration_seconds_count 3"));
        validate(&text).unwrap();
    }

    #[test]
    fn validator_rejects_undeclared_and_malformed() {
        assert!(validate("ka_orphan 1\n").is_err());
        let missing_inf = "# HELP h x\n# TYPE h histogram\nh_sum 1\nh_count 1\n";
        assert!(validate(missing_inf).is_err());
        let bad_value = "# HELP c x\n# TYPE c counter\nc notanumber\n";
        assert!(validate(bad_value).is_err());
        let ok = "# HELP c x\n# TYPE c counter\nc 1\n";
        validate(ok).unwrap();
    }
}
