//! Workflow DAG: validation, topological order, schedule estimation.

use super::task::TaskSpec;

/// The four scientific workflows evaluated in the paper plus Custom.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkflowType {
    /// Astronomy mosaics — fork-join with data-dependent diffs (21 tasks).
    Montage,
    /// Genome sequencing — four parallel pipelines (20 tasks).
    Epigenomics,
    /// Earthquake science — shallow and very wide (22 tasks).
    CyberShake,
    /// Gravitational-wave analysis — two concurrent phases (23 tasks).
    Ligo,
    /// User-supplied JSON definition.
    Custom,
}

impl WorkflowType {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_lowercase().as_str() {
            "montage" => Ok(WorkflowType::Montage),
            "epigenomics" => Ok(WorkflowType::Epigenomics),
            "cybershake" => Ok(WorkflowType::CyberShake),
            "ligo" | "inspiral" => Ok(WorkflowType::Ligo),
            "custom" => Ok(WorkflowType::Custom),
            other => anyhow::bail!("unknown workflow '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            WorkflowType::Montage => "montage",
            WorkflowType::Epigenomics => "epigenomics",
            WorkflowType::CyberShake => "cybershake",
            WorkflowType::Ligo => "ligo",
            WorkflowType::Custom => "custom",
        }
    }

    /// The paper's four evaluation workflows.
    pub fn paper_set() -> [WorkflowType; 4] {
        [
            WorkflowType::Montage,
            WorkflowType::Epigenomics,
            WorkflowType::CyberShake,
            WorkflowType::Ligo,
        ]
    }
}

/// A validated workflow definition (a DAG of [`TaskSpec`]s).
#[derive(Debug, Clone)]
pub struct WorkflowSpec {
    pub kind: WorkflowType,
    pub name: String,
    pub tasks: Vec<TaskSpec>,
    /// Optional workflow deadline SLO (seconds from injection; Eq. 3/4).
    pub deadline_s: Option<f64>,
}

#[derive(Debug)]
pub enum DagError {
    BadDep(usize, usize),
    Cycle(usize),
    Empty,
}

impl std::fmt::Display for DagError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DagError::BadDep(task, dep) => {
                write!(f, "task {task} has out-of-range dependency {dep}")
            }
            DagError::Cycle(task) => {
                write!(f, "dependency cycle detected involving task {task}")
            }
            DagError::Empty => write!(f, "workflow has no tasks"),
        }
    }
}

impl std::error::Error for DagError {}

impl WorkflowSpec {
    /// Validate structure: deps in range, acyclic, non-empty.
    pub fn validate(&self) -> Result<(), DagError> {
        if self.tasks.is_empty() {
            return Err(DagError::Empty);
        }
        for (i, t) in self.tasks.iter().enumerate() {
            for &d in &t.deps {
                if d >= self.tasks.len() {
                    return Err(DagError::BadDep(i, d));
                }
            }
        }
        self.topo_order().map(|_| ())
    }

    /// Kahn topological order; errors on cycles.
    pub fn topo_order(&self) -> Result<Vec<usize>, DagError> {
        let n = self.tasks.len();
        let mut indeg = vec![0usize; n];
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, t) in self.tasks.iter().enumerate() {
            for &d in &t.deps {
                indeg[i] += 1;
                succs[d].push(i);
            }
        }
        let mut queue: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut head = 0;
        while head < queue.len() {
            let u = queue[head];
            head += 1;
            order.push(u);
            for &v in &succs[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if order.len() != n {
            let stuck = (0..n).find(|&i| indeg[i] > 0).unwrap_or(0);
            return Err(DagError::Cycle(stuck));
        }
        Ok(order)
    }

    /// Successor adjacency (used by the engine to release ready tasks).
    pub fn successors(&self) -> Vec<Vec<usize>> {
        let mut succs: Vec<Vec<usize>> = vec![Vec::new(); self.tasks.len()];
        for (i, t) in self.tasks.iter().enumerate() {
            for &d in &t.deps {
                succs[d].push(i);
            }
        }
        succs
    }

    /// Source tasks (no dependencies).
    pub fn sources(&self) -> Vec<usize> {
        (0..self.tasks.len()).filter(|&i| self.tasks[i].deps.is_empty()).collect()
    }

    /// Sink tasks (no successors).
    pub fn sinks(&self) -> Vec<usize> {
        let succs = self.successors();
        (0..self.tasks.len()).filter(|&i| succs[i].is_empty()).collect()
    }

    /// Estimated start times assuming each task starts as soon as its
    /// predecessors finish. `startup_s` is the pod create→Running latency;
    /// `gap_s` the pred-completion→successor-request latency (deletion
    /// feedback + informer propagation). This is the schedule the
    /// Interface Unit writes to the state store for ARAS's lookahead
    /// (Alg. 1 lines 8–13, Fig. 1) — accuracy matters: a future task only
    /// competes for resources if its estimated start falls inside the
    /// current task's lifecycle window.
    pub fn estimate_schedule(&self, base: f64, startup_s: f64, gap_s: f64) -> Vec<(f64, f64)> {
        let order = self.topo_order().expect("validated dag");
        let mut est = vec![(0.0f64, 0.0f64); self.tasks.len()];
        for &i in &order {
            let ready = self.tasks[i]
                .deps
                .iter()
                .map(|&d| est[d].1 + gap_s)
                .fold(base, f64::max);
            let start = ready + startup_s;
            est[i] = (start, start + self.tasks[i].duration_s);
        }
        est
    }

    /// Maximum number of structurally concurrent tasks (max antichain
    /// level width) — used by tests to characterize the Fig. 4 shapes.
    pub fn max_width(&self) -> usize {
        let order = self.topo_order().expect("validated dag");
        let mut level = vec![0usize; self.tasks.len()];
        for &i in &order {
            level[i] = self.tasks[i].deps.iter().map(|&d| level[d] + 1).max().unwrap_or(0);
        }
        let max_level = level.iter().copied().max().unwrap_or(0);
        let mut width = vec![0usize; max_level + 1];
        for &l in &level {
            width[l] += 1;
        }
        width.into_iter().max().unwrap_or(0)
    }

    /// DAG depth (longest chain length).
    pub fn depth(&self) -> usize {
        let order = self.topo_order().expect("validated dag");
        let mut level = vec![0usize; self.tasks.len()];
        for &i in &order {
            level[i] = self.tasks[i].deps.iter().map(|&d| level[d] + 1).max().unwrap_or(0);
        }
        level.into_iter().max().unwrap_or(0) + 1
    }

    /// Graphviz DOT rendering (Fig. 4 regeneration).
    pub fn to_dot(&self) -> String {
        let mut s = format!("digraph \"{}\" {{\n  rankdir=TB;\n", self.name);
        for (i, t) in self.tasks.iter().enumerate() {
            s.push_str(&format!("  n{} [label=\"{}\"];\n", i, t.name));
        }
        for (i, t) in self.tasks.iter().enumerate() {
            for &d in &t.deps {
                s.push_str(&format!("  n{} -> n{};\n", d, i));
            }
        }
        s.push_str("}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> WorkflowSpec {
        WorkflowSpec {
            kind: WorkflowType::Custom,
            name: "diamond".into(),
            tasks: vec![
                TaskSpec::stage("a", vec![]),
                TaskSpec::stage("b", vec![0]),
                TaskSpec::stage("c", vec![0]),
                TaskSpec::stage("d", vec![1, 2]),
            ],
            deadline_s: None,
        }
    }

    #[test]
    fn topo_order_respects_deps() {
        let wf = diamond();
        let order = wf.topo_order().unwrap();
        let pos: Vec<usize> = {
            let mut p = vec![0; 4];
            for (rank, &i) in order.iter().enumerate() {
                p[i] = rank;
            }
            p
        };
        assert!(pos[0] < pos[1] && pos[0] < pos[2]);
        assert!(pos[1] < pos[3] && pos[2] < pos[3]);
    }

    #[test]
    fn cycle_detected() {
        let mut wf = diamond();
        wf.tasks[0].deps = vec![3];
        assert!(matches!(wf.validate(), Err(DagError::Cycle(_))));
    }

    #[test]
    fn bad_dep_detected() {
        let mut wf = diamond();
        wf.tasks[1].deps = vec![9];
        assert!(matches!(wf.validate(), Err(DagError::BadDep(1, 9))));
    }

    #[test]
    fn sources_and_sinks() {
        let wf = diamond();
        assert_eq!(wf.sources(), vec![0]);
        assert_eq!(wf.sinks(), vec![3]);
    }

    #[test]
    fn schedule_estimation_chains_durations() {
        let mut wf = diamond();
        for t in &mut wf.tasks {
            t.duration_s = 10.0;
        }
        let est = wf.estimate_schedule(100.0, 2.0, 3.0);
        assert_eq!(est[0], (102.0, 112.0));
        assert_eq!(est[1], (117.0, 127.0)); // 112 + gap 3 + startup 2
        assert_eq!(est[3], (132.0, 142.0)); // after max(b,c) = 127
    }

    #[test]
    fn width_and_depth() {
        let wf = diamond();
        assert_eq!(wf.max_width(), 2);
        assert_eq!(wf.depth(), 3);
    }

    #[test]
    fn dot_contains_all_edges() {
        let dot = diamond().to_dot();
        assert!(dot.contains("n0 -> n1"));
        assert!(dot.contains("n2 -> n3"));
    }
}
