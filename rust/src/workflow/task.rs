//! Workflow task definition — the paper's Eq. (1):
//! `s_ij = {sla, id, image, cpu, mem, duration, min_cpu, min_mem}`.

/// A task template inside a workflow DAG. Durations are filled at
/// instantiation time (sampled U[lo,hi] per §6.1.3) — `duration = 0`
/// in a template means "sample at injection".
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Human-readable stage name (e.g. "mProjectPP-2").
    pub name: String,
    /// Docker image address (metadata only in the simulator).
    pub image: String,
    /// Requested CPU, milli-cores (Eq. 1 `cpu`).
    pub cpu_milli: i64,
    /// Requested memory, Mi (Eq. 1 `mem`).
    pub mem_mi: i64,
    /// Minimum CPU to run (Eq. 1 `min_cpu`).
    pub min_cpu_milli: i64,
    /// Minimum memory to run (Eq. 1 `min_mem` — the Stress allocation).
    pub min_mem_mi: i64,
    /// Predefined duration in seconds (0 = sample at injection).
    pub duration_s: f64,
    /// Indices of predecessor tasks within the workflow.
    pub deps: Vec<usize>,
    /// Optional per-task deadline SLO (seconds from workflow start).
    pub deadline_s: Option<f64>,
}

impl TaskSpec {
    /// A template with paper-default resources and dependencies `deps`.
    pub fn stage(name: impl Into<String>, deps: Vec<usize>) -> TaskSpec {
        TaskSpec {
            name: name.into(),
            image: "registry.local/task-emulator:latest".into(),
            cpu_milli: 2000,
            mem_mi: 4000,
            min_cpu_milli: 200,
            min_mem_mi: 1000,
            duration_s: 0.0,
            deps,
            deadline_s: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stage_defaults_match_paper() {
        let t = TaskSpec::stage("x", vec![0, 1]);
        assert_eq!(t.cpu_milli, 2000);
        assert_eq!(t.mem_mi, 4000);
        assert_eq!(t.min_mem_mi, 1000);
        assert_eq!(t.deps, vec![0, 1]);
    }
}
