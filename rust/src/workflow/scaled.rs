//! Parameterized (scaled) topology generators.
//!
//! The paper evaluates the small-scale (~20-task) instances from the
//! Pegasus workflow gallery; the gallery also ships medium and large
//! variants. These generators produce the same structural families at
//! arbitrary scale so the engine can be driven far beyond the paper's
//! sizes (used by the `cluster_scaling` example and scale tests).

use super::dag::{WorkflowSpec, WorkflowType};
use super::task::TaskSpec;

/// Scaled Montage: `w` parallel mProjectPP, pairwise diffs for every
/// projection pair at distance <= 3 (the gallery's overlap structure),
/// `w` backgrounds, then the linear tail.
pub fn montage(w: usize) -> WorkflowSpec {
    assert!(w >= 2, "montage needs at least 2 projections");
    let mut t = Vec::new();
    t.push(TaskSpec::stage("entry", vec![]));
    let proj: Vec<usize> = (0..w)
        .map(|i| {
            t.push(TaskSpec::stage(format!("mProjectPP-{i}"), vec![0]));
            t.len() - 1
        })
        .collect();
    let mut diffs = Vec::new();
    for i in 0..w {
        for d in 1..=3usize {
            if i + d < w {
                t.push(TaskSpec::stage(
                    format!("mDiffFit-{i}-{}", i + d),
                    vec![proj[i], proj[i + d]],
                ));
                diffs.push(t.len() - 1);
            }
        }
    }
    t.push(TaskSpec::stage("mConcatFit", diffs));
    let concat = t.len() - 1;
    t.push(TaskSpec::stage("mBgModel", vec![concat]));
    let bg = t.len() - 1;
    let backgrounds: Vec<usize> = (0..w)
        .map(|i| {
            t.push(TaskSpec::stage(format!("mBackground-{i}"), vec![bg, proj[i]]));
            t.len() - 1
        })
        .collect();
    t.push(TaskSpec::stage("mImgtbl", backgrounds));
    let imgtbl = t.len() - 1;
    t.push(TaskSpec::stage("mAdd", vec![imgtbl]));
    t.push(TaskSpec::stage("mShrink", vec![t.len() - 1]));
    t.push(TaskSpec::stage("mJPEG", vec![t.len() - 1]));
    WorkflowSpec {
        kind: WorkflowType::Montage,
        name: format!("montage-{w}"),
        tasks: t,
        deadline_s: None,
    }
}

/// Scaled Epigenomics: `lanes` parallel pipelines of `stages` steps.
pub fn epigenomics(lanes: usize, stages: usize) -> WorkflowSpec {
    assert!(lanes >= 1 && stages >= 1);
    let mut t = Vec::new();
    t.push(TaskSpec::stage("fastqSplit", vec![]));
    let mut lane_ends = Vec::new();
    for lane in 0..lanes {
        let mut prev = 0usize;
        for s in 0..stages {
            t.push(TaskSpec::stage(format!("lane{lane}-stage{s}"), vec![prev]));
            prev = t.len() - 1;
        }
        lane_ends.push(prev);
    }
    t.push(TaskSpec::stage("mapMerge", lane_ends));
    let merge = t.len() - 1;
    t.push(TaskSpec::stage("maqIndex", vec![merge]));
    t.push(TaskSpec::stage("pileup", vec![t.len() - 1]));
    WorkflowSpec {
        kind: WorkflowType::Epigenomics,
        name: format!("epigenomics-{lanes}x{stages}"),
        tasks: t,
        deadline_s: None,
    }
}

/// Scaled CyberShake: `sgt` extractions, `per` synthesis jobs each.
pub fn cybershake(sgt: usize, per: usize) -> WorkflowSpec {
    assert!(sgt >= 1 && per >= 1);
    let mut t = Vec::new();
    t.push(TaskSpec::stage("entry", vec![]));
    let mut synth = Vec::new();
    let mut peaks = Vec::new();
    for e in 0..sgt {
        t.push(TaskSpec::stage(format!("ExtractSGT-{e}"), vec![0]));
        let ex = t.len() - 1;
        for s in 0..per {
            t.push(TaskSpec::stage(format!("SeismogramSynthesis-{e}-{s}"), vec![ex]));
            let sy = t.len() - 1;
            synth.push(sy);
            t.push(TaskSpec::stage(format!("PeakValCalcOkaya-{e}-{s}"), vec![sy]));
            peaks.push(t.len() - 1);
        }
    }
    t.push(TaskSpec::stage("ZipSeis", synth));
    let zs = t.len() - 1;
    t.push(TaskSpec::stage("ZipPSA", peaks));
    let zp = t.len() - 1;
    t.push(TaskSpec::stage("exit", vec![zs, zp]));
    WorkflowSpec {
        kind: WorkflowType::CyberShake,
        name: format!("cybershake-{sgt}x{per}"),
        tasks: t,
        deadline_s: None,
    }
}

/// Scaled LIGO Inspiral: `banks` template banks per phase.
pub fn ligo(banks: usize) -> WorkflowSpec {
    assert!(banks >= 1);
    let mut t = Vec::new();
    t.push(TaskSpec::stage("entry", vec![]));
    let insp1: Vec<usize> = (0..banks)
        .map(|i| {
            t.push(TaskSpec::stage(format!("TmpltBank-{i}"), vec![0]));
            let b = t.len() - 1;
            t.push(TaskSpec::stage(format!("Inspiral1-{i}"), vec![b]));
            t.len() - 1
        })
        .collect();
    t.push(TaskSpec::stage("Thinca1", insp1));
    let th1 = t.len() - 1;
    let insp2: Vec<usize> = (0..banks)
        .map(|i| {
            t.push(TaskSpec::stage(format!("TrigBank-{i}"), vec![th1]));
            let b = t.len() - 1;
            t.push(TaskSpec::stage(format!("Inspiral2-{i}"), vec![b]));
            t.len() - 1
        })
        .collect();
    t.push(TaskSpec::stage("Thinca2", insp2));
    WorkflowSpec { kind: WorkflowType::Ligo, name: format!("ligo-{banks}"), tasks: t, deadline_s: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_scale_matches_small_topologies() {
        assert_eq!(montage(4).tasks.len(), 21);
        assert_eq!(epigenomics(4, 4).tasks.len(), 20);
        assert_eq!(cybershake(2, 4).tasks.len(), 22);
        assert_eq!(ligo(5).tasks.len(), 23);
    }

    #[test]
    fn scaled_variants_validate() {
        for spec in [
            montage(16),
            montage(2),
            epigenomics(16, 8),
            epigenomics(1, 1),
            cybershake(8, 16),
            cybershake(1, 1),
            ligo(50),
            ligo(1),
        ] {
            spec.validate().unwrap_or_else(|e| panic!("{}: {e}", spec.name));
        }
    }

    #[test]
    fn montage_diff_count_follows_overlap_rule() {
        // distance <= 3 pairs of w projections: 3w - 6 for w > 3.
        let w = 10;
        let spec = montage(w);
        let diffs = spec.tasks.iter().filter(|t| t.name.starts_with("mDiffFit")).count();
        assert_eq!(diffs, 3 * w - 6);
    }

    #[test]
    fn width_scales_with_parameters() {
        assert!(cybershake(8, 16).max_width() >= 128);
        assert_eq!(epigenomics(12, 3).max_width(), 12);
        assert_eq!(ligo(20).max_width(), 20);
    }

    #[test]
    fn large_workflow_runs_end_to_end() {
        use crate::config::{ArrivalPattern, ExperimentConfig};
        use crate::engine::Engine;
        use crate::resources::AdaptivePolicy;
        use crate::workflow::WorkflowType;

        let spec = cybershake(4, 8); // 72 tasks, width 32
        let mut cfg = ExperimentConfig::default();
        cfg.workload.workflow = WorkflowType::Custom;
        cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 2, bursts: 1 };
        cfg.sample_interval_s = 10.0;
        let out = Engine::with_custom_workflow(cfg, Box::new(AdaptivePolicy::new(0.8, true)), &spec)
            .unwrap()
            .run();
        assert_eq!(out.summary.workflows_completed, 2);
        assert_eq!(out.summary.tasks_completed, 2 * 72);
    }
}
