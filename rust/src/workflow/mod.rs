//! Workflow model: tasks (Eq. 1), DAGs, the four scientific topologies
//! (Fig. 4) and a JSON parser for user-defined workflows.

pub mod dag;
pub mod parser;
pub mod scaled;
pub mod task;
pub mod topologies;

pub use dag::{WorkflowSpec, WorkflowType};
pub use task::TaskSpec;
