//! The paper's four small-scale scientific workflow topologies (Fig. 4).
//!
//! Derived from the Pegasus workflow gallery shapes with virtual
//! entry/exit nodes where the paper adds them, matched to the paper's
//! task counts: Montage 21, Epigenomics 20, CyberShake 22, LIGO 23.
//! Structure classes covered: in-tree, out-tree, fork-join and pipeline
//! (§6.1.2). Every node uses the paper-default resource template; actual
//! durations are sampled at injection time.

use super::dag::{WorkflowSpec, WorkflowType};
use super::task::TaskSpec;

/// Build the named topology.
pub fn build(kind: WorkflowType) -> WorkflowSpec {
    match kind {
        WorkflowType::Montage => montage(),
        WorkflowType::Epigenomics => epigenomics(),
        WorkflowType::CyberShake => cybershake(),
        WorkflowType::Ligo => ligo(),
        WorkflowType::Custom => panic!("custom workflows come from parser::from_json"),
    }
}

/// Montage (astronomy, 21 tasks): fork-join with pairwise overlap diffs.
///
/// entry → 4×mProjectPP → 6×mDiffFit → mConcatFit → mBgModel →
/// 4×mBackground (each also depends on its mProjectPP) → mImgtbl → mAdd →
/// mShrink → mJPEG.
pub fn montage() -> WorkflowSpec {
    let mut t = Vec::new();
    t.push(TaskSpec::stage("entry", vec![])); // 0 (virtual entrance)
    let proj: Vec<usize> = (0..4)
        .map(|i| {
            t.push(TaskSpec::stage(format!("mProjectPP-{i}"), vec![0]));
            t.len() - 1
        })
        .collect();
    // 6 pairwise overlaps of the 4 projections: (0,1) (1,2) (2,3) (0,2) (1,3) (0,3)
    let pairs = [(0, 1), (1, 2), (2, 3), (0, 2), (1, 3), (0, 3)];
    let diffs: Vec<usize> = pairs
        .iter()
        .enumerate()
        .map(|(i, &(a, b))| {
            t.push(TaskSpec::stage(format!("mDiffFit-{i}"), vec![proj[a], proj[b]]));
            t.len() - 1
        })
        .collect();
    t.push(TaskSpec::stage("mConcatFit", diffs.clone())); // in-tree join
    let concat = t.len() - 1;
    t.push(TaskSpec::stage("mBgModel", vec![concat]));
    let bgmodel = t.len() - 1;
    let backgrounds: Vec<usize> = (0..4)
        .map(|i| {
            t.push(TaskSpec::stage(format!("mBackground-{i}"), vec![bgmodel, proj[i]]));
            t.len() - 1
        })
        .collect();
    t.push(TaskSpec::stage("mImgtbl", backgrounds.clone()));
    let imgtbl = t.len() - 1;
    t.push(TaskSpec::stage("mAdd", vec![imgtbl]));
    let madd = t.len() - 1;
    t.push(TaskSpec::stage("mShrink", vec![madd]));
    let shrink = t.len() - 1;
    t.push(TaskSpec::stage("mJPEG", vec![shrink]));
    WorkflowSpec { kind: WorkflowType::Montage, name: "montage".into(), tasks: t, deadline_s: None }
}

/// Epigenomics (genome sequencing, 20 tasks): four parallel 4-stage
/// pipelines between a split and a merge — the paper calls out its
/// pipeline structure as the high-concurrency-friendly one.
///
/// fastqSplit → 4×(filterContams → sol2sanger → fastq2bfq → map) →
/// mapMerge → maqIndex → pileup.
pub fn epigenomics() -> WorkflowSpec {
    let mut t = Vec::new();
    t.push(TaskSpec::stage("fastqSplit", vec![])); // 0
    let mut map_stages = Vec::new();
    for lane in 0..4 {
        t.push(TaskSpec::stage(format!("filterContams-{lane}"), vec![0]));
        let f = t.len() - 1;
        t.push(TaskSpec::stage(format!("sol2sanger-{lane}"), vec![f]));
        let s = t.len() - 1;
        t.push(TaskSpec::stage(format!("fastq2bfq-{lane}"), vec![s]));
        let q = t.len() - 1;
        t.push(TaskSpec::stage(format!("map-{lane}"), vec![q]));
        map_stages.push(t.len() - 1);
    }
    t.push(TaskSpec::stage("mapMerge", map_stages));
    let merge = t.len() - 1;
    t.push(TaskSpec::stage("maqIndex", vec![merge]));
    let idx = t.len() - 1;
    t.push(TaskSpec::stage("pileup", vec![idx]));
    WorkflowSpec {
        kind: WorkflowType::Epigenomics,
        name: "epigenomics".into(),
        tasks: t,
        deadline_s: None,
    }
}

/// CyberShake (earthquake science, 22 tasks): shallow and very wide —
/// "smaller depth and greater width ... higher degree of inherent
/// parallelism" (§6.2.1).
///
/// entry → 2×ExtractSGT → 8×SeismogramSynthesis → 8×PeakValCalcOkaya,
/// all synthesis → ZipSeis, all peaks → ZipPSA → exit.
pub fn cybershake() -> WorkflowSpec {
    let mut t = Vec::new();
    t.push(TaskSpec::stage("entry", vec![])); // virtual entrance
    let extracts: Vec<usize> = (0..2)
        .map(|i| {
            t.push(TaskSpec::stage(format!("ExtractSGT-{i}"), vec![0]));
            t.len() - 1
        })
        .collect();
    let mut synth = Vec::new();
    let mut peaks = Vec::new();
    for i in 0..8 {
        let parent = extracts[i / 4]; // 4 synthesis jobs per SGT
        t.push(TaskSpec::stage(format!("SeismogramSynthesis-{i}"), vec![parent]));
        let s = t.len() - 1;
        synth.push(s);
        t.push(TaskSpec::stage(format!("PeakValCalcOkaya-{i}"), vec![s]));
        peaks.push(t.len() - 1);
    }
    t.push(TaskSpec::stage("ZipSeis", synth.clone()));
    let zip_seis = t.len() - 1;
    t.push(TaskSpec::stage("ZipPSA", peaks.clone()));
    let zip_psa = t.len() - 1;
    t.push(TaskSpec::stage("exit", vec![zip_seis, zip_psa])); // virtual exit
    WorkflowSpec {
        kind: WorkflowType::CyberShake,
        name: "cybershake".into(),
        tasks: t,
        deadline_s: None,
    }
}

/// LIGO Inspiral (gravitational physics, 23 tasks): two concurrent
/// analysis phases joined by coincidence tests.
///
/// entry → 5×TmpltBank → 5×Inspiral → Thinca1 → 5×TrigBank →
/// 5×Inspiral2 → Thinca2.
pub fn ligo() -> WorkflowSpec {
    let mut t = Vec::new();
    t.push(TaskSpec::stage("entry", vec![])); // virtual entrance
    let banks: Vec<usize> = (0..5)
        .map(|i| {
            t.push(TaskSpec::stage(format!("TmpltBank-{i}"), vec![0]));
            t.len() - 1
        })
        .collect();
    let insp1: Vec<usize> = banks
        .iter()
        .enumerate()
        .map(|(i, &b)| {
            t.push(TaskSpec::stage(format!("Inspiral1-{i}"), vec![b]));
            t.len() - 1
        })
        .collect();
    t.push(TaskSpec::stage("Thinca1", insp1.clone()));
    let thinca1 = t.len() - 1;
    let trig: Vec<usize> = (0..5)
        .map(|i| {
            t.push(TaskSpec::stage(format!("TrigBank-{i}"), vec![thinca1]));
            t.len() - 1
        })
        .collect();
    let insp2: Vec<usize> = trig
        .iter()
        .enumerate()
        .map(|(i, &b)| {
            t.push(TaskSpec::stage(format!("Inspiral2-{i}"), vec![b]));
            t.len() - 1
        })
        .collect();
    t.push(TaskSpec::stage("Thinca2", insp2.clone()));
    WorkflowSpec { kind: WorkflowType::Ligo, name: "ligo".into(), tasks: t, deadline_s: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_counts_match_paper() {
        assert_eq!(montage().tasks.len(), 21);
        assert_eq!(epigenomics().tasks.len(), 20);
        assert_eq!(cybershake().tasks.len(), 22);
        assert_eq!(ligo().tasks.len(), 23);
    }

    #[test]
    fn all_topologies_validate() {
        for kind in WorkflowType::paper_set() {
            build(kind).validate().unwrap();
        }
    }

    #[test]
    fn single_entry_single_exit_where_paper_shows_them() {
        assert_eq!(montage().sources().len(), 1);
        assert_eq!(montage().sinks().len(), 1);
        assert_eq!(cybershake().sources().len(), 1);
        assert_eq!(cybershake().sinks().len(), 1);
        assert_eq!(ligo().sources().len(), 1);
        assert_eq!(ligo().sinks().len(), 1);
        assert_eq!(epigenomics().sources().len(), 1);
        assert_eq!(epigenomics().sinks().len(), 1);
    }

    #[test]
    fn cybershake_is_wide_and_shallow() {
        let cs = cybershake();
        let mo = montage();
        assert!(cs.max_width() >= 8, "width={}", cs.max_width());
        assert!(cs.depth() < mo.depth(), "cybershake should be shallower than montage");
    }

    #[test]
    fn epigenomics_is_pipeline_shaped() {
        let epi = epigenomics();
        assert_eq!(epi.max_width(), 4); // four parallel lanes
        assert!(epi.depth() >= 7); // long pipelines
    }

    #[test]
    fn ligo_has_two_concurrent_phases() {
        let l = ligo();
        assert_eq!(l.max_width(), 5);
        // Thinca1 joins all five first-phase inspirals
        let thinca1 = l.tasks.iter().position(|t| t.name == "Thinca1").unwrap();
        assert_eq!(l.tasks[thinca1].deps.len(), 5);
    }

    #[test]
    fn montage_diffs_depend_on_projection_pairs() {
        let m = montage();
        let d0 = m.tasks.iter().find(|t| t.name == "mDiffFit-0").unwrap();
        assert_eq!(d0.deps.len(), 2);
    }
}
