//! JSON workflow definitions (the CLI's "customize workflows on demand").
//!
//! Format:
//! ```json
//! {
//!   "name": "my-pipeline",
//!   "deadline_s": 600,
//!   "tasks": [
//!     {"name": "extract", "cpu_milli": 2000, "mem_mi": 4000, "deps": []},
//!     {"name": "transform", "deps": [0], "duration_s": 12.5},
//!     {"name": "load", "deps": [1], "min_mem_mi": 500}
//!   ]
//! }
//! ```
//! Unspecified resource fields fall back to the paper-default template.

use super::dag::{WorkflowSpec, WorkflowType};
use super::task::TaskSpec;
use crate::util::json::Json;

pub fn from_json_str(s: &str) -> anyhow::Result<WorkflowSpec> {
    from_json(&Json::parse(s)?)
}

pub fn from_json(j: &Json) -> anyhow::Result<WorkflowSpec> {
    let name = j
        .get("name")
        .and_then(|v| v.as_str())
        .unwrap_or("custom")
        .to_string();
    let deadline_s = j.get("deadline_s").and_then(|v| v.as_f64());
    let tasks_json = j
        .get("tasks")
        .and_then(|v| v.as_arr())
        .ok_or_else(|| anyhow::anyhow!("workflow definition needs a 'tasks' array"))?;
    anyhow::ensure!(!tasks_json.is_empty(), "'tasks' must not be empty");

    let mut tasks = Vec::with_capacity(tasks_json.len());
    for (i, tj) in tasks_json.iter().enumerate() {
        let deps = tj
            .get("deps")
            .and_then(|v| v.as_arr())
            .map(|a| {
                a.iter()
                    .map(|d| {
                        d.as_i64()
                            .map(|x| x as usize)
                            .ok_or_else(|| anyhow::anyhow!("task {i}: deps must be integers"))
                    })
                    .collect::<anyhow::Result<Vec<usize>>>()
            })
            .transpose()?
            .unwrap_or_default();
        let mut t = TaskSpec::stage(
            tj.get("name").and_then(|v| v.as_str()).unwrap_or(&format!("task-{i}")).to_string(),
            deps,
        );
        if let Some(v) = tj.get("cpu_milli").and_then(|v| v.as_i64()) {
            t.cpu_milli = v;
        }
        if let Some(v) = tj.get("mem_mi").and_then(|v| v.as_i64()) {
            t.mem_mi = v;
        }
        if let Some(v) = tj.get("min_cpu_milli").and_then(|v| v.as_i64()) {
            t.min_cpu_milli = v;
        }
        if let Some(v) = tj.get("min_mem_mi").and_then(|v| v.as_i64()) {
            t.min_mem_mi = v;
        }
        if let Some(v) = tj.get("duration_s").and_then(|v| v.as_f64()) {
            t.duration_s = v;
        }
        if let Some(v) = tj.get("deadline_s").and_then(|v| v.as_f64()) {
            t.deadline_s = Some(v);
        }
        if let Some(v) = tj.get("image").and_then(|v| v.as_str()) {
            t.image = v.to_string();
        }
        tasks.push(t);
    }

    let spec = WorkflowSpec { kind: WorkflowType::Custom, name, tasks, deadline_s };
    spec.validate()?;
    Ok(spec)
}

pub fn from_file(path: &str) -> anyhow::Result<WorkflowSpec> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading workflow file {path}: {e}"))?;
    from_json_str(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_definition() {
        let wf = from_json_str(
            r#"{"name":"etl","tasks":[
                {"name":"a","deps":[]},
                {"name":"b","deps":[0],"cpu_milli":500,"duration_s":5.0}
            ]}"#,
        )
        .unwrap();
        assert_eq!(wf.name, "etl");
        assert_eq!(wf.tasks.len(), 2);
        assert_eq!(wf.tasks[1].cpu_milli, 500);
        assert_eq!(wf.tasks[1].duration_s, 5.0);
        assert_eq!(wf.tasks[0].cpu_milli, 2000); // default template
    }

    #[test]
    fn rejects_cycles() {
        let r = from_json_str(
            r#"{"tasks":[{"name":"a","deps":[1]},{"name":"b","deps":[0]}]}"#,
        );
        assert!(r.is_err());
    }

    #[test]
    fn rejects_missing_tasks() {
        assert!(from_json_str(r#"{"name":"x"}"#).is_err());
        assert!(from_json_str(r#"{"tasks":[]}"#).is_err());
    }

    #[test]
    fn deadline_passthrough() {
        let wf = from_json_str(
            r#"{"deadline_s": 300, "tasks":[{"name":"a","deps":[],"deadline_s":120}]}"#,
        )
        .unwrap();
        assert_eq!(wf.deadline_s, Some(300.0));
        assert_eq!(wf.tasks[0].deadline_s, Some(120.0));
    }
}
