//! The open forecaster registry — the forecasting twin of
//! [`crate::resources::registry`]: string names (plus aliases) map to
//! factory closures that turn a [`ForecasterSpec`] (name + numeric
//! params, carried by `config::ForecastConfig`) into a boxed
//! [`Forecaster`]. The process-wide registry starts with the four
//! built-ins (`naive-last`, `window-mean`, `holt`, `seasonal`);
//! mounting a new predictor is one call:
//!
//! ```
//! use kubeadaptor::forecast::{registry, NaiveLastForecaster};
//!
//! registry::register_forecaster("my-oracle", &[], "always the last tick", |_spec| {
//!     Ok(Box::new(NaiveLastForecaster::new()))
//! })
//! .unwrap();
//! // From here `--forecaster my-oracle`, config files and campaign
//! // grids all resolve it.
//! ```
//!
//! Unknown names fail at engine construction with the roster; unknown
//! params fail inside the factory (each built-in validates its accepted
//! keys).
//!
//! **Aliases are an input convenience, not an identity** (same rule as
//! the policy registry): report grouping and the campaign
//! forecaster-axis duplicate check compare [`ForecasterSpec`] values,
//! and the built-in aliases (`last`, `ewma`, `holt-winters`) are
//! canonicalized in [`ForecasterSpec::named`]/`parse` — kept in
//! lockstep with the alias lists below. Aliases of user-registered
//! forecasters are resolved here when building but not rewritten there.

use std::sync::{OnceLock, RwLock};

use super::{
    Forecaster, HoltForecaster, NaiveLastForecaster, SeasonalForecaster, WindowMeanForecaster,
};

pub use crate::config::ForecasterSpec;

/// Factory signature: the parsed spec (name + params).
pub type ForecasterFactory =
    Box<dyn Fn(&ForecasterSpec) -> anyhow::Result<Box<dyn Forecaster>> + Send + Sync>;

/// One registered forecaster.
pub struct ForecasterEntry {
    pub name: String,
    pub aliases: Vec<String>,
    /// One-line description for `--list-forecasters`.
    pub summary: String,
    factory: ForecasterFactory,
}

impl ForecasterEntry {
    fn matches(&self, name: &str) -> bool {
        self.name.eq_ignore_ascii_case(name)
            || self.aliases.iter().any(|a| a.eq_ignore_ascii_case(name))
    }
}

/// String-keyed forecaster registry.
#[derive(Default)]
pub struct ForecasterRegistry {
    entries: Vec<ForecasterEntry>,
}

impl ForecasterRegistry {
    /// An empty registry (library embedders composing their own set).
    pub fn empty() -> Self {
        Self::default()
    }

    /// A registry pre-loaded with the four built-in forecasters.
    pub fn with_builtins() -> Self {
        let mut r = Self::empty();
        r.register(
            "naive-last",
            &["last"],
            "repeat the last observation (no params)",
            |spec| {
                check_params(spec, &[])?;
                Ok(Box::new(NaiveLastForecaster::new()))
            },
        )
        .expect("builtin registration");
        r.register(
            "window-mean",
            &[],
            "mean over a sliding sample window [params: window]",
            |spec| {
                check_params(spec, &["window"])?;
                let window = match spec.param("window") {
                    None => WindowMeanForecaster::DEFAULT_WINDOW,
                    Some(w) => {
                        anyhow::ensure!(
                            w.is_finite() && w.fract() == 0.0 && w >= 1.0,
                            "window-mean window must be a positive integer, got {w}"
                        );
                        w as usize
                    }
                };
                Ok(Box::new(WindowMeanForecaster::new(window)?))
            },
        )
        .expect("builtin registration");
        r.register(
            "holt",
            &["ewma"],
            "Holt linear smoothing (beta=0 is plain EWMA) [params: alpha, beta]",
            |spec| {
                check_params(spec, &["alpha", "beta"])?;
                let alpha = spec.param("alpha").unwrap_or(HoltForecaster::DEFAULT_ALPHA);
                let beta = spec.param("beta").unwrap_or(HoltForecaster::DEFAULT_BETA);
                Ok(Box::new(HoltForecaster::new(alpha, beta)?))
            },
        )
        .expect("builtin registration");
        r.register(
            "seasonal",
            &["holt-winters"],
            "Holt-Winters-style additive seasonality over a fixed period \
             [params: period, buckets, alpha, beta, gamma]",
            |spec| {
                check_params(spec, &["period", "buckets", "alpha", "beta", "gamma"])?;
                let period = spec.param("period").unwrap_or(SeasonalForecaster::DEFAULT_PERIOD_S);
                let buckets = match spec.param("buckets") {
                    None => SeasonalForecaster::DEFAULT_BUCKETS,
                    Some(b) => {
                        anyhow::ensure!(
                            b.is_finite() && b.fract() == 0.0 && b >= 1.0,
                            "seasonal buckets must be a positive integer, got {b}"
                        );
                        b as usize
                    }
                };
                let alpha = spec.param("alpha").unwrap_or(SeasonalForecaster::DEFAULT_ALPHA);
                let beta = spec.param("beta").unwrap_or(SeasonalForecaster::DEFAULT_BETA);
                let gamma = spec.param("gamma").unwrap_or(SeasonalForecaster::DEFAULT_GAMMA);
                Ok(Box::new(SeasonalForecaster::new(period, buckets, alpha, beta, gamma)?))
            },
        )
        .expect("builtin registration");
        r
    }

    /// Mount a forecaster: `name` (and each alias) must not collide with
    /// an existing entry.
    pub fn register(
        &mut self,
        name: impl Into<String>,
        aliases: &[&str],
        summary: impl Into<String>,
        factory: impl Fn(&ForecasterSpec) -> anyhow::Result<Box<dyn Forecaster>> + Send + Sync + 'static,
    ) -> anyhow::Result<()> {
        let name = name.into().to_lowercase();
        anyhow::ensure!(!name.is_empty(), "forecaster name must be non-empty");
        for candidate in std::iter::once(name.as_str()).chain(aliases.iter().copied()) {
            anyhow::ensure!(
                self.resolve(candidate).is_none(),
                "forecaster name '{candidate}' is already registered"
            );
        }
        self.entries.push(ForecasterEntry {
            name,
            aliases: aliases.iter().map(|a| a.to_lowercase()).collect(),
            summary: summary.into(),
            factory: Box::new(factory),
        });
        Ok(())
    }

    /// Look an entry up by name or alias (case-insensitive).
    pub fn resolve(&self, name: &str) -> Option<&ForecasterEntry> {
        self.entries.iter().find(|e| e.matches(name))
    }

    /// Canonical name for a spelling (alias → primary name).
    pub fn canonical_name(&self, name: &str) -> Option<&str> {
        self.resolve(name).map(|e| e.name.as_str())
    }

    /// Instantiate the forecaster a spec describes.
    pub fn build(&self, spec: &ForecasterSpec) -> anyhow::Result<Box<dyn Forecaster>> {
        let entry = self.resolve(&spec.name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown forecaster '{}' (registered: {})",
                spec.name,
                self.names().join(", ")
            )
        })?;
        (entry.factory)(spec)
            .map_err(|e| anyhow::anyhow!("building forecaster '{}': {e}", entry.name))
    }

    /// Registered canonical names, in registration order.
    pub fn names(&self) -> Vec<String> {
        self.entries.iter().map(|e| e.name.clone()).collect()
    }

    /// (name, aliases, summary) rows for `--list-forecasters`, sorted by
    /// name so the roster prints deterministically regardless of
    /// registration order.
    pub fn listing(&self) -> Vec<(String, Vec<String>, String)> {
        let mut rows: Vec<(String, Vec<String>, String)> = self
            .entries
            .iter()
            .map(|e| (e.name.clone(), e.aliases.clone(), e.summary.clone()))
            .collect();
        rows.sort_by(|a, b| a.0.cmp(&b.0));
        rows
    }

    pub fn entries(&self) -> &[ForecasterEntry] {
        &self.entries
    }
}

// ------------------------------------------------------- global registry

static GLOBAL: OnceLock<RwLock<ForecasterRegistry>> = OnceLock::new();

/// The process-wide registry (built-ins pre-registered on first use).
pub fn global() -> &'static RwLock<ForecasterRegistry> {
    GLOBAL.get_or_init(|| RwLock::new(ForecasterRegistry::with_builtins()))
}

/// Mount a forecaster into the global registry.
pub fn register_forecaster(
    name: impl Into<String>,
    aliases: &[&str],
    summary: impl Into<String>,
    factory: impl Fn(&ForecasterSpec) -> anyhow::Result<Box<dyn Forecaster>> + Send + Sync + 'static,
) -> anyhow::Result<()> {
    global().write().unwrap().register(name, aliases, summary, factory)
}

/// Instantiate `spec` via the global registry.
pub fn build_forecaster(spec: &ForecasterSpec) -> anyhow::Result<Box<dyn Forecaster>> {
    global().read().unwrap().build(spec)
}

/// Canonical names registered globally, in registration order.
pub fn forecaster_names() -> Vec<String> {
    global().read().unwrap().names()
}

/// Sorted (name, aliases, summary) rows for `--list-forecasters`.
pub fn forecaster_listing() -> Vec<(String, Vec<String>, String)> {
    global().read().unwrap().listing()
}

/// Reject params a forecaster does not understand (typo protection).
fn check_params(spec: &ForecasterSpec, allowed: &[&str]) -> anyhow::Result<()> {
    for (key, _) in &spec.params {
        anyhow::ensure!(
            allowed.contains(&key.as_str()),
            "forecaster '{}' has no parameter '{}'{}",
            spec.name,
            key,
            if allowed.is_empty() {
                " (it takes none)".to_string()
            } else {
                format!(" (accepted: {})", allowed.join(", "))
            }
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtins_resolve_by_name_and_alias() {
        let r = ForecasterRegistry::with_builtins();
        assert_eq!(r.names(), vec!["naive-last", "window-mean", "holt", "seasonal"]);
        assert_eq!(r.canonical_name("EWMA"), Some("holt"));
        assert_eq!(r.canonical_name("holt-winters"), Some("seasonal"));
        assert_eq!(r.canonical_name("last"), Some("naive-last"));
        assert!(r.resolve("nope").is_none());
    }

    #[test]
    fn listing_is_sorted_regardless_of_registration_order() {
        let mut r = ForecasterRegistry::with_builtins();
        // Registered last, sorts first.
        r.register("aaa-oracle", &[], "test", |_s| Ok(Box::new(NaiveLastForecaster::new())))
            .unwrap();
        let names: Vec<&str> = r.listing().iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(names, vec!["aaa-oracle", "holt", "naive-last", "seasonal", "window-mean"]);
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
    }

    #[test]
    fn build_reports_unknown_names_with_the_roster() {
        let r = ForecasterRegistry::with_builtins();
        let err = r.build(&ForecasterSpec::named("nope")).unwrap_err().to_string();
        assert!(err.contains("unknown forecaster 'nope'"), "{err}");
        assert!(err.contains("seasonal"), "{err}");
    }

    #[test]
    fn unknown_params_are_rejected() {
        let r = ForecasterRegistry::with_builtins();
        let err = r
            .build(&ForecasterSpec::named("naive-last").with_param("zeal", 9.0))
            .unwrap_err()
            .to_string();
        assert!(err.contains("no parameter 'zeal'"), "{err}");
        assert!(r.build(&ForecasterSpec::named("holt").with_param("warp", 1.0)).is_err());
    }

    #[test]
    fn params_flow_into_factories() {
        let r = ForecasterRegistry::with_builtins();
        assert!(r.build(&ForecasterSpec::named("window-mean").with_param("window", 4.0)).is_ok());
        assert!(r.build(&ForecasterSpec::named("window-mean").with_param("window", 2.5)).is_err());
        assert!(r.build(&ForecasterSpec::named("holt").with_param("alpha", 0.0)).is_err());
        assert!(r
            .build(
                &ForecasterSpec::named("seasonal")
                    .with_param("period", 120.0)
                    .with_param("buckets", 6.0)
            )
            .is_ok());
        assert!(r.build(&ForecasterSpec::named("seasonal").with_param("period", 0.0)).is_err());
    }

    #[test]
    fn duplicate_registration_is_rejected() {
        let mut r = ForecasterRegistry::with_builtins();
        let err = r
            .register("ewma", &[], "dup", |_s| Ok(Box::new(NaiveLastForecaster::new())))
            .unwrap_err()
            .to_string();
        assert!(err.contains("already registered"), "{err}");
    }
}
