//! Demand forecasting — the subsystem behind the paper's *predictive*
//! pitch: ARAS allocates "considering potential future workflow task
//! requests within the current task pod's lifecycle", but a policy can
//! only look ahead at task records that already exist in the Knowledge
//! base. A [`Forecaster`] extrapolates beyond them: it observes one
//! [`DemandSample`] per engine metrics tick (arrivals, queue pressure,
//! declared CPU/memory demand) and answers [`Forecaster::predict`] with
//! a [`DemandForecast`] at a requested horizon.
//!
//! Consumers:
//! * the engine attaches the current forecast to every
//!   [`crate::resources::ClusterSnapshot`] it captures;
//! * the `predictive` policy ([`crate::resources::PredictivePolicy`])
//!   augments ARAS's lifecycle-window demand with forecast arrivals;
//! * the autoscaler's `predictive` mode scales ahead of forecast queue
//!   pressure instead of trailing the actual queue;
//! * the engine scores every one-tick-ahead prediction against the
//!   demand that materializes (MAPE/RMSE in the run summary).
//!
//! Forecasters are pure, deterministic state machines — same observation
//! stream, same predictions, bit for bit — and are resolved by name
//! through [`registry`], mirroring the policy registry: `--forecaster
//! name:key=value`, `--list-forecasters`, one [`registry::register_forecaster`]
//! call to mount a new predictor.
//!
//! Built-ins:
//!
//! | name          | aliases        | model |
//! |---------------|----------------|-------|
//! | `naive-last`  | `last`         | repeat the last observation |
//! | `window-mean` |                | mean over a sliding window [`window`] |
//! | `holt`        | `ewma`         | Holt linear smoothing [`alpha`, `beta`]; β=0 is plain EWMA |
//! | `seasonal`    | `holt-winters` | Holt-Winters-style additive seasonality [`period`, `buckets`, `alpha`, `beta`, `gamma`] |

pub mod registry;

pub use registry::{
    build_forecaster, forecaster_listing, forecaster_names, register_forecaster,
    ForecasterRegistry,
};

use std::collections::VecDeque;

use crate::simcore::SimTime;

/// Number of forecast series: CPU demand, memory demand, queue length,
/// arrival rate.
const SERIES: usize = 4;

/// One observation per engine metrics tick.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DemandSample {
    /// Virtual time of the tick.
    pub t: SimTime,
    /// Workflow requests injected since the previous observation.
    pub arrivals: f64,
    /// Allocation-queue length at the tick.
    pub queue_len: f64,
    /// Declared CPU demand (milli-cores): requests held by live pods
    /// plus the declared demand of queued tasks.
    pub cpu_demand: f64,
    /// Declared memory demand (Mi), same accounting.
    pub mem_demand: f64,
}

/// A forecaster's answer: expected state `horizon_s` seconds ahead.
/// Every field is finite and non-negative by construction.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DemandForecast {
    /// Horizon the prediction was made for (virtual seconds ahead).
    pub horizon_s: f64,
    /// Predicted cluster-wide declared CPU demand (milli-cores).
    pub cpu_demand: f64,
    /// Predicted cluster-wide declared memory demand (Mi).
    pub mem_demand: f64,
    /// Predicted allocation-queue length.
    pub queue_len: f64,
    /// Predicted workflow arrival rate (requests per virtual second).
    pub arrival_rate: f64,
}

/// A pluggable demand predictor. Implementations must be deterministic:
/// identical observation streams must yield bit-identical forecasts
/// (property-checked in `rust/tests/forecast.rs`).
pub trait Forecaster {
    /// Registry name of this forecaster.
    fn name(&self) -> &str;

    /// Ingest one tick's observation. Samples arrive in time order.
    fn observe(&mut self, sample: &DemandSample);

    /// Predict `horizon_s` seconds past the last observation. `None`
    /// until at least one sample has been observed.
    fn predict(&self, horizon_s: f64) -> Option<DemandForecast>;
}

/// Per-series values of one sample, in [`SERIES`] order. The arrival
/// *rate* needs the spacing to the previous sample; with no previous
/// sample (or a non-positive spacing) it is taken as 0.
fn series_values(sample: &DemandSample, dt: Option<f64>) -> [f64; SERIES] {
    let rate = match dt {
        Some(d) if d > 0.0 => sample.arrivals / d,
        _ => 0.0,
    };
    [sample.cpu_demand, sample.mem_demand, sample.queue_len, rate]
}

/// Forecast values are demands/rates: clamp extrapolations into the
/// physically meaningful range (finite, non-negative).
fn clamp(v: f64) -> f64 {
    if v.is_finite() {
        v.max(0.0)
    } else {
        0.0
    }
}

fn forecast_from(horizon_s: f64, v: [f64; SERIES]) -> DemandForecast {
    DemandForecast {
        horizon_s,
        cpu_demand: clamp(v[0]),
        mem_demand: clamp(v[1]),
        queue_len: clamp(v[2]),
        arrival_rate: clamp(v[3]),
    }
}

// ----------------------------------------------------------- naive-last

/// `naive-last`: tomorrow looks exactly like the last tick.
#[derive(Debug, Default, Clone)]
pub struct NaiveLastForecaster {
    last: Option<(SimTime, [f64; SERIES])>,
}

impl NaiveLastForecaster {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Forecaster for NaiveLastForecaster {
    fn name(&self) -> &str {
        "naive-last"
    }

    fn observe(&mut self, sample: &DemandSample) {
        let dt = self.last.map(|(t0, _)| sample.t - t0);
        self.last = Some((sample.t, series_values(sample, dt)));
    }

    fn predict(&self, horizon_s: f64) -> Option<DemandForecast> {
        self.last.map(|(_, v)| forecast_from(horizon_s, v))
    }
}

// ---------------------------------------------------------- window-mean

/// `window-mean`: the mean of the last `window` observations. Horizon-
/// independent, order-invariant over the values inside one window.
#[derive(Debug, Clone)]
pub struct WindowMeanForecaster {
    window: usize,
    last_t: Option<SimTime>,
    samples: VecDeque<[f64; SERIES]>,
}

impl WindowMeanForecaster {
    pub const DEFAULT_WINDOW: usize = 12;

    pub fn new(window: usize) -> anyhow::Result<Self> {
        anyhow::ensure!(window >= 1, "window-mean window must be >= 1, got {window}");
        Ok(Self { window, last_t: None, samples: VecDeque::new() })
    }
}

impl Forecaster for WindowMeanForecaster {
    fn name(&self) -> &str {
        "window-mean"
    }

    fn observe(&mut self, sample: &DemandSample) {
        let dt = self.last_t.map(|t0| sample.t - t0);
        self.last_t = Some(sample.t);
        self.samples.push_back(series_values(sample, dt));
        while self.samples.len() > self.window {
            self.samples.pop_front();
        }
    }

    fn predict(&self, horizon_s: f64) -> Option<DemandForecast> {
        if self.samples.is_empty() {
            return None;
        }
        let n = self.samples.len() as f64;
        let mut sums = [0.0f64; SERIES];
        for v in &self.samples {
            for (sum, x) in sums.iter_mut().zip(v) {
                *sum += x;
            }
        }
        for sum in &mut sums {
            *sum /= n;
        }
        Some(forecast_from(horizon_s, sums))
    }
}

// ----------------------------------------------------------------- holt

/// One Holt linear-trend smoother over an unevenly-sampled series; the
/// trend is per virtual second. β = 0 degenerates to plain EWMA.
#[derive(Debug, Clone, Copy)]
struct HoltSeries {
    alpha: f64,
    beta: f64,
    level: f64,
    trend: f64,
    primed: bool,
}

impl HoltSeries {
    fn new(alpha: f64, beta: f64) -> Self {
        Self { alpha, beta, level: 0.0, trend: 0.0, primed: false }
    }

    fn observe(&mut self, dt: Option<f64>, x: f64) {
        match dt {
            Some(dt) if self.primed && dt > 0.0 => {
                let prev = self.level;
                self.level = self.alpha * x + (1.0 - self.alpha) * (self.level + self.trend * dt);
                self.trend = self.beta * ((self.level - prev) / dt) + (1.0 - self.beta) * self.trend;
            }
            Some(_) if self.primed => {
                // Coincident sample: refresh the level, keep the trend.
                self.level = self.alpha * x + (1.0 - self.alpha) * self.level;
            }
            _ => {
                self.level = x;
                self.primed = true;
            }
        }
    }

    fn predict(&self, horizon_s: f64) -> f64 {
        self.level + self.trend * horizon_s
    }
}

/// `holt` (alias `ewma`): double exponential smoothing — an EWMA level
/// plus a per-second linear trend, extrapolated over the horizon.
#[derive(Debug, Clone)]
pub struct HoltForecaster {
    last_t: Option<SimTime>,
    series: [HoltSeries; SERIES],
}

impl HoltForecaster {
    pub const DEFAULT_ALPHA: f64 = 0.3;
    pub const DEFAULT_BETA: f64 = 0.1;

    pub fn new(alpha: f64, beta: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(
            alpha.is_finite() && alpha > 0.0 && alpha <= 1.0,
            "holt alpha must be in (0,1], got {alpha}"
        );
        anyhow::ensure!(
            beta.is_finite() && (0.0..=1.0).contains(&beta),
            "holt beta must be in [0,1], got {beta}"
        );
        Ok(Self { last_t: None, series: [HoltSeries::new(alpha, beta); SERIES] })
    }
}

impl Forecaster for HoltForecaster {
    fn name(&self) -> &str {
        "holt"
    }

    fn observe(&mut self, sample: &DemandSample) {
        let dt = self.last_t.map(|t0| sample.t - t0);
        self.last_t = Some(sample.t);
        let values = series_values(sample, dt);
        for (s, x) in self.series.iter_mut().zip(values) {
            s.observe(dt, x);
        }
    }

    fn predict(&self, horizon_s: f64) -> Option<DemandForecast> {
        self.last_t?;
        Some(forecast_from(
            horizon_s,
            [
                self.series[0].predict(horizon_s),
                self.series[1].predict(horizon_s),
                self.series[2].predict(horizon_s),
                self.series[3].predict(horizon_s),
            ],
        ))
    }
}

// ------------------------------------------------------------- seasonal

/// One Holt-Winters-style additive smoother: a Holt level/trend over the
/// deseasoned signal plus a per-bucket seasonal offset learned over a
/// fixed period.
#[derive(Debug, Clone)]
struct SeasonalSeries {
    alpha: f64,
    beta: f64,
    gamma: f64,
    level: f64,
    trend: f64,
    primed: bool,
    seasonal: Vec<f64>,
}

impl SeasonalSeries {
    fn new(alpha: f64, beta: f64, gamma: f64, buckets: usize) -> Self {
        Self { alpha, beta, gamma, level: 0.0, trend: 0.0, primed: false, seasonal: vec![0.0; buckets] }
    }

    fn observe(&mut self, dt: Option<f64>, bucket: usize, x: f64) {
        let s = self.seasonal[bucket];
        match dt {
            Some(dt) if self.primed && dt > 0.0 => {
                let prev = self.level;
                self.level =
                    self.alpha * (x - s) + (1.0 - self.alpha) * (self.level + self.trend * dt);
                self.trend = self.beta * ((self.level - prev) / dt) + (1.0 - self.beta) * self.trend;
            }
            Some(_) if self.primed => {
                self.level = self.alpha * (x - s) + (1.0 - self.alpha) * self.level;
            }
            _ => {
                self.level = x - s;
                self.primed = true;
            }
        }
        self.seasonal[bucket] = self.gamma * (x - self.level) + (1.0 - self.gamma) * s;
    }

    fn predict(&self, horizon_s: f64, bucket: usize) -> f64 {
        self.level + self.trend * horizon_s + self.seasonal[bucket]
    }
}

/// `seasonal` (alias `holt-winters`): Holt linear smoothing plus an
/// additive seasonal profile over a fixed period split into equal-width
/// buckets — the predictor that learns recurring burst patterns (the
/// paper's 300 s injection cadence) and sees the next burst *before* it
/// arrives.
#[derive(Debug, Clone)]
pub struct SeasonalForecaster {
    period_s: f64,
    last_t: Option<SimTime>,
    series: [SeasonalSeries; SERIES],
}

impl SeasonalForecaster {
    /// Default period = the paper's burst interval (§6.1.4).
    pub const DEFAULT_PERIOD_S: f64 = 300.0;
    pub const DEFAULT_BUCKETS: usize = 10;
    pub const DEFAULT_ALPHA: f64 = 0.3;
    pub const DEFAULT_BETA: f64 = 0.05;
    pub const DEFAULT_GAMMA: f64 = 0.5;

    pub fn new(
        period_s: f64,
        buckets: usize,
        alpha: f64,
        beta: f64,
        gamma: f64,
    ) -> anyhow::Result<Self> {
        anyhow::ensure!(
            period_s.is_finite() && period_s > 0.0,
            "seasonal period must be finite and > 0, got {period_s}"
        );
        anyhow::ensure!(buckets >= 1, "seasonal buckets must be >= 1, got {buckets}");
        anyhow::ensure!(
            alpha.is_finite() && alpha > 0.0 && alpha <= 1.0,
            "seasonal alpha must be in (0,1], got {alpha}"
        );
        anyhow::ensure!(
            beta.is_finite() && (0.0..=1.0).contains(&beta),
            "seasonal beta must be in [0,1], got {beta}"
        );
        anyhow::ensure!(
            gamma.is_finite() && (0.0..=1.0).contains(&gamma),
            "seasonal gamma must be in [0,1], got {gamma}"
        );
        let s = SeasonalSeries::new(alpha, beta, gamma, buckets);
        Ok(Self {
            period_s,
            last_t: None,
            series: [s.clone(), s.clone(), s.clone(), s],
        })
    }

    fn bucket(&self, t: SimTime) -> usize {
        let buckets = self.series[0].seasonal.len();
        let phase = t.rem_euclid(self.period_s) / self.period_s; // [0, 1)
        ((phase * buckets as f64) as usize).min(buckets - 1)
    }
}

impl Forecaster for SeasonalForecaster {
    fn name(&self) -> &str {
        "seasonal"
    }

    fn observe(&mut self, sample: &DemandSample) {
        let dt = self.last_t.map(|t0| sample.t - t0);
        self.last_t = Some(sample.t);
        let bucket = self.bucket(sample.t);
        let values = series_values(sample, dt);
        for (s, x) in self.series.iter_mut().zip(values) {
            s.observe(dt, bucket, x);
        }
    }

    fn predict(&self, horizon_s: f64) -> Option<DemandForecast> {
        let t0 = self.last_t?;
        let bucket = self.bucket(t0 + horizon_s);
        Some(forecast_from(
            horizon_s,
            [
                self.series[0].predict(horizon_s, bucket),
                self.series[1].predict(horizon_s, bucket),
                self.series[2].predict(horizon_s, bucket),
                self.series[3].predict(horizon_s, bucket),
            ],
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t: f64, cpu: f64) -> DemandSample {
        DemandSample { t, arrivals: 0.0, queue_len: 0.0, cpu_demand: cpu, mem_demand: 2.0 * cpu }
    }

    #[test]
    fn unprimed_forecasters_return_none() {
        let naive = NaiveLastForecaster::new();
        assert!(naive.predict(30.0).is_none());
        let wm = WindowMeanForecaster::new(4).unwrap();
        assert!(wm.predict(30.0).is_none());
        let holt = HoltForecaster::new(0.3, 0.1).unwrap();
        assert!(holt.predict(30.0).is_none());
        let seasonal = SeasonalForecaster::new(300.0, 10, 0.3, 0.05, 0.5).unwrap();
        assert!(seasonal.predict(30.0).is_none());
    }

    #[test]
    fn naive_last_repeats_the_last_sample() {
        let mut f = NaiveLastForecaster::new();
        f.observe(&sample(0.0, 100.0));
        f.observe(&sample(5.0, 250.0));
        let fc = f.predict(60.0).unwrap();
        assert_eq!(fc.cpu_demand, 250.0);
        assert_eq!(fc.mem_demand, 500.0);
        assert_eq!(fc.horizon_s, 60.0);
    }

    #[test]
    fn window_mean_averages_and_evicts() {
        let mut f = WindowMeanForecaster::new(2).unwrap();
        f.observe(&sample(0.0, 100.0));
        f.observe(&sample(5.0, 200.0));
        assert_eq!(f.predict(1.0).unwrap().cpu_demand, 150.0);
        // Third sample evicts the first: mean of {200, 500}.
        f.observe(&sample(10.0, 500.0));
        assert_eq!(f.predict(1.0).unwrap().cpu_demand, 350.0);
    }

    #[test]
    fn arrival_rate_is_per_second_over_the_sample_gap() {
        let mut f = NaiveLastForecaster::new();
        let mut s = sample(0.0, 0.0);
        s.arrivals = 5.0;
        f.observe(&s);
        // First sample has no gap: rate pinned to 0.
        assert_eq!(f.predict(1.0).unwrap().arrival_rate, 0.0);
        let mut s = sample(10.0, 0.0);
        s.arrivals = 5.0;
        f.observe(&s);
        assert_eq!(f.predict(1.0).unwrap().arrival_rate, 0.5);
    }

    #[test]
    fn holt_with_zero_beta_is_plain_ewma() {
        let mut f = HoltForecaster::new(0.5, 0.0).unwrap();
        f.observe(&sample(0.0, 10.0));
        f.observe(&sample(1.0, 20.0));
        // level = 0.5*20 + 0.5*10 = 15; trend stays 0 at any horizon.
        assert_eq!(f.predict(0.0).unwrap().cpu_demand, 15.0);
        assert_eq!(f.predict(100.0).unwrap().cpu_demand, 15.0);
    }

    #[test]
    fn holt_trend_extrapolates_a_ramp() {
        // A perfect ramp: alpha=1 tracks the signal exactly, beta=1
        // makes the trend the exact slope.
        let mut f = HoltForecaster::new(1.0, 1.0).unwrap();
        for i in 0..5 {
            f.observe(&sample(i as f64 * 10.0, 100.0 * i as f64));
        }
        // level = 400 at t=40, trend = 10/s → predict(20) = 600.
        let fc = f.predict(20.0).unwrap();
        assert!((fc.cpu_demand - 600.0).abs() < 1e-9, "{}", fc.cpu_demand);
    }

    #[test]
    fn forecasts_are_clamped_non_negative() {
        // A steep downward ramp extrapolates below zero — the forecast
        // must clamp at 0.
        let mut f = HoltForecaster::new(1.0, 1.0).unwrap();
        f.observe(&sample(0.0, 100.0));
        f.observe(&sample(10.0, 0.0));
        let fc = f.predict(100.0).unwrap();
        assert_eq!(fc.cpu_demand, 0.0);
    }

    #[test]
    fn seasonal_buckets_wrap_the_period() {
        let f = SeasonalForecaster::new(300.0, 10, 0.3, 0.05, 0.5).unwrap();
        assert_eq!(f.bucket(0.0), 0);
        assert_eq!(f.bucket(29.9), 0);
        assert_eq!(f.bucket(30.0), 1);
        assert_eq!(f.bucket(299.9), 9);
        assert_eq!(f.bucket(300.0), 0);
        assert_eq!(f.bucket(645.0), 1);
    }

    #[test]
    fn seasonal_learns_a_recurring_spike() {
        // Period 100 s, 4 buckets; a spike in bucket 0, calm elsewhere,
        // repeated over several periods. Predicting into bucket 0 must
        // exceed predicting into bucket 2.
        let mut f = SeasonalForecaster::new(100.0, 4, 0.3, 0.0, 0.5).unwrap();
        for period in 0..6 {
            for b in 0..4 {
                let t = period as f64 * 100.0 + b as f64 * 25.0;
                let v = if b == 0 { 1000.0 } else { 10.0 };
                f.observe(&sample(t, v));
            }
        }
        // Last observation at t=575 (bucket 3). Horizon 25 lands in
        // bucket 0 (spike), horizon 75 in bucket 2 (calm).
        let spike = f.predict(25.0).unwrap().cpu_demand;
        let calm = f.predict(75.0).unwrap().cpu_demand;
        assert!(
            spike > calm + 100.0,
            "seasonal must anticipate the spike: spike={spike} calm={calm}"
        );
    }

    #[test]
    fn constructor_params_are_validated() {
        assert!(WindowMeanForecaster::new(0).is_err());
        assert!(HoltForecaster::new(0.0, 0.1).is_err());
        assert!(HoltForecaster::new(1.5, 0.1).is_err());
        assert!(HoltForecaster::new(0.5, -0.1).is_err());
        assert!(SeasonalForecaster::new(0.0, 10, 0.3, 0.05, 0.5).is_err());
        assert!(SeasonalForecaster::new(300.0, 0, 0.3, 0.05, 0.5).is_err());
        assert!(SeasonalForecaster::new(300.0, 10, 0.3, 0.05, 1.5).is_err());
    }
}
