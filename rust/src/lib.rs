//! # KubeAdaptor + ARAS — paper reproduction library
//!
//! Reproduction of *"Adaptive Resource Allocation for Workflow
//! Containerization on Kubernetes"* (Shan et al., 2023) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the KubeAdaptor workflow engine with the
//!   ARAS resource manager (Algorithms 1–3, Eq. 9), the FCFS baseline, a
//!   MAPE-K control loop, and every substrate the paper runs on: a
//!   discrete-event Kubernetes cluster simulator ([`cluster`]), a
//!   Redis-like state store ([`statestore`]), workload injectors
//!   ([`workload`]), metrics and the experiment harness.
//! * **Layer 2/1 (build-time Python)** — the fused ARAS decision graph
//!   (JAX + Pallas kernels), AOT-lowered to `artifacts/*.hlo.txt` and
//!   executed from the allocation hot path through [`runtime`] (PJRT).
//!
//! ## Quickstart
//!
//! ```no_run
//! use kubeadaptor::prelude::*;
//!
//! let mut cfg = ExperimentConfig::default();
//! cfg.workload.workflow = WorkflowType::Montage;
//! cfg.workload.pattern = ArrivalPattern::Constant { per_burst: 5, bursts: 6 };
//! cfg.alloc.policy = PolicySpec::adaptive(); // any registered policy name works
//! let outcome = kubeadaptor::engine::run_experiment(&cfg).unwrap();
//! println!("total duration: {:.2} min", outcome.summary.total_duration_min);
//! ```

pub mod simcore;
pub mod util;
pub mod config;
pub mod statestore;
pub mod cluster;
pub mod chaos;
pub mod workflow;
pub mod workload;
pub mod forecast;
pub mod resources;
pub mod runtime;
pub mod obs;
pub mod engine;
pub mod federation;
pub mod daemon;
pub mod metrics;
pub mod report;
pub mod campaign;
pub mod experiments;
pub mod testutil;

/// Convenient re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::campaign::{CampaignResult, CampaignSpec};
    pub use crate::chaos::{ChaosConfig, ChaosKind, ChaosProfile, ChaosScenario};
    pub use crate::cluster::{
        AutoscalerConfig, AutoscalerMode, ChurnProfile, ClusterEvent, ClusterEventKind,
    };
    pub use crate::config::{
        AllocConfig, ArrivalPattern, Backend, ClusterConfig, ClusterSpec, DaemonConfig,
        ExperimentConfig, FederationConfig, ForecastConfig, ForecasterSpec, NodePool, PolicySpec,
        RouterSpec, SnapshotMode, TaskConfig, TimingConfig, WorkloadConfig,
    };
    pub use crate::daemon::{client::Client, serve, Listen};
    pub use crate::engine::{run_experiment, Engine, RunOutcome};
    pub use crate::federation::{
        FederatedSummary, FederationResult, FederationSpec, RouteInput, Router,
    };
    pub use crate::forecast::{DemandForecast, DemandSample, Forecaster, ForecasterRegistry};
    pub use crate::metrics::RunSummary;
    pub use crate::resources::{
        registry, AdaptivePolicy, ClusterSnapshot, FcfsPolicy, Policy, PolicyRegistry,
        PredictivePolicy, RateCappedPolicy, StaticHeadroomPolicy,
    };
    pub use crate::workflow::{WorkflowSpec, WorkflowType};
}
