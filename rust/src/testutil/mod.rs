//! Mini property-testing harness (proptest replacement — offline build).
//!
//! Seeded generators + a `forall` runner that reports the failing seed and
//! performs bounded shrinking on integer-vector inputs. Used by
//! `rust/tests/properties.rs` for coordinator invariants.

use crate::simcore::Rng;

/// A generator of random values of `T` from an [`Rng`].
pub trait Gen<T> {
    fn generate(&self, rng: &mut Rng) -> T;
}

impl<T, F: Fn(&mut Rng) -> T> Gen<T> for F {
    fn generate(&self, rng: &mut Rng) -> T {
        self(rng)
    }
}

/// Outcome of a property check.
#[derive(Debug)]
pub enum PropResult {
    Ok { cases: usize },
    Failed { seed: u64, case: usize, message: String },
}

impl PropResult {
    /// Panic with diagnostics if the property failed.
    pub fn unwrap(self) {
        if let PropResult::Failed { seed, case, message } = self {
            panic!("property failed (seed={seed}, case={case}): {message}");
        }
    }
}

/// Run `prop` against `cases` random inputs. `prop` returns `Err(msg)` on
/// violation. Deterministic for a given `seed`.
pub fn forall<T>(
    seed: u64,
    cases: usize,
    gen: impl Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) -> PropResult {
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64 * 0x9E37_79B9);
        let mut rng = Rng::new(case_seed);
        let input = gen.generate(&mut rng);
        if let Err(message) = prop(&input) {
            return PropResult::Failed { seed: case_seed, case, message };
        }
    }
    PropResult::Ok { cases }
}

/// Shrinking variant for `Vec<i64>` inputs: on failure, tries removing
/// chunks and halving elements to find a smaller witness.
pub fn forall_vec(
    seed: u64,
    cases: usize,
    gen: impl Gen<Vec<i64>>,
    prop: impl Fn(&[i64]) -> Result<(), String>,
) -> PropResult {
    for case in 0..cases {
        let case_seed = seed.wrapping_add(case as u64 * 0x9E37_79B9);
        let mut rng = Rng::new(case_seed);
        let input = gen.generate(&mut rng);
        if let Err(first_msg) = prop(&input) {
            let (witness, message) = shrink(input, first_msg, &prop);
            return PropResult::Failed {
                seed: case_seed,
                case,
                message: format!("{message}; minimal witness: {witness:?}"),
            };
        }
    }
    PropResult::Ok { cases }
}

fn shrink(
    mut input: Vec<i64>,
    mut msg: String,
    prop: &impl Fn(&[i64]) -> Result<(), String>,
) -> (Vec<i64>, String) {
    // Remove halves/quarters while the property still fails.
    let mut improved = true;
    while improved && input.len() > 1 {
        improved = false;
        let chunk = (input.len() / 2).max(1);
        for start in (0..input.len()).step_by(chunk) {
            let mut candidate = input.clone();
            let end = (start + chunk).min(candidate.len());
            candidate.drain(start..end);
            if candidate.is_empty() {
                continue;
            }
            if let Err(m) = prop(&candidate) {
                input = candidate;
                msg = m;
                improved = true;
                break;
            }
        }
    }
    // Halve individual elements toward zero.
    let mut changed = true;
    while changed {
        changed = false;
        for i in 0..input.len() {
            if input[i] == 0 {
                continue;
            }
            let mut candidate = input.clone();
            candidate[i] /= 2;
            if let Err(m) = prop(&candidate) {
                input = candidate;
                msg = m;
                changed = true;
            }
        }
    }
    (input, msg)
}

/// Common generators.
pub mod gens {
    use crate::simcore::Rng;

    pub fn vec_i64(len_lo: usize, len_hi: usize, lo: i64, hi: i64) -> impl Fn(&mut Rng) -> Vec<i64> {
        move |rng| {
            let n = rng.range_inclusive(len_lo as i64, len_hi as i64) as usize;
            (0..n).map(|_| rng.range_inclusive(lo, hi)).collect()
        }
    }

    pub fn f64_in(lo: f64, hi: f64) -> impl Fn(&mut Rng) -> f64 {
        move |rng| rng.uniform(lo, hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_reports_cases() {
        let r = forall(1, 50, gens::f64_in(0.0, 1.0), |x| {
            if (0.0..1.0).contains(x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert!(matches!(r, PropResult::Ok { cases: 50 }));
    }

    #[test]
    fn failing_property_is_caught_and_shrunk() {
        let r = forall_vec(1, 100, gens::vec_i64(1, 20, 0, 100), |xs| {
            if xs.iter().sum::<i64>() < 150 {
                Ok(())
            } else {
                Err("sum too large".into())
            }
        });
        match r {
            PropResult::Failed { message, .. } => {
                assert!(message.contains("minimal witness"));
            }
            _ => panic!("expected failure"),
        }
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn unwrap_panics_on_failure() {
        forall(1, 10, gens::f64_in(0.0, 1.0), |_| Err("always".into())).unwrap();
    }
}
