//! Redis-equivalent workflow state store (DESIGN.md §Substitutions).
//!
//! Holds the paper's Eq. (8) task-state records
//! `task_redis = {t_start, duration, t_end, cpu, mem, flag}` keyed by the
//! unique task id, plus workflow-level status — exactly the data the
//! Interface Unit writes and Algorithm 1 reads (lines 4–13).
//!
//! For tasks not yet launched, `t_start`/`t_end` hold the *estimated*
//! schedule derived from the DAG's predefined durations and deadlines
//! (the paper's "potential future workflow task requests within the
//! current task pod's lifecycle"); the Containerized Executor overwrites
//! them with actual times as pods start and finish.

use std::collections::BTreeMap;

use crate::simcore::SimTime;

/// Eq. (8): one task-state record.
#[derive(Debug, Clone, PartialEq)]
pub struct TaskRecord {
    /// Workflow instance this record belongs to.
    pub workflow_uid: u64,
    /// Start time (actual once running, estimated before).
    pub t_start: SimTime,
    /// Predefined running duration of the task pod.
    pub duration: f64,
    /// End time (actual once complete, estimated before).
    pub t_end: SimTime,
    /// Requested CPU, milli-cores (Eq. 1 `cpu`).
    pub cpu: f64,
    /// Requested memory, Mi (Eq. 1 `mem`).
    pub mem: f64,
    /// Completion flag (false = not complete).
    pub flag: bool,
    /// Whether t_start/t_end are estimates (task not yet launched).
    pub estimated: bool,
}

/// Workflow lifecycle status tracked alongside task records.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkflowStatus {
    Queued,
    Running,
    Completed,
}

#[derive(Debug, Clone)]
pub struct WorkflowRecord {
    pub uid: u64,
    pub name: String,
    pub injected_at: SimTime,
    pub started_at: Option<SimTime>,
    pub completed_at: Option<SimTime>,
    pub status: WorkflowStatus,
    pub total_tasks: usize,
    pub done_tasks: usize,
    /// Absolute SLA deadline (Eq. 3), if the workload assigns one.
    pub deadline_at: Option<SimTime>,
}

impl WorkflowRecord {
    /// SLA violated: completed after the deadline (or still incomplete
    /// past it, when queried with `now`).
    pub fn sla_violated(&self, now: SimTime) -> bool {
        match self.deadline_at {
            None => false,
            Some(d) => self.completed_at.unwrap_or(now) > d,
        }
    }
}

/// The store: `Map<task_id, TaskRecord>` plus workflow records.
///
/// Single-threaded by design — the DES engine is the only writer, mirroring
/// how KubeAdaptor funnels all Redis writes through the Interface Unit.
#[derive(Debug, Default)]
pub struct StateStore {
    tasks: BTreeMap<String, TaskRecord>,
    workflows: BTreeMap<u64, WorkflowRecord>,
    writes: u64,
}

impl StateStore {
    pub fn new() -> Self {
        Self::default()
    }

    // ---------------------------------------------------------- tasks

    /// Insert or overwrite a task record (Interface Unit path).
    pub fn put_task(&mut self, task_id: impl Into<String>, rec: TaskRecord) {
        self.writes += 1;
        self.tasks.insert(task_id.into(), rec);
    }

    pub fn get_task(&self, task_id: &str) -> Option<&TaskRecord> {
        self.tasks.get(task_id)
    }

    /// Update an existing record in place (Containerized Executor path).
    pub fn update_task(&mut self, task_id: &str, f: impl FnOnce(&mut TaskRecord)) -> bool {
        if let Some(rec) = self.tasks.get_mut(task_id) {
            self.writes += 1;
            f(rec);
            true
        } else {
            false
        }
    }

    /// All records for Algorithm 1's window scan (line 7: "Get all
    /// task_redis for all workflows from Redis").
    pub fn all_tasks(&self) -> impl Iterator<Item = (&String, &TaskRecord)> {
        self.tasks.iter()
    }

    /// Incomplete records only — the candidates that can compete for
    /// resources within a lifecycle window.
    pub fn pending_tasks(&self) -> impl Iterator<Item = (&String, &TaskRecord)> {
        self.tasks.iter().filter(|(_, r)| !r.flag)
    }

    pub fn remove_workflow_tasks(&mut self, workflow_uid: u64) {
        self.tasks.retain(|_, r| r.workflow_uid != workflow_uid);
    }

    pub fn task_count(&self) -> usize {
        self.tasks.len()
    }

    /// Total write operations (monitoring-overhead metric; the paper
    /// argues against hammering kube-apiserver — we track store traffic).
    pub fn write_count(&self) -> u64 {
        self.writes
    }

    // ------------------------------------------------------ workflows

    pub fn put_workflow(&mut self, rec: WorkflowRecord) {
        self.writes += 1;
        self.workflows.insert(rec.uid, rec);
    }

    pub fn get_workflow(&self, uid: u64) -> Option<&WorkflowRecord> {
        self.workflows.get(&uid)
    }

    pub fn update_workflow(&mut self, uid: u64, f: impl FnOnce(&mut WorkflowRecord)) -> bool {
        if let Some(rec) = self.workflows.get_mut(&uid) {
            self.writes += 1;
            f(rec);
            true
        } else {
            false
        }
    }

    pub fn workflows(&self) -> impl Iterator<Item = &WorkflowRecord> {
        self.workflows.values()
    }

    pub fn all_workflows_complete(&self) -> bool {
        !self.workflows.is_empty()
            && self.workflows.values().all(|w| w.status == WorkflowStatus::Completed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(wf: u64, t0: f64, done: bool) -> TaskRecord {
        TaskRecord {
            workflow_uid: wf,
            t_start: t0,
            duration: 15.0,
            t_end: t0 + 15.0,
            cpu: 2000.0,
            mem: 4000.0,
            flag: done,
            estimated: !done,
        }
    }

    #[test]
    fn put_get_update() {
        let mut s = StateStore::new();
        s.put_task("w1-t1", rec(1, 0.0, false));
        assert!(s.get_task("w1-t1").is_some());
        assert!(s.update_task("w1-t1", |r| r.flag = true));
        assert!(s.get_task("w1-t1").unwrap().flag);
        assert!(!s.update_task("nope", |_| {}));
    }

    #[test]
    fn pending_filters_completed() {
        let mut s = StateStore::new();
        s.put_task("a", rec(1, 0.0, true));
        s.put_task("b", rec(1, 5.0, false));
        let pending: Vec<_> = s.pending_tasks().map(|(k, _)| k.clone()).collect();
        assert_eq!(pending, vec!["b"]);
    }

    #[test]
    fn remove_workflow_tasks_scopes_by_uid() {
        let mut s = StateStore::new();
        s.put_task("a", rec(1, 0.0, false));
        s.put_task("b", rec(2, 0.0, false));
        s.remove_workflow_tasks(1);
        assert_eq!(s.task_count(), 1);
        assert!(s.get_task("b").is_some());
    }

    #[test]
    fn workflow_completion_aggregate() {
        let mut s = StateStore::new();
        assert!(!s.all_workflows_complete()); // empty != complete
        s.put_workflow(WorkflowRecord {
            uid: 1,
            name: "montage".into(),
            injected_at: 0.0,
            started_at: None,
            completed_at: None,
            status: WorkflowStatus::Running,
            total_tasks: 21,
            done_tasks: 0,
            deadline_at: None,
        });
        assert!(!s.all_workflows_complete());
        s.update_workflow(1, |w| w.status = WorkflowStatus::Completed);
        assert!(s.all_workflows_complete());
    }

    #[test]
    fn write_count_tracks_traffic() {
        let mut s = StateStore::new();
        s.put_task("a", rec(1, 0.0, false));
        s.update_task("a", |r| r.flag = true);
        assert_eq!(s.write_count(), 2);
    }
}
