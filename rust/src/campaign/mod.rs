//! Campaign runner — declarative experiment sweeps executed in parallel.
//!
//! A [`CampaignSpec`] describes a grid of experiment configurations
//! (workflow topologies × arrival patterns × policies × cluster sizes ×
//! α values × lookahead settings × repetitions). [`CampaignSpec::expand`]
//! turns the grid into concrete [`ExperimentConfig`]s, and [`run`]
//! executes them across a configurable OS-thread worker pool.
//!
//! **Determinism contract.** Every planned run gets its workload seed
//! from [`crate::simcore::derive_seed`] over its *grid coordinates*
//! (workflow, pattern, repetition — deliberately NOT the policy, α,
//! lookahead or cluster-size axes, so an ARAS run and its baseline twin
//! see bit-identical workloads). Because each run is a self-contained
//! discrete-event simulation and results are re-ordered by grid index
//! after the pool drains, a campaign's output is byte-identical at 1
//! worker thread and at N — asserted in `rust/tests/campaign.rs`.
//!
//! The `experiments/` modules (`fig1`, `table2`, `ablation`, `oom`,
//! `usage_curves`) are all thin [`CampaignSpec`] definitions over this
//! runner; rendering lives in [`crate::report::campaign`].

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::chaos::ChaosProfile;
use crate::cluster::ChurnProfile;
use crate::config::{ArrivalPattern, ExperimentConfig, ForecasterSpec, PolicySpec, RouterSpec};
use crate::engine::{run_experiment, RunOutcome};
use crate::federation;
use crate::report::Cell;
use crate::simcore::derive_seed;
use crate::workflow::WorkflowType;

/// A declarative sweep grid. Every axis must be non-empty; the cross
/// product of all axes × `reps` is the set of runs.
#[derive(Debug, Clone)]
pub struct CampaignSpec {
    /// Campaign name (used in report titles and output file names).
    pub name: String,
    /// Template config; grid axes override the corresponding fields,
    /// everything else (timing, task shape, β, strict_min…) is shared.
    pub base: ExperimentConfig,
    pub workflows: Vec<WorkflowType>,
    pub patterns: Vec<ArrivalPattern>,
    /// Policy axis: registry specs (name + params), so any registered
    /// policy — built-in or user-mounted — can ride the grid.
    pub policies: Vec<PolicySpec>,
    /// Worker-node counts to sweep (cluster scaling axis).
    pub cluster_sizes: Vec<usize>,
    /// Eq. (9) α values to sweep (ablation axis).
    pub alphas: Vec<f64>,
    /// ARAS lookahead on/off (ablation axis).
    pub lookaheads: Vec<bool>,
    /// Cluster-turbulence axis: node-lifecycle event scripts and/or
    /// autoscaler settings. Orthogonal to the policy axis (and excluded
    /// from seed derivation), so every policy is compared on static vs.
    /// churning clusters under bit-identical workloads.
    pub churns: Vec<ChurnProfile>,
    /// Demand-forecaster axis: `None` = forecasting off. Excluded from
    /// seed derivation like `churns`, so forecaster cells replay
    /// bit-identical workloads.
    pub forecasters: Vec<Option<ForecasterSpec>>,
    /// Fault-injection axis: chaos scenario scripts. Excluded from seed
    /// derivation like `churns`/`forecasters`, so every fault family is
    /// compared against the quiet cluster under bit-identical workloads.
    pub chaos: Vec<ChaosProfile>,
    /// Federation axis: cluster counts to sweep. `1` (the default) runs
    /// the ordinary single-cluster engine — labels and reports are
    /// byte-identical to pre-federation campaigns. `k > 1` runs the
    /// cell as a homogeneous federation of `k` shards of the cell's
    /// cluster config behind `router`, folded to one outcome. Excluded
    /// from seed derivation like `churns`, so federated cells replay
    /// bit-identical workloads.
    pub clusters: Vec<usize>,
    /// Global router for federated cells (`clusters > 1`); single-cluster
    /// cells ignore it.
    pub router: RouterSpec,
    /// Repetitions per cell; repetition `r` is a distinct seed stream.
    pub reps: usize,
    /// Root of the seed tree — the only entropy input of a campaign.
    pub base_seed: u64,
    /// Worker OS threads; 0 = one per available core.
    pub threads: usize,
}

impl Default for CampaignSpec {
    fn default() -> Self {
        let base = ExperimentConfig::default();
        CampaignSpec {
            name: "campaign".to_string(),
            workflows: vec![base.workload.workflow],
            patterns: vec![base.workload.pattern],
            policies: vec![PolicySpec::adaptive(), PolicySpec::fcfs()],
            cluster_sizes: vec![base.cluster.nodes],
            alphas: vec![base.alloc.alpha],
            lookaheads: vec![base.alloc.lookahead],
            churns: vec![ChurnProfile::from_cluster(&base.cluster.events, &base.cluster.autoscaler)],
            forecasters: vec![base.forecast.forecaster.clone()],
            chaos: vec![ChaosProfile::from_config(&base.chaos)],
            clusters: vec![1],
            router: RouterSpec::default(),
            reps: 1,
            base_seed: base.workload.seed,
            threads: 0,
            base,
        }
    }
}

/// Report label of a forecaster-axis value (`"none"` when disabled).
pub fn forecaster_label(f: &Option<ForecasterSpec>) -> String {
    f.as_ref().map(|s| s.label()).unwrap_or_else(|| "none".to_string())
}

/// Grid coordinates of one planned run, plus its derived seed.
#[derive(Debug, Clone)]
pub struct RunCoord {
    /// Position in expansion order (stable sort key for results).
    pub index: usize,
    pub workflow: WorkflowType,
    pub pattern: ArrivalPattern,
    pub policy: PolicySpec,
    pub nodes: usize,
    pub alpha: f64,
    pub lookahead: bool,
    /// Churn-axis label ("static" for the quiet cluster).
    pub churn: String,
    /// Forecaster-axis label ("none" when forecasting is off).
    pub forecaster: String,
    /// Chaos-axis label ("none" for the fault-free cluster).
    pub chaos: String,
    /// Federation-axis cluster count (1 = ordinary single-cluster run).
    pub clusters: usize,
    /// Router label of a federated cell ("none" when `clusters == 1`).
    pub router: String,
    pub rep: usize,
    /// Workload seed derived from (base_seed, workflow identity,
    /// pattern identity, rep) — identical across the
    /// policy/α/lookahead/cluster-size/churn/clusters axes by design, so
    /// those comparisons are workload-paired, and independent of what
    /// else the grid contains.
    pub seed: u64,
}

impl RunCoord {
    /// Compact human-readable label, e.g.
    /// `montage/constant/adaptive n=6 a=0.8 la=on c=static r0`. The
    /// forecaster (` f=<label>`), chaos (` x=<label>`) and federation
    /// (` fed=<k>x<router>`) segments appear only when those axes are
    /// set, so single-cluster fault-free labels match pre-chaos and
    /// pre-federation snapshots.
    pub fn label(&self) -> String {
        let forecaster = if self.forecaster == "none" {
            String::new()
        } else {
            format!(" f={}", self.forecaster)
        };
        let chaos = if self.chaos == "none" {
            String::new()
        } else {
            format!(" x={}", self.chaos)
        };
        let federation = if self.clusters <= 1 {
            String::new()
        } else {
            format!(" fed={}x{}", self.clusters, self.router)
        };
        format!(
            "{}/{}/{} n={} a={} la={} c={}{}{}{} r{}",
            self.workflow.name(),
            self.pattern.name(),
            self.policy.label(),
            self.nodes,
            self.alpha,
            if self.lookahead { "on" } else { "off" },
            self.churn,
            forecaster,
            chaos,
            federation,
            self.rep,
        )
    }
}

/// One fully-resolved run: coordinates + the config the engine executes.
#[derive(Debug, Clone)]
pub struct PlannedRun {
    pub coord: RunCoord,
    pub cfg: ExperimentConfig,
}

/// One completed run.
pub struct CampaignRun {
    pub coord: RunCoord,
    pub outcome: RunOutcome,
}

/// All runs of a campaign, in grid-expansion order.
pub struct CampaignResult {
    pub name: String,
    pub runs: Vec<CampaignRun>,
    /// Worker threads actually used.
    pub threads_used: usize,
}

/// Stable identity code of a workflow type — part of the seed
/// derivation, so it must never depend on grid position and must stay
/// fixed across releases (append-only).
fn workflow_code(wf: WorkflowType) -> u64 {
    match wf {
        WorkflowType::Montage => 1,
        WorkflowType::Epigenomics => 2,
        WorkflowType::CyberShake => 3,
        WorkflowType::Ligo => 4,
        WorkflowType::Custom => 5,
    }
}

/// Stable identity code of an arrival pattern: variant tag mixed with
/// its parameters, so `Constant{5,6}` and `Constant{2,2}` get distinct
/// streams but the same pattern always gets the same code regardless of
/// where (or whether) other patterns appear in the grid.
fn pattern_code(p: ArrivalPattern) -> u64 {
    match p {
        ArrivalPattern::Constant { per_burst, bursts } => {
            derive_seed(1, &[per_burst as u64, bursts as u64])
        }
        ArrivalPattern::Linear { d, k, total } => {
            derive_seed(2, &[d as u64, k as u64, total as u64])
        }
        ArrivalPattern::Pyramid { start, step, peak, total } => {
            derive_seed(3, &[start as u64, step as u64, peak as u64, total as u64])
        }
    }
}

impl CampaignSpec {
    /// A single-cell spec whose *every* grid axis is seeded from `base`'s
    /// own values (policy, α, lookahead, cluster size, workflow,
    /// pattern). Use this when a carefully-constructed base config must
    /// keep those settings — `expand()` overwrites the base's axis fields
    /// from the axis vectors, so a hand-copied subset can silently drift.
    /// Widen individual axes afterwards to sweep.
    ///
    /// Note the workload seed is NOT passed through verbatim:
    /// `base.workload.seed` becomes the campaign's `base_seed`, from
    /// which `expand()` derives the run's seed over the (workflow,
    /// pattern, rep) identities like any other campaign — so a
    /// `from_base` cell matches the same cell inside a wider sweep, not
    /// a bare `run_experiment(&base)`.
    pub fn from_base(base: ExperimentConfig) -> Self {
        CampaignSpec {
            name: "campaign".to_string(),
            workflows: vec![base.workload.workflow],
            patterns: vec![base.workload.pattern],
            policies: vec![base.alloc.policy.clone()],
            cluster_sizes: vec![base.cluster.nodes],
            alphas: vec![base.alloc.alpha],
            lookaheads: vec![base.alloc.lookahead],
            churns: vec![ChurnProfile::from_cluster(&base.cluster.events, &base.cluster.autoscaler)],
            forecasters: vec![base.forecast.forecaster.clone()],
            chaos: vec![ChaosProfile::from_config(&base.chaos)],
            clusters: vec![1],
            router: RouterSpec::default(),
            reps: 1,
            base_seed: base.workload.seed,
            threads: 0,
            base,
        }
    }

    /// Number of runs the grid expands to.
    pub fn total_runs(&self) -> usize {
        self.workflows.len()
            * self.patterns.len()
            * self.policies.len()
            * self.cluster_sizes.len()
            * self.alphas.len()
            * self.lookaheads.len()
            * self.churns.len()
            * self.forecasters.len()
            * self.chaos.len()
            * self.clusters.len()
            * self.reps
    }

    fn validate(&self) -> anyhow::Result<()> {
        // Duplicate axis values would run identical (coordinate, seed)
        // cells twice and let comparison() count one run as two
        // repetitions of statistical evidence — reject them.
        fn axis<T: PartialEq>(xs: &[T], what: &str) -> anyhow::Result<()> {
            anyhow::ensure!(!xs.is_empty(), "campaign needs >= 1 {what}");
            for (i, x) in xs.iter().enumerate() {
                anyhow::ensure!(
                    !xs[..i].contains(x),
                    "campaign {what} axis contains a duplicate value"
                );
            }
            Ok(())
        }
        axis(&self.workflows, "workflow")?;
        axis(&self.patterns, "pattern")?;
        axis(&self.policies, "policy")?;
        axis(&self.cluster_sizes, "cluster size")?;
        axis(&self.alphas, "alpha")?;
        axis(&self.lookaheads, "lookahead setting")?;
        axis(&self.churns, "churn profile")?;
        axis(&self.forecasters, "forecaster")?;
        axis(&self.chaos, "chaos profile")?;
        axis(&self.clusters, "cluster count")?;
        anyhow::ensure!(
            self.clusters.iter().all(|&k| k >= 1),
            "campaign cluster-count axis values must be >= 1"
        );
        // Churn labels key the report grouping: two distinct profiles
        // with one label would blend as repetitions.
        for (i, churn) in self.churns.iter().enumerate() {
            anyhow::ensure!(
                !self.churns[..i].iter().any(|c| c.label == churn.label),
                "campaign churn axis repeats label '{}'",
                churn.label
            );
        }
        // Same for forecaster labels (a registered forecaster literally
        // named "none" would collide with the disabled slot).
        for (i, f) in self.forecasters.iter().enumerate() {
            let label = forecaster_label(f);
            anyhow::ensure!(
                !self.forecasters[..i].iter().any(|o| forecaster_label(o) == label),
                "campaign forecaster axis repeats label '{label}'"
            );
        }
        // Chaos labels key the report grouping like churn labels do.
        for (i, profile) in self.chaos.iter().enumerate() {
            anyhow::ensure!(
                !self.chaos[..i].iter().any(|c| c.label == profile.label),
                "campaign chaos axis repeats label '{}'",
                profile.label
            );
        }
        // The cluster-size axis scales the legacy uniform pool; with
        // explicit heterogeneous pools it would be silently ignored.
        anyhow::ensure!(
            self.base.cluster.pools.is_empty() || self.cluster_sizes.len() == 1,
            "cluster-size axis conflicts with explicit node pools (sweep pools via base configs)"
        );
        // A spec-level alpha/lookahead param would silently override the
        // grid axis inside the policy factory while RunCoord still
        // reports the axis value — fabricated differentiation. Those
        // knobs belong to the grid in a campaign.
        for policy in &self.policies {
            for axis_key in ["alpha", "lookahead"] {
                anyhow::ensure!(
                    policy.param(axis_key).is_none(),
                    "policy '{}' carries a '{axis_key}' param; in a campaign sweep that \
                     knob via the grid axis instead",
                    policy.label()
                );
            }
        }
        anyhow::ensure!(self.reps >= 1, "campaign needs >= 1 repetition");
        anyhow::ensure!(
            !self.workflows.contains(&WorkflowType::Custom),
            "campaign grids take named topologies (custom specs need an explicit parser pass)"
        );
        Ok(())
    }

    /// Expand the grid into concrete runs, in deterministic order:
    /// workflow → pattern → nodes → α → lookahead → churn → forecaster →
    /// chaos → clusters → policy → rep. Each run's config is validated
    /// before it is returned.
    pub fn expand(&self) -> anyhow::Result<Vec<PlannedRun>> {
        self.validate()?;
        let mut runs = Vec::with_capacity(self.total_runs());
        for &workflow in &self.workflows {
            for &pattern in &self.patterns {
                for &nodes in &self.cluster_sizes {
                    for &alpha in &self.alphas {
                        for &lookahead in &self.lookaheads {
                            for churn in &self.churns {
                                for forecaster in &self.forecasters {
                                    for chaos in &self.chaos {
                                        for &clusters in &self.clusters {
                                            for policy in &self.policies {
                                                for rep in 0..self.reps {
                                                    let cell = CellCoord {
                                                        workflow,
                                                        pattern,
                                                        nodes,
                                                        alpha,
                                                        lookahead,
                                                        churn,
                                                        forecaster,
                                                        chaos,
                                                        clusters,
                                                        policy,
                                                        rep,
                                                    };
                                                    runs.push(self.plan_run(&cell, runs.len())?);
                                                }
                                            }
                                        }
                                    }
                                }
                            }
                        }
                    }
                }
            }
        }
        Ok(runs)
    }

    /// Resolve one grid cell into a planned run. Split out of `expand`'s
    /// loop nest so the cell body reads at sane indentation.
    fn plan_run(&self, cell: &CellCoord<'_>, index: usize) -> anyhow::Result<PlannedRun> {
        // Seed coordinates are the *stable identities* of the axes that
        // shape the workload (topology, pattern, repetition) — never grid
        // positions, and never the policy/α/lookahead/cluster-size/churn/
        // forecaster/chaos/clusters axes. So comparison twins see
        // identical workloads, and a cell's workload is the same whether
        // it runs alone or inside a 1000-cell sweep.
        let seed = derive_seed(
            self.base_seed,
            &[workflow_code(cell.workflow), pattern_code(cell.pattern), cell.rep as u64],
        );
        let mut cfg = self.base.clone();
        cfg.workload.workflow = cell.workflow;
        cfg.workload.pattern = cell.pattern;
        cfg.workload.seed = seed;
        cfg.alloc.policy = cell.policy.clone();
        cfg.alloc.alpha = cell.alpha;
        cfg.alloc.lookahead = cell.lookahead;
        cfg.cluster.nodes = cell.nodes;
        cfg.cluster.events = cell.churn.events.clone();
        cfg.cluster.autoscaler = cell.churn.autoscaler.clone();
        cfg.forecast.forecaster = cell.forecaster.clone();
        cfg.chaos = cell.chaos.to_config();
        // sample_interval_s <= 0 falls back to the engine's default in
        // run_experiment.
        cfg.validate()?;
        // Report the node count the run will actually start with: for
        // explicit pools the legacy `nodes` axis value is ignored by the
        // engine, and a label saying otherwise would misstate the
        // experiment record.
        let actual_nodes = cfg.cluster.initial_nodes();
        Ok(PlannedRun {
            coord: RunCoord {
                index,
                workflow: cell.workflow,
                pattern: cell.pattern,
                policy: cell.policy.clone(),
                nodes: actual_nodes,
                alpha: cell.alpha,
                lookahead: cell.lookahead,
                churn: cell.churn.label.clone(),
                forecaster: forecaster_label(cell.forecaster),
                chaos: cell.chaos.label.clone(),
                clusters: cell.clusters,
                router: if cell.clusters > 1 {
                    self.router.label()
                } else {
                    "none".to_string()
                },
                rep: cell.rep,
                seed,
            },
            cfg,
        })
    }
}

/// Borrowed coordinates of one grid cell while `expand` walks the nest.
struct CellCoord<'a> {
    workflow: WorkflowType,
    pattern: ArrivalPattern,
    nodes: usize,
    alpha: f64,
    lookahead: bool,
    churn: &'a ChurnProfile,
    forecaster: &'a Option<ForecasterSpec>,
    chaos: &'a ChaosProfile,
    clusters: usize,
    policy: &'a PolicySpec,
    rep: usize,
}

/// Resolve the worker-pool width: explicit > cores > at most one thread
/// per run (spawning idle workers is pointless).
fn effective_threads(requested: usize, total_runs: usize) -> usize {
    let t = if requested > 0 {
        requested
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    };
    t.clamp(1, total_runs.max(1))
}

/// Execute a campaign across the worker pool and return results in
/// grid-expansion order. Each worker pulls the next un-started run from
/// a shared counter (work stealing), so stragglers never serialize the
/// tail; determinism comes from per-run seeding + the final re-sort, not
/// from the schedule.
pub fn run(spec: &CampaignSpec) -> anyhow::Result<CampaignResult> {
    let planned = spec.expand()?;
    let threads = effective_threads(spec.threads, planned.len());
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, anyhow::Result<RunOutcome>)>();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            let tx = tx.clone();
            let next = &next;
            let planned = &planned;
            let router = &spec.router;
            scope.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= planned.len() {
                    break;
                }
                // Federated cells shard the cell's config across
                // `clusters` member engines and fold the result back to
                // one RunOutcome; each federation runs sequentially
                // inside this worker, so the pool parallelism stays
                // across cells only and results remain bit-deterministic
                // at any thread count.
                let clusters = planned[i].coord.clusters;
                let result = if clusters > 1 {
                    federation::run_sharded(&planned[i].cfg, clusters, router)
                } else {
                    run_experiment(&planned[i].cfg)
                };
                if tx.send((i, result)).is_err() {
                    break;
                }
            });
        }
    });
    drop(tx);

    let mut slots: Vec<Option<anyhow::Result<RunOutcome>>> =
        (0..planned.len()).map(|_| None).collect();
    for (i, result) in rx {
        slots[i] = Some(result);
    }

    let mut runs = Vec::with_capacity(planned.len());
    for (planned_run, slot) in planned.into_iter().zip(slots) {
        let outcome = match slot {
            Some(Ok(outcome)) => outcome,
            Some(Err(e)) => {
                anyhow::bail!("campaign run {} failed: {e}", planned_run.coord.label())
            }
            None => anyhow::bail!(
                "campaign run {} produced no result (worker died)",
                planned_run.coord.label()
            ),
        };
        runs.push(CampaignRun { coord: planned_run.coord, outcome });
    }
    Ok(CampaignResult { name: spec.name.clone(), runs, threads_used: threads })
}

// --------------------------------------------------------------- analysis

/// Aggregated metrics of one policy inside one comparison cell
/// (mean ± δ over repetitions, like a Table 2 cell group).
#[derive(Debug, Clone)]
pub struct PolicyAgg {
    pub policy: String,
    pub runs: usize,
    pub total_duration_min: Cell,
    pub avg_workflow_duration_min: Cell,
    pub cpu_usage: Cell,
    pub mem_usage: Cell,
    pub oom_events: f64,
    pub alloc_waits: f64,
    /// Mean streaming-quantile median workflow duration (seconds).
    pub wf_duration_p50_s: f64,
    /// Mean `policy.plan()` invocations per run (span-derived).
    pub plan_calls: f64,
}

/// One comparison cell: a grid point with the policy axis collapsed
/// (and reps aggregated). Carries the full workflow and pattern values
/// so same-variant patterns with different parameters remain
/// distinguishable (render with `.name()`/`.detail()`). The paper's
/// ARAS-vs-FCFS pair gets dedicated slots (the headline deltas are
/// defined between them); every other registered policy that rode the
/// grid lands in `extras`, one aggregate per distinct spec label.
#[derive(Debug, Clone)]
pub struct ComparisonRow {
    pub workflow: WorkflowType,
    pub pattern: ArrivalPattern,
    pub nodes: usize,
    pub alpha: f64,
    pub lookahead: bool,
    /// Churn-axis label of this cell ("static" for quiet clusters).
    pub churn: String,
    /// Forecaster-axis label of this cell ("none" when forecasting is off).
    pub forecaster: String,
    /// Chaos-axis label of this cell ("none" for the fault-free cluster).
    pub chaos: String,
    /// Federation-axis cluster count of this cell (1 = single-cluster).
    pub clusters: usize,
    /// Router label of this cell ("none" when `clusters == 1`).
    pub router: String,
    pub adaptive: Option<PolicyAgg>,
    pub baseline: Option<PolicyAgg>,
    /// Aggregates of non-{adaptive, baseline} policies (grid order).
    pub extras: Vec<PolicyAgg>,
}

impl ComparisonRow {
    /// Paper-style time saving: `(1 - adaptive/baseline) * 100`,
    /// positive when ARAS is faster.
    pub fn total_saving_pct(&self) -> Option<f64> {
        saving(&self.adaptive, &self.baseline, |a| a.total_duration_min.mean)
    }

    pub fn avg_saving_pct(&self) -> Option<f64> {
        saving(&self.adaptive, &self.baseline, |a| a.avg_workflow_duration_min.mean)
    }

    /// Usage-rate delta in percentage points, positive when ARAS is higher.
    pub fn cpu_gain_pts(&self) -> Option<f64> {
        delta(&self.adaptive, &self.baseline, |a| a.cpu_usage.mean)
    }

    pub fn mem_gain_pts(&self) -> Option<f64> {
        delta(&self.adaptive, &self.baseline, |a| a.mem_usage.mean)
    }
}

fn saving(
    adaptive: &Option<PolicyAgg>,
    baseline: &Option<PolicyAgg>,
    pick: impl Fn(&PolicyAgg) -> f64,
) -> Option<f64> {
    let (a, b) = (adaptive.as_ref()?, baseline.as_ref()?);
    let base = pick(b);
    if base > 0.0 {
        Some((1.0 - pick(a) / base) * 100.0)
    } else {
        None
    }
}

fn delta(
    adaptive: &Option<PolicyAgg>,
    baseline: &Option<PolicyAgg>,
    pick: impl Fn(&PolicyAgg) -> f64,
) -> Option<f64> {
    Some((pick(adaptive.as_ref()?) - pick(baseline.as_ref()?)) * 100.0)
}

impl CampaignResult {
    /// Group runs into comparison cells (first-appearance order, which
    /// equals grid order) and aggregate each policy's repetitions.
    /// Grouping compares the full pattern *value*, not just its name —
    /// two `Constant` patterns with different parameters are distinct
    /// cells, never blended as if they were repetitions.
    pub fn comparison(&self) -> Vec<ComparisonRow> {
        // Collect unique cells in first-appearance (= grid) order.
        let mut rows: Vec<ComparisonRow> = Vec::new();
        for run in &self.runs {
            let c = &run.coord;
            let seen = rows.iter().any(|r| {
                r.workflow == c.workflow
                    && r.pattern == c.pattern
                    && r.nodes == c.nodes
                    && r.alpha == c.alpha
                    && r.lookahead == c.lookahead
                    && r.churn == c.churn
                    && r.forecaster == c.forecaster
                    && r.chaos == c.chaos
                    && r.clusters == c.clusters
                    && r.router == c.router
            });
            if !seen {
                rows.push(ComparisonRow {
                    workflow: c.workflow,
                    pattern: c.pattern,
                    nodes: c.nodes,
                    alpha: c.alpha,
                    lookahead: c.lookahead,
                    churn: c.churn.clone(),
                    forecaster: c.forecaster.clone(),
                    chaos: c.chaos.clone(),
                    clusters: c.clusters,
                    router: c.router.clone(),
                    adaptive: None,
                    baseline: None,
                    extras: Vec::new(),
                });
            }
        }
        for row in &mut rows {
            // Copy the cell key out so the filter closure doesn't hold a
            // borrow of `row` across the slot assignments below.
            let (workflow, pattern, nodes, alpha, lookahead, churn, forecaster, chaos, clusters, router) = (
                row.workflow,
                row.pattern,
                row.nodes,
                row.alpha,
                row.lookahead,
                row.churn.clone(),
                row.forecaster.clone(),
                row.chaos.clone(),
                row.clusters,
                row.router.clone(),
            );
            let in_cell = move |r: &CampaignRun| {
                r.coord.workflow == workflow
                    && r.coord.pattern == pattern
                    && r.coord.nodes == nodes
                    && r.coord.alpha == alpha
                    && r.coord.lookahead == lookahead
                    && r.coord.churn == churn
                    && r.coord.forecaster == forecaster
                    && r.coord.chaos == chaos
                    && r.coord.clusters == clusters
                    && r.coord.router == router
            };
            // Distinct policy specs in this cell, first-appearance order.
            // Full-spec identity (not just name): differently-parameterized
            // variants of one policy aggregate separately, never blended
            // as if they were repetitions.
            let mut specs: Vec<PolicySpec> = Vec::new();
            for run in self.runs.iter().filter(|r| in_cell(r)) {
                if !specs.contains(&run.coord.policy) {
                    specs.push(run.coord.policy.clone());
                }
            }
            for spec in specs {
                let group: Vec<&CampaignRun> = self
                    .runs
                    .iter()
                    .filter(|r| in_cell(r))
                    .filter(|r| r.coord.policy == spec)
                    .collect();
                let col = |pick: fn(&CampaignRun) -> f64| -> Vec<f64> {
                    group.iter().map(|&r| pick(r)).collect()
                };
                let agg = PolicyAgg {
                    policy: spec.label(),
                    runs: group.len(),
                    total_duration_min: Cell::of(&col(|r| r.outcome.summary.total_duration_min)),
                    avg_workflow_duration_min: Cell::of(&col(|r| {
                        r.outcome.summary.avg_workflow_duration_min
                    })),
                    cpu_usage: Cell::of(&col(|r| r.outcome.summary.cpu_usage)),
                    mem_usage: Cell::of(&col(|r| r.outcome.summary.mem_usage)),
                    oom_events: crate::util::stats::mean(&col(|r| {
                        r.outcome.summary.oom_events as f64
                    })),
                    alloc_waits: crate::util::stats::mean(&col(|r| {
                        r.outcome.summary.alloc_waits as f64
                    })),
                    wf_duration_p50_s: crate::util::stats::mean(&col(|r| {
                        r.outcome.summary.wf_duration_p50_s
                    })),
                    plan_calls: crate::util::stats::mean(&col(|r| {
                        r.outcome.summary.phases.plan_calls as f64
                    })),
                };
                // The parameter-less canonical pair keeps its dedicated
                // slots (paper deltas); everything else is an extra.
                match agg.policy.as_str() {
                    "adaptive" => row.adaptive = Some(agg),
                    "baseline" => row.baseline = Some(agg),
                    _ => row.extras.push(agg),
                }
            }
        }
        rows
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec() -> CampaignSpec {
        let mut spec = CampaignSpec::default();
        spec.base.workload.pattern = ArrivalPattern::Constant { per_burst: 2, bursts: 1 };
        spec.patterns = vec![spec.base.workload.pattern];
        spec.base.sample_interval_s = 5.0;
        spec
    }

    #[test]
    fn expansion_covers_the_cross_product() {
        let mut spec = small_spec();
        spec.workflows = vec![WorkflowType::Montage, WorkflowType::Ligo];
        spec.patterns =
            vec![ArrivalPattern::paper_constant(), ArrivalPattern::paper_linear()];
        spec.cluster_sizes = vec![4, 6];
        spec.reps = 3;
        assert_eq!(spec.total_runs(), 2 * 2 * 2 * 2 * 3);
        let runs = spec.expand().unwrap();
        assert_eq!(runs.len(), spec.total_runs());
        for (i, r) in runs.iter().enumerate() {
            assert_eq!(r.coord.index, i);
            assert_eq!(r.cfg.workload.seed, r.coord.seed);
            assert_eq!(r.cfg.cluster.nodes, r.coord.nodes);
        }
    }

    #[test]
    fn policy_twins_share_a_seed_but_reps_do_not() {
        let mut spec = small_spec();
        spec.reps = 2;
        let runs = spec.expand().unwrap();
        assert_eq!(runs.len(), 4); // 2 policies x 2 reps
        let seed_of = |policy: &PolicySpec, rep: usize| {
            runs.iter()
                .find(|r| r.coord.policy == *policy && r.coord.rep == rep)
                .unwrap()
                .coord
                .seed
        };
        let (aras, fcfs) = (PolicySpec::adaptive(), PolicySpec::fcfs());
        assert_eq!(seed_of(&aras, 0), seed_of(&fcfs, 0));
        assert_eq!(seed_of(&aras, 1), seed_of(&fcfs, 1));
        assert_ne!(seed_of(&aras, 0), seed_of(&aras, 1));
    }

    #[test]
    fn seed_is_independent_of_grid_composition() {
        // The same (workflow, pattern, rep) cell gets the same seed no
        // matter what else the campaign sweeps — cross-campaign
        // reproducibility.
        let mut solo = small_spec();
        solo.workflows = vec![WorkflowType::Montage];
        let mut sweep = small_spec();
        sweep.workflows = vec![WorkflowType::Ligo, WorkflowType::Montage];
        sweep.cluster_sizes = vec![3, 6, 12];
        let solo_seed = solo.expand().unwrap()[0].coord.seed;
        let sweep_runs = sweep.expand().unwrap();
        let montage = sweep_runs
            .iter()
            .find(|r| r.coord.workflow == WorkflowType::Montage)
            .unwrap();
        assert_eq!(solo_seed, montage.coord.seed);
    }

    #[test]
    fn empty_axis_is_rejected() {
        let mut spec = small_spec();
        spec.policies.clear();
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.reps = 0;
        assert!(spec.expand().is_err());
    }

    #[test]
    fn churn_axis_is_workload_paired_and_orthogonal() {
        let mut spec = small_spec();
        spec.churns = vec![
            ChurnProfile::none(),
            ChurnProfile::drain_storm(60.0, 120.0, 2),
            ChurnProfile::autoscaled(4, 10),
        ];
        assert_eq!(spec.total_runs(), 2 * 3);
        let runs = spec.expand().unwrap();
        // Same policy, different churn → identical workload seed.
        let static_run = runs
            .iter()
            .find(|r| r.coord.churn == "static" && r.coord.policy == PolicySpec::adaptive())
            .unwrap();
        let storm_run = runs
            .iter()
            .find(|r| r.coord.churn.starts_with("drain-storm") && r.coord.policy == PolicySpec::adaptive())
            .unwrap();
        assert_eq!(static_run.coord.seed, storm_run.coord.seed);
        // The churn profile lands in the run's cluster config.
        assert_eq!(storm_run.cfg.cluster.events.len(), 2);
        assert!(static_run.cfg.cluster.events.is_empty());
        let auto_run = runs
            .iter()
            .find(|r| r.coord.churn.starts_with("autoscale"))
            .unwrap();
        assert!(auto_run.cfg.cluster.autoscaler.is_some());
    }

    #[test]
    fn forecaster_axis_is_workload_paired_and_labeled() {
        let mut spec = small_spec();
        spec.forecasters = vec![None, Some(ForecasterSpec::named("holt"))];
        assert_eq!(spec.total_runs(), 2 * 2);
        let runs = spec.expand().unwrap();
        let off = runs
            .iter()
            .find(|r| r.coord.forecaster == "none" && r.coord.policy == PolicySpec::adaptive())
            .unwrap();
        let on = runs
            .iter()
            .find(|r| r.coord.forecaster == "holt" && r.coord.policy == PolicySpec::adaptive())
            .unwrap();
        // Excluded from seed derivation: identical workloads.
        assert_eq!(off.coord.seed, on.coord.seed);
        // The forecaster lands in the run config.
        assert!(off.cfg.forecast.forecaster.is_none());
        assert_eq!(on.cfg.forecast.forecaster.as_ref().unwrap().name, "holt");
        // Labels: the "none" cell keeps the pre-forecast shape.
        assert!(!off.coord.label().contains(" f="), "{}", off.coord.label());
        assert!(on.coord.label().contains(" f=holt"), "{}", on.coord.label());
    }

    #[test]
    fn duplicate_forecaster_axis_values_are_rejected() {
        let mut spec = small_spec();
        spec.forecasters = vec![None, None];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.forecasters =
            vec![Some(ForecasterSpec::named("holt")), Some(ForecasterSpec::named("holt"))];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.forecasters.clear();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn chaos_axis_is_workload_paired_and_labeled() {
        let mut spec = small_spec();
        spec.chaos = vec![
            ChaosProfile::none(),
            ChaosProfile::cpu_hog(60.0, 120.0, 4000),
            ChaosProfile::partition(60.0, 90.0),
        ];
        assert_eq!(spec.total_runs(), 2 * 3);
        let runs = spec.expand().unwrap();
        let quiet = runs
            .iter()
            .find(|r| r.coord.chaos == "none" && r.coord.policy == PolicySpec::adaptive())
            .unwrap();
        let hogged = runs
            .iter()
            .find(|r| {
                r.coord.chaos.starts_with("cpu-hog") && r.coord.policy == PolicySpec::adaptive()
            })
            .unwrap();
        // Excluded from seed derivation: identical workloads.
        assert_eq!(quiet.coord.seed, hogged.coord.seed);
        // The scenarios land in the run config.
        assert!(quiet.cfg.chaos.is_quiet());
        assert_eq!(hogged.cfg.chaos.scenarios.len(), 1);
        // Labels: the quiet cell keeps the pre-chaos shape.
        assert!(!quiet.coord.label().contains(" x="), "{}", quiet.coord.label());
        assert!(hogged.coord.label().contains(" x=cpu-hog"), "{}", hogged.coord.label());
    }

    #[test]
    fn duplicate_chaos_labels_are_rejected() {
        let mut spec = small_spec();
        let a = ChaosProfile::partition(60.0, 90.0);
        let mut b = ChaosProfile::partition(120.0, 90.0);
        b.label = a.label.clone(); // distinct scenarios, same label
        spec.chaos = vec![a, b];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.chaos.clear();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn chaos_cells_group_separately_in_comparison() {
        let mut spec = small_spec();
        spec.chaos = vec![ChaosProfile::none(), ChaosProfile::partition(5.0, 60.0)];
        spec.threads = 2;
        let result = run(&spec).unwrap();
        let rows = result.comparison();
        assert_eq!(rows.len(), 2);
        let labels: Vec<&str> = rows.iter().map(|r| r.chaos.as_str()).collect();
        assert_eq!(labels, vec!["none", "partition[5/60]"]);
        for row in &rows {
            assert!(row.adaptive.is_some() && row.baseline.is_some());
        }
    }

    #[test]
    fn clusters_axis_is_workload_paired_federated_and_labeled() {
        let mut spec = small_spec();
        spec.policies = vec![PolicySpec::adaptive()];
        spec.clusters = vec![1, 2];
        spec.router = RouterSpec::named("lq"); // alias canonicalizes
        assert_eq!(spec.total_runs(), 2);
        let runs = spec.expand().unwrap();
        let single = runs.iter().find(|r| r.coord.clusters == 1).unwrap();
        let fed = runs.iter().find(|r| r.coord.clusters == 2).unwrap();
        // Excluded from seed derivation: identical workloads.
        assert_eq!(single.coord.seed, fed.coord.seed);
        // Labels: the single-cluster cell keeps the pre-federation shape.
        assert!(!single.coord.label().contains(" fed="), "{}", single.coord.label());
        assert!(fed.coord.label().contains(" fed=2xleast-queue"), "{}", fed.coord.label());
        assert_eq!(single.coord.router, "none");
        // Federated cells run and group separately from their twin.
        spec.threads = 2;
        let result = run(&spec).unwrap();
        let rows = result.comparison();
        assert_eq!(rows.len(), 2);
        let clusters: Vec<usize> = rows.iter().map(|r| r.clusters).collect();
        assert_eq!(clusters, vec![1, 2]);
        for run in &result.runs {
            assert_eq!(run.outcome.summary.workflows_completed, 2);
        }
    }

    #[test]
    fn zero_cluster_count_is_rejected() {
        let mut spec = small_spec();
        spec.clusters = vec![0];
        assert!(spec.expand().is_err());
        let mut spec = small_spec();
        spec.clusters.clear();
        assert!(spec.expand().is_err());
    }

    #[test]
    fn duplicate_churn_labels_are_rejected() {
        let mut spec = small_spec();
        let mut a = ChurnProfile::drain_storm(60.0, 120.0, 2);
        let b = ChurnProfile::drain_storm(90.0, 60.0, 2);
        a.label = b.label.clone(); // distinct events, same label
        spec.churns = vec![a, b];
        assert!(spec.expand().is_err());
    }

    #[test]
    fn churn_cells_group_separately_in_comparison() {
        let mut spec = small_spec();
        spec.churns = vec![ChurnProfile::none(), ChurnProfile::drain_storm(30.0, 60.0, 1)];
        spec.threads = 2;
        let result = run(&spec).unwrap();
        let rows = result.comparison();
        assert_eq!(rows.len(), 2);
        let labels: Vec<&str> = rows.iter().map(|r| r.churn.as_str()).collect();
        assert_eq!(labels, vec!["static", "drain-storm[1@30/60]"]);
        for row in &rows {
            assert!(row.adaptive.is_some() && row.baseline.is_some());
        }
    }

    #[test]
    fn duplicate_axis_values_are_rejected() {
        let mut spec = small_spec();
        spec.cluster_sizes = vec![6, 6];
        assert!(spec.expand().is_err(), "duplicate nodes would double-count runs");
        let mut spec = small_spec();
        spec.alphas = vec![0.8, 0.8];
        assert!(spec.expand().is_err());
    }

    #[test]
    fn single_cell_campaign_runs() {
        let mut spec = small_spec();
        spec.policies = vec![PolicySpec::adaptive()];
        spec.threads = 2;
        let result = run(&spec).unwrap();
        assert_eq!(result.runs.len(), 1);
        assert_eq!(result.runs[0].outcome.summary.workflows_completed, 2);
    }

    #[test]
    fn non_canonical_policies_land_in_extras() {
        let mut spec = small_spec();
        spec.policies = vec![
            PolicySpec::adaptive(),
            PolicySpec::fcfs(),
            PolicySpec::named("static-headroom"),
            PolicySpec::named("rate-capped").with_param("budget", 2.0),
        ];
        spec.threads = 2;
        let result = run(&spec).unwrap();
        let rows = result.comparison();
        assert_eq!(rows.len(), 1);
        let row = &rows[0];
        assert!(row.adaptive.is_some() && row.baseline.is_some());
        let labels: Vec<&str> = row.extras.iter().map(|a| a.policy.as_str()).collect();
        assert_eq!(labels, vec!["static-headroom", "rate-capped:budget=2"]);
        // Headline deltas stay defined between the canonical pair.
        assert!(row.total_saving_pct().is_some());
    }

    #[test]
    fn comparison_pairs_policies() {
        let mut spec = small_spec();
        spec.threads = 2;
        let result = run(&spec).unwrap();
        let rows = result.comparison();
        assert_eq!(rows.len(), 1);
        let row = &rows[0];
        assert!(row.adaptive.is_some() && row.baseline.is_some());
        assert!(row.total_saving_pct().is_some());
    }
}
