//! Declarative fault injection: krkn-style chaos scenarios riding the
//! sim event queue.
//!
//! Real clusters do not only churn nodes (PR 3) — they degrade. krkn
//! (kraken) expresses that as per-scenario input files: CPU/memory/I-O
//! hogs pinned to nodes, and network disruptions that cut components
//! off from the apiserver. This module holds the *descriptions* of that
//! degradation — the engine interprets them on its event queue, exactly
//! like [`crate::cluster::dynamics`] lifecycle events:
//!
//! * **Noisy-neighbor hogs** (`cpu-hog` / `mem-hog` / `io-hog`) — an
//!   uninstrumented co-tenant consumes node resources outside the
//!   engine's control. Hog magnitudes shrink the node's allocatable
//!   capacity (so every `NodeResidual` derived from it shrinks with no
//!   corresponding allocation), and `io-hog` additionally stretches the
//!   runtime of pods on the pressured node.
//! * **Informer-latency storms** (`latency-storm`) — store→informer
//!   watch propagation degrades: syncs are suppressed unless at least
//!   `magnitude` seconds have passed since the last one, so the engine
//!   plans against stale [`crate::resources::ClusterSnapshot`]s.
//! * **Informer↔store partitions** (`partition`) — propagation stops
//!   entirely: snapshots are frozen at the pre-partition cache state,
//!   exposing the double-allocation risk real informers have.
//!
//! Scenario-file format (JSON, the krkn `input.yaml` idiom flattened
//! into one document):
//! ```json
//! {"chaos_scenarios": [
//!   {"at": 120, "kind": "cpu-hog", "duration": 300, "magnitude": 4000, "node": "node-0"},
//!   {"at": 120, "kind": "mem-hog", "duration": 300, "magnitude": 8192},
//!   {"at": 500, "kind": "io-hog", "duration": 200, "magnitude": 4},
//!   {"at": 800, "kind": "latency-storm", "duration": 120, "magnitude": 45},
//!   {"at": 1000, "kind": "partition", "duration": 90}
//! ]}
//! ```
//! Times are seconds from run start and must be finite, non-negative
//! and time-ordered; durations must be positive. `magnitude` is
//! per-kind: stolen milli-cores (`cpu-hog`), stolen Mi (`mem-hog`), a
//! runtime slowdown factor > 1 (`io-hog`), or the minimum seconds
//! between informer syncs (`latency-storm`); `partition` takes none.
//! Hogs may omit `node`; the engine then picks a victim
//! deterministically (the busiest schedulable node, like unnamed
//! drains). Chaos is strictly opt-in: an empty scenario list leaves the
//! engine bit-identical to a chaos-free build.

use crate::simcore::SimTime;
use crate::util::json::Json;

/// Which fault a scenario injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChaosKind {
    /// A co-tenant burns `magnitude` milli-cores on one node.
    CpuHog,
    /// A co-tenant holds `magnitude` Mi on one node.
    MemHog,
    /// I/O pressure: pods on the node run `magnitude`× slower.
    IoHog,
    /// Informer syncs are suppressed unless `magnitude` seconds have
    /// passed since the previous sync.
    LatencyStorm,
    /// Informer syncs stop entirely; snapshots freeze.
    Partition,
}

impl ChaosKind {
    pub fn name(self) -> &'static str {
        match self {
            ChaosKind::CpuHog => "cpu-hog",
            ChaosKind::MemHog => "mem-hog",
            ChaosKind::IoHog => "io-hog",
            ChaosKind::LatencyStorm => "latency-storm",
            ChaosKind::Partition => "partition",
        }
    }

    /// Whether this kind targets a single node (hogs do; informer
    /// faults are control-plane-wide).
    pub fn node_scoped(self) -> bool {
        matches!(self, ChaosKind::CpuHog | ChaosKind::MemHog | ChaosKind::IoHog)
    }
}

/// One scheduled fault: active over `[at, at + duration)`.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosScenario {
    pub at: SimTime,
    pub duration: f64,
    pub kind: ChaosKind,
    /// Target node for hogs (`None` = engine-picked victim). Must be
    /// `None` for informer faults.
    pub node: Option<String>,
    /// Per-kind magnitude (see module docs); 0 for `partition`.
    pub magnitude: f64,
}

impl ChaosScenario {
    /// Reject every value that would corrupt the event queue or
    /// silently truncate: non-finite/negative times, zero/negative
    /// durations and magnitudes, fractional resource amounts,
    /// mis-scoped node targets.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.at.is_finite(), "non-finite time");
        anyhow::ensure!(self.at >= 0.0, "negative time");
        anyhow::ensure!(
            self.duration.is_finite() && self.duration > 0.0,
            "duration must be finite and positive"
        );
        match self.kind {
            ChaosKind::CpuHog | ChaosKind::MemHog => {
                anyhow::ensure!(
                    self.magnitude.is_finite() && self.magnitude > 0.0,
                    "{} magnitude must be finite and positive",
                    self.kind.name()
                );
                anyhow::ensure!(
                    self.magnitude.fract() == 0.0,
                    "{} magnitude must be a whole resource amount",
                    self.kind.name()
                );
            }
            ChaosKind::IoHog => {
                anyhow::ensure!(
                    self.magnitude.is_finite() && self.magnitude > 1.0,
                    "io-hog magnitude is a slowdown factor and must be > 1"
                );
            }
            ChaosKind::LatencyStorm => {
                anyhow::ensure!(
                    self.magnitude.is_finite() && self.magnitude > 0.0,
                    "latency-storm magnitude (sync delay seconds) must be finite and positive"
                );
            }
            ChaosKind::Partition => {
                anyhow::ensure!(self.magnitude == 0.0, "partition takes no magnitude");
            }
        }
        if !self.kind.node_scoped() {
            anyhow::ensure!(
                self.node.is_none(),
                "{} is cluster-wide and takes no 'node'",
                self.kind.name()
            );
        }
        Ok(())
    }
}

/// The experiment-level chaos configuration: a time-ordered scenario
/// list. Default (empty) means *no* chaos — the engine schedules
/// nothing and default runs stay bit-identical.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChaosConfig {
    pub scenarios: Vec<ChaosScenario>,
}

impl ChaosConfig {
    pub fn is_quiet(&self) -> bool {
        self.scenarios.is_empty()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        let mut last = f64::NEG_INFINITY;
        for (i, s) in self.scenarios.iter().enumerate() {
            s.validate().map_err(|e| anyhow::anyhow!("chaos scenario {i}: {e}"))?;
            anyhow::ensure!(s.at >= last, "chaos scenario {i}: out of order");
            last = s.at;
        }
        Ok(())
    }
}

// ---------------------------------------------------------- file I/O

/// Parse a chaos-scenarios array (the value of `"chaos_scenarios"`).
/// Shares the workload/cluster trace harness's validation posture:
/// strict keys, loud rejections.
pub fn scenarios_from_json(j: &Json) -> anyhow::Result<Vec<ChaosScenario>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("chaos_scenarios must be an array"))?;
    let mut scenarios = Vec::with_capacity(arr.len());
    let mut last = f64::NEG_INFINITY;
    for (i, s) in arr.iter().enumerate() {
        let obj = s
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("chaos scenario {i}: must be an object"))?;
        let kind_name = s
            .get("kind")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("chaos scenario {i}: missing 'kind'"))?;
        let kind = match kind_name {
            "cpu-hog" => ChaosKind::CpuHog,
            "mem-hog" => ChaosKind::MemHog,
            "io-hog" => ChaosKind::IoHog,
            "latency-storm" => ChaosKind::LatencyStorm,
            "partition" => ChaosKind::Partition,
            other => anyhow::bail!(
                "chaos scenario {i}: unknown kind '{other}' \
                 (cpu-hog|mem-hog|io-hog|latency-storm|partition)"
            ),
        };
        // Strict keys, like every other config parser here: a misspelled
        // 'node' must not silently turn a targeted hog into an
        // engine-picked victim.
        let allowed: &[&str] = match kind {
            ChaosKind::CpuHog | ChaosKind::MemHog | ChaosKind::IoHog => {
                &["at", "kind", "duration", "magnitude", "node"]
            }
            ChaosKind::LatencyStorm => &["at", "kind", "duration", "magnitude"],
            ChaosKind::Partition => &["at", "kind", "duration"],
        };
        for key in obj.keys() {
            anyhow::ensure!(
                allowed.contains(&key.as_str()),
                "chaos scenario {i} ({kind_name}): unknown key '{key}' (allowed: {})",
                allowed.join(", ")
            );
        }
        let at = s
            .get("at")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("chaos scenario {i}: missing 'at'"))?;
        let duration = s
            .get("duration")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("chaos scenario {i}: missing 'duration'"))?;
        let magnitude = match kind {
            ChaosKind::Partition => 0.0,
            _ => s
                .get("magnitude")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow::anyhow!("chaos scenario {i}: missing 'magnitude'"))?,
        };
        let node = match s.get("node") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| {
                        anyhow::anyhow!("chaos scenario {i}: 'node' must be a string")
                    })?
                    .to_string(),
            ),
        };
        let scenario = ChaosScenario { at, duration, kind, node, magnitude };
        scenario.validate().map_err(|e| anyhow::anyhow!("chaos scenario {i}: {e}"))?;
        anyhow::ensure!(at >= last, "chaos scenario {i}: out of order");
        last = at;
        scenarios.push(scenario);
    }
    Ok(scenarios)
}

/// Parse a full scenario document: `{"chaos_scenarios": [...]}`.
pub fn parse(text: &str) -> anyhow::Result<Vec<ChaosScenario>> {
    let j = Json::parse(text)?;
    let arr = j
        .get("chaos_scenarios")
        .ok_or_else(|| anyhow::anyhow!("chaos file needs a 'chaos_scenarios' array"))?;
    let scenarios = scenarios_from_json(arr)?;
    anyhow::ensure!(!scenarios.is_empty(), "chaos file has no scenarios");
    Ok(scenarios)
}

pub fn from_file(path: &str) -> anyhow::Result<Vec<ChaosScenario>> {
    parse(
        &std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading chaos scenarios {path}: {e}"))?,
    )
}

/// The `"chaos_scenarios"` array value (embeddable in a config object).
pub fn scenarios_to_json(scenarios: &[ChaosScenario]) -> Json {
    let items: Vec<Json> = scenarios
        .iter()
        .map(|s| {
            let mut pairs = vec![
                ("at", Json::num(s.at)),
                ("kind", Json::str(s.kind.name())),
                ("duration", Json::num(s.duration)),
            ];
            if s.kind != ChaosKind::Partition {
                pairs.push(("magnitude", Json::num(s.magnitude)));
            }
            if let Some(n) = &s.node {
                pairs.push(("node", Json::str(n.clone())));
            }
            Json::obj(pairs)
        })
        .collect();
    Json::Arr(items)
}

/// Serialize scenarios back to the file format (round-trips with
/// [`parse`]).
pub fn to_json(scenarios: &[ChaosScenario]) -> String {
    Json::obj(vec![("chaos_scenarios", scenarios_to_json(scenarios))]).to_string_pretty()
}

// ------------------------------------------------------ chaos profiles

/// A named chaos scenario bundle — the campaign runner's chaos axis,
/// orthogonal to policies, churn and forecasters and (like them)
/// excluded from seed derivation, so every profile faces bit-identical
/// workloads.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosProfile {
    /// Report label (must be unique within a campaign axis).
    pub label: String,
    pub scenarios: Vec<ChaosScenario>,
}

impl ChaosProfile {
    /// The quiet run: no faults. Labelled "none"; run labels omit the
    /// chaos segment for it, keeping pre-chaos labels byte-identical.
    pub fn none() -> Self {
        ChaosProfile { label: "none".to_string(), scenarios: Vec::new() }
    }

    /// One CPU hog stealing `milli` milli-cores over `[at, at+duration)`.
    pub fn cpu_hog(at: SimTime, duration: f64, milli: i64) -> Self {
        ChaosProfile {
            label: format!("cpu-hog[{milli}m@{at}/{duration}]"),
            scenarios: vec![ChaosScenario {
                at,
                duration,
                kind: ChaosKind::CpuHog,
                node: None,
                magnitude: milli as f64,
            }],
        }
    }

    /// One memory hog holding `mi` Mi over `[at, at+duration)`.
    pub fn mem_hog(at: SimTime, duration: f64, mi: i64) -> Self {
        ChaosProfile {
            label: format!("mem-hog[{mi}Mi@{at}/{duration}]"),
            scenarios: vec![ChaosScenario {
                at,
                duration,
                kind: ChaosKind::MemHog,
                node: None,
                magnitude: mi as f64,
            }],
        }
    }

    /// One I/O hog slowing the victim's pods by `factor`×.
    pub fn io_hog(at: SimTime, duration: f64, factor: f64) -> Self {
        ChaosProfile {
            label: format!("io-hog[{factor}x@{at}/{duration}]"),
            scenarios: vec![ChaosScenario {
                at,
                duration,
                kind: ChaosKind::IoHog,
                node: None,
                magnitude: factor,
            }],
        }
    }

    /// One informer-latency storm: syncs at most every `delay_s` seconds.
    pub fn latency_storm(at: SimTime, duration: f64, delay_s: f64) -> Self {
        ChaosProfile {
            label: format!("latency-storm[{delay_s}s@{at}/{duration}]"),
            scenarios: vec![ChaosScenario {
                at,
                duration,
                kind: ChaosKind::LatencyStorm,
                node: None,
                magnitude: delay_s,
            }],
        }
    }

    /// One informer↔store partition: snapshots frozen for the window.
    pub fn partition(at: SimTime, duration: f64) -> Self {
        ChaosProfile {
            label: format!("partition[{at}/{duration}]"),
            scenarios: vec![ChaosScenario {
                at,
                duration,
                kind: ChaosKind::Partition,
                node: None,
                magnitude: 0.0,
            }],
        }
    }

    /// Capture whatever chaos an experiment config already carries (the
    /// campaign `from_base` seeding path).
    pub fn from_config(cfg: &ChaosConfig) -> Self {
        if cfg.is_quiet() {
            return Self::none();
        }
        ChaosProfile { label: "base".to_string(), scenarios: cfg.scenarios.clone() }
    }

    /// Expand into an experiment-level [`ChaosConfig`].
    pub fn to_config(&self) -> ChaosConfig {
        ChaosConfig { scenarios: self.scenarios.clone() }
    }

    /// Parse a CLI chaos spec:
    /// `none` | `cpu-hog:at=A,duration=D,magnitude=M`
    /// | `mem-hog:at=A,duration=D,magnitude=M`
    /// | `io-hog:at=A,duration=D,magnitude=F`
    /// | `latency-storm:at=A,duration=D,magnitude=S`
    /// | `partition:at=A,duration=D`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.trim();
        let (name, raw_params) = match s.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (s, None),
        };
        let mut params: Vec<(String, f64)> = Vec::new();
        if let Some(raw) = raw_params {
            for pair in raw.split(',').filter(|p| !p.trim().is_empty()) {
                let (k, v) = pair
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("chaos param '{pair}' is not key=value"))?;
                let value: f64 = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("chaos param '{k}': bad value '{v}'"))?;
                params.push((k.trim().to_lowercase(), value));
            }
        }
        // Negative or non-finite values would corrupt the queue or
        // saturate through casts into a mislabeled profile — reject.
        for (k, v) in &params {
            anyhow::ensure!(
                v.is_finite() && *v >= 0.0,
                "chaos param '{k}': value {v} must be finite and >= 0"
            );
        }
        let get = |key: &str, default: f64| {
            params.iter().find(|(k, _)| k == key).map(|&(_, v)| v).unwrap_or(default)
        };
        let get_amount = |key: &str, default: i64| -> anyhow::Result<i64> {
            match params.iter().find(|(k, _)| k == key) {
                None => Ok(default),
                Some(&(_, v)) => {
                    anyhow::ensure!(
                        v.fract() == 0.0,
                        "chaos param '{key}': {v} must be an integer"
                    );
                    Ok(v as i64)
                }
            }
        };
        let known = |allowed: &[&str]| -> anyhow::Result<()> {
            for (k, _) in &params {
                anyhow::ensure!(
                    allowed.contains(&k.as_str()),
                    "chaos '{name}': unknown param '{k}' (allowed: {})",
                    allowed.join(", ")
                );
            }
            Ok(())
        };
        let profile = match name.to_lowercase().as_str() {
            "none" => {
                known(&[])?;
                Self::none()
            }
            "cpu-hog" => {
                known(&["at", "duration", "magnitude"])?;
                Self::cpu_hog(
                    get("at", 120.0),
                    get("duration", 300.0),
                    get_amount("magnitude", 4000)?,
                )
            }
            "mem-hog" => {
                known(&["at", "duration", "magnitude"])?;
                Self::mem_hog(
                    get("at", 120.0),
                    get("duration", 300.0),
                    get_amount("magnitude", 8192)?,
                )
            }
            "io-hog" => {
                known(&["at", "duration", "magnitude"])?;
                Self::io_hog(get("at", 120.0), get("duration", 300.0), get("magnitude", 4.0))
            }
            "latency-storm" => {
                known(&["at", "duration", "magnitude"])?;
                Self::latency_storm(
                    get("at", 120.0),
                    get("duration", 300.0),
                    get("magnitude", 45.0),
                )
            }
            "partition" => {
                known(&["at", "duration"])?;
                Self::partition(get("at", 120.0), get("duration", 90.0))
            }
            other => anyhow::bail!(
                "unknown chaos profile '{other}' \
                 (none|cpu-hog|mem-hog|io-hog|latency-storm|partition)"
            ),
        };
        profile.to_config().validate()?;
        Ok(profile)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_valid_scenario_file() {
        let scenarios = parse(
            r#"{"chaos_scenarios":[
                {"at":120,"kind":"cpu-hog","duration":300,"magnitude":4000,"node":"node-0"},
                {"at":120,"kind":"mem-hog","duration":300,"magnitude":8192},
                {"at":500,"kind":"io-hog","duration":200,"magnitude":4},
                {"at":800,"kind":"latency-storm","duration":120,"magnitude":45},
                {"at":1000,"kind":"partition","duration":90}
            ]}"#,
        )
        .unwrap();
        assert_eq!(scenarios.len(), 5);
        assert_eq!(scenarios[0].kind, ChaosKind::CpuHog);
        assert_eq!(scenarios[0].node.as_deref(), Some("node-0"));
        assert_eq!(scenarios[1].node, None);
        assert_eq!(scenarios[4].kind, ChaosKind::Partition);
        assert_eq!(scenarios[4].magnitude, 0.0);
    }

    #[test]
    fn rejects_malformed_scenarios() {
        assert!(parse(r#"{}"#).is_err());
        assert!(parse(r#"{"chaos_scenarios":[]}"#).is_err());
        // Negative time.
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":-1,"kind":"partition","duration":10}]}"#
        )
        .is_err());
        // Zero and negative durations.
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"partition","duration":0}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"partition","duration":-5}]}"#
        )
        .is_err());
        // Zero/negative magnitudes.
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"cpu-hog","duration":10,"magnitude":0}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"mem-hog","duration":10,"magnitude":-64}]}"#
        )
        .is_err());
        // io-hog magnitude is a slowdown factor: 1.0 (no slowdown) is a
        // config mistake, not a fault.
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"io-hog","duration":10,"magnitude":1}]}"#
        )
        .is_err());
        // Unknown kind.
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"gpu-hog","duration":10,"magnitude":1}]}"#
        )
        .is_err());
        // Out of order.
        assert!(parse(
            r#"{"chaos_scenarios":[
                {"at":10,"kind":"partition","duration":5},
                {"at":5,"kind":"partition","duration":5}
            ]}"#
        )
        .is_err());
        // Missing required fields.
        assert!(parse(r#"{"chaos_scenarios":[{"kind":"partition","duration":5}]}"#).is_err());
        assert!(parse(r#"{"chaos_scenarios":[{"at":0,"kind":"partition"}]}"#).is_err());
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"cpu-hog","duration":5}]}"#
        )
        .is_err());
        // Strict keys: partitions are cluster-wide; a 'node' there is a
        // misunderstanding, and a misspelled key must not pass silently.
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"partition","duration":5,"node":"node-0"}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"partition","duration":5,"magnitude":3}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"chaos_scenarios":[
                {"at":0,"kind":"cpu-hog","duration":5,"magnitude":100,"Node":"node-0"}
            ]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"cpu-hog","duration":5,"magnitude":100,"node":3}]}"#
        )
        .is_err());
        // Fractional resource amounts would truncate through i64 casts.
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"cpu-hog","duration":5,"magnitude":10.5}]}"#
        )
        .is_err());
    }

    #[test]
    fn rejects_non_finite_values() {
        // 1e999 overflows f64 parsing to +inf; inf or NaN times/durations
        // would corrupt the event queue's ordering (same edge the
        // workload and cluster trace parsers guard).
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":1e999,"kind":"partition","duration":5}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":-1e999,"kind":"partition","duration":5}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"partition","duration":1e999}]}"#
        )
        .is_err());
        assert!(parse(
            r#"{"chaos_scenarios":[{"at":0,"kind":"cpu-hog","duration":5,"magnitude":1e999}]}"#
        )
        .is_err());
    }

    #[test]
    fn random_scenarios_roundtrip_bit_exactly() {
        // Property: parse(to_json(s)) == s for arbitrary valid scenario
        // lists, including fractional times (shortest-roundtrip float
        // printing) — the PR 3 trace-harness property, ported.
        crate::testutil::forall(
            0xC4A0_5,
            200,
            |rng: &mut crate::simcore::Rng| {
                let n = rng.range_inclusive(1, 8) as usize;
                let mut t = 0.0f64;
                (0..n)
                    .map(|_| {
                        t += rng.uniform(0.0, 400.0);
                        let duration = rng.uniform(0.5, 600.0);
                        let node = if rng.range_inclusive(0, 1) == 1 {
                            Some(format!("node-{}", rng.range_inclusive(0, 5)))
                        } else {
                            None
                        };
                        match rng.range_inclusive(0, 4) {
                            0 => ChaosScenario {
                                at: t,
                                duration,
                                kind: ChaosKind::CpuHog,
                                node,
                                magnitude: rng.range_inclusive(1, 16000) as f64,
                            },
                            1 => ChaosScenario {
                                at: t,
                                duration,
                                kind: ChaosKind::MemHog,
                                node,
                                magnitude: rng.range_inclusive(1, 32768) as f64,
                            },
                            2 => ChaosScenario {
                                at: t,
                                duration,
                                kind: ChaosKind::IoHog,
                                node,
                                magnitude: 1.0 + rng.uniform(0.1, 9.0),
                            },
                            3 => ChaosScenario {
                                at: t,
                                duration,
                                kind: ChaosKind::LatencyStorm,
                                node: None,
                                magnitude: rng.uniform(1.0, 120.0),
                            },
                            _ => ChaosScenario {
                                at: t,
                                duration,
                                kind: ChaosKind::Partition,
                                node: None,
                                magnitude: 0.0,
                            },
                        }
                    })
                    .collect::<Vec<_>>()
            },
            |scenarios| {
                let again = parse(&to_json(scenarios)).map_err(|e| e.to_string())?;
                if &again == scenarios {
                    Ok(())
                } else {
                    Err(format!("round-trip drift: {scenarios:?} != {again:?}"))
                }
            },
        )
        .unwrap();
    }

    #[test]
    fn chaos_profiles_parse() {
        assert_eq!(ChaosProfile::parse("none").unwrap(), ChaosProfile::none());
        let c = ChaosProfile::parse("cpu-hog:at=100,duration=60,magnitude=2000").unwrap();
        assert_eq!(c.label, "cpu-hog[2000m@100/60]");
        assert_eq!(c.scenarios[0].magnitude, 2000.0);
        // Labels carry every parameter: same-magnitude hogs with
        // different timing are distinct axis values.
        assert_ne!(
            c.label,
            ChaosProfile::parse("cpu-hog:at=500,duration=60,magnitude=2000").unwrap().label
        );
        let m = ChaosProfile::parse("mem-hog").unwrap();
        assert_eq!(m.label, "mem-hog[8192Mi@120/300]");
        let io = ChaosProfile::parse("io-hog:magnitude=3").unwrap();
        assert_eq!(io.scenarios[0].kind, ChaosKind::IoHog);
        assert_eq!(io.scenarios[0].magnitude, 3.0);
        let ls = ChaosProfile::parse("latency-storm:magnitude=30").unwrap();
        assert_eq!(ls.label, "latency-storm[30s@120/300]");
        let p = ChaosProfile::parse("partition:at=200,duration=80").unwrap();
        assert_eq!(p.label, "partition[200/80]");
        assert!(ChaosProfile::parse("meteor").is_err());
        assert!(ChaosProfile::parse("cpu-hog:depth=3").is_err());
        assert!(ChaosProfile::parse("partition:magnitude=3").is_err());
        // Negative/fractional/degenerate numerics must not slip through.
        assert!(ChaosProfile::parse("cpu-hog:magnitude=-100").is_err());
        assert!(ChaosProfile::parse("cpu-hog:magnitude=10.5").is_err());
        assert!(ChaosProfile::parse("cpu-hog:duration=0").is_err());
        assert!(ChaosProfile::parse("io-hog:magnitude=0.5").is_err());
    }

    #[test]
    fn profile_config_roundtrip_and_validation() {
        let p = ChaosProfile::cpu_hog(120.0, 300.0, 4000);
        let cfg = p.to_config();
        cfg.validate().unwrap();
        assert_eq!(ChaosProfile::from_config(&cfg).scenarios, p.scenarios);
        assert_eq!(ChaosProfile::from_config(&ChaosConfig::default()), ChaosProfile::none());
        assert!(ChaosConfig::default().is_quiet());
        // Out-of-order programmatic configs are rejected by validate.
        let mut bad = ChaosConfig::default();
        bad.scenarios = vec![
            ChaosProfile::partition(100.0, 10.0).scenarios.remove(0),
            ChaosProfile::partition(50.0, 10.0).scenarios.remove(0),
        ];
        assert!(bad.validate().is_err());
    }
}
