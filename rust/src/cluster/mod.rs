//! Discrete-event Kubernetes cluster simulator (the paper's testbed,
//! rebuilt — DESIGN.md §Substitutions).
//!
//! Components mirror the pieces KubeAdaptor touches:
//!
//! * [`objects`]   — typed API objects: [`objects::Node`], [`objects::Pod`],
//!   phases including `OOMKilled`.
//! * [`store`]     — the kube-apiserver equivalent: a versioned object
//!   store emitting List-Watch events.
//! * [`informer`]  — client-go Informer equivalent: local cache synced
//!   from the store's watch stream; provides `PodLister`/`NodeLister`
//!   (Algorithm 2's inputs).
//! * [`scheduler`] — pod placement onto feasible nodes (most-residual
//!   spreading, matching kube-scheduler's default LeastAllocated flavor),
//!   skipping cordoned nodes.
//! * [`dynamics`]  — cluster dynamics: declarative node-lifecycle events
//!   (join/drain/crash, replayable from JSON traces), the reactive
//!   autoscaler's configuration, and reusable churn profiles.
//!
//! Pod lifecycle transitions (`Pending → Running → Succeeded/ OOMKilled`)
//! are *driven by the engine's event queue*; this module owns the state
//! and the legality of each transition. Node lifecycle transitions
//! (join → cordon → drain/crash → remove) are likewise engine-driven
//! events over the store's node set.

pub mod dynamics;
pub mod informer;
pub mod objects;
pub mod scheduler;
pub mod store;

pub use dynamics::{AutoscalerConfig, AutoscalerMode, ChurnProfile, ClusterEvent, ClusterEventKind};
pub use informer::Informer;
pub use objects::{Node, Pod, PodPhase};
pub use scheduler::Scheduler;
pub use store::{ObjectStore, WatchEvent};
