//! Discrete-event Kubernetes cluster simulator (the paper's testbed,
//! rebuilt — DESIGN.md §Substitutions).
//!
//! Components mirror the pieces KubeAdaptor touches:
//!
//! * [`objects`]   — typed API objects: [`objects::Node`], [`objects::Pod`],
//!   phases including `OOMKilled`.
//! * [`store`]     — the kube-apiserver equivalent: a versioned object
//!   store emitting List-Watch events.
//! * [`informer`]  — client-go Informer equivalent: local cache synced
//!   from the store's watch stream; provides `PodLister`/`NodeLister`
//!   (Algorithm 2's inputs).
//! * [`scheduler`] — pod placement onto feasible nodes (most-residual
//!   spreading, matching kube-scheduler's default LeastAllocated flavor).
//!
//! Pod lifecycle transitions (`Pending → Running → Succeeded/ OOMKilled`)
//! are *driven by the engine's event queue*; this module owns the state
//! and the legality of each transition.

pub mod informer;
pub mod objects;
pub mod scheduler;
pub mod store;

pub use informer::Informer;
pub use objects::{Node, Pod, PodPhase};
pub use scheduler::Scheduler;
pub use store::{ObjectStore, WatchEvent};
