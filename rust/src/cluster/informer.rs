//! client-go Informer equivalent: a local cache synced from the store's
//! watch stream, exposing `PodLister`/`NodeLister` (Algorithm 2 inputs).
//!
//! The Resource Discovery module reads *only* this cache — never the
//! object store directly — reproducing the paper's "novel monitoring
//! mechanism" that avoids hammering kube-apiserver (§1, §2.3). The cache
//! tracks its own last-synced resource version; `sync` drains new watch
//! events incrementally.

use std::collections::BTreeMap;

use super::objects::{Node, Pod};
use super::store::{ObjectStore, WatchEvent};

/// Local cache of pods and nodes.
#[derive(Debug, Default)]
pub struct Informer {
    pods: BTreeMap<u64, Pod>,
    nodes: BTreeMap<String, Node>,
    synced_version: u64,
    syncs: u64,
}

impl Informer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drain watch events since our last sync and update the cache.
    /// Returns the number of events applied.
    pub fn sync(&mut self, store: &ObjectStore) -> usize {
        self.sync_events(store).len()
    }

    /// [`Informer::sync`], but hand back the drained watch events so a
    /// delta consumer (incremental Resource Discovery) can apply exactly
    /// what this sync applied. Same single `watch_since` round-trip,
    /// same sync accounting — `sync` delegates here.
    pub fn sync_events(&mut self, store: &ObjectStore) -> Vec<(u64, WatchEvent)> {
        let events: Vec<(u64, WatchEvent)> = store.watch_since(self.synced_version).to_vec();
        for (version, ev) in &events {
            match ev {
                WatchEvent::PodAdded(uid) | WatchEvent::PodModified(uid) => {
                    if let Some(pod) = store.pod(*uid) {
                        self.pods.insert(*uid, pod.clone());
                    }
                }
                WatchEvent::PodDeleted(uid) => {
                    self.pods.remove(uid);
                }
                WatchEvent::NodeAdded(name) | WatchEvent::NodeModified(name) => {
                    if let Some(node) = store.node(name) {
                        self.nodes.insert(name.clone(), node.clone());
                    }
                }
                WatchEvent::NodeDeleted(name) => {
                    self.nodes.remove(name);
                }
                // Namespace lifecycle is tracked by the State Tracker,
                // not needed in the resource-discovery cache.
                WatchEvent::NamespaceAdded(_) | WatchEvent::NamespaceDeleted(_) => {}
            }
            self.synced_version = *version;
        }
        self.syncs += 1;
        events
    }

    /// `PodLister`: cached pod list.
    pub fn pod_list(&self) -> Vec<&Pod> {
        self.pods.values().collect()
    }

    /// `NodeLister`: cached node list.
    pub fn node_list(&self) -> Vec<&Node> {
        self.nodes.values().collect()
    }

    pub fn pod(&self, uid: u64) -> Option<&Pod> {
        self.pods.get(&uid)
    }

    /// Cached pod count (all phases) — snapshot metadata.
    pub fn pod_count(&self) -> usize {
        self.pods.len()
    }

    /// Cached node count — snapshot metadata.
    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    pub fn synced_version(&self) -> u64 {
        self.synced_version
    }

    pub fn sync_count(&self) -> u64 {
        self.syncs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::objects::PodPhase;

    fn pod(uid: u64) -> Pod {
        Pod {
            uid,
            name: format!("p{uid}"),
            namespace: "ns".into(),
            task_id: format!("t{uid}"),
            phase: PodPhase::Pending,
            node: None,
            request_cpu: 500,
            request_mem: 1000,
            min_mem: 500,
            duration: 10.0,
            created_at: 0.0,
            started_at: None,
            finished_at: None,
        }
    }

    #[test]
    fn cache_follows_store() {
        let mut store = ObjectStore::new();
        let mut inf = Informer::new();
        store.add_node(Node::new(0, 8000, 16384));
        store.create_pod(pod(1));
        assert_eq!(inf.sync(&store), 2);
        assert_eq!(inf.pod_list().len(), 1);
        assert_eq!(inf.node_list().len(), 1);

        store.set_pod_phase(1, PodPhase::Running, 1.0);
        inf.sync(&store);
        assert_eq!(inf.pod(1).unwrap().phase, PodPhase::Running);

        store.delete_pod(1);
        inf.sync(&store);
        assert!(inf.pod(1).is_none());
    }

    #[test]
    fn node_lifecycle_follows_store() {
        let mut store = ObjectStore::new();
        let mut inf = Informer::new();
        store.add_node(Node::new(0, 8000, 16384));
        store.add_node(Node::new(1, 8000, 16384));
        inf.sync(&store);
        assert_eq!(inf.node_count(), 2);

        store.set_schedulable("node-0", false);
        inf.sync(&store);
        assert!(!inf.node_list().iter().find(|n| n.name == "node-0").unwrap().schedulable);

        store.remove_node("node-0");
        inf.sync(&store);
        assert_eq!(inf.node_count(), 1);
        assert_eq!(inf.node_list()[0].name, "node-1");
    }

    #[test]
    fn incremental_sync_applies_only_new_events() {
        let mut store = ObjectStore::new();
        let mut inf = Informer::new();
        store.create_pod(pod(1));
        inf.sync(&store);
        store.create_pod(pod(2));
        assert_eq!(inf.sync(&store), 1); // only the new event
        assert_eq!(inf.sync(&store), 0); // idempotent
    }

    #[test]
    fn cache_reads_do_not_touch_store_lists() {
        let mut store = ObjectStore::new();
        let mut inf = Informer::new();
        store.create_pod(pod(1));
        inf.sync(&store);
        let before = store.list_call_count();
        let _ = inf.pod_list();
        let _ = inf.node_list();
        assert_eq!(store.list_call_count(), before);
    }
}
