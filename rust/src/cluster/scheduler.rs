//! Pod scheduler: place pending pods onto feasible nodes.
//!
//! Mirrors kube-scheduler's default bin-spreading behaviour
//! (LeastAllocated): among nodes whose residual covers the pod's request,
//! pick the one with the most residual CPU (ties: most residual memory,
//! then stable name order). The paper relies on default K8s scheduling —
//! its contribution is *how much* to request, not *where* to place.

use super::objects::Pod;
use super::store::ObjectStore;

#[derive(Debug, Default)]
pub struct Scheduler {
    attempts: u64,
    failures: u64,
    /// Candidate nodes examined across all attempts — the placement
    /// loop's work metric (observability exposition).
    nodes_considered: u64,
}

impl Scheduler {
    pub fn new() -> Self {
        Self::default()
    }

    /// Choose a node for `pod`; returns the node name or None if no node
    /// currently fits (the pod stays Pending — Algorithm 1's wait path).
    /// Cordoned (draining) nodes are never candidates, matching
    /// kube-scheduler's `node.Spec.Unschedulable` filter.
    pub fn select_node(&mut self, store: &ObjectStore, pod: &Pod) -> Option<String> {
        self.attempts += 1;
        let mut best: Option<(i64, i64, String)> = None;
        for node in store.node_names() {
            self.nodes_considered += 1;
            if !store.node(&node).is_some_and(|n| n.schedulable) {
                continue;
            }
            if let Some((res_cpu, res_mem)) = store.residual_of(&node) {
                if res_cpu >= pod.request_cpu && res_mem >= pod.request_mem {
                    let cand = (res_cpu, res_mem, node);
                    best = match best {
                        None => Some(cand),
                        Some(b) => {
                            // Larger residual wins; name ascending for
                            // ties — compared by reference (&str), no
                            // per-candidate String clone.
                            if (cand.0, cand.1, std::cmp::Reverse(cand.2.as_str()))
                                > (b.0, b.1, std::cmp::Reverse(b.2.as_str()))
                            {
                                Some(cand)
                            } else {
                                Some(b)
                            }
                        }
                    };
                }
            }
        }
        if best.is_none() {
            self.failures += 1;
        }
        best.map(|(_, _, name)| name)
    }

    /// Schedule + bind in one step. Returns the bound node name.
    pub fn schedule(&mut self, store: &mut ObjectStore, pod_uid: u64) -> Option<String> {
        let pod = store.pod(pod_uid)?.clone();
        let node = self.select_node(store, &pod)?;
        if store.bind_pod(pod_uid, &node) {
            Some(node)
        } else {
            None
        }
    }

    pub fn attempts(&self) -> u64 {
        self.attempts
    }

    pub fn failures(&self) -> u64 {
        self.failures
    }

    pub fn nodes_considered(&self) -> u64 {
        self.nodes_considered
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::objects::{Node, PodPhase};

    fn pod(uid: u64, cpu: i64, mem: i64) -> Pod {
        Pod {
            uid,
            name: format!("p{uid}"),
            namespace: "ns".into(),
            task_id: format!("t{uid}"),
            phase: PodPhase::Pending,
            node: None,
            request_cpu: cpu,
            request_mem: mem,
            min_mem: 1000,
            duration: 10.0,
            created_at: 0.0,
            started_at: None,
            finished_at: None,
        }
    }

    fn cluster(n: usize) -> ObjectStore {
        let mut s = ObjectStore::new();
        for i in 0..n {
            s.add_node(Node::new(i, 8000, 16384));
        }
        s
    }

    #[test]
    fn picks_most_residual_node() {
        let mut store = cluster(2);
        let mut sched = Scheduler::new();
        // Load node-0 with a pod.
        let mut p = pod(1, 4000, 8000);
        p.node = Some("node-0".into());
        store.create_pod(p);
        store.create_pod(pod(2, 1000, 1000));
        let node = sched.schedule(&mut store, 2).unwrap();
        assert_eq!(node, "node-1");
    }

    #[test]
    fn returns_none_when_nothing_fits() {
        let mut store = cluster(1);
        let mut sched = Scheduler::new();
        store.create_pod(pod(1, 9000, 1000)); // > node capacity
        assert!(sched.schedule(&mut store, 1).is_none());
        assert_eq!(sched.failures(), 1);
    }

    #[test]
    fn respects_both_dimensions() {
        let mut store = cluster(1);
        let mut sched = Scheduler::new();
        let mut hog = pod(1, 1000, 16000);
        hog.node = Some("node-0".into());
        store.create_pod(hog);
        store.create_pod(pod(2, 1000, 1000)); // cpu fits, mem doesn't
        assert!(sched.schedule(&mut store, 2).is_none());
    }

    #[test]
    fn cordoned_nodes_are_never_selected() {
        let mut store = cluster(2);
        let mut sched = Scheduler::new();
        // node-1 has more residual but is draining.
        let mut p = pod(1, 4000, 8000);
        p.node = Some("node-0".into());
        store.create_pod(p);
        store.set_schedulable("node-1", false);
        store.create_pod(pod(2, 1000, 1000));
        assert_eq!(sched.schedule(&mut store, 2).unwrap(), "node-0");
        // Cordon everything: nothing fits.
        store.set_schedulable("node-0", false);
        store.create_pod(pod(3, 1000, 1000));
        assert!(sched.schedule(&mut store, 3).is_none());
    }

    #[test]
    fn heterogeneous_pools_pick_most_residual() {
        let mut store = ObjectStore::new();
        store.add_node(Node::labeled("small", 0, 0, 4000, 8192));
        store.add_node(Node::labeled("big", 0, 1, 16000, 32768));
        let mut sched = Scheduler::new();
        store.create_pod(pod(1, 1000, 1000));
        assert_eq!(sched.schedule(&mut store, 1).unwrap(), "big-0");
        // A pod only the big node can host.
        store.create_pod(pod(2, 8000, 16000));
        assert_eq!(sched.schedule(&mut store, 2).unwrap(), "big-0");
    }

    #[test]
    fn spreads_across_equal_nodes_deterministically() {
        let mut store = cluster(3);
        let mut sched = Scheduler::new();
        store.create_pod(pod(1, 1000, 1000));
        let n1 = sched.schedule(&mut store, 1).unwrap();
        assert_eq!(n1, "node-0"); // ties broken by name ascending
        store.create_pod(pod(2, 1000, 1000));
        let n2 = sched.schedule(&mut store, 2).unwrap();
        assert_eq!(n2, "node-1"); // node-0 now less residual
    }
}
