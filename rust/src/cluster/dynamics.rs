//! Cluster dynamics: declarative node-lifecycle events, the reactive
//! autoscaler's configuration, and reusable churn profiles.
//!
//! Real clusters are not the paper's fixed six workers: nodes join,
//! drain and crash mid-run, and autoscalers reshape capacity under
//! load. This module holds the *descriptions* of that turbulence — the
//! engine interprets them on its event queue:
//!
//! * [`ClusterEvent`] — one scheduled lifecycle event (`join` / `drain`
//!   / `crash`), replayable from a JSON trace exactly like
//!   [`crate::workload::trace`] replays arrival bursts.
//! * [`AutoscalerConfig`] — the reactive autoscaler's bounds and
//!   thresholds. Policy-orthogonal: any registered policy can run
//!   against a static or an autoscaled cluster.
//! * [`ChurnProfile`] — a named (events, autoscaler) bundle, the
//!   campaign runner's churn axis.
//!
//! Trace format (JSON):
//! ```json
//! {"cluster_events": [
//!   {"at": 0,   "kind": "join",  "pool": "burst", "count": 2},
//!   {"at": 600, "kind": "drain", "node": "node-3"},
//!   {"at": 900, "kind": "crash"}
//! ]}
//! ```
//! Times are seconds from run start and must be finite, non-negative
//! and time-ordered. `drain`/`crash` may omit `node`; the engine then
//! picks a victim deterministically — the schedulable node hosting the
//! most resource-holding pods (ties broken by highest name), and never
//! the last schedulable node standing.

use crate::simcore::SimTime;
use crate::util::json::Json;

/// What happens to the cluster at a scheduled instant.
#[derive(Debug, Clone, PartialEq)]
pub enum ClusterEventKind {
    /// `count` nodes of pool `pool` join the cluster.
    Join { pool: String, count: usize },
    /// A node is cordoned, its pods evicted gracefully (grace period =
    /// `pod_delete_s`), then the node is removed. Evicted tasks are
    /// rescheduled through the reallocation path.
    Drain { node: Option<String> },
    /// A node vanishes immediately; its pods are killed and their tasks
    /// rescheduled once the control plane notices (informer latency).
    Crash { node: Option<String> },
}

impl ClusterEventKind {
    pub fn name(&self) -> &'static str {
        match self {
            ClusterEventKind::Join { .. } => "join",
            ClusterEventKind::Drain { .. } => "drain",
            ClusterEventKind::Crash { .. } => "crash",
        }
    }
}

/// One scheduled node-lifecycle event.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterEvent {
    pub at: SimTime,
    pub kind: ClusterEventKind,
}

/// How the autoscaler decides to scale.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AutoscalerMode {
    /// Trail actual allocation-queue pressure (the PR 3 behavior, and
    /// the default — pre-mode configs are bit-compatible).
    #[default]
    Reactive,
    /// Scale ahead of *forecast* queue pressure: the queue the run's
    /// [`crate::forecast::Forecaster`] predicts one provisioning delay
    /// ahead counts as pressure too, so capacity is ready when the
    /// burst lands. Without a configured forecaster (or before its
    /// first observation) it behaves exactly reactively.
    Predictive,
}

impl AutoscalerMode {
    pub fn name(self) -> &'static str {
        match self {
            AutoscalerMode::Reactive => "reactive",
            AutoscalerMode::Predictive => "predictive",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s.to_lowercase().as_str() {
            "reactive" => Ok(AutoscalerMode::Reactive),
            "predictive" => Ok(AutoscalerMode::Predictive),
            other => anyhow::bail!("unknown autoscaler mode '{other}' (reactive|predictive)"),
        }
    }
}

/// Autoscaler configuration. The engine evaluates it on every metrics
/// tick: sustained allocation-queue pressure (actual, or forecast in
/// [`AutoscalerMode::Predictive`]) adds a node after a provisioning
/// delay; sustained calm drains an empty node the autoscaler itself
/// added — it never touches the statically configured cluster, so a run
/// always converges back to its initial shape.
#[derive(Debug, Clone, PartialEq)]
pub struct AutoscalerConfig {
    /// Never drain below this many schedulable nodes.
    pub min_nodes: usize,
    /// Never scale above this many schedulable nodes (including nodes
    /// still provisioning).
    pub max_nodes: usize,
    /// Pending allocation requests that count as pressure (>= 1).
    pub scale_up_queue: usize,
    /// Consecutive pressure-free ticks before one idle autoscaled node
    /// is drained (>= 1).
    pub scale_down_ticks: u32,
    /// Virtual seconds a new node takes to provision and join.
    pub provision_s: f64,
    /// Pool shape for autoscaled nodes; None = the first configured pool.
    pub pool: Option<String>,
    /// Scaling discipline (reactive trail vs forecast-driven look-ahead).
    pub mode: AutoscalerMode,
}

impl Default for AutoscalerConfig {
    fn default() -> Self {
        Self {
            min_nodes: 1,
            max_nodes: 12,
            scale_up_queue: 2,
            scale_down_ticks: 3,
            provision_s: 30.0,
            pool: None,
            mode: AutoscalerMode::Reactive,
        }
    }
}

impl AutoscalerConfig {
    /// Bounds-only constructor with default thresholds.
    pub fn bounded(min_nodes: usize, max_nodes: usize) -> Self {
        Self { min_nodes, max_nodes, ..Self::default() }
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.max_nodes >= 1, "autoscaler max_nodes >= 1");
        anyhow::ensure!(
            self.min_nodes <= self.max_nodes,
            "autoscaler min_nodes ({}) > max_nodes ({})",
            self.min_nodes,
            self.max_nodes
        );
        anyhow::ensure!(self.scale_up_queue >= 1, "autoscaler scale_up_queue >= 1");
        anyhow::ensure!(self.scale_down_ticks >= 1, "autoscaler scale_down_ticks >= 1");
        anyhow::ensure!(
            self.provision_s.is_finite() && self.provision_s >= 0.0,
            "autoscaler provision_s must be finite and >= 0"
        );
        Ok(())
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Self> {
        let obj =
            j.as_obj().ok_or_else(|| anyhow::anyhow!("autoscaler must be an object"))?;
        let mut cfg = AutoscalerConfig::default();
        for (k, v) in obj {
            let num = || {
                v.as_f64().ok_or_else(|| anyhow::anyhow!("autoscaler '{k}' must be a number"))
            };
            match k.as_str() {
                "min_nodes" => cfg.min_nodes = num()? as usize,
                "max_nodes" => cfg.max_nodes = num()? as usize,
                "scale_up_queue" => cfg.scale_up_queue = num()? as usize,
                "scale_down_ticks" => cfg.scale_down_ticks = num()? as u32,
                "provision_s" => cfg.provision_s = num()?,
                "pool" => {
                    cfg.pool = Some(
                        v.as_str()
                            .ok_or_else(|| anyhow::anyhow!("autoscaler 'pool' must be a string"))?
                            .to_string(),
                    )
                }
                "mode" => {
                    cfg.mode = AutoscalerMode::parse(
                        v.as_str()
                            .ok_or_else(|| anyhow::anyhow!("autoscaler 'mode' must be a string"))?,
                    )?
                }
                other => anyhow::bail!("unknown autoscaler key '{other}'"),
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("min_nodes", Json::num(self.min_nodes as f64)),
            ("max_nodes", Json::num(self.max_nodes as f64)),
            ("scale_up_queue", Json::num(self.scale_up_queue as f64)),
            ("scale_down_ticks", Json::num(self.scale_down_ticks as f64)),
            ("provision_s", Json::num(self.provision_s)),
            ("mode", Json::str(self.mode.name())),
        ];
        if let Some(pool) = &self.pool {
            pairs.push(("pool", Json::str(pool.clone())));
        }
        Json::obj(pairs)
    }
}

// ------------------------------------------------------------ trace I/O

/// Parse a cluster-events array (the value of `"cluster_events"`).
/// Shares the workload-trace harness's validation posture: reject
/// non-finite times, out-of-order events and zero counts loudly.
pub fn events_from_json(j: &Json) -> anyhow::Result<Vec<ClusterEvent>> {
    let arr = j
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("cluster_events must be an array"))?;
    let mut events = Vec::with_capacity(arr.len());
    let mut last = f64::NEG_INFINITY;
    for (i, e) in arr.iter().enumerate() {
        let obj = e
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("cluster event {i}: must be an object"))?;
        let at = e
            .get("at")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow::anyhow!("cluster event {i}: missing 'at'"))?;
        anyhow::ensure!(at.is_finite(), "cluster event {i}: non-finite time");
        anyhow::ensure!(at >= 0.0, "cluster event {i}: negative time");
        anyhow::ensure!(at >= last, "cluster event {i}: out of order");
        last = at;
        let kind_name = e
            .get("kind")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow::anyhow!("cluster event {i}: missing 'kind'"))?;
        // Strict keys, like every other config parser here: a misspelled
        // 'node' must not silently turn a targeted drain into an
        // engine-picked victim.
        let allowed: &[&str] = match kind_name {
            "join" => &["at", "kind", "pool", "count"],
            _ => &["at", "kind", "node"],
        };
        for key in obj.keys() {
            anyhow::ensure!(
                allowed.contains(&key.as_str()),
                "cluster event {i} ({kind_name}): unknown key '{key}' (allowed: {})",
                allowed.join(", ")
            );
        }
        let node = match e.get("node") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| anyhow::anyhow!("cluster event {i}: 'node' must be a string"))?
                    .to_string(),
            ),
        };
        let kind = match kind_name {
            "join" => {
                let pool = match e.get("pool") {
                    None => "node".to_string(),
                    Some(v) => v
                        .as_str()
                        .ok_or_else(|| {
                            anyhow::anyhow!("cluster event {i}: 'pool' must be a string")
                        })?
                        .to_string(),
                };
                let count = match e.get("count") {
                    None => 1,
                    Some(v) => v.as_f64().filter(|c| c.is_finite() && c.fract() == 0.0).ok_or_else(
                        || anyhow::anyhow!("cluster event {i}: 'count' must be an integer"),
                    )? as i64,
                };
                anyhow::ensure!(count > 0, "cluster event {i}: count must be positive");
                ClusterEventKind::Join { pool, count: count as usize }
            }
            "drain" => ClusterEventKind::Drain { node },
            "crash" => ClusterEventKind::Crash { node },
            other => anyhow::bail!("cluster event {i}: unknown kind '{other}' (join|drain|crash)"),
        };
        events.push(ClusterEvent { at, kind });
    }
    Ok(events)
}

/// Parse a full trace document: `{"cluster_events": [...]}`.
pub fn parse(text: &str) -> anyhow::Result<Vec<ClusterEvent>> {
    let j = Json::parse(text)?;
    let arr = j
        .get("cluster_events")
        .ok_or_else(|| anyhow::anyhow!("trace needs a 'cluster_events' array"))?;
    let events = events_from_json(arr)?;
    anyhow::ensure!(!events.is_empty(), "trace has no cluster events");
    Ok(events)
}

pub fn from_file(path: &str) -> anyhow::Result<Vec<ClusterEvent>> {
    parse(
        &std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading cluster-events trace {path}: {e}"))?,
    )
}

/// Serialize events back to the trace format (round-trips with [`parse`]).
pub fn to_json(events: &[ClusterEvent]) -> String {
    Json::obj(vec![("cluster_events", events_to_json(events))]).to_string_pretty()
}

/// The `"cluster_events"` array value (embeddable in a config object).
pub fn events_to_json(events: &[ClusterEvent]) -> Json {
    let items: Vec<Json> = events
        .iter()
        .map(|e| {
            let mut pairs = vec![
                ("at", Json::num(e.at)),
                ("kind", Json::str(e.kind.name())),
            ];
            match &e.kind {
                ClusterEventKind::Join { pool, count } => {
                    pairs.push(("pool", Json::str(pool.clone())));
                    pairs.push(("count", Json::num(*count as f64)));
                }
                ClusterEventKind::Drain { node } | ClusterEventKind::Crash { node } => {
                    if let Some(n) = node {
                        pairs.push(("node", Json::str(n.clone())));
                    }
                }
            }
            Json::obj(pairs)
        })
        .collect();
    Json::Arr(items)
}

// ------------------------------------------------------- churn profiles

/// A named cluster-turbulence scenario: scheduled lifecycle events plus
/// an optional autoscaler. The campaign runner sweeps these as a grid
/// axis orthogonal to the policy axis, so every registered policy can be
/// compared on static vs. churning vs. autoscaled clusters under
/// bit-identical workloads.
#[derive(Debug, Clone, PartialEq)]
pub struct ChurnProfile {
    /// Report label (must be unique within a campaign axis).
    pub label: String,
    pub events: Vec<ClusterEvent>,
    pub autoscaler: Option<AutoscalerConfig>,
}

impl ChurnProfile {
    /// The quiet cluster: no lifecycle events, no autoscaler.
    pub fn none() -> Self {
        ChurnProfile { label: "static".to_string(), events: Vec::new(), autoscaler: None }
    }

    /// Reactive autoscaling within `[min, max]` schedulable nodes.
    pub fn autoscaled(min_nodes: usize, max_nodes: usize) -> Self {
        ChurnProfile {
            label: format!("autoscale[{min_nodes},{max_nodes}]"),
            events: Vec::new(),
            autoscaler: Some(AutoscalerConfig::bounded(min_nodes, max_nodes)),
        }
    }

    /// Forecast-driven autoscaling within `[min, max]` schedulable nodes
    /// ([`AutoscalerMode::Predictive`]); pair it with a configured
    /// forecaster or it degenerates to the reactive profile.
    pub fn autoscaled_predictive(min_nodes: usize, max_nodes: usize) -> Self {
        let mut asc = AutoscalerConfig::bounded(min_nodes, max_nodes);
        asc.mode = AutoscalerMode::Predictive;
        ChurnProfile {
            label: format!("autoscale-pred[{min_nodes},{max_nodes}]"),
            events: Vec::new(),
            autoscaler: Some(asc),
        }
    }

    /// `drains` unnamed drain events, the first at `start`, then every
    /// `period` seconds — the "drain storm" degradation scenario. The
    /// label carries all three parameters so differently-timed storms
    /// of the same size stay distinct on a campaign churn axis.
    pub fn drain_storm(start: SimTime, period: f64, drains: usize) -> Self {
        let events = (0..drains)
            .map(|i| ClusterEvent {
                at: start + period * i as f64,
                kind: ClusterEventKind::Drain { node: None },
            })
            .collect();
        ChurnProfile {
            label: format!("drain-storm[{drains}@{start}/{period}]"),
            events,
            autoscaler: None,
        }
    }

    /// Like [`ChurnProfile::drain_storm`], but nodes crash instead of
    /// draining (no grace period).
    pub fn crash_storm(start: SimTime, period: f64, crashes: usize) -> Self {
        let events = (0..crashes)
            .map(|i| ClusterEvent {
                at: start + period * i as f64,
                kind: ClusterEventKind::Crash { node: None },
            })
            .collect();
        ChurnProfile {
            label: format!("crash-storm[{crashes}@{start}/{period}]"),
            events,
            autoscaler: None,
        }
    }

    /// Capture whatever dynamics a cluster config already carries (the
    /// campaign `from_base` seeding path).
    pub fn from_cluster(events: &[ClusterEvent], autoscaler: &Option<AutoscalerConfig>) -> Self {
        if events.is_empty() && autoscaler.is_none() {
            return Self::none();
        }
        ChurnProfile {
            label: "base".to_string(),
            events: events.to_vec(),
            autoscaler: autoscaler.clone(),
        }
    }

    /// Parse a CLI churn spec:
    /// `static` | `autoscale:min=M,max=N` | `drain-storm:start=S,period=P,drains=N`
    /// | `crash-storm:start=S,period=P,crashes=N`.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let s = s.trim();
        let (name, raw_params) = match s.split_once(':') {
            Some((n, p)) => (n.trim(), Some(p)),
            None => (s, None),
        };
        let mut params: Vec<(String, f64)> = Vec::new();
        if let Some(raw) = raw_params {
            for pair in raw.split(',').filter(|p| !p.trim().is_empty()) {
                let (k, v) = pair
                    .split_once('=')
                    .ok_or_else(|| anyhow::anyhow!("churn param '{pair}' is not key=value"))?;
                let value: f64 = v
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("churn param '{k}': bad value '{v}'"))?;
                params.push((k.trim().to_lowercase(), value));
            }
        }
        // Negative or fractional values would silently saturate/truncate
        // through `as usize` into a mislabeled profile — reject instead.
        for (k, v) in &params {
            anyhow::ensure!(
                v.is_finite() && *v >= 0.0,
                "churn param '{k}': value {v} must be finite and >= 0"
            );
        }
        let get = |key: &str, default: f64| {
            params.iter().find(|(k, _)| k == key).map(|&(_, v)| v).unwrap_or(default)
        };
        let get_count = |key: &str, default: usize| -> anyhow::Result<usize> {
            match params.iter().find(|(k, _)| k == key) {
                None => Ok(default),
                Some(&(_, v)) => {
                    anyhow::ensure!(v.fract() == 0.0, "churn param '{key}': {v} must be an integer");
                    Ok(v as usize)
                }
            }
        };
        let known = |allowed: &[&str]| -> anyhow::Result<()> {
            for (k, _) in &params {
                anyhow::ensure!(
                    allowed.contains(&k.as_str()),
                    "churn '{name}': unknown param '{k}' (allowed: {})",
                    allowed.join(", ")
                );
            }
            Ok(())
        };
        match name.to_lowercase().as_str() {
            "static" => {
                known(&[])?;
                Ok(Self::none())
            }
            "autoscale" => {
                known(&["min", "max"])?;
                Ok(Self::autoscaled(get_count("min", 1)?, get_count("max", 12)?))
            }
            "autoscale-pred" => {
                known(&["min", "max"])?;
                Ok(Self::autoscaled_predictive(get_count("min", 1)?, get_count("max", 12)?))
            }
            "drain-storm" => {
                known(&["start", "period", "drains"])?;
                Ok(Self::drain_storm(
                    get("start", 300.0),
                    get("period", 300.0),
                    get_count("drains", 3)?,
                ))
            }
            "crash-storm" => {
                known(&["start", "period", "crashes"])?;
                Ok(Self::crash_storm(
                    get("start", 300.0),
                    get("period", 300.0),
                    get_count("crashes", 2)?,
                ))
            }
            other => anyhow::bail!(
                "unknown churn profile '{other}' \
                 (static|autoscale|autoscale-pred|drain-storm|crash-storm)"
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_valid_trace() {
        let evs = parse(
            r#"{"cluster_events":[
                {"at":0,"kind":"join","pool":"burst","count":2},
                {"at":600,"kind":"drain","node":"node-3"},
                {"at":900,"kind":"crash"}
            ]}"#,
        )
        .unwrap();
        assert_eq!(evs.len(), 3);
        assert_eq!(
            evs[0].kind,
            ClusterEventKind::Join { pool: "burst".into(), count: 2 }
        );
        assert_eq!(evs[1].kind, ClusterEventKind::Drain { node: Some("node-3".into()) });
        assert_eq!(evs[2].kind, ClusterEventKind::Crash { node: None });
    }

    #[test]
    fn rejects_malformed_traces() {
        assert!(parse(r#"{}"#).is_err());
        assert!(parse(r#"{"cluster_events":[]}"#).is_err());
        assert!(parse(r#"{"cluster_events":[{"at":-1,"kind":"drain"}]}"#).is_err());
        assert!(parse(r#"{"cluster_events":[{"at":1,"kind":"flood"}]}"#).is_err());
        assert!(parse(r#"{"cluster_events":[{"kind":"drain"}]}"#).is_err());
        // Out of order.
        assert!(parse(
            r#"{"cluster_events":[{"at":10,"kind":"drain"},{"at":5,"kind":"drain"}]}"#
        )
        .is_err());
        // Zero-count join.
        assert!(parse(r#"{"cluster_events":[{"at":0,"kind":"join","count":0}]}"#).is_err());
        // Strict keys: a misspelled 'node' must not silently fall back
        // to engine-picked victims.
        assert!(parse(r#"{"cluster_events":[{"at":1,"kind":"drain","Node":"node-3"}]}"#).is_err());
        assert!(parse(r#"{"cluster_events":[{"at":1,"kind":"drain","node":3}]}"#).is_err());
        assert!(parse(r#"{"cluster_events":[{"at":1,"kind":"join","node":"x"}]}"#).is_err());
        // Non-integer / non-numeric counts.
        assert!(parse(r#"{"cluster_events":[{"at":1,"kind":"join","count":2.5}]}"#).is_err());
        assert!(parse(r#"{"cluster_events":[{"at":1,"kind":"join","count":"3"}]}"#).is_err());
    }

    #[test]
    fn rejects_non_finite_times() {
        // 1e999 overflows f64 parsing to +inf; the harness must refuse it
        // (same edge the workload trace parser guards).
        assert!(parse(r#"{"cluster_events":[{"at":1e999,"kind":"drain"}]}"#).is_err());
        assert!(parse(r#"{"cluster_events":[{"at":-1e999,"kind":"drain"}]}"#).is_err());
    }

    #[test]
    fn trace_roundtrips() {
        let evs = vec![
            ClusterEvent { at: 0.0, kind: ClusterEventKind::Join { pool: "x".into(), count: 3 } },
            ClusterEvent { at: 120.5, kind: ClusterEventKind::Drain { node: None } },
            ClusterEvent {
                at: 240.25,
                kind: ClusterEventKind::Crash { node: Some("x-1".into()) },
            },
        ];
        assert_eq!(parse(&to_json(&evs)).unwrap(), evs);
    }

    #[test]
    fn autoscaler_validation_and_json() {
        assert!(AutoscalerConfig::bounded(4, 2).validate().is_err());
        assert!(AutoscalerConfig::bounded(2, 8).validate().is_ok());
        let j = Json::parse(r#"{"min_nodes":2,"max_nodes":9,"provision_s":15}"#).unwrap();
        let cfg = AutoscalerConfig::from_json(&j).unwrap();
        assert_eq!((cfg.min_nodes, cfg.max_nodes), (2, 9));
        assert_eq!(cfg.provision_s, 15.0);
        assert_eq!(cfg.mode, AutoscalerMode::Reactive);
        // Round-trip through to_json.
        let again = AutoscalerConfig::from_json(&cfg.to_json()).unwrap();
        assert_eq!(again, cfg);
        assert!(AutoscalerConfig::from_json(&Json::parse(r#"{"nope":1}"#).unwrap()).is_err());
        // Predictive mode parses and round-trips.
        let j = Json::parse(r#"{"min_nodes":2,"max_nodes":9,"mode":"predictive"}"#).unwrap();
        let cfg = AutoscalerConfig::from_json(&j).unwrap();
        assert_eq!(cfg.mode, AutoscalerMode::Predictive);
        assert_eq!(AutoscalerConfig::from_json(&cfg.to_json()).unwrap(), cfg);
        assert!(AutoscalerConfig::from_json(
            &Json::parse(r#"{"mode":"clairvoyant"}"#).unwrap()
        )
        .is_err());
    }

    #[test]
    fn churn_profiles_parse() {
        assert_eq!(ChurnProfile::parse("static").unwrap(), ChurnProfile::none());
        let a = ChurnProfile::parse("autoscale:min=4,max=10").unwrap();
        assert_eq!(a.autoscaler.as_ref().unwrap().min_nodes, 4);
        assert_eq!(a.label, "autoscale[4,10]");
        let d = ChurnProfile::parse("drain-storm:start=100,period=50,drains=4").unwrap();
        assert_eq!(d.events.len(), 4);
        assert_eq!(d.events[3].at, 250.0);
        // Labels carry every parameter: same-size storms with different
        // timing are distinct axis values.
        assert_eq!(d.label, "drain-storm[4@100/50]");
        assert_ne!(
            d.label,
            ChurnProfile::parse("drain-storm:start=500,period=50,drains=4").unwrap().label
        );
        let p = ChurnProfile::parse("autoscale-pred:min=4,max=10").unwrap();
        assert_eq!(p.label, "autoscale-pred[4,10]");
        assert_eq!(p.autoscaler.as_ref().unwrap().mode, AutoscalerMode::Predictive);
        assert!(ChurnProfile::parse("tsunami").is_err());
        assert!(ChurnProfile::parse("autoscale:depth=3").is_err());
        // Negative/fractional numerics must not saturate or truncate.
        assert!(ChurnProfile::parse("drain-storm:drains=-1").is_err());
        assert!(ChurnProfile::parse("drain-storm:drains=2.5").is_err());
        assert!(ChurnProfile::parse("autoscale:min=-5").is_err());
    }

    #[test]
    fn drain_storm_events_are_ordered() {
        let p = ChurnProfile::drain_storm(300.0, 300.0, 3);
        let times: Vec<f64> = p.events.iter().map(|e| e.at).collect();
        assert_eq!(times, vec![300.0, 600.0, 900.0]);
        assert!(p.events.iter().all(|e| matches!(e.kind, ClusterEventKind::Drain { node: None })));
    }
}
