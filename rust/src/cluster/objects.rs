//! Typed API objects: nodes and pods.

use crate::simcore::SimTime;

/// A cluster worker node (a VM in the paper's testbed).
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    /// Stable address used as the ResidualMap key (Algorithm 2 line 22).
    pub ip: String,
    /// Allocatable CPU in milli-cores.
    pub allocatable_cpu: i64,
    /// Allocatable memory in Mi.
    pub allocatable_mem: i64,
}

impl Node {
    pub fn new(idx: usize, cpu_milli: i64, mem_mi: i64) -> Node {
        Node {
            name: format!("node-{idx}"),
            ip: format!("10.0.0.{}", idx + 1),
            allocatable_cpu: cpu_milli,
            allocatable_mem: mem_mi,
        }
    }
}

/// Pod lifecycle phase. `OOMKilled` is modeled as a phase (the paper
/// treats it alongside Succeeded/Failed for the Task Container Cleaner).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PodPhase {
    Pending,
    Running,
    Succeeded,
    Failed,
    OomKilled,
}

impl PodPhase {
    /// Phases whose resource requests still count against the node
    /// (Algorithm 2 line 8 sums Running and Pending pods).
    pub fn holds_resources(&self) -> bool {
        matches!(self, PodPhase::Pending | PodPhase::Running)
    }

    /// Phases the Task Container Cleaner deletes.
    pub fn cleanable(&self) -> bool {
        matches!(self, PodPhase::Succeeded | PodPhase::Failed | PodPhase::OomKilled)
    }

    pub fn name(&self) -> &'static str {
        match self {
            PodPhase::Pending => "Pending",
            PodPhase::Running => "Running",
            PodPhase::Succeeded => "Succeeded",
            PodPhase::Failed => "Failed",
            PodPhase::OomKilled => "OOMKilled",
        }
    }
}

/// A task pod. Requests == limits (Guaranteed QoS, §6.1.3).
#[derive(Debug, Clone)]
pub struct Pod {
    pub uid: u64,
    pub name: String,
    /// Workflow namespace (one namespace per workflow instance).
    pub namespace: String,
    /// Task id this pod executes (key into the state store).
    pub task_id: String,
    pub phase: PodPhase,
    /// Node the scheduler bound this pod to (None while unschedulable).
    pub node: Option<String>,
    /// Allocated CPU request, milli-cores (what ARAS decided).
    pub request_cpu: i64,
    /// Allocated memory request, Mi.
    pub request_mem: i64,
    /// Minimum memory the payload actually needs (Stress allocation).
    pub min_mem: i64,
    /// Predefined run duration (seconds).
    pub duration: f64,
    pub created_at: SimTime,
    pub started_at: Option<SimTime>,
    pub finished_at: Option<SimTime>,
}

impl Pod {
    /// Whether the allocation is sufficient to avoid an OOM kill:
    /// the paper's §6.2.2 criterion `allocated_mem >= min_mem + β`.
    pub fn mem_sufficient(&self, beta_mi: f64) -> bool {
        (self.request_mem as f64) >= self.min_mem as f64 + beta_mi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_resource_accounting() {
        assert!(PodPhase::Pending.holds_resources());
        assert!(PodPhase::Running.holds_resources());
        assert!(!PodPhase::Succeeded.holds_resources());
        assert!(!PodPhase::OomKilled.holds_resources());
    }

    #[test]
    fn cleanable_phases() {
        assert!(PodPhase::Succeeded.cleanable());
        assert!(PodPhase::Failed.cleanable());
        assert!(PodPhase::OomKilled.cleanable());
        assert!(!PodPhase::Running.cleanable());
    }

    #[test]
    fn mem_sufficiency_uses_beta() {
        let pod = Pod {
            uid: 1,
            name: "p".into(),
            namespace: "wf-1".into(),
            task_id: "t".into(),
            phase: PodPhase::Pending,
            node: None,
            request_cpu: 1000,
            request_mem: 2010,
            min_mem: 2000,
            duration: 10.0,
            created_at: 0.0,
            started_at: None,
            finished_at: None,
        };
        assert!(!pod.mem_sufficient(20.0)); // 2010 < 2000+20
        assert!(pod.mem_sufficient(10.0)); // 2010 >= 2010
    }

    #[test]
    fn node_ips_unique() {
        let a = Node::new(0, 8000, 16384);
        let b = Node::new(1, 8000, 16384);
        assert_ne!(a.ip, b.ip);
    }
}
