//! Typed API objects: nodes and pods.

use crate::simcore::SimTime;

/// A cluster worker node (a VM in the paper's testbed).
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    /// Stable address used as the ResidualMap key (Algorithm 2 line 22).
    pub ip: String,
    /// Allocatable CPU in milli-cores.
    pub allocatable_cpu: i64,
    /// Allocatable memory in Mi.
    pub allocatable_mem: i64,
    /// Node-pool label this node belongs to (heterogeneous clusters run
    /// several pools with different shapes; the default pool is "node").
    pub pool: String,
    /// False while the node is cordoned (draining): the scheduler must
    /// not bind new pods, and Resource Discovery excludes its residuals.
    pub schedulable: bool,
}

impl Node {
    /// A node of the default pool — name `node-{idx}`, legacy IP scheme.
    pub fn new(idx: usize, cpu_milli: i64, mem_mi: i64) -> Node {
        Node::labeled("node", idx, idx, cpu_milli, mem_mi)
    }

    /// A node of pool `pool`, the `idx`-th of that pool, with a
    /// cluster-wide `ordinal` that makes the IP unique across pools.
    /// For the single default pool `ordinal == idx` and the IP matches
    /// the pre-pool scheme (`10.0.0.{idx+1}`).
    pub fn labeled(pool: &str, idx: usize, ordinal: usize, cpu_milli: i64, mem_mi: i64) -> Node {
        Node {
            name: format!("{pool}-{idx}"),
            ip: format!("10.0.{}.{}", ordinal / 250, ordinal % 250 + 1),
            allocatable_cpu: cpu_milli,
            allocatable_mem: mem_mi,
            pool: pool.to_string(),
            schedulable: true,
        }
    }
}

/// Pod lifecycle phase. `OOMKilled` is modeled as a phase (the paper
/// treats it alongside Succeeded/Failed for the Task Container Cleaner).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PodPhase {
    Pending,
    Running,
    Succeeded,
    Failed,
    OomKilled,
}

impl PodPhase {
    /// Phases whose resource requests still count against the node
    /// (Algorithm 2 line 8 sums Running and Pending pods).
    pub fn holds_resources(&self) -> bool {
        matches!(self, PodPhase::Pending | PodPhase::Running)
    }

    /// Phases the Task Container Cleaner deletes.
    pub fn cleanable(&self) -> bool {
        matches!(self, PodPhase::Succeeded | PodPhase::Failed | PodPhase::OomKilled)
    }

    pub fn name(&self) -> &'static str {
        match self {
            PodPhase::Pending => "Pending",
            PodPhase::Running => "Running",
            PodPhase::Succeeded => "Succeeded",
            PodPhase::Failed => "Failed",
            PodPhase::OomKilled => "OOMKilled",
        }
    }
}

/// A task pod. Requests == limits (Guaranteed QoS, §6.1.3).
#[derive(Debug, Clone)]
pub struct Pod {
    pub uid: u64,
    pub name: String,
    /// Workflow namespace (one namespace per workflow instance).
    pub namespace: String,
    /// Task id this pod executes (key into the state store).
    pub task_id: String,
    pub phase: PodPhase,
    /// Node the scheduler bound this pod to (None while unschedulable).
    pub node: Option<String>,
    /// Allocated CPU request, milli-cores (what ARAS decided).
    pub request_cpu: i64,
    /// Allocated memory request, Mi.
    pub request_mem: i64,
    /// Minimum memory the payload actually needs (Stress allocation).
    pub min_mem: i64,
    /// Predefined run duration (seconds).
    pub duration: f64,
    pub created_at: SimTime,
    pub started_at: Option<SimTime>,
    pub finished_at: Option<SimTime>,
}

impl Pod {
    /// Whether the allocation is sufficient to avoid an OOM kill:
    /// the paper's §6.2.2 criterion `allocated_mem >= min_mem + β`.
    pub fn mem_sufficient(&self, beta_mi: f64) -> bool {
        (self.request_mem as f64) >= self.min_mem as f64 + beta_mi
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_resource_accounting() {
        assert!(PodPhase::Pending.holds_resources());
        assert!(PodPhase::Running.holds_resources());
        assert!(!PodPhase::Succeeded.holds_resources());
        assert!(!PodPhase::OomKilled.holds_resources());
    }

    #[test]
    fn cleanable_phases() {
        assert!(PodPhase::Succeeded.cleanable());
        assert!(PodPhase::Failed.cleanable());
        assert!(PodPhase::OomKilled.cleanable());
        assert!(!PodPhase::Running.cleanable());
    }

    #[test]
    fn mem_sufficiency_uses_beta() {
        let pod = Pod {
            uid: 1,
            name: "p".into(),
            namespace: "wf-1".into(),
            task_id: "t".into(),
            phase: PodPhase::Pending,
            node: None,
            request_cpu: 1000,
            request_mem: 2010,
            min_mem: 2000,
            duration: 10.0,
            created_at: 0.0,
            started_at: None,
            finished_at: None,
        };
        assert!(!pod.mem_sufficient(20.0)); // 2010 < 2000+20
        assert!(pod.mem_sufficient(10.0)); // 2010 >= 2010
    }

    #[test]
    fn node_ips_unique() {
        let a = Node::new(0, 8000, 16384);
        let b = Node::new(1, 8000, 16384);
        assert_ne!(a.ip, b.ip);
    }

    #[test]
    fn default_pool_matches_legacy_naming() {
        let n = Node::new(3, 8000, 16384);
        assert_eq!(n.name, "node-3");
        assert_eq!(n.ip, "10.0.0.4");
        assert_eq!(n.pool, "node");
        assert!(n.schedulable);
    }

    #[test]
    fn pool_nodes_get_unique_ips_across_pools() {
        let a = Node::labeled("big", 0, 0, 16000, 32768);
        let b = Node::labeled("small", 0, 1, 4000, 8192);
        assert_eq!(a.name, "big-0");
        assert_eq!(b.name, "small-0");
        assert_ne!(a.ip, b.ip);
        // Ordinals past 249 roll into the next /24.
        let far = Node::labeled("node", 260, 260, 8000, 16384);
        assert_eq!(far.ip, "10.0.1.11");
    }
}
