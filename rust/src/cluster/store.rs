//! The kube-apiserver equivalent: versioned object store + List-Watch.
//!
//! Every mutation bumps a resource version and appends a [`WatchEvent`]
//! that informers drain ("List-Watch mechanism" — the paper's State
//! Tracker and Informer both hang off this stream). Access counts are
//! tracked because the paper explicitly criticizes monitoring stacks that
//! hammer kube-apiserver; our Informer's cache keeps direct store reads
//! near zero on the hot path (asserted in tests).

use std::cell::Cell;
use std::collections::BTreeMap;

use super::objects::{Node, Pod, PodPhase};
use crate::simcore::SimTime;

/// A watch stream event (the List-Watch payloads informers consume).
#[derive(Debug, Clone)]
pub enum WatchEvent {
    PodAdded(u64),
    PodModified(u64),
    PodDeleted(u64),
    NodeAdded(String),
    /// Node spec changed (cordon/uncordon — the drain path's first step).
    NodeModified(String),
    /// Node left the cluster (drain completed, or crash).
    NodeDeleted(String),
    NamespaceAdded(String),
    NamespaceDeleted(String),
}

/// Versioned object store.
#[derive(Debug, Default)]
pub struct ObjectStore {
    nodes: BTreeMap<String, Node>,
    pods: BTreeMap<u64, Pod>,
    namespaces: std::collections::BTreeSet<String>,
    resource_version: u64,
    watch_log: Vec<(u64, WatchEvent)>,
    /// Apiserver read round-trips: LIST calls and watch drains (a `Cell`
    /// so read paths stay `&self`). The paper criticizes monitoring
    /// stacks that hammer kube-apiserver; this is the pressure metric
    /// the engine reports — exactly one watch drain per discovery
    /// snapshot, one snapshot per queue-serve cycle (asserted in
    /// `rust/tests/policy_v2.rs`).
    list_calls: Cell<u64>,
}

impl ObjectStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn bump(&mut self, ev: WatchEvent) {
        self.resource_version += 1;
        self.watch_log.push((self.resource_version, ev));
    }

    pub fn resource_version(&self) -> u64 {
        self.resource_version
    }

    // ----------------------------------------------------------- nodes

    pub fn add_node(&mut self, node: Node) {
        let name = node.name.clone();
        self.nodes.insert(name.clone(), node);
        self.bump(WatchEvent::NodeAdded(name));
    }

    pub fn node(&self, name: &str) -> Option<&Node> {
        self.nodes.get(name)
    }

    /// Cordon (`schedulable = false`) or uncordon a node. Returns false
    /// if the node is unknown or already in the requested state.
    pub fn set_schedulable(&mut self, name: &str, schedulable: bool) -> bool {
        let Some(node) = self.nodes.get_mut(name) else { return false };
        if node.schedulable == schedulable {
            return false;
        }
        node.schedulable = schedulable;
        self.bump(WatchEvent::NodeModified(name.to_string()));
        true
    }

    /// Shift a node's allocatable capacity by a delta (chaos hogs: a
    /// noisy neighbor consuming resources outside the engine's control
    /// shrinks what kubelet reports as allocatable; the hog's end
    /// restores it). Residuals may go negative while a hog holds more
    /// than the node had free — correct: the node is over-committed and
    /// must not admit new pods. Returns false if the node is unknown.
    pub fn adjust_allocatable(&mut self, name: &str, d_cpu: i64, d_mem: i64) -> bool {
        let Some(node) = self.nodes.get_mut(name) else { return false };
        node.allocatable_cpu += d_cpu;
        node.allocatable_mem += d_mem;
        self.bump(WatchEvent::NodeModified(name.to_string()));
        true
    }

    /// Remove a node from the cluster (drain completion or crash). Pods
    /// still referencing the node keep their binding string — exactly
    /// like K8s pods orphaned by a deleted node — and are the engine's
    /// responsibility to evict.
    pub fn remove_node(&mut self, name: &str) -> Option<Node> {
        let node = self.nodes.remove(name)?;
        self.bump(WatchEvent::NodeDeleted(name.to_string()));
        Some(node)
    }

    /// Full node list (a LIST call — counted).
    pub fn list_nodes(&self) -> Vec<Node> {
        self.list_calls.set(self.list_calls.get() + 1);
        self.nodes.values().cloned().collect()
    }

    /// Node names in stable (BTreeMap) order — the scheduler's working
    /// set. Not counted as a LIST: kube-scheduler keeps its own informer
    /// cache, which this models.
    pub fn node_names(&self) -> Vec<String> {
        self.nodes.keys().cloned().collect()
    }

    /// Borrow-iterate the nodes (metrics denominators, autoscaler scans).
    pub fn nodes_iter(&self) -> impl Iterator<Item = &Node> {
        self.nodes.values()
    }

    /// Nodes currently accepting pods.
    pub fn schedulable_node_count(&self) -> usize {
        self.nodes.values().filter(|n| n.schedulable).count()
    }

    pub fn node_count(&self) -> usize {
        self.nodes.len()
    }

    // ------------------------------------------------------ namespaces

    /// Create a workflow namespace (idempotent).
    pub fn create_namespace(&mut self, name: &str) -> bool {
        if self.namespaces.insert(name.to_string()) {
            self.bump(WatchEvent::NamespaceAdded(name.to_string()));
            true
        } else {
            false
        }
    }

    /// Delete a namespace; refused while it still hosts pods (K8s
    /// semantics: namespace deletion drains its objects first — the
    /// Task Container Cleaner only deletes namespaces "without
    /// uncompleted task pods").
    pub fn delete_namespace(&mut self, name: &str) -> bool {
        if self.pods.values().any(|p| p.namespace == name) {
            return false;
        }
        if self.namespaces.remove(name) {
            self.bump(WatchEvent::NamespaceDeleted(name.to_string()));
            true
        } else {
            false
        }
    }

    pub fn namespace_exists(&self, name: &str) -> bool {
        self.namespaces.contains(name)
    }

    pub fn namespace_count(&self) -> usize {
        self.namespaces.len()
    }

    // ------------------------------------------------------------ pods

    pub fn create_pod(&mut self, pod: Pod) {
        let uid = pod.uid;
        debug_assert!(!self.pods.contains_key(&uid), "duplicate pod uid");
        self.pods.insert(uid, pod);
        self.bump(WatchEvent::PodAdded(uid));
    }

    pub fn pod(&self, uid: u64) -> Option<&Pod> {
        self.pods.get(&uid)
    }

    /// Bind a pending pod to a node (scheduler's write).
    pub fn bind_pod(&mut self, uid: u64, node: &str) -> bool {
        let Some(pod) = self.pods.get_mut(&uid) else { return false };
        if pod.phase != PodPhase::Pending || pod.node.is_some() {
            return false;
        }
        pod.node = Some(node.to_string());
        self.bump(WatchEvent::PodModified(uid));
        true
    }

    /// Legal phase transition; returns false on illegal moves.
    pub fn set_pod_phase(&mut self, uid: u64, phase: PodPhase, now: SimTime) -> bool {
        let Some(pod) = self.pods.get_mut(&uid) else { return false };
        let ok = matches!(
            (pod.phase, phase),
            (PodPhase::Pending, PodPhase::Running)
                | (PodPhase::Pending, PodPhase::Failed)
                | (PodPhase::Running, PodPhase::Succeeded)
                | (PodPhase::Running, PodPhase::Failed)
                | (PodPhase::Running, PodPhase::OomKilled)
        );
        if !ok {
            return false;
        }
        match phase {
            PodPhase::Running => pod.started_at = Some(now),
            PodPhase::Succeeded | PodPhase::Failed | PodPhase::OomKilled => {
                pod.finished_at = Some(now)
            }
            _ => {}
        }
        pod.phase = phase;
        self.bump(WatchEvent::PodModified(uid));
        true
    }

    pub fn delete_pod(&mut self, uid: u64) -> Option<Pod> {
        let pod = self.pods.remove(&uid)?;
        self.bump(WatchEvent::PodDeleted(uid));
        Some(pod)
    }

    /// Full pod list (a LIST call — counted).
    pub fn list_pods(&self) -> Vec<Pod> {
        self.list_calls.set(self.list_calls.get() + 1);
        self.pods.values().cloned().collect()
    }

    pub fn pods_iter(&self) -> impl Iterator<Item = &Pod> {
        self.pods.values()
    }

    pub fn pod_count(&self) -> usize {
        self.pods.len()
    }

    pub fn list_call_count(&self) -> u64 {
        self.list_calls.get()
    }

    // ------------------------------------------------------ watch feed

    /// Events after `since_version` (informer resync path). Each drain
    /// is one apiserver read round-trip — counted like a LIST call.
    pub fn watch_since(&self, since_version: u64) -> &[(u64, WatchEvent)] {
        self.list_calls.set(self.list_calls.get() + 1);
        let start = self.watch_log.partition_point(|(v, _)| *v <= since_version);
        &self.watch_log[start..]
    }

    /// Residual (allocatable - requested-by-live-pods) per node — the
    /// ground truth Algorithm 2 recomputes through the informer cache.
    pub fn residual_of(&self, node_name: &str) -> Option<(i64, i64)> {
        let node = self.nodes.get(node_name)?;
        let (mut cpu, mut mem) = (node.allocatable_cpu, node.allocatable_mem);
        for pod in self.pods.values() {
            if pod.phase.holds_resources() && pod.node.as_deref() == Some(node_name) {
                cpu -= pod.request_cpu;
                mem -= pod.request_mem;
            }
        }
        Some((cpu, mem))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pod(uid: u64) -> Pod {
        Pod {
            uid,
            name: format!("p{uid}"),
            namespace: "wf-1".into(),
            task_id: format!("t{uid}"),
            phase: PodPhase::Pending,
            node: None,
            request_cpu: 1000,
            request_mem: 2000,
            min_mem: 1000,
            duration: 10.0,
            created_at: 0.0,
            started_at: None,
            finished_at: None,
        }
    }

    #[test]
    fn watch_log_grows_with_mutations() {
        let mut s = ObjectStore::new();
        s.add_node(Node::new(0, 8000, 16384));
        s.create_pod(pod(1));
        s.bind_pod(1, "node-0");
        assert_eq!(s.watch_since(0).len(), 3);
        assert_eq!(s.watch_since(2).len(), 1);
    }

    #[test]
    fn illegal_transitions_rejected() {
        let mut s = ObjectStore::new();
        s.create_pod(pod(1));
        assert!(!s.set_pod_phase(1, PodPhase::Succeeded, 1.0)); // pending->succeeded
        assert!(s.set_pod_phase(1, PodPhase::Running, 1.0));
        assert!(!s.set_pod_phase(1, PodPhase::Running, 2.0)); // running->running
        assert!(s.set_pod_phase(1, PodPhase::OomKilled, 3.0));
        assert!(!s.set_pod_phase(1, PodPhase::Succeeded, 4.0)); // terminal
    }

    #[test]
    fn bind_requires_pending_unbound() {
        let mut s = ObjectStore::new();
        s.create_pod(pod(1));
        assert!(s.bind_pod(1, "node-0"));
        assert!(!s.bind_pod(1, "node-1")); // already bound
    }

    #[test]
    fn residual_counts_pending_and_running_only() {
        let mut s = ObjectStore::new();
        s.add_node(Node::new(0, 8000, 16384));
        let mut p1 = pod(1);
        p1.node = Some("node-0".into());
        s.create_pod(p1);
        assert_eq!(s.residual_of("node-0"), Some((7000, 14384)));
        s.set_pod_phase(1, PodPhase::Running, 1.0);
        assert_eq!(s.residual_of("node-0"), Some((7000, 14384)));
        s.set_pod_phase(1, PodPhase::Succeeded, 2.0);
        assert_eq!(s.residual_of("node-0"), Some((8000, 16384)));
    }

    #[test]
    fn namespace_lifecycle() {
        let mut s = ObjectStore::new();
        assert!(s.create_namespace("wf-1"));
        assert!(!s.create_namespace("wf-1")); // idempotent
        let mut p = pod(1);
        p.namespace = "wf-1".into();
        s.create_pod(p);
        assert!(!s.delete_namespace("wf-1")); // still hosts a pod
        s.delete_pod(1);
        assert!(s.delete_namespace("wf-1"));
        assert!(!s.namespace_exists("wf-1"));
        assert_eq!(s.namespace_count(), 0);
    }

    #[test]
    fn cordon_and_remove_emit_watch_events() {
        let mut s = ObjectStore::new();
        s.add_node(Node::new(0, 8000, 16384));
        let v0 = s.resource_version();
        assert!(s.set_schedulable("node-0", false));
        assert!(!s.set_schedulable("node-0", false)); // idempotent
        assert!(!s.node("node-0").unwrap().schedulable);
        assert!(s.remove_node("node-0").is_some());
        assert!(s.remove_node("node-0").is_none());
        assert_eq!(s.node_count(), 0);
        let kinds: Vec<&WatchEvent> = s.watch_since(v0).iter().map(|(_, e)| e).collect();
        assert!(matches!(kinds[0], WatchEvent::NodeModified(n) if n == "node-0"));
        assert!(matches!(kinds[1], WatchEvent::NodeDeleted(n) if n == "node-0"));
    }

    #[test]
    fn node_names_are_sorted_and_uncounted() {
        let mut s = ObjectStore::new();
        s.add_node(Node::new(1, 8000, 16384));
        s.add_node(Node::new(0, 8000, 16384));
        let before = s.list_call_count();
        assert_eq!(s.node_names(), vec!["node-0".to_string(), "node-1".to_string()]);
        assert_eq!(s.list_call_count(), before);
        s.set_schedulable("node-1", false);
        assert_eq!(s.schedulable_node_count(), 1);
    }

    #[test]
    fn adjust_allocatable_shifts_residuals_and_emits_watch_events() {
        let mut s = ObjectStore::new();
        s.add_node(Node::new(0, 8000, 16384));
        let v0 = s.resource_version();
        assert!(s.adjust_allocatable("node-0", -3000, -4096));
        assert_eq!(s.residual_of("node-0"), Some((5000, 12288)));
        // A hog bigger than the node's free capacity drives the residual
        // negative — the node is over-committed, not clamped.
        assert!(s.adjust_allocatable("node-0", -6000, 0));
        assert_eq!(s.residual_of("node-0"), Some((-1000, 12288)));
        assert!(s.adjust_allocatable("node-0", 9000, 4096));
        assert_eq!(s.residual_of("node-0"), Some((8000, 16384)));
        assert!(!s.adjust_allocatable("node-9", -1, 0));
        let kinds: Vec<&WatchEvent> = s.watch_since(v0).iter().map(|(_, e)| e).collect();
        assert_eq!(kinds.len(), 3);
        assert!(kinds.iter().all(|e| matches!(e, WatchEvent::NodeModified(n) if n == "node-0")));
    }

    #[test]
    fn removed_node_orphans_bound_pods() {
        let mut s = ObjectStore::new();
        s.add_node(Node::new(0, 8000, 16384));
        let mut p = pod(1);
        p.node = Some("node-0".into());
        s.create_pod(p);
        s.remove_node("node-0");
        // The pod keeps its stale binding; residuals of a gone node are None.
        assert_eq!(s.pod(1).unwrap().node.as_deref(), Some("node-0"));
        assert!(s.residual_of("node-0").is_none());
    }

    #[test]
    fn timestamps_recorded_on_transitions() {
        let mut s = ObjectStore::new();
        s.create_pod(pod(1));
        s.set_pod_phase(1, PodPhase::Running, 5.0);
        s.set_pod_phase(1, PodPhase::Succeeded, 17.5);
        let p = s.pod(1).unwrap();
        assert_eq!(p.started_at, Some(5.0));
        assert_eq!(p.finished_at, Some(17.5));
    }
}
