//! Decision-backend roster — the single wiring point between
//! [`crate::config::Backend`] and a live
//! [`DecisionBackend`](crate::resources::adaptive::DecisionBackend).
//!
//! Three backends implement the same decision mathematics (bit-identical
//! on integral inputs, enforced by `rust/tests/backend_parity.rs`):
//!
//! | name     | path                   | batching            | availability |
//! |----------|------------------------|---------------------|--------------|
//! | `scalar` | `resources/evaluator`  | per item            | always       |
//! | `native` | `runtime/native`       | `cap_batch` lanes   | always       |
//! | `pjrt`   | `runtime/pjrt`         | `cap_batch` lanes   | needs `artifacts/` + a real XLA binding |
//!
//! Selected with `--backend` on `run`/`campaign`/`daemon` or the config
//! JSON `"backend"` key; default `scalar`. Every ARAS-based policy
//! (`adaptive`, `rate-capped`, `predictive`) resolves its backend
//! through [`build`], so parameter semantics are identical across
//! backends.

use crate::config::Backend;
use crate::resources::adaptive::{DecisionBackend, ScalarBackend};

/// Instantiate the backend a config names. `pjrt` fails gracefully when
/// the runtime or artifacts are missing; `scalar` and `native` cannot
/// fail to load (native falls back to `model.py` capacities when no
/// `artifacts/manifest.json` exists).
pub fn build(backend: Backend) -> anyhow::Result<Box<dyn DecisionBackend>> {
    Ok(match backend {
        Backend::Scalar => Box::new(ScalarBackend),
        Backend::Native => Box::new(crate::runtime::NativeBackend::load_default()?),
        Backend::Pjrt => Box::new(crate::runtime::PjrtBackend::load_default()?),
    })
}

/// All selectable backends, in precedence-free roster order.
pub fn roster() -> [Backend; 3] {
    [Backend::Scalar, Backend::Native, Backend::Pjrt]
}

/// (name, summary, availability note) rows for `--list-backends`.
/// Availability is probed live: `pjrt` reports *why* it is unavailable
/// (stub runtime, missing artifacts) instead of a bare "no".
pub fn listing() -> Vec<(String, String, String)> {
    roster()
        .iter()
        .map(|&b| {
            let summary = match b {
                Backend::Scalar => {
                    "pure-Rust scalar evaluator (per-item; the reference path)".to_string()
                }
                Backend::Native => {
                    "native vectorized interpreter of the compiled decision graph \
                     (lane-batched decide_batch)"
                        .to_string()
                }
                Backend::Pjrt => {
                    "AOT-compiled XLA module via the PJRT CPU client (lane-batched)".to_string()
                }
            };
            let availability = match build(b) {
                Ok(built) => {
                    debug_assert_eq!(built.backend_name(), b.name());
                    "available".to_string()
                }
                Err(e) => format!("unavailable: {e}"),
            };
            (b.name().to_string(), summary, availability)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::adaptive::DecisionInputs;

    fn inputs() -> DecisionInputs {
        DecisionInputs {
            records: vec![(1.0, 500.0, 700.0), (30.0, 100.0, 100.0)],
            win_start: 0.0,
            win_end: 15.0,
            req_cpu: 2000.0,
            req_mem: 4000.0,
            node_res: vec![(8000.0, 16384.0); 6],
            alpha: 0.8,
        }
    }

    #[test]
    fn scalar_and_native_always_build_and_agree() {
        let mut scalar = build(Backend::Scalar).unwrap();
        let mut native = build(Backend::Native).unwrap();
        assert_eq!(scalar.backend_name(), "scalar");
        assert_eq!(native.backend_name(), "native");
        assert_eq!(scalar.decide(&inputs()), native.decide(&inputs()));
    }

    #[test]
    fn listing_has_all_roster_rows() {
        let rows = listing();
        let names: Vec<&str> = rows.iter().map(|(n, _, _)| n.as_str()).collect();
        assert_eq!(names, vec!["scalar", "native", "pjrt"]);
        assert!(rows[0].2 == "available" && rows[1].2 == "available");
        // pjrt may be available (real binding + artifacts) or carry an
        // actionable reason; either way the row exists and is non-empty.
        assert!(!rows[2].2.is_empty());
    }

    #[test]
    fn backend_parse_round_trips_names() {
        for b in roster() {
            assert_eq!(Backend::parse(b.name()).unwrap(), b);
        }
        assert_eq!(Backend::parse("interpreter").unwrap(), Backend::Native);
        assert!(Backend::parse("cuda").is_err());
    }
}
