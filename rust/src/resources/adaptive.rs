//! The ARAS driver — Algorithm 1 (AdaptiveResourceAllocationAlgorithm),
//! batched over a queue-serve cycle.
//!
//! For each task request in the cycle's batch:
//! 1. read the state store and aggregate the demand of every task record
//!    whose start time falls in the request's lifecycle window
//!    (lines 4–13 — skipped when the `lookahead` ablation is off);
//! 2. take the ResidualMap from the cycle's [`ClusterSnapshot`] and
//!    reduce it to the cluster aggregates (lines 15–23);
//! 3. run the Resource Evaluator (line 25) through the selected numeric
//!    backend — the scalar f32 path or the AOT-compiled PJRT module,
//!    which receives the whole batch at once ([`DecisionBackend::decide_batch`]).
//!
//! **Batch semantics.** The batch is decided as if served one request at
//! a time against a store the engine refreshes between decisions (the
//! v1 contract): for request *i*, batch members *j < i* are seen at
//! their refreshed positions (`t_start = win_start`, i.e. "this task is
//! being admitted now"), members *j > i* at their stale stored
//! estimates, and the request's own record is excluded. The overlay in
//! [`AdaptivePolicy::gather_batch_inputs`] reproduces this without
//! store mutation, so batched and sequential plans are bit-identical —
//! property-checked in `rust/tests/policy_v2.rs`.
//!
//! The min-resource retry condition (line 27) is enforced by the engine
//! (it owns time and the retry queue); `Decision::meets_minimum` is the
//! predicate it uses.

use super::{ClusterSnapshot, Decision, Policy, TaskRequest};
use crate::statestore::StateStore;
use super::evaluator::{alloc_eval, window_demand, ClusterAggregates};

/// Inputs handed to a decision backend (already reduced to f32 arrays).
#[derive(Debug, Clone)]
pub struct DecisionInputs {
    /// Live task records: (t_start, cpu, mem).
    pub records: Vec<(f32, f32, f32)>,
    pub win_start: f32,
    pub win_end: f32,
    pub req_cpu: f32,
    pub req_mem: f32,
    /// Per-node residuals: (cpu, mem).
    pub node_res: Vec<(f32, f32)>,
    pub alpha: f32,
}

/// Raw backend output (pre-rounding).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DecisionOutputs {
    pub alloc_cpu: f32,
    pub alloc_mem: f32,
    pub request_cpu: f32,
    pub request_mem: f32,
}

/// Numeric backend for the fused decision (scalar twin vs PJRT module).
pub trait DecisionBackend {
    fn backend_name(&self) -> &'static str;
    fn decide(&mut self, inputs: &DecisionInputs) -> DecisionOutputs;

    /// Decide a whole queue-serve cycle. The default maps [`Self::decide`]
    /// over the batch; batched implementors (PJRT) override this to fill
    /// the artifact's batch lanes and amortize the device round-trip.
    fn decide_batch(&mut self, inputs: &[DecisionInputs]) -> Vec<DecisionOutputs> {
        inputs.iter().map(|i| self.decide(i)).collect()
    }
}

/// Pure-Rust scalar backend (always available).
#[derive(Debug, Default, Clone)]
pub struct ScalarBackend;

impl DecisionBackend for ScalarBackend {
    fn backend_name(&self) -> &'static str {
        "scalar"
    }

    fn decide(&mut self, inputs: &DecisionInputs) -> DecisionOutputs {
        let (request_cpu, request_mem) = window_demand(
            inputs.records.iter().copied(),
            inputs.win_start,
            inputs.win_end,
            inputs.req_cpu,
            inputs.req_mem,
        );
        // Node aggregation mirrors kernels' node_aggregate (argmax-CPU).
        let mut total_cpu = 0.0f32;
        let mut total_mem = 0.0f32;
        let mut remax_cpu = f32::NEG_INFINITY;
        let mut remax_mem = 0.0f32;
        for &(c, m) in &inputs.node_res {
            total_cpu += c;
            total_mem += m;
            if c > remax_cpu {
                remax_cpu = c;
                remax_mem = m;
            }
        }
        if inputs.node_res.is_empty() {
            remax_cpu = 0.0;
        }
        let agg = ClusterAggregates {
            total_res_cpu: total_cpu,
            total_res_mem: total_mem,
            remax_cpu,
            remax_mem,
            alpha: inputs.alpha,
        };
        let (alloc_cpu, alloc_mem) =
            alloc_eval(inputs.req_cpu, inputs.req_mem, request_cpu, request_mem, &agg);
        DecisionOutputs { alloc_cpu, alloc_mem, request_cpu, request_mem }
    }
}

/// The ARAS policy: Algorithm 1 over a pluggable backend.
pub struct AdaptivePolicy {
    backend: Box<dyn DecisionBackend>,
    alpha: f64,
    lookahead: bool,
    decisions: u64,
}

impl AdaptivePolicy {
    pub fn new(alpha: f64, lookahead: bool) -> Self {
        Self { backend: Box::new(ScalarBackend), alpha, lookahead, decisions: 0 }
    }

    /// Swap the numeric backend (e.g. for the PJRT path).
    pub fn with_backend(mut self, backend: Box<dyn DecisionBackend>) -> Self {
        self.backend = backend;
        self
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.backend_name()
    }

    pub fn decision_count(&self) -> u64 {
        self.decisions
    }

    /// Build per-request backend inputs for a whole cycle (Alg. 1 lines
    /// 4–13 + 15), applying the sequential-equivalence overlay: for
    /// request `i`, records of batch members `j < i` are substituted in
    /// place with their refreshed positions (`t_start = win_start_j`),
    /// members `j > i` keep their stale stored estimates, and request
    /// `i`'s own record is omitted — exactly the store states a
    /// one-request-at-a-time engine would have produced. Substitution
    /// (not append) keeps the record iteration order, so f32 summation
    /// order — and therefore every bit of the result — is unchanged.
    pub fn gather_batch_inputs(
        &self,
        batch: &[TaskRequest],
        snapshot: &ClusterSnapshot,
        store: &StateStore,
    ) -> Vec<DecisionInputs> {
        let node_res: Vec<(f32, f32)> = snapshot
            .residuals
            .entries
            .iter()
            .map(|e| (e.residual_cpu as f32, e.residual_mem as f32))
            .collect();
        // Base records in store order; each tagged with the batch member
        // that owns it (if any) so the per-request pass can substitute or
        // omit without re-scanning the store.
        let base: Vec<(Option<usize>, f32, f32, f32)> = if self.lookahead {
            store
                .pending_tasks()
                .map(|(id, r)| {
                    let member = batch.iter().position(|b| b.task_id == *id);
                    (member, r.t_start as f32, r.cpu as f32, r.mem as f32)
                })
                .collect()
        } else {
            Vec::new() // ablation A2: no future-task awareness
        };
        batch
            .iter()
            .enumerate()
            .map(|(i, req)| {
                let records: Vec<(f32, f32, f32)> = base
                    .iter()
                    .filter(|(member, ..)| *member != Some(i))
                    .map(|&(member, t_start, cpu, mem)| match member {
                        Some(j) if j < i => (batch[j].win_start as f32, cpu, mem),
                        _ => (t_start, cpu, mem),
                    })
                    .collect();
                DecisionInputs {
                    records,
                    win_start: req.win_start as f32,
                    win_end: req.win_end as f32,
                    req_cpu: req.req_cpu as f32,
                    req_mem: req.req_mem as f32,
                    node_res: node_res.clone(),
                    alpha: self.alpha as f32,
                }
            })
            .collect()
    }
}

impl AdaptivePolicy {
    /// Run pre-gathered inputs through the numeric backend and round to
    /// kubelet-style integral quotas. [`Policy::plan`] is exactly
    /// gather + decide; [`super::PredictivePolicy`] augments the
    /// gathered inputs between the two steps.
    pub fn decide_inputs(&mut self, inputs: &[DecisionInputs]) -> Vec<Decision> {
        self.decisions += inputs.len() as u64;
        self.backend
            .decide_batch(inputs)
            .into_iter()
            .map(|out| Decision {
                cpu_milli: out.alloc_cpu.floor() as i64,
                mem_mi: out.alloc_mem.floor() as i64,
                request_cpu: out.request_cpu as f64,
                request_mem: out.request_mem as f64,
            })
            .collect()
    }
}

impl Policy for AdaptivePolicy {
    fn name(&self) -> &str {
        "adaptive"
    }

    fn plan(
        &mut self,
        batch: &[TaskRequest],
        snapshot: &ClusterSnapshot,
        store: &StateStore,
    ) -> Vec<Decision> {
        let inputs = self.gather_batch_inputs(batch, snapshot, store);
        self.decide_inputs(&inputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::discovery::{NodeResidual, ResidualMap};
    use crate::statestore::TaskRecord;

    fn snapshot(nodes: &[(f64, f64)]) -> ClusterSnapshot {
        ClusterSnapshot::from_residuals(ResidualMap {
            entries: nodes
                .iter()
                .enumerate()
                .map(|(i, &(c, m))| NodeResidual {
                    ip: format!("10.0.0.{i}"),
                    name: format!("node-{i}"),
                    pool: "node".into(),
                    residual_cpu: c,
                    residual_mem: m,
                })
                .collect(),
        })
    }

    fn store_with(records: &[(f64, f64, f64)]) -> StateStore {
        let mut s = StateStore::new();
        for (i, &(t0, cpu, mem)) in records.iter().enumerate() {
            s.put_task(
                format!("w1-{i}"),
                TaskRecord {
                    workflow_uid: 1,
                    t_start: t0,
                    duration: 15.0,
                    t_end: t0 + 15.0,
                    cpu,
                    mem,
                    flag: false,
                    estimated: true,
                },
            );
        }
        s
    }

    fn req(win: (f64, f64)) -> TaskRequest {
        TaskRequest {
            task_id: "req-task".into(),
            req_cpu: 2000.0,
            req_mem: 4000.0,
            min_cpu: 200.0,
            min_mem: 1000.0,
            win_start: win.0,
            win_end: win.1,
        }
    }

    fn decide_one(
        p: &mut AdaptivePolicy,
        req: &TaskRequest,
        snap: &ClusterSnapshot,
        store: &StateStore,
    ) -> Decision {
        p.plan(std::slice::from_ref(req), snap, store)[0]
    }

    #[test]
    fn uncontended_request_granted_in_full() {
        let mut p = AdaptivePolicy::new(0.8, true);
        let d = decide_one(
            &mut p,
            &req((0.0, 15.0)),
            &snapshot(&[(8000.0, 16384.0); 6]),
            &store_with(&[]),
        );
        assert_eq!(d.cpu_milli, 2000);
        assert_eq!(d.mem_mi, 4000);
    }

    #[test]
    fn contended_request_scaled_down() {
        // 30 concurrent tasks of 2000m/4000Mi inside the window on a
        // 6-node cluster => demand 62000m vs residual 48000m.
        let recs: Vec<(f64, f64, f64)> = (0..30).map(|i| (i as f64 * 0.1, 2000.0, 4000.0)).collect();
        let mut p = AdaptivePolicy::new(0.8, true);
        let d = decide_one(
            &mut p,
            &req((0.0, 15.0)),
            &snapshot(&[(8000.0, 16384.0); 6]),
            &store_with(&recs),
        );
        assert_eq!(d.request_cpu, 62000.0);
        assert!(d.cpu_milli < 2000, "scaled: {}", d.cpu_milli);
        // cut = 2000 * 48000/62000 = 1548.38 -> floor
        assert_eq!(d.cpu_milli, 1548);
        assert!(d.mem_mi < 4000);
    }

    #[test]
    fn lookahead_off_ignores_records() {
        let recs: Vec<(f64, f64, f64)> = (0..30).map(|_| (1.0, 2000.0, 4000.0)).collect();
        let mut p = AdaptivePolicy::new(0.8, false);
        let d = decide_one(
            &mut p,
            &req((0.0, 15.0)),
            &snapshot(&[(8000.0, 16384.0); 6]),
            &store_with(&recs),
        );
        assert_eq!(d.cpu_milli, 2000);
        assert_eq!(d.request_cpu, 2000.0);
    }

    #[test]
    fn own_record_excluded_from_window_demand() {
        let mut s = store_with(&[]);
        s.put_task(
            "req-task",
            TaskRecord {
                workflow_uid: 1,
                t_start: 1.0,
                duration: 15.0,
                t_end: 16.0,
                cpu: 2000.0,
                mem: 4000.0,
                flag: false,
                estimated: true,
            },
        );
        let mut p = AdaptivePolicy::new(0.8, true);
        let d = decide_one(&mut p, &req((0.0, 15.0)), &snapshot(&[(8000.0, 16384.0); 6]), &s);
        // Only its own demand counts once.
        assert_eq!(d.request_cpu, 2000.0);
    }

    #[test]
    fn completed_records_not_counted() {
        let mut s = store_with(&[(1.0, 2000.0, 4000.0)]);
        s.update_task("w1-0", |r| r.flag = true);
        let mut p = AdaptivePolicy::new(0.8, true);
        let d = decide_one(&mut p, &req((0.0, 15.0)), &snapshot(&[(8000.0, 16384.0); 6]), &s);
        assert_eq!(d.request_cpu, 2000.0);
    }

    #[test]
    fn batch_overlay_counts_admitted_predecessors() {
        // Two batch members whose stored estimates lie *outside* each
        // other's windows: the overlay must still charge member 1 for
        // member 0 (admitted "now"), while member 0 sees member 1's
        // stale, out-of-window estimate and pays nothing.
        let mut s = StateStore::new();
        for (i, key) in ["b0", "b1"].iter().enumerate() {
            s.put_task(
                *key,
                TaskRecord {
                    workflow_uid: 1,
                    t_start: 900.0 + i as f64, // stale estimate far in the future
                    duration: 15.0,
                    t_end: 915.0 + i as f64,
                    cpu: 2000.0,
                    mem: 4000.0,
                    flag: false,
                    estimated: true,
                },
            );
        }
        let mk = |id: &str| TaskRequest {
            task_id: id.into(),
            req_cpu: 2000.0,
            req_mem: 4000.0,
            min_cpu: 200.0,
            min_mem: 1000.0,
            win_start: 0.0,
            win_end: 15.0,
        };
        let batch = vec![mk("b0"), mk("b1")];
        let mut p = AdaptivePolicy::new(0.8, true);
        let ds = p.plan(&batch, &snapshot(&[(8000.0, 16384.0); 6]), &s);
        assert_eq!(ds[0].request_cpu, 2000.0, "b0 sees only its own demand");
        assert_eq!(ds[1].request_cpu, 4000.0, "b1 pays for the admitted b0");
    }
}
