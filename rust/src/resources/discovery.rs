//! Resource Discovery — Algorithm 2.
//!
//! Builds the `ResidualMap` (per-node remaining CPU/memory) from the
//! Informer's cached `PodList`/`NodeList`, counting the requests of pods
//! in `Running` or `Pending` phase exactly as the paper's lines 6–13 do.
//! Reads touch only the informer cache — never the apiserver store.

use std::collections::BTreeMap;

use crate::cluster::store::WatchEvent;
use crate::cluster::Informer;

/// One node's entry in the ResidualMap (keyed by node IP, Alg. 2 line 22).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeResidual {
    pub ip: String,
    pub name: String,
    /// Node-pool label (heterogeneous clusters; "node" for the default
    /// pool). Lets pool-aware policies partition residuals per pool.
    pub pool: String,
    pub residual_cpu: f64,
    pub residual_mem: f64,
}

/// The dictionary Algorithm 2 returns, plus the cluster-level aggregates
/// Algorithm 1 computes from it (lines 16–23).
#[derive(Debug, Clone, Default)]
pub struct ResidualMap {
    pub entries: Vec<NodeResidual>,
}

impl ResidualMap {
    /// Total residual CPU across the cluster (Alg. 1 line 17).
    pub fn total_cpu(&self) -> f64 {
        self.entries.iter().map(|e| e.residual_cpu).sum()
    }

    /// Total residual memory across the cluster (Alg. 1 line 18).
    pub fn total_mem(&self) -> f64 {
        self.entries.iter().map(|e| e.residual_mem).sum()
    }

    /// (Re_max_cpu, Re_max_mem): the residuals *of the argmax-CPU node*
    /// — the paper assumes the max-CPU node also holds the max memory
    /// (Alg. 1 lines 19–22), so memory is reported from that same node.
    pub fn remax(&self) -> (f64, f64) {
        let mut best: Option<&NodeResidual> = None;
        for e in &self.entries {
            if best.map_or(true, |b| e.residual_cpu > b.residual_cpu) {
                best = Some(e);
            }
        }
        best.map_or((0.0, 0.0), |e| (e.residual_cpu, e.residual_mem))
    }

    /// Whether any node fits a (cpu, mem) request — the baseline's and
    /// scheduler's feasibility check.
    pub fn any_node_fits(&self, cpu: f64, mem: f64) -> bool {
        self.entries.iter().any(|e| e.residual_cpu >= cpu && e.residual_mem >= mem)
    }
}

/// Algorithm 2: ResourceDiscoveryAlgorithm.
pub fn discover(informer: &Informer) -> ResidualMap {
    // nodeReq accumulators per node (lines 6–13).
    let mut node_req: BTreeMap<&str, (i64, i64)> = BTreeMap::new();
    for pod in informer.pod_list() {
        if pod.phase.holds_resources() {
            if let Some(node) = pod.node.as_deref() {
                let e = node_req.entry_or_insert(node);
                e.0 += pod.request_cpu;
                e.1 += pod.request_mem;
            }
        }
    }
    // allocatable − nodeReq per node (lines 15–22). Cordoned (draining)
    // nodes are excluded: their remaining capacity cannot take new pods,
    // so counting it would let Eq. (9) hand out resources the scheduler
    // will refuse to bind.
    let mut entries = Vec::new();
    for node in informer.node_list() {
        if !node.schedulable {
            continue;
        }
        let (req_cpu, req_mem) = node_req.get(node.name.as_str()).copied().unwrap_or((0, 0));
        entries.push(NodeResidual {
            ip: node.ip.clone(),
            name: node.name.clone(),
            pool: node.pool.clone(),
            residual_cpu: (node.allocatable_cpu - req_cpu) as f64,
            residual_mem: (node.allocatable_mem - req_mem) as f64,
        });
    }
    ResidualMap { entries }
}

/// Incrementally maintained Algorithm 2 state: instead of folding the
/// whole `PodList` every serve cycle, per-pod request contributions are
/// kept alongside the aggregated per-node accumulators and updated from
/// the same watch events the informer applies (`Informer::sync_events`).
///
/// Residuals stay bit-exact with [`discover`] because the accumulators
/// are the same `i64` sums — integer addition is commutative and
/// associative, so add/remove order cannot change the result — and the
/// final `(allocatable − req) as f64` conversion is shared verbatim.
/// Node allocatable/schedulable state is always read fresh from the
/// informer cache at `residuals()` time, so node-side churn (join,
/// cordon, crash, chaos hogs shrinking allocatable) needs no delta
/// handling here.
#[derive(Debug, Default)]
pub struct IncrementalDiscovery {
    /// uid → (node, cpu, mem) for pods currently counted (bound +
    /// `holds_resources()`), i.e. each pod's live contribution to
    /// `node_req`.
    contrib: BTreeMap<u64, (String, i64, i64)>,
    /// Aggregated nodeReq accumulators (Alg. 2 lines 6–13), maintained
    /// by delta instead of recomputed.
    node_req: BTreeMap<String, (i64, i64)>,
    /// Lifetime watch-event deltas applied (observability counter — the
    /// incremental path's work metric, vs. full-fold pod walks).
    deltas_applied: u64,
}

impl IncrementalDiscovery {
    /// Build state from a full fold over the informer cache — used once
    /// at engine construction; thereafter only deltas are applied.
    pub fn prime(informer: &Informer) -> Self {
        let mut inc = Self::default();
        for pod in informer.pod_list() {
            inc.set_pod(pod.uid, informer);
        }
        inc
    }

    /// Apply one watch event *after* the informer has synced it, so the
    /// informer cache is the post-event truth we reconcile against.
    /// Reconciling against the cache (rather than interpreting the event
    /// kind) makes application idempotent: Added-then-Deleted nets to
    /// zero, Modified with no resource change is a no-op.
    pub fn apply(&mut self, ev: &WatchEvent, informer: &Informer) {
        self.deltas_applied += 1;
        match ev {
            WatchEvent::PodAdded(uid)
            | WatchEvent::PodModified(uid)
            | WatchEvent::PodDeleted(uid) => self.set_pod(*uid, informer),
            // Node and namespace events carry no pod-request deltas;
            // node state is read fresh in `residuals`.
            WatchEvent::NodeAdded(_)
            | WatchEvent::NodeModified(_)
            | WatchEvent::NodeDeleted(_)
            | WatchEvent::NamespaceAdded(_)
            | WatchEvent::NamespaceDeleted(_) => {}
        }
    }

    /// Reconcile one pod's contribution with the informer cache.
    fn set_pod(&mut self, uid: u64, informer: &Informer) {
        // Retract the old contribution, if any.
        if let Some((node, cpu, mem)) = self.contrib.remove(&uid) {
            if let Some(e) = self.node_req.get_mut(&node) {
                e.0 -= cpu;
                e.1 -= mem;
                if *e == (0, 0) {
                    // Keep the map tight: absent and (0,0) are
                    // equivalent in `discover`'s lookup too.
                    self.node_req.remove(&node);
                }
            }
        }
        // Count the new one exactly as Alg. 2 lines 6–13 filter.
        if let Some(pod) = informer.pod(uid) {
            if pod.phase.holds_resources() {
                if let Some(node) = pod.node.as_deref() {
                    let e = self.node_req.entry(node.to_string()).or_insert((0, 0));
                    e.0 += pod.request_cpu;
                    e.1 += pod.request_mem;
                    self.contrib
                        .insert(uid, (node.to_string(), pod.request_cpu, pod.request_mem));
                }
            }
        }
    }

    /// Algorithm 2 output from the maintained accumulators — same node
    /// walk and `(allocatable − req) as f64` arithmetic as [`discover`].
    pub fn residuals(&self, informer: &Informer) -> ResidualMap {
        let mut entries = Vec::new();
        for node in informer.node_list() {
            if !node.schedulable {
                continue;
            }
            let (req_cpu, req_mem) =
                self.node_req.get(node.name.as_str()).copied().unwrap_or((0, 0));
            entries.push(NodeResidual {
                ip: node.ip.clone(),
                name: node.name.clone(),
                pool: node.pool.clone(),
                residual_cpu: (node.allocatable_cpu - req_cpu) as f64,
                residual_mem: (node.allocatable_mem - req_mem) as f64,
            });
        }
        ResidualMap { entries }
    }

    /// Number of pods currently contributing requests (diagnostics).
    pub fn tracked_pods(&self) -> usize {
        self.contrib.len()
    }

    /// Lifetime watch-event deltas applied (diagnostics / exposition).
    pub fn deltas_applied(&self) -> u64 {
        self.deltas_applied
    }
}

// Small extension trait to keep the accumulation loop tidy.
trait EntryOrInsert<'a> {
    fn entry_or_insert(&mut self, key: &'a str) -> &mut (i64, i64);
}

impl<'a> EntryOrInsert<'a> for BTreeMap<&'a str, (i64, i64)> {
    fn entry_or_insert(&mut self, key: &'a str) -> &mut (i64, i64) {
        self.entry(key).or_insert((0, 0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::objects::{Node, Pod, PodPhase};
    use crate::cluster::ObjectStore;

    fn pod(uid: u64, node: &str, phase: PodPhase, cpu: i64, mem: i64) -> Pod {
        Pod {
            uid,
            name: format!("p{uid}"),
            namespace: "ns".into(),
            task_id: format!("t{uid}"),
            phase: PodPhase::Pending,
            node: Some(node.to_string()),
            request_cpu: cpu,
            request_mem: mem,
            min_mem: 1000,
            duration: 10.0,
            created_at: 0.0,
            started_at: None,
            finished_at: None,
        }
        .with_phase(phase)
    }

    trait WithPhase {
        fn with_phase(self, p: PodPhase) -> Pod;
    }
    impl WithPhase for Pod {
        fn with_phase(mut self, p: PodPhase) -> Pod {
            self.phase = p;
            self
        }
    }

    fn setup() -> Informer {
        let mut store = ObjectStore::new();
        store.add_node(Node::new(0, 8000, 16384));
        store.add_node(Node::new(1, 8000, 16384));
        store.create_pod(pod(1, "node-0", PodPhase::Running, 2000, 4000));
        store.create_pod(pod(2, "node-0", PodPhase::Pending, 1000, 2000));
        store.create_pod(pod(3, "node-1", PodPhase::Succeeded, 2000, 4000)); // ignored
        let mut inf = Informer::new();
        inf.sync(&store);
        inf
    }

    fn residual(name: &str, cpu: f64, mem: f64) -> NodeResidual {
        NodeResidual {
            ip: name.into(),
            name: name.into(),
            pool: "node".into(),
            residual_cpu: cpu,
            residual_mem: mem,
        }
    }

    #[test]
    fn residuals_count_pending_and_running_only() {
        let m = discover(&setup());
        assert_eq!(m.entries.len(), 2);
        let n0 = &m.entries[0];
        assert_eq!(n0.residual_cpu, 5000.0);
        assert_eq!(n0.residual_mem, 10384.0);
        let n1 = &m.entries[1];
        assert_eq!(n1.residual_cpu, 8000.0); // Succeeded pod released
    }

    #[test]
    fn cordoned_nodes_are_excluded_from_residuals() {
        let mut store = ObjectStore::new();
        store.add_node(Node::new(0, 8000, 16384));
        store.add_node(Node::new(1, 8000, 16384));
        store.set_schedulable("node-1", false);
        let mut inf = Informer::new();
        inf.sync(&store);
        let m = discover(&inf);
        assert_eq!(m.entries.len(), 1);
        assert_eq!(m.entries[0].name, "node-0");
        assert_eq!(m.total_cpu(), 8000.0);
        // Uncordon restores it.
        store.set_schedulable("node-1", true);
        inf.sync(&store);
        assert_eq!(discover(&inf).entries.len(), 2);
    }

    #[test]
    fn pool_labels_flow_into_the_residual_map() {
        let mut store = ObjectStore::new();
        store.add_node(Node::labeled("big", 0, 0, 16000, 32768));
        store.add_node(Node::labeled("small", 0, 1, 4000, 8192));
        let mut inf = Informer::new();
        inf.sync(&store);
        let m = discover(&inf);
        let pools: Vec<&str> = m.entries.iter().map(|e| e.pool.as_str()).collect();
        assert_eq!(pools, vec!["big", "small"]);
    }

    #[test]
    fn aggregates_match_paper_semantics() {
        let m = discover(&setup());
        assert_eq!(m.total_cpu(), 13000.0);
        assert_eq!(m.total_mem(), 26768.0);
        let (rc, rm) = m.remax();
        assert_eq!(rc, 8000.0);
        assert_eq!(rm, 16384.0); // mem of the argmax-CPU node
    }

    #[test]
    fn remax_reports_argmax_cpu_nodes_memory_not_global_max() {
        let m = ResidualMap {
            entries: vec![
                residual("a", 9000.0, 100.0),
                residual("b", 100.0, 16000.0),
            ],
        };
        // Paper's simplifying assumption: report (9000, 100), NOT (9000, 16000).
        assert_eq!(m.remax(), (9000.0, 100.0));
    }

    #[test]
    fn any_node_fits_is_per_node_not_total() {
        let m = ResidualMap {
            entries: vec![
                residual("a", 3000.0, 3000.0),
                residual("b", 3000.0, 3000.0),
            ],
        };
        assert!(m.any_node_fits(3000.0, 3000.0));
        assert!(!m.any_node_fits(4000.0, 1.0)); // total is 6000 but no node has 4000
    }

    #[test]
    fn empty_map_safe() {
        let m = ResidualMap::default();
        assert_eq!(m.total_cpu(), 0.0);
        assert_eq!(m.remax(), (0.0, 0.0));
        assert!(!m.any_node_fits(1.0, 1.0));
    }

    // ---- incremental discovery: bit-equality with the full fold ----

    /// Assert entry-for-entry, bit-for-bit equality of the two maps.
    fn assert_bit_equal(full: &ResidualMap, inc: &ResidualMap) {
        assert_eq!(full.entries.len(), inc.entries.len(), "entry count diverged");
        for (f, i) in full.entries.iter().zip(&inc.entries) {
            assert_eq!(f.name, i.name);
            assert_eq!(f.ip, i.ip);
            assert_eq!(f.pool, i.pool);
            assert_eq!(
                f.residual_cpu.to_bits(),
                i.residual_cpu.to_bits(),
                "cpu diverged on {}: full={} inc={}",
                f.name,
                f.residual_cpu,
                i.residual_cpu
            );
            assert_eq!(
                f.residual_mem.to_bits(),
                i.residual_mem.to_bits(),
                "mem diverged on {}: full={} inc={}",
                f.name,
                f.residual_mem,
                i.residual_mem
            );
        }
    }

    /// Sync the informer via `sync_events`, feed every event to the
    /// incremental state, then check it against a fresh full `discover`.
    fn sync_and_check(store: &ObjectStore, inf: &mut Informer, inc: &mut IncrementalDiscovery) {
        for (_, ev) in inf.sync_events(store) {
            inc.apply(&ev, inf);
        }
        assert_bit_equal(&discover(inf), &inc.residuals(inf));
    }

    #[test]
    fn incremental_tracks_pod_lifecycle() {
        let mut store = ObjectStore::new();
        store.add_node(Node::new(0, 8000, 16384));
        store.add_node(Node::new(1, 8000, 16384));
        let mut inf = Informer::new();
        inf.sync(&store);
        let mut inc = IncrementalDiscovery::prime(&inf);
        assert_bit_equal(&discover(&inf), &inc.residuals(&inf));

        // Add: pending pods bound to nodes count immediately.
        store.create_pod(pod(1, "node-0", PodPhase::Pending, 2000, 4000));
        store.create_pod(pod(2, "node-1", PodPhase::Pending, 1000, 2000));
        sync_and_check(&store, &mut inf, &mut inc);
        assert_eq!(inc.tracked_pods(), 2);

        // Modify: Running still holds resources; Succeeded releases.
        store.set_pod_phase(1, PodPhase::Running, 1.0);
        sync_and_check(&store, &mut inf, &mut inc);
        store.set_pod_phase(2, PodPhase::Succeeded, 2.0);
        sync_and_check(&store, &mut inf, &mut inc);
        assert_eq!(inc.tracked_pods(), 1);

        // Delete: contribution fully retracted.
        store.delete_pod(1);
        store.delete_pod(2);
        sync_and_check(&store, &mut inf, &mut inc);
        assert_eq!(inc.tracked_pods(), 0);
    }

    #[test]
    fn incremental_add_then_delete_between_syncs_nets_zero() {
        let mut store = ObjectStore::new();
        store.add_node(Node::new(0, 8000, 16384));
        let mut inf = Informer::new();
        inf.sync(&store);
        let mut inc = IncrementalDiscovery::prime(&inf);

        // Both events arrive in one sync batch; the cache already shows
        // the pod gone when PodAdded is applied.
        store.create_pod(pod(7, "node-0", PodPhase::Pending, 3000, 3000));
        store.delete_pod(7);
        sync_and_check(&store, &mut inf, &mut inc);
        assert_eq!(inc.tracked_pods(), 0);
        assert_eq!(inc.residuals(&inf).total_cpu(), 8000.0);
    }

    #[test]
    fn incremental_survives_node_churn_and_allocatable_changes() {
        let mut store = ObjectStore::new();
        store.add_node(Node::new(0, 8000, 16384));
        store.add_node(Node::new(1, 8000, 16384));
        store.create_pod(pod(1, "node-0", PodPhase::Running, 2000, 4000));
        store.create_pod(pod(2, "node-1", PodPhase::Running, 1000, 2000));
        let mut inf = Informer::new();
        inf.sync(&store);
        let mut inc = IncrementalDiscovery::prime(&inf);

        // Join, cordon, chaos-hog allocatable shrink, crash-removal:
        // all node-side — residuals() reads them fresh every time.
        store.add_node(Node::labeled("big", 1, 2, 16000, 32768));
        sync_and_check(&store, &mut inf, &mut inc);
        store.set_schedulable("node-0", false);
        sync_and_check(&store, &mut inf, &mut inc);
        store.adjust_allocatable("node-1", -1500, -1024);
        sync_and_check(&store, &mut inf, &mut inc);
        store.adjust_allocatable("node-1", 1500, 1024);
        sync_and_check(&store, &mut inf, &mut inc);
        store.set_schedulable("node-0", true);
        sync_and_check(&store, &mut inf, &mut inc);

        // Node removed while its pod record still exists: the stale
        // node_req entry is unreachable (no node walk hits it) and must
        // not corrupt other nodes.
        store.delete_pod(2);
        store.remove_node("node-1");
        sync_and_check(&store, &mut inf, &mut inc);
    }

    #[test]
    fn incremental_matches_full_under_randomized_churn() {
        use crate::simcore::Rng;
        let mut store = ObjectStore::new();
        for i in 0..4 {
            store.add_node(Node::new(i, 8000, 16384));
        }
        let mut inf = Informer::new();
        inf.sync(&store);
        let mut inc = IncrementalDiscovery::prime(&inf);

        let mut rng = Rng::new(0xD15C0);
        let mut live: Vec<u64> = Vec::new();
        let mut next_uid = 1u64;
        for step in 0..400u64 {
            match rng.below(4) {
                0 => {
                    let node = format!("node-{}", rng.below(4));
                    let cpu = 100 + rng.below(2000) as i64;
                    let mem = 100 + rng.below(4000) as i64;
                    store.create_pod(pod(next_uid, &node, PodPhase::Pending, cpu, mem));
                    live.push(next_uid);
                    next_uid += 1;
                }
                1 if !live.is_empty() => {
                    let uid = live[rng.below(live.len() as u64) as usize];
                    store.set_pod_phase(uid, PodPhase::Running, step as f64);
                }
                2 if !live.is_empty() => {
                    let idx = rng.below(live.len() as u64) as usize;
                    store.set_pod_phase(live[idx], PodPhase::Succeeded, step as f64);
                }
                3 if !live.is_empty() => {
                    let idx = rng.below(live.len() as u64) as usize;
                    let uid = live.swap_remove(idx);
                    store.delete_pod(uid);
                }
                _ => {}
            }
            // Sync only every few steps so batches carry mixed events.
            if step % 3 == 0 {
                sync_and_check(&store, &mut inf, &mut inc);
            }
        }
        sync_and_check(&store, &mut inf, &mut inc);
    }
}
