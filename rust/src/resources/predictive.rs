//! `predictive` — ARAS augmented with forecast demand.
//!
//! Algorithm 1's lifecycle-window aggregation only sees task records
//! that already exist in the Knowledge base, so ARAS is blind to
//! workflows that will *arrive* during the pod it is sizing. The
//! predictive policy closes that gap with the run's
//! [`crate::forecast::DemandForecast`] (attached to each
//! [`ClusterSnapshot`] by the engine): every request's window demand is
//! additionally charged with the load the forecaster expects to arrive
//! inside it —
//!
//! ```text
//! expected = arrival_rate × (win_end − win_start) × weight
//! extra    = (expected × req_cpu, expected × req_mem)
//! ```
//!
//! appended as one synthetic record at the window start (arriving
//! workflows request the same uniform task shape, §6.1.3). Under bursty
//! arrivals this scales allocations down *before* the burst lands,
//! keeping the allocation queue flowing instead of reacting after the
//! head stalls.
//!
//! With no forecast on the snapshot — forecasting disabled, or no
//! observations yet — the policy is bit-identical to `adaptive`
//! (regression-tested in the engine and locked by the golden harness).

use super::adaptive::AdaptivePolicy;
use super::{ClusterSnapshot, Decision, Policy, TaskRequest};
use crate::simcore::SimTime;
use crate::statestore::StateStore;

/// ARAS over a forecast-augmented demand window.
pub struct PredictivePolicy {
    inner: AdaptivePolicy,
    weight: f64,
}

impl PredictivePolicy {
    /// Default scaling of the forecast demand term.
    pub const DEFAULT_WEIGHT: f64 = 1.0;

    pub fn new(inner: AdaptivePolicy, weight: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(
            weight.is_finite() && weight >= 0.0,
            "predictive weight must be finite and >= 0, got {weight}"
        );
        Ok(Self { inner, weight })
    }

    pub fn backend_name(&self) -> &'static str {
        self.inner.backend_name()
    }
}

impl Policy for PredictivePolicy {
    fn name(&self) -> &str {
        "predictive"
    }

    fn plan(
        &mut self,
        batch: &[TaskRequest],
        snapshot: &ClusterSnapshot,
        store: &StateStore,
    ) -> Vec<Decision> {
        let Some(fc) = snapshot.forecast else {
            // No forecast: exactly ARAS.
            return self.inner.plan(batch, snapshot, store);
        };
        let mut inputs = self.inner.gather_batch_inputs(batch, snapshot, store);
        for (input, req) in inputs.iter_mut().zip(batch) {
            let window = (req.win_end - req.win_start).max(0.0);
            let expected = fc.arrival_rate * window * self.weight;
            if expected > 0.0 {
                // One synthetic record at the window start; appended
                // last so the f32 summation order of the real records
                // is untouched.
                input.records.push((
                    input.win_start,
                    (expected * req.req_cpu) as f32,
                    (expected * req.req_mem) as f32,
                ));
            }
        }
        self.inner.decide_inputs(&inputs)
    }

    fn on_release(&mut self, now: SimTime) {
        self.inner.on_release(now);
    }

    fn on_oom(&mut self, task_id: &str, now: SimTime) {
        self.inner.on_oom(task_id, now);
    }

    fn on_tick(&mut self, now: SimTime) {
        self.inner.on_tick(now);
    }

    fn reactive_monitoring(&self) -> bool {
        self.inner.reactive_monitoring()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::forecast::DemandForecast;
    use crate::resources::discovery::{NodeResidual, ResidualMap};

    fn snapshot(forecast: Option<DemandForecast>) -> ClusterSnapshot {
        let mut snap = ClusterSnapshot::from_residuals(ResidualMap {
            entries: (0..6)
                .map(|i| NodeResidual {
                    ip: format!("10.0.0.{i}"),
                    name: format!("node-{i}"),
                    pool: "node".into(),
                    residual_cpu: 8000.0,
                    residual_mem: 16384.0,
                })
                .collect(),
        });
        snap.forecast = forecast;
        snap
    }

    fn req() -> TaskRequest {
        TaskRequest {
            task_id: "t".into(),
            req_cpu: 2000.0,
            req_mem: 4000.0,
            min_cpu: 200.0,
            min_mem: 1000.0,
            win_start: 0.0,
            win_end: 15.0,
        }
    }

    fn forecast(arrival_rate: f64) -> DemandForecast {
        DemandForecast {
            horizon_s: 60.0,
            cpu_demand: 0.0,
            mem_demand: 0.0,
            queue_len: 0.0,
            arrival_rate,
        }
    }

    fn predictive(weight: f64) -> PredictivePolicy {
        PredictivePolicy::new(AdaptivePolicy::new(0.8, true), weight).unwrap()
    }

    #[test]
    fn without_forecast_matches_adaptive_bit_for_bit() {
        let store = StateStore::new();
        let mut p = predictive(PredictivePolicy::DEFAULT_WEIGHT);
        let mut a = AdaptivePolicy::new(0.8, true);
        let snap = snapshot(None);
        let dp = p.plan(&[req()], &snap, &store);
        let da = a.plan(&[req()], &snap, &store);
        assert_eq!(dp, da);
    }

    #[test]
    fn forecast_demand_scales_the_allocation_down() {
        let store = StateStore::new();
        // 2 workflows/s over a 15 s window = 30 expected arrivals, each
        // charged at the request shape: demand 2000 + 30*2000 = 62000m
        // vs 48000m residual → the Eq. 9 cut (same arithmetic as the
        // adaptive contended_request_scaled_down test).
        let mut p = predictive(1.0);
        let d = p.plan(&[req()], &snapshot(Some(forecast(2.0))), &store)[0];
        assert_eq!(d.request_cpu, 62000.0);
        assert_eq!(d.cpu_milli, 1548);
        assert!(d.mem_mi < 4000);
    }

    #[test]
    fn zero_weight_ignores_the_forecast() {
        let store = StateStore::new();
        let mut p = predictive(0.0);
        let d = p.plan(&[req()], &snapshot(Some(forecast(2.0))), &store)[0];
        assert_eq!(d.cpu_milli, 2000);
        assert_eq!(d.mem_mi, 4000);
    }

    #[test]
    fn zero_arrival_rate_forecast_changes_nothing() {
        let store = StateStore::new();
        let mut p = predictive(1.0);
        let d = p.plan(&[req()], &snapshot(Some(forecast(0.0))), &store)[0];
        assert_eq!(d.cpu_milli, 2000);
    }

    #[test]
    fn weight_is_validated() {
        assert!(PredictivePolicy::new(AdaptivePolicy::new(0.8, true), -1.0).is_err());
        assert!(PredictivePolicy::new(AdaptivePolicy::new(0.8, true), f64::NAN).is_err());
        assert!(PredictivePolicy::new(AdaptivePolicy::new(0.8, true), 0.5).is_ok());
    }
}
