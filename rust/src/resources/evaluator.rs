//! Resource Evaluator — Algorithm 3 + Eq. (9), scalar reference path.
//!
//! Implemented in **f32 with the exact op order of the Pallas kernel**
//! (`python/compile/kernels/alloc_eval.py`) so the scalar and PJRT
//! backends agree bit-for-bit on integral inputs — enforced by
//! `rust/tests/backend_parity.rs` (and pinned to the jnp oracle by the
//! committed golden vectors). Keep the twins in sync: this file,
//! `runtime/native.rs`, and the Pallas kernels.

/// Cluster aggregates consumed by the evaluator (Alg. 1 lines 16–23).
#[derive(Debug, Clone, Copy)]
pub struct ClusterAggregates {
    pub total_res_cpu: f32,
    pub total_res_mem: f32,
    pub remax_cpu: f32,
    pub remax_mem: f32,
    pub alpha: f32,
}

/// Eq. (9): scale the request by total-residual / total-demand.
/// Division guarded for the degenerate zero-demand case exactly like the
/// kernel (`max(request, 1.0)`).
#[inline]
pub fn resource_cut(req: f32, total_residual: f32, request_total: f32) -> f32 {
    req * (total_residual / request_total.max(1.0))
}

/// Algorithm 3: the four-regime evaluation. Returns (alloc_cpu, alloc_mem).
///
/// `req_*` is the current task's own demand; `request_*` the aggregated
/// demand of all tasks competing within its lifecycle window.
pub fn alloc_eval(
    req_cpu: f32,
    req_mem: f32,
    request_cpu: f32,
    request_mem: f32,
    agg: &ClusterAggregates,
) -> (f32, f32) {
    let cpu_cut = resource_cut(req_cpu, agg.total_res_cpu, request_cpu);
    let mem_cut = resource_cut(req_mem, agg.total_res_mem, request_mem);

    let a1 = request_cpu < agg.total_res_cpu;
    let a2 = request_mem < agg.total_res_mem;
    let b1 = req_cpu < agg.remax_cpu;
    let b2 = req_mem < agg.remax_mem;
    let c1 = cpu_cut < agg.remax_cpu;
    let c2 = mem_cut < agg.remax_mem;

    let remax_cpu_a = agg.remax_cpu * agg.alpha;
    let remax_mem_a = agg.remax_mem * agg.alpha;

    // CPU: regime 1/3 (A1) -> B1 ? req : remax*α
    //      regime 2 (!A1 & A2) -> C1 ? cut : remax*α
    //      regime 4 (!A1 & !A2) -> cut
    let cpu_suff = if b1 { req_cpu } else { remax_cpu_a };
    let cpu_insuff = if c1 { cpu_cut } else { remax_cpu_a };
    let alloc_cpu = if a1 { cpu_suff } else if a2 { cpu_insuff } else { cpu_cut };

    // Memory mirrors with regimes 2/3 swapped.
    let mem_suff = if b2 { req_mem } else { remax_mem_a };
    let mem_insuff = if c2 { mem_cut } else { remax_mem_a };
    let alloc_mem = if a2 { mem_suff } else if a1 { mem_insuff } else { mem_cut };

    (alloc_cpu, alloc_mem)
}

/// Lifecycle-window demand aggregation (Algorithm 1 lines 4–13), the
/// scalar twin of the `overlap` Pallas kernel: sum the requests of every
/// record whose start falls in `[win_start, win_end)`.
pub fn window_demand(
    records: impl Iterator<Item = (f32, f32, f32)>, // (t_start, cpu, mem)
    win_start: f32,
    win_end: f32,
    req_cpu: f32,
    req_mem: f32,
) -> (f32, f32) {
    let mut cpu = req_cpu;
    let mut mem = req_mem;
    for (t_start, c, m) in records {
        if t_start >= win_start && t_start < win_end {
            cpu += c;
            mem += m;
        }
    }
    (cpu, mem)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn agg() -> ClusterAggregates {
        ClusterAggregates {
            total_res_cpu: 40000.0,
            total_res_mem: 90000.0,
            remax_cpu: 7000.0,
            remax_mem: 15000.0,
            alpha: 0.8,
        }
    }

    #[test]
    fn regime1_grants_request() {
        let (c, m) = alloc_eval(1000.0, 2000.0, 5000.0, 5000.0, &agg());
        assert_eq!((c, m), (1000.0, 2000.0));
    }

    #[test]
    fn regime1_clamps_oversized_cpu_to_alpha_remax() {
        let (c, m) = alloc_eval(9000.0, 2000.0, 9000.0, 2000.0, &agg());
        assert_eq!(c, 7000.0 * 0.8);
        assert_eq!(m, 2000.0);
    }

    #[test]
    fn regime1_clamps_oversized_mem_to_alpha_remax() {
        let (c, m) = alloc_eval(1000.0, 20000.0, 1000.0, 20000.0, &agg());
        // request_mem=20000 < total 90000 so A2 holds; B2 fails.
        assert_eq!(c, 1000.0);
        assert_eq!(m, 15000.0 * 0.8);
    }

    #[test]
    fn regime2_scales_cpu_by_eq9() {
        // request.cpu 50000 >= total 40000 -> !A1; mem fine.
        let (c, m) = alloc_eval(2000.0, 2000.0, 50000.0, 2000.0, &agg());
        assert_eq!(c, 2000.0 * (40000.0 / 50000.0));
        assert_eq!(m, 2000.0);
    }

    #[test]
    fn regime2_cut_exceeding_remax_falls_to_alpha() {
        let a = ClusterAggregates { remax_cpu: 1000.0, ..agg() };
        // cut = 2000*40000/50000 = 1600 >= remax 1000 -> remax*α
        let (c, _) = alloc_eval(2000.0, 2000.0, 50000.0, 2000.0, &a);
        assert_eq!(c, 1000.0 * 0.8);
    }

    #[test]
    fn regime3_scales_mem_by_eq9() {
        let (c, m) = alloc_eval(2000.0, 4000.0, 2000.0, 100000.0, &agg());
        assert_eq!(c, 2000.0);
        assert_eq!(m, 4000.0 * (90000.0 / 100000.0));
    }

    #[test]
    fn regime4_scales_both_unconditionally() {
        let (c, m) = alloc_eval(2000.0, 4000.0, 50000.0, 100000.0, &agg());
        assert_eq!(c, 2000.0 * (40000.0 / 50000.0));
        assert_eq!(m, 4000.0 * (90000.0 / 100000.0));
    }

    #[test]
    fn boundary_equal_demand_is_insufficient() {
        // Strict '<' in all paper conditions: equality counts as insufficient.
        let (c, _) = alloc_eval(2000.0, 100.0, 40000.0, 100.0, &agg());
        assert_eq!(c, 2000.0 * (40000.0 / 40000.0)); // regime 2 cut (== req here)
    }

    #[test]
    fn window_demand_half_open() {
        let recs = vec![(10.0, 100.0, 200.0), (20.0, 100.0, 200.0), (5.0, 100.0, 200.0)];
        let (c, m) = window_demand(recs.into_iter(), 10.0, 20.0, 50.0, 60.0);
        assert_eq!(c, 150.0); // only t_start=10 inside [10,20)
        assert_eq!(m, 260.0);
    }

    #[test]
    fn zero_total_demand_guard() {
        // Padded/degenerate: request == 0 -> division by max(0,1)=1, no NaN.
        let v = resource_cut(0.0, 40000.0, 0.0);
        assert_eq!(v, 0.0);
    }
}
