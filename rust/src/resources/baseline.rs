//! The baseline policy (§6.1.6): the authors' prior resource-allocation
//! strategy [21] — First-Come-First-Serve with full requests and no
//! lookahead. The allocation is always the user-declared request; if no
//! node currently fits, the request *waits* for other task pods to
//! release resources (the engine's retry loop).

use super::{ClusterSnapshot, Decision, Policy, TaskRequest};
use crate::statestore::StateStore;

#[derive(Debug, Default)]
pub struct FcfsPolicy {
    decisions: u64,
}

impl FcfsPolicy {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn decision_count(&self) -> u64 {
        self.decisions
    }
}

impl Policy for FcfsPolicy {
    fn name(&self) -> &str {
        "baseline"
    }

    fn plan(
        &mut self,
        batch: &[TaskRequest],
        _snapshot: &ClusterSnapshot,
        _store: &StateStore,
    ) -> Vec<Decision> {
        self.decisions += batch.len() as u64;
        // FCFS: allocate exactly what was asked; feasibility (a node with
        // enough residual) is the scheduler's problem — if nothing fits,
        // the engine waits and retries, matching the paper's description
        // of "endless waiting" under high concurrency. Each decision
        // depends only on its own request, so the batch is trivially
        // equivalent to sequential service.
        batch
            .iter()
            .map(|req| Decision {
                cpu_milli: req.req_cpu as i64,
                mem_mi: req.req_mem as i64,
                request_cpu: req.req_cpu,
                request_mem: req.req_mem,
            })
            .collect()
    }

    /// Baseline [21] predates the Informer-driven monitoring mechanism:
    /// stalled requests recover only on the periodic resync timer.
    fn reactive_monitoring(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::ResidualMap;

    #[test]
    fn always_grants_full_request() {
        let mut p = FcfsPolicy::new();
        let req = TaskRequest {
            task_id: "t".into(),
            req_cpu: 2000.0,
            req_mem: 4000.0,
            min_cpu: 200.0,
            min_mem: 1000.0,
            win_start: 0.0,
            win_end: 15.0,
        };
        let snap = ClusterSnapshot::from_residuals(ResidualMap::default());
        let d = p.plan(std::slice::from_ref(&req), &snap, &StateStore::new())[0];
        assert_eq!(d.cpu_milli, 2000);
        assert_eq!(d.mem_mi, 4000);
        assert_eq!(p.decision_count(), 1);

        // Batched service is position-independent.
        let ds = p.plan(&[req.clone(), req], &snap, &StateStore::new());
        assert_eq!(ds[0], ds[1]);
        assert_eq!(p.decision_count(), 3);
    }
}
