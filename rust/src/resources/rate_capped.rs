//! `rate-capped` — ARAS with a per-cycle scaling budget.
//!
//! Operators are often wary of letting an autoscaler shrink *every*
//! pod in a burst at once (ARC-V/AHPA-style vertical adaptivity papers
//! cap their actuation rate for the same reason). This policy runs the
//! full ARAS plan for the cycle's batch, then lets at most `budget`
//! requests per queue-serve cycle keep a scaled-down quota; any further
//! scaled request in the same cycle falls back to its full declared
//! request (FCFS-like), so it waits instead of shrinking. `budget = 0`
//! degenerates to the FCFS baseline's allocations (with reactive
//! monitoring); a budget larger than any batch is plain ARAS.
//!
//! Registered in [`super::registry`] as the second registry-proving
//! policy — it wraps [`AdaptivePolicy`] without the engine, config or
//! campaign layers knowing it exists.
//!
//! This is a deliberately **cycle-scoped** policy (see the
//! [`Policy`](super::Policy) contract): the budget applies per `plan()`
//! call (normally one per queue-serve cycle; the engine's stalled-head
//! probe may split a cycle into a head call plus a rest call), so how
//! requests group into batches is part of its semantics — it
//! intentionally does *not* satisfy the sequential-equivalence property
//! that request-scoped policies (ARAS, FCFS, static-headroom) uphold.
//! Each individual decision is still either the ARAS quota or the full
//! request, so prefix-only service by the engine remains valid.

use super::adaptive::AdaptivePolicy;
use super::{ClusterSnapshot, Decision, Policy, TaskRequest};
use crate::statestore::StateStore;

/// Default per-cycle scaling budget.
pub const DEFAULT_BUDGET: usize = 4;

pub struct RateCappedPolicy {
    inner: AdaptivePolicy,
    budget: usize,
    /// Decisions forced back to the full request by the cap (diagnostics).
    capped: u64,
}

impl RateCappedPolicy {
    pub fn new(alpha: f64, lookahead: bool, budget: usize) -> Self {
        Self::with_inner(AdaptivePolicy::new(alpha, lookahead), budget)
    }

    /// Wrap an already-assembled ARAS core (the registry uses this so
    /// the inner policy carries whatever backend `alloc.backend` chose).
    pub fn with_inner(inner: AdaptivePolicy, budget: usize) -> Self {
        Self { inner, budget, capped: 0 }
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn capped_count(&self) -> u64 {
        self.capped
    }
}

impl Policy for RateCappedPolicy {
    fn name(&self) -> &str {
        "rate-capped"
    }

    fn plan(
        &mut self,
        batch: &[TaskRequest],
        snapshot: &ClusterSnapshot,
        store: &StateStore,
    ) -> Vec<Decision> {
        let mut decisions = self.inner.plan(batch, snapshot, store);
        let mut scaled = 0usize;
        for (decision, req) in decisions.iter_mut().zip(batch) {
            let is_scaled = (decision.cpu_milli as f64) < req.req_cpu
                || (decision.mem_mi as f64) < req.req_mem;
            if !is_scaled {
                continue;
            }
            if scaled < self.budget {
                scaled += 1;
            } else {
                // Budget exhausted: restore the declared request, keep
                // the aggregated-demand diagnostics ARAS computed.
                decision.cpu_milli = req.req_cpu as i64;
                decision.mem_mi = req.req_mem as i64;
                self.capped += 1;
            }
        }
        decisions
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::discovery::{NodeResidual, ResidualMap};
    use crate::statestore::TaskRecord;

    fn snapshot() -> ClusterSnapshot {
        ClusterSnapshot::from_residuals(ResidualMap {
            entries: (0..6)
                .map(|i| NodeResidual {
                    ip: format!("10.0.0.{i}"),
                    name: format!("node-{i}"),
                    pool: "node".into(),
                    residual_cpu: 8000.0,
                    residual_mem: 16384.0,
                })
                .collect(),
        })
    }

    /// A store crowded enough that ARAS scales every request down.
    fn crowded_store() -> StateStore {
        let mut s = StateStore::new();
        for i in 0..30 {
            s.put_task(
                format!("w1-{i}"),
                TaskRecord {
                    workflow_uid: 1,
                    t_start: 1.0,
                    duration: 15.0,
                    t_end: 16.0,
                    cpu: 2000.0,
                    mem: 4000.0,
                    flag: false,
                    estimated: true,
                },
            );
        }
        s
    }

    fn batch(n: usize) -> Vec<TaskRequest> {
        (0..n)
            .map(|i| TaskRequest {
                task_id: format!("b{i}"),
                req_cpu: 2000.0,
                req_mem: 4000.0,
                min_cpu: 200.0,
                min_mem: 1000.0,
                win_start: 0.0,
                win_end: 15.0,
            })
            .collect()
    }

    #[test]
    fn cap_limits_scaled_decisions_per_cycle() {
        let mut p = RateCappedPolicy::new(0.8, true, 2);
        let ds = p.plan(&batch(5), &snapshot(), &crowded_store());
        let scaled = ds.iter().filter(|d| d.cpu_milli < 2000).count();
        assert_eq!(scaled, 2, "exactly the budget may scale: {ds:?}");
        assert_eq!(p.capped_count(), 3);
        for d in &ds[2..] {
            assert_eq!((d.cpu_milli, d.mem_mi), (2000, 4000));
        }
    }

    #[test]
    fn zero_budget_matches_fcfs_allocations() {
        let mut p = RateCappedPolicy::new(0.8, true, 0);
        let ds = p.plan(&batch(3), &snapshot(), &crowded_store());
        for d in &ds {
            assert_eq!((d.cpu_milli, d.mem_mi), (2000, 4000));
        }
    }

    #[test]
    fn generous_budget_is_plain_aras() {
        let mut capped = RateCappedPolicy::new(0.8, true, usize::MAX);
        let mut aras = AdaptivePolicy::new(0.8, true);
        let b = batch(4);
        let a = capped.plan(&b, &snapshot(), &crowded_store());
        let e = aras.plan(&b, &snapshot(), &crowded_store());
        assert_eq!(a, e);
    }
}
